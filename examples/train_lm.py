"""End-to-end driver: train a (reduced) assigned-architecture LM for a few
hundred steps on CPU with the full production stack — data pipeline, AdamW,
checkpointing, crash recovery, straggler detection.

  PYTHONPATH=src python examples/train_lm.py [--arch qwen3_4b] [--steps 200]
"""
import argparse
import json
import tempfile

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    from repro.configs.base import get_smoke_arch
    from repro.data.pipeline import SyntheticLM
    from repro.models.model_zoo import build
    from repro.train.train_loop import train

    model = build(get_smoke_arch(args.arch))
    cfg = model.cfg
    data = SyntheticLM(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.batch,
        n_prefix_tokens=cfg.n_prefix_tokens if cfg.modality == "vision" else 0,
        frontend_dim=cfg.frontend_dim, family=cfg.family)

    with tempfile.TemporaryDirectory() as ckdir:
        report = train(
            model, data, steps=args.steps, lr=1e-3, warmup=20,
            checkpoint_dir=ckdir, checkpoint_every=50, log_every=20)
    hist = report["history"]
    print(json.dumps({
        "arch": cfg.name,
        "params": sum(int(p.size) for p in
                      jax.tree_util.tree_leaves(report["params"])),
        "first_loss": hist[0]["loss"],
        "last_loss": hist[-1]["loss"],
        "steps": report["final_step"],
        "restarts": report["restarts"],
    }, indent=1))
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"


if __name__ == "__main__":
    main()
