"""The paper's serving scenario, end to end: an LM generates tokens, the
bitstream is convolutionally encoded, corrupted by a noisy channel, and
recovered through the unified decode API — the '10^15 bits/day digital TV'
pipeline with a modern source.

The codec and packing constants come from configs/paper_viterbi.py (the same
spec the benchmarks use); the backend is chosen by repro.decode.plan_decode.

  PYTHONPATH=src python examples/serve_viterbi.py
"""
import jax

from repro.configs.base import get_smoke_arch
from repro.configs.paper_viterbi import (
    DECODE_SPEC,
    DECODE_SPEC_SOFT,
    SERVE_BITS_PER_TOKEN,
)
from repro.decode import DecodeRequest, decode
from repro.models.model_zoo import build
from repro.serve import ServeEngine, bits_to_tokens, tokens_to_bits


def main():
    # --- source: a (reduced) qwen2.5 generates a token stream -------------- #
    model = build(get_smoke_arch("qwen2_5_3b"))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=48, temperature=0.8)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 1, model.cfg.vocab)
    toks = engine.generate(prompts, max_new_tokens=32, seed=7)["tokens"]
    print(f"LM emitted {toks.shape[0]}x{toks.shape[1]} tokens")

    # --- transport: conv-encode, noisy channel, planned decode ------------- #
    bits = tokens_to_bits(toks, bits_per_token=SERVE_BITS_PER_TOKEN)
    spec = DECODE_SPEC
    coded = spec.encode(bits)
    for i, flip in enumerate((0.0, 0.01, 0.03)):
        rx = spec.channel(jax.random.fold_in(jax.random.PRNGKey(2), i),
                          coded, flip_prob=flip)
        res = decode(DecodeRequest(spec, received=rx))
        if i == 0:
            print(res.plan.explain())
        exact = bool((res.info_bits == bits).all())
        ber = float((res.info_bits != bits).mean())
        status = "EXACT" if exact else f"BER={ber:.4f}"
        print(f"channel flip={flip:5.2f}: decode {status}")
        if exact:
            rec = bits_to_tokens(res.info_bits, SERVE_BITS_PER_TOKEN)
            assert (rec == toks).all()

    # soft-decision variant over an AWGN channel
    spec_soft = DECODE_SPEC_SOFT
    rx = spec_soft.channel(jax.random.PRNGKey(3), spec_soft.encode(bits), snr_db=3.0)
    res = decode(DecodeRequest(spec_soft, received=rx))
    ber = float((res.info_bits != bits).mean())
    exact = bool((res.info_bits == bits).all())
    print(f"AWGN 3dB soft decode: {'EXACT' if exact else f'BER={ber:.4f}'}")


if __name__ == "__main__":
    main()
