"""The paper's serving scenario, end to end: an LM generates tokens, the
bitstream is convolutionally encoded, corrupted by a noisy channel, and
recovered by the fused Viterbi head — the '10^15 bits/day digital TV'
pipeline with a modern source.

  PYTHONPATH=src python examples/serve_viterbi.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_arch
from repro.models.model_zoo import build
from repro.serve.engine import ServeEngine
from repro.serve.viterbi_head import ViterbiHead, bits_to_tokens, tokens_to_bits


def main():
    # --- source: a (reduced) qwen2.5 generates a token stream -------------- #
    model = build(get_smoke_arch("qwen2_5_3b"))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=48, temperature=0.8)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 1, model.cfg.vocab)
    toks = engine.generate(prompts, max_new_tokens=32, seed=7)["tokens"]
    print(f"LM emitted {toks.shape[0]}x{toks.shape[1]} tokens")

    # --- transport: conv-encode, noisy channel, Viterbi decode ------------- #
    bits = tokens_to_bits(toks, bits_per_token=9)  # vocab 512 -> 9 bits
    head = ViterbiHead(mode="fused")
    for flip in (0.0, 0.01, 0.03):
        dec, ber, exact = head.roundtrip(jax.random.PRNGKey(2), bits,
                                         flip_prob=flip)
        status = "EXACT" if exact else f"BER={float(ber):.4f}"
        print(f"channel flip={flip:5.2f}: decode {status}")
        if exact:
            rec = bits_to_tokens(dec, 9)
            assert (rec == toks).all()
    # soft-decision variant over an AWGN channel
    soft_head = ViterbiHead(mode="fused", soft=True)
    dec, ber, exact = soft_head.roundtrip(jax.random.PRNGKey(3), bits, snr_db=3.0)
    print(f"AWGN 3dB soft decode: {'EXACT' if exact else f'BER={float(ber):.4f}'}")


if __name__ == "__main__":
    main()
