"""Long-stream decoding two ways (the technique the paper's future-work
section gestures at — parallel execution of the custom instruction):

1. a 64k-bit coded stream decoded by the (min,+) associative scan
   (log-depth, the block-parallel form of the paper's ACS recurrence);
2. the same decode distributed over a mesh axis with shard_map
   (sequence-parallel Viterbi — communication independent of T);
3. an SSM-family LM (xlstm) decoding with O(1) state, the architectural
   cousin of the same recurrence trick.

  PYTHONPATH=src python examples/long_context.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import CODE_K3_STD, bsc, encode, hard_branch_metrics
from repro.core.viterbi import viterbi_decode, viterbi_decode_parallel


def main():
    code = CODE_K3_STD
    key = jax.random.PRNGKey(0)
    T = 65536
    bits = jax.random.bernoulli(key, 0.5, (1, T)).astype(jnp.int32)
    rx = bsc(jax.random.fold_in(key, 1), encode(code, bits, terminate=True), 0.01)
    bm = hard_branch_metrics(code, rx)

    seq = jax.jit(lambda b: viterbi_decode(code, b))
    par = jax.jit(lambda b: viterbi_decode_parallel(code, b, chunk=512))
    d1, m1 = seq(bm)
    d2, m2 = par(bm)
    jax.block_until_ready((d1, d2))
    assert jnp.allclose(m1, m2) and (d1 == d2).all()

    t0 = time.perf_counter(); jax.block_until_ready(seq(bm)[1]); t_seq = time.perf_counter() - t0
    t0 = time.perf_counter(); jax.block_until_ready(par(bm)[1]); t_par = time.perf_counter() - t0
    ber = float((d2[:, :T] != bits).mean())
    print(f"64k-bit stream: sequential {t_seq*1e3:.0f}ms, "
          f"assoc-scan {t_par*1e3:.0f}ms, BER={ber:.5f}")

    # 2: mesh-distributed (single device here -> axis size 1, same numerics)
    mesh = jax.make_mesh((1,), ("model",))
    from repro.parallel.collectives import viterbi_decode_seqparallel

    with mesh:
        d3, m3 = viterbi_decode_seqparallel(code, bm, mesh)
    assert jnp.allclose(m3, m1)
    print("sequence-parallel shard_map decode matches (comm = n·S² floats, "
          "independent of T)")

    # 3: the same recurrence idea as an LM: xlstm decodes with O(1) state
    from repro.configs.base import get_smoke_arch
    from repro.models.model_zoo import build

    model = build(get_smoke_arch("xlstm_350m"))
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 32
    caches = model.init_cache(B, S)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, model.cfg.vocab)
    logits, caches = model.prefill(params, {"tokens": toks}, caches)
    state_bytes = sum(c.size * c.dtype.itemsize
                      for c in jax.tree_util.tree_leaves(caches))
    print(f"xlstm decode state: {state_bytes/1e3:.0f} kB — constant in context "
          f"length (the 500k-token dry-run cell decodes with the same state)")


if __name__ == "__main__":
    main()
