"""Quickstart: the paper's technique in five minutes.

  PYTHONPATH=src python examples/quickstart.py

1. Build a rate-1/2 convolutional code (the paper's K=3 trellis).
2. Encode a batch of messages, push them through a noisy channel.
3. Decode with the fused Pallas `Texpand` pipeline (the paper's custom
   instruction, TPU-native) and with the plain decoder — same answer.
4. Decode a long stream with the beyond-paper (min,+) parallel scan.
"""
import jax
import jax.numpy as jnp

from repro.core import (
    CODE_K3_STD,
    bsc,
    encode,
    hard_branch_metrics,
    paper_expansion_calls,
    viterbi_decode,
    viterbi_decode_parallel,
)
from repro.kernels import viterbi_decode_fused


def main():
    code = CODE_K3_STD
    key = jax.random.PRNGKey(0)

    # --- 1-2: encode + channel ------------------------------------------- #
    bits = jax.random.bernoulli(key, 0.5, (8, 64)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)  # (8, 66, 2) — rate 1/2
    received = bsc(jax.random.fold_in(key, 1), coded, flip_prob=0.02)
    print(f"coded bits per stream: {coded.shape[1] * coded.shape[2]} "
          f"(paper counts {paper_expansion_calls(coded.shape[1]*2)} ACS calls)")

    # --- 3: decode (fused kernel == reference) ---------------------------- #
    bm = hard_branch_metrics(code, received)
    dec_ref, metric_ref = viterbi_decode(code, bm)
    dec_fused, metric_fused = viterbi_decode_fused(code, bm)
    assert (dec_ref == dec_fused).all() and jnp.allclose(metric_ref, metric_fused)
    ber = float((dec_fused[:, :64] != bits).mean())
    print(f"fused Texpand decode: BER={ber:.4f}  "
          f"path metrics {metric_fused[:4].tolist()}")

    # --- 4: beyond-paper parallel decode ----------------------------------- #
    long_bits = jax.random.bernoulli(key, 0.5, (2, 4096)).astype(jnp.int32)
    long_rx = bsc(jax.random.fold_in(key, 2),
                  encode(code, long_bits, terminate=True), 0.02)
    long_bm = hard_branch_metrics(code, long_rx)
    dec_par, m_par = viterbi_decode_parallel(code, long_bm, chunk=256)
    dec_seq, m_seq = viterbi_decode(code, long_bm)
    assert jnp.allclose(m_par, m_seq)
    print(f"4096-bit stream: (min,+) associative-scan decode matches "
          f"sequential (metric {float(m_par[0]):.0f}) at log-depth")


if __name__ == "__main__":
    main()
