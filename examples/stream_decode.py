"""Online decode of long-lived broadcast streams — the paper's '10^15
bits/day of digital TV' scenario, done the way real receivers do it: a
truncated-traceback sliding window emits bits a fixed lag behind the channel,
in O(window) memory, and a continuous-batching scheduler multiplexes many
independent stations through one jitted Pallas call.

The codec and stream shapes (chunk, depth rule) come from
configs/paper_viterbi.py — the same spec the serve example and the
benchmarks use.

  PYTHONPATH=src python examples/stream_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_viterbi import DECODE_SPEC, STREAM
from repro.core.viterbi import viterbi_decode
from repro.stream import StreamBusy, StreamScheduler, StreamSession


def main():
    spec = DECODE_SPEC
    code = spec.code
    chunk = STREAM.chunk
    key = jax.random.PRNGKey(0)

    # --- one unbounded stream, chunk by chunk ----------------------------- #
    print(f"== single session: bits arrive in {chunk}-step chunks ==")
    T = 1024
    info = jax.random.bernoulli(key, 0.5, (1, T - spec.n_flush)).astype(jnp.int32)
    rx = spec.channel(jax.random.fold_in(key, 1), spec.encode(info), flip_prob=0.02)
    bm = spec.branch_metrics(rx)

    # fused_packed + inputs="received": raw channel symbols go straight into
    # the kernel (in-kernel branch metrics, bit-packed survivor ring,
    # on-device traceback) — no bm tables on the session hot path.
    sess = StreamSession(spec, chunk=chunk, depth=STREAM.depth(code),
                         backend="fused_packed", inputs="received")
    decoded = []
    for i in range(T // chunk):
        out = sess.push(rx[:, i * chunk : (i + 1) * chunk])
        decoded.append(np.asarray(out))
        if i in (0, 1, 4):
            print(f"  chunk {i}: emitted {out.shape[1]} bits (lag {sess.lag})")
    rest, metric = sess.finish()  # terminated per the spec
    decoded.append(np.asarray(rest))
    bits = np.concatenate(decoded, axis=1)
    ber = float((bits[:, : info.shape[1]] != np.asarray(info)).mean())
    print(f"  stream done: {bits.shape[1]} bits, metric {float(metric[0]):.1f}, BER {ber:.2e}")

    # --- many stations through one scheduler ------------------------------ #
    print("== continuous batching: 12 stations, 4 decode slots ==")
    sched = StreamScheduler(spec, n_slots=4, chunk=chunk, backend="fused_packed")
    truth = {}
    for i in range(12):
        k = jax.random.fold_in(key, 100 + i)
        n = int(jax.random.randint(jax.random.fold_in(k, 0), (), 200, 500))
        ib = jax.random.bernoulli(k, 0.5, (1, n)).astype(jnp.int32)
        sbm = spec.branch_metrics(
            spec.channel(jax.random.fold_in(k, 1), spec.encode(ib), flip_prob=0.01)
        )
        truth[f"station-{i}"] = (ib, sbm)
        sched.submit(f"station-{i}", sbm[0])
    results = sched.run()
    exact = 0
    for sid, (_ib, sbm) in truth.items():
        ref, _ = viterbi_decode(code, sbm)
        exact += int((results[sid][0] == np.asarray(ref[0])).all())
    s = sched.stats
    print(f"  {s.streams_finished} streams drained in {s.ticks} ticks, "
          f"{s.slot_claims} slot claims over {sched.n_slots} slots")
    print(f"  {exact}/12 streams match the full-block decoder bit-for-bit")

    # --- online ingestion: chunk-fed producers + backpressure -------------- #
    # No stream hands over a full table: one station attaches a generator
    # producer (polled every tick within its credit), the other is fed
    # manually from a "connection" loop that throttles on StreamBusy — the
    # decoded bits are identical to the offline decode of the same symbols.
    print("== online ingestion: generator producer + backpressured feed ==")
    online = StreamScheduler(spec, n_slots=2, chunk=chunk,
                             backend="fused_packed", depth=1024,
                             max_buffered=STREAM.max_buffered)
    tables = {}
    for sid in ("gen-fed", "chunk-fed"):
        k = jax.random.fold_in(key, hash(sid) % 1000)
        ib = jax.random.bernoulli(k, 0.5, (1, 700)).astype(jnp.int32)
        tables[sid] = (ib, np.asarray(spec.branch_metrics(
            spec.channel(jax.random.fold_in(k, 1), spec.encode(ib), flip_prob=0.01)
        ))[0])

    def bursty(table, sizes=(48, 130, 7, 200, 64)):
        i = 0
        while i < len(table):
            sz = sizes[i % len(sizes)]
            yield table[i : i + sz]
            i += sz

    online.open_stream("gen-fed", producer=bursty(tables["gen-fed"][1]))
    online.open_stream("chunk-fed")
    conn, fed, throttled = tables["chunk-fed"][1], 0, 0
    while online.pending_work():
        if fed < len(conn):  # the live-connection side: push, throttle, close
            try:
                online.submit_chunk("chunk-fed", conn[fed : fed + 96])
                fed += min(96, len(conn) - fed)
                if fed == len(conn):
                    online.close("chunk-fed")  # EOF: mid-chunk tail flushes
            except StreamBusy:
                throttled += 1  # queue full — back off until ticks drain it
        online.step()
    report = online.load_report()
    ok = 0
    for sid, (_ib, bm) in tables.items():
        ref, _ = viterbi_decode(code, bm[None])
        ok += int((online.pop_result(sid)[0] == np.asarray(ref[0])).all())
    print(f"  backpressure throttled the feed {throttled}x "
          f"(max queue {online.max_buffered} rows), "
          f"{online.stats.starved_slot_ticks} starved slot-ticks")
    print(f"  {ok}/2 online streams bit-exact vs the offline block decode; "
          f"queues drained: {report['queued_rows_total']} rows left")


if __name__ == "__main__":
    main()
