"""Packed-survivor pipeline: pack/unpack round-trip property, the Pallas
traceback kernel vs the XLA scan-of-gathers oracle, in-kernel branch metrics
vs the table builders, and golden-grid equivalence of the ``fused_packed``
backend (raw-symbol entry) against the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CODE_K3_STD, CODE_K5_GSM, CODE_K7_NASA, viterbi_decode
from repro.core.puncture import PUNCTURE_2_3
from repro.core.viterbi import _traceback
from repro.decode import CodecSpec, DecodeContext, DecodeRequest, decode, get_decoder
from repro.kernels import (
    fused_metric_plan,
    pack_survivors,
    unpack_survivors,
    viterbi_forward_op,
    viterbi_forward_packed_op,
    viterbi_traceback_op,
)
from repro.kernels.common import PACK_BITS

try:  # the property test widens coverage when hypothesis is available
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

CODES = {"k3": CODE_K3_STD, "k5": CODE_K5_GSM, "k7": CODE_K7_NASA}


def _noisy(spec, key, batch, n_info, **chan):
    bits = jax.random.bernoulli(key, 0.5, (batch, n_info)).astype(jnp.int32)
    coded = spec.encode(bits)
    rx = spec.channel(jax.random.fold_in(key, 1), coded, **chan)
    return bits, rx, spec.branch_metrics(rx)


# --------------------------------------------------------------------------- #
# pack/unpack round-trip (arbitrary T, including partial last words)           #
# --------------------------------------------------------------------------- #


def _assert_roundtrip(T, S, B, seed):
    rng = np.random.default_rng(seed)
    bps = jnp.asarray(rng.integers(0, 2, size=(T, S, B), dtype=np.int32))
    packed = pack_survivors(bps)
    assert packed.shape == (-(-T // PACK_BITS), S, B)
    assert packed.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(unpack_survivors(packed, T)), np.asarray(bps))


@pytest.mark.parametrize(
    "T", [1, 2, 31, 32, 33, 63, 64, 65, 96, 107]  # word edges + tails
)
def test_pack_unpack_roundtrip(T):
    _assert_roundtrip(T, S=4, B=3, seed=T)


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        T=st.integers(1, 3 * PACK_BITS + 7),  # covers T < 32, T % 32 == 0, tails
        S=st.sampled_from([2, 4, 16]),
        B=st.integers(1, 5),
        seed=st.integers(0, 2 ** 16),
    )
    def test_pack_unpack_roundtrip_property(T, S, B, seed):
        _assert_roundtrip(T, S, B, seed)


def test_pack_tail_bits_are_zero():
    bps = jnp.ones((PACK_BITS + 5, 2, 2), jnp.int32)
    packed = np.asarray(pack_survivors(bps))
    assert packed.shape[0] == 2
    assert (packed[0] == np.uint32(0xFFFFFFFF)).all()
    assert (packed[1] == np.uint32((1 << 5) - 1)).all()  # bits >= T stay 0


# --------------------------------------------------------------------------- #
# kernel packing == helper packing; Pallas traceback == XLA traceback          #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_id", sorted(CODES))
@pytest.mark.parametrize("B,T", [(1, 7), (8, 64), (130, 33)])  # lane padding + tails
def test_packed_forward_matches_unpacked(code_id, B, T, rng):
    code = CODES[code_id]
    bm = jax.random.uniform(rng, (B, T, code.n_symbols), jnp.float32, 0, 2)
    pm_u, bps = viterbi_forward_op(code, bm)  # (T, B, S) unpacked
    pm_p, packed = viterbi_forward_packed_op(code, bm)
    np.testing.assert_allclose(np.asarray(pm_p), np.asarray(pm_u), rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(packed), np.asarray(pack_survivors(bps))
    )


@pytest.mark.parametrize("code_id", sorted(CODES))
@pytest.mark.parametrize("B,T", [(3, 50), (8, 96)])
def test_traceback_kernel_matches_xla_scan(code_id, B, T, rng):
    """Random survivor memory + random start states: the packed walk must
    reproduce the scan-of-gathers traceback exactly."""
    code = CODES[code_id]
    S = code.n_states
    bps = jax.random.bernoulli(rng, 0.5, (T, B, S)).astype(jnp.int32)
    fs = jax.random.randint(jax.random.fold_in(rng, 1), (B,), 0, S, jnp.int32)
    ref_bits, _ = _traceback(code, bps, fs)
    bits = viterbi_traceback_op(code, pack_survivors(bps), fs, T)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(ref_bits))


# --------------------------------------------------------------------------- #
# in-kernel metric plans == the table builders                                 #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_id", ["k3", "k7"])
@pytest.mark.parametrize("metric", ["hard", "soft"])
@pytest.mark.parametrize("punctured", [False, True], ids=["unpunct", "punct23"])
def test_metric_plan_affine_form_matches_tables(code_id, metric, punctured, rng):
    code = CODES[code_id]
    spec = CodecSpec(
        code=code, metric=metric, puncture=PUNCTURE_2_3 if punctured else None
    )
    chan = {"snr_db": 4.0} if metric == "soft" else {"flip_prob": 0.03}
    _, rx, bm = _noisy(spec, rng, 3, 25, **chan)
    plan = fused_metric_plan(code, metric, spec.puncture_array)
    rebuilt = plan.bm_tables(rx)
    np.testing.assert_allclose(np.asarray(rebuilt), np.asarray(bm), rtol=1e-5, atol=1e-5)
    # mid-stream phase: rows [t0:] of the full mask == a chunk built at t0
    t0 = 7
    np.testing.assert_allclose(
        np.asarray(plan.bm_tables(rx[:, t0:], t0=t0)),
        np.asarray(bm[:, t0:]),
        rtol=1e-5, atol=1e-5,
    )


# --------------------------------------------------------------------------- #
# golden grid: fused_packed raw-symbol entry vs the sequential oracle          #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_name", ["k3", "k7"])
@pytest.mark.parametrize("metric", ["hard", "soft"])
@pytest.mark.parametrize("terminated", [True, False], ids=["term", "open"])
def test_fused_packed_from_received_golden_grid(code_name, metric, terminated, rng):
    code = CODES[code_name]
    spec = CodecSpec(code=code, metric=metric, terminated=terminated)
    cell = code.constraint * 4 + (metric == "soft") * 2 + terminated
    key = jax.random.fold_in(rng, cell)
    chan = {"snr_db": 4.0} if metric == "soft" else {"flip_prob": 0.03}
    _, rx, bm = _noisy(spec, key, 2, 30, **chan)
    ref_bits, ref_metric = viterbi_decode(code, bm, terminated=terminated)
    res = get_decoder("fused_packed").decode_received(spec, rx, ctx=DecodeContext())
    assert res.diagnostics["metrics"] == "in-kernel"
    np.testing.assert_array_equal(
        np.asarray(res.bits), np.asarray(ref_bits),
        err_msg=f"fused_packed (in-kernel metrics) diverged on {spec.describe()}",
    )
    np.testing.assert_allclose(
        np.asarray(res.path_metric), np.asarray(ref_metric), rtol=1e-5
    )


def test_decode_routes_received_to_in_kernel_metrics(rng):
    """decode() with raw channel output skips the host bm table entirely."""
    spec = CodecSpec()
    _, rx, bm = _noisy(spec, rng, 4, 40, flip_prob=0.02)
    ref_bits, _ = viterbi_decode(spec.code, bm)
    res = decode(DecodeRequest(spec, received=rx))
    assert res.plan.backend == "fused_packed"
    assert res.diagnostics["metrics"] == "in-kernel"
    np.testing.assert_array_equal(np.asarray(res.bits), np.asarray(ref_bits))
    # precomputed tables take the table fallback of the same backend
    res2 = decode(DecodeRequest(spec, bm_tables=bm))
    assert res2.diagnostics["metrics"] == "table"
    np.testing.assert_array_equal(np.asarray(res2.bits), np.asarray(ref_bits))
    # bm_tables precedence (the DecodeRequest contract): custom tables must
    # NOT be recomputed from received when both are given
    custom = jnp.zeros_like(bm).at[..., 0].set(-1.0)  # forces all-zero symbols
    res3 = decode(DecodeRequest(spec, received=rx, bm_tables=custom))
    assert res3.diagnostics["metrics"] == "table"
    ref_custom, _ = viterbi_decode(spec.code, custom)
    np.testing.assert_array_equal(np.asarray(res3.bits), np.asarray(ref_custom))


# --------------------------------------------------------------------------- #
# interpret-mode resolution is pinned per decode                               #
# --------------------------------------------------------------------------- #


def test_interpret_resolution_pinned_per_decode(rng, monkeypatch):
    """``interpret=None`` must resolve exactly ONCE per decode — at the
    ops.py entry point — so the forward scan and the traceback kernel can
    never auto-detect onto different code paths.  Per-kernel resolution
    would consult ``jax.default_backend()`` at each kernel's trace time
    (>= 2 consultations on a fresh shape; 0 on cached executables), so a
    platform-context change between traces could silently split one decode
    across compiled and interpreted kernels."""
    from repro.kernels import common
    from repro.kernels.ops import viterbi_decode_packed

    spec = CodecSpec()
    _, _, bm = _noisy(spec, rng, 3, 37, flip_prob=0.02)  # fresh (B, T) shape
    calls = {"n": 0}
    real = common.jax.default_backend

    def counting():
        calls["n"] += 1
        return real()

    monkeypatch.setattr(common.jax, "default_backend", counting)
    bits, _ = viterbi_decode_packed(spec.code, bm)
    assert calls["n"] == 1, (
        f"interpret auto-detect consulted the platform {calls['n']} times in "
        "one decode; it must be pinned once at the decode entry point"
    )
    ref_bits, _ = viterbi_decode(spec.code, bm)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(ref_bits))


def test_interpret_resolution_survives_platform_flip(rng, monkeypatch):
    """Forced host platform: even if the platform answer CHANGES mid-decode
    (the mixed-resolution hazard), the pinned decode keeps every kernel on
    the resolution captured at entry."""
    from repro.kernels import common
    from repro.kernels.ops import viterbi_decode_packed

    spec = CodecSpec()
    _, _, bm = _noisy(spec, rng, 3, 41, flip_prob=0.02)  # fresh (B, T) shape
    real = common.jax.default_backend
    first = {"done": False}

    def flipping():
        if not first["done"]:
            first["done"] = True
            return real()  # honest answer for the pinning consultation
        return "tpu"  # later consultations would demand compiled kernels

    monkeypatch.setattr(common.jax, "default_backend", flipping)
    bits, _ = viterbi_decode_packed(spec.code, bm)  # must not try TPU lowering
    ref_bits, _ = viterbi_decode(spec.code, bm)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(ref_bits))


def test_stream_components_pin_interpret(mesh11):
    """Sessions and schedulers pin the resolution at construction: one
    stream decode spans many kernel dispatches (ticks, tail feeds, flush)
    across which the platform answer must be frozen."""
    from repro.kernels.common import resolve_interpret
    from repro.stream import StreamScheduler, StreamSession

    expected = resolve_interpret(None)
    sess = StreamSession(CODE_K3_STD, chunk=32, backend="fused_packed")
    sched = StreamScheduler(CODE_K3_STD, n_slots=2, chunk=32, backend="fused_packed")
    sharded = StreamScheduler(
        CODE_K3_STD, n_slots=2, chunk=32, backend="fused_packed", mesh=mesh11
    )
    assert sess._interpret is expected
    assert sched._interpret is expected
    assert sharded._interpret is expected
