"""Time-parallel tiled decode (kernels/tiling.py + ops.viterbi_decode_tiled_op).

The gate for the tiled backend, in four layers:

  1. differential fuzz — tiled vs the sequential oracle and the fused_packed
     pipeline over (K3/K7 x hard/soft x punctured x terminated/open x P x
     awkward T), bit-exact in the exact seam regime.  Soft cells quantize the
     channel noise to a 1/64 grid so every float32 metric sum is exactly
     representable — reassociating sums across tile seams is then lossless
     and the bit-exact assert is deterministic, not flaky.
  2. min-plus seam algebra vs a brute-force oracle — per-tile transfer maps
     (the same scan-of-acs_step oracle the seqparallel decoder uses) composed
     with prefix_maps must reproduce the full-length forward metrics at every
     seam, ties included; the tie-break rule is pinned (lowest state index).
  3. windowed-kernel parity — the per-lane validity windows reduce to the
     plain packed scan/traceback when the window covers everything, and to a
     sliced scan when it does not.
  4. truncation regime — overlap >= 5·K is promoted to exact; short warm-ups
     stay approximate with a seeded BER-drift bound.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CODE_K3_STD, CODE_K7_NASA, viterbi_decode
from repro.core.acs import acs_step
from repro.core.puncture import PUNCTURE_2_3
from repro.core.trellis import NEG_UNREACHABLE
from repro.core.viterbi import _traceback
from repro.decode import CodecSpec, DecodeContext, get_decoder
from repro.kernels import (
    compose_maps,
    fused_metric_plan,
    identity_map,
    plan_tiles,
    prefix_maps,
    seam_argmin,
    tile_entry_metrics,
    default_tiles,
    traceback_packed,
    traceback_packed_window,
    truncation_depth,
    viterbi_decode_packed,
    viterbi_decode_tiled_fused,
    viterbi_decode_tiled_op,
)
from repro.kernels.common import lane_block, pad_axis_to
from repro.kernels.viterbi_scan import (
    table_weights,
    viterbi_scan_packed_carry,
    viterbi_scan_packed_window,
)
from repro.parallel.collectives import _local_transfer_and_bps

try:  # the property layer widens coverage when hypothesis is available
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

CODES = {"k3": CODE_K3_STD, "k7": CODE_K7_NASA}


def _noisy(spec, key, batch, n_info, **chan):
    """bits + channel output + bm tables; soft noise lands on a 1/64 grid so
    float32 metric sums are exact under any association order."""
    bits = jax.random.bernoulli(key, 0.5, (batch, n_info)).astype(jnp.int32)
    coded = spec.encode(bits)
    rx = spec.channel(jax.random.fold_in(key, 1), coded, **chan)
    if spec.soft:
        rx = jnp.round(rx * 64.0) / 64.0
    return bits, rx, spec.branch_metrics(rx)


def _pm_trace(code, bm, clamp=True):
    """Oracle forward pass from state 0 collecting the metrics *entering*
    every step: (T+1, B, S) with row t = metrics after t ACS steps."""
    B = bm.shape[0]
    S = code.n_states
    pm0 = jnp.full((B, S), NEG_UNREACHABLE, jnp.float32).at[:, 0].set(0.0)

    def step(pm, bm_t):
        new_pm, _ = acs_step(code, pm, bm_t)
        return jnp.minimum(new_pm, NEG_UNREACHABLE), pm

    last, trace = jax.lax.scan(step, pm0, bm.swapaxes(0, 1))
    return jnp.concatenate([trace, last[None]], axis=0)


# --------------------------------------------------------------------------- #
# 1. differential fuzz: tiled == sequential == fused_packed (exact regime)     #
# --------------------------------------------------------------------------- #

#: curated awkward cells: T % P != 0, T % 32 != 0, T < span, T < 5·K, P = 1
FUZZ_CELLS = [
    # (code, metric, punctured, terminated, P, n_info)
    ("k3", "hard", False, True, 4, 150),
    ("k3", "hard", False, False, 7, 149),  # open + ragged last tile
    ("k3", "soft", False, True, 4, 101),  # T % 32 != 0
    ("k3", "hard", True, True, 2, 96),
    ("k3", "soft", True, False, 4, 75),
    ("k3", "hard", False, True, 1, 64),  # degenerate tiling
    ("k3", "hard", False, True, 7, 9),  # T=11: more tiles than fit
    ("k7", "hard", False, True, 4, 120),
    ("k7", "soft", False, True, 7, 130),
    ("k7", "hard", True, False, 2, 90),
    ("k7", "hard", False, True, 4, 5),  # T=11 < truncation depth 35
]


@pytest.mark.parametrize(
    "code_id,metric,punctured,terminated,P,n_info",
    FUZZ_CELLS,
    ids=[f"{c}-{m}-{'p' if pu else 'u'}-{'t' if te else 'o'}-P{P}-I{n}"
         for c, m, pu, te, P, n in FUZZ_CELLS],
)
def test_tiled_differential_exact(code_id, metric, punctured, terminated,
                                  P, n_info, rng):
    code = CODES[code_id]
    spec = CodecSpec(
        code=code, metric=metric, terminated=terminated,
        puncture=PUNCTURE_2_3 if punctured else None,
    )
    cell = (code.constraint * 16 + punctured * 8 + (metric == "soft") * 4
            + terminated * 2 + P)
    key = jax.random.fold_in(rng, cell)
    chan = {"snr_db": 3.0} if metric == "soft" else {"flip_prob": 0.04}
    _, rx, bm = _noisy(spec, key, 2, n_info, **chan)

    ref_bits, ref_metric = viterbi_decode(code, bm, terminated=terminated)
    pk_bits, pk_metric = viterbi_decode_packed(
        code, bm, terminated=terminated
    )
    td_bits, td_metric = viterbi_decode_tiled_op(
        code, bm, P, terminated=terminated
    )
    msg = f"tiled P={P} diverged on {spec.describe()} T={bm.shape[1]}"
    np.testing.assert_array_equal(np.asarray(td_bits), np.asarray(ref_bits),
                                  err_msg=msg)
    np.testing.assert_array_equal(np.asarray(td_bits), np.asarray(pk_bits),
                                  err_msg=msg)
    # 1/64-grid inputs keep every sum exact -> metrics match bit-for-bit
    np.testing.assert_array_equal(np.asarray(td_metric), np.asarray(ref_metric),
                                  err_msg=msg)
    assert pk_metric.shape == td_metric.shape

    # the raw-symbol (in-kernel metric) entry decodes identically
    plan = fused_metric_plan(code, metric, spec.puncture_array)
    fd_bits, fd_metric = viterbi_decode_tiled_fused(
        plan, rx, P, terminated=terminated
    )
    np.testing.assert_array_equal(np.asarray(fd_bits), np.asarray(ref_bits),
                                  err_msg=msg + " (fused entry)")
    np.testing.assert_allclose(np.asarray(fd_metric), np.asarray(ref_metric),
                               rtol=1e-5, err_msg=msg + " (fused entry)")


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        T=st.integers(2, 96),
        P=st.integers(1, 7),
        seed=st.integers(0, 2 ** 16),
        terminated=st.booleans(),
    )
    def test_tiled_differential_property(T, P, seed, terminated):
        """Arbitrary small-integer metric tables (ties everywhere): exact-mode
        tiling must reproduce the sequential walk, tie-breaks included."""
        code = CODE_K3_STD
        gen = np.random.default_rng(seed)
        bm = jnp.asarray(
            gen.integers(0, 3, size=(2, T, code.n_symbols)).astype(np.float32)
        )
        ref_bits, ref_metric = viterbi_decode(code, bm, terminated=terminated)
        td_bits, td_metric = viterbi_decode_tiled_op(
            code, bm, P, terminated=terminated
        )
        np.testing.assert_array_equal(np.asarray(td_bits), np.asarray(ref_bits))
        np.testing.assert_array_equal(
            np.asarray(td_metric), np.asarray(ref_metric)
        )


# --------------------------------------------------------------------------- #
# 2. min-plus seam algebra vs the brute-force oracle                           #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_id", ["k3", "k7"])
@pytest.mark.parametrize("P", [2, 4, 7])
def test_seam_metrics_match_full_forward(code_id, P, rng):
    """Composed per-tile transfer maps must yield, at every seam, exactly the
    path metrics the full-length forward pass has there — the invariant that
    makes the exact regime bit-exact."""
    code = CODES[code_id]
    spec = CodecSpec(code=code, metric="hard")
    key = jax.random.fold_in(rng, code.constraint * 8 + P)
    _, _, bm = _noisy(spec, key, 2, 61, flip_prob=0.05)
    T = bm.shape[1]
    tp = plan_tiles(T, P)

    maps = jnp.stack([
        _local_transfer_and_bps(
            code, bm[:, p * tp.core:(p + 1) * tp.core]
        )
        for p in range(tp.n_tiles)
    ])  # (P, B, S, S) — the seqparallel decoder's own chunk oracle
    excl, total = prefix_maps(maps)
    entry = tile_entry_metrics(excl)  # (P, B, S)

    trace = _pm_trace(code, bm)  # (T+1, B, S)
    for p in range(tp.n_tiles):
        np.testing.assert_array_equal(
            np.asarray(entry[p]), np.asarray(trace[p * tp.core]),
            err_msg=f"seam {p} (step {p * tp.core}) metrics diverged",
        )
    np.testing.assert_array_equal(
        np.asarray(total[:, 0, :]), np.asarray(trace[T]),
        err_msg="composed total != full forward frontier",
    )


def test_seam_argmin_tie_break_is_lowest_state():
    """Pinned rule: seam ties resolve to the LOWEST state index — the same
    first-occurrence convention as jnp.argmin and ops._frontier, so a tiled
    traceback entered through a tied seam picks the same path as the
    sequential walk."""
    m = jnp.asarray([[3.0, 1.0, 1.0, 5.0], [2.0, 2.0, 2.0, 2.0]])
    np.testing.assert_array_equal(np.asarray(seam_argmin(m)), [1, 0])
    assert seam_argmin(m).dtype == jnp.int32


def test_compose_maps_identity_and_associativity():
    """(min,+) maps form a monoid on integer-valued metrics: identity is
    neutral and composition reassociates losslessly — the property prefix_maps
    leans on."""
    S = 4
    gen = np.random.default_rng(7)
    a, b, c = (
        jnp.asarray(gen.integers(0, 9, size=(S, S)).astype(np.float32))
        for _ in range(3)
    )
    eye = identity_map(S)
    np.testing.assert_array_equal(np.asarray(compose_maps(eye, a)), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(compose_maps(a, eye)), np.asarray(a))
    left = compose_maps(compose_maps(a, b), c)
    right = compose_maps(a, compose_maps(b, c))
    np.testing.assert_array_equal(np.asarray(left), np.asarray(right))
    # unreachable entries stay clamped, never overflow past the sentinel
    blocked = jnp.full((S, S), NEG_UNREACHABLE, jnp.float32)
    out = compose_maps(blocked, blocked)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(blocked))


def test_prefix_maps_exclusive_convention():
    """excl[p] composes tiles 0..p-1 (excl[0] = identity); total composes all
    — the exclusive-prefix convention the seam seeding assumes."""
    S = 2
    m0 = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    m1 = jnp.asarray([[5.0, 6.0], [7.0, 8.0]])
    excl, total = prefix_maps(jnp.stack([m0, m1]))
    np.testing.assert_array_equal(np.asarray(excl[0]), np.asarray(identity_map(S)))
    np.testing.assert_array_equal(np.asarray(excl[1]), np.asarray(m0))
    np.testing.assert_array_equal(
        np.asarray(total), np.asarray(compose_maps(m0, m1))
    )


# --------------------------------------------------------------------------- #
# 3. windowed kernels reduce to the plain ones                                 #
# --------------------------------------------------------------------------- #


def _packed_fixture(code, key, B, T):
    """Random tables + seed metrics in kernel layout, lane-padded."""
    M = code.n_symbols
    S = code.n_states
    bm = jax.random.randint(key, (T, M, B), 0, 5).astype(jnp.float32)
    pm0 = jnp.full((S, B), NEG_UNREACHABLE, jnp.float32).at[0].set(0.0)
    blk = lane_block(B)
    bm_p, _ = pad_axis_to(bm, 2, blk, 0.0)
    pm0_p, _ = pad_axis_to(pm0, 1, blk, NEG_UNREACHABLE)
    return bm, pm0, bm_p, pm0_p, blk


def test_windowed_scan_full_window_matches_carry_scan(rng):
    code = CODE_K3_STD
    B, T = 3, 45
    bm, pm0, bm_p, pm0_p, blk = _packed_fixture(code, rng, B, T)
    b0, b1, rb = table_weights(code)
    ref_pm, ref_packed = viterbi_scan_packed_carry(
        code, pm0_p, bm_p, b0, b1, rb, blk
    )
    full = jnp.zeros((1, bm_p.shape[2]), jnp.int32)
    win_pm, win_packed = viterbi_scan_packed_window(
        code, pm0_p, bm_p, b0, b1, rb, full, full + T, blk
    )
    np.testing.assert_array_equal(np.asarray(win_pm[:, :B]),
                                  np.asarray(ref_pm[:, :B]))
    np.testing.assert_array_equal(np.asarray(win_packed[:, :, :B]),
                                  np.asarray(ref_packed[:, :, :B]))


def test_windowed_scan_partial_window_matches_sliced_scan(rng):
    """A lane windowed to [lo, hi) must end with exactly the metrics of a
    plain scan over rows lo..hi-1, and emit survivor bit 0 elsewhere."""
    code = CODE_K3_STD
    B, T, lo, hi = 2, 40, 5, 29
    bm, pm0, bm_p, pm0_p, blk = _packed_fixture(code, rng, B, T)
    b0, b1, rb = table_weights(code)
    ones = jnp.ones((1, bm_p.shape[2]), jnp.int32)
    win_pm, win_packed = viterbi_scan_packed_window(
        code, pm0_p, bm_p, b0, b1, rb, ones * lo, ones * hi, blk
    )
    sl_p, _ = pad_axis_to(bm[lo:hi], 2, blk, 0.0)
    ref_pm, ref_packed = viterbi_scan_packed_carry(
        code, pm0_p, sl_p, b0, b1, rb, blk
    )
    np.testing.assert_array_equal(np.asarray(win_pm[:, :B]),
                                  np.asarray(ref_pm[:, :B]))
    # bits inside the window line up step-for-step; outside they are zero
    bits = np.asarray(win_packed[:, :, :B])
    unpacked = np.zeros((T, code.n_states, B), np.int64)
    for t in range(T):
        unpacked[t] = (bits[t // 32] >> (t % 32)) & 1
    assert (unpacked[:lo] == 0).all() and (unpacked[hi:] == 0).all()
    ref_bits = np.asarray(ref_packed[:, :, :B])
    ref_unpacked = np.zeros((hi - lo, code.n_states, B), np.int64)
    for t in range(hi - lo):
        ref_unpacked[t] = (ref_bits[t // 32] >> (t % 32)) & 1
    np.testing.assert_array_equal(unpacked[lo:hi], ref_unpacked)


def test_windowed_traceback_full_window_matches_plain(rng):
    code = CODE_K7_NASA
    S = code.n_states
    B, T = 3, 50
    W = -(-T // 32)
    bps = jax.random.bernoulli(rng, 0.5, (T, B, S)).astype(jnp.int32)
    fs = jax.random.randint(jax.random.fold_in(rng, 1), (B,), 0, S, jnp.int32)
    ref_bits, ref_states = _traceback(code, bps, fs)

    from repro.kernels import pack_survivors

    packed = pack_survivors(bps.transpose(0, 2, 1))  # (W, S, B)
    blk = lane_block(B)
    pk, _ = pad_axis_to(packed, 2, blk, 0)
    st_, _ = pad_axis_to(fs[None, :], 1, blk, 0)
    zeros = jnp.zeros((1, pk.shape[2]), jnp.int32)
    bits, entry = traceback_packed_window(
        code, pk, st_, zeros, zeros + T, blk
    )
    np.testing.assert_array_equal(np.asarray(bits[:T, :B].T),
                                  np.asarray(ref_bits))
    # entry state = the state reached walking all the way back to step 0,
    # i.e. the step the sequential walk's state sequence *entered* on
    plain = traceback_packed(code, pk, st_, T, blk)
    np.testing.assert_array_equal(np.asarray(plain[:T, :B].T),
                                  np.asarray(ref_bits))
    # oracle entry: one more backpointer hop from the earliest kept state
    s1 = np.asarray(ref_states)[:, 0]  # state after step 0
    half = S // 2
    j = np.asarray(bps)[0, np.arange(B), s1]
    s0 = 2 * (s1 & (half - 1)) + j if half > 1 else j
    np.testing.assert_array_equal(np.asarray(entry[0, :B]), s0)


# --------------------------------------------------------------------------- #
# 4. truncation regime: promotion + seeded drift bound                         #
# --------------------------------------------------------------------------- #


def test_overlap_at_depth_promotes_to_exact(rng):
    """overlap >= 5·K always means bit-exact: the op promotes it to the
    exact seam regime rather than running an equal-cost approximation."""
    code = CODE_K3_STD
    spec = CodecSpec(code=code, metric="hard")
    _, _, bm = _noisy(spec, rng, 2, 300, flip_prob=0.06)
    D = truncation_depth(code)
    ref_bits, ref_metric = viterbi_decode(code, bm)
    for ov in (D, D + 7, 10_000):
        bits, metric = viterbi_decode_tiled_op(code, bm, 4, overlap=ov)
        np.testing.assert_array_equal(np.asarray(bits), np.asarray(ref_bits))
        np.testing.assert_array_equal(np.asarray(metric), np.asarray(ref_metric))


def test_truncated_regime_ber_drift_bounded(rng):
    """Short warm-up (overlap < 5·K) is allowed to disagree with the exact
    decode, but at a noisy operating point its end-to-end BER must stay
    within a small absolute drift of exact — the usual truncated-traceback
    argument applied to tile seams.  Seeded, so the bound is deterministic."""
    code = CODE_K3_STD
    spec = CodecSpec(code=code, metric="hard")
    key = jax.random.fold_in(rng, 99)
    bits, _, bm = _noisy(spec, key, 4, 400, flip_prob=0.08)
    sent = np.asarray(bits)

    exact_bits, _ = viterbi_decode_tiled_op(code, bm, 4)
    trunc_bits, _ = viterbi_decode_tiled_op(code, bm, 4, overlap=8)
    assert exact_bits.shape == trunc_bits.shape

    def ber(decoded):
        got = np.asarray(spec.strip_flush(decoded))
        return float((got != sent).mean())

    drift = ber(trunc_bits) - ber(exact_bits)
    assert drift <= 0.02, (
        f"truncated seam warm-up drifted {drift:.4f} BER past exact"
    )


def test_tile_plan_partitions_every_step_once():
    """windows()/gather_index() consistency: the kept cores tile [0, T)
    exactly — no step decoded twice, none dropped — for awkward shapes."""
    for T, P, ov in [(11, 7, 0), (96, 4, 0), (101, 4, 9), (5, 9, 50), (130, 3, 15)]:
        tp = plan_tiles(T, P, ov)
        lo, hi = tp.windows()
        gi = tp.gather_index()
        covered = np.concatenate([
            gi[p, tp.overlap:hi[p]] for p in range(tp.n_tiles)
        ])
        np.testing.assert_array_equal(covered, np.arange(T))
        assert (lo >= 0).all() and (hi <= tp.span).all()
        assert sum(tp.tile_length(p) for p in range(tp.n_tiles)) == T


def test_default_tiles_respects_floors_and_budget():
    assert default_tiles(1, 64, 4) == 1  # shorter than MIN_TILE_CORE
    assert default_tiles(1, 4096, 4) >= 4
    B, T, S = 8, 100_000, 64
    P = default_tiles(B, T, S)
    assert B * P * S <= 512 and P >= 1


# --------------------------------------------------------------------------- #
# decode()-level integration                                                   #
# --------------------------------------------------------------------------- #


def test_tiled_backend_registry_entry(rng):
    spec = CodecSpec(code=CODE_K3_STD, metric="hard")
    _, rx, bm = _noisy(spec, rng, 2, 120, flip_prob=0.03)
    ref_bits, _ = viterbi_decode(spec.code, bm)

    res = get_decoder("tiled")(spec, bm, ctx=DecodeContext(tiles=4))
    assert res.diagnostics["backend"] == "tiled"
    assert res.diagnostics["tiles"] == 4
    assert res.diagnostics["metrics"] == "table"
    np.testing.assert_array_equal(np.asarray(res.bits), np.asarray(ref_bits))

    res2 = get_decoder("tiled").decode_received(
        spec, rx, ctx=DecodeContext(tiles=4)
    )
    assert res2.diagnostics["metrics"] == "in-kernel"
    np.testing.assert_array_equal(np.asarray(res2.bits), np.asarray(ref_bits))


def test_tiled_backend_open_trellis_and_ctx_overlap(rng):
    spec = CodecSpec(code=CODE_K3_STD, metric="hard", terminated=False)
    _, _, bm = _noisy(spec, rng, 2, 140, flip_prob=0.03)
    ref_bits, ref_metric = viterbi_decode(spec.code, bm, terminated=False)
    ctx = DecodeContext(tiles=4, tile_overlap=truncation_depth(spec.code))
    res = get_decoder("tiled")(spec, bm, ctx=ctx)
    np.testing.assert_array_equal(np.asarray(res.bits), np.asarray(ref_bits))
    np.testing.assert_allclose(
        np.asarray(res.path_metric), np.asarray(ref_metric), rtol=1e-6
    )
