"""Serving engine + decode-API transport end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_arch
from repro.decode import CodecSpec, decode
from repro.models.model_zoo import build
from repro.serve import ServeEngine, bits_to_tokens, tokens_to_bits
from repro.serve.kv_cache import SlotAllocator, cache_bytes, pick_bucket


def test_engine_generates(rng):
    model = build(get_smoke_arch("qwen2_5_3b"))
    params = model.init(rng)
    engine = ServeEngine(model, params, max_len=24)
    prompts = jax.random.randint(jax.random.fold_in(rng, 1), (2, 8), 1,
                                 model.cfg.vocab)
    out = engine.generate(prompts, max_new_tokens=8)
    assert out["tokens"].shape == (2, 8)
    assert bool((out["tokens"] >= 0).all())


def test_engine_greedy_is_deterministic(rng):
    model = build(get_smoke_arch("qwen3_4b"))
    params = model.init(rng)
    engine = ServeEngine(model, params, max_len=20, temperature=0.0)
    prompts = jax.random.randint(jax.random.fold_in(rng, 1), (2, 6), 1,
                                 model.cfg.vocab)
    a = engine.generate(prompts, 6)["tokens"]
    b = engine.generate(prompts, 6)["tokens"]
    assert (a == b).all()


@pytest.mark.parametrize("backend", ["fused", "sequential", "parallel"])
def test_decode_transport_roundtrip(backend, rng):
    spec = CodecSpec()
    bits = jax.random.bernoulli(rng, 0.5, (8, 64)).astype(jnp.int32)
    rx = spec.channel(jax.random.fold_in(rng, 1), spec.encode(bits),
                      flip_prob=0.01)
    res = decode(spec, rx, backend=backend)
    assert res.info_bits.shape == bits.shape
    assert float((res.info_bits != bits).mean()) < 0.05


def test_decode_transport_soft(rng):
    spec = CodecSpec(metric="soft")
    bits = jax.random.bernoulli(rng, 0.5, (8, 64)).astype(jnp.int32)
    rx = spec.channel(jax.random.fold_in(rng, 1), spec.encode(bits), snr_db=4.0)
    res = decode(spec, rx)
    assert float((res.info_bits != bits).mean()) < 0.03


def test_lm_to_viterbi_pipeline(rng):
    """The paper's serving scenario end-to-end: LM output -> bitstream ->
    conv encode -> noisy channel -> fused Viterbi decode -> exact recovery."""
    model = build(get_smoke_arch("qwen2_5_3b"))
    params = model.init(rng)
    engine = ServeEngine(model, params, max_len=16)
    prompts = jax.random.randint(jax.random.fold_in(rng, 1), (2, 8), 1,
                                 model.cfg.vocab)
    toks = engine.generate(prompts, 8)["tokens"]
    bits = tokens_to_bits(toks, bits_per_token=9)  # vocab 512
    spec = CodecSpec()
    rx = spec.channel(jax.random.fold_in(rng, 2), spec.encode(bits),
                      flip_prob=0.005)
    res = decode(spec, rx)
    dec = res.info_bits
    exact = bool((dec == bits).all())
    assert exact or float((np.asarray(dec) != np.asarray(bits)).mean()) < 0.01
    recovered = bits_to_tokens(dec, 9)
    if exact:
        assert (recovered == toks).all()


def test_bits_tokens_roundtrip(rng):
    toks = jax.random.randint(rng, (3, 10), 0, 512)
    assert (bits_to_tokens(tokens_to_bits(toks, 9), 9) == toks).all()


def test_kv_cache_utils():
    assert pick_bucket(100, 200) == 1024
    assert pick_bucket(4000, 96) == 4096
    assert pick_bucket(4000, 100) == 16384  # 4100 > 4096 -> next bucket
    with pytest.raises(ValueError):
        pick_bucket(600000, 1)
    model = build(get_smoke_arch("qwen3_4b"))
    b = cache_bytes(model, B=2, S=64)
    assert b > 0
    alloc = SlotAllocator(2)
    s0 = alloc.claim("a")
    alloc.claim("b")
    assert alloc.claim("c") is None
    alloc.release(s0)
    assert alloc.claim("c") is not None
    assert alloc.utilization() == 1.0
