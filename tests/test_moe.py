"""MoE dispatch invariants."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig, ModelConfig
from repro.models.common import init_params
from repro.models.moe import moe_apply, moe_specs


def _cfg(**kw):
    base = dict(name="moe-test", d_model=32, d_ff=64, compute_dtype="float32",
                moe=MoEConfig(n_experts=8, top_k=2, d_expert=64,
                              capacity_factor=8.0))
    base.update(kw)
    return ModelConfig(**base)


def _dense_reference(params, cfg, x):
    """Per-token dense mixture: route every token through its top-k experts
    with no capacity limit."""
    moe = cfg.moe
    B, S, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x, params["router"]["kernel"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, moe.top_k)
    if moe.renormalize:
        gates = gates / gates.sum(-1, keepdims=True)
    g = jnp.einsum("bsd,edf->bsef", x, params["gate"]["kernel"])
    u = jnp.einsum("bsd,edf->bsef", x, params["up"]["kernel"])
    y_all = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u,
                       params["down"]["kernel"])  # (B,S,E,d)
    picked = jnp.take_along_axis(y_all, idx[..., None], axis=2)  # (B,S,k,d)
    return (picked * gates[..., None]).sum(axis=2)


def test_moe_matches_dense_reference_with_ample_capacity(rng):
    cfg = _cfg()
    params = init_params(moe_specs(cfg, 0), rng)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 16, cfg.d_model))
    y, aux = moe_apply(params, cfg, x)
    ref = _dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-3, atol=2e-3)
    assert float(aux["load_balance_loss"]) > 0.0


def test_moe_capacity_drops_are_zero_contribution(rng):
    """With capacity_factor → tiny, overflowing tokens contribute exactly 0
    (not garbage)."""
    cfg = _cfg(moe=MoEConfig(n_experts=2, top_k=1, d_expert=64,
                             capacity_factor=0.01))
    params = init_params(moe_specs(cfg, 0), rng)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (1, 16, cfg.d_model))
    y, _ = moe_apply(params, cfg, x)
    # capacity C = max(int(16*1/2*0.01)+1, 1) = 1 -> at most 2 tokens routed
    nonzero_tokens = int((jnp.abs(y[0]).sum(-1) > 1e-6).sum())
    assert nonzero_tokens <= 2 * 1  # experts x capacity


def test_moe_single_token_decode_path(rng):
    """S=1 (decode): top-k distinct experts always fit capacity 1."""
    cfg = _cfg()
    params = init_params(moe_specs(cfg, 0), rng)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (4, 1, cfg.d_model))
    y, _ = moe_apply(params, cfg, x)
    ref = _dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_moe_shared_experts(rng):
    cfg = _cfg(moe=MoEConfig(n_experts=4, top_k=2, d_expert=32, n_shared=1,
                             capacity_factor=8.0))
    params = init_params(moe_specs(cfg, 0), rng)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 8, cfg.d_model))
    y, _ = moe_apply(params, cfg, x)
    from repro.models.mlp import mlp_apply

    routed = y - mlp_apply(params["shared"], cfg, x)
    ref = _dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(routed), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


def test_load_balance_loss_prefers_uniform(rng):
    """lb loss is ~1 for a uniform router and > 1 for a collapsed one."""
    cfg = _cfg()
    params = init_params(moe_specs(cfg, 0), rng)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 64, cfg.d_model))
    # collapse the router: all tokens to expert 0
    collapsed = jax.tree_util.tree_map(lambda p: p, params)
    collapsed["router"]["kernel"] = jnp.zeros_like(
        params["router"]["kernel"]).at[:, 0].set(10.0)
    _, aux_u = moe_apply(params, cfg, x)
    _, aux_c = moe_apply(collapsed, cfg, x)
    assert float(aux_c["load_balance_loss"]) > float(aux_u["load_balance_loss"])
