"""Online ingestion: chunk-fed scheduler, producers, backpressure.

The invariant everything here leans on: ARRIVAL SCHEDULE NEVER CHANGES THE
DECODE.  However a stream's rows trickle in — bursty generator, drip-fed
submit_chunk, starvation gaps, early close mid-chunk — the committed bits
and final metric must be bit-identical to the one-shot ``submit`` of the
concatenated table (and, at depth >= T, to the offline block decoder).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CODE_K3_STD,
    bsc,
    encode,
    hard_branch_metrics,
    viterbi_decode,
)
from repro.obs import Telemetry
from repro.stream import (
    CallableProducer,
    GeneratorProducer,
    PushProducer,
    RateLimitedProducer,
    StreamBusy,
    StreamScheduler,
    as_producer,
)

CODE = CODE_K3_STD


def _noisy_bm(key, info_bits, flip=0.02, batch=1):
    bits = jax.random.bernoulli(key, 0.5, (batch, info_bits)).astype(jnp.int32)
    coded = encode(CODE, bits, terminate=True)
    rx = bsc(jax.random.fold_in(key, 1), coded, flip)
    return bits, np.asarray(hard_branch_metrics(CODE, rx))


def _chunks_of(table, sizes):
    """Split a (T, M) table into arrival chunks of the given sizes (the last
    chunk absorbs any remainder)."""
    out, i = [], 0
    for sz in sizes:
        out.append(table[i : i + sz])
        i += sz
        if i >= len(table):
            break
    if i < len(table):
        out.append(table[i:])
    return [c for c in out if len(c)]


# --------------------------------------------------------------------------- #
# producer adapters                                                            #
# --------------------------------------------------------------------------- #


def test_generator_producer_splits_and_fills_credit():
    rows = np.arange(20, dtype=np.float32).reshape(10, 2)
    prod = GeneratorProducer(iter([rows[:7], rows[7:]]))
    got = prod.poll(3)  # 7-row burst split against credit 3
    np.testing.assert_array_equal(got, rows[:3])
    np.testing.assert_array_equal(prod.poll(4), rows[3:7])
    assert not prod.exhausted
    # a poll keeps pulling source chunks until the credit is filled or the
    # source ends — never capped at one yielded chunk per poll
    np.testing.assert_array_equal(prod.poll(100), rows[7:])
    assert prod.poll(5) is None and prod.exhausted
    assert GeneratorProducer(iter([rows])).poll(0) is None  # zero credit


def test_generator_producer_fills_credit_from_tiny_yields():
    """Many small source chunks assemble into ONE poll up to the credit —
    a 1-row generator must not throttle ingest to one row per tick."""
    rows = np.arange(24, dtype=np.float32).reshape(12, 2)
    prod = GeneratorProducer(rows[i : i + 1] for i in range(12))
    got = prod.poll(8)
    np.testing.assert_array_equal(got, rows[:8])
    np.testing.assert_array_equal(prod.poll(8), rows[8:])
    assert prod.exhausted


def test_callable_producer_none_means_not_ready():
    state = {"n": 0}

    def fn(max_rows):
        state["n"] += 1
        if state["n"] == 1:
            return None  # nothing ready yet
        if state["n"] == 2:
            return np.ones((4, 2), np.float32)
        raise StopIteration

    prod = CallableProducer(fn)
    assert prod.poll(8) is None and not prod.exhausted
    assert prod.poll(8).shape == (4, 2)
    assert prod.poll(8) is None and prod.exhausted


def test_push_producer_feed_poll_and_bound():
    prod = PushProducer(max_rows=8)
    prod.feed(np.zeros((5, 2), np.float32))
    with pytest.raises(StreamBusy):
        prod.feed(np.zeros((4, 2), np.float32), block=False)  # 5 + 4 > 8
    got = prod.poll(3)
    assert got.shape == (3, 2)
    prod.feed(np.zeros((4, 2), np.float32), block=False)  # drained below bound
    prod.close()
    assert not prod.exhausted  # rows still buffered
    assert prod.poll(100).shape == (6, 2)
    assert prod.exhausted
    with pytest.raises(RuntimeError):
        prod.feed(np.zeros((1, 2), np.float32))


def test_as_producer_coercion():
    assert isinstance(as_producer(iter([])), GeneratorProducer)
    assert isinstance(as_producer([np.zeros((1, 2))]), GeneratorProducer)
    assert isinstance(as_producer(lambda n: None), CallableProducer)
    p = PushProducer()
    assert as_producer(p) is p


def test_rate_limited_producer_respects_clock():
    table = np.arange(40, dtype=np.float32).reshape(20, 2)
    now = {"t": 0.0}
    prod = RateLimitedProducer(table, rows_per_s=10.0, clock=lambda: now["t"])
    assert prod.poll(100) is None  # no time elapsed, nothing released
    now["t"] = 0.5  # 5 rows released
    np.testing.assert_array_equal(prod.poll(100), table[:5])
    now["t"] = 10.0
    np.testing.assert_array_equal(prod.poll(4), table[5:9])  # capped by credit
    np.testing.assert_array_equal(prod.poll(100), table[9:])
    assert prod.exhausted


# --------------------------------------------------------------------------- #
# chunk-fed decode == one-shot submit == offline block decode                  #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("backend,chunk", [("scan", 16), ("fused_packed", 32)])
def test_chunk_fed_bit_exact_vs_offline(backend, chunk, rng):
    """Drip-fed arrival (sizes unrelated to the decode chunk, early-close
    mid-chunk tail) decodes bit-identically to the offline block decoder."""
    sizes = (5, 31, 2, 64, 17, 9, 50)
    sched = StreamScheduler(CODE, n_slots=2, chunk=chunk, depth=400, backend=backend)
    refs = {}
    feeds = {}
    for i in range(4):
        _, bm = _noisy_bm(jax.random.fold_in(rng, i), (91, 130, 64, 175)[i % 4])
        refs[f"s{i}"] = viterbi_decode(CODE, bm)
        feeds[f"s{i}"] = _chunks_of(bm[0], sizes)
        sched.open_stream(f"s{i}")
    while sched.pending_work():
        for sid, chunks in feeds.items():
            if chunks:
                try:
                    sched.submit_chunk(sid, chunks[0])
                except StreamBusy:
                    continue  # retry next tick — backpressure in action
                chunks.pop(0)
                if not chunks:
                    sched.close(sid)
        sched.step()
    for sid, (rb, rm) in refs.items():
        bits, metric = sched.results[sid]
        np.testing.assert_array_equal(bits, np.asarray(rb[0]))
        assert abs(metric - float(rm[0])) < 1e-3 * max(1.0, abs(float(rm[0])))


@pytest.mark.parametrize("backend,chunk", [("scan", 16), ("fused_packed", 32)])
def test_starved_slot_idles_without_corruption(backend, chunk, rng):
    """A stream fed in bursts with long gaps starves its slot for several
    ticks while a neighbor keeps decoding: the starved slot's carried state
    must be untouched by the masked ticks (bit-exact decode, no eviction)."""
    _, bm_a = _noisy_bm(rng, 8 * chunk - 2, 0.05)
    _, bm_b = _noisy_bm(jax.random.fold_in(rng, 1), 6 * chunk - 2, 0.05)
    ref_a, _ = viterbi_decode(CODE, bm_a)
    ref_b, _ = viterbi_decode(CODE, bm_b)
    sched = StreamScheduler(
        CODE, n_slots=2, chunk=chunk, depth=16 * chunk, backend=backend
    )
    sched.submit("a", bm_a[0])  # fully buffered: never starves
    sched.open_stream("b")
    fed = 0
    table_b = bm_b[0]
    burst = 0
    while sched.pending_work():
        # feed b one chunk every third tick only
        if fed < len(table_b) and burst % 3 == 0:
            n = min(chunk, len(table_b) - fed)
            sched.submit_chunk("b", table_b[fed : fed + n])
            fed += n
            if fed == len(table_b):
                sched.close("b")
        burst += 1
        sched.step()
        assert "b" in {st.stream_id for st in sched.active.values()} or (
            "b" in sched.results
        )  # starvation never evicts
    assert sched.stats.starved_slot_ticks > 0
    np.testing.assert_array_equal(sched.results["a"][0], np.asarray(ref_a[0]))
    np.testing.assert_array_equal(sched.results["b"][0], np.asarray(ref_b[0]))


def test_submit_is_adapter_over_chunk_path(rng, monkeypatch):
    """submit() routes through open_stream + submit_chunk + close — there is
    no second ingestion path left in the scheduler."""
    sched = StreamScheduler(CODE, n_slots=2, chunk=16, depth=30, backend="scan")
    calls = {"open": 0, "chunk": 0}
    orig_open, orig_chunk = sched.open_stream, sched.submit_chunk

    def open_spy(*a, **k):
        calls["open"] += 1
        return orig_open(*a, **k)

    def chunk_spy(*a, **k):
        calls["chunk"] += 1
        return orig_chunk(*a, **k)

    monkeypatch.setattr(sched, "open_stream", open_spy)
    monkeypatch.setattr(sched, "submit_chunk", chunk_spy)
    _, bm = _noisy_bm(rng, 62)
    ref, _ = viterbi_decode(CODE, bm)
    sched.submit("s", bm[0])
    st = next(iter(sched.active.values()))
    assert st.closed  # the adapter closed it
    out = sched.run()
    assert calls == {"open": 1, "chunk": 1}
    np.testing.assert_array_equal(out["s"][0], np.asarray(ref[0]))


# --------------------------------------------------------------------------- #
# backpressure                                                                 #
# --------------------------------------------------------------------------- #


def test_submit_chunk_credit_and_stream_busy(rng):
    sched = StreamScheduler(
        CODE, n_slots=1, chunk=16, depth=30, backend="scan", max_buffered=32
    )
    _, bm = _noisy_bm(rng, 126)
    table = bm[0]
    sched.open_stream("s")
    assert sched.credit("s") == 32
    credit = sched.submit_chunk("s", table[:20])
    assert credit == 12 == sched.credit("s")
    with pytest.raises(StreamBusy) as exc:
        sched.submit_chunk("s", table[20:40])  # 20 > 12
    assert exc.value.credit == 12 and exc.value.offered == 20
    assert sched.stats.busy_rejections == 1
    assert sched.credit("s") == 12  # rejected chunk took nothing
    sched.step()  # consumes one decode chunk -> credit recovers
    assert sched.credit("s") == 28
    sched.submit_chunk("s", table[20:40])
    fed = 40  # feed the rest within credit, ticking to drain the queue
    while fed < len(table):
        n = min(sched.credit("s"), len(table) - fed)
        if n:
            sched.submit_chunk("s", table[fed : fed + n])
            fed += n
        sched.step()
    sched.close("s")
    out = sched.run()
    ref, _ = viterbi_decode(CODE, bm)
    np.testing.assert_array_equal(out["s"][0], np.asarray(ref[0]))


def test_backpressure_bounds_queue_depth(rng):
    """A producer can never push a stream's unconsumed rows past its bound,
    no matter how fast it generates."""
    _, bm = _noisy_bm(rng, 510)
    sched = StreamScheduler(
        CODE, n_slots=1, chunk=16, depth=30, backend="scan", max_buffered=48
    )
    sched.open_stream("s", producer=iter([bm[0]]))  # one 512-row burst
    depths = []
    while sched.pending_work():
        sched.step()
        depths.append(sched.load_report()["queued_rows_total"])
    assert max(depths) <= 48
    ref, _ = viterbi_decode(CODE, bm)
    np.testing.assert_array_equal(sched.results["s"][0], np.asarray(ref[0]))


def test_producer_fed_run_drains_everything(rng):
    """run() busy-polls producer-fed streams to completion; generator sizes
    are decoupled from chunk and credit."""
    sched = StreamScheduler(
        CODE, n_slots=2, chunk=16, depth=300, backend="scan", max_buffered=40
    )
    refs = {}
    for i in range(5):
        _, bm = _noisy_bm(jax.random.fold_in(rng, i), (80, 130, 62)[i % 3])
        refs[f"s{i}"] = viterbi_decode(CODE, bm)
        sched.open_stream(
            f"s{i}", producer=_chunks_of(bm[0], (9, 33, 5, 70, 21, 48))
        )
    out = sched.run()
    for sid, (rb, rm) in refs.items():
        np.testing.assert_array_equal(out[sid][0], np.asarray(rb[0]))
        assert abs(out[sid][1] - float(rm[0])) < 1e-3 * max(1.0, abs(float(rm[0])))


def test_run_raises_on_starved_stream_without_producer(rng):
    sched = StreamScheduler(CODE, n_slots=1, chunk=16, depth=30, backend="scan")
    sched.open_stream("stuck")
    sched.submit_chunk("stuck", _noisy_bm(rng, 6)[1][0])  # < one chunk, no close
    with pytest.raises(RuntimeError, match="starved with no producer"):
        sched.run()
    sched.close("stuck")  # now it can retire
    out = sched.run()
    assert "stuck" in out


# --------------------------------------------------------------------------- #
# lifecycle edges of the chunk path                                            #
# --------------------------------------------------------------------------- #


def test_open_close_zero_rows(rng):
    """open + close with no rows at all: retires with empty bits, slot
    recycled, later streams unaffected."""
    sched = StreamScheduler(CODE, n_slots=1, chunk=16, depth=30, backend="scan")
    sched.open_stream("empty")
    sched.close("empty")
    _, bm = _noisy_bm(rng, 62)
    ref, _ = viterbi_decode(CODE, bm)
    sched.submit("real", bm[0])
    out = sched.run()
    assert out["empty"][0].shape == (0,)
    np.testing.assert_array_equal(out["real"][0], np.asarray(ref[0]))


def test_early_close_mid_chunk_tail(rng):
    """close() with a buffered sub-chunk tail (the connection dropped):
    the tail is finalized through the grouped tail-feed, bit-exact."""
    sched = StreamScheduler(CODE, n_slots=2, chunk=32, depth=200, backend="scan")
    _, bm = _noisy_bm(rng, 75)  # 77 steps: 2 full chunks + 13-row tail
    ref, _ = viterbi_decode(CODE, bm)
    sched.open_stream("s")
    sched.submit_chunk("s", bm[0][:64])
    sched.step()
    sched.submit_chunk("s", bm[0][64:])  # 13 rows
    sched.close("s")
    out = sched.run()
    np.testing.assert_array_equal(out["s"][0], np.asarray(ref[0]))


def test_chunk_api_validation(rng):
    # a queue bound below one decode chunk could never fill a tick: the
    # stream would starve forever with zero credit — rejected up front
    with pytest.raises(ValueError, match="max_buffered"):
        StreamScheduler(CODE, n_slots=1, chunk=16, backend="scan", max_buffered=8)
    sched = StreamScheduler(CODE, n_slots=1, chunk=16, depth=30, backend="scan")
    with pytest.raises(ValueError, match="max_buffered"):
        sched.open_stream("tiny-bound", max_buffered=4)
    with pytest.raises(KeyError, match="unknown or finished"):
        sched.submit_chunk("nope", np.zeros((4, CODE.n_symbols), np.float32))
    with pytest.raises(KeyError, match="unknown or finished"):
        sched.close("nope")
    sched.open_stream("s")
    with pytest.raises(KeyError, match="duplicate"):
        sched.open_stream("s")
    with pytest.raises(ValueError, match="shaped"):
        sched.submit_chunk("s", np.zeros((4, 3), np.float32))
    sched.close("s")
    with pytest.raises(RuntimeError, match="closed"):
        sched.submit_chunk("s", np.zeros((4, CODE.n_symbols), np.float32))
    sched.run()
    with pytest.raises(KeyError):  # finished streams are gone from the intake
        sched.close("s")


def test_evict_pending_chunk_fed_stream(rng):
    """Evicting a stream that queued rows but never got a slot drops its
    host-side queue cleanly."""
    sched = StreamScheduler(CODE, n_slots=1, chunk=16, depth=30, backend="scan")
    _, bm_a = _noisy_bm(rng, 158)
    sched.submit("a", bm_a[0])
    sched.open_stream("b")
    sched.submit_chunk("b", _noisy_bm(jax.random.fold_in(rng, 1), 62)[1][0])
    assert sched.evict("b") is None  # pending: nothing committed
    out = sched.run()
    assert set(out) == {"a"}


def test_load_report_queue_depth_stats(rng):
    sched = StreamScheduler(
        CODE, n_slots=2, chunk=16, depth=30, backend="scan", max_buffered=64
    )
    _, bm = _noisy_bm(rng, 62)
    sched.open_stream("starved")  # admitted, nothing buffered
    sched.open_stream("fed")
    sched.submit_chunk("fed", bm[0][:40])
    report = sched.load_report()
    assert report["active_total"] == 2
    assert report["queued_rows_total"] == 40
    assert report["starved_active"] >= 1  # 'starved' holds no full chunk
    assert sum(report["per_shard_queued_rows"]) == 40
    sched.submit_chunk("fed", bm[0][40:], close=True)
    sched.close("starved")
    sched.run()
    assert sched.load_report()["queued_rows_total"] == 0


# --------------------------------------------------------------------------- #
# arrival-schedule fuzz (hypothesis)                                           #
# --------------------------------------------------------------------------- #

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # dev-only dep: the fuzz leg runs in CI
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @st.composite
    def arrival_schedules(draw):
        """Per-stream arrival plans: chunk sizes (bursty), starvation gaps,
        and whether the stream closes early (truncating mid-chunk)."""
        n_streams = draw(st.integers(2, 4))
        plans = []
        for _ in range(n_streams):
            info_bits = draw(st.integers(20, 140))
            sizes = draw(st.lists(st.integers(1, 70), min_size=1, max_size=8))
            gap = draw(st.integers(0, 3))  # ticks between deliveries
            early_close = draw(st.integers(0, 1))
            plans.append((info_bits, tuple(sizes), gap, early_close))
        seed = draw(st.integers(0, 2 ** 16))
        return plans, seed

else:  # pragma: no cover - placeholder so the skip is visible in reports

    def arrival_schedules():
        return None

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda fn: fn


@settings(max_examples=12, deadline=None)
@given(arrival_schedules())
def test_fuzz_arrival_schedule_invariance(case):
    """However chunks arrive — bursty, starved, early-closed — the online
    decode is bit-identical to one-shot submit() of the same rows."""
    plans, seed = case
    key = jax.random.PRNGKey(seed)
    # full telemetry on the online side: tracing + metrics + device counters
    # must observe the decode, never perturb it — the invariance holds with
    # the instrumented tick vs the bare offline scheduler
    online = StreamScheduler(CODE, n_slots=2, chunk=16, depth=400, backend="scan",
                             telemetry=Telemetry.enabled(device_counters=True))
    offline = StreamScheduler(CODE, n_slots=2, chunk=16, depth=400, backend="scan")
    feeds = {}
    for i, (info_bits, sizes, gap, early_close) in enumerate(plans):
        _, bm = _noisy_bm(jax.random.fold_in(key, i), info_bits, 0.04)
        table = bm[0]
        chunks = _chunks_of(table, sizes)
        if early_close:
            chunks = chunks[: max(1, len(chunks) - 1)]  # drop the tail: early EOF
        actual = np.concatenate(chunks, axis=0)
        sid = f"s{i}"
        offline.submit(sid, actual)
        online.open_stream(sid)
        feeds[sid] = {"chunks": chunks, "gap": gap, "wait": 0}
    guard = 0
    while online.pending_work():
        for sid, f in feeds.items():
            if not f["chunks"]:
                continue
            if f["wait"] > 0:
                f["wait"] -= 1
                continue
            try:
                online.submit_chunk(sid, f["chunks"][0])
            except StreamBusy:
                continue
            f["chunks"].pop(0)
            f["wait"] = f["gap"]
            if not f["chunks"]:
                online.close(sid)
        online.step()
        guard += 1
        assert guard < 2000, "online drain did not converge"
    out_online, out_offline = online.results, offline.run()
    for sid in out_offline:
        np.testing.assert_array_equal(out_online[sid][0], out_offline[sid][0])
        assert abs(out_online[sid][1] - out_offline[sid][1]) <= 1e-3 * max(
            1.0, abs(out_offline[sid][1])
        )
