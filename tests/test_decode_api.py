"""Unified decode API: CodecSpec, DecoderRegistry, shape-aware planner, and
the backend-equivalence golden grid.

The golden grid is the acceptance gate for the registry re-home: every
registered Viterbi ("conv"-family) backend must agree bit-exactly with
core.viterbi.viterbi_decode over (code K3/K7 x punctured/unpunctured x
hard/soft x terminated/open).  The SISO "bcjr"/"turbo" entries are a
different code family (routed by spec.family, never by shape) and are gated
in tests/test_siso.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CODE_K3_STD, CODE_K7_NASA, viterbi_decode
from repro.core.puncture import PUNCTURE_2_3
from repro.decode import (
    LONG_BLOCK_T,
    CodecSpec,
    DecodeContext,
    DecodeRequest,
    DecoderRegistry,
    decode,
    get_decoder,
    list_decoders,
    plan_decode,
)

GRID_CODES = {"k3": CODE_K3_STD, "k7": CODE_K7_NASA}
EXPECTED_BACKENDS = (
    "bcjr", "fused", "fused_packed", "parallel", "seqparallel", "sequential",
    "sharded_stream", "streaming", "tiled", "turbo",
)
#: the Viterbi backends the bit-exact equivalence grid sweeps (same family,
#: same algebra); SISO backends decode a different family and are excluded.
CONV_BACKENDS = tuple(
    n for n in EXPECTED_BACKENDS if n not in ("bcjr", "turbo")
)


def _grid_tables(spec: CodecSpec, key, batch=2, n_info=30):
    """bits + branch-metric tables for one golden-grid cell."""
    bits = jax.random.bernoulli(key, 0.5, (batch, n_info)).astype(jnp.int32)
    coded = spec.encode(bits)
    if spec.soft:
        rx = spec.channel(jax.random.fold_in(key, 1), coded, snr_db=4.0)
    else:
        rx = spec.channel(jax.random.fold_in(key, 1), coded, flip_prob=0.03)
    return bits, spec.branch_metrics(rx)


# --------------------------------------------------------------------------- #
# CodecSpec                                                                    #
# --------------------------------------------------------------------------- #


def test_codec_spec_is_hashable_and_normalizes_patterns():
    a = CodecSpec(code=CODE_K3_STD, puncture=PUNCTURE_2_3)
    b = CodecSpec(code=CODE_K3_STD, puncture=((1, 1), (1, 0)))
    assert a == b and hash(a) == hash(b)
    assert isinstance(a.puncture, tuple)
    np.testing.assert_array_equal(a.puncture_array, PUNCTURE_2_3)
    assert {a: "ok"}[b] == "ok"


def test_codec_spec_validation():
    with pytest.raises(ValueError):
        CodecSpec(metric="llr2")
    with pytest.raises(ValueError):
        CodecSpec(puncture=((1, 1),))  # wrong n_out rows
    with pytest.raises(TypeError):
        CodecSpec.of("k3")


def test_codec_spec_flush_accounting(rng):
    spec = CodecSpec(code=CODE_K3_STD, terminated=True)
    open_spec = dataclasses.replace(spec, terminated=False)
    bits = jax.random.bernoulli(rng, 0.5, (2, 10)).astype(jnp.int32)
    assert spec.encode(bits).shape == (2, 12, 2)  # K-1 flush steps
    assert open_spec.encode(bits).shape == (2, 10, 2)
    assert spec.n_flush == 2 and open_spec.n_flush == 0
    assert spec.strip_flush(jnp.zeros((2, 12))).shape == (2, 10)
    assert open_spec.strip_flush(jnp.zeros((2, 10))).shape == (2, 10)


def test_codec_spec_soft_channel_needs_snr(rng):
    spec = CodecSpec(metric="soft")
    with pytest.raises(ValueError):
        spec.channel(rng, jnp.zeros((1, 4, 2)))


# --------------------------------------------------------------------------- #
# registry                                                                     #
# --------------------------------------------------------------------------- #


def test_all_builtin_backends_registered():
    assert list_decoders() == tuple(sorted(EXPECTED_BACKENDS))
    for name in EXPECTED_BACKENDS:
        dec = get_decoder(name)
        assert dec.name == name and dec.summary


def test_registry_rejects_duplicates_and_unknown():
    reg = DecoderRegistry()

    @reg.register("x", summary="first")
    def _x(spec, bm, *, ctx):
        return None

    with pytest.raises(KeyError):

        @reg.register("x")
        def _x2(spec, bm, *, ctx):
            return None

    with pytest.raises(KeyError, match="registered"):
        reg.get("nope")
    with pytest.raises(KeyError, match="fused"):
        get_decoder("no-such-backend")


def test_capability_records():
    assert get_decoder("seqparallel").capabilities.requires_mesh
    assert get_decoder("streaming").capabilities.supports_streaming
    assert get_decoder("fused").capabilities.max_states is not None
    caps = get_decoder("sharded_stream").capabilities
    assert caps.sharded_stream and caps.requires_mesh and caps.supports_streaming
    for name in CONV_BACKENDS:
        assert get_decoder(name).capabilities.family == "conv"
    assert get_decoder("bcjr").capabilities.family == "rsc"
    assert get_decoder("turbo").capabilities.family == "turbo"


# --------------------------------------------------------------------------- #
# golden grid: every backend == core.viterbi_decode, bit-exact                 #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_name", sorted(GRID_CODES))
@pytest.mark.parametrize("punctured", [False, True], ids=["unpunct", "punct23"])
@pytest.mark.parametrize("metric", ["hard", "soft"])
@pytest.mark.parametrize("terminated", [True, False], ids=["term", "open"])
def test_backend_equivalence_grid(code_name, punctured, metric, terminated,
                                  mesh11, rng):
    code = GRID_CODES[code_name]
    spec = CodecSpec(
        code=code,
        metric=metric,
        puncture=PUNCTURE_2_3 if punctured else None,
        terminated=terminated,
    )
    # deterministic per-cell fold (hash(spec) would vary with PYTHONHASHSEED)
    cell = (
        code.constraint * 8 + punctured * 4 + (metric == "soft") * 2 + terminated
    )
    key = jax.random.fold_in(rng, cell)
    _, bm = _grid_tables(spec, key)
    T = bm.shape[1]
    ref_bits, ref_metric = viterbi_decode(code, bm, terminated=terminated)

    for name in CONV_BACKENDS:
        needs_mesh = get_decoder(name).capabilities.requires_mesh
        ctx = DecodeContext(
            mesh=mesh11 if needs_mesh else None,
            chunk=16,
            stream_depth=T,  # window covers the block -> exactness regime
        )
        res = get_decoder(name)(spec, bm, ctx=ctx)
        np.testing.assert_array_equal(
            np.asarray(res.bits), np.asarray(ref_bits),
            err_msg=f"backend {name!r} diverged on {spec.describe()}",
        )
        np.testing.assert_allclose(
            np.asarray(res.path_metric), np.asarray(ref_metric), rtol=1e-5,
            err_msg=f"backend {name!r} metric diverged on {spec.describe()}",
        )
        assert res.spec == spec
        assert res.diagnostics["backend"] == name


# --------------------------------------------------------------------------- #
# planner                                                                      #
# --------------------------------------------------------------------------- #


def test_planner_picks_fused_packed_for_short_batched_blocks():
    plan = plan_decode(CodecSpec(), (32, 256))
    assert plan.backend == "fused_packed"
    assert "short batched block" in plan.reason


def test_planner_picks_tiled_for_long_blocks_without_mesh():
    plan = plan_decode(CodecSpec(), (4, LONG_BLOCK_T))
    assert plan.backend == "tiled"
    assert "no mesh" in plan.reason
    assert "long-conv-tiled" in plan.reason
    assert plan.ctx.tiles is not None and plan.ctx.tiles >= 1


def test_planner_honors_pinned_tile_count():
    ctx = DecodeContext(tiles=4)
    plan = plan_decode(CodecSpec(), (4, LONG_BLOCK_T), ctx=ctx)
    assert plan.backend == "tiled"
    assert plan.ctx.tiles == 4
    assert "pinned by caller" in plan.reason


def test_planner_picks_seqparallel_for_long_blocks_on_mesh(mesh11):
    plan = plan_decode(CodecSpec(), (4, 2 * LONG_BLOCK_T), mesh=mesh11)
    assert plan.backend == "seqparallel"


def test_planner_falls_back_when_mesh_lacks_axis():
    """A data-parallel-only mesh (no 'model' axis) must fall back to the
    single-device time-parallel route, not crash on the axis lookup."""
    mesh = jax.make_mesh((1,), ("data",))
    plan = plan_decode(CodecSpec(), (4, 2 * LONG_BLOCK_T), mesh=mesh)
    assert plan.backend == "tiled"
    assert "lacks axis" in plan.reason


def test_windowed_decode_defaults_terminated_from_spec(rng):
    """viterbi_decode_windowed given an open CodecSpec must trace back from
    the best frontier state by default, not silently force state 0."""
    from repro.stream import viterbi_decode_windowed

    spec = CodecSpec(terminated=False)
    bits = jax.random.bernoulli(rng, 0.5, (2, 50)).astype(jnp.int32)
    bm = spec.branch_metrics(spec.encode(bits))  # noiseless open block
    ref_bits, ref_metric = viterbi_decode(spec.code, bm, terminated=False)
    got_bits, got_metric = viterbi_decode_windowed(spec, bm, depth=bm.shape[1])
    np.testing.assert_array_equal(np.asarray(got_bits), np.asarray(ref_bits))
    np.testing.assert_allclose(np.asarray(got_metric), np.asarray(ref_metric))


def test_planner_picks_streaming_for_session_context():
    plan = plan_decode(CodecSpec(), (1, 10_000_000),
                       ctx=DecodeContext(streaming=True, stream_depth=15))
    assert plan.backend == "streaming"


class _StubMesh:
    """Planner-only mesh stand-in: plan_decode reads nothing but
    ``mesh.shape`` (a Mapping), so routing rules for multi-device meshes are
    unit-testable on the single-CPU suite (execution runs in
    tests/multidevice on real fake devices)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


def test_planner_routes_multi_device_streaming_to_sharded_stream():
    ctx = DecodeContext(streaming=True, stream_depth=15)
    plan = plan_decode(CodecSpec(), (64, 4096), mesh=_StubMesh(data=8, model=1),
                       ctx=ctx)
    assert plan.backend == "sharded_stream"
    assert "data=8" in plan.reason


def test_planner_streaming_falls_back_when_trellis_exceeds_sharded_cap():
    """S above the fused VMEM cap must fall back to the uncapped streaming
    backend, not raise (regression: the sharded route skipped max_states)."""
    from repro.core import ConvCode
    from repro.decode.backends import FUSED_MAX_STATES

    big = CodecSpec(code=ConvCode(14, (0o32721, 0o26741)))
    assert big.code.n_states > FUSED_MAX_STATES
    ctx = DecodeContext(streaming=True, stream_depth=15)
    plan = plan_decode(big, (64, 4096), mesh=_StubMesh(data=8), ctx=ctx)
    assert plan.backend == "streaming"
    assert "exceeds" in plan.reason


def test_stream_defaults_weak_scaling_rule():
    """The config's one slot-table sizing rule: per-shard load x shards."""
    from repro.configs.paper_viterbi import STREAM

    assert STREAM.mesh_axis == "data"
    assert STREAM.n_slots_for(8) == 8 * STREAM.n_slots
    assert STREAM.n_slots_for(4, slots_per_shard=16) == 64
    assert STREAM.n_slots_for(1) == STREAM.n_slots


def test_planner_keeps_streaming_on_unit_data_axis(mesh11):
    """A streaming context with a 1-device data axis stays on the plain
    streaming backend — sharding only pays for itself past one device (the
    multi-device routing positive case runs in tests/multidevice)."""
    plan = plan_decode(CodecSpec(), (8, 4096), mesh=mesh11,
                       ctx=DecodeContext(streaming=True, stream_depth=15))
    assert plan.backend == "streaming"


def test_sharded_stream_backend_validation(mesh11):
    """Explicit sharded_stream override: refuses to run without a mesh, and
    refuses a mesh lacking the batch axis; a unit data axis is accepted."""
    with pytest.raises(ValueError, match="mesh"):
        plan_decode(CodecSpec(), (8, 64), backend="sharded_stream")
    model_only = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="data"):
        plan_decode(CodecSpec(), (8, 64), backend="sharded_stream", mesh=model_only)
    plan = plan_decode(CodecSpec(), (8, 64), backend="sharded_stream", mesh=mesh11)
    assert plan.backend == "sharded_stream"


def test_planner_override_and_validation(mesh11):
    plan = plan_decode(CodecSpec(), (4, 2 * LONG_BLOCK_T), backend="sequential")
    assert plan.backend == "sequential" and "override" in plan.reason
    with pytest.raises(KeyError):
        plan_decode(CodecSpec(), (4, 64), backend="no-such-backend")
    with pytest.raises(ValueError, match="mesh"):
        plan_decode(CodecSpec(), (4, 64), backend="seqparallel")  # no mesh given
    plan_decode(CodecSpec(), (4, 64), backend="seqparallel", mesh=mesh11)  # fine


def test_planner_is_deterministic_and_explains():
    a = plan_decode(CodecSpec(), (8, 512), ctx=DecodeContext(chunk=32))
    b = plan_decode(CodecSpec(), (8, 512), ctx=DecodeContext(chunk=32))
    assert a == b
    text = a.explain()
    assert a.backend in text and "why:" in text and "caps:" in text


def test_decode_one_shot_roundtrip(rng):
    spec = CodecSpec()
    bits = jax.random.bernoulli(rng, 0.5, (4, 48)).astype(jnp.int32)
    rx = spec.channel(jax.random.fold_in(rng, 1), spec.encode(bits), flip_prob=0.01)
    res = decode(DecodeRequest(spec, received=rx))
    assert res.plan is not None and res.plan.backend == "fused_packed"
    assert res.diagnostics["metrics"] == "in-kernel"  # raw rx skipped the bm table
    assert res.info_bits.shape == bits.shape
    assert float((res.info_bits != bits).mean()) < 0.05
    # shorthand form: decode(spec, rx)
    res2 = decode(spec, rx, backend="sequential")
    np.testing.assert_array_equal(np.asarray(res.bits), np.asarray(res2.bits))


# --------------------------------------------------------------------------- #
# shim removal: repro.decode is the only decode entry point                    #
# --------------------------------------------------------------------------- #


def test_viterbi_head_shim_is_gone():
    """The deprecated serve.viterbi_head module was removed (PR 7); the
    token-packing helpers live on in repro.serve.bits."""
    with pytest.raises(ImportError):
        import repro.serve.viterbi_head  # noqa: F401
    import repro.serve as serve

    assert not hasattr(serve, "ViterbiHead")
    assert callable(serve.tokens_to_bits) and callable(serve.bits_to_tokens)


def test_open_spec_plumbs_terminated_end_to_end(rng):
    """terminated=False flows spec -> encoder (no flush bits) -> backend ->
    traceback through the decode() surface."""
    spec = CodecSpec(terminated=False)
    bits = jax.random.bernoulli(rng, 0.5, (4, 40)).astype(jnp.int32)
    coded = spec.encode(bits)
    assert coded.shape == (4, 40, 2)  # no flush steps appended
    bm = spec.branch_metrics(coded)
    res = decode(spec, coded, backend="sequential")
    assert res.info_bits.shape == bits.shape  # nothing stripped when open
    ref_bits, ref_metric = viterbi_decode(CODE_K3_STD, bm, terminated=False)
    np.testing.assert_array_equal(np.asarray(res.bits), np.asarray(ref_bits))
    np.testing.assert_allclose(
        np.asarray(res.path_metric), np.asarray(ref_metric), rtol=1e-6
    )
    # terminated spec on the same noiseless block: flush stripped, exact
    term = CodecSpec(terminated=True)
    res_t = decode(term, term.encode(bits), backend="sequential")
    assert res_t.info_bits.shape == bits.shape
    np.testing.assert_array_equal(np.asarray(res_t.info_bits), np.asarray(bits))
