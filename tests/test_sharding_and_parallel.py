"""Sharding rules, shard_map collectives (seq-parallel Viterbi, flash
decode), pipeline stage, roofline parsers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import common as cm


# --------------------------------------------------------------------------- #
# resolve_axes                                                                 #
# --------------------------------------------------------------------------- #


def test_resolve_axes_divisibility(mesh11):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = dict(cm.DEFAULT_RULES)
    # kv_heads=2 under model=1: divisible, sharded (trivially)
    spec = cm.resolve_axes(mesh, rules, (8, 2, 64), ("batch", "kv_heads", None))
    assert spec == P(("data",), ("model",)) or spec == P("data", "model")


def test_resolve_axes_never_reuses_axis():
    mesh = jax.make_mesh((1,), ("model",))
    rules = {"a": "model", "b": "model"}
    spec = cm.resolve_axes(mesh, rules, (4, 4), ("a", "b"))
    # second use of 'model' must drop, not duplicate
    flat = [s for s in spec if s is not None]
    assert len(flat) <= 1


def test_resolve_axes_non_dividing_drops():
    mesh = jax.make_mesh((1,), ("model",))
    # size 3 divides 1 trivially; simulate non-division via fake rule chain
    spec = cm.resolve_axes(mesh, {"x": "missing_axis"}, (3,), ("x",))
    assert spec == P()


def test_fsdp_rules_shard_embed_dim(mesh11):
    from repro.parallel.sharding import make_rules
    from repro.configs.base import PartitionConfig

    r = make_rules(PartitionConfig(fsdp=True))
    assert r["embed"] == "data"
    r0 = make_rules(PartitionConfig(fsdp=False))
    assert r0["embed"] is None


# --------------------------------------------------------------------------- #
# sequence-parallel Viterbi (shard_map)                                        #
# --------------------------------------------------------------------------- #


def test_collectives_sum_across_shards(mesh11):
    """The sharded scheduler's scalar reduction: per-shard rows psum to the
    mesh-global total (size-1 data axis here; tests/multidevice covers 8)."""
    from repro.parallel.collectives import mesh_axis_size, sum_across_shards

    assert mesh_axis_size(mesh11, "data") == 1
    assert mesh_axis_size(mesh11, "nope") == 0
    assert mesh_axis_size(None, "data") == 0
    total = sum_across_shards(mesh11, "data", jnp.asarray([[3, 5]]))
    np.testing.assert_array_equal(np.asarray(total), [3, 5])


def test_seqparallel_viterbi_matches_sequential(mesh11, rng):
    from repro.core import CODE_K3_STD, bsc, encode, hard_branch_metrics, viterbi_decode
    from repro.parallel.collectives import viterbi_decode_seqparallel

    code = CODE_K3_STD
    bits = jax.random.bernoulli(rng, 0.5, (4, 62)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(rng, 1), coded, 0.05)
    bm = hard_branch_metrics(code, rx)
    d_ref, m_ref = viterbi_decode(code, bm)
    with mesh11:
        d_sp, m_sp = viterbi_decode_seqparallel(code, bm, mesh11)
    np.testing.assert_allclose(np.asarray(m_ref), np.asarray(m_sp), rtol=1e-5)
    assert (np.asarray(d_ref) == np.asarray(d_sp)).all()


# --------------------------------------------------------------------------- #
# pipeline                                                                     #
# --------------------------------------------------------------------------- #


def test_pipeline_single_stage_identity(rng):
    from repro.parallel.pipeline import bubble_fraction, pipeline_apply

    mesh = jax.make_mesh((1,), ("stage",))
    W = jax.random.normal(rng, (1, 8, 8))

    def layer(w, h):
        return jnp.tanh(h @ w)

    x = jax.random.normal(jax.random.fold_in(rng, 1), (3, 4, 8))  # 3 microbatches
    out = pipeline_apply(layer, W, x, mesh=mesh, axis="stage")
    ref = jnp.stack([layer(W[0], x[i]) for i in range(3)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)


# --------------------------------------------------------------------------- #
# roofline parsers                                                             #
# --------------------------------------------------------------------------- #


def test_collective_parser_shapes():
    from repro.roofline.analysis import _shape_bytes, collective_bytes

    assert _shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert _shape_bytes("(bf16[4,4], f32[2])") == 4 * 4 * 2 + 2 * 4
    hlo = """
  %ag = f32[16,256]{1,0} all-gather(f32[1,256]{1,0} %x), replica_groups={}
  %ar = bf16[8,8]{1,0} all-reduce(bf16[8,8]{1,0} %y), to_apply=%add
"""
    out = collective_bytes(hlo)
    assert out["per_kind"]["all-gather"] == 16 * 256 * 4
    assert out["per_kind"]["all-reduce"] == 8 * 8 * 2
    assert out["counts"]["all-gather"] == 1


def test_while_trip_parser():
    from repro.roofline.hlo_loops import collective_bytes_with_trips

    hlo = """HloModule test

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4]{0} all-reduce(%gte), to_apply=%add
}

%cond (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
  %ag = f32[8]{0} all-gather(%gte2), replica_groups={}
}
"""
    out = collective_bytes_with_trips(hlo)
    assert out["trip_corrected"]
    # all-reduce: 4*4 bytes * 2 (AR convention) * 7 trips; all-gather: 8*4 once
    assert out["per_kind"]["all-reduce"] == 4 * 4 * 2 * 7
    assert out["per_kind"]["all-gather"] == 8 * 4


def test_jaxpr_cost_counts_scan_trips():
    from repro.roofline.jaxpr_cost import count_fn_costs

    W = jnp.zeros((32, 32))

    def fn(x):
        def body(h, _):
            return jnp.tanh(h @ W), None

        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    out = count_fn_costs(fn, jnp.zeros((4, 32)))
    dot_flops = 2 * 4 * 32 * 32
    assert out["flops"] >= 10 * dot_flops  # 10 trips counted
    assert out["flops"] < 12 * dot_flops + 10 * 4 * 32 * 5  # no gross overcount


def test_jaxpr_cost_counts_remat():
    from repro.roofline.jaxpr_cost import count_fn_costs

    W = jnp.zeros((16, 16))

    def loss(x):
        f = jax.checkpoint(lambda h: jnp.tanh(h @ W))
        return f(f(x)).sum()

    plain = count_fn_costs(jax.grad(loss), jnp.zeros((2, 16)))
    # remat recompute present: > fwd(2 dots) + bwd(4 dots)
    assert plain["flops"] > 6 * 2 * 2 * 16 * 16


def test_model_flops_conventions():
    from repro.configs.base import SHAPES, get_arch
    from repro.roofline.analysis import model_flops

    bundle = get_arch("qwen3_4b")
    mf_train = model_flops(bundle.model, SHAPES["train_4k"])
    mf_decode = model_flops(bundle.model, SHAPES["decode_32k"])
    n = bundle.model.param_count()["active"]
    assert mf_train == pytest.approx(6 * n * 4096 * 256)
    assert mf_decode == pytest.approx(2 * n * 128)
