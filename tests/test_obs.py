"""Telemetry plane: metrics / tracing / logging primitives, device-side
decode counters, and the scheduler + session instrumentation contract.

The load-bearing guarantees under test:

  * the metric primitives are exact where they claim exactness (count, sum,
    min, max) and ordered where they claim order (p50 <= p95);
  * decode output is bit-identical with telemetry on — tracing and device
    counters observe, never perturb;
  * device counters add ZERO per-tick host syncs: the tick's only
    device->host materialization stays the committed-bits transfer (spied
    on below by counting ``np.asarray(jax.Array)`` calls);
  * ``survivor_merge_depth`` matches a brute-force walker oracle;
  * every ``load_report()`` field exists and satisfies its invariant on the
    single-device AND the unit-mesh sharded scheduler.
"""
import io
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CODE_K3_STD,
    bsc,
    encode,
    hard_branch_metrics,
)
from repro.decode import plan_decode
from repro.obs import (
    MetricsRegistry,
    Telemetry,
    Tracer,
    percentile,
    span,
)
from repro.obs.log import get_logger, kv
from repro.obs.metrics import Histogram
from repro.parallel.collectives import reduce_across_shards
from repro.stream import StreamScheduler, StreamSession
from repro.stream import window as _w
from repro.stream.scheduler import TICK_PHASES

CODE = CODE_K3_STD


def _noisy_bm(code, key, batch, info_bits, flip=0.04):
    bits = jax.random.bernoulli(key, 0.5, (batch, info_bits)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(key, 1), coded, flip)
    return bits, hard_branch_metrics(code, rx)


# --------------------------------------------------------------------------- #
# metrics primitives                                                           #
# --------------------------------------------------------------------------- #


def test_percentile_nearest_rank():
    vals = [5.0, 1.0, 3.0, 2.0, 4.0]  # unsorted on purpose
    assert percentile(vals, 0.0) == 1.0
    assert percentile(vals, 0.5) == 3.0
    assert percentile(vals, 0.95) == 5.0
    assert percentile(vals, 1.0) == 5.0


def test_percentile_empty_and_bounds():
    assert percentile([], 0.5) == 0.0
    assert percentile([], 0.5, default=-1.0) == -1.0
    with pytest.raises(ValueError):
        percentile([1.0], 1.5)
    with pytest.raises(ValueError):
        percentile([1.0], -0.1)


def test_counter_and_gauge():
    m = MetricsRegistry()
    c = m.counter("ticks")
    c.inc()
    c.inc(3)
    assert c.value == 4
    c.set(10)  # absorbing an external monotone count
    assert c.value == 10
    g = m.gauge("depth")
    g.set(2.5)
    g.inc()
    g.dec(0.5)
    assert g.value == 3.0


def test_histogram_exact_envelope_and_quantiles():
    h = Histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 100.0):  # last lands in the +inf overflow
        h.observe(v)
    assert h.count == 4
    assert h.sum == pytest.approx(105.0)
    assert h.min == 0.5 and h.max == 100.0
    assert h.counts == [1, 1, 1, 1]
    # bucket-upper estimate, clamped into the exact [min, max] envelope
    assert h.quantile(0.5) == 2.0
    assert h.quantile(1.0) == 100.0
    s = h.summary()
    assert set(s) == {"count", "mean", "p50", "p95", "max"}
    assert s["p50"] <= s["p95"] <= s["max"]


def test_histogram_single_observation_is_exact():
    h = Histogram("one", buckets=(1.0, 4.0))
    h.observe(3.0)
    # 3.0 falls in the le=4 bucket, but clamping reports the sample itself
    assert h.quantile(0.5) == 3.0 == h.quantile(0.95) == h.max == h.min


def test_histogram_empty_summary():
    h = Histogram("empty", buckets=(1.0,))
    assert h.summary() == {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                           "max": 0.0}


def test_registry_get_or_create_and_kind_mismatch():
    m = MetricsRegistry()
    assert m.counter("x") is m.counter("x")
    with pytest.raises(TypeError):
        m.gauge("x")
    m.histogram("h", buckets=(1, 2)).observe(1.5)
    snap = m.snapshot()
    assert snap["x"] == 0.0
    assert snap["h"]["count"] == 1
    assert list(snap) == sorted(snap)


def test_registry_prometheus_render():
    m = MetricsRegistry()
    m.counter("reqs", help="requests").inc(2)
    m.gauge("util").set(0.5)
    m.histogram("lat", buckets=(1.0, 2.0)).observe(1.5)
    text = m.render()
    assert "# HELP reqs requests" in text
    assert "# TYPE reqs counter" in text and "reqs 2" in text
    assert "# TYPE util gauge" in text
    assert '# TYPE lat histogram' in text
    assert 'lat_bucket{le="2"} 1' in text  # cumulative
    assert 'lat_bucket{le="+Inf"} 1' in text
    assert "lat_count 1" in text


# --------------------------------------------------------------------------- #
# tracing                                                                      #
# --------------------------------------------------------------------------- #


def test_span_disabled_is_noop():
    s = span(None, "anything")
    with s:
        pass
    assert span(None, "other") is s  # one shared instance, no allocation


def test_tracer_records_nested_spans_and_coverage():
    tr = Tracer("test")
    with span(tr, "tick"):
        with span(tr, "step"):
            pass
        with span(tr, "commit"):
            pass
    assert len(tr) == 3
    assert tr.durations_s("tick") and tr.total_s("tick") > 0
    cov = tr.coverage("tick", ("step", "commit"))
    assert 0.0 < cov <= 1.0
    assert tr.coverage("missing", ("step",)) == 0.0
    tr.instant("evict")
    assert tr.durations_s("evict") == [0.0]
    tr.clear()
    assert len(tr) == 0


def test_tracer_chrome_and_jsonl_export(tmp_path):
    tr = Tracer("proc-name")
    with span(tr, "tick"):
        pass
    events = tr.chrome_events()
    meta, ev = events[0], events[1]
    assert meta["ph"] == "M" and meta["args"]["name"] == "proc-name"
    assert ev["ph"] == "X" and ev["name"] == "tick"
    assert ev["ts"] >= 0 and ev["dur"] >= 0 and ev["pid"] == 1
    tr.write_chrome(tmp_path / "trace.json")
    payload = json.loads((tmp_path / "trace.json").read_text())
    assert payload["traceEvents"][1]["name"] == "tick"
    tr.write_jsonl(tmp_path / "trace.jsonl")
    lines = (tmp_path / "trace.jsonl").read_text().splitlines()
    assert len(lines) == 1
    assert json.loads(lines[0])["name"] == "tick"


# --------------------------------------------------------------------------- #
# structured logging                                                           #
# --------------------------------------------------------------------------- #


def test_kv_formatting():
    line = kv(a=1, rate=123456.789, label="two words", flag=True)
    assert "a=1" in line
    assert "rate=123457" in line  # 6 significant digits
    assert "label='two words'" in line
    assert "flag=True" in line


def test_get_logger_structured_lines_and_quiet():
    buf = io.StringIO()
    log = get_logger("test-obs", stream=buf)
    log.info("tick done", bits=64, elapsed_s=0.25)
    assert "tick done bits=64 elapsed_s=0.25" in buf.getvalue()

    quiet_buf = io.StringIO()
    log = get_logger("test-obs", quiet=True, stream=quiet_buf)
    log.info("suppressed", n=1)
    log.warning("kept", n=2)
    out = quiet_buf.getvalue()
    assert "suppressed" not in out and "kept n=2" in out
    # reconfiguration replaced (not stacked) the handler: exactly one line
    assert out.count("\n") == 1


# --------------------------------------------------------------------------- #
# survivor merge depth: device computation vs brute-force oracle               #
# --------------------------------------------------------------------------- #


def _merge_depth_oracle(code, ring):
    """Walk all S survivor paths back from the frontier one step at a time;
    the merge depth is the first step at which they all sit on one node."""
    ring = np.asarray(ring)
    R, B, S = ring.shape
    half = S // 2
    out = np.full((B,), R + 1, dtype=np.int32)
    for b in range(B):
        walkers = np.arange(S)
        for d, i in enumerate(range(R - 1, -1, -1), start=1):
            j = ring[i, b][walkers]
            v = walkers & (half - 1) if half > 1 else np.zeros_like(walkers)
            walkers = 2 * v + j
            if (walkers == walkers[0]).all():
                out[b] = d
                break
    return out


def test_survivor_merge_depth_matches_oracle(rng):
    sess = StreamSession(CODE, batch=4, chunk=16, depth=16, backend="scan")
    _, bm = _noisy_bm(CODE, rng, 4, 94)
    for i in range(4):  # 64 steps: the (R=32)-deep ring is fully real
        sess.push(bm[:, i * 16 : (i + 1) * 16])
    got = np.asarray(_w.survivor_merge_depth(CODE, sess.state.ring))
    np.testing.assert_array_equal(got, _merge_depth_oracle(CODE, sess.state.ring))
    assert (1 <= got).all() and (got <= sess.ring_size + 1).all()


def test_survivor_merge_depth_unpacks_packed_rings(rng):
    sess = StreamSession(CODE, batch=2, chunk=32, depth=32, backend="fused_packed")
    _, bm = _noisy_bm(CODE, rng, 2, 126)
    for i in range(2):
        sess.push(bm[:, i * 32 : (i + 1) * 32])
    assert sess.state.ring.dtype == jnp.uint32
    got = np.asarray(_w.survivor_merge_depth(CODE, sess.state.ring))
    unpacked = _w.unpack_ring(CODE, sess.state.ring)
    np.testing.assert_array_equal(got, _merge_depth_oracle(CODE, unpacked))


def test_never_merging_ring_reports_sentinel():
    # identity backpointers (j == 0 for even, parity split) never coalesce
    # beyond construction: an all-zeros ring sends every walker to state
    # floor(s/2)*... -- easier: two states that map to themselves forever.
    R, B, S = 8, 1, CODE.n_states
    ring = np.zeros((R, B, S), dtype=np.int32)
    ring[:, :, :] = np.arange(S) % 2  # prev = 2*(s & 1) + (s % 2): fixed pts
    got = np.asarray(_w.survivor_merge_depth(CODE, jnp.asarray(ring)))
    oracle = _merge_depth_oracle(CODE, ring)
    np.testing.assert_array_equal(got, oracle)
    assert (got == R + 1).all()  # walkers 0 and 3 never meet


# --------------------------------------------------------------------------- #
# session telemetry                                                            #
# --------------------------------------------------------------------------- #


def test_session_device_counters_leave_decode_unchanged(rng):
    _, bm = _noisy_bm(CODE, rng, 3, 126)
    plain = StreamSession(CODE, batch=3, chunk=16, depth=30, backend="scan")
    tel = Telemetry.enabled()
    traced = StreamSession(
        CODE, batch=3, chunk=16, depth=30, backend="scan", telemetry=tel
    )
    bits_p, metric_p = plain.decode_all(bm)
    bits_t, metric_t = traced.decode_all(bm)
    np.testing.assert_array_equal(np.asarray(bits_p), np.asarray(bits_t))
    np.testing.assert_allclose(
        np.asarray(metric_p), np.asarray(metric_t), rtol=1e-6
    )
    # push + finish spans were recorded
    assert len(tel.tracer.durations_s("push")) == 8  # 128 // 16 full chunks
    assert len(tel.tracer.durations_s("finish")) == 1
    rep = traced.device_counter_report()
    assert rep["ticks"] == [8, 8, 8]
    assert all(1 <= d <= traced.ring_size + 1 for d in rep["merge_depth_last"])
    assert all(m >= 1 for m in rep["merge_depth_mean"])
    assert all(r >= 0 for r in rep["renorm_sum"])


def test_session_counter_report_requires_flag():
    sess = StreamSession(CODE, batch=1, chunk=16, backend="scan")
    with pytest.raises(RuntimeError):
        sess.device_counter_report()


# --------------------------------------------------------------------------- #
# scheduler telemetry                                                          #
# --------------------------------------------------------------------------- #


def _run_workload(sched, bm_by_id):
    for sid, bm in bm_by_id.items():
        sched.submit(sid, bm)
    return sched.run()


def _make_streams(rng, n, info_bits=94):
    _, bm = _noisy_bm(CODE, rng, n, info_bits)
    return {f"s{i}": bm[i] for i in range(n)}


def test_scheduler_decode_bit_exact_with_full_telemetry(rng):
    streams = _make_streams(rng, 3)
    plain = StreamScheduler(CODE, n_slots=2, chunk=16, depth=30, backend="scan")
    out_p = _run_workload(plain, streams)
    tel = Telemetry.enabled()
    traced = StreamScheduler(
        CODE, n_slots=2, chunk=16, depth=30, backend="scan", telemetry=tel
    )
    out_t = _run_workload(traced, streams)
    for sid in streams:
        np.testing.assert_array_equal(out_p[sid][0], out_t[sid][0])


def test_scheduler_tick_phase_coverage_and_stats_mirror(rng):
    tel = Telemetry.enabled(device_counters=False)
    sched = StreamScheduler(
        CODE, n_slots=2, chunk=16, depth=30, backend="scan", telemetry=tel
    )
    _run_workload(sched, _make_streams(rng, 3))
    tr = tel.tracer
    # every advancing tick gets a span; idle polls (nothing ready) are also
    # spanned but don't count as scheduler ticks
    assert len(tr.durations_s("tick")) >= sched.stats.ticks > 0
    # the named phases account for (at least) 95% of tick wall clock
    assert tr.coverage("tick", TICK_PHASES) >= 0.95
    snap = sched.metrics_snapshot()
    for name, v in sched.stats.asdict().items():
        assert snap[f"scheduler_{name}"] == v
    assert snap["scheduler_active_slots"] == 0  # drained
    assert snap["scheduler_utilization"] == 0.0
    text = sched.metrics_text()
    assert "# TYPE scheduler_ticks counter" in text
    assert "stream_arrival_to_commit_seconds_count" in text


def test_scheduler_stats_deterministic_accounting(rng):
    n, info_bits = 3, 94
    sched = StreamScheduler(CODE, n_slots=2, chunk=16, depth=30, backend="scan",
                            telemetry=Telemetry.enabled())
    _run_workload(sched, _make_streams(rng, n, info_bits))
    T = info_bits + CODE.constraint - 1  # terminated: bits + flush
    s = sched.stats
    assert s.streams_submitted == s.streams_finished == s.slot_claims == n
    assert s.steps_decoded == n * T
    assert s.chunks_submitted == n
    assert s.busy_rejections == 0
    # one merge-depth observation per retiring stream
    assert sched.telemetry.metrics.histogram("stream_merge_depth").count == n


def _check_load_report_fields(report, n_shards, device_counters):
    for field in ("n_shards", "per_shard_active", "per_shard_queued_rows",
                  "active_total", "pending_total", "queued_rows_total",
                  "pending_rows", "max_stream_queued_rows", "starved_active",
                  "utilization", "latency_s"):
        assert field in report, f"load_report missing {field}"
    assert report["n_shards"] == n_shards
    assert len(report["per_shard_active"]) == n_shards
    assert len(report["per_shard_queued_rows"]) == n_shards
    assert report["active_total"] == sum(report["per_shard_active"])
    assert 0.0 <= report["utilization"] <= 1.0
    lat = report["latency_s"]
    assert set(lat) == {"count", "mean", "p50", "p95", "max"}
    assert 0 <= lat["mean"] <= lat["max"] or lat["count"] == 0
    assert lat["p50"] <= lat["p95"]
    assert ("merge_depth" in report) == device_counters


@pytest.mark.parametrize("device_counters", [False, True])
def test_load_report_fields_single_device(rng, device_counters):
    tel = Telemetry.enabled(device_counters=device_counters)
    sched = StreamScheduler(
        CODE, n_slots=2, chunk=16, depth=60, backend="scan", telemetry=tel
    )
    for sid, bm in _make_streams(rng, 2, info_bits=126).items():
        sched.submit(sid, bm)
    for _ in range(3):  # mid-flight: streams still admitted + decoding
        sched.step()
    report = sched.load_report()
    _check_load_report_fields(report, n_shards=1, device_counters=device_counters)
    assert report["active_total"] == 2
    if device_counters:
        md = report["merge_depth"]
        assert set(md) == {"s0", "s1"}
        R = sched.depth + sched.chunk
        for row in md.values():
            assert set(row) == {"ticks", "starved_ticks", "merge_depth_last",
                                "merge_depth_mean", "merge_depth_max",
                                "renorm_sum"}
            assert row["ticks"] == 3
            assert 1 <= row["merge_depth_last"] <= R + 1
            assert row["merge_depth_mean"] <= row["merge_depth_max"] <= R + 1
    sched.run()
    done = sched.load_report()
    assert done["active_total"] == 0 and done["latency_s"]["count"] >= 2


def test_device_counter_report_requires_flag(rng):
    sched = StreamScheduler(CODE, n_slots=2, chunk=16, backend="scan")
    with pytest.raises(RuntimeError):
        sched.device_counter_report()


def test_device_counters_add_no_per_tick_host_syncs(rng, sanitized_guards):
    """THE zero-sync guarantee: with device counters on, a steady-state tick
    materializes exactly one device array on the host — the committed bits —
    same as with telemetry off entirely.  Runs under the full sanitizer
    bundle (transfer guard + debug-NaNs + recompile counter), with the
    original np.asarray spy kept as an independent cross-check on the
    guard's own host-sync counter."""
    with sanitized_guards.allow_transfers():  # control plane may move data
        streams = _make_streams(rng, 2, info_bits=158)  # 160 steps = 10 ticks
        sched = StreamScheduler(
            CODE, n_slots=2, chunk=16, depth=30, backend="scan",
            telemetry=Telemetry.enabled(device_counters=True),
        )
        for sid, bm in streams.items():
            sched.submit(sid, bm)
        # warm here: trace + compile land before the snapshot, so the
        # steady-state recompile assertion below is a real zero-delta check
        sched.step()

    real_asarray = np.asarray  # already the guard's counting wrapper
    raw_asarray = getattr(real_asarray, "_orig", real_asarray)
    sync_counts = []

    def spy(a, *args, **kwargs):
        caller = sys._getframe(1).f_globals.get("__name__", "")
        if caller == "jax" or caller.startswith("jax."):
            # debug_nans output checks: sanitizer overhead, not user syncs —
            # bypass the guard's counter the same way it would filter them
            return raw_asarray(a, *args, **kwargs)
        if isinstance(a, jax.Array):
            sync_counts.append(1)
        return real_asarray(a, *args, **kwargs)

    np.asarray = spy
    try:
        base = sanitized_guards.snapshot()
        for _ in range(4):  # steady-state ticks, far from the final drain
            before = len(sync_counts)
            tick_base = sanitized_guards.snapshot()
            sched.step()
            assert len(sync_counts) - before == 1, (
                "device counters leaked an extra per-tick host sync"
            )
            assert sanitized_guards.host_syncs - tick_base.host_syncs == 1, (
                "sanitizer host-sync counter disagrees with the spy"
            )
        assert sanitized_guards.recompiles == base.recompiles, (
            "steady-state tick recompiled — shape leak in the tick body"
        )
    finally:
        np.asarray = real_asarray
    with sanitized_guards.allow_transfers():  # drain: finishing slots is
        sched.run()                           # control plane, not the tick


# --------------------------------------------------------------------------- #
# sharded (unit-mesh) scheduler telemetry                                      #
# --------------------------------------------------------------------------- #


def test_sharded_scheduler_telemetry_bit_exact_and_report(rng, mesh11):
    streams = _make_streams(rng, 3)
    plain = StreamScheduler(CODE, n_slots=2, chunk=16, depth=30, backend="scan")
    out_p = _run_workload(plain, streams)
    tel = Telemetry.enabled(device_counters=True)
    sharded = StreamScheduler(
        CODE, n_slots=2, chunk=16, depth=30, backend="scan",
        mesh=mesh11, telemetry=tel,
    )
    for sid, bm in streams.items():
        sharded.submit(sid, bm)
    for _ in range(3):
        sharded.step()
    report = sharded.load_report()
    _check_load_report_fields(report, n_shards=1, device_counters=True)
    for row in report["merge_depth"].values():
        assert row["ticks"] > 0
        assert 1 <= row["merge_depth_last"] <= sharded.depth + sharded.chunk + 1
    out_t = sharded.run()
    for sid in streams:
        np.testing.assert_array_equal(out_p[sid][0], out_t[sid][0])
    assert tel.tracer.coverage("tick", TICK_PHASES) >= 0.95
    assert sharded.load_report()["latency_s"]["count"] >= 3
    assert (
        sharded.telemetry.metrics.histogram("stream_merge_depth").count == 3
    )


def test_reduce_across_shards_ops(mesh11):
    per_shard = jnp.asarray([[3.0, -1.0, 2.0]])  # (n_shards=1, 3)
    for op, expect in (("sum", [3.0, -1.0, 2.0]),
                       ("max", [3.0, -1.0, 2.0]),
                       ("min", [3.0, -1.0, 2.0])):
        got = reduce_across_shards(mesh11, "data", per_shard, op=op)
        np.testing.assert_allclose(np.asarray(got), expect)
    with pytest.raises(ValueError):
        reduce_across_shards(mesh11, "data", per_shard, op="mean")


# --------------------------------------------------------------------------- #
# planner roofline cost surfacing                                              #
# --------------------------------------------------------------------------- #


def test_planner_predicted_costs_and_explain():
    plan = plan_decode(CODE, (4, 128))
    assert plan.backend == "fused_packed"
    costs = plan.predicted_costs()
    assert costs is not None
    assert costs["flops"] > 0 and costs["bytes"] > 0 and costs["input_bytes"] > 0
    text = plan.explain(costs=True)
    assert "cost:" in text and "flops/byte" in text
    # without the flag the plan summary stays cost-free
    assert "cost:" not in plan.explain()
