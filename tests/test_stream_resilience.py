"""Serving resilience: snapshot/restore, chaos injection, degradation.

Two acceptance gates live here:

  1. SNAPSHOT FIDELITY — freezing a live scheduler at ANY tick boundary and
     restoring onto a fresh one (producers re-attached) must commit exactly
     the bits the uninterrupted run commits, fuzzed over arrival schedules
     and snapshot points (the sharded legs are in tests/multidevice/).
  2. CHAOS SURVIVAL + DETECTION — every fault class the harness can inject
     (producer exception/stall/slow-drip, NaN/Inf/shape corruption, device
     step failure, clock skew) must leave the scheduler serving, with the
     injection AND the scheduler's reaction visible in ``metrics_text()``.
"""
import contextlib
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CODE_K3_STD, bsc, encode, hard_branch_metrics
from repro.decode import DecodeRequest, decode
from repro.obs import Telemetry
from repro.stream import (
    FAULT_CLASSES,
    ChaosClock,
    ChaosPolicy,
    ChaosProducer,
    RateLimitedProducer,
    SNAPSHOT_VERSION,
    StreamBusy,
    StreamScheduler,
    StreamSession,
    install_tick_faults,
)
from repro.train.fault_tolerance import StragglerDetector

CODE = CODE_K3_STD


def _noisy_bm(seed, info_bits, flip=0.02):
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (1, info_bits)).astype(jnp.int32)
    coded = encode(CODE, bits, terminate=True)
    rx = bsc(jax.random.fold_in(key, 1), coded, flip)
    return np.asarray(hard_branch_metrics(CODE, rx))[0]


def _chunks_of(table, sizes):
    out, i = [], 0
    for sz in sizes:
        out.append(table[i : i + sz])
        i += sz
        if i >= len(table):
            break
    if i < len(table):
        out.append(table[i:])
    return [c for c in out if len(c)]


def _run_uninterrupted(tables, **kw):
    sched = StreamScheduler(CODE, **kw)
    for sid, t in tables.items():
        sched.open_stream(sid, max_buffered=max(kw.get("chunk", 64), len(t)))
        sched.submit_chunk(sid, t, close=True)
    return sched.run()


def _assert_same_results(ref, got, atol=1e-2):
    assert set(ref) <= set(got)
    for sid in ref:
        np.testing.assert_array_equal(
            ref[sid][0], got[sid][0], err_msg=f"bits differ for {sid!r}"
        )
        assert abs(ref[sid][1] - got[sid][1]) < atol, sid


# --------------------------------------------------------------------------- #
# snapshot / restore                                                          #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("backend", ["scan", "fused_packed"])
@pytest.mark.parametrize("snap_tick", [0, 1, 4])
def test_snapshot_restore_bit_exact(backend, snap_tick):
    tables = {f"s{i}": _noisy_bm(i, 180) for i in range(5)}
    kw = dict(n_slots=4, chunk=32, backend=backend)
    ref = _run_uninterrupted(tables, **kw)

    sched = StreamScheduler(CODE, **kw)
    for sid, t in tables.items():
        sched.open_stream(sid, max_buffered=max(64, len(t)))
        sched.submit_chunk(sid, t, close=True)
    for _ in range(snap_tick):
        sched.step()
    snap = pickle.loads(pickle.dumps(sched.snapshot()))  # across-host shape
    restored = StreamScheduler.restore(snap)
    _assert_same_results(ref, restored.run())


def test_snapshot_restore_mid_drip_with_device_counters():
    """Streams frozen at arbitrary window positions — some starved, some
    with pre-admission queued rows — restore bit-exact, DeviceCounters
    included."""
    tables = {f"s{i}": _noisy_bm(10 + i, 240) for i in range(6)}
    kw = dict(n_slots=4, chunk=32, backend="fused_packed")
    ref = _run_uninterrupted(tables, **kw)

    sched = StreamScheduler(
        CODE, telemetry=Telemetry(device_counters=True), **kw
    )
    served = {sid: 0 for sid in tables}

    def drip(s, upto):
        for sid, t in tables.items():
            while served[sid] < min(upto, len(t)):
                n = min(50, len(t) - served[sid], upto - served[sid])
                try:
                    s.submit_chunk(sid, t[served[sid] : served[sid] + n])
                    served[sid] += n
                except StreamBusy:
                    break
            if served[sid] >= len(t):
                with contextlib.suppress(KeyError):  # already retired
                    s.close(sid)

    for sid in tables:
        sched.open_stream(sid, max_buffered=256)
    for _ in range(6):
        drip(sched, 120)
        sched.step()
    snap = sched.snapshot()
    restored = StreamScheduler.restore(
        snap, telemetry=Telemetry(device_counters=True)
    )
    # the original keeps serving after a snapshot — it is non-destructive
    sched.step()
    while restored.pending_work():
        drip(restored, 10**9)
        restored.step()
    _assert_same_results(ref, restored.results)
    # counters survived: the restored streams kept their tick history
    assert restored.stats.ticks >= 6


def test_snapshot_restore_received_inputs():
    """inputs='received': arena rows are stored POST-feature-transform, so a
    restore must not re-apply the transform — this is the regression test."""
    key = jax.random.PRNGKey(3)
    bits = jax.random.bernoulli(key, 0.5, (1, 200)).astype(jnp.int32)
    coded = encode(CODE, bits, terminate=True)
    rx = np.asarray(bsc(jax.random.fold_in(key, 1), coded, 0.02))[0].astype(
        np.float32
    )
    kw = dict(n_slots=2, chunk=32, backend="fused_packed", inputs="received")
    ref = _run_uninterrupted({"rx": rx}, **kw)

    sched = StreamScheduler(CODE, **kw)
    sched.open_stream("rx", max_buffered=max(64, len(rx)))
    sched.submit_chunk("rx", rx, close=True)
    for _ in range(3):
        sched.step()
    restored = StreamScheduler.restore(sched.snapshot())
    _assert_same_results(ref, restored.run())


def test_snapshot_save_load_and_version_gate(tmp_path):
    tables = {"a": _noisy_bm(1, 100)}
    sched = StreamScheduler(CODE, n_slots=2, chunk=32, backend="scan")
    sched.open_stream("a", max_buffered=128)
    sched.submit_chunk("a", tables["a"], close=True)
    sched.step()
    snap = sched.snapshot()
    path = tmp_path / "sched.snap"
    snap.save(path)
    loaded = type(snap).load(path)
    assert loaded.version == SNAPSHOT_VERSION
    assert loaded.stream_ids == ["a"]
    _assert_same_results(
        _run_uninterrupted(tables, n_slots=2, chunk=32, backend="scan"),
        StreamScheduler.restore(loaded).run(),
    )
    loaded.version = SNAPSHOT_VERSION + 1
    with pytest.raises(ValueError, match="snapshot version"):
        StreamScheduler.restore(loaded)
    (tmp_path / "junk").write_bytes(pickle.dumps({"not": "a snapshot"}))
    with pytest.raises(TypeError):
        type(snap).load(tmp_path / "junk")


def test_snapshot_carries_stats_results_errors():
    sched = StreamScheduler(CODE, n_slots=2, chunk=32, backend="scan")
    done = _noisy_bm(4, 80)
    sched.submit("done", done)
    sched.run()
    sched.open_stream("poisoned", max_buffered=128)
    bad = _noisy_bm(5, 80).copy()
    bad[3, 1] = np.nan
    sched.open_stream("live", max_buffered=128)
    sched.submit_chunk("live", _noisy_bm(6, 80), close=True)
    # poison via producer so it quarantines instead of raising to us
    sched.attach_producer("poisoned", iter([bad]))
    sched.step()
    assert sched.errors["poisoned"].reason == "poisoned_chunk"
    snap = sched.snapshot()
    restored = StreamScheduler.restore(snap)
    assert restored.stats.ticks == sched.stats.ticks
    assert restored.stats.streams_quarantined == 1
    assert "poisoned" in restored.errors
    np.testing.assert_array_equal(
        restored.results["done"][0], sched.results["done"][0]
    )
    restored.run()
    assert "live" in restored.results


def test_snapshot_restore_fuzz_seeded():
    """Always-on seeded fuzz over (arrival schedule, snapshot point) — the
    hypothesis variant below widens the search when the dep is installed."""
    rng = np.random.RandomState(0)
    for _case in range(6):
        sizes = rng.randint(1, 90, size=24).tolist()
        snap_tick = int(rng.randint(0, 8))
        _fuzz_one(sizes, snap_tick, n_streams=int(rng.randint(2, 6)))


def _fuzz_one(sizes, snap_tick, n_streams):
    tables = {f"s{i}": _noisy_bm(100 + i, 150) for i in range(n_streams)}
    kw = dict(n_slots=2, chunk=32, backend="fused_packed")
    ref = _run_uninterrupted(tables, **kw)

    sched = StreamScheduler(CODE, **kw)
    feeds = {
        sid: list(_chunks_of(t, sizes)) for sid, t in tables.items()
    }
    for sid in tables:
        sched.open_stream(sid, max_buffered=256)

    def feed(s):
        for sid, chunks in feeds.items():
            while chunks:
                try:
                    s.submit_chunk(sid, chunks[0])
                    chunks.pop(0)
                except StreamBusy:
                    break
                except KeyError:
                    chunks.clear()
            if not chunks:
                with contextlib.suppress(KeyError):  # already retired
                    s.close(sid)

    for _ in range(snap_tick):
        feed(sched)
        sched.step()
    restored = StreamScheduler.restore(
        pickle.loads(pickle.dumps(sched.snapshot()))
    )
    guard = 0
    while restored.pending_work():
        feed(restored)
        restored.step()
        guard += 1
        assert guard < 1000
    _assert_same_results(ref, restored.results)


# dev-only dep — the seeded fuzz above always runs without it
with contextlib.suppress(ImportError):
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=10, deadline=None)
    @given(
        sizes=st.lists(st.integers(1, 90), min_size=3, max_size=20),
        snap_tick=st.integers(0, 8),
        n_streams=st.integers(1, 5),
    )
    def test_snapshot_restore_fuzz_hypothesis(sizes, snap_tick, n_streams):
        _fuzz_one(sizes, snap_tick, n_streams)


# --------------------------------------------------------------------------- #
# chaos harness: every fault class survived AND detected                       #
# --------------------------------------------------------------------------- #


def test_chaos_policy_catalog_covers_all_classes():
    pol = ChaosPolicy(seed=1, **{cls: 0.5 for cls in FAULT_CLASSES})
    for cls in FAULT_CLASSES:
        assert pol.rate(cls) == 0.5
    mix = ChaosPolicy.producer_mix(0.4, seed=9)
    assert mix.producer_stall == pytest.approx(0.2)
    assert mix.seed == 9


def test_chaos_injection_is_deterministic():
    table = _noisy_bm(7, 120)
    pol = ChaosPolicy(seed=42, producer_stall=0.5, slow_drip=0.3)

    def run():
        prod = ChaosProducer(iter([table]), pol, "det")
        out = []
        for _ in range(40):
            got = prod.poll(16)
            out.append(None if got is None else got.shape[0])
            if prod.exhausted:
                break
        return out, dict(prod.injected)

    assert run() == run()


@pytest.mark.parametrize(
    "cls", ["producer_exception", "corrupt_nan", "corrupt_inf", "corrupt_shape"]
)
def test_chaos_fatal_faults_quarantine_one_stream(cls):
    """A crashed or poisoning producer fails ITS stream with a structured
    error; the co-resident healthy stream decodes bit-exact and the fault is
    visible in the metrics exposition."""
    good_t = _noisy_bm(20, 160)
    bad_t = _noisy_bm(21, 160)
    ref = _run_uninterrupted({"good": good_t}, n_slots=2, chunk=32, backend="scan")

    sched = StreamScheduler(CODE, n_slots=2, chunk=32, backend="scan")
    pol = ChaosPolicy(seed=5, **{cls: 1.0})
    sched.open_stream("good", max_buffered=256)
    sched.submit_chunk("good", good_t, close=True)
    sched.open_stream(
        "bad",
        producer=ChaosProducer(iter([bad_t]), pol, "bad", sched.telemetry.metrics),
        max_buffered=256,
    )
    while sched.pending_work():
        sched.step()
    _assert_same_results(ref, sched.results)
    err = sched.pop_error("bad")
    expected = (
        "producer_error" if cls == "producer_exception" else "poisoned_chunk"
    )
    assert err.reason == expected
    assert sched.stats.streams_quarantined == 1
    text = sched.metrics_text()
    assert f"chaos_{cls}_total" in text  # injected (detection half)
    assert "stream_quarantined_total 1" in text  # survived (reaction half)


@pytest.mark.parametrize("cls", ["producer_stall", "slow_drip"])
def test_chaos_timing_faults_never_change_the_decode(cls):
    """Stalls and slow drips are arrival-schedule perturbations: the
    arrival-invariance contract absorbs them bit-exactly."""
    tables = {f"s{i}": _noisy_bm(30 + i, 140) for i in range(3)}
    ref = _run_uninterrupted(tables, n_slots=2, chunk=32, backend="scan")
    sched = StreamScheduler(CODE, n_slots=2, chunk=32, backend="scan")
    pol = ChaosPolicy(seed=11, **{cls: 0.6})
    for sid, t in tables.items():
        sched.open_stream(
            sid,
            producer=ChaosProducer(iter([t]), pol, sid, sched.telemetry.metrics),
            max_buffered=256,
        )
    guard = 0
    while sched.pending_work():
        sched.step()
        guard += 1
        assert guard < 2000
    _assert_same_results(ref, sched.results)
    assert not sched.errors
    assert f"chaos_{cls}_total" in sched.metrics_text()


def test_chaos_device_step_failure_drops_tick_and_retries():
    table = _noisy_bm(40, 200)
    ref = _run_uninterrupted({"a": table}, n_slots=2, chunk=32, backend="scan")
    sched = StreamScheduler(CODE, n_slots=2, chunk=32, backend="scan")
    injector = install_tick_faults(
        sched, ChaosPolicy(seed=3, device_step_failure=0.3)
    )
    sched.open_stream("a", max_buffered=256)
    sched.submit_chunk("a", table, close=True)
    guard = 0
    while sched.pending_work():
        sched.step()
        guard += 1
        assert guard < 1000
    _assert_same_results(ref, sched.results)
    n_faults = injector.injected["device_step_failure"]
    assert n_faults > 0
    assert sched.stats.tick_device_failures == n_faults
    assert (
        f"stream_tick_device_failures_total {n_faults}" in sched.metrics_text()
    )
    # uninstall restores a clean tick path
    sched.tick_fault_hook = None


def test_chaos_clock_skew_is_bit_exact():
    table = _noisy_bm(41, 160)
    ref = _run_uninterrupted({"r": table}, n_slots=1, chunk=32, backend="scan")
    sched = StreamScheduler(CODE, n_slots=1, chunk=32, backend="scan")
    fake = {"t": 0.0}

    def base_clock():
        fake["t"] += 0.005
        return fake["t"]

    clock = ChaosClock(
        ChaosPolicy(seed=13, clock_skew=0.5),
        max_skew_s=0.5,
        clock=base_clock,
        metrics=sched.telemetry.metrics,
    )
    sched.open_stream(
        "r",
        producer=RateLimitedProducer(table, rows_per_s=2000.0, clock=clock),
        max_buffered=256,
    )
    guard = 0
    while sched.pending_work():
        sched.step()
        guard += 1
        assert guard < 5000
    _assert_same_results(ref, sched.results)
    assert clock.injector.injected["clock_skew"] > 0
    assert "chaos_clock_skew_total" in sched.metrics_text()


# --------------------------------------------------------------------------- #
# graceful degradation                                                         #
# --------------------------------------------------------------------------- #


def test_non_finite_chunk_rejected_at_submit():
    sched = StreamScheduler(CODE, n_slots=2, chunk=32, backend="scan")
    sched.open_stream("a", max_buffered=128)
    bad = _noisy_bm(1, 60).copy()
    bad[5, 0] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        sched.submit_chunk("a", bad)
    assert sched.stats.poisoned_rejections == 1
    # direct submit_chunk rejection does NOT kill the stream — the caller
    # holds the bad chunk, the stream keeps its slot
    good = _noisy_bm(1, 60)
    sched.submit_chunk("a", good, close=True)
    sched.run()
    assert "a" in sched.results


def test_session_push_rejects_non_finite():
    sess = StreamSession(CODE, batch=1, chunk=32, backend="scan")
    bad = np.zeros((1, 32, CODE.n_symbols), dtype=np.float32)
    bad[0, 3, 1] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        sess.push(bad)
    with pytest.raises(ValueError, match="non-finite"):
        sess.finish(jnp.asarray(bad[:, :5] * np.nan))
    # opt-out for measured hot paths
    lax = StreamSession(CODE, batch=1, chunk=32, backend="scan", validate=False)
    lax.push(jnp.asarray(bad))  # no raise


def test_decode_from_received_rejects_non_finite():
    key = jax.random.PRNGKey(0)
    bits = jax.random.bernoulli(key, 0.5, (2, 64)).astype(jnp.int32)
    rx = np.asarray(encode(CODE, bits, terminate=True), dtype=np.float32)
    rx[0, 3, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        decode(DecodeRequest(spec=CODE, received=jnp.asarray(rx)))


def test_ttl_expiry_flushes_partial_and_records_error():
    table = _noisy_bm(8, 300)
    sched = StreamScheduler(CODE, n_slots=2, chunk=32, backend="scan")
    sched.open_stream("t", ttl_ticks=3, max_buffered=512)
    sched.submit_chunk("t", table)  # never closed: would serve forever
    for _ in range(6):
        sched.step()
    err = sched.errors["t"]
    assert err.reason == "expired"
    bits, _ = sched.results["t"]
    assert err.committed_bits == bits.shape[0] > 0
    assert sched.stats.streams_expired == 1
    assert "stream_expired_total 1" in sched.metrics_text()
    # bits committed BEFORE the cut agree with the uninterrupted decode;
    # the final traceback-window tail may differ (the full run had future
    # evidence the truncated one does not)
    ref_bits = _run_uninterrupted(
        {"t": table}, n_slots=2, chunk=32, backend="scan"
    )["t"][0]
    firm = bits.shape[0] - sched.depth
    np.testing.assert_array_equal(bits[:firm], ref_bits[:firm])


def test_overload_sheds_lowest_priority_with_partial_flush():
    sched = StreamScheduler(
        CODE, n_slots=2, chunk=32, backend="scan", max_pending=1
    )
    t = _noisy_bm(9, 100)
    for i in range(3):
        sched.open_stream(f"p{i}", priority=i, max_buffered=256)
        sched.submit_chunk(f"p{i}", t)
    sched.step()
    assert not sched.errors  # within bounds: nothing shed yet
    # two more arrivals push pending past the bound; the lowest-priority
    # open streams lose, even though they are the ACTIVE ones
    sched.open_stream("p3", priority=3, max_buffered=256)
    sched.open_stream("p4", priority=4, max_buffered=256)
    assert sorted(sched.errors) == ["p0", "p1"]
    assert all(e.reason == "shed" for e in sched.errors.values())
    assert sched.stats.streams_shed == 2
    # p0 was active and had committed bits — partial result flushed
    assert "p0" in sched.results
    assert sched.errors["p0"].committed_bits == sched.results["p0"][0].shape[0]
    # the survivors (higher priority) are being served
    live = {st.stream_id for st in sched.active.values()} | {
        st.stream_id for st in sched.pending
    }
    assert live == {"p2", "p3", "p4"}
    assert "stream_shed_total 2" in sched.metrics_text()


def test_evict_while_producer_has_pending_credit():
    """Lifecycle: evicting a producer-fed stream mid-flight (its producer
    still holding undelivered rows within credit) detaches cleanly — no
    error records, the slot recycles, and other streams are unaffected."""
    t_long = _noisy_bm(14, 400)
    t_other = _noisy_bm(15, 120)
    ref = _run_uninterrupted({"other": t_other}, n_slots=2, chunk=32, backend="scan")
    sched = StreamScheduler(CODE, n_slots=2, chunk=32, backend="scan")
    prod = RateLimitedProducer(t_long, rows_per_s=1e9)
    sched.open_stream("victim", producer=prod, max_buffered=64)
    sched.open_stream("other", max_buffered=256)
    sched.submit_chunk("other", t_other, close=True)
    for _ in range(3):
        sched.step()
    assert not prod.exhausted  # credit-bounded: rows still undelivered
    partial = sched.evict("victim")
    assert partial is not None
    assert "victim" not in sched.errors  # evict is a caller action, not a fault
    with pytest.raises(KeyError):
        sched.credit("victim")
    while sched.pending_work():
        sched.step()
    _assert_same_results(ref, sched.results)
    assert "victim" not in sched.results
    # the freed slot is reusable immediately
    sched.open_stream("next", max_buffered=256)
    sched.submit_chunk("next", t_other, close=True)
    sched.run()
    np.testing.assert_array_equal(sched.results["next"][0], ref["other"][0])


def test_evict_pending_stream_returns_none():
    sched = StreamScheduler(CODE, n_slots=1, chunk=32, backend="scan")
    sched.open_stream("a", max_buffered=64)
    sched.open_stream("b", max_buffered=64)  # queued: slot taken by a
    assert sched.evict("b") is None
    with pytest.raises(KeyError):
        sched.evict("b")


# --------------------------------------------------------------------------- #
# backpressure hint + straggler wiring                                         #
# --------------------------------------------------------------------------- #


def test_stream_busy_carries_retry_after_ticks():
    sched = StreamScheduler(CODE, n_slots=1, chunk=32, backend="scan")
    sched.open_stream("a", max_buffered=64)
    big = _noisy_bm(2, 500)
    with pytest.raises(StreamBusy) as exc:
        sched.submit_chunk("a", big)
    # queue empty: a split submit of <= credit rows would land NOW, so the
    # hint is the 1-tick minimum even though the whole chunk can never fit
    assert exc.value.retry_after_ticks == 1
    assert "retry in ~1 tick(s)" in str(exc.value)
    # queue full: 64 buffered rows drain at 32/tick -> 2 ticks
    sched.submit_chunk("a", big[:64])
    with pytest.raises(StreamBusy) as exc_full:
        sched.submit_chunk("a", big[64:])
    assert exc_full.value.retry_after_ticks == 2
    # a pending (not yet admitted) stream's hint includes its queue position
    sched.open_stream("b", max_buffered=64)
    sched.submit_chunk("b", big[:64])
    with pytest.raises(StreamBusy) as exc_b:
        sched.submit_chunk("b", big[64:])
    assert exc_b.value.retry_after_ticks > exc_full.value.retry_after_ticks


def test_rate_limited_pump_backoff_converges():
    """The pump honors retry_after_ticks: roughly half the pump calls are
    skipped in backoff instead of hot-spinning a rejected submit per tick,
    and the decode is still bit-exact."""
    table = _noisy_bm(3, 2000)
    ref = _run_uninterrupted({"r": table}, n_slots=1, chunk=32, backend="scan")
    sched = StreamScheduler(CODE, n_slots=1, chunk=32, backend="scan")
    sched.open_stream("r", max_buffered=64)
    prod = RateLimitedProducer(table, rows_per_s=1e9)
    ticks = 0
    while sched.pending_work():
        prod.pump(sched, "r")
        sched.step()
        ticks += 1
        assert ticks < 500, "backoff loop did not converge"
    _assert_same_results(ref, sched.results)
    assert prod.busy_events > 0
    assert prod.skipped_pumps >= prod.busy_events  # backed off, every time
    # converged: rejections are bounded by the drain schedule, not one per tick
    assert prod.busy_events <= ticks / 2 + 1


def test_straggler_detector_wired_into_tick():
    sched = StreamScheduler(CODE, n_slots=2, chunk=32, backend="scan")
    # ticks that dispatch work feed the EMA
    sched.submit("a", _noisy_bm(4, 200))
    sched.run()
    assert sched.straggler.n > 0
    n_after_work = sched.straggler.n
    # idle ticks (nothing admitted) must NOT feed it
    sched.step()
    assert sched.straggler.n == n_after_work
    # a tick wildly slower than the baseline is flagged and counted
    sched.straggler = StragglerDetector(zscore=2.0, warmup_steps=1)
    sched._observe_tick_time(0.01)
    sched._observe_tick_time(0.01)
    sched._observe_tick_time(5.0)
    assert sched.stats.straggler_ticks == 1
    assert "stream_tick_straggler_total 1" in sched.metrics_text()
    snap = sched.metrics_snapshot()
    assert snap["stream_tick_seconds"]["count"] >= 3
