"""Sharded snapshot/restore: drain a mesh-spanning scheduler, resume
anywhere — the hot-migration primitive the multi-controller plane needs.

The snapshot is keyed per STREAM (pm row / ring column / arena rows), so a
restore onto a different mesh shape — 8-shard to single-device, single to
8-shard, 8 to 4 — is a re-layout, not a reshard of opaque buffers.  Every
leg asserts committed bits are identical to the uninterrupted run.
"""
import contextlib
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CODE_K3_STD, bsc, encode, hard_branch_metrics
from repro.stream import ChaosPolicy, StreamScheduler, install_tick_faults

CODE = CODE_K3_STD


def _noisy_bm(seed, info_bits, flip=0.02):
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (1, info_bits)).astype(jnp.int32)
    coded = encode(CODE, bits, terminate=True)
    rx = bsc(jax.random.fold_in(key, 1), coded, flip)
    return np.asarray(hard_branch_metrics(CODE, rx))[0]


def _tables(n, base_seed=0):
    return {
        f"s{i}": _noisy_bm(base_seed + i, (92, 150, 60, 198)[i % 4])
        for i in range(n)
    }


def _feed_all(sched, tables):
    for sid, t in tables.items():
        sched.open_stream(sid, max_buffered=max(64, len(t)))
        sched.submit_chunk(sid, t, close=True)


def _reference(tables, **kw):
    sched = StreamScheduler(CODE, **kw)
    _feed_all(sched, tables)
    return sched.run()


def _assert_same(ref, got):
    assert set(ref) == set(got)
    for sid in ref:
        np.testing.assert_array_equal(ref[sid][0], got[sid][0], err_msg=sid)
        assert abs(ref[sid][1] - got[sid][1]) < 1e-2, sid


KW = dict(n_slots=8, chunk=32, backend="fused_packed")


@pytest.mark.parametrize("snap_tick", [0, 2, 5])
def test_sharded_snapshot_restores_onto_same_mesh(mesh81, snap_tick):
    tables = _tables(12)
    ref = _reference(tables, **KW)
    sched = StreamScheduler(CODE, mesh=mesh81, **KW)
    _feed_all(sched, tables)
    for _ in range(snap_tick):
        sched.step()
    snap = pickle.loads(pickle.dumps(sched.snapshot()))
    restored = StreamScheduler.restore(snap, mesh=mesh81)
    assert restored.n_shards == 8
    _assert_same(ref, restored.run())


def test_sharded_snapshot_restores_onto_single_device(mesh81):
    """Host-failure drain: collapse an 8-shard scheduler onto one device."""
    tables = _tables(12, base_seed=40)
    ref = _reference(tables, **KW)
    sched = StreamScheduler(CODE, mesh=mesh81, **KW)
    _feed_all(sched, tables)
    for _ in range(3):
        sched.step()
    restored = StreamScheduler.restore(sched.snapshot())
    assert restored.n_shards == 1
    _assert_same(ref, restored.run())


def test_single_device_snapshot_restores_onto_mesh(mesh81):
    """Scale-up migration: single-device state fans out across 8 shards."""
    tables = _tables(12, base_seed=80)
    ref = _reference(tables, **KW)
    sched = StreamScheduler(CODE, **KW)
    _feed_all(sched, tables)
    for _ in range(3):
        sched.step()
    restored = StreamScheduler.restore(sched.snapshot(), mesh=mesh81)
    assert restored.n_shards == 8
    _assert_same(ref, restored.run())


def test_sharded_snapshot_restores_onto_smaller_mesh(mesh81, mesh42):
    """Elastic shrink (8 -> 4 data shards), the elastic_mesh idiom."""
    tables = _tables(10, base_seed=120)
    ref = _reference(tables, **KW)
    sched = StreamScheduler(CODE, mesh=mesh81, **KW)
    _feed_all(sched, tables)
    for _ in range(4):
        sched.step()
    restored = StreamScheduler.restore(sched.snapshot(), mesh=mesh42)
    assert restored.n_shards == 4
    _assert_same(ref, restored.run())


def test_sharded_tick_faults_survived_bit_exact(mesh81):
    """Simulated device-step failures on the sharded tick: dropped ticks
    retry the same gather, the decode never changes."""
    tables = _tables(8, base_seed=160)
    ref = _reference(tables, **KW)
    sched = StreamScheduler(CODE, mesh=mesh81, **KW)
    injector = install_tick_faults(
        sched, ChaosPolicy(seed=17, device_step_failure=0.25)
    )
    _feed_all(sched, tables)
    guard = 0
    while sched.pending_work():
        sched.step()
        guard += 1
        assert guard < 1000
    assert injector.injected["device_step_failure"] > 0
    assert sched.stats.tick_device_failures == injector.injected[
        "device_step_failure"
    ]
    _assert_same(ref, sched.results)


def test_sharded_snapshot_fuzz_points(mesh81):
    """Seeded fuzz over snapshot points with drip-fed arrivals on the mesh:
    pending + starved + mid-window streams all restore bit-exact."""
    rng = np.random.RandomState(7)
    tables = _tables(10, base_seed=200)
    ref = _reference(tables, **KW)
    for _trial in range(2):
        sched = StreamScheduler(CODE, mesh=mesh81, **KW)
        feeds = {sid: [t] for sid, t in tables.items()}
        for sid in tables:
            sched.open_stream(sid, max_buffered=256)
        snap_tick = int(rng.randint(1, 6))

        def feed(s):
            from repro.stream import StreamBusy

            for sid, chunks in feeds.items():
                while chunks:
                    n = int(rng.randint(1, 80))
                    try:
                        s.submit_chunk(sid, chunks[0][:n])
                        rest = chunks[0][n:]
                        chunks.pop(0)
                        if len(rest):
                            chunks.insert(0, rest)
                    except StreamBusy:
                        break
                    except KeyError:
                        chunks.clear()
                if not chunks:
                    with contextlib.suppress(KeyError):  # already retired
                        s.close(sid)

        for _ in range(snap_tick):
            feed(sched)
            sched.step()
        restored = StreamScheduler.restore(sched.snapshot(), mesh=mesh81)
        guard = 0
        while restored.pending_work():
            feed(restored)
            restored.step()
            guard += 1
            assert guard < 2000
        _assert_same(ref, restored.results)
