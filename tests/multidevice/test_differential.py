"""Differential fuzzing: sharded scheduler vs single-device scheduler vs the
offline fused_packed backend, over randomly drawn codec/workload tuples.

In the exactness regime (depth >= T) all three must be BIT-exact; away from
it the two schedulers must still agree bit-for-bit with each other (same
truncation, different placement).  Hypothesis draws (K, polys, puncture,
metric, T, noise, terminated) — the same axes test_property.py fuzzes for
the block decoders.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import CODE_K3_STD, CODE_K5_GSM, ConvCode
from repro.decode import CodecSpec, DecodeContext, get_decoder
from repro.stream import StreamScheduler

CODES = [CODE_K3_STD, CODE_K5_GSM, ConvCode(4, (0b1111, 0b1101))]
PUNCTURES = [None, ((1, 1), (1, 0))]  # rate 1/2 and punctured rate 2/3
DEPTH = 160  # >= every drawn T: the exactness regime
CHUNK = 16


@st.composite
def decode_cases(draw):
    code = draw(st.sampled_from(CODES))
    metric = draw(st.sampled_from(["hard", "soft"]))
    puncture = draw(st.sampled_from(PUNCTURES))
    terminated = draw(st.booleans())
    info_bits = draw(st.integers(8, 72))
    seed = draw(st.integers(0, 2 ** 16))
    if metric == "hard":
        channel = {"flip_prob": draw(st.floats(0.0, 0.1))}
    else:
        channel = {"snr_db": draw(st.floats(0.0, 8.0))}
    return code, metric, puncture, terminated, info_bits, seed, channel


def _workload(case, batch=4):
    code, metric, puncture, terminated, info_bits, seed, channel = case
    spec = CodecSpec(code=code, metric=metric, puncture=puncture,
                     terminated=terminated)
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (batch, info_bits)).astype(jnp.int32)
    rx = spec.channel(jax.random.fold_in(key, 1), spec.encode(bits), **channel)
    return spec, spec.branch_metrics(rx)


def _drain(sched, bm):
    for i in range(bm.shape[0]):
        sched.submit(f"s{i}", bm[i])
    return sched.run()


@settings(max_examples=6, deadline=None)
@given(case=decode_cases())
def test_sharded_single_and_offline_agree_exactly(case, mesh81):
    """depth >= T: sharded scheduler == single-device scheduler == offline
    fused_packed block decode, bit for bit, on every drawn tuple."""
    spec, bm = _workload(case)
    out_single = _drain(
        StreamScheduler(spec, n_slots=8, chunk=CHUNK, depth=DEPTH, backend="scan"),
        bm,
    )
    out_shard = _drain(
        StreamScheduler(spec, n_slots=8, chunk=CHUNK, depth=DEPTH, backend="scan",
                        mesh=mesh81),
        bm,
    )
    offline = get_decoder("fused_packed")(spec, bm, ctx=DecodeContext())
    off_bits = np.asarray(offline.bits)
    off_metric = np.asarray(offline.path_metric)
    for i in range(bm.shape[0]):
        sid = f"s{i}"
        np.testing.assert_array_equal(out_shard[sid][0], out_single[sid][0])
        np.testing.assert_array_equal(out_shard[sid][0], off_bits[i])
        assert out_shard[sid][1] == pytest.approx(out_single[sid][1], abs=1e-3)
        assert out_shard[sid][1] == pytest.approx(float(off_metric[i]), rel=1e-4,
                                                  abs=1e-3)


@settings(max_examples=6, deadline=None)
@given(case=decode_cases())
def test_sharded_matches_single_in_truncation_regime(case, mesh81):
    """depth < T: the truncated-window commits of the sharded and single
    schedulers must still be identical (placement must not change decode)."""
    spec, bm = _workload(case)
    kw = dict(n_slots=8, chunk=CHUNK, depth=24, backend="scan")
    out_single = _drain(StreamScheduler(spec, **kw), bm)
    out_shard = _drain(StreamScheduler(spec, mesh=mesh81, **kw), bm)
    for i in range(bm.shape[0]):
        sid = f"s{i}"
        np.testing.assert_array_equal(out_shard[sid][0], out_single[sid][0])
        assert out_shard[sid][1] == pytest.approx(out_single[sid][1], abs=1e-3)


@st.composite
def arrival_plans(draw):
    """Chunk-arrival schedules for the online-ingestion fuzz: per-stream
    burst sizes, starvation gaps, and early close."""
    n_streams = draw(st.integers(3, 6))
    plans = []
    for _ in range(n_streams):
        plans.append((
            draw(st.integers(16, 120)),                                  # info bits
            tuple(draw(st.lists(st.integers(1, 60), min_size=1, max_size=6))),
            draw(st.integers(0, 2)),                                     # gap ticks
            draw(st.booleans()),                                         # early close
        ))
    return plans, draw(st.integers(0, 2 ** 16))


@settings(max_examples=6, deadline=None)
@given(case=arrival_plans())
def test_sharded_online_ingestion_matches_offline(case, mesh81):
    """Chunk-fed arrival (bursty, starved, early-closed) through the SHARDED
    scheduler == one-shot submit of the concatenated rows on the sharded AND
    single-device schedulers, bit for bit."""
    from repro.stream import StreamBusy

    plans, seed = case
    spec = CodecSpec(code=CODE_K3_STD)
    key = jax.random.PRNGKey(seed)
    online = StreamScheduler(spec, n_slots=8, chunk=CHUNK, depth=DEPTH,
                             backend="scan", mesh=mesh81)
    offline_shard = StreamScheduler(spec, n_slots=8, chunk=CHUNK, depth=DEPTH,
                                    backend="scan", mesh=mesh81)
    offline_single = StreamScheduler(spec, n_slots=8, chunk=CHUNK, depth=DEPTH,
                                     backend="scan")
    feeds = {}
    for i, (info_bits, sizes, gap, early_close) in enumerate(plans):
        bits = jax.random.bernoulli(jax.random.fold_in(key, i), 0.5,
                                    (1, info_bits)).astype(jnp.int32)
        rx = spec.channel(jax.random.fold_in(key, 1000 + i),
                          spec.encode(bits), flip_prob=0.05)
        table = np.asarray(spec.branch_metrics(rx))[0]
        chunks, k = [], 0
        for sz in sizes:
            chunks.append(table[k : k + sz])
            k += sz
            if k >= len(table):
                break
        if k < len(table) and not early_close:
            chunks.append(table[k:])
        chunks = [c for c in chunks if len(c)]
        actual = (np.concatenate(chunks, axis=0) if chunks
                  else np.zeros((0, table.shape[1]), np.float32))
        sid = f"s{i}"
        offline_shard.submit(sid, actual)
        offline_single.submit(sid, actual)
        online.open_stream(sid)
        feeds[sid] = {"chunks": chunks, "gap": gap, "wait": 0}
    guard = 0
    while online.pending_work():
        for sid, f in feeds.items():
            if not f["chunks"]:
                continue
            if f["wait"] > 0:
                f["wait"] -= 1
                continue
            try:
                online.submit_chunk(sid, f["chunks"][0])
            except StreamBusy:
                continue
            f["chunks"].pop(0)
            f["wait"] = f["gap"]
            if not f["chunks"]:
                online.close(sid)
        online.step()
        guard += 1
        assert guard < 2000, "online drain did not converge"
    for sid in feeds:
        if feeds[sid]["chunks"]:
            online.close(sid)
    out_online = online.results
    out_shard, out_single = offline_shard.run(), offline_single.run()
    for sid in out_shard:
        np.testing.assert_array_equal(out_online[sid][0], out_shard[sid][0])
        np.testing.assert_array_equal(out_online[sid][0], out_single[sid][0])
        assert out_online[sid][1] == pytest.approx(out_shard[sid][1], abs=1e-3)
