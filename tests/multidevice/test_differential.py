"""Differential fuzzing: sharded scheduler vs single-device scheduler vs the
offline fused_packed backend, over randomly drawn codec/workload tuples.

In the exactness regime (depth >= T) all three must be BIT-exact; away from
it the two schedulers must still agree bit-for-bit with each other (same
truncation, different placement).  Hypothesis draws (K, polys, puncture,
metric, T, noise, terminated) — the same axes test_property.py fuzzes for
the block decoders.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import CODE_K3_STD, CODE_K5_GSM, ConvCode
from repro.decode import CodecSpec, DecodeContext, get_decoder
from repro.stream import StreamScheduler

CODES = [CODE_K3_STD, CODE_K5_GSM, ConvCode(4, (0b1111, 0b1101))]
PUNCTURES = [None, ((1, 1), (1, 0))]  # rate 1/2 and punctured rate 2/3
DEPTH = 160  # >= every drawn T: the exactness regime
CHUNK = 16


@st.composite
def decode_cases(draw):
    code = draw(st.sampled_from(CODES))
    metric = draw(st.sampled_from(["hard", "soft"]))
    puncture = draw(st.sampled_from(PUNCTURES))
    terminated = draw(st.booleans())
    info_bits = draw(st.integers(8, 72))
    seed = draw(st.integers(0, 2 ** 16))
    if metric == "hard":
        channel = {"flip_prob": draw(st.floats(0.0, 0.1))}
    else:
        channel = {"snr_db": draw(st.floats(0.0, 8.0))}
    return code, metric, puncture, terminated, info_bits, seed, channel


def _workload(case, batch=4):
    code, metric, puncture, terminated, info_bits, seed, channel = case
    spec = CodecSpec(code=code, metric=metric, puncture=puncture,
                     terminated=terminated)
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (batch, info_bits)).astype(jnp.int32)
    rx = spec.channel(jax.random.fold_in(key, 1), spec.encode(bits), **channel)
    return spec, spec.branch_metrics(rx)


def _drain(sched, bm):
    for i in range(bm.shape[0]):
        sched.submit(f"s{i}", bm[i])
    return sched.run()


@settings(max_examples=6, deadline=None)
@given(case=decode_cases())
def test_sharded_single_and_offline_agree_exactly(case, mesh81):
    """depth >= T: sharded scheduler == single-device scheduler == offline
    fused_packed block decode, bit for bit, on every drawn tuple."""
    spec, bm = _workload(case)
    out_single = _drain(
        StreamScheduler(spec, n_slots=8, chunk=CHUNK, depth=DEPTH, backend="scan"),
        bm,
    )
    out_shard = _drain(
        StreamScheduler(spec, n_slots=8, chunk=CHUNK, depth=DEPTH, backend="scan",
                        mesh=mesh81),
        bm,
    )
    offline = get_decoder("fused_packed")(spec, bm, ctx=DecodeContext())
    off_bits = np.asarray(offline.bits)
    off_metric = np.asarray(offline.path_metric)
    for i in range(bm.shape[0]):
        sid = f"s{i}"
        np.testing.assert_array_equal(out_shard[sid][0], out_single[sid][0])
        np.testing.assert_array_equal(out_shard[sid][0], off_bits[i])
        assert out_shard[sid][1] == pytest.approx(out_single[sid][1], abs=1e-3)
        assert out_shard[sid][1] == pytest.approx(float(off_metric[i]), rel=1e-4,
                                                  abs=1e-3)


@settings(max_examples=6, deadline=None)
@given(case=decode_cases())
def test_sharded_matches_single_in_truncation_regime(case, mesh81):
    """depth < T: the truncated-window commits of the sharded and single
    schedulers must still be identical (placement must not change decode)."""
    spec, bm = _workload(case)
    kw = dict(n_slots=8, chunk=CHUNK, depth=24, backend="scan")
    out_single = _drain(StreamScheduler(spec, **kw), bm)
    out_shard = _drain(StreamScheduler(spec, mesh=mesh81, **kw), bm)
    for i in range(bm.shape[0]):
        sid = f"s{i}"
        np.testing.assert_array_equal(out_shard[sid][0], out_single[sid][0])
        assert out_shard[sid][1] == pytest.approx(out_single[sid][1], abs=1e-3)
