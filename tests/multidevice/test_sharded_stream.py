"""Sharded stream scheduler/session on a real (fake-8-device) mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CODE_K3_STD, bsc, encode, hard_branch_metrics, viterbi_decode
from repro.decode import CodecSpec, DecodeContext, get_decoder, plan_decode
from repro.stream import StreamScheduler, StreamSession

CODE = CODE_K3_STD


def _noisy_bm(key, batch, info_bits, flip=0.02):
    bits = jax.random.bernoulli(key, 0.5, (batch, info_bits)).astype(jnp.int32)
    coded = encode(CODE, bits, terminate=True)
    rx = bsc(jax.random.fold_in(key, 1), coded, flip)
    return bits, hard_branch_metrics(CODE, rx)


def _run_pair(mesh, streams, *, n_slots=8, chunk=16, depth=30, backend="scan",
              mesh_axis="data"):
    """Same submissions through a single-device and a sharded scheduler."""
    single = StreamScheduler(CODE, n_slots=n_slots, chunk=chunk, depth=depth,
                             backend=backend)
    shard = StreamScheduler(CODE, n_slots=n_slots, chunk=chunk, depth=depth,
                            backend=backend, mesh=mesh, mesh_axis=mesh_axis)
    for sid, bm in streams.items():
        single.submit(sid, bm)
        shard.submit(sid, bm)
    return single.run(), shard.run(), shard


@pytest.mark.parametrize("mesh_name", ["mesh81", "mesh42"])
def test_sharded_scheduler_bit_exact_with_single_device(mesh_name, request, rng):
    """Staggered lengths + slot turnover: the sharded scheduler commits the
    same bits and metrics as the single-device one on every stream."""
    mesh = request.getfixturevalue(mesh_name)
    streams = {}
    for i in range(20):
        _, bm = _noisy_bm(jax.random.fold_in(rng, i), 1, (92, 128, 60, 198)[i % 4])
        streams[f"s{i}"] = bm[0]
    out_single, out_shard, shard = _run_pair(mesh, streams)
    assert shard.stats.streams_finished == 20
    assert shard.stats.slot_claims == 20 > shard.n_slots  # slots recycled
    for sid in streams:
        np.testing.assert_array_equal(out_shard[sid][0], out_single[sid][0])
        assert abs(out_shard[sid][1] - out_single[sid][1]) < 1e-4


def test_sharded_packed_backend_bit_exact_with_block_decoder(mesh81, rng):
    """fused_packed hot loop under shard_map, depth >= T: bit-identical to
    the full-block Viterbi decode (ring + Pallas traceback per shard)."""
    sched = StreamScheduler(CODE, n_slots=8, chunk=32, depth=224,
                            backend="fused_packed", mesh=mesh81)
    refs = {}
    for i in range(12):
        _, bm = _noisy_bm(jax.random.fold_in(rng, i), 1, (94, 130, 62)[i % 3])
        rb, rm = viterbi_decode(CODE, bm)
        refs[f"s{i}"] = (np.asarray(rb[0]), float(rm[0]))
        sched.submit(f"s{i}", bm[0])
    out = sched.run()
    for sid, (rb, rm) in refs.items():
        np.testing.assert_array_equal(out[sid][0], rb)
        assert abs(out[sid][1] - rm) < 1e-3 * max(1.0, abs(rm))


def test_sharded_received_inputs_in_kernel_metrics(mesh81, rng):
    """inputs='received' sharded: raw symbols through the per-shard arena,
    branch metrics in-kernel — exact vs the table-fed block decode."""
    bits = jax.random.bernoulli(rng, 0.5, (4, 94)).astype(jnp.int32)
    coded = encode(CODE, bits, terminate=True)
    rx = bsc(jax.random.fold_in(rng, 1), coded, 0.03)
    ref_bits, _ = viterbi_decode(CODE, hard_branch_metrics(CODE, rx))
    sched = StreamScheduler(CODE, n_slots=8, chunk=32, depth=96,
                            backend="fused_packed", inputs="received", mesh=mesh81)
    for i in range(4):
        sched.submit(f"s{i}", rx[i])
    out = sched.run()
    for i in range(4):
        np.testing.assert_array_equal(out[f"s{i}"][0], np.asarray(ref_bits[i]))


def test_sharded_arena_compaction_with_live_sharded_slots(mesh81, rng):
    """Compaction rebuilds every shard's slab mid-run without disturbing
    live sharded streams (the single-device regression, on the mesh)."""
    sched = StreamScheduler(CODE, n_slots=8, chunk=16, depth=15, backend="scan",
                            mesh=mesh81)
    sched._compact_floor = 0
    sched._compact_ratio = 2
    refs = {}
    for i in range(24):
        _, bm = _noisy_bm(jax.random.fold_in(rng, i), 1, 62, 0.01)
        rb, _ = viterbi_decode(CODE, bm)
        refs[f"s{i}"] = np.asarray(rb[0])
        sched.submit(f"s{i}", bm[0])
    out = sched.run()
    assert sched.stats.arena_compactions > 0
    for sid, rb in refs.items():
        np.testing.assert_array_equal(out[sid][0], rb)


def test_sharded_state_layout_and_load_report(mesh81, rng):
    """The slot table is partitioned contiguously: state rows live on the
    shard owning the slot, and the collective load report agrees with the
    host-side bookkeeping."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    sched = StreamScheduler(CODE, n_slots=16, chunk=16, depth=30, backend="scan",
                            mesh=mesh81)
    assert sched.n_shards == 8 and sched.slots_per_shard == 2
    assert sched.state.pm.sharding.is_equivalent_to(
        NamedSharding(mesh81, P("data", None)), sched.state.pm.ndim
    )
    assert sched.state.ring.sharding.is_equivalent_to(
        NamedSharding(mesh81, P(None, "data", None)), sched.state.ring.ndim
    )
    for i in range(5):
        _, bm = _noisy_bm(jax.random.fold_in(rng, i), 1, 92)
        sched.submit(f"s{i}", bm[0])
    sched.step()
    report = sched.load_report()
    assert report["n_shards"] == 8
    assert report["active_total"] == sum(report["per_shard_active"]) == 5
    assert report["utilization"] == pytest.approx(5 / 16)
    sched.run()


def test_sharded_session_matches_single_device(mesh81, rng):
    """Mesh-sharded StreamSession (per-shard carried pytrees): same bits and
    metric as the unsharded session, chunk by chunk."""
    _, bm = _noisy_bm(rng, 8, 124, 0.02)
    ref_bits, ref_metric = viterbi_decode(CODE, bm)
    sess = StreamSession(CODE, batch=8, chunk=32, depth=128, backend="scan",
                         mesh=mesh81)
    bits, metric = sess.decode_all(bm)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(ref_bits))
    np.testing.assert_allclose(np.asarray(metric), np.asarray(ref_metric), rtol=1e-5)


def test_session_batch_must_divide_over_shards(mesh81):
    with pytest.raises(ValueError, match="divide evenly"):
        StreamSession(CODE, batch=3, chunk=32, mesh=mesh81)
    with pytest.raises(ValueError, match="divide evenly"):
        StreamScheduler(CODE, n_slots=12, chunk=16, mesh=mesh81)


def test_planner_routes_streaming_mesh_to_sharded_stream(mesh81, mesh42):
    """ctx.streaming + a multi-device data axis -> sharded_stream; the same
    context without a mesh stays on the single-device streaming backend."""
    spec = CodecSpec(code=CODE)
    ctx = DecodeContext(streaming=True, chunk=32, stream_depth=128)
    assert plan_decode(spec, (8, 128), mesh=mesh81, ctx=ctx).backend == "sharded_stream"
    assert plan_decode(spec, (8, 128), mesh=mesh42, ctx=ctx).backend == "sharded_stream"
    assert plan_decode(spec, (8, 128), ctx=ctx).backend == "streaming"


def test_sharded_stream_backend_executes_bit_exact(mesh81, rng):
    """The registry backend end-to-end: (B, T, M) block through the sharded
    scheduler, bit-exact vs the sequential oracle at depth >= T."""
    _, bm = _noisy_bm(rng, 8, 126, 0.02)
    ref_bits, ref_metric = viterbi_decode(CODE, bm)
    res = get_decoder("sharded_stream")(
        CodecSpec(code=CODE), bm,
        ctx=DecodeContext(mesh=mesh81, streaming=True, chunk=32, stream_depth=128),
    )
    np.testing.assert_array_equal(np.asarray(res.bits), np.asarray(ref_bits))
    np.testing.assert_allclose(
        np.asarray(res.path_metric), np.asarray(ref_metric), rtol=1e-4
    )
    assert res.diagnostics["shards"] == 8


def test_sharded_online_chunk_fed_with_starvation(mesh81, rng):
    """Chunk-fed sharded scheduler: producer-fed streams with bursty arrival
    starve their slots across shards; results stay bit-exact with the block
    decoder and the per-shard queue accounting reduces coherently."""
    sched = StreamScheduler(CODE, n_slots=8, chunk=16, depth=300,
                            backend="scan", mesh=mesh81, max_buffered=64)
    refs = {}
    for i in range(10):
        _, bm = _noisy_bm(jax.random.fold_in(rng, i), 1, (92, 60, 128)[i % 3])
        rb, _ = viterbi_decode(CODE, bm)
        refs[f"s{i}"] = np.asarray(rb[0])
        table = np.asarray(bm[0])
        sched.open_stream(f"s{i}",
                          producer=iter([table[k : k + 29]
                                         for k in range(0, len(table), 29)]))
    report_seen = {"queued": 0, "starved": 0}
    while sched.pending_work():
        sched.step()
        report = sched.load_report()
        assert report["queued_rows_total"] == sum(report["per_shard_queued_rows"])
        report_seen["queued"] = max(report_seen["queued"], report["queued_rows_total"])
        report_seen["starved"] = max(report_seen["starved"], report["starved_active"])
    assert report_seen["queued"] > 0  # the accounting actually saw live queues
    for sid, rb in refs.items():
        np.testing.assert_array_equal(sched.results[sid][0], rb)


def test_sharded_submit_adapter_over_chunk_path(mesh81, rng):
    """The sharded scheduler's submit() rides the same chunk ingestion path
    (open + submit_chunk + close) — and stays bit-exact with it."""
    _, bm = _noisy_bm(rng, 8, 92)
    ref_bits, _ = viterbi_decode(CODE, bm)
    via_submit = StreamScheduler(CODE, n_slots=8, chunk=16, depth=128,
                                 backend="scan", mesh=mesh81)
    via_chunks = StreamScheduler(CODE, n_slots=8, chunk=16, depth=128,
                                 backend="scan", mesh=mesh81)
    for i in range(8):
        via_submit.submit(f"s{i}", bm[i])
        via_chunks.open_stream(f"s{i}",
                               max_buffered=max(via_chunks.max_buffered,
                                                bm.shape[1]))
        table = np.asarray(bm[i])
        via_chunks.submit_chunk(f"s{i}", table[:37])
        via_chunks.submit_chunk(f"s{i}", table[37:], close=True)
    out_a, out_b = via_submit.run(), via_chunks.run()
    for i in range(8):
        sid = f"s{i}"
        np.testing.assert_array_equal(out_a[sid][0], np.asarray(ref_bits[i]))
        np.testing.assert_array_equal(out_b[sid][0], out_a[sid][0])
        assert abs(out_a[sid][1] - out_b[sid][1]) < 1e-3
