"""Multi-device test leg: 8 fake host-platform devices.

XLA reads ``--xla_force_host_platform_device_count`` when the backend first
initializes — it cannot be applied after ``import jax`` has touched devices —
so this leg runs as a SEPARATE pytest invocation that opts in via env var:

    REPRO_MULTIDEVICE=1 PYTHONPATH=src python -m pytest tests/multidevice -q

The main suite (plain ``pytest``) keeps running on the real single CPU
device: without the opt-in the flag is never set, and everything under this
directory is skipped when fewer than 8 devices exist.  CI wires the two as
distinct jobs (see .github/workflows/ci.yml, ``test-multidevice``).
"""
import os

if os.environ.get("REPRO_MULTIDEVICE") == "1":
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8"
        ).strip()

import jax  # noqa: E402  (after the device-count env setup)
import pytest  # noqa: E402

_HERE = os.path.dirname(os.path.abspath(__file__))


def pytest_collection_modifyitems(config, items):
    if len(jax.devices()) >= 8:
        return
    skip = pytest.mark.skip(
        reason="needs >= 8 devices; run REPRO_MULTIDEVICE=1 python -m pytest tests/multidevice"
    )
    for item in items:
        if str(item.fspath).startswith(_HERE):
            item.add_marker(skip)


@pytest.fixture(scope="session")
def mesh81():
    """(8, 1) ('data', 'model') — every fake device on the data axis."""
    return jax.make_mesh((8, 1), ("data", "model"))


@pytest.fixture(scope="session")
def mesh42():
    """(4, 2) ('data', 'model') — data sharding alongside a model axis."""
    return jax.make_mesh((4, 2), ("data", "model"))
