"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward/train step on CPU, asserting output
shapes and no NaNs; plus prefill/decode consistency against the full
forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import arch_ids, get_smoke_arch
from repro.models.model_zoo import build

B, S = 2, 32


def _batch_for(model, key):
    cfg = model.cfg
    if cfg.family == "encdec":
        S_dec = S // cfg.dec_ratio
        return {
            "frames": jax.random.normal(key, (B, S, cfg.frontend_dim), jnp.bfloat16),
            "tokens": jax.random.randint(key, (B, S_dec), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, S_dec), 0, cfg.vocab),
        }
    if cfg.modality == "vision":
        nt = S - cfg.n_prefix_tokens
        return {
            "tokens": jax.random.randint(key, (B, nt), 0, cfg.vocab),
            "patches": jax.random.normal(
                key, (B, cfg.n_prefix_tokens, cfg.frontend_dim), jnp.bfloat16),
            "labels": jax.random.randint(key, (B, nt), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch_id", arch_ids())
def test_train_step_shapes_and_finite(arch_id, rng):
    model = build(get_smoke_arch(arch_id))
    params = model.init(rng)
    batch = _batch_for(model, jax.random.fold_in(rng, 1))
    loss, metrics = jax.jit(lambda p, b: model.train_loss(p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch_id}: loss={loss}"
    # gradients exist and are finite for every parameter
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), arch_id


@pytest.mark.parametrize("arch_id", arch_ids())
def test_prefill_decode_runs(arch_id, rng):
    model = build(get_smoke_arch(arch_id))
    cfg = model.cfg
    params = model.init(rng)
    batch = _batch_for(model, jax.random.fold_in(rng, 1))
    batch.pop("labels")
    caches = model.init_cache(B, S)
    logits, caches = jax.jit(lambda p, b, c: model.prefill(p, b, c))(
        params, batch, caches)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch_id
    pos0 = S // cfg.dec_ratio if cfg.family == "encdec" else (
        S if cfg.modality != "vision" else S)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(2):
        logits, caches = jax.jit(
            lambda p, t, po, c: model.decode_step(p, t, po, c))(
            params, tok, jnp.full((B,), pos0 + i, jnp.int32), caches)
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), arch_id
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch_id", ["qwen3_4b", "gemma3_12b", "qwen2_5_3b",
                                     "jamba_v0_1_52b", "xlstm_350m"])
def test_decode_consistent_with_full_forward(arch_id, rng):
    """Teacher-forcing consistency: prefill(S tokens) + decode(token S)
    produces the same logits as a full forward over S+1 tokens.  Run in
    float32 compute to make the comparison meaningful.

    MoE capacity is raised so routing drops (which legitimately differ
    between a full pass and single-token decode) don't enter the check;
    chunkwise-parallel recurrences (mLSTM/sLSTM) are allowed their
    documented ~1e-2 stabilizer-reordering drift."""
    bundle = get_smoke_arch(arch_id)
    cfg = dataclasses.replace(bundle.model, compute_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    bundle = dataclasses.replace(bundle, model=cfg)
    model = build(bundle)
    params = model.init(rng)
    toks = jax.random.randint(jax.random.fold_in(rng, 1), (B, S + 1), 0, cfg.vocab)

    # full forward logits at position S (predicting token S+1)
    from repro.models import transformer as tf

    x = tf.embed_tokens(params, cfg, toks)
    x, _, _ = tf.run_stack_full(params["blocks"], cfg, model.part, x)
    from repro.models import common as cm

    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps,
                   compute_dtype=jnp.float32)
    full_logits = tf.lm_head(params, cfg, x)[:, S]

    caches = model.init_cache(B, S + 1)
    _, caches = model.prefill(params, {"tokens": toks[:, :S]}, caches)
    dec_logits, _ = model.decode_step(
        params, toks[:, S:S + 1], jnp.full((B,), S, jnp.int32), caches)
    loose = arch_id in ("xlstm_350m", "jamba_v0_1_52b")  # chunkwise recurrences
    tol = dict(rtol=1e-1, atol=2e-1) if loose else dict(rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), **tol)
    # and the argmax token must agree everywhere
    assert (np.asarray(dec_logits.argmax(-1)) ==
            np.asarray(full_logits.argmax(-1))).all()


def test_param_counts_are_plausible():
    """Analytic param counts (roofline MODEL_FLOPS source) are within 2x of
    the materialized smoke param count scaled... sanity only: exact count
    check on the smoke config itself."""
    import numpy as np

    for arch_id in arch_ids():
        bundle = get_smoke_arch(arch_id)
        model = build(bundle)
        params = model.init(jax.random.PRNGKey(0))
        real = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
        if bundle.model.family == "encdec":
            continue  # analytic model covers the decoder family only
        est = bundle.model.param_count()["total"]
        assert 0.4 * real < est < 2.5 * real, (arch_id, est, real)
