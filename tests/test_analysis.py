"""The analyzer analyzed: accept/reject fixtures for every layer of
``repro.analysis``.

Three groups:

  * jaxpr contract lint — a clean kernel passes; an injected ``psum`` in a
    shard_map body, a float64 constant, a host callback, and an
    over-budget output list each produce the right
    :class:`ContractViolation` kind;
  * repo-rule linter — per-rule accept/reject source fixtures (RPR001
    print, RPR002 raw interpret literal, RPR003 pragma-less host sync in
    a hot scope, RPR004 uncovered backend, RPR005 missing family), pragma
    suppression, and the repo-wide gates: ``src`` lints clean, every
    registered backend is traced (count == len(list_decoders())), and the
    one sanctioned sync is the ONLY RPR003 pragma in ``src/repro/stream/``;
  * runtime guards — ``sanitized()`` counts user host syncs and
    recompiles, filters jax-internal reads, raises on NaN, and refuses to
    nest.
"""
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    GOLDEN_BER_EXEMPT,
    Contract,
    check_hot_paths,
    count_pragmas,
    find_pragmas,
    hot_path_catalog,
    lint_paths,
    sanitized,
    trace_contract,
)
from repro.analysis.repo_lint import check_backend_coverage
from repro.decode import list_decoders

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


# --------------------------------------------------------------------------- #
# jaxpr contract lint                                                          #
# --------------------------------------------------------------------------- #


def _kinds(violations):
    return sorted({v.kind for v in violations})


def test_clean_function_has_no_violations():
    def f(x):
        return jnp.cumsum(x * 2.0), jnp.min(x)

    closed, violations = trace_contract(
        f, [jax.ShapeDtypeStruct((8,), jnp.float32)],
        Contract(name="clean", max_outputs=2),
    )
    assert violations == []
    assert len(closed.jaxpr.eqns) > 0


def test_injected_psum_in_shard_map_is_a_collective_violation(mesh11):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(x):
        return jax.lax.psum(x, "data")

    def f(x):
        return shard_map(
            body, mesh=mesh11, in_specs=P("data"), out_specs=P()
        )(x)

    _, violations = trace_contract(
        f, [jax.ShapeDtypeStruct((4,), jnp.float32)],
        Contract(name="comms-free"),
    )
    assert _kinds(violations) == ["collective"]
    assert violations[0].primitive == "psum"
    assert "shard_map" in violations[0].path

    # the same psum under a contract that allowlists it is clean
    _, allowed = trace_contract(
        f, [jax.ShapeDtypeStruct((4,), jnp.float32)],
        Contract(name="seam", allowed_collectives=frozenset({"psum"})),
    )
    assert allowed == []


def test_injected_float64_constant_is_flagged_with_source_line():
    def f(x):
        with jax.experimental.enable_x64():
            y = x.astype(jnp.float64) * 1.5  # the leak
        return y.astype(jnp.float32)

    _, violations = trace_contract(
        f, [jax.ShapeDtypeStruct((4,), jnp.float32)],
        Contract(name="f32-only"),
    )
    assert "float64" in _kinds(violations)
    flagged = [v for v in violations if v.kind == "float64"]
    assert any("test_analysis" in v.where for v in flagged)


def test_bf16_outside_metric_dtype_is_a_dtype_violation():
    def f(x):
        return x + x.astype(jnp.bfloat16).astype(jnp.float32)

    _, violations = trace_contract(
        f, [jax.ShapeDtypeStruct((4,), jnp.float32)], Contract(name="strict")
    )
    assert "dtype" in _kinds(violations)

    _, tolerated = trace_contract(
        f, [jax.ShapeDtypeStruct((4,), jnp.float32)],
        Contract(name="mixed", extra_float_dtypes=("bfloat16",)),
    )
    assert tolerated == []


def test_host_callback_is_flagged():
    def f(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct((4,), jnp.float32),
            x,
        )
        return y

    _, violations = trace_contract(
        f, [jax.ShapeDtypeStruct((4,), jnp.float32)], Contract(name="no-cb")
    )
    assert _kinds(violations) == ["host-callback"]


def test_output_budget_is_enforced():
    def f(x):
        return x, x * 2, x * 3

    _, violations = trace_contract(
        f, [jax.ShapeDtypeStruct((4,), jnp.float32)],
        Contract(name="two-out", max_outputs=2),
    )
    assert _kinds(violations) == ["outputs"]


# --------------------------------------------------------------------------- #
# hot-path catalog: the CI coverage gate                                       #
# --------------------------------------------------------------------------- #


def test_every_registered_backend_is_traced_and_clean():
    report = check_hot_paths()
    backends = {entry["backend"] for entry in report.values()}
    assert backends == set(list_decoders())
    assert len(backends) == len(list_decoders())
    for name, entry in report.items():
        assert entry["violations"] == [], f"{name}: {entry['violations']}"
        assert entry["equations"] > 0


def test_catalog_contracts_are_meaningfully_strict():
    catalog = {hp.name: hp for hp in hot_path_catalog()}
    # the sharded tick is the comms-free guarantee the GPU-decoder line of
    # work depends on: no collective may EVER be allowlisted there
    assert catalog["sharded_stream_tick"].contract.allowed_collectives == frozenset()
    # seqparallel's seam exchange is the one sanctioned collective user
    assert catalog["seqparallel"].contract.allowed_collectives
    for hp in catalog.values():
        assert not hp.contract.allow_host_callbacks


# --------------------------------------------------------------------------- #
# repo-rule linter: per-rule accept/reject fixtures                            #
# --------------------------------------------------------------------------- #


def _lint_snippet(tmp_path, rel, code):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code))
    violations, n = lint_paths([path], repo_rules=False)
    assert n == 1
    return violations


def test_rpr001_print_rejected_and_log_accepted(tmp_path):
    bad = _lint_snippet(tmp_path, "src/repro/x.py", """
        def f():
            print("debug")
    """)
    assert [v.rule for v in bad] == ["RPR001"]
    good = _lint_snippet(tmp_path, "src/repro/y.py", """
        from repro.obs.log import get_logger
        def f():
            get_logger("x").info("debug")
    """)
    assert good == []


def test_rpr002_raw_interpret_literal_rejected(tmp_path):
    bad = _lint_snippet(tmp_path, "src/repro/k.py", """
        def f(x):
            return kernel_call(x, interpret=True)
    """)
    assert [v.rule for v in bad] == ["RPR002"]
    # None and a resolved variable are both the sanctioned idiom
    good = _lint_snippet(tmp_path, "src/repro/k2.py", """
        def f(x, mode):
            a = kernel_call(x, interpret=None)
            return kernel_call(a, interpret=mode)
    """)
    assert good == []


def test_rpr003_pragma_less_host_sync_rejected(tmp_path):
    bad = _lint_snippet(tmp_path, "repro/stream/window.py", """
        import numpy as np
        def tick(x):
            return np.asarray(x)
    """)
    assert [v.rule for v in bad] == ["RPR003"]

    pragma = _lint_snippet(tmp_path, "repro/stream/window2.py", """
        import numpy as np
        def tick(x):
            return np.asarray(x)  # repr-lint: allow[RPR003]
    """)
    # window2.py is not a hot scope (suffix mismatch) — prove the pragma
    # works on a real hot-scope path instead
    assert pragma == []
    ok = _lint_snippet(tmp_path, "two/repro/stream/window.py", """
        import numpy as np
        def tick(x):
            return np.asarray(x)  # repr-lint: allow[RPR003]
    """)
    assert ok == []


def test_rpr003_catches_every_sync_idiom(tmp_path):
    bad = _lint_snippet(tmp_path, "repro/kernels/hot.py", """
        import numpy as np
        import jax
        def f(x):
            a = np.array(x)
            b = float(x[0])
            c = x.item()
            d = x.block_until_ready()
            e = jax.device_get(x)
            return a, b, c, d, e
    """)
    assert [v.rule for v in bad] == ["RPR003"] * 5


def test_rpr003_scheduler_scope_is_function_limited(tmp_path):
    # host syncs outside step/_step_traced (ingest, reports) stay legal
    violations = _lint_snippet(tmp_path, "repro/stream/scheduler.py", """
        import numpy as np
        def load_report(x):
            return np.asarray(x)
        def _step_traced(x):
            return np.asarray(x)
    """)
    assert [(v.rule, v.line) for v in violations] == [("RPR003", 6)]


def test_rpr005_missing_family_rejected(tmp_path):
    bad = _lint_snippet(tmp_path, "src/repro/b.py", """
        @register_decoder("x", capabilities=BackendCapabilities(online=True))
        def d(spec, bm, *, ctx):
            return None
    """)
    assert [v.rule for v in bad] == ["RPR005"]
    none = _lint_snippet(tmp_path, "src/repro/b2.py", """
        @register_decoder("x")
        def d(spec, bm, *, ctx):
            return None
    """)
    assert [v.rule for v in none] == ["RPR005"]
    good = _lint_snippet(tmp_path, "src/repro/b3.py", """
        @register_decoder("x", capabilities=BackendCapabilities(family="conv"))
        def d(spec, bm, *, ctx):
            return None
    """)
    assert good == []


def test_rpr004_uncovered_backend_rejected(tmp_path):
    (tmp_path / "pyproject.toml").write_text("[project]\nname='fx'\n")
    src = tmp_path / "src"
    src.mkdir()
    (src / "mod.py").write_text(textwrap.dedent("""
        @register_decoder("ghost", capabilities=BackendCapabilities(family="conv"))
        def d(spec, bm, *, ctx):
            return None
    """))
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_decode_api.py").write_text("EXPECTED_BACKENDS = ()\n")
    (tests / "test_golden_ber.py").write_text("CODECS = {}\n")
    violations = check_backend_coverage(tmp_path)
    assert [v.rule for v in violations] == ["RPR004", "RPR004"]
    msgs = " ".join(v.message for v in violations)
    assert "equivalence grid" in msgs and "golden BER" in msgs

    # covering both legs silences it
    (tests / "test_decode_api.py").write_text(
        "EXPECTED_BACKENDS = ('ghost',)\n"
    )
    (tests / "test_golden_ber.py").write_text(
        "K_BACKENDS = ('ghost',)\nCODECS = {}\n"
    )
    assert check_backend_coverage(tmp_path) == []


def test_rpr004_exemptions_name_real_backends_with_reasons():
    for name, reason in GOLDEN_BER_EXEMPT.items():
        assert name in list_decoders()
        assert len(reason) > 20  # a reason, not a rubber stamp


def test_pragma_parser_handles_multiple_codes():
    source = "x = 1  # repr-lint: allow[RPR001, RPR003]\ny = 2\n"
    assert find_pragmas(source) == {1: {"RPR001", "RPR003"}}


# --------------------------------------------------------------------------- #
# repo-wide gates                                                              #
# --------------------------------------------------------------------------- #


def test_src_lints_clean():
    violations, n_files = lint_paths([SRC])
    assert violations == [], "\n".join(map(str, violations))
    assert n_files > 80


def test_the_one_sanctioned_sync_is_the_only_stream_rpr003_pragma():
    pragmas = count_pragmas([SRC / "repro" / "stream"])
    assert pragmas == {"RPR003": 1}, pragmas
    # and it is exactly the committed-bits transfer in the scheduler
    sched = (SRC / "repro" / "stream" / "scheduler.py").read_text()
    line = next(
        text for text in sched.splitlines() if "repr-lint: allow" in text
    )
    assert "np.asarray(bits)" in line


def test_cli_clean_on_src_and_failing_on_bad_file(tmp_path):
    from repro.analysis.__main__ import main

    assert main([str(SRC), "--quiet"]) == 0
    bad = tmp_path / "bad.py"
    bad.write_text("print('hi')\n")
    # a loose file outside src/repro is not library code: RPR001 no-op
    assert main([str(bad), "--quiet"]) == 0
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "bad.py").write_text("print('hi')\n")
    assert main([str(pkg), "--quiet"]) == 1
    assert main([str(tmp_path / "missing.py"), "--quiet"]) == 2


# --------------------------------------------------------------------------- #
# runtime guards                                                               #
# --------------------------------------------------------------------------- #


def test_sanitized_counts_user_host_syncs():
    x = jnp.arange(8.0)
    with sanitized(transfer_guard=None, debug_nans=False) as rep:
        np.asarray(x)
        float(x[0])
        assert rep.host_syncs == 2
        np.asarray(np.ones(3))  # host->host: not a sync
        assert rep.host_syncs == 2
    assert rep.host_syncs == 2


def test_sanitized_counts_recompiles_and_freezes_on_exit():
    @jax.jit
    def f(a):
        return a * 2

    with sanitized(transfer_guard=None, count_host_syncs=False) as rep:
        f(jnp.ones(3)).block_until_ready()
        first = rep.recompiles
        assert first >= 1
        f(jnp.ones(3)).block_until_ready()  # cached: no new compile
        assert rep.recompiles == first
        f(jnp.ones(4)).block_until_ready()  # new shape: recompiles
        assert rep.recompiles > first
    frozen = rep.recompiles
    jax.jit(lambda a: a + 1)(jnp.ones(5)).block_until_ready()
    assert rep.recompiles == frozen  # report is frozen after exit


def test_sanitized_debug_nans_raises():
    with (
        pytest.raises(FloatingPointError),
        sanitized(transfer_guard=None, count_host_syncs=False),
    ):
        jnp.log(jnp.asarray(-1.0)).block_until_ready()


def test_sanitized_transfer_guard_blocks_implicit_and_allows_window():
    with sanitized(debug_nans=False, count_host_syncs=False) as rep:
        with pytest.raises(Exception, match="[Dd]isallow"):
            jax.jit(lambda a: a + 1)(np.ones(3, np.float32))
        with rep.allow_transfers():
            jax.jit(lambda a: a + 1)(np.ones(3, np.float32))


def test_sanitized_does_not_nest():
    with (
        sanitized(transfer_guard=None, debug_nans=False),
        pytest.raises(RuntimeError, match="nest"),
        sanitized(),
    ):
        pass


def test_sanitized_restores_numpy_entry_points():
    orig_asarray, orig_array = np.asarray, np.array
    with sanitized(transfer_guard=None, debug_nans=False):
        assert np.asarray is not orig_asarray
    assert np.asarray is orig_asarray and np.array is orig_array
