"""CRF head (trainable trellis) and punctured-code tests."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis gates ONLY the property test below — the CRF and puncture
# coverage must run even on containers without it
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.crf import (
    crf_decode,
    crf_log_norm,
    crf_loss,
    crf_marginals,
    crf_score,
)
from repro.core.puncture import (
    PUNCTURE_2_3,
    PUNCTURE_3_4,
    PUNCTURE_5_6,
    PUNCTURE_TURBO_1_2,
    effective_rate,
    pattern_mask,
    punctured_hard_metrics,
)
from repro.core import CODE_K3_STD, bsc, encode, viterbi_decode


def _rand_crf(rng, B=2, T=5, S=3):
    k1, k2 = jax.random.split(rng)
    trans = jax.random.normal(k1, (S, S))
    emis = jax.random.normal(k2, (B, T, S))
    return trans, emis


def test_crf_log_norm_matches_brute_force(rng):
    trans, emis = _rand_crf(rng)
    B, T, S = emis.shape
    logz = crf_log_norm(trans, emis)
    for b in range(B):
        scores = []
        for path in itertools.product(range(S), repeat=T):
            s = emis[b, 0, path[0]]
            for t in range(1, T):
                s += trans[path[t - 1], path[t]] + emis[b, t, path[t]]
            scores.append(float(s))
        np.testing.assert_allclose(float(logz[b]),
                                   float(jax.nn.logsumexp(jnp.array(scores))),
                                   rtol=1e-5)


def test_crf_parallel_forward_matches_sequential(rng):
    trans, emis = _rand_crf(rng, B=3, T=17, S=4)
    seq = crf_log_norm(trans, emis, parallel=False)
    par = crf_log_norm(trans, emis, parallel=True)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(par), rtol=1e-5)


def test_crf_decode_is_map(rng):
    trans, emis = _rand_crf(rng)
    B, T, S = emis.shape
    tags, _ = crf_decode(trans, emis)
    for b in range(B):
        best, best_s = None, -np.inf
        for path in itertools.product(range(S), repeat=T):
            s = float(crf_score(trans, emis[b:b + 1],
                                jnp.array(path)[None])[0])
            if s > best_s:
                best, best_s = path, s
        assert tuple(np.asarray(tags[b])) == best


def test_crf_marginals_sum_to_one(rng):
    trans, emis = _rand_crf(rng, B=2, T=6, S=4)
    marg = crf_marginals(trans, emis)
    np.testing.assert_allclose(np.asarray(marg.sum(-1)), 1.0, atol=1e-5)


def test_crf_trains(rng):
    """Gradient descent on the CRF NLL fits a noisy tagging problem."""
    S, B, T = 3, 16, 10
    k = jax.random.fold_in(rng, 7)
    tags = jax.random.randint(k, (B, T), 0, S)
    emis_obs = jax.nn.one_hot(tags, S) * 2.0 + \
        0.5 * jax.random.normal(jax.random.fold_in(k, 1), (B, T, S))
    trans = jnp.zeros((S, S))
    loss0 = crf_loss(trans, emis_obs, tags)
    for _ in range(40):
        g = jax.grad(crf_loss)(trans, emis_obs, tags)
        trans = trans - 0.5 * g
    assert crf_loss(trans, emis_obs, tags) < loss0
    dec, _ = crf_decode(trans, emis_obs)
    assert float((dec == tags).mean()) > 0.9


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), T=st.integers(2, 10))
    def test_crf_loss_nonnegative_and_zero_gap(seed, T):
        """log Z >= score(any path): NLL of every labeling is >= 0."""
        key = jax.random.PRNGKey(seed)
        trans = jax.random.normal(key, (3, 3))
        emis = jax.random.normal(jax.random.fold_in(key, 1), (1, T, 3))
        tags = jax.random.randint(jax.random.fold_in(key, 2), (1, T), 0, 3)
        nll = crf_log_norm(trans, emis) - crf_score(trans, emis, tags)
        assert float(nll[0]) >= -1e-5


# ----------------------------- puncturing -------------------------------- #


def test_effective_rates():
    assert effective_rate(CODE_K3_STD, PUNCTURE_2_3) == pytest.approx(2 / 3)
    assert effective_rate(CODE_K3_STD, PUNCTURE_3_4) == pytest.approx(3 / 4)
    assert effective_rate(CODE_K3_STD, PUNCTURE_5_6) == pytest.approx(5 / 6)
    assert effective_rate(CODE_K3_STD, PUNCTURE_TURBO_1_2) == pytest.approx(1 / 2)


def test_pattern_mask_tiles_and_accepts_any_stream_count():
    """pattern_mask works from a ConvCode, an RSCCode, or a bare stream
    count (the turbo 3-stream layout belongs to no single trellis), and
    tiles correctly when T is not a multiple of the pattern period."""
    from repro.siso import RSC_K3_75

    T = 7  # not a multiple of PUNCTURE_3_4's period (3)
    m_code = np.asarray(pattern_mask(CODE_K3_STD, T, PUNCTURE_3_4))
    m_int = np.asarray(pattern_mask(2, T, PUNCTURE_3_4))
    m_rsc = np.asarray(pattern_mask(RSC_K3_75, T, PUNCTURE_3_4))
    want = np.tile(PUNCTURE_3_4.T, (3, 1))[:T]
    for m in (m_code, m_int, m_rsc):
        assert m.shape == (T, 2)
        np.testing.assert_array_equal(m, want)
    m3 = np.asarray(pattern_mask(3, 5, PUNCTURE_TURBO_1_2))
    assert m3.shape == (5, 3)
    assert (m3[:, 0] == 1).all()  # systematic stream never punctured
    with pytest.raises(AssertionError):
        pattern_mask(3, 4, PUNCTURE_2_3)  # stream-count mismatch


def test_punctured_5_6_noiseless_roundtrip(rng):
    """The most aggressive WIMAX rate still decodes exactly without noise
    through the same erasure-metric Viterbi path."""
    code = CODE_K3_STD
    bits = jax.random.bernoulli(rng, 0.5, (8, 50)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    bm = punctured_hard_metrics(code, coded, PUNCTURE_5_6)
    dec, metric = viterbi_decode(code, bm)
    assert (metric == 0).all()
    assert (dec[:, :50] == bits).all()


def test_punctured_noiseless_roundtrip(rng):
    """Rate-2/3 punctured stream decodes exactly without noise (erasure
    metrics leave the surviving positions decisive)."""
    code = CODE_K3_STD
    bits = jax.random.bernoulli(rng, 0.5, (8, 40)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    bm = punctured_hard_metrics(code, coded, PUNCTURE_2_3)
    dec, metric = viterbi_decode(code, bm)
    assert (metric == 0).all()
    assert (dec[:, :40] == bits).all()


def test_punctured_corrects_errors_on_surviving_bits(rng):
    code = CODE_K3_STD
    bits = jax.random.bernoulli(rng, 0.5, (16, 60)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(rng, 1), coded, 0.01)
    bm = punctured_hard_metrics(code, rx, PUNCTURE_2_3)
    dec, _ = viterbi_decode(code, bm)
    ber = float((dec[:, :60] != bits).mean())
    assert ber < 0.05


def test_higher_puncture_rate_is_weaker(rng):
    """3/4-punctured decoding has (weakly) higher BER than unpunctured at
    the same channel — the information-theoretic sanity check."""
    code = CODE_K3_STD
    bits = jax.random.bernoulli(rng, 0.5, (64, 80)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(rng, 1), coded, 0.06)
    from repro.core import hard_branch_metrics

    dec_full, _ = viterbi_decode(code, hard_branch_metrics(code, rx))
    dec_p34, _ = viterbi_decode(code, punctured_hard_metrics(code, rx, PUNCTURE_3_4))
    ber_full = float((dec_full[:, :80] != bits).mean())
    ber_p34 = float((dec_p34[:, :80] != bits).mean())
    assert ber_p34 >= ber_full - 1e-9
