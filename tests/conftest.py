"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the real single
CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def mesh11():
    """A (1,1) ('data','model') mesh on the single CPU device — exercises
    every mesh code path (shard_map, flash decode, sharding rules) without
    multiple devices."""
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture
def sanitized_guards():
    """Opt-in runtime sanitizer: the test body runs under
    ``repro.analysis.sanitized()`` (transfer guard + debug-NaNs + live
    recompile/host-sync counters) and receives the live report."""
    from repro.analysis import sanitized

    with sanitized() as report:
        yield report
