"""Streaming subsystem: sliding-window decode, sessions, scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CODE_K3_STD,
    CODE_K5_GSM,
    bsc,
    encode,
    hard_branch_metrics,
    viterbi_decode,
)
from repro.kernels.ops import viterbi_forward_chunk_op, viterbi_forward_op
from repro.stream import (
    StreamScheduler,
    StreamSession,
    chunk_forward_scan,
    default_depth,
    init_stream_state,
    viterbi_decode_windowed,
)

CODES = {"k3": CODE_K3_STD, "k5": CODE_K5_GSM}


def _noisy_bm(code, key, batch, info_bits, flip):
    bits = jax.random.bernoulli(key, 0.5, (batch, info_bits)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(key, 1), coded, flip)
    return bits, hard_branch_metrics(code, rx)


# --------------------------------------------------------------------------- #
# chunked forward op                                                           #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_name", sorted(CODES))
def test_chunked_forward_matches_full_scan(code_name, rng):
    """Composing carried-state chunk scans == one full-block forward pass."""
    code = CODES[code_name]
    _, bm = _noisy_bm(code, rng, 4, 61, 0.05)
    full_pm, full_bps = viterbi_forward_op(code, bm)

    pm = init_stream_state(code, 4, 1, 1).pm
    bps_parts = []
    C = 16
    T = bm.shape[1]
    for i in range(0, T, C):
        chunk = bm[:, i : i + C]
        if chunk.shape[1] == C:
            pm, bps = viterbi_forward_chunk_op(code, pm, chunk)
        else:  # odd tail goes through the scan reference
            pm, bps = chunk_forward_scan(code, pm, chunk)
        bps_parts.append(bps)
    np.testing.assert_allclose(np.asarray(pm), np.asarray(full_pm), rtol=1e-6)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(b) for b in bps_parts]), np.asarray(full_bps)
    )


def test_chunk_op_matches_scan_reference(rng):
    code = CODE_K3_STD
    _, bm = _noisy_bm(code, rng, 8, 30, 0.1)
    pm0 = init_stream_state(code, 8, 1, 1).pm
    pm_f, bps_f = viterbi_forward_chunk_op(code, pm0, bm)
    pm_s, bps_s = chunk_forward_scan(code, pm0, bm)
    np.testing.assert_allclose(np.asarray(pm_f), np.asarray(pm_s), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(bps_f), np.asarray(bps_s))


# --------------------------------------------------------------------------- #
# (a) windowed == full-block when D >= T                                       #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_name", sorted(CODES))
@pytest.mark.parametrize("backend", ["scan", "fused"])
def test_windowed_bit_exact_when_depth_covers_block(code_name, backend, rng):
    code = CODES[code_name]
    _, bm = _noisy_bm(code, rng, 4, 96 - (code.constraint - 1), 0.04)
    ref_bits, ref_metric = viterbi_decode(code, bm)
    T = bm.shape[1]
    bits, metric = viterbi_decode_windowed(
        code, bm, depth=T, chunk=32, backend=backend
    )
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(ref_bits))
    np.testing.assert_allclose(np.asarray(metric), np.asarray(ref_metric), rtol=1e-5)


def test_windowed_handles_odd_tail(rng):
    """T not a multiple of chunk: the remainder flows through finish()."""
    code = CODE_K3_STD
    _, bm = _noisy_bm(code, rng, 2, 83, 0.02)
    ref_bits, _ = viterbi_decode(code, bm)
    bits, _ = viterbi_decode_windowed(code, bm, depth=bm.shape[1], chunk=32)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(ref_bits))


# --------------------------------------------------------------------------- #
# (b) BER parity at D = 5K on a noisy channel                                  #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_name", sorted(CODES))
def test_windowed_ber_parity_at_truncation_depth(code_name, rng):
    code = CODES[code_name]
    info, bm = _noisy_bm(code, rng, 8, 512, 0.02)
    ref_bits, _ = viterbi_decode(code, bm)
    bits, _ = viterbi_decode_windowed(
        code, bm, depth=default_depth(code), chunk=64, backend="scan"
    )
    n = info.shape[1]
    ber_ref = float((np.asarray(ref_bits)[:, :n] != np.asarray(info)).mean())
    ber_win = float((np.asarray(bits)[:, :n] != np.asarray(info)).mean())
    assert abs(ber_win - ber_ref) <= 1e-3


# --------------------------------------------------------------------------- #
# (c) session chunk-boundary invariance                                        #
# --------------------------------------------------------------------------- #


def test_session_chunk_boundary_invariance(rng):
    """One 4096-step stream decoded in 64-step chunks == one-shot decode."""
    code = CODE_K3_STD
    T = 4096
    info, bm = _noisy_bm(code, rng, 1, T - (code.constraint - 1), 0.01)
    ref_bits, ref_metric = viterbi_decode(code, bm)

    sess = StreamSession(code, batch=1, chunk=64, depth=40, backend="scan")
    parts = []
    for i in range(T // 64):
        parts.append(np.asarray(sess.push(bm[:, i * 64 : (i + 1) * 64])))
    rest, metric = sess.finish(terminated=True)
    parts.append(np.asarray(rest))
    bits = np.concatenate(parts, axis=1)
    assert bits.shape == ref_bits.shape
    np.testing.assert_array_equal(bits, np.asarray(ref_bits))
    np.testing.assert_allclose(np.asarray(metric), np.asarray(ref_metric), rtol=1e-5)


def test_session_emission_bookkeeping(rng):
    """Commit lag: nothing before depth steps, chunk bits at steady state,
    the final `lag` bits on finish."""
    code = CODE_K3_STD
    sess = StreamSession(code, batch=2, chunk=16, depth=24, backend="scan")
    _, bm = _noisy_bm(code, rng, 2, 62, 0.0)
    counts = []
    for i in range(4):
        counts.append(sess.push(bm[:, i * 16 : (i + 1) * 16]).shape[1])
    assert counts == [0, 8, 16, 16]  # t=16,32,48,64 vs depth 24
    assert sess.lag == 24
    rest, _ = sess.finish(terminated=True)
    assert rest.shape[1] == 24
    with pytest.raises(RuntimeError):
        sess.push(bm[:, :16])


def test_session_normalization_keeps_metrics_bounded(rng):
    """A long stream with per-chunk renorm: path metrics stay O(chunk) while
    the reconstructed absolute metric still matches the block decoder."""
    code = CODE_K3_STD
    _, bm = _noisy_bm(code, rng, 1, 1022, 0.05)
    ref_bits, ref_metric = viterbi_decode(code, bm)
    sess = StreamSession(code, batch=1, chunk=64, depth=1024, backend="scan")
    bits, metric = sess.decode_all(bm)
    assert float(sess.state.pm.min()) == 0.0  # renormalized every chunk
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(ref_bits))
    np.testing.assert_allclose(np.asarray(metric), np.asarray(ref_metric), rtol=1e-5)


# --------------------------------------------------------------------------- #
# (d) scheduler: continuous batching + slot reuse                              #
# --------------------------------------------------------------------------- #


def test_scheduler_slot_reuse_across_completions(rng):
    """More streams than slots, staggered lengths: every stream decodes
    exactly, and slots turn over (claims > n_slots)."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=4, chunk=16, depth=30, backend="scan")
    refs = {}
    for i in range(10):
        k = jax.random.fold_in(rng, i)
        T = (96, 130, 64, 200)[i % 4]
        _, bm = _noisy_bm(code, k, 1, T, 0.01)
        rb, rm = viterbi_decode(code, bm)
        refs[f"s{i}"] = (np.asarray(rb[0]), float(rm[0]))
        sched.submit(f"s{i}", bm[0])
    out = sched.run()
    assert sched.stats.streams_finished == 10
    assert sched.stats.slot_claims == 10 > sched.n_slots  # slots were recycled
    assert sched.utilization() == 0.0
    for sid, (rb, rm) in refs.items():
        bits, metric = out[sid]
        np.testing.assert_array_equal(bits, rb)
        assert abs(metric - rm) < 1e-3 * max(1.0, abs(rm))


def test_scheduler_single_jitted_call_per_tick(rng):
    """The hot loop traces once: many ticks with many live streams reuse one
    compiled stream_step."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=8, chunk=16, depth=15, backend="scan")
    traces = {"n": 0}
    orig = sched._step_fn

    def counting(state, bm, weights=None, active=None):
        traces["n"] += 1
        return orig(state, bm, weights, active)

    sched._step_fn = counting
    for i in range(8):
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, i), 1, 94, 0.0)
        sched.submit(f"s{i}", bm[0])
    sched.run()
    assert traces["n"] == sched.stats.ticks  # one batched dispatch per tick


def test_scheduler_short_stream_admitted_mid_run(rng):
    """A stream shorter than one chunk that queues behind a full slot must
    retire cleanly when admitted mid-run (regression: it used to crash the
    packing loop)."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=1, chunk=32, depth=15, backend="scan")
    _, bm_long = _noisy_bm(code, rng, 1, 126, 0.0)
    _, bm_short = _noisy_bm(code, jax.random.fold_in(rng, 1), 1, 10, 0.0)
    ref_short, _ = viterbi_decode(code, bm_short)
    sched.submit("long", bm_long[0])
    sched.submit("short", bm_short[0])  # queues: T=12 < chunk
    out = sched.run()
    assert set(out) == {"long", "short"}
    np.testing.assert_array_equal(out["short"][0], np.asarray(ref_short[0]))


def test_scheduler_slot_state_reset_after_idle_ticks(rng):
    """A slot that sat free (and was advanced with zero branch metrics for
    several ticks) must be re-initialized when a later stream claims it
    (regression: drifted path metrics erased the start-in-state-0
    constraint)."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=2, chunk=16, depth=30, backend="scan")
    _, bm_a = _noisy_bm(code, rng, 1, 158, 0.01)
    sched.submit("a", bm_a[0])
    for _ in range(4):  # slot 1 idles through real ticks
        sched.step()
    # noisy enough that an un-reset (drifted, all-zero) initial pm would
    # decode different bits and understate the metric
    _, bm_b = _noisy_bm(code, jax.random.fold_in(rng, 7), 1, 94, 0.12)
    ref_b, ref_mb = viterbi_decode(code, bm_b)
    sched.submit("b", bm_b[0])
    out = sched.run()
    bits_b, metric_b = out["b"]
    np.testing.assert_array_equal(bits_b, np.asarray(ref_b[0]))
    assert abs(metric_b - float(ref_mb[0])) < 1e-3


def test_scheduler_batched_slot_flush(rng, monkeypatch):
    """All slots retiring in the same tick flush through ONE batched
    traceback call (grouped tail-feeds), not one dispatch per slot — and the
    batched path stays bit-exact, including distinct odd tail lengths."""
    from repro.stream import window as _w

    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=4, chunk=16, depth=90, backend="scan")
    flush_factory = _w.jitted_stream_flush
    calls = {"n": 0}

    def counting_flush(code_, terminated=True, interpret=None):
        calls["n"] += 1
        return flush_factory(code_, terminated=terminated, interpret=interpret)

    monkeypatch.setattr(_w, "jitted_stream_flush", counting_flush)
    refs = {}
    for i, T in enumerate((80, 83, 87, 83)):  # same tick out, 3 tail lengths
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, i), 1, T, 0.02)
        rb, rm = viterbi_decode(code, bm)
        refs[f"s{i}"] = (np.asarray(rb[0]), float(rm[0]))
        sched.submit(f"s{i}", bm[0])
    out = sched.run()
    assert sched.stats.streams_finished == 4
    assert calls["n"] == 1  # one flush for the whole retiring cohort
    for sid, (rb, rm) in refs.items():
        bits, metric = out[sid]
        np.testing.assert_array_equal(bits, rb)
        assert abs(metric - rm) < 1e-3 * max(1.0, abs(rm))


def test_scheduler_accepts_codec_spec(rng):
    """The scheduler consumes a CodecSpec; submit() inherits its terminated
    flag (here: open trellis -> traceback from the best frontier state)."""
    from repro.decode import CodecSpec

    code = CODE_K3_STD
    spec = CodecSpec(code=code, terminated=False)
    sched = StreamScheduler(spec, n_slots=2, chunk=16, depth=200, backend="scan")
    bits = jax.random.bernoulli(rng, 0.5, (1, 90)).astype(jnp.int32)
    bm = spec.branch_metrics(
        bsc(jax.random.fold_in(rng, 1), spec.encode(bits), 0.01)
    )
    ref, _ = viterbi_decode(code, bm, terminated=False)
    sched.submit("open-stream", bm[0])  # terminated defaults from the spec
    out = sched.run()
    np.testing.assert_array_equal(out["open-stream"][0], np.asarray(ref[0]))


def test_scheduler_evict(rng):
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=2, chunk=16, depth=15, backend="scan")
    for i in range(3):
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, i), 1, 158, 0.0)
        sched.submit(f"s{i}", bm[0])
    sched.step()
    assert sched.evict("s2") is None  # still pending
    partial = sched.evict("s0")  # active: returns committed prefix
    assert partial is not None
    out = sched.run()
    assert set(out) == {"s1"}
    with pytest.raises(KeyError):
        sched.evict("nope")


# --------------------------------------------------------------------------- #
# (e) packed-survivor streaming (fused_packed backend)                         #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_name", sorted(CODES))
def test_packed_windowed_bit_exact_when_depth_covers_block(code_name, rng):
    """fused_packed streaming (packed ring + Pallas traceback) stays bit-
    identical to the block decoder in the exactness regime."""
    code = CODES[code_name]
    _, bm = _noisy_bm(code, rng, 4, 96 - (code.constraint - 1), 0.04)
    ref_bits, ref_metric = viterbi_decode(code, bm)
    bits, metric = viterbi_decode_windowed(
        code, bm, depth=bm.shape[1], chunk=32, backend="fused_packed"
    )
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(ref_bits))
    np.testing.assert_allclose(np.asarray(metric), np.asarray(ref_metric), rtol=1e-5)


def test_packed_truncated_window_matches_scan_backend(rng):
    """Away from the exactness regime the packed and unpacked windows must
    still commit identical bits (same truncation, different survivor
    format); the packed depth rounds up to a word multiple."""
    code = CODE_K3_STD
    _, bm = _noisy_bm(code, rng, 4, 254, 0.03)
    b_packed, _ = viterbi_decode_windowed(
        code, bm, depth=32, chunk=32, backend="fused_packed"
    )
    b_scan, _ = viterbi_decode_windowed(code, bm, depth=32, chunk=32, backend="scan")
    np.testing.assert_array_equal(np.asarray(b_packed), np.asarray(b_scan))


def test_packed_session_rounds_depth_and_handles_odd_tail(rng):
    code = CODE_K3_STD
    _, bm = _noisy_bm(code, rng, 2, 81, 0.02)  # T = 83: odd tail of 19
    ref_bits, ref_metric = viterbi_decode(code, bm)
    sess = StreamSession(code, batch=2, chunk=32, depth=bm.shape[1],
                         backend="fused_packed")
    assert sess.depth % 32 == 0 and sess.depth >= bm.shape[1]
    bits, metric = sess.decode_all(bm)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(ref_bits))
    np.testing.assert_allclose(np.asarray(metric), np.asarray(ref_metric), rtol=1e-5)
    with pytest.raises(ValueError, match="chunk"):
        StreamSession(code, chunk=20, backend="fused_packed")


def test_packed_session_from_received_in_kernel_metrics(rng):
    """inputs='received': the session feeds raw symbols and the kernel
    computes the branch metrics — bit-exact vs the table-fed block decode."""
    code = CODE_K3_STD
    bits = jax.random.bernoulli(rng, 0.5, (4, 126)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(rng, 1), coded, 0.03)
    bm = hard_branch_metrics(code, rx)
    ref_bits, ref_metric = viterbi_decode(code, bm)
    sess = StreamSession(code, batch=4, chunk=32, depth=bm.shape[1],
                         backend="fused_packed", inputs="received")
    out, metric = sess.decode_all(rx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_bits))
    np.testing.assert_allclose(np.asarray(metric), np.asarray(ref_metric), rtol=1e-5)


def test_packed_scheduler_slot_reuse_bit_exact(rng):
    """Packed hot loop end-to-end through the scheduler: staggered lengths,
    slot turnover, odd tails — every stream decodes exactly."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=3, chunk=32, depth=250,
                            backend="fused_packed")
    refs = {}
    for i in range(8):
        k = jax.random.fold_in(rng, i)
        T = (96, 130, 64, 200)[i % 4]
        _, bm = _noisy_bm(code, k, 1, T, 0.01)
        rb, rm = viterbi_decode(code, bm)
        refs[f"s{i}"] = (np.asarray(rb[0]), float(rm[0]))
        sched.submit(f"s{i}", bm[0])
    out = sched.run()
    assert sched.stats.streams_finished == 8
    assert sched.stats.slot_claims == 8 > sched.n_slots
    for sid, (rb, rm) in refs.items():
        bits, metric = out[sid]
        np.testing.assert_array_equal(bits, rb)
        assert abs(metric - rm) < 1e-3 * max(1.0, abs(rm))


# --------------------------------------------------------------------------- #
# (f) device-resident scheduler input arena                                    #
# --------------------------------------------------------------------------- #


def test_scheduler_hot_loop_packs_on_device(rng, monkeypatch):
    """The per-tick (n_slots, chunk, M) block is gathered from the device
    arena by slot offset — no host numpy packing in step()."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=4, chunk=16, depth=30, backend="scan")
    gathers = {"n": 0}
    orig = sched._gather

    def counting(arena, offs):
        gathers["n"] += 1
        return orig(arena, offs)

    monkeypatch.setattr(sched, "_gather", counting)
    refs = {}
    for i in range(6):
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, i), 1, (60, 94)[i % 2], 0.01)
        rb, _ = viterbi_decode(code, bm)
        refs[f"s{i}"] = np.asarray(rb[0])
        sched.submit(f"s{i}", bm[0])
    out = sched.run()
    assert gathers["n"] == sched.stats.ticks  # one device gather per tick
    for sid, rb in refs.items():
        np.testing.assert_array_equal(out[sid][0], rb)


def test_scheduler_arena_compaction_preserves_streams(rng):
    """Retired segments eventually dominate the arena; compaction rebuilds
    it around the live streams without disturbing in-flight decodes."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=2, chunk=16, depth=15, backend="scan")
    sched._compact_floor = 0  # exercise compaction at toy sizes
    sched._compact_ratio = 2
    refs = {}
    for i in range(10):
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, i), 1, 62, 0.01)
        rb, _ = viterbi_decode(code, bm)
        refs[f"s{i}"] = np.asarray(rb[0])
        sched.submit(f"s{i}", bm[0])
    out = sched.run()
    assert sched.stats.arena_compactions > 0
    for sid, rb in refs.items():
        np.testing.assert_array_equal(out[sid][0], rb)


# --------------------------------------------------------------------------- #
# (g) scheduler lifecycle edge cases                                           #
# --------------------------------------------------------------------------- #


def test_scheduler_evict_while_draining(rng):
    """Evicting a stream whose remainder is already below one chunk (it
    would retire next tick) must return the committed prefix and free the
    slot without corrupting the streams still in flight."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=2, chunk=16, depth=15, backend="scan")
    _, bm_a = _noisy_bm(code, rng, 1, 158, 0.01)
    _, bm_b = _noisy_bm(code, jax.random.fold_in(rng, 1), 1, 40, 0.01)
    ref_a, _ = viterbi_decode(code, bm_a)
    sched.submit("a", bm_a[0])
    sched.submit("b", bm_b[0])
    for _ in range(8):
        sched.step()
        st_b = next((s for s in sched.active.values() if s.stream_id == "b"), None)
        if st_b is not None and 0 < st_b.available < sched.chunk:
            break
    else:
        pytest.fail("stream 'b' never reached the draining window")
    partial = sched.evict("b")  # draining: remainder < chunk
    assert partial is not None and partial.dtype == np.int32
    out = sched.run()
    assert set(out) == {"a"}
    np.testing.assert_array_equal(out["a"][0], np.asarray(ref_a[0]))
    with pytest.raises(KeyError):
        sched.evict("b")  # already gone


def test_scheduler_submit_after_all_slots_retired(rng):
    """A drained scheduler (every slot retired, results collected) must
    accept and decode a fresh wave of streams."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=2, chunk=16, depth=30, backend="scan")
    for i in range(3):
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, i), 1, 62, 0.01)
        sched.submit(f"wave1-{i}", bm[0])
    sched.run()
    assert not sched.pending_work() and sched.utilization() == 0.0
    _, bm = _noisy_bm(code, jax.random.fold_in(rng, 99), 1, 94, 0.05)
    ref, ref_m = viterbi_decode(code, bm)
    sched.submit("wave2", bm[0])
    out = sched.run()
    np.testing.assert_array_equal(out["wave2"][0], np.asarray(ref[0]))
    assert abs(out["wave2"][1] - float(ref_m[0])) < 1e-3
    assert sched.stats.streams_finished == 4


def test_scheduler_zero_length_stream(rng):
    """A zero-step stream must retire cleanly with empty bits (and must not
    wedge the tick loop or the batched flush)."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=2, chunk=16, depth=15, backend="scan")
    _, bm_real = _noisy_bm(code, rng, 1, 62, 0.01)
    ref, _ = viterbi_decode(code, bm_real)
    sched.submit("empty", np.zeros((0, code.n_symbols), np.float32))
    sched.submit("real", bm_real[0])
    out = sched.run()
    assert out["empty"][0].shape == (0,)
    np.testing.assert_array_equal(out["real"][0], np.asarray(ref[0]))
    assert sched.stats.streams_finished == 2


def test_scheduler_compaction_mid_tick_with_live_slots(rng):
    """Compaction triggered between ticks while streams are mid-flight (the
    admit path compacts): live segments must be relocated coherently so the
    in-flight decode continues bit-exact."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=2, chunk=16, depth=15, backend="scan")
    sched._compact_floor = 0
    sched._compact_ratio = 1  # compact aggressively, incl. with live slots
    refs = {}
    long_ids = []
    for i in range(2):  # long residents: stay live across compactions
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, i), 1, 190, 0.02)
        rb, _ = viterbi_decode(code, bm)
        refs[f"long{i}"] = np.asarray(rb[0])
        long_ids.append(f"long{i}")
        sched.submit(f"long{i}", bm[0])
    sched.step()  # both residents mid-stream
    for i in range(6):  # churn short streams through the queue
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, 100 + i), 1, 46, 0.02)
        rb, _ = viterbi_decode(code, bm)
        refs[f"short{i}"] = np.asarray(rb[0])
        sched.submit(f"short{i}", bm[0])
    out = sched.run()
    assert sched.stats.arena_compactions > 0
    for sid, rb in refs.items():
        np.testing.assert_array_equal(out[sid][0], rb)


# --------------------------------------------------------------------------- #
# (h) mesh-sharded scheduler, single-device degenerate mesh                    #
# --------------------------------------------------------------------------- #


def test_sharded_scheduler_on_unit_mesh_matches_unsharded(mesh11, rng):
    """mesh with data=1: the sharded code path (shard_map tick, per-shard
    arena, collective load report) runs on the main suite's single device
    and stays bit-exact with the plain scheduler."""
    code = CODE_K3_STD
    plain = StreamScheduler(code, n_slots=4, chunk=16, depth=30, backend="scan")
    shard = StreamScheduler(code, n_slots=4, chunk=16, depth=30, backend="scan",
                            mesh=mesh11, mesh_axis="data")
    assert shard.n_shards == 1 and shard._sharded_step is not None
    for i in range(6):
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, i), 1, (94, 62)[i % 2], 0.02)
        plain.submit(f"s{i}", bm[0])
        shard.submit(f"s{i}", bm[0])
    out_p, out_s = plain.run(), shard.run()
    for sid in out_p:
        np.testing.assert_array_equal(out_s[sid][0], out_p[sid][0])
        assert abs(out_s[sid][1] - out_p[sid][1]) < 1e-4
    report = shard.load_report()
    assert report["n_shards"] == 1 and report["active_total"] == 0


def test_sharded_scheduler_validates_mesh(mesh11):
    code = CODE_K3_STD
    with pytest.raises(ValueError, match="no 'nope' axis"):
        StreamScheduler(code, n_slots=4, mesh=mesh11, mesh_axis="nope")


# --------------------------------------------------------------------------- #
# decode-API streaming integration                                             #
# --------------------------------------------------------------------------- #


def test_decode_api_streaming_backend(rng):
    from repro.decode import CodecSpec, DecodeContext, decode

    spec = CodecSpec()
    bits = jax.random.bernoulli(rng, 0.5, (4, 94)).astype(jnp.int32)
    rx = spec.channel(jax.random.fold_in(rng, 1), spec.encode(bits),
                      flip_prob=0.01)
    res = decode(spec, rx, backend="streaming", ctx=DecodeContext(chunk=32))
    assert res.info_bits.shape == bits.shape
    assert float((res.info_bits != bits).mean()) < 0.05


# --------------------------------------------------------------------------- #
# (i) packed odd-tail hardening: T % 32 != 0 in the truncation regime          #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("T", [33, 65, 97, 255])
@pytest.mark.parametrize("depth", [32, 40, 64])
def test_packed_odd_tail_truncation_open_trellis_session(T, depth, rng):
    """Regression (odd-tail audit): T % 32 != 0 with terminated=False — the
    truncation regime — through the packed session's unpack-at-flush path.
    The final segment is smaller than one packed word and the requested
    depth need not be word-aligned (the session rounds it up); committed
    bits and metric must match the unpacked scan backend bit-for-bit at the
    session's EFFECTIVE (rounded) depth."""
    code = CODE_K3_STD
    bits = jax.random.bernoulli(jax.random.fold_in(rng, T * 100 + depth), 0.5,
                                (4, T)).astype(jnp.int32)
    from repro.core import bsc as _bsc
    coded = encode(code, bits, terminate=False)
    rx = _bsc(jax.random.fold_in(rng, T), coded, 0.03)
    bm = hard_branch_metrics(code, rx)
    assert bm.shape[1] % 32 != 0
    sess_p = StreamSession(code, batch=4, chunk=32, depth=depth,
                           backend="fused_packed")
    b_packed, m_packed = sess_p.decode_all(bm, terminated=False)
    # compare at the packed session's effective depth (rounded to a word)
    sess_s = StreamSession(code, batch=4, chunk=32, depth=sess_p.depth,
                           backend="scan")
    b_scan, m_scan = sess_s.decode_all(bm, terminated=False)
    np.testing.assert_array_equal(np.asarray(b_packed), np.asarray(b_scan))
    np.testing.assert_allclose(np.asarray(m_packed), np.asarray(m_scan),
                               rtol=1e-5)


def test_packed_odd_tail_open_trellis_exact_regime(rng):
    """Same odd-tail path in the exactness regime (depth >= T): bit-identical
    to the full-block open-trellis decode, metric included."""
    code = CODE_K3_STD
    for T in (33, 94, 127):
        bits = jax.random.bernoulli(jax.random.fold_in(rng, T), 0.5,
                                    (2, T)).astype(jnp.int32)
        from repro.core import bsc as _bsc
        coded = encode(code, bits, terminate=False)
        rx = _bsc(jax.random.fold_in(rng, T + 1), coded, 0.05)
        bm = hard_branch_metrics(code, rx)
        ref_bits, ref_metric = viterbi_decode(code, bm, terminated=False)
        b, m = viterbi_decode_windowed(code, bm, depth=T, chunk=32,
                                       backend="fused_packed", terminated=False)
        np.testing.assert_array_equal(np.asarray(b), np.asarray(ref_bits))
        np.testing.assert_allclose(np.asarray(m), np.asarray(ref_metric),
                                   rtol=1e-5)


def test_packed_scheduler_odd_tails_truncation_open_trellis(rng):
    """Scheduler flush hardening: packed hot loop, depth < T, odd tails of
    several lengths retiring in mixed cohorts, open trellises — identical to
    the scan-backend scheduler at the same (word-aligned) depth."""
    code = CODE_K3_STD
    sp = StreamScheduler(code, n_slots=3, chunk=32, depth=64,
                         backend="fused_packed")
    ss = StreamScheduler(code, n_slots=3, chunk=32, depth=64, backend="scan")
    for i, T in enumerate((97, 130, 65, 201, 99, 33)):
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, i), 1, T, 0.03)
        sp.submit(f"s{i}", bm[0], terminated=False)
        ss.submit(f"s{i}", bm[0], terminated=False)
    op, os_ = sp.run(), ss.run()
    for sid in op:
        np.testing.assert_array_equal(op[sid][0], os_[sid][0])
        assert abs(op[sid][1] - os_[sid][1]) < 1e-3 * max(1.0, abs(os_[sid][1]))


# --------------------------------------------------------------------------- #
# (j) drain-before-gather: sub-chunk admissions, compaction, arena integrity   #
# --------------------------------------------------------------------------- #


def _assert_arena_integrity(sched):
    """Every live slot's row map must point inside its shard's used prefix,
    cover exactly its unconsumed steps, and never alias another stream."""
    by_shard = {}
    for st in sched.active.values():
        assert len(st.rows) == st.available, st.stream_id
        if len(st.rows):
            assert st.rows.min() >= sched.chunk  # zero prefix is reserved
            assert st.rows.max() < sched._arena_len[st.shard], (
                f"{st.stream_id} points past the used prefix "
                f"(stale _arena_len or compacted rows)"
            )
        by_shard.setdefault(st.shard, []).append(st)
    for streams in by_shard.values():
        all_rows = np.concatenate([st.rows for st in streams]) if streams else []
        assert len(all_rows) == len(set(all_rows.tolist())), "row aliasing"


def test_scheduler_subchunk_streams_retired_same_tick_arena_integrity(rng):
    """Regression (drain-before-gather): zero- and sub-chunk-length streams
    submitted and retired in the same tick, interleaved with compaction
    while long streams stay live — no stale _arena_len entries and no live
    slot left pointing at compacted rows, checked after every tick."""
    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=3, chunk=16, depth=15, backend="scan")
    sched._compact_floor = 0
    sched._compact_ratio = 1  # compact as aggressively as possible
    refs = {}
    _, bm_long = _noisy_bm(code, rng, 2, 190, 0.02)
    for j in range(2):
        rb, _ = viterbi_decode(code, bm_long[j : j + 1])
        refs[f"long{j}"] = np.asarray(rb[0])
        sched.submit(f"long{j}", bm_long[j])
    sched.step()
    _assert_arena_integrity(sched)
    for i in range(8):  # churn sub-chunk and zero-length streams
        T = (10, 0, 3, 14)[i % 4]
        if T:
            _, bm = _noisy_bm(code, jax.random.fold_in(rng, 50 + i), 1, T, 0.02)
            rb, _ = viterbi_decode(code, bm)
            refs[f"tiny{i}"] = np.asarray(rb[0])
            sched.submit(f"tiny{i}", bm[0])
        else:
            refs[f"tiny{i}"] = np.zeros((0,), np.int32)
            sched.submit(f"tiny{i}", np.zeros((0, code.n_symbols), np.float32))
        sched.step()  # the tiny stream admits AND retires inside this tick
        _assert_arena_integrity(sched)
    out = sched.run()
    _assert_arena_integrity(sched)
    assert sched.stats.arena_compactions > 0
    for sid, rb in refs.items():
        np.testing.assert_array_equal(out[sid][0], rb)


def test_scheduler_chunk_fed_submit_tick_compact_interleaving(rng):
    """The same interleaving through the CHUNK-FED path: partial feeds land
    between ticks and compactions relocate live, partially-consumed row
    maps; decode stays bit-exact and the arena stays coherent throughout."""
    from repro.stream import StreamBusy

    code = CODE_K3_STD
    sched = StreamScheduler(code, n_slots=2, chunk=16, depth=15, backend="scan")
    sched._compact_floor = 0
    sched._compact_ratio = 1
    refs, feeds = {}, {}
    for i in range(4):
        _, bm = _noisy_bm(code, jax.random.fold_in(rng, i), 1, (90, 61, 170, 44)[i], 0.02)
        rb, _ = viterbi_decode(code, bm)
        refs[f"s{i}"] = np.asarray(rb[0])
        sched.open_stream(f"s{i}")
        t = np.asarray(bm[0])
        feeds[f"s{i}"] = [t[k : k + 23] for k in range(0, len(t), 23)]
    while sched.pending_work():
        for sid, chunks in feeds.items():
            if chunks:
                try:
                    sched.submit_chunk(sid, chunks[0])
                except StreamBusy:
                    continue
                chunks.pop(0)
                if not chunks:
                    sched.close(sid)
        sched.step()
        _assert_arena_integrity(sched)
    assert sched.stats.arena_compactions > 0
    for sid, rb in refs.items():
        np.testing.assert_array_equal(sched.results[sid][0], rb)
