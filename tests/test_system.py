"""End-to-end system tests: the paper's full workload behind the public API,
plus a real dry-run cell executed in a subprocess (the 512-device path)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

REPO = Path(__file__).resolve().parents[1]


def test_paper_workload_end_to_end(rng):
    """bits -> conv encode -> BSC -> branch metrics -> fused Viterbi ->
    recovered bits, at the paper's sizes (12..60 coded bits) and at
    TPU-throughput batch."""
    from repro.configs.paper_viterbi import ARCH
    from repro.data.pipeline import ViterbiStream
    from repro.decode import CodecSpec, get_decoder
    from repro.decode.request import DecodeContext

    spec = CodecSpec(code=ARCH.code)
    for shape in ARCH.shapes[:5]:  # the paper's Fig. 3 sweep
        stream = ViterbiStream(ARCH.code, shape.n_info_bits, batch=8,
                               flip_prob=0.02)
        batch = stream(0)
        res = get_decoder("fused")(spec, batch["bm_tables"], ctx=DecodeContext())
        ber = float((res.info_bits != batch["info_bits"]).mean())
        assert ber < 0.2, (shape.name, ber)


def test_trellis_expansion_count_matches_paper():
    """§V: Viterbi for 12 coded bits calls the expansion function 19 times;
    our full-sequence kernel runs exactly T=6 grid steps of batched ACS —
    the fused equivalent (4 states × 6 steps ≥ 19 active expansions)."""
    from repro.core import paper_expansion_calls

    assert paper_expansion_calls(12) == 19
    assert paper_expansion_calls(60) == 115


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """The multi-pod dry-run machinery works end to end: lower + compile a
    real cell on the 512-device (2,16,16) mesh in a fresh process."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm_350m",
         "--shape", "decode_32k", "--mesh", "multi", "--force"],
        capture_output=True, text=True, timeout=1200, env=env, cwd=str(REPO))
    assert out.returncode == 0, out.stderr[-2000:]
    cell = json.loads(
        (REPO / "benchmarks/results/dryrun/xlstm_350m--decode_32k--multi.json"
         ).read_text())
    assert cell["status"] == "ok"
    assert cell["chips"] == 512
    # fits per-chip HBM
    assert cell["memory_analysis"]["temp_size_in_bytes"] < 16 * 2 ** 30


def test_seqparallel_decode_on_mesh(mesh11, rng):
    from repro.decode import CodecSpec, decode

    spec = CodecSpec()
    bits = jax.random.bernoulli(rng, 0.5, (4, 62)).astype(jnp.int32)
    rx = spec.channel(jax.random.fold_in(rng, 1), spec.encode(bits),
                      flip_prob=0.01)
    res = decode(spec, rx, backend="seqparallel", mesh=mesh11)
    assert float((res.info_bits != bits).mean()) < 0.05
