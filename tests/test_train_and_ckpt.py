"""Training loop, optimizers, microbatching, checkpoint/restart,
fault tolerance, elastic restore."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_arch
from repro.data.pipeline import SyntheticLM
from repro.models.model_zoo import build
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adafactor, adamw, cosine_warmup, get_optimizer
from repro.train.train_loop import build_step_fn, make_train_step, train


def _tiny_model(arch="qwen2_5_3b", **part_kw):
    bundle = get_smoke_arch(arch)
    if part_kw:
        bundle = dataclasses.replace(
            bundle, partition=dataclasses.replace(bundle.partition, **part_kw))
    return build(bundle)


def _data(model, S=32, B=4):
    return SyntheticLM(vocab=model.cfg.vocab, seq_len=S, global_batch=B, seed=0)


def test_loss_decreases_overfit():
    model = _tiny_model()
    fixed = _data(model)(0)  # one fixed batch, overfit it
    report = train(model, lambda step: fixed, steps=8, lr=5e-3, warmup=2,
                   log_every=1)
    losses = [h["loss"] for h in report["history"]]
    assert losses[-1] < losses[0] * 0.9, losses


def test_microbatch_equals_full_batch(rng):
    """Gradient accumulation is exact: mb=2 and mb=1 produce the same
    updated params on the same batch."""
    m1 = _tiny_model(microbatches=1)
    m2 = _tiny_model(microbatches=2)
    opt = adamw()
    lr_fn = cosine_warmup(1e-3, 1, 10)
    s1 = build_step_fn(m1, opt, lr_fn)
    s2 = build_step_fn(m2, opt, lr_fn)
    params = m1.init(rng)
    opt_state = opt.init(params)
    batch = _data(m1)(0)
    p1, _, met1 = jax.jit(s1)(params, opt_state, batch, 0)
    p2, _, met2 = jax.jit(s2)(params, opt_state, batch, 0)
    np.testing.assert_allclose(float(met1["loss"]), float(met2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizers_reduce_loss(opt_name, rng):
    model = _tiny_model()
    opt = get_optimizer(opt_name)
    step = make_train_step(model, opt, cosine_warmup(3e-3, 1, 20), donate=False)
    params = model.init(rng)
    state = opt.init(params)
    batch = _data(model)(0)
    losses = []
    for i in range(6):
        params, state, met = step(params, state, batch, i)
        losses.append(float(met["loss"]))
    assert losses[-1] < losses[0]


def test_adafactor_state_is_factored():
    model = _tiny_model()
    opt = adafactor(min_dim_size_to_factor=8)
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    leaves = jax.tree_util.tree_leaves(state)
    p_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))
    s_bytes = sum(x.size * x.dtype.itemsize for x in leaves)
    assert s_bytes < 0.8 * p_bytes  # factored: far below one moment per param


def test_checkpoint_roundtrip(tmp_path):
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw()
    state = opt.init(params)
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    saver.save(5, params, state)
    saver.wait()
    path = saver.latest_path()
    assert path and path.endswith("step_00000005")
    p2, s2, step = ckpt.reshard_restored(path, params, state)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_newest(tmp_path):
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    state = adamw().init(params)
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        saver.save(s, params, state)
    saver.wait()
    saver._gc()
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_crash_restore_resume(tmp_path):
    """A simulated node failure mid-run restores the last committed
    checkpoint and still reaches the target step count."""
    model = _tiny_model()
    crashed = {"done": False}

    def fail_hook(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise ckpt.SimulatedFailure("node lost")

    report = train(model, _data(model), steps=8, lr=1e-3, warmup=1,
                   checkpoint_dir=str(tmp_path), checkpoint_every=2,
                   fail_hook=fail_hook, log_every=1)
    assert report["restarts"] == 1
    assert report["final_step"] == 8
    assert crashed["done"]


def test_straggler_detector_flags_outlier():
    from repro.train.fault_tolerance import StragglerDetector

    det = StragglerDetector(zscore=3.0, warmup_steps=3)
    for i in range(10):
        assert not det.observe(i, 0.1 + 0.001 * (i % 2))
    assert det.observe(10, 1.5)  # 15x the baseline -> flagged
    assert len(det.events) == 1


def test_elastic_mesh_shrinks_leading_axis():
    from repro.train.fault_tolerance import elastic_mesh

    mesh = elastic_mesh((4, 1), ("data", "model"), devices=jax.devices())
    # only 1 CPU device exists: data axis shrinks 4 -> 1
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_elastic_restore_across_meshes(tmp_path, mesh11):
    """A checkpoint saved unsharded restores onto a mesh with shardings
    (the elastic-restart path)."""
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    state = adamw().init(params)
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save(3, params, state)
    saver.wait()
    sh = model.param_shardings(mesh11)
    like = jax.tree_util.tree_map(
        lambda p, s: jax.device_put(p, s), params, sh)
    p2, s2, step = ckpt.reshard_restored(saver.latest_path(), like, state)
    assert step == 3
    lead = jax.tree_util.tree_leaves(p2)[0]
    assert lead.sharding is not None
