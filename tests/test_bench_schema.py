"""BENCH_viterbi.json schema gate (v8): the validator the CI bench-smoke job
runs must accept well-formed payloads — including the ``stream.online``,
telemetry-acceptance ``obs``, SISO ``turbo``, fault-injection
``stream.resilience``, time-parallel ``long_blocks``, and static-analysis
``analysis`` sections — and reject the invariants it exists to guard."""
import copy

import pytest

from benchmarks.viterbi_throughput import BENCH_SCHEMA, check_schema


def _workload():
    return {
        "workload": {"constraint": 7, "n_states": 64, "batch": 8, "steps": 90},
        "backends": {
            name: {"bits_per_s": 1e6}
            for name in ("sequential", "fused", "fused_packed",
                         "fused_packed_received")
        },
        "survivor_bytes": {"shrink_x": 30.0},
        "speedup": {
            "fused_packed_vs_fused_hbm_model": 14.8,
            "fused_packed_received_vs_fused_hbm_model": 19.0,
        },
    }


def _payload():
    return {
        "schema": BENCH_SCHEMA,
        "paper_workload_k7": _workload(),
        "paper_workload_k3": _workload(),
        "stream": {
            "by_shards": {
                "1": {"shards": 1, "slots_per_shard": 8, "n_slots": 8,
                      "bits_per_s": 1e5},
                "8": {"shards": 8, "slots_per_shard": 8, "n_slots": 64,
                      "bits_per_s": 8e5, "scaling_vs_shards1": 8.0},
            },
            "online": {
                "sessions": 8,
                "steps": 384,
                "chunk": 64,
                "depth": 15,
                "max_buffered": 512,
                "offered_rows_per_s_per_stream": 250.0,
                "bits_per_s": 1.2e3,
                "ticks": 7,
                "bit_exact_vs_offline": True,
                "latency_s": {"mean": 0.6, "p50": 0.55, "p95": 1.0, "max": 1.2},
                "queue_depth_rows": {"mean": 640.0, "max": 1650,
                                     "max_stream": 244},
            },
            "resilience": {
                "sessions": 8,
                "steps": 384,
                "chunk": 64,
                "depth": 15,
                "backend": "scan",
                "seed": 0,
                "producer_fault_rate": 0.1,
                "elapsed_s": 2.5,
                "injected": {
                    "producer_stall": 21,
                    "slow_drip": 9,
                    "producer_exception": 1,
                    "corrupt_nan": 1,
                    "device_step_failure": 2,
                },
                "streams_finished": 6,
                "streams_quarantined": 2,
                "quarantine_reasons": {"s1": "producer_error",
                                       "s4": "poisoned_chunk"},
                "ticks": 19,
                "ticks_dropped": 2,
                "bits_committed": 2500,
                "timing_faults_bit_exact": True,
                "snapshot": {
                    "tick": 3,
                    "streams": 8,
                    "bytes": 120000,
                    "save_s": 0.004,
                    "restore_s": 0.02,
                    "bit_exact": True,
                },
            },
        },
        "obs": {
            "sessions": 4,
            "steps": 192,
            "chunk": 64,
            "depth": 15,
            "backend": "scan",
            "ticks": 3,
            "repeats": 2,
            "elapsed_off_s": 0.034,
            "elapsed_on_s": 0.032,
            "overhead_frac": -0.045,
            "tick_span_coverage": 0.998,
            "trace_events": 24,
            "latency_s": {"count": 3, "mean": 0.01, "p50": 0.008,
                          "p95": 0.02, "max": 0.02},
            "device_counters": {
                "elapsed_s": 0.035,
                "overhead_frac_ungated": 0.032,
                "merge_depth": {"count": 4, "mean": 2.0, "p50": 2,
                                "p95": 2, "max": 2},
            },
            "bit_exact_with_telemetry": True,
        },
        "long_blocks": {
            "workload": {"constraint": 3, "n_states": 4, "metric": "hard",
                         "batch": 1, "Ts": [2048, 8192],
                         "tile_counts": [4, 16]},
            "by_T": {
                "2048": {
                    "sequential": {"time_s": 0.45, "bits_per_s": 4551.0},
                    "tiled": {
                        "4": {"time_s": 0.30, "bits_per_s": 6826.0,
                              "bit_exact": True,
                              "speedup_vs_sequential": 1.5},
                        "16": {"time_s": 0.21, "bits_per_s": 9752.0,
                               "bit_exact": True,
                               "speedup_vs_sequential": 2.14},
                    },
                    "best_tiles": 16,
                    "best_speedup_vs_sequential": 2.14,
                },
                "8192": {
                    "sequential": {"time_s": 0.48, "bits_per_s": 17066.0},
                    "tiled": {
                        "16": {"time_s": 0.28, "bits_per_s": 29257.0,
                               "bit_exact": True,
                               "speedup_vs_sequential": 1.71},
                    },
                    "best_tiles": 16,
                    "best_speedup_vs_sequential": 1.71,
                },
            },
            "crossover_T_vs_sequential": 2048,
            "note": "measured wall-clock; monotonicity recorded, not asserted",
        },
        "analysis": {
            "lint": {"files": 93, "rules": 5, "violations": 0,
                     "violation_lines": []},
            "jaxpr": {
                "contracts": {
                    "fused": {"backend": "fused", "equations": 69,
                              "violations": 0},
                    "sharded_stream_tick": {"backend": "sharded_stream",
                                            "equations": 913, "violations": 0},
                },
                "backends_registered": 2,
                "backends_traced": 2,
                "violations": 0,
            },
            "pragmas": {"RPR003": 5},
            "stream_pragmas": {"RPR003": 1},
            "sanitize": {
                "ticks": 4,
                "host_syncs_per_tick": [1, 1, 1, 1],
                "steady_recompiles": 0,
                "guarded_tick_s": 0.004,
                "transfer_guard": "disallow",
                "debug_nans": True,
                "bit_exact_vs_unguarded": True,
            },
        },
        "turbo": {
            "workload": {
                "code": "rsc_k4_lte", "interleaver": "qpp(512,31,64)",
                "batch": 8, "block_len": 512, "iterations": 6,
            },
            "ebn0_db": 1.0,
            "ber": {"turbo": 0.0007, "viterbi": 0.012},
            "by_iterations": {
                "1": {"time_s": 0.02, "bits_per_s": 1.6e5},
                "2": {"time_s": 0.05, "bits_per_s": 8.2e4},
                "6": {"time_s": 0.15, "bits_per_s": 2.6e4},
            },
            "early_exit": {
                "time_s": 0.13, "bits_per_s": 3.1e4,
                "iterations_run": 5, "converged_frac": 1.0,
            },
        },
    }


def test_schema_is_v8():
    assert BENCH_SCHEMA == "bench_viterbi/v8"


def test_check_schema_accepts_valid_payload():
    check_schema(_payload())


def test_check_schema_accepts_payload_without_optional_sections():
    payload = _payload()
    del payload["stream"]
    del payload["obs"]
    del payload["turbo"]
    del payload["long_blocks"]  # pre-v7 content is fine
    del payload["analysis"]  # pre-v8 content is fine
    check_schema(payload)
    payload = _payload()
    del payload["analysis"]["sanitize"]  # lint-only analysis run is fine
    check_schema(payload)
    payload = _payload()
    del payload["stream"]["online"]  # by_shards alone (pre-v3 content) is fine
    del payload["stream"]["resilience"]  # pre-v6 content is fine too
    check_schema(payload)


def test_check_schema_accepts_chaos_run_with_no_fatal_faults():
    # a lucky seed can inject only timing faults: nothing quarantined
    payload = _payload()
    res = payload["stream"]["resilience"]
    res["injected"] = {"producer_stall": 4, "slow_drip": 2,
                       "device_step_failure": 1}
    res["streams_finished"] = 8
    res["streams_quarantined"] = 0
    res["quarantine_reasons"] = {}
    res["ticks_dropped"] = 1
    check_schema(payload)


@pytest.mark.parametrize(
    "mutate",
    [
        lambda p: p.__setitem__("schema", "bench_viterbi/v2"),
        lambda p: p["stream"]["online"].pop("latency_s"),
        lambda p: p["stream"]["online"].pop("max_buffered"),
        lambda p: p["stream"]["online"].__setitem__("bit_exact_vs_offline", False),
        # a single stream's queue deeper than its bound = backpressure broken
        lambda p: p["stream"]["online"]["queue_depth_rows"].__setitem__(
            "max_stream", 513
        ),
        # total queue deeper than sessions x bound = accounting broken
        lambda p: p["stream"]["online"]["queue_depth_rows"].__setitem__(
            "max", 8 * 512 + 1
        ),
        lambda p: p["stream"]["online"]["latency_s"].__setitem__("p95", 0.1),
    ],
)
def test_check_schema_rejects_broken_online_sections(mutate):
    payload = copy.deepcopy(_payload())
    mutate(payload)
    with pytest.raises((AssertionError, KeyError)):
        check_schema(payload)


@pytest.mark.parametrize(
    "mutate",
    [
        # the telemetry-plane acceptance gates, re-checked on the artifact
        lambda p: p["obs"].__setitem__("overhead_frac", 0.06),
        lambda p: p["obs"].__setitem__("tick_span_coverage", 0.90),
        lambda p: p["obs"].__setitem__("trace_events", 0),
        lambda p: p["obs"].__setitem__("bit_exact_with_telemetry", False),
        lambda p: p["obs"].pop("latency_s"),
        lambda p: p["obs"].pop("device_counters"),
        lambda p: p["obs"]["device_counters"].pop("merge_depth"),
        # merge depth above the R+1 "never merged" sentinel is impossible
        lambda p: p["obs"]["device_counters"]["merge_depth"].__setitem__(
            "max", 15 + 64 + 2
        ),
        lambda p: p["obs"]["latency_s"].__setitem__("p95", 0.001),
    ],
)
def test_check_schema_rejects_broken_obs_sections(mutate):
    payload = copy.deepcopy(_payload())
    mutate(payload)
    with pytest.raises((AssertionError, KeyError)):
        check_schema(payload)


@pytest.mark.parametrize(
    "mutate",
    [
        # a chaos run with zero injected faults is not a chaos run
        lambda p: p["stream"]["resilience"].__setitem__("injected", {}),
        # stream accounting broken: finished + quarantined != sessions
        lambda p: p["stream"]["resilience"].__setitem__("streams_finished", 7),
        # quarantine without any fatal fault class injected
        lambda p: p["stream"]["resilience"].__setitem__(
            "injected", {"producer_stall": 5, "device_step_failure": 2}
        ),
        # dropped ticks must equal injected device-step failures
        lambda p: p["stream"]["resilience"].__setitem__("ticks_dropped", 5),
        # timing faults changing the decode = arrival invariance broken
        lambda p: p["stream"]["resilience"].__setitem__(
            "timing_faults_bit_exact", False
        ),
        lambda p: p["stream"]["resilience"].pop("snapshot"),
        lambda p: p["stream"]["resilience"]["snapshot"].__setitem__(
            "bit_exact", False
        ),
        lambda p: p["stream"]["resilience"]["snapshot"].__setitem__(
            "restore_s", -0.01
        ),
        lambda p: p["stream"]["resilience"]["snapshot"].__setitem__(
            "streams", 0
        ),
        lambda p: p["stream"]["resilience"].__setitem__("bits_committed", 0),
    ],
)
def test_check_schema_rejects_broken_resilience_sections(mutate):
    payload = copy.deepcopy(_payload())
    mutate(payload)
    with pytest.raises((AssertionError, KeyError)):
        check_schema(payload)


@pytest.mark.parametrize(
    "mutate",
    [
        # the exact seam regime may never trade correctness for speed
        lambda p: p["long_blocks"]["by_T"]["2048"]["tiled"]["16"].__setitem__(
            "bit_exact", False
        ),
        lambda p: p["long_blocks"]["by_T"]["2048"]["tiled"]["4"].__setitem__(
            "time_s", 0.0
        ),
        lambda p: p["long_blocks"]["by_T"]["8192"]["sequential"].__setitem__(
            "time_s", -0.1
        ),
        # crossover claimed at a T where the best tiled config does not win
        lambda p: p["long_blocks"]["by_T"]["2048"].__setitem__(
            "best_speedup_vs_sequential", 0.9
        ),
        # crossover later than a T that already won
        lambda p: p["long_blocks"].__setitem__(
            "crossover_T_vs_sequential", 8192
        ),
        # best_tiles must point at a recorded tiled row
        lambda p: p["long_blocks"]["by_T"]["8192"].__setitem__("best_tiles", 32),
        lambda p: p["long_blocks"]["by_T"]["2048"].__setitem__("tiled", {}),
        lambda p: p["long_blocks"].pop("by_T"),
        lambda p: p["long_blocks"].pop("crossover_T_vs_sequential"),
    ],
)
def test_check_schema_rejects_broken_long_blocks_sections(mutate):
    payload = copy.deepcopy(_payload())
    mutate(payload)
    with pytest.raises((AssertionError, KeyError)):
        check_schema(payload)


@pytest.mark.parametrize(
    "mutate",
    [
        # the whole point of the section: the repo must lint clean
        lambda p: p["analysis"]["lint"].__setitem__("violations", 1),
        lambda p: p["analysis"]["jaxpr"].__setitem__("violations", 1),
        # a registered backend with no hot-path contract trace
        lambda p: p["analysis"]["jaxpr"].__setitem__("backends_registered", 3),
        lambda p: p["analysis"]["jaxpr"]["contracts"]["fused"].__setitem__(
            "violations", 2
        ),
        lambda p: p["analysis"]["jaxpr"]["contracts"]["fused"].__setitem__(
            "equations", 0
        ),
        lambda p: p["analysis"]["jaxpr"].__setitem__("contracts", {}),
        # a second RPR003 pragma sneaking into the streaming hot path
        lambda p: p["analysis"].__setitem__("stream_pragmas", {"RPR003": 2}),
        lambda p: p["analysis"].__setitem__("stream_pragmas", {}),
        # the guarded probe leaking an extra per-tick sync or a recompile
        lambda p: p["analysis"]["sanitize"].__setitem__(
            "host_syncs_per_tick", [1, 2, 1, 1]
        ),
        lambda p: p["analysis"]["sanitize"].__setitem__("steady_recompiles", 1),
        lambda p: p["analysis"]["sanitize"].__setitem__(
            "bit_exact_vs_unguarded", False
        ),
        lambda p: p["analysis"]["sanitize"].__setitem__("transfer_guard", None),
        lambda p: p["analysis"].pop("lint"),
        lambda p: p["analysis"].pop("stream_pragmas"),
    ],
)
def test_check_schema_rejects_broken_analysis_sections(mutate):
    payload = copy.deepcopy(_payload())
    mutate(payload)
    with pytest.raises((AssertionError, KeyError)):
        check_schema(payload)


@pytest.mark.parametrize(
    "mutate",
    [
        # the whole point of the section: turbo worse than Viterbi = rejected
        lambda p: p["turbo"]["ber"].__setitem__("turbo", 0.05),
        lambda p: p["turbo"]["ber"].__setitem__("viterbi", -0.01),
        lambda p: p["turbo"].pop("ber"),
        lambda p: p["turbo"].pop("early_exit"),
        lambda p: p["turbo"].__setitem__("by_iterations", {}),
        lambda p: p["turbo"]["by_iterations"]["6"].__setitem__("bits_per_s", 0),
        lambda p: p["turbo"]["by_iterations"]["1"].__setitem__("time_s", -1.0),
        # early exit cannot have run more iterations than the spec allows
        lambda p: p["turbo"]["early_exit"].__setitem__("iterations_run", 7),
        lambda p: p["turbo"]["early_exit"].__setitem__("bits_per_s", 0),
    ],
)
def test_check_schema_rejects_broken_turbo_sections(mutate):
    payload = copy.deepcopy(_payload())
    mutate(payload)
    with pytest.raises((AssertionError, KeyError)):
        check_schema(payload)
