"""SISO codec subsystem: RSC trellises, the max-log-MAP BCJR kernel,
interleavers, the iterative turbo decoder, and their registry/planner wiring.

The correctness anchor is the brute-force posterior oracle: in the min
domain, max-log BCJR LLRs are exactly (best cost of any input sequence with
u_t = 1) - (best with u_t = 0), so for short blocks every LLR is checked
against full sequence enumeration — in interpret mode AND under jit.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.puncture import (
    PUNCTURE_2_3,
    PUNCTURE_TURBO_1_2,
    effective_rate,
    pattern_mask,
)
from repro.core.trellis import CODE_K3_STD
from repro.decode import CodecSpec, decode, get_decoder, plan_decode, spec_family
from repro.kernels.ops import bcjr_llr_op
from repro.kernels.ref import bcjr_llr_ref
from repro.obs import MetricsRegistry
from repro.siso import (
    BlockInterleaver,
    QPPInterleaver,
    RSC_K3_75,
    RSC_K4_LTE,
    RSCCode,
    TurboSpec,
    turbo_decode,
)

CODES = {"k3": RSC_K3_75, "k4": RSC_K4_LTE}
#: small spec whose jit caches stay warm across the file
TSPEC = TurboSpec(code=RSC_K3_75, interleaver=QPPInterleaver(64, 7, 16))


def _rand_bits(key, shape):
    return jax.random.bernoulli(key, 0.5, shape).astype(jnp.int32)


# --------------------------------------------------------------------------- #
# RSC codes                                                                    #
# --------------------------------------------------------------------------- #


def test_rsc_encode_is_systematic_and_terminates(rng):
    code = RSC_K4_LTE
    bits = _rand_bits(rng, (4, 10))
    coded = np.asarray(code.encode(bits, terminate=True))
    assert coded.shape == (4, 10 + code.n_flush, code.n_out)
    np.testing.assert_array_equal(coded[:, :10, 0], np.asarray(bits))
    # replay the trellis: every symbol must be consistent and the tail must
    # drive the register back to state 0
    nxt, out = code.next_state, code.out_bits
    for b in range(4):
        s = 0
        for t in range(coded.shape[1]):
            u = int(coded[b, t, 0])
            np.testing.assert_array_equal(out[s, u], coded[b, t])
            s = int(nxt[s, u])
        assert s == 0


def test_rsc_open_encode_appends_nothing(rng):
    bits = _rand_bits(rng, (2, 7))
    assert RSC_K3_75.encode(bits, terminate=False).shape == (2, 7, 2)


def test_rsc_validation():
    with pytest.raises(ValueError):
        RSCCode(3, 0b011, (0b101,))  # feedback not monic
    code = RSC_K3_75
    assert code.n_states == 4 and code.n_out == 2 and code.n_features == 3


# --------------------------------------------------------------------------- #
# interleavers                                                                 #
# --------------------------------------------------------------------------- #


def test_block_interleaver_is_a_permutation_with_inverse():
    il = BlockInterleaver(4, 8)
    assert il.n == 32
    perm, inv = il.permutation, il.inverse
    assert sorted(perm) == list(range(32))
    x = np.arange(32) * 3
    np.testing.assert_array_equal(x[perm][inv], x)


def test_qpp_interleaver_matches_polynomial_and_inverts():
    il = QPPInterleaver(64, 7, 16)
    k = np.arange(64)
    np.testing.assert_array_equal(il.permutation, (7 * k + 16 * k * k) % 64)
    x = np.arange(64) + 100
    np.testing.assert_array_equal(x[il.permutation][il.inverse], x)


def test_qpp_rejects_non_permutation_polynomial():
    with pytest.raises(ValueError, match="not a permutation"):
        QPPInterleaver(64, 2, 4)  # f1 even: 0 and 32 collide
    with pytest.raises(ValueError):
        QPPInterleaver(1, 1, 0)


# --------------------------------------------------------------------------- #
# BCJR vs brute-force posterior oracle                                         #
# --------------------------------------------------------------------------- #


def _brute_llr(code, feat_tb, terminated):
    """(T, F) single-stream features -> (T,) max-log LLRs by enumerating
    every input sequence (cost = coded bits . channel LLRs + u . a-priori)."""
    T, _ = feat_tb.shape
    n = code.n_out
    out, nxt = np.asarray(code.out_bits), np.asarray(code.next_state)
    best0, best1 = np.full(T, np.inf), np.full(T, np.inf)
    for m in range(1 << T):
        s, cost = 0, 0.0
        u_seq = [(m >> t) & 1 for t in range(T)]
        for t, u in enumerate(u_seq):
            cost += float(np.dot(out[s, u], feat_tb[t, :n])) + u * feat_tb[t, n]
            s = nxt[s, u]
        if terminated and s != 0:
            continue
        for t, u in enumerate(u_seq):
            if u == 0:
                best0[t] = min(best0[t], cost)
            else:
                best1[t] = min(best1[t], cost)
    return best1 - best0


@pytest.mark.parametrize("code_name", sorted(CODES))
@pytest.mark.parametrize("terminated", [False, True], ids=["open", "term"])
def test_bcjr_llr_matches_brute_force(code_name, terminated):
    code = CODES[code_name]
    T, B = 8, 3
    feat = np.random.default_rng(17).normal(
        size=(B, T, code.n_features)).astype(np.float32)
    llr_coded = jnp.asarray(feat[..., : code.n_out])
    apriori = jnp.asarray(feat[..., code.n_out])
    brute = np.stack([_brute_llr(code, feat[b], terminated) for b in range(B)])

    # interpret-mode op
    llr_op, metric = bcjr_llr_op(
        code, llr_coded, apriori, terminated=terminated, interpret=True
    )
    np.testing.assert_allclose(np.asarray(llr_op), brute, atol=1e-4)
    assert metric.shape == (B,)
    # lax.scan reference
    ref = bcjr_llr_ref(
        code, jnp.asarray(feat.transpose(1, 2, 0)), terminated=terminated
    ).T
    np.testing.assert_allclose(np.asarray(ref), brute, atol=1e-4)
    # under jit: identical to the eager op
    llr_jit, _ = jax.jit(
        lambda c, a: bcjr_llr_op(code, c, a, terminated=terminated,
                                 interpret=True)
    )(llr_coded, apriori)
    np.testing.assert_array_equal(np.asarray(llr_jit), np.asarray(llr_op))


def test_bcjr_metric_is_best_sequence_cost():
    """The returned per-stream metric equals min over all sequences of the
    total cost — the Viterbi path metric of the same trellis."""
    code = RSC_K3_75
    T, B = 6, 2
    feat = np.random.default_rng(3).normal(
        size=(B, T, code.n_features)).astype(np.float32)
    _, metric = bcjr_llr_op(
        code, jnp.asarray(feat[..., :2]), jnp.asarray(feat[..., 2]),
        terminated=False, interpret=True,
    )
    out, nxt = np.asarray(code.out_bits), np.asarray(code.next_state)
    for b in range(B):
        best = np.inf
        for m in range(1 << T):
            s, cost = 0, 0.0
            for t in range(T):
                u = (m >> t) & 1
                cost += float(np.dot(out[s, u], feat[b, t, :2]))
                cost += u * feat[b, t, 2]
                s = nxt[s, u]
            best = min(best, cost)
        np.testing.assert_allclose(float(metric[b]), best, atol=1e-4)


def test_bcjr_noiseless_decode_is_exact_through_decode_api(rng):
    spec = CodecSpec(code=RSC_K3_75, metric="soft", terminated=True)
    bits = _rand_bits(rng, (4, 32))
    rx = jnp.asarray(1.0 - 2.0 * spec.encode(bits), jnp.float32)  # clean BPSK
    res = decode(spec, rx)
    assert res.diagnostics["backend"] == "bcjr"
    np.testing.assert_array_equal(np.asarray(res.info_bits), np.asarray(bits))
    assert "llr" in res.diagnostics


# --------------------------------------------------------------------------- #
# turbo codec                                                                  #
# --------------------------------------------------------------------------- #


def test_turbo_spec_validation():
    with pytest.raises(ValueError, match="iterations"):
        dataclasses.replace(TSPEC, iterations=0)
    with pytest.raises(ValueError, match="n_streams"):
        dataclasses.replace(TSPEC, puncture=((1, 1), (1, 0)))  # 2 rows, 3 streams
    with pytest.raises(ValueError, match="block length"):
        TSPEC.encode(jnp.zeros((2, 32), jnp.int32))  # spec block is 64
    assert TSPEC.n_streams == 3 and TSPEC.block_len == 64
    assert spec_family(TSPEC) == "turbo" and TSPEC.metric == "soft"
    assert hash(TSPEC) == hash(dataclasses.replace(TSPEC))


def test_turbo_encode_layout(rng):
    bits = _rand_bits(rng, (2, 64))
    coded = np.asarray(TSPEC.encode(bits))
    assert coded.shape == (2, 64, 3)
    np.testing.assert_array_equal(coded[..., 0], np.asarray(bits))  # systematic
    # parity2 is the constituent parity of the interleaved input
    perm = TSPEC.interleaver.permutation
    c2 = np.asarray(TSPEC.code.encode(bits[:, perm], terminate=False))
    np.testing.assert_array_equal(coded[..., 2], c2[..., 1])


def test_turbo_noiseless_decode_converges_and_early_exits(rng):
    bits = _rand_bits(rng, (4, 64))
    llrs = TSPEC.channel_llrs(1.0 - 2.0 * TSPEC.encode(bits))
    res = turbo_decode(TSPEC, llrs, interpret=True)
    np.testing.assert_array_equal(np.asarray(res.bits), np.asarray(bits))
    assert res.iterations_run < TSPEC.iterations  # early exit fired
    assert bool(res.converged.all())
    assert res.agreement[-1] == 1.0


def test_turbo_early_exit_is_bit_exact_with_fixed_iterations(rng):
    """The freeze-at-convergence construction: stopping early must return
    exactly the bits the full iteration budget would have."""
    bits = _rand_bits(rng, (8, 64))
    snr_db = 1.0 + 10 * np.log10(1 / 3)
    rx = TSPEC.channel(jax.random.fold_in(rng, 9), TSPEC.encode(bits),
                       snr_db=snr_db)
    llrs = TSPEC.channel_llrs(rx, snr_db=snr_db)
    ee = turbo_decode(TSPEC, llrs, early_exit=True, interpret=True)
    fixed = turbo_decode(TSPEC, llrs, early_exit=False, interpret=True)
    assert fixed.iterations_run == TSPEC.iterations
    np.testing.assert_array_equal(np.asarray(ee.bits), np.asarray(fixed.bits))


def test_turbo_records_telemetry(rng):
    bits = _rand_bits(rng, (4, 64))
    llrs = TSPEC.channel_llrs(1.0 - 2.0 * TSPEC.encode(bits))
    reg = MetricsRegistry()
    res = turbo_decode(TSPEC, llrs, interpret=True, metrics=reg)
    snap = reg.snapshot()
    assert snap["turbo_iterations_total"] == res.iterations_run
    assert snap["turbo_early_exits_total"] == 1
    assert snap["turbo_converged_streams"] == 4.0
    assert reg.histogram("turbo_llr_agreement").count == res.iterations_run


# --------------------------------------------------------------------------- #
# puncturing across the SISO paths (satellite: WIMAX-style rates)              #
# --------------------------------------------------------------------------- #


def test_effective_rate_and_mask_for_turbo_pattern():
    assert effective_rate(CODE_K3_STD, PUNCTURE_TURBO_1_2) == pytest.approx(1 / 2)
    # pattern_mask takes a bare stream count for turbo's trellis-less streams
    mask = np.asarray(pattern_mask(3, 5, PUNCTURE_TURBO_1_2))
    assert mask.shape == (5, 3)
    np.testing.assert_array_equal(mask[:, 0], 1)  # systematic always kept
    np.testing.assert_array_equal(mask[:2, 1], [1, 0])  # parities alternate
    np.testing.assert_array_equal(mask[:2, 2], [0, 1])


def test_rsc_codec_spec_punctured_noiseless_roundtrip(rng):
    """Rate-2/3 punctured RSC stream decodes exactly without noise through
    the bcjr backend (erasures leave surviving positions decisive)."""
    spec = CodecSpec(code=RSC_K3_75, metric="soft", terminated=True,
                     puncture=PUNCTURE_2_3)
    bits = _rand_bits(rng, (4, 32))
    rx = jnp.asarray(1.0 - 2.0 * spec.encode(bits), jnp.float32)
    res = decode(spec, rx)
    assert res.plan.backend == "bcjr"
    np.testing.assert_array_equal(np.asarray(res.info_bits), np.asarray(bits))


def test_turbo_punctured_noiseless_roundtrip(rng):
    """WIMAX-style rate-1/2 turbo puncturing (alternating parities) still
    decodes a clean block exactly."""
    spec = dataclasses.replace(TSPEC, puncture=PUNCTURE_TURBO_1_2)
    bits = _rand_bits(rng, (4, 64))
    coded = spec.encode(bits)
    # punctured positions really are not transmitted
    mask = np.asarray(pattern_mask(3, 64, spec.puncture_array))
    assert (np.asarray(coded)[..., mask == 0] == 0).all()
    llrs = spec.channel_llrs(1.0 - 2.0 * coded)
    res = turbo_decode(spec, llrs, interpret=True)
    np.testing.assert_array_equal(np.asarray(res.bits), np.asarray(bits))


# --------------------------------------------------------------------------- #
# registry + planner wiring                                                    #
# --------------------------------------------------------------------------- #


def test_planner_routes_turbo_spec_with_family_rule(rng):
    plan = plan_decode(TSPEC, (4, 64))
    assert plan.backend == "turbo"
    assert "family" in plan.reason and "turbo" in plan.reason
    assert "family" in plan.explain()


def test_planner_routes_rsc_spec_to_bcjr():
    spec = CodecSpec(code=RSC_K4_LTE, metric="soft")
    plan = plan_decode(spec, (4, 128))
    assert plan.backend == "bcjr"
    assert "family" in plan.reason


def test_planner_conv_selection_is_unchanged_by_siso_families():
    """Pin: adding the SISO families must not move any Viterbi choice."""
    from repro.decode import LONG_BLOCK_T, DecodeContext

    assert plan_decode(CodecSpec(), (32, 256)).backend == "fused_packed"
    # long blocks without a mesh route to ``tiled`` since the time-parallel
    # backend landed; the SISO families still leave that choice untouched.
    assert plan_decode(CodecSpec(), (4, LONG_BLOCK_T)).backend == "tiled"
    ctx = DecodeContext(streaming=True, stream_depth=15)
    assert plan_decode(CodecSpec(), (1, 4096), ctx=ctx).backend == "streaming"


def test_family_mismatch_is_a_validation_error():
    with pytest.raises(ValueError, match="family"):
        plan_decode(TSPEC, (4, 64), backend="fused")
    with pytest.raises(ValueError, match="family"):
        plan_decode(CodecSpec(), (4, 64), backend="turbo")
    with pytest.raises(ValueError, match="family"):
        plan_decode(CodecSpec(code=RSC_K3_75), (4, 34), backend="sequential")


def test_decode_turbo_end_to_end_from_received(rng):
    """decode(TurboSpec, rx): raw channel output routes through the turbo
    backend's from_received entry; diagnostics carry the iteration count."""
    bits = _rand_bits(rng, (4, 64))
    snr_db = 2.0 + 10 * np.log10(1 / 3)
    rx = TSPEC.channel(jax.random.fold_in(rng, 3), TSPEC.encode(bits),
                       snr_db=snr_db)
    res = decode(TSPEC, rx)
    assert res.plan.backend == "turbo"
    assert res.diagnostics["backend"] == "turbo"
    assert 1 <= res.diagnostics["iterations"] <= TSPEC.iterations
    assert res.info_bits.shape == (4, 64)
    assert float((res.info_bits != bits).mean()) < 0.05
    assert res.path_metric.shape == (4,)


def test_turbo_backend_capabilities():
    turbo = get_decoder("turbo")
    assert turbo.capabilities.family == "turbo"
    assert turbo.capabilities.accepts_received
    bcjr = get_decoder("bcjr")
    assert bcjr.capabilities.family == "rsc"
    assert bcjr.capabilities.accepts_received


def test_turbo_beats_single_pass_at_low_snr(rng):
    """Iteration must actually help: 6 iterations strictly fewer bit errors
    than 1 iteration on a noisy block (the subsystem's raison d'etre)."""
    bits = _rand_bits(rng, (16, 64))
    snr_db = 0.0 + 10 * np.log10(1 / 3)
    rx = TSPEC.channel(jax.random.fold_in(rng, 4), TSPEC.encode(bits),
                       snr_db=snr_db)
    llrs = TSPEC.channel_llrs(rx, snr_db=snr_db)
    one = turbo_decode(TSPEC, llrs, iterations=1, early_exit=False,
                       interpret=True)
    six = turbo_decode(TSPEC, llrs, iterations=6, early_exit=False,
                       interpret=True)
    err1 = int(jnp.sum(one.bits != bits))
    err6 = int(jnp.sum(six.bits != bits))
    assert err6 < err1, (err6, err1)
