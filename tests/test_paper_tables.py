"""The paper's published numbers, reproduced exactly by the cycle model."""
import pytest

from benchmarks import paper_model as pm


def test_table3_dlx_exact():
    got = pm.table3()
    assert got["assembly_total_mi"] == 6460
    assert got["assembly_total_cycles"] == 25840
    assert got["texpand_total_mi"] == 1919
    assert got["texpand_total_cycles"] == 7676
    assert round(got["improvement_pct"]) == 237  # paper prints 236 (truncated)
    assert got["speedup"] == pytest.approx(3.366, abs=0.01)


def test_table4_picojava_exact():
    got = pm.table4()
    assert got["assembly_total_mi"] == 5624
    assert got["assembly_total_cycles"] == 22496
    assert got["texpand_total_mi"] == 1957
    assert got["texpand_total_cycles"] == 7828
    assert round(got["improvement_pct"]) == 187


def test_table5_nios_exact():
    got = pm.table5()
    assert got["f"]["assembly_total_cycles"] == 1121
    assert got["f"]["ci_total_cycles"] == 532
    assert got["f"]["improvement_pct"] == pytest.approx(110.7, abs=0.05)
    assert got["s"]["ci_total_cycles"] == 665
    assert got["s"]["improvement_pct"] == pytest.approx(68.5, abs=0.1)
    assert got["e"]["assembly_total_cycles"] == 5016
    assert got["e"]["ci_total_cycles"] == 2869
    assert got["e"]["improvement_pct"] == pytest.approx(74.8, abs=0.1)


def test_calls_scaling_matches_fig3():
    assert pm.calls_for_bits(12) == 19
    for bits in (12, 24, 36, 48, 60):
        assert pm.calls_for_bits(bits) == 2 * bits - 5


def test_tpu_analogue_fused_is_one_op():
    from benchmarks.tables import acs_op_counts

    ops = acs_op_counts()
    # the paper: 63 A.I -> 1 custom instruction.  ours: many HLO ops -> 1
    # pallas_call (+ layout/padding glue), and the unfused baseline is an
    # order of magnitude above the fused reference.
    assert ops["fused_kernel_ops"] <= 12
    assert ops["unfused_ops"] > 3 * ops["fused_ref_ops"]
    assert ops["unfused_ops"] >= 40
