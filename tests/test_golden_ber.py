"""Golden BER regression: decode quality must not drift across kernel PRs.

Every entry in the CODECS registry pins a seeded noise sweep for one codec
family into its own ``tests/golden/ber_<name>.json``:

  k7     the K=7 NASA Viterbi code decoded by every hot-path backend over a
         BSC flip sweep — catches kernels that stay shape-correct but decode
         the wrong path.
  turbo  the rate-1/3 LTE-constituent turbo code (K=4 RSC, N=512 QPP) vs the
         equivalent-rate K=7 soft Viterbi baseline over an Eb/N0 sweep — the
         SISO subsystem's acceptance gate: turbo must BEAT Viterbi at the
         1.0 dB waterfall point, not merely not drift.

Regenerate (only when a change is *supposed* to move BER, e.g. a new
truncation policy) with:

    PYTHONPATH=src python tests/test_golden_ber.py --regen [name ...]

No names = every registered codec.  Adding a codec = one registry entry
(filename + payload function); the drift gate and the --regen CLI pick it
up generically.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CODE_K7_NASA
from repro.core.trellis import ConvCode
from repro.decode import CodecSpec, DecodeContext, decode, get_decoder
from repro.siso import QPPInterleaver, RSC_K4_LTE, TurboSpec, turbo_decode

GOLDEN_DIR = Path(__file__).resolve().parent / "golden"
TOLERANCE = 1e-3  # absolute BER drift that fails the gate
SEED = 2026

# ---------------------------- k7 Viterbi sweep ---------------------------- #

K7_BATCH = 16
K7_INFO_BITS = 96
K7_FLIPS = (0.02, 0.06, 0.11)  # clean floor -> waterfall knee -> lossy region
#: every decode path whose quality the file pins: the oracle, the (min,+)
#: scan, the packed Pallas pipeline, the truncated-window streamer, and the
#: time-parallel tiled decoder (P=4 exact seams — must sit exactly on the
#: sequential curve).
K7_BACKENDS = (
    "sequential",
    "parallel",
    "fused",
    "fused_packed",
    "streaming",
    "tiled",
)


def compute_k7_payload():
    """{flip: {backend: ber}} on the pinned seeded workload."""
    spec = CodecSpec(code=CODE_K7_NASA, metric="hard")
    key = jax.random.PRNGKey(SEED)
    bits = jax.random.bernoulli(key, 0.5, (K7_BATCH, K7_INFO_BITS)).astype(jnp.int32)
    coded = spec.encode(bits)
    truth = np.asarray(bits)
    grid = {}
    for i, flip in enumerate(K7_FLIPS):
        rx = spec.channel(jax.random.fold_in(key, 100 + i), coded, flip_prob=flip)
        bm = spec.branch_metrics(rx)
        row = {}
        for name in K7_BACKENDS:
            ctx = DecodeContext(chunk=16, tiles=4 if name == "tiled" else None)
            res = get_decoder(name)(spec, bm, ctx=ctx)
            row[name] = float((np.asarray(res.info_bits) != truth).mean())
        grid[f"{flip:g}"] = row
    return {
        "code": "k7_nasa",
        "metric": "hard",
        "seed": SEED,
        "batch": K7_BATCH,
        "info_bits": K7_INFO_BITS,
        "tolerance": TOLERANCE,
        "ber": grid,
    }


# ------------------------- turbo vs Viterbi sweep ------------------------- #

TURBO_SPEC = TurboSpec(code=RSC_K4_LTE, interleaver=QPPInterleaver(512, 31, 64))
TURBO_BASELINE = CodecSpec(
    code=ConvCode(7, (0o133, 0o171, 0o165)), metric="soft", terminated=False
)
TURBO_RATE = 1.0 / 3.0
TURBO_BATCH = 8
TURBO_EBN0S = (0.5, 1.0, 1.5)
#: the Eb/N0 point where the iterative gain must show: turbo strictly
#: below the equivalent-rate one-shot Viterbi baseline.
TURBO_GATE_EBN0 = 1.0


def compute_turbo_payload():
    """{ebn0: {"turbo": ber, "viterbi": ber}} — same info bits, same rate,
    independent AWGN draws per codec (both channels carry 3 coded bits per
    info bit at snr = ebn0 + 10*log10(1/3))."""
    rng = np.random.default_rng(SEED)
    bits = jnp.asarray(
        rng.integers(0, 2, size=(TURBO_BATCH, TURBO_SPEC.block_len)), jnp.int32
    )
    tcoded = TURBO_SPEC.encode(bits)
    ccoded = TURBO_BASELINE.encode(bits)
    grid = {}
    for i, ebn0 in enumerate(TURBO_EBN0S):
        snr_db = float(ebn0 + 10 * np.log10(TURBO_RATE))
        k1, k2 = jax.random.split(jax.random.PRNGKey(SEED + i))
        rx_t = TURBO_SPEC.channel(k1, tcoded, snr_db=snr_db)
        res_t = turbo_decode(
            TURBO_SPEC, TURBO_SPEC.channel_llrs(rx_t, snr_db=snr_db)
        )
        rx_c = TURBO_BASELINE.channel(k2, ccoded, snr_db=snr_db)
        res_c = decode(TURBO_BASELINE, rx_c)
        grid[f"{ebn0:g}"] = {
            "turbo": float((res_t.bits != bits).mean()),
            "viterbi": float((res_c.info_bits != bits).mean()),
        }
    return {
        "code": "turbo_k4_qpp512 vs k7_soft",
        "seed": SEED,
        "batch": TURBO_BATCH,
        "block_len": TURBO_SPEC.block_len,
        "rate": TURBO_RATE,
        "iterations": TURBO_SPEC.iterations,
        "extrinsic_scale": TURBO_SPEC.extrinsic_scale,
        "gate_ebn0_db": TURBO_GATE_EBN0,
        "tolerance": TOLERANCE,
        "ber": grid,
    }


# ------------------------------- registry -------------------------------- #

#: name -> (golden filename, payload function).  --regen and the drift gate
#: below iterate this; a new codec family is one entry here.
CODECS = {
    "k7": ("ber_k7.json", compute_k7_payload),
    "turbo": ("ber_turbo.json", compute_turbo_payload),
}


def _golden_path(name: str) -> Path:
    return GOLDEN_DIR / CODECS[name][0]


def _load_golden(name: str) -> dict:
    path = _golden_path(name)
    assert path.exists(), (
        f"{path} missing — regenerate with "
        f"PYTHONPATH=src python tests/test_golden_ber.py --regen {name}"
    )
    return json.loads(path.read_text())


@pytest.mark.parametrize("name", sorted(CODECS))
def test_golden_ber_no_drift(name):
    golden = _load_golden(name)
    assert golden["seed"] == SEED
    grid = CODECS[name][1]()["ber"]
    for point, row in golden["ber"].items():
        for series, want in row.items():
            got = grid[point][series]
            assert abs(got - want) <= TOLERANCE, (
                f"BER drift for {name}/{series} at {point}: "
                f"golden {want:.6f} vs current {got:.6f} "
                f"(|diff| > {TOLERANCE:g})"
            )


def test_golden_covers_every_pinned_backend():
    golden = _load_golden("k7")
    for flip in K7_FLIPS:
        assert set(golden["ber"][f"{flip:g}"]) == set(K7_BACKENDS)


def test_golden_turbo_beats_viterbi_at_gate():
    """The SISO acceptance gate: at the pinned 1.0 dB waterfall point the
    6-iteration turbo decode must be strictly better than the
    equivalent-rate soft Viterbi baseline — in the golden file AND in the
    recomputed grid (a stale-but-passing golden file cannot hide a
    regression)."""
    golden = _load_golden("turbo")
    point = f"{TURBO_GATE_EBN0:g}"
    assert golden["ber"][point]["turbo"] < golden["ber"][point]["viterbi"]
    grid = compute_turbo_payload()["ber"]
    assert grid[point]["turbo"] < grid[point]["viterbi"], grid[point]


def _regen(names):
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    for name in names:
        path = _golden_path(name)
        payload = CODECS[name][1]()
        path.write_text(json.dumps(payload, indent=1) + "\n")
        print(f"wrote {path}")
        print(json.dumps(payload["ber"], indent=1))


if __name__ == "__main__":
    import sys

    argv = sys.argv[1:]
    if "--regen" not in argv:
        sys.exit("refusing to overwrite golden files: pass --regen [name ...]")
    picked = [a for a in argv if a != "--regen"]
    unknown = set(picked) - set(CODECS)
    if unknown:
        sys.exit(f"unknown codec(s) {sorted(unknown)}; have {sorted(CODECS)}")
    _regen(picked or sorted(CODECS))
