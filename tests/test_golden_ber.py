"""Golden BER regression: decode quality must not drift across kernel PRs.

A seeded K=7 (NASA code) noise sweep is decoded by every hot-path backend
and the resulting bit-error rates are pinned in ``tests/golden/ber_k7.json``.
Any future kernel/scheduler change that silently degrades decode quality by
more than 1e-3 absolute BER fails here — catching the class of bug where a
kernel stays shape-correct but decodes the wrong path.

Regenerate (only when a change is *supposed* to move BER, e.g. a new
truncation policy) with:

    PYTHONPATH=src python tests/test_golden_ber.py --regen
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CODE_K7_NASA
from repro.decode import CodecSpec, DecodeContext, get_decoder

GOLDEN = Path(__file__).resolve().parent / "golden" / "ber_k7.json"
TOLERANCE = 1e-3  # absolute BER drift that fails the gate

SEED = 2026
BATCH = 16
INFO_BITS = 96
FLIPS = (0.02, 0.06, 0.11)  # clean floor -> waterfall knee -> lossy region
#: every decode path whose quality the file pins: the oracle, the (min,+)
#: scan, the packed Pallas pipeline, and the truncated-window streamer.
BACKENDS = ("sequential", "parallel", "fused_packed", "streaming")


def compute_ber_grid():
    """{flip: {backend: ber}} on the pinned seeded workload."""
    spec = CodecSpec(code=CODE_K7_NASA, metric="hard")
    key = jax.random.PRNGKey(SEED)
    bits = jax.random.bernoulli(key, 0.5, (BATCH, INFO_BITS)).astype(jnp.int32)
    coded = spec.encode(bits)
    truth = np.asarray(bits)
    grid = {}
    for i, flip in enumerate(FLIPS):
        rx = spec.channel(jax.random.fold_in(key, 100 + i), coded, flip_prob=flip)
        bm = spec.branch_metrics(rx)
        row = {}
        for name in BACKENDS:
            res = get_decoder(name)(spec, bm, ctx=DecodeContext(chunk=16))
            row[name] = float((np.asarray(res.info_bits) != truth).mean())
        grid[f"{flip:g}"] = row
    return grid


def test_golden_ber_no_drift():
    assert GOLDEN.exists(), (
        f"{GOLDEN} missing — regenerate with "
        "PYTHONPATH=src python tests/test_golden_ber.py --regen"
    )
    golden = json.loads(GOLDEN.read_text())
    assert golden["code"] == "k7_nasa" and golden["seed"] == SEED
    grid = compute_ber_grid()
    for flip, row in golden["ber"].items():
        for backend, want in row.items():
            got = grid[flip][backend]
            assert abs(got - want) <= TOLERANCE, (
                f"BER drift for backend {backend!r} at flip={flip}: "
                f"golden {want:.6f} vs current {got:.6f} "
                f"(|diff| > {TOLERANCE:g})"
            )


def test_golden_covers_every_pinned_backend():
    golden = json.loads(GOLDEN.read_text())
    for flip in FLIPS:
        assert set(golden["ber"][f"{flip:g}"]) == set(BACKENDS)


def _regen():
    GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    payload = {
        "code": "k7_nasa",
        "metric": "hard",
        "seed": SEED,
        "batch": BATCH,
        "info_bits": INFO_BITS,
        "tolerance": TOLERANCE,
        "ber": compute_ber_grid(),
    }
    GOLDEN.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"wrote {GOLDEN}")
    print(json.dumps(payload["ber"], indent=1))


if __name__ == "__main__":
    import sys

    if "--regen" not in sys.argv:
        sys.exit("refusing to overwrite the golden file: pass --regen")
    _regen()
