"""Per-kernel validation: every Pallas kernel swept over shapes/dtypes and
asserted allclose against the ref.py pure-jnp oracle (interpret mode on CPU,
per the assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import CODE_K3_PAPER, CODE_K3_STD, CODE_K5_GSM, CODE_K7_NASA
from repro.core.trellis import NEG_UNREACHABLE
from repro.kernels import minplus_matmul_op, texpand_op, viterbi_decode_fused, viterbi_forward_op
from repro.kernels.ref import minplus_matmul_ref, texpand_ref, viterbi_scan_ref

CODES = {"k3": CODE_K3_STD, "k3p": CODE_K3_PAPER, "k5": CODE_K5_GSM, "k7": CODE_K7_NASA}


# --------------------------------------------------------------------------- #
# texpand (one fused ACS step)                                                 #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_id", list(CODES))
@pytest.mark.parametrize("B", [1, 8, 128, 200])  # 200: exercises lane padding
def test_texpand_matches_ref(code_id, B, rng):
    code = CODES[code_id]
    S, M = code.n_states, code.n_symbols
    pm = jax.random.normal(rng, (B, S), jnp.float32) * 10
    bm = jax.random.uniform(jax.random.fold_in(rng, 1), (B, M), jnp.float32, 0, 2)
    new_pm, bp = texpand_op(code, pm, bm)
    ref_pm, ref_bp = texpand_ref(code, pm.T, bm.T)
    np.testing.assert_allclose(np.asarray(new_pm), np.asarray(ref_pm.T), rtol=1e-6)
    assert (np.asarray(bp) == np.asarray(ref_bp.T)).all()


def test_texpand_tie_break(rng):
    """Kernel preserves the paper's lowest-state tie rule (strict <)."""
    code = CODE_K3_STD
    pm = jnp.zeros((8, code.n_states))
    bm = jnp.zeros((8, code.n_symbols))
    _, bp = texpand_op(code, pm, bm)
    assert (bp == 0).all()


# --------------------------------------------------------------------------- #
# viterbi_scan (full-sequence fused forward)                                   #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("code_id", ["k3", "k5", "k7"])
@pytest.mark.parametrize("B,T", [(1, 4), (8, 31), (130, 16)])
def test_viterbi_scan_matches_ref(code_id, B, T, rng):
    code = CODES[code_id]
    M, S = code.n_symbols, code.n_states
    bm = jax.random.uniform(rng, (B, T, M), jnp.float32, 0, 2)
    final_pm, bps = viterbi_forward_op(code, bm)
    pm0 = jnp.full((S, B), NEG_UNREACHABLE, jnp.float32).at[0].set(0.0)
    ref_pm, ref_bps = viterbi_scan_ref(code, bm.transpose(1, 2, 0), pm0)
    ref_pm = jnp.minimum(ref_pm, NEG_UNREACHABLE)
    np.testing.assert_allclose(
        np.asarray(final_pm), np.asarray(ref_pm.T), rtol=1e-5)
    assert (np.asarray(bps) == np.asarray(ref_bps.transpose(0, 2, 1))).all()


def test_fused_decoder_equals_reference_decoder(rng):
    from repro.core import bsc, encode, hard_branch_metrics, viterbi_decode

    code = CODE_K5_GSM
    bits = jax.random.bernoulli(rng, 0.5, (32, 60)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(rng, 1), coded, 0.03)
    bm = hard_branch_metrics(code, rx)
    d_ref, m_ref = viterbi_decode(code, bm)
    d_fused, m_fused = viterbi_decode_fused(code, bm)
    assert jnp.allclose(m_ref, m_fused)
    assert (d_ref == d_fused).all()


# --------------------------------------------------------------------------- #
# minplus matmul                                                               #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("shape", [(1, 4, 4, 4), (2, 8, 16, 8), (3, 130, 64, 70)])
def test_minplus_matches_ref(shape, rng):
    N, I, K, J = shape
    a = jax.random.normal(rng, (N, I, K)) * 5
    b = jax.random.normal(jax.random.fold_in(rng, 1), (N, K, J)) * 5
    out = minplus_matmul_op(a, b)
    ref = minplus_matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 3), i=st.integers(1, 12), k=st.integers(1, 12),
    j=st.integers(1, 12), seed=st.integers(0, 2 ** 16),
)
def test_minplus_property(n, i, k, j, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (n, i, k)) * 3
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, k, j)) * 3
    out = minplus_matmul_op(a, b)
    ref = minplus_matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_minplus_associativity(seed):
    """(A⊗B)⊗C == A⊗(B⊗C) in (min,+): the property the block-parallel and
    sequence-parallel decoders rely on."""
    key = jax.random.PRNGKey(seed)
    mats = [jax.random.normal(jax.random.fold_in(key, i), (1, 4, 4)) * 3
            for i in range(3)]
    ab_c = minplus_matmul_op(minplus_matmul_op(mats[0], mats[1]), mats[2])
    a_bc = minplus_matmul_op(mats[0], minplus_matmul_op(mats[1], mats[2]))
    np.testing.assert_allclose(np.asarray(ab_c), np.asarray(a_bc), rtol=1e-4, atol=1e-4)
