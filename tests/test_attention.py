"""Attention unit tests: chunked/online-softmax vs naive, sliding window,
GQA, flash decode on a mesh, ring-cache decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    _masked_decode,
    chunked_attention,
    flash_decode_sharded,
)


def naive_attention(q, k, v, causal=True, window=0, softcap=0.0):
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    q4 = (q * D ** -0.5).reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q4, k).astype(jnp.float32)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, v.shape[-1])


def _qkv(rng, B=2, S=64, H=4, KV=2, D=16, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    return q, k, v


@pytest.mark.parametrize("chunk_q,chunk_kv", [(16, 16), (64, 32), (8, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_naive(chunk_q, chunk_kv, causal, rng):
    q, k, v = _qkv(rng)
    out = chunked_attention(q, k, v, causal=causal, chunk_q=chunk_q,
                            chunk_kv=chunk_kv)
    ref = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [8, 16, 48])
def test_sliding_window_matches_naive(window, rng):
    q, k, v = _qkv(rng)
    out = chunked_attention(q, k, v, causal=True, window=window,
                            chunk_q=16, chunk_kv=16)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_softcap(rng):
    q, k, v = _qkv(rng, S=32)
    out = chunked_attention(q, k, v, causal=True, softcap=20.0,
                            chunk_q=16, chunk_kv=16)
    ref = naive_attention(q, k, v, causal=True, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_decode_matches_full_row(rng):
    """_masked_decode for the last position == full attention's last row."""
    q, k, v = _qkv(rng, S=32)
    B, S, H, D = q.shape
    full = naive_attention(q, k, v, causal=True)
    lo = jnp.zeros((B,), jnp.int32)
    hi = jnp.full((B,), S, jnp.int32)
    dec = _masked_decode(q[:, -1], k, v, lo, hi, 0.0)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_sharded_matches_masked(rng, mesh11):
    q, k, v = _qkv(rng, S=32)
    B, S, H, D = q.shape
    lo = jnp.zeros((B,), jnp.int32)
    hi = jnp.full((B,), S - 3, jnp.int32)  # partially filled cache
    ref = _masked_decode(q[:, -1], k, v, lo, hi, 0.0)
    with mesh11:
        out = flash_decode_sharded(q[:, -1], k, v, lo, hi, 0.0, mesh11,
                                   ("data",))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_ring_cache_decode_matches_window_attention(rng):
    """Streaming W-window decode with the ring cache == banded attention's
    last row, after enough steps to wrap the ring."""
    import dataclasses

    from repro.configs.base import get_smoke_arch
    from repro.models import transformer as tf
    from repro.models.common import init_params
    from repro.models.attention import attention_specs

    bundle = get_smoke_arch("gemma3_12b")
    cfg = dataclasses.replace(bundle.model, compute_dtype="float32")
    part = bundle.partition
    specs = attention_specs(cfg, 0)
    params = init_params(specs, rng)
    B, S = 2, 48  # window is 16 -> ring wraps twice
    x = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, cfg.d_model))

    from repro.models.attention import self_attention

    full, _ = self_attention(params, cfg, part, x, kind="attn_local")

    W = cfg.window
    cache = {
        "k": jnp.zeros((B, W, cfg.n_kv_heads, cfg.resolved_head_dim), jnp.float32),
        "v": jnp.zeros((B, W, cfg.n_kv_heads, cfg.resolved_head_dim), jnp.float32),
        "pos": jnp.full((B, W), -1, jnp.int32),
    }
    outs = []
    for t in range(S):
        y, cache = tf._local_ring_decode(
            params, cfg, part, x[:, t:t + 1],
            positions=jnp.full((B,), t, jnp.int32), cache=cache)
        outs.append(y[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=3e-3, atol=3e-3)


def test_gqa_group_broadcast(rng):
    """KV heads broadcast across query groups exactly (KV=1 == MHA with
    repeated heads)."""
    q, k, v = _qkv(rng, H=4, KV=1)
    out = chunked_attention(q, k, v, causal=True, chunk_q=16, chunk_kv=16)
    k4 = jnp.repeat(k, 4, axis=2)
    v4 = jnp.repeat(v, 4, axis=2)
    ref = chunked_attention(q, k4, v4, causal=True, chunk_q=16, chunk_kv=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
