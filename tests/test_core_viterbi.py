"""Core Viterbi correctness: exact MLD vs brute force, the paper's
tie-break rule, parallel == sequential, error-correction behaviour."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CODE_K3_PAPER,
    CODE_K3_STD,
    CODE_K5_GSM,
    ConvCode,
    bsc,
    encode,
    hard_branch_metrics,
    hmm_viterbi,
    paper_expansion_calls,
    soft_branch_metrics,
    viterbi_decode,
    viterbi_decode_parallel,
)
from repro.core.channel import awgn, bpsk_modulate


def brute_force_mld(code: ConvCode, rx_bits: np.ndarray) -> np.ndarray:
    """Exhaustive maximum-likelihood decoding (small T only).
    Ties resolve to the lexicographically-smallest info word, which matches
    the paper's lowest-state rule for terminated trellises."""
    T_out = rx_bits.shape[0]
    K = code.constraint
    T_info = T_out - (K - 1)
    best, best_metric = None, None
    for cand in itertools.product([0, 1], repeat=T_info):
        coded = np.asarray(encode(code, jnp.asarray(cand)[None], terminate=True))[0]
        metric = int((coded != rx_bits).sum())
        if best_metric is None or metric < best_metric:
            best, best_metric = cand, metric
    return np.asarray(best), best_metric


@pytest.mark.parametrize("code", [CODE_K3_STD, CODE_K3_PAPER, CODE_K5_GSM],
                         ids=["k3std", "k3paper", "k5gsm"])
def test_exact_mld_vs_brute_force(code, rng):
    """The decoder is EXACT maximum-likelihood — matches brute force metric
    on every random noisy word (metrics always equal; bits equal when the
    optimum is unique)."""
    T_info = 6
    for trial in range(8):
        key = jax.random.fold_in(rng, trial)
        k1, k2 = jax.random.split(key)
        bits = jax.random.bernoulli(k1, 0.5, (1, T_info)).astype(jnp.int32)
        coded = encode(code, bits, terminate=True)
        rx = bsc(k2, coded, 0.15)
        bm = hard_branch_metrics(code, rx)
        dec, metric = viterbi_decode(code, bm)
        bf_bits, bf_metric = brute_force_mld(code, np.asarray(rx[0]))
        assert int(metric[0]) == bf_metric
        dec_coded = encode(code, dec[:, :T_info], terminate=True)
        assert int((np.asarray(dec_coded[0]) != np.asarray(rx[0])).sum()) == bf_metric


def test_noiseless_roundtrip(rng):
    for code in (CODE_K3_STD, CODE_K5_GSM):
        bits = jax.random.bernoulli(rng, 0.5, (16, 40)).astype(jnp.int32)
        coded = encode(code, bits, terminate=True)
        bm = hard_branch_metrics(code, coded)
        dec, metric = viterbi_decode(code, bm)
        assert (metric == 0).all()
        assert (dec[:, :40] == bits).all()


def test_single_error_correction(rng):
    """(7,5) K=3 has free distance 5: any single bit error is corrected."""
    bits = jax.random.bernoulli(rng, 0.5, (4, 20)).astype(jnp.int32)
    coded = encode(CODE_K3_STD, bits, terminate=True)  # (4, 22, 2)
    flat = coded.reshape(4, -1)
    for pos in (0, 7, 21, 43):
        rx = flat.at[:, pos].set(1 - flat[:, pos]).reshape(coded.shape)
        bm = hard_branch_metrics(CODE_K3_STD, rx)
        dec, metric = viterbi_decode(CODE_K3_STD, bm)
        assert (dec[:, :20] == bits).all(), f"failed at flip {pos}"
        assert (metric == 1).all()


def test_paper_tiebreak_lowest_state():
    """Paper §IV-B: equal arriving weights -> path from the lowest state
    survives.  With an all-zero branch-metric table every transition ties,
    so every survivor must come from predecessor parity j=0 (state 2v)."""
    from repro.core.acs import acs_step

    code = CODE_K3_STD
    pm = jnp.zeros((1, code.n_states))
    bm = jnp.zeros((1, code.n_symbols))
    _, bp = acs_step(code, pm, bm)
    assert (bp == 0).all()


def test_expansion_call_counts():
    """Paper §V: 19 trellis-expansion calls for 12 coded bits (4-state)."""
    assert paper_expansion_calls(12) == 19
    # Fig 3 sweep: calls(bits) = 2*bits - 5 for the 4-state code, bits >= 6
    for bits in (12, 24, 36, 48, 60):
        assert paper_expansion_calls(bits) == 2 * bits - 5


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_parallel_matches_sequential(chunk, rng):
    code = CODE_K3_STD
    bits = jax.random.bernoulli(rng, 0.5, (8, 50)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(rng, 1), coded, 0.05)
    bm = hard_branch_metrics(code, rx)
    d1, m1 = viterbi_decode(code, bm)
    d2, m2 = viterbi_decode_parallel(code, bm, chunk=chunk)
    assert jnp.allclose(m1, m2)
    assert (d1 == d2).all()


def test_soft_decision_beats_hard(rng):
    """At moderate SNR soft-decision decoding has (weakly) lower BER."""
    code = CODE_K3_STD
    bits = jax.random.bernoulli(rng, 0.5, (64, 100)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    tx = bpsk_modulate(coded)
    rx = awgn(jax.random.fold_in(rng, 2), tx, snr_db=1.0)
    hard_bits = (rx < 0).astype(jnp.int32)
    d_hard, _ = viterbi_decode(code, hard_branch_metrics(code, hard_bits))
    d_soft, _ = viterbi_decode(code, soft_branch_metrics(code, rx))
    ber_hard = float((d_hard[:, :100] != bits).mean())
    ber_soft = float((d_soft[:, :100] != bits).mean())
    assert ber_soft <= ber_hard + 1e-9


def test_hmm_viterbi_matches_brute_force(rng):
    S, T, B = 3, 6, 2
    k1, k2, k3 = jax.random.split(rng, 3)
    log_trans = jax.nn.log_softmax(jax.random.normal(k1, (S, S)), axis=-1)
    log_emit = jax.nn.log_softmax(jax.random.normal(k2, (B, T, S)), axis=-1)
    log_init = jax.nn.log_softmax(jax.random.normal(k3, (S,)))
    states, ll = hmm_viterbi(log_trans, log_emit, log_init)
    for b in range(B):
        best, best_ll = None, -np.inf
        for path in itertools.product(range(S), repeat=T):
            lp = log_init[path[0]] + log_emit[b, 0, path[0]]
            for t in range(1, T):
                lp += log_trans[path[t - 1], path[t]] + log_emit[b, t, path[t]]
            if float(lp) > best_ll:
                best, best_ll = path, float(lp)
        assert np.allclose(float(ll[b]), best_ll, atol=1e-4)
        assert tuple(np.asarray(states[b])) == best


def test_unfused_matches_fused_acs(rng):
    """The paper's 'assembly function' baseline and the fused ACS are
    semantically identical."""
    from repro.core.acs import acs_step, acs_step_unfused

    for code in (CODE_K3_STD, CODE_K5_GSM):
        pm = jax.random.normal(rng, (4, code.n_states))
        bm = jax.random.normal(jax.random.fold_in(rng, 1), (4, code.n_symbols))
        pm1, bp1 = acs_step(code, pm, bm)
        pm2, bp2 = acs_step_unfused(code, pm, bm)
        assert jnp.allclose(pm1, pm2, atol=1e-5)
        # backpointers agree as parities (unfused tracks p&1 = j)
        assert (bp1 == bp2).all()
