"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    CODE_K3_STD,
    CODE_K5_GSM,
    ConvCode,
    bsc,
    encode,
    hard_branch_metrics,
    viterbi_decode,
)

codes = st.sampled_from([CODE_K3_STD, CODE_K5_GSM, ConvCode(4, (0b1111, 0b1101))])


@settings(max_examples=25, deadline=None)
@given(code=codes, seed=st.integers(0, 2 ** 16), T=st.integers(4, 24))
def test_encoder_is_gf2_linear(code, seed, T):
    """Convolutional encoders are LTI over GF(2):
    encode(a ^ b) == encode(a) ^ encode(b)."""
    key = jax.random.PRNGKey(seed)
    a = jax.random.bernoulli(key, 0.5, (1, T)).astype(jnp.int32)
    b = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (1, T)).astype(jnp.int32)
    lhs = encode(code, a ^ b, terminate=False)
    rhs = encode(code, a, terminate=False) ^ encode(code, b, terminate=False)
    assert (lhs == rhs).all()


@settings(max_examples=25, deadline=None)
@given(code=codes, seed=st.integers(0, 2 ** 16), T=st.integers(4, 20),
       p=st.floats(0.0, 0.2))
def test_decoded_metric_lower_bounds_truth(code, seed, T, p):
    """MLD optimality: the decoder's path metric never exceeds the Hamming
    distance between the received word and the TRUE transmitted codeword."""
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (2, T)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(key, 1), coded, p)
    bm = hard_branch_metrics(code, rx)
    _, metric = viterbi_decode(code, bm)
    true_dist = (coded != rx).sum(axis=(1, 2))
    assert (np.asarray(metric) <= np.asarray(true_dist) + 1e-6).all()


@settings(max_examples=25, deadline=None)
@given(code=codes, seed=st.integers(0, 2 ** 16), T=st.integers(4, 20))
def test_decoded_word_is_valid_codeword(code, seed, T):
    """Decoder output, re-encoded, achieves exactly the reported metric —
    i.e. the decoded path is a real path through the trellis."""
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (1, T)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(key, 1), coded, 0.1)
    bm = hard_branch_metrics(code, rx)
    dec, metric = viterbi_decode(code, bm)
    K = code.constraint
    # decoded bits include flush bits; last K-1 must be zero (terminated)
    assert (np.asarray(dec[:, -(K - 1):]) == 0).all()
    re_coded = encode(code, dec[:, : T], terminate=True)
    dist = (re_coded != rx).sum()
    assert int(dist) == int(metric[0])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), T=st.integers(1, 40),
       chunk=st.integers(1, 16))
def test_parallel_decoder_metric_invariant(seed, T, chunk):
    """Sequential and (min,+)-scan decoders agree on the optimal metric for
    arbitrary (T, chunk) combinations incl. ragged padding."""
    from repro.core import viterbi_decode_parallel

    code = CODE_K3_STD
    key = jax.random.PRNGKey(seed)
    bm = jax.random.uniform(key, (2, T, code.n_symbols), minval=0, maxval=3)
    _, m1 = viterbi_decode(code, bm, terminated=False)
    _, m2 = viterbi_decode_parallel(code, bm, chunk=chunk, terminated=False)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_data_pipeline_determinism(seed):
    """Restart safety: batch(step) is a pure function of (seed, step)."""
    from repro.data.pipeline import SyntheticLM

    gen = SyntheticLM(vocab=100, seq_len=32, global_batch=2, seed=seed)
    b1 = gen(7)
    b2 = gen(7)
    b3 = gen(8)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert not bool((b1["tokens"] == b3["tokens"]).all())
