"""Fig. 3 — clock cycles vs number of received bits (12..60), for DLX,
PicoJava II and NIOS II, with and without the custom instruction; plus the
measured TPU-analogue scaling (fused vs unfused decode wall time vs T)."""
from __future__ import annotations

import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks import paper_model as pm
from repro.core import CODE_K3_STD, bsc, encode, hard_branch_metrics, viterbi_decode
from repro.core.acs import acs_step_unfused

BITS = (12, 24, 36, 48, 60)


def cycle_model_sweep() -> List[Dict]:
    rows = []
    for bits in BITS:
        calls = pm.calls_for_bits(bits)
        rows.append({
            "coded_bits": bits,
            "expansion_calls": calls,
            "dlx_assembly_cycles": pm.DLX_ASSEMBLY.total_cycles(calls),
            "dlx_texpand_cycles": pm.DLX_TEXPAND.total_cycles(calls),
            "picojava_assembly_cycles": pm.PICOJAVA_ASSEMBLY.total_cycles(calls),
            "picojava_texpand_cycles": pm.PICOJAVA_TEXPAND.total_cycles(calls),
            "nios_f_assembly_cycles": pm.NIOS["f"][0].total_cycles(calls),
            "nios_f_ci_cycles": pm.NIOS["f"][1].total_cycles(calls),
        })
    return rows


def measured_sweep(batch=256, seed=0) -> List[Dict]:
    code = CODE_K3_STD
    rows = []
    for bits in BITS:
        T = bits // 2
        info = T - (code.constraint - 1)
        key = jax.random.PRNGKey(seed)
        b = jax.random.bernoulli(key, 0.5, (batch, info)).astype(jnp.int32)
        coded = encode(code, b, terminate=True)
        rx = bsc(jax.random.fold_in(key, 1), coded, 0.02)
        bm = hard_branch_metrics(code, rx)

        @jax.jit
        def unfused(bm):
            pm0 = jnp.full((bm.shape[0], code.n_states), 1e30).at[:, 0].set(0.0)
            pmv, _ = jax.lax.scan(
                lambda p, t: acs_step_unfused(code, p, t), pm0, bm.swapaxes(0, 1))
            return pmv

        def timeit(fn):
            jax.block_until_ready(fn(bm))
            t0 = time.perf_counter()
            for _ in range(5):
                out = fn(bm)
            jax.block_until_ready(out)
            return (time.perf_counter() - t0) / 5 * 1e3

        fused = jax.jit(lambda bm: viterbi_decode(code, bm)[1])
        rows.append({
            "coded_bits": bits,
            "t_unfused_ms": timeit(unfused),
            "t_fused_ms": timeit(fused),
        })
    return rows


def run() -> Dict:
    model = cycle_model_sweep()
    measured = measured_sweep()
    # the paper's qualitative claim: cycles grow linearly in bits and the
    # custom-instruction variant stays ~3x below — check the ratio trend
    ratios = [r["dlx_assembly_cycles"] / r["dlx_texpand_cycles"] for r in model]
    assert all(abs(r - ratios[0]) < 1e-9 for r in ratios)  # constant 3.37x
    return {"cycle_model": model, "measured_walltime": measured,
            "dlx_ratio": ratios[0]}


if __name__ == "__main__":
    from repro.obs.log import get_logger

    get_logger("bench.fig3").info(json.dumps(run(), indent=1))
