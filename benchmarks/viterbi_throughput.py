"""TPU-scale Viterbi throughput: decoder backends head-to-head on the
paper's workloads, plus the HBM-traffic accounting of the fused pipeline —
the repo's perf baseline, emitted as machine-readable ``BENCH_viterbi.json``.

The headline comparison is the K=7 NASA code (the paper's production-scale
analogue): sequential lax.scan oracle vs the pre-packing fused Pallas
backend vs the packed pipeline (bit-packed survivors + on-device traceback,
optionally with in-kernel branch metrics from raw symbols).  Wall-clock on
the CPU container is interpret-mode (shape parity only); the bytes-moved
model below is exact arithmetic and is the CI proxy for the speedup gate.

HBM bytes per trellis step per stream (float32/int32 = 4 bytes, uint32
survivor words amortized over 32 steps, decoded bit out = 4):

  fused                 4·(M + 2S + 1)    bm in, unpacked survivors out +
                                          re-read by the XLA traceback
  fused_packed          4·(M + S/16 + 1)  bm in, packed survivors out +
                                          re-read by the Pallas traceback
  fused_packed+rx       4·(n + S/16 + 1)  raw symbols in (no bm table)

  PYTHONPATH=src python benchmarks/viterbi_throughput.py [--smoke]
      [--out benchmarks/results/BENCH_viterbi.json]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_viterbi import CODES, DECODE_SPEC
from repro.obs.log import get_logger
from repro.core.viterbi import viterbi_decode
from repro.decode import CodecSpec, plan_decode
from repro.kernels import fused_metric_plan
from repro.kernels.common import PACK_BITS
from repro.kernels.ops import (
    viterbi_decode_fused,
    viterbi_decode_fused_packed,
    viterbi_decode_packed,
)

log = get_logger("bench.viterbi")

#: v2 added the optional ``stream.by_shards`` per-shard-count scaling table
#: (stream_throughput.py --shards N); v3 adds the optional ``stream.online``
#: steady-state ingestion section (stream_throughput.py --online: sustained
#: bits/s under rate-limited producers, arrival-to-commit latency, queue
#: depths, backpressure counters); v4 adds the optional top-level ``obs``
#: telemetry-acceptance section (stream_throughput.py --telemetry: tracing
#: on/off overhead, tick-phase span coverage, device-counter drain); v5 adds
#: the optional top-level ``turbo`` SISO section (siso_throughput.py: a BER
#: point vs the equivalent-rate Viterbi baseline + decoded bits/s per
#: iteration count); v6 adds the optional ``stream.resilience`` section
#: (stream_throughput.py --chaos: seeded fault-injection drain — injected
#: fault counts by class, survival accounting, snapshot/restore recovery
#: latency, bit-exactness flags).
BENCH_SCHEMA = "bench_viterbi/v6"
DEFAULT_OUT = Path(__file__).resolve().parent / "results" / "BENCH_viterbi.json"


def _mk_inputs(spec: CodecSpec, info_bits: int, batch: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (batch, info_bits)).astype(jnp.int32)
    coded = spec.encode(bits)
    rx = spec.channel(jax.random.fold_in(key, 1), coded, flip_prob=0.02)
    return bits, rx, spec.branch_metrics(rx)


def _timeit(fn, *args, iters: int = 3):
    """(mean seconds, last output) — the output doubles as the oracle check
    so callers don't pay another full decode for it."""
    out = fn(*args)  # warm (trace + compile)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def hbm_bytes_per_step(code, backend: str) -> float:
    """Hot-path HBM bytes per trellis step per stream (model, see module
    doc).  Survivor words amortize over PACK_BITS steps, read + written."""
    S, M, n = code.n_states, code.n_symbols, code.n_out
    packed_sv = 2 * S * 4.0 / PACK_BITS  # write + traceback re-read
    if backend == "fused":
        return 4.0 * (M + 2 * S + 1)
    if backend == "fused_packed":
        return 4.0 * (M + 1) + packed_sv
    if backend == "fused_packed_received":
        return 4.0 * (n + 1) + packed_sv
    raise KeyError(backend)


def bench_backends(spec: CodecSpec, batch: int, info_bits: int, iters: int) -> Dict:
    """One workload, all hot-path backends: measured bits/s + modeled HBM
    traffic.  ``fused_packed_received`` feeds raw symbols (in-kernel
    metrics); the others consume precomputed bm tables."""
    code = spec.code
    bits, rx, bm = _mk_inputs(spec, info_bits, batch)
    T = bm.shape[1]
    total_bits = batch * T
    plan = fused_metric_plan(code, spec.metric, spec.puncture_array)
    runners = {
        "sequential": (jax.jit(lambda b: viterbi_decode(code, b)[0]), bm),
        "fused": (jax.jit(lambda b: viterbi_decode_fused(code, b)[0]), bm),
        "fused_packed": (jax.jit(lambda b: viterbi_decode_packed(code, b)[0]), bm),
        "fused_packed_received": (
            jax.jit(lambda r: viterbi_decode_fused_packed(plan, r)[0]),
            rx,
        ),
    }
    backends: Dict[str, Dict] = {}
    decoded = {}
    for name, (fn, arg) in runners.items():
        t, out = _timeit(fn, arg, iters=iters)
        decoded[name] = np.asarray(out)
        row = {"time_s": t, "bits_per_s": total_bits / t}
        if name != "sequential":
            bps = hbm_bytes_per_step(code, name)
            row["hbm_bytes_per_step_per_stream"] = bps
            row["hbm_bytes_total"] = bps * total_bits
            row["hbm_bytes_per_bit"] = bps
        backends[name] = row
    # every backend must agree with the oracle before its number counts
    for name in ("fused", "fused_packed", "fused_packed_received"):
        assert (decoded[name] == decoded["sequential"]).all(), (
            f"{name} diverged from the sequential oracle"
        )
    S = code.n_states
    return {
        "workload": {
            "constraint": code.constraint,
            "polys_oct": [oct(g) for g in code.polys],
            "n_states": S,
            "batch": batch,
            "steps": T,
            "metric": spec.metric,
            "decoded_bits": total_bits,
        },
        "backends": backends,
        "survivor_bytes": {
            "unpacked_int32": T * S * batch * 4,
            "packed_uint32": -(-T // PACK_BITS) * S * batch * 4,
            "shrink_x": T / float(-(-T // PACK_BITS)),
        },
        "speedup": {
            "fused_packed_vs_sequential_measured": (
                backends["fused_packed"]["bits_per_s"]
                / backends["sequential"]["bits_per_s"]
            ),
            "fused_packed_vs_fused_measured": (
                backends["fused_packed"]["bits_per_s"]
                / backends["fused"]["bits_per_s"]
            ),
            # exact arithmetic — the CI (interpret-mode) proxy for the gate
            "fused_packed_vs_fused_hbm_model": (
                hbm_bytes_per_step(code, "fused")
                / hbm_bytes_per_step(code, "fused_packed")
            ),
            "fused_packed_received_vs_fused_hbm_model": (
                hbm_bytes_per_step(code, "fused")
                / hbm_bytes_per_step(code, "fused_packed_received")
            ),
        },
    }


def run(quick: bool = True, out: Path = DEFAULT_OUT) -> Dict:
    """Benchmark + write BENCH_viterbi.json; returns the payload.  ``quick``
    is the CPU-container (--smoke) shape; full mode runs the production
    batch."""
    interpret = jax.default_backend() != "tpu"
    k7 = CodecSpec(code=CODES["k7_nasa"], metric=DECODE_SPEC.metric)
    k3 = DECODE_SPEC
    if quick:
        k7_shape, k3_shape, iters = (8, 90), (32, 126), 2
    else:
        k7_shape, k3_shape, iters = (128, 1018), (1024, 1022), 3
    payload = {
        "schema": BENCH_SCHEMA,
        "generated_by": "benchmarks/viterbi_throughput.py",
        "smoke": quick,
        "interpret_mode": interpret,
        "device": jax.devices()[0].platform,
        "paper_workload_k7": bench_backends(k7, *k7_shape, iters=iters),
        "paper_workload_k3": bench_backends(k3, *k3_shape, iters=iters),
        "planned_backend_short_block": plan_decode(k7, (k7_shape[0], 256)).backend,
    }
    out = Path(out)
    out.parent.mkdir(parents=True, exist_ok=True)
    if out.exists():  # preserve sections merged in by other benchmarks
        try:
            existing = json.loads(out.read_text())
        except (ValueError, OSError):
            existing = {}
        for section in ("stream", "obs", "turbo"):
            if existing.get(section) is not None:
                payload[section] = existing[section]
    out.write_text(json.dumps(payload, indent=1))
    return payload


def check_schema(payload: Dict) -> None:
    """Schema gate used by the CI smoke job (and tests)."""
    assert payload["schema"] == BENCH_SCHEMA
    for wl_key in ("paper_workload_k7", "paper_workload_k3"):
        wl = payload[wl_key]
        for field in ("workload", "backends", "survivor_bytes", "speedup"):
            assert field in wl, f"{wl_key} missing {field}"
        for name in ("sequential", "fused", "fused_packed", "fused_packed_received"):
            assert wl["backends"][name]["bits_per_s"] > 0
        assert wl["survivor_bytes"]["shrink_x"] > 16  # ~32 for T >> 32
        assert wl["speedup"]["fused_packed_vs_fused_hbm_model"] >= 2.0
        assert wl["speedup"]["fused_packed_received_vs_fused_hbm_model"] >= 2.0
    # optional sharded-scheduler scaling table (stream_throughput --shards N)
    by_shards = (payload.get("stream") or {}).get("by_shards")
    if by_shards is not None:
        for n, row in by_shards.items():
            assert row["shards"] == int(n)
            assert row["n_slots"] == row["slots_per_shard"] * row["shards"]
            assert row["bits_per_s"] > 0
        if "1" in by_shards:
            for n, row in by_shards.items():
                if n != "1":
                    assert "scaling_vs_shards1" in row
    # optional online-ingestion section (stream_throughput --online): v3
    online = (payload.get("stream") or {}).get("online")
    if online is not None:
        for field in ("sessions", "steps", "chunk", "depth", "max_buffered",
                      "offered_rows_per_s_per_stream", "bits_per_s",
                      "latency_s", "queue_depth_rows", "ticks"):
            assert field in online, f"stream.online missing {field}"
        assert online["bits_per_s"] > 0
        assert online["bit_exact_vs_offline"] is True
        lat = online["latency_s"]
        assert 0 <= lat["mean"] <= lat["max"] and lat["p50"] <= lat["p95"]
        q = online["queue_depth_rows"]
        # backpressure invariant: no single stream's bounded queue can ever
        # overrun its credit limit (totals are bounded by sessions x limit)
        assert 0 <= q["max_stream"] <= online["max_buffered"]
        assert 0 <= q["mean"] <= q["max"] <= (
            online["sessions"] * online["max_buffered"]
        )
    # optional telemetry-acceptance section (stream_throughput --telemetry): v4
    obs = payload.get("obs")
    if obs is not None:
        for field in ("sessions", "steps", "chunk", "depth", "ticks", "repeats",
                      "elapsed_off_s", "elapsed_on_s", "overhead_frac",
                      "tick_span_coverage", "trace_events", "latency_s",
                      "device_counters", "bit_exact_with_telemetry"):
            assert field in obs, f"obs missing {field}"
        assert obs["bit_exact_with_telemetry"] is True
        # the acceptance gates the benchmark already enforced, re-checked here
        # so a hand-edited or stale results file cannot pass CI
        assert obs["overhead_frac"] < 0.05, obs["overhead_frac"]
        assert obs["tick_span_coverage"] >= 0.95, obs["tick_span_coverage"]
        assert obs["trace_events"] > 0 and obs["ticks"] > 0
        lat = obs["latency_s"]
        assert 0 <= lat["mean"] <= lat["max"] and lat["p50"] <= lat["p95"]
        dc = obs["device_counters"]
        for field in ("elapsed_s", "overhead_frac_ungated", "merge_depth"):
            assert field in dc, f"obs.device_counters missing {field}"
        md = dc["merge_depth"]
        # merge depth is measured in trellis steps within the R-deep window;
        # R+1 is the sentinel for "never merged"
        window = obs["depth"] + obs["chunk"]
        assert 1 <= md["p50"] <= md["max"] <= window + 1
    # optional resilience / fault-injection section (--chaos): v6
    res = (payload.get("stream") or {}).get("resilience")
    if res is not None:
        for field in ("sessions", "steps", "chunk", "depth", "backend", "seed",
                      "producer_fault_rate", "injected", "streams_finished",
                      "streams_quarantined", "ticks_dropped", "snapshot",
                      "bits_committed", "timing_faults_bit_exact"):
            assert field in res, f"stream.resilience missing {field}"
        inj = res["injected"]
        assert inj and all(int(v) >= 0 for v in inj.values()), inj
        # the drain must actually have been chaotic: at least one injected
        # fault, and every stream accounted for — finished or quarantined,
        # none lost
        assert sum(int(v) for v in inj.values()) > 0
        assert (res["streams_finished"] + res["streams_quarantined"]
                == res["sessions"])
        # only fatal fault classes may quarantine; timing faults never do
        fatal = (inj.get("producer_exception", 0) + inj.get("corrupt_nan", 0)
                 + inj.get("corrupt_inf", 0) + inj.get("corrupt_shape", 0))
        assert res["streams_quarantined"] <= res["sessions"]
        if fatal == 0:
            assert res["streams_quarantined"] == 0
        # dropped ticks are exactly the injected device-step failures
        assert res["ticks_dropped"] == inj.get("device_step_failure", 0)
        assert res["timing_faults_bit_exact"] is True
        assert res["bits_committed"] > 0
        snap = res["snapshot"]
        for field in ("tick", "streams", "save_s", "restore_s", "bit_exact"):
            assert field in snap, f"stream.resilience.snapshot missing {field}"
        assert snap["bit_exact"] is True
        assert snap["save_s"] >= 0 and snap["restore_s"] >= 0
        assert 0 < snap["streams"] <= res["sessions"]
    # optional SISO turbo section (siso_throughput.py): v5
    turbo = payload.get("turbo")
    if turbo is not None:
        for field in ("workload", "ebn0_db", "ber", "by_iterations",
                      "early_exit"):
            assert field in turbo, f"turbo missing {field}"
        ber = turbo["ber"]
        # the reason the subsystem exists: iterative SISO decode must beat
        # the equivalent-rate conv/Viterbi baseline at the pinned Eb/N0
        assert ber["turbo"] <= ber["viterbi"], ber
        assert 0 <= ber["turbo"] <= 1 and 0 <= ber["viterbi"] <= 1
        assert turbo["by_iterations"], "by_iterations must be non-empty"
        for n, row in turbo["by_iterations"].items():
            assert int(n) >= 1
            assert row["bits_per_s"] > 0 and row["time_s"] > 0
        ee = turbo["early_exit"]
        assert ee["bits_per_s"] > 0
        assert 1 <= ee["iterations_run"] <= turbo["workload"]["iterations"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    size = ap.add_mutually_exclusive_group()
    size.add_argument("--smoke", action="store_true",
                      help="small CPU-container shapes (the CI gate; default)")
    size.add_argument("--full", action="store_true", help="production batch shapes")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--quiet", action="store_true",
                    help="warnings only (the JSON artifact is still written)")
    args = ap.parse_args()
    global log
    log = get_logger("bench.viterbi", quiet=args.quiet)
    payload = run(quick=not args.full, out=args.out)
    check_schema(payload)
    for wl_key in ("paper_workload_k7", "paper_workload_k3"):
        wl = payload[wl_key]
        for name, row in wl["backends"].items():
            log.info(
                f"{wl_key}/{name}",
                time_s=row["time_s"],
                bits_per_s=row["bits_per_s"],
                hbm_bytes_per_bit=row.get("hbm_bytes_per_bit", 0.0),
            )
        log.info(
            f"{wl_key}/speedup",
            packed_vs_fused_hbm_model=wl["speedup"]["fused_packed_vs_fused_hbm_model"],
            packed_vs_sequential_measured=(
                wl["speedup"]["fused_packed_vs_sequential_measured"]
            ),
        )
    log.info("wrote", path=str(args.out), schema=payload["schema"],
             smoke=payload["smoke"], interpret=payload["interpret_mode"])


if __name__ == "__main__":
    main()
