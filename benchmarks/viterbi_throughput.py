"""TPU-scale Viterbi throughput: decoder backends head-to-head on the
paper's workloads, plus the HBM-traffic accounting of the fused pipeline —
the repo's perf baseline, emitted as machine-readable ``BENCH_viterbi.json``.

The headline comparison is the K=7 NASA code (the paper's production-scale
analogue): sequential lax.scan oracle vs the pre-packing fused Pallas
backend vs the packed pipeline (bit-packed survivors + on-device traceback,
optionally with in-kernel branch metrics from raw symbols).  Wall-clock on
the CPU container is interpret-mode (shape parity only); the bytes-moved
model below is exact arithmetic and is the CI proxy for the speedup gate.

HBM bytes per trellis step per stream (float32/int32 = 4 bytes, uint32
survivor words amortized over 32 steps, decoded bit out = 4):

  fused                 4·(M + 2S + 1)    bm in, unpacked survivors out +
                                          re-read by the XLA traceback
  fused_packed          4·(M + S/16 + 1)  bm in, packed survivors out +
                                          re-read by the Pallas traceback
  fused_packed+rx       4·(n + S/16 + 1)  raw symbols in (no bm table)

  PYTHONPATH=src python benchmarks/viterbi_throughput.py [--smoke]
      [--long-blocks] [--out benchmarks/results/BENCH_viterbi.json]

``--long-blocks`` adds the time-parallel section: the K=3 production code on
single long streams, sequential scan vs the tiled decoder at several tile
counts P — wall-clock, bit-exactness (the exact seam regime must never
trade correctness for speed), and the crossover T where tiling first wins.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_viterbi import CODES, DECODE_SPEC
from repro.obs.log import get_logger
from repro.core.viterbi import viterbi_decode
from repro.decode import CodecSpec, plan_decode
from repro.kernels import fused_metric_plan
from repro.kernels.common import PACK_BITS
from repro.kernels.ops import (
    viterbi_decode_fused,
    viterbi_decode_fused_packed,
    viterbi_decode_packed,
    viterbi_decode_tiled_op,
)

log = get_logger("bench.viterbi")

#: v2 added the optional ``stream.by_shards`` per-shard-count scaling table
#: (stream_throughput.py --shards N); v3 adds the optional ``stream.online``
#: steady-state ingestion section (stream_throughput.py --online: sustained
#: bits/s under rate-limited producers, arrival-to-commit latency, queue
#: depths, backpressure counters); v4 adds the optional top-level ``obs``
#: telemetry-acceptance section (stream_throughput.py --telemetry: tracing
#: on/off overhead, tick-phase span coverage, device-counter drain); v5 adds
#: the optional top-level ``turbo`` SISO section (siso_throughput.py: a BER
#: point vs the equivalent-rate Viterbi baseline + decoded bits/s per
#: iteration count); v6 adds the optional ``stream.resilience`` section
#: (stream_throughput.py --chaos: seeded fault-injection drain — injected
#: fault counts by class, survival accounting, snapshot/restore recovery
#: latency, bit-exactness flags); v7 adds the optional top-level
#: ``long_blocks`` section (--long-blocks: sequential vs time-parallel tiled
#: decode on single long K=3 streams — time vs tile count P, per-row
#: bit-exactness, and the crossover T where tiling first beats sequential;
#: speedup-vs-P monotonicity is recorded, not asserted); v8 adds the optional
#: top-level ``analysis`` section (analysis_report.py: repo-rule lint result,
#: jaxpr contract trace of every registered hot path, pragma census, and the
#: --sanitize steady-state guard probe — one user host sync per tick, zero
#: steady recompiles, bit-exact under guards).
BENCH_SCHEMA = "bench_viterbi/v8"
DEFAULT_OUT = Path(__file__).resolve().parent / "results" / "BENCH_viterbi.json"


def _mk_inputs(spec: CodecSpec, info_bits: int, batch: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (batch, info_bits)).astype(jnp.int32)
    coded = spec.encode(bits)
    rx = spec.channel(jax.random.fold_in(key, 1), coded, flip_prob=0.02)
    return bits, rx, spec.branch_metrics(rx)


def _timeit(fn, *args, iters: int = 3):
    """(mean seconds, last output) — the output doubles as the oracle check
    so callers don't pay another full decode for it."""
    out = fn(*args)  # warm (trace + compile)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def hbm_bytes_per_step(code, backend: str) -> float:
    """Hot-path HBM bytes per trellis step per stream (model, see module
    doc).  Survivor words amortize over PACK_BITS steps, read + written."""
    S, M, n = code.n_states, code.n_symbols, code.n_out
    packed_sv = 2 * S * 4.0 / PACK_BITS  # write + traceback re-read
    if backend == "fused":
        return 4.0 * (M + 2 * S + 1)
    if backend == "fused_packed":
        return 4.0 * (M + 1) + packed_sv
    if backend == "fused_packed_received":
        return 4.0 * (n + 1) + packed_sv
    raise KeyError(backend)


def bench_backends(spec: CodecSpec, batch: int, info_bits: int, iters: int) -> Dict:
    """One workload, all hot-path backends: measured bits/s + modeled HBM
    traffic.  ``fused_packed_received`` feeds raw symbols (in-kernel
    metrics); the others consume precomputed bm tables."""
    code = spec.code
    bits, rx, bm = _mk_inputs(spec, info_bits, batch)
    T = bm.shape[1]
    total_bits = batch * T
    plan = fused_metric_plan(code, spec.metric, spec.puncture_array)
    runners = {
        "sequential": (jax.jit(lambda b: viterbi_decode(code, b)[0]), bm),
        "fused": (jax.jit(lambda b: viterbi_decode_fused(code, b)[0]), bm),
        "fused_packed": (jax.jit(lambda b: viterbi_decode_packed(code, b)[0]), bm),
        "fused_packed_received": (
            jax.jit(lambda r: viterbi_decode_fused_packed(plan, r)[0]),
            rx,
        ),
    }
    backends: Dict[str, Dict] = {}
    decoded = {}
    for name, (fn, arg) in runners.items():
        t, out = _timeit(fn, arg, iters=iters)
        decoded[name] = np.asarray(out)
        row = {"time_s": t, "bits_per_s": total_bits / t}
        if name != "sequential":
            bps = hbm_bytes_per_step(code, name)
            row["hbm_bytes_per_step_per_stream"] = bps
            row["hbm_bytes_total"] = bps * total_bits
            row["hbm_bytes_per_bit"] = bps
        backends[name] = row
    # every backend must agree with the oracle before its number counts
    for name in ("fused", "fused_packed", "fused_packed_received"):
        assert (decoded[name] == decoded["sequential"]).all(), (
            f"{name} diverged from the sequential oracle"
        )
    S = code.n_states
    return {
        "workload": {
            "constraint": code.constraint,
            "polys_oct": [oct(g) for g in code.polys],
            "n_states": S,
            "batch": batch,
            "steps": T,
            "metric": spec.metric,
            "decoded_bits": total_bits,
        },
        "backends": backends,
        "survivor_bytes": {
            "unpacked_int32": T * S * batch * 4,
            "packed_uint32": -(-T // PACK_BITS) * S * batch * 4,
            "shrink_x": T / float(-(-T // PACK_BITS)),
        },
        "speedup": {
            "fused_packed_vs_sequential_measured": (
                backends["fused_packed"]["bits_per_s"]
                / backends["sequential"]["bits_per_s"]
            ),
            "fused_packed_vs_fused_measured": (
                backends["fused_packed"]["bits_per_s"]
                / backends["fused"]["bits_per_s"]
            ),
            # exact arithmetic — the CI (interpret-mode) proxy for the gate
            "fused_packed_vs_fused_hbm_model": (
                hbm_bytes_per_step(code, "fused")
                / hbm_bytes_per_step(code, "fused_packed")
            ),
            "fused_packed_received_vs_fused_hbm_model": (
                hbm_bytes_per_step(code, "fused")
                / hbm_bytes_per_step(code, "fused_packed_received")
            ),
        },
    }


#: --long-blocks sweep: single-stream lengths and tile counts.  Smoke keeps
#: the CI job short; full adds the deep point where tiling matters most.
LONG_BLOCK_SWEEP = {"Ts": (2048, 8192), "tile_counts": (4, 16)}
LONG_BLOCK_SWEEP_FULL = {"Ts": (2048, 8192, 32768), "tile_counts": (4, 16, 32)}


def bench_long_blocks(spec: CodecSpec, Ts, tile_counts, iters: int) -> Dict:
    """Single long streams (B=1): the un-tiled packed pipeline walks a
    T-step launch time grid, the tiled decoder a T/P-step one plus seam
    work — measure where the crossover lands and that the exact seam regime
    stays bit-exact while winning.

    The ``sequential`` baseline is viterbi_decode_packed — the SAME kernel
    pipeline with P=1, so the delta is the time-tiling and nothing else (the
    only apples-to-apples wall-clock on an interpret-mode container, where
    Pallas-vs-XLA ratios say nothing about TPU).  The XLA lax.scan oracle is
    recorded alongside as ``xla_scan`` for context and the oracle check."""
    code = spec.code
    by_T: Dict[str, Dict] = {}
    crossover = None
    for T in Ts:
        n_info = T - (code.constraint - 1)  # steps == T after flush
        _, _, bm = _mk_inputs(spec, n_info, 1, seed=7)
        assert bm.shape[1] == T, (bm.shape, T)
        t_scan, out_scan = _timeit(
            jax.jit(lambda b: viterbi_decode(code, b)[0]), bm, iters=iters
        )
        ref = np.asarray(out_scan)
        t_seq, out_seq = _timeit(
            jax.jit(lambda b: viterbi_decode_packed(code, b)[0]), bm,
            iters=iters,
        )
        assert (np.asarray(out_seq) == ref).all(), "packed baseline diverged"
        tiled_rows: Dict[str, Dict] = {}
        for P in tile_counts:
            fn = jax.jit(lambda b, P=P: viterbi_decode_tiled_op(code, b, P)[0])
            t, out = _timeit(fn, bm, iters=iters)
            tiled_rows[str(P)] = {
                "time_s": t,
                "bits_per_s": T / t,
                "bit_exact": bool((np.asarray(out) == ref).all()),
                "speedup_vs_sequential": t_seq / t,
            }
        best = max(tiled_rows, key=lambda p: tiled_rows[p]["speedup_vs_sequential"])
        by_T[str(T)] = {
            "sequential": {"time_s": t_seq, "bits_per_s": T / t_seq,
                           "backend": "fused_packed (un-tiled, P=1)"},
            "xla_scan": {"time_s": t_scan, "bits_per_s": T / t_scan},
            "tiled": tiled_rows,
            "best_tiles": int(best),
            "best_speedup_vs_sequential": (
                tiled_rows[best]["speedup_vs_sequential"]
            ),
        }
        if crossover is None and by_T[str(T)]["best_speedup_vs_sequential"] > 1.0:
            crossover = T
    return {
        "workload": {
            "constraint": code.constraint,
            "n_states": code.n_states,
            "metric": spec.metric,
            "batch": 1,
            "Ts": [int(T) for T in Ts],
            "tile_counts": [int(P) for P in tile_counts],
            "sequential_backend": "fused_packed (un-tiled, P=1)",
        },
        "by_T": by_T,
        # smallest swept T where the best tiled config beats the un-tiled run
        "crossover_T_vs_sequential": crossover,
        "note": ("measured wall-clock vs the un-tiled run of the same packed "
                 "pipeline (interpret-mode off-TPU); speedup monotonicity in "
                 "P is recorded, not asserted"),
    }


def run(quick: bool = True, out: Path = DEFAULT_OUT,
        long_blocks: bool = False) -> Dict:
    """Benchmark + write BENCH_viterbi.json; returns the payload.  ``quick``
    is the CPU-container (--smoke) shape; full mode runs the production
    batch."""
    interpret = jax.default_backend() != "tpu"
    k7 = CodecSpec(code=CODES["k7_nasa"], metric=DECODE_SPEC.metric)
    k3 = DECODE_SPEC
    if quick:
        k7_shape, k3_shape, iters = (8, 90), (32, 126), 2
    else:
        k7_shape, k3_shape, iters = (128, 1018), (1024, 1022), 3
    payload = {
        "schema": BENCH_SCHEMA,
        "generated_by": "benchmarks/viterbi_throughput.py",
        "smoke": quick,
        "interpret_mode": interpret,
        "device": jax.devices()[0].platform,
        "paper_workload_k7": bench_backends(k7, *k7_shape, iters=iters),
        "paper_workload_k3": bench_backends(k3, *k3_shape, iters=iters),
        "planned_backend_short_block": plan_decode(k7, (k7_shape[0], 256)).backend,
        "planned_backend_long_block": plan_decode(k3, (1, 8192)).backend,
    }
    if long_blocks:
        sweep = LONG_BLOCK_SWEEP if quick else LONG_BLOCK_SWEEP_FULL
        payload["long_blocks"] = bench_long_blocks(
            k3, sweep["Ts"], sweep["tile_counts"], iters=2 if quick else 3
        )
    out = Path(out)
    out.parent.mkdir(parents=True, exist_ok=True)
    if out.exists():  # preserve sections merged in by other benchmarks/runs
        try:
            existing = json.loads(out.read_text())
        except (ValueError, OSError):
            existing = {}
        preserved = ["stream", "obs", "turbo", "analysis"]
        if not long_blocks:
            preserved.append("long_blocks")
        for section in preserved:
            if existing.get(section) is not None:
                payload[section] = existing[section]
    out.write_text(json.dumps(payload, indent=1))
    return payload


def check_schema(payload: Dict) -> None:
    """Schema gate used by the CI smoke job (and tests)."""
    assert payload["schema"] == BENCH_SCHEMA
    for wl_key in ("paper_workload_k7", "paper_workload_k3"):
        wl = payload[wl_key]
        for field in ("workload", "backends", "survivor_bytes", "speedup"):
            assert field in wl, f"{wl_key} missing {field}"
        for name in ("sequential", "fused", "fused_packed", "fused_packed_received"):
            assert wl["backends"][name]["bits_per_s"] > 0
        assert wl["survivor_bytes"]["shrink_x"] > 16  # ~32 for T >> 32
        assert wl["speedup"]["fused_packed_vs_fused_hbm_model"] >= 2.0
        assert wl["speedup"]["fused_packed_received_vs_fused_hbm_model"] >= 2.0
    # optional sharded-scheduler scaling table (stream_throughput --shards N)
    by_shards = (payload.get("stream") or {}).get("by_shards")
    if by_shards is not None:
        for n, row in by_shards.items():
            assert row["shards"] == int(n)
            assert row["n_slots"] == row["slots_per_shard"] * row["shards"]
            assert row["bits_per_s"] > 0
        if "1" in by_shards:
            for n, row in by_shards.items():
                if n != "1":
                    assert "scaling_vs_shards1" in row
    # optional online-ingestion section (stream_throughput --online): v3
    online = (payload.get("stream") or {}).get("online")
    if online is not None:
        for field in ("sessions", "steps", "chunk", "depth", "max_buffered",
                      "offered_rows_per_s_per_stream", "bits_per_s",
                      "latency_s", "queue_depth_rows", "ticks"):
            assert field in online, f"stream.online missing {field}"
        assert online["bits_per_s"] > 0
        assert online["bit_exact_vs_offline"] is True
        lat = online["latency_s"]
        assert 0 <= lat["mean"] <= lat["max"] and lat["p50"] <= lat["p95"]
        q = online["queue_depth_rows"]
        # backpressure invariant: no single stream's bounded queue can ever
        # overrun its credit limit (totals are bounded by sessions x limit)
        assert 0 <= q["max_stream"] <= online["max_buffered"]
        assert 0 <= q["mean"] <= q["max"] <= (
            online["sessions"] * online["max_buffered"]
        )
    # optional telemetry-acceptance section (stream_throughput --telemetry): v4
    obs = payload.get("obs")
    if obs is not None:
        for field in ("sessions", "steps", "chunk", "depth", "ticks", "repeats",
                      "elapsed_off_s", "elapsed_on_s", "overhead_frac",
                      "tick_span_coverage", "trace_events", "latency_s",
                      "device_counters", "bit_exact_with_telemetry"):
            assert field in obs, f"obs missing {field}"
        assert obs["bit_exact_with_telemetry"] is True
        # the acceptance gates the benchmark already enforced, re-checked here
        # so a hand-edited or stale results file cannot pass CI
        assert obs["overhead_frac"] < 0.05, obs["overhead_frac"]
        assert obs["tick_span_coverage"] >= 0.95, obs["tick_span_coverage"]
        assert obs["trace_events"] > 0 and obs["ticks"] > 0
        lat = obs["latency_s"]
        assert 0 <= lat["mean"] <= lat["max"] and lat["p50"] <= lat["p95"]
        dc = obs["device_counters"]
        for field in ("elapsed_s", "overhead_frac_ungated", "merge_depth"):
            assert field in dc, f"obs.device_counters missing {field}"
        md = dc["merge_depth"]
        # merge depth is measured in trellis steps within the R-deep window;
        # R+1 is the sentinel for "never merged"
        window = obs["depth"] + obs["chunk"]
        assert 1 <= md["p50"] <= md["max"] <= window + 1
    # optional resilience / fault-injection section (--chaos): v6
    res = (payload.get("stream") or {}).get("resilience")
    if res is not None:
        for field in ("sessions", "steps", "chunk", "depth", "backend", "seed",
                      "producer_fault_rate", "injected", "streams_finished",
                      "streams_quarantined", "ticks_dropped", "snapshot",
                      "bits_committed", "timing_faults_bit_exact"):
            assert field in res, f"stream.resilience missing {field}"
        inj = res["injected"]
        assert inj and all(int(v) >= 0 for v in inj.values()), inj
        # the drain must actually have been chaotic: at least one injected
        # fault, and every stream accounted for — finished or quarantined,
        # none lost
        assert sum(int(v) for v in inj.values()) > 0
        assert (res["streams_finished"] + res["streams_quarantined"]
                == res["sessions"])
        # only fatal fault classes may quarantine; timing faults never do
        fatal = (inj.get("producer_exception", 0) + inj.get("corrupt_nan", 0)
                 + inj.get("corrupt_inf", 0) + inj.get("corrupt_shape", 0))
        assert res["streams_quarantined"] <= res["sessions"]
        if fatal == 0:
            assert res["streams_quarantined"] == 0
        # dropped ticks are exactly the injected device-step failures
        assert res["ticks_dropped"] == inj.get("device_step_failure", 0)
        assert res["timing_faults_bit_exact"] is True
        assert res["bits_committed"] > 0
        snap = res["snapshot"]
        for field in ("tick", "streams", "save_s", "restore_s", "bit_exact"):
            assert field in snap, f"stream.resilience.snapshot missing {field}"
        assert snap["bit_exact"] is True
        assert snap["save_s"] >= 0 and snap["restore_s"] >= 0
        assert 0 < snap["streams"] <= res["sessions"]
    # optional time-parallel tiled section (--long-blocks): v7
    lb = payload.get("long_blocks")
    if lb is not None:
        for field in ("workload", "by_T", "crossover_T_vs_sequential", "note"):
            assert field in lb, f"long_blocks missing {field}"
        assert lb["by_T"], "long_blocks.by_T must be non-empty"
        for T, row in lb["by_T"].items():
            assert int(T) >= 1
            assert row["sequential"]["time_s"] > 0
            if "xla_scan" in row:
                assert row["xla_scan"]["time_s"] > 0
            assert row["tiled"], f"long_blocks.by_T[{T}] has no tiled rows"
            for P, trow in row["tiled"].items():
                assert int(P) >= 1
                assert trow["time_s"] > 0 and trow["bits_per_s"] > 0
                # the exact seam regime may never trade correctness for
                # speed: every recorded tiled row must be bit-exact
                assert trow["bit_exact"] is True, f"tiled P={P} at T={T}"
                assert trow["speedup_vs_sequential"] > 0
                # speedup monotonicity in P is recorded, NOT asserted: it
                # legitimately rolls off past the lane budget
            assert str(row["best_tiles"]) in row["tiled"]
            best = row["tiled"][str(row["best_tiles"])]
            assert abs(row["best_speedup_vs_sequential"]
                       - best["speedup_vs_sequential"]) < 1e-9
        cx = lb["crossover_T_vs_sequential"]
        if cx is not None:
            row = lb["by_T"][str(cx)]
            assert row["best_speedup_vs_sequential"] > 1.0, (
                "crossover recorded at a T where tiling does not win"
            )
            # no smaller swept T already won
            for T, r in lb["by_T"].items():
                if int(T) < int(cx):
                    assert r["best_speedup_vs_sequential"] <= 1.0
    # optional static-analysis section (analysis_report.py): v8
    ana = payload.get("analysis")
    if ana is not None:
        for field in ("lint", "jaxpr", "pragmas", "stream_pragmas"):
            assert field in ana, f"analysis missing {field}"
        lint = ana["lint"]
        assert lint["files"] > 0 and lint["rules"] >= 5
        # the whole point of the section: the repo lints clean
        assert lint["violations"] == 0, lint.get("violation_lines")
        jx = ana["jaxpr"]
        assert jx["violations"] == 0, jx
        # every registered backend must be traced by a contract — a new
        # backend that lands without a hot-path contract fails the gate
        assert jx["backends_traced"] == jx["backends_registered"], jx
        assert jx["contracts"] and len(jx["contracts"]) >= jx["backends_traced"]
        for name, row in jx["contracts"].items():
            assert row["equations"] > 0, f"contract {name} traced nothing"
            assert row["violations"] == 0, f"contract {name} has violations"
        # exactly one sanctioned host sync in the streaming hot path
        assert ana["stream_pragmas"] == {"RPR003": 1}, ana["stream_pragmas"]
        san = ana.get("sanitize")
        if san is not None:
            assert san["ticks"] >= 1
            assert all(s == 1 for s in san["host_syncs_per_tick"]), san
            assert san["steady_recompiles"] == 0, san
            assert san["bit_exact_vs_unguarded"] is True
            assert san["transfer_guard"] == "disallow" and san["debug_nans"]
    # optional SISO turbo section (siso_throughput.py): v5
    turbo = payload.get("turbo")
    if turbo is not None:
        for field in ("workload", "ebn0_db", "ber", "by_iterations",
                      "early_exit"):
            assert field in turbo, f"turbo missing {field}"
        ber = turbo["ber"]
        # the reason the subsystem exists: iterative SISO decode must beat
        # the equivalent-rate conv/Viterbi baseline at the pinned Eb/N0
        assert ber["turbo"] <= ber["viterbi"], ber
        assert 0 <= ber["turbo"] <= 1 and 0 <= ber["viterbi"] <= 1
        assert turbo["by_iterations"], "by_iterations must be non-empty"
        for n, row in turbo["by_iterations"].items():
            assert int(n) >= 1
            assert row["bits_per_s"] > 0 and row["time_s"] > 0
        ee = turbo["early_exit"]
        assert ee["bits_per_s"] > 0
        assert 1 <= ee["iterations_run"] <= turbo["workload"]["iterations"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    size = ap.add_mutually_exclusive_group()
    size.add_argument("--smoke", action="store_true",
                      help="small CPU-container shapes (the CI gate; default)")
    size.add_argument("--full", action="store_true", help="production batch shapes")
    ap.add_argument("--long-blocks", action="store_true",
                    help="add the sequential-vs-tiled long-stream sweep")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--quiet", action="store_true",
                    help="warnings only (the JSON artifact is still written)")
    args = ap.parse_args()
    global log
    log = get_logger("bench.viterbi", quiet=args.quiet)
    payload = run(quick=not args.full, out=args.out,
                  long_blocks=args.long_blocks)
    check_schema(payload)
    for wl_key in ("paper_workload_k7", "paper_workload_k3"):
        wl = payload[wl_key]
        for name, row in wl["backends"].items():
            log.info(
                f"{wl_key}/{name}",
                time_s=row["time_s"],
                bits_per_s=row["bits_per_s"],
                hbm_bytes_per_bit=row.get("hbm_bytes_per_bit", 0.0),
            )
        log.info(
            f"{wl_key}/speedup",
            packed_vs_fused_hbm_model=wl["speedup"]["fused_packed_vs_fused_hbm_model"],
            packed_vs_sequential_measured=(
                wl["speedup"]["fused_packed_vs_sequential_measured"]
            ),
        )
    lb = payload.get("long_blocks")
    if lb is not None:
        for T, row in lb["by_T"].items():
            log.info(
                f"long_blocks/T={T}",
                sequential_s=row["sequential"]["time_s"],
                best_tiles=row["best_tiles"],
                best_speedup=row["best_speedup_vs_sequential"],
            )
        log.info("long_blocks/crossover",
                 T=lb["crossover_T_vs_sequential"])
    log.info("wrote", path=str(args.out), schema=payload["schema"],
             smoke=payload["smoke"], interpret=payload["interpret_mode"])


if __name__ == "__main__":
    main()
