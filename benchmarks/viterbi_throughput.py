"""TPU-scale Viterbi throughput: the paper's workload at production batch
sizes (paper_viterbi config shapes), comparing decoder variants, plus the
roofline math for the fused kernel on the TPU v5e target.

Roofline of the fused ACS step (K=3, batch B lane-resident):
  per step per stream: 4 small matmuls (S×S @ S×B and S×M @ M×B) ≈
  2·S·(S+M)·B·2 flops + (S+M)·B·4 bytes streamed.  With S=4,M=4,B=128-lane
  tiles the kernel is *memory-bound* on the bm stream: bytes/step = (M+S+S)
  ·B·4 ≈ 6 KB vs 16K flops -> AI ≈ 2.7 flop/byte << 240 (v5e ridge) — so
  peak decode rate ≈ HBM_bw / bytes-per-trellis-step; the table reports that
  bound next to the measured (interpret-mode) CPU numbers for shape parity.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.paper_viterbi import ARCH, CODES, DECODE_SPEC
from repro.decode import DecodeContext, get_decoder, plan_decode
from repro.roofline.analysis import HW


def _mk_inputs(spec, info_bits, batch, seed=0):
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (batch, info_bits)).astype(jnp.int32)
    coded = spec.encode(bits)
    rx = spec.channel(jax.random.fold_in(key, 1), coded, flip_prob=0.02)
    return bits, spec.branch_metrics(rx)


def _timeit(fn, *args, iters=3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def tpu_bound_bits_per_s(code, batch) -> float:
    """Memory-roofline bound for the fused kernel on v5e (per chip)."""
    S, M = code.n_states, code.n_symbols
    bytes_per_step_per_stream = (M + 2 * S) * 4.0  # bm in, bp+pm out (f32)
    steps_per_s = HW.hbm_bw / (bytes_per_step_per_stream * batch)
    return steps_per_s * batch  # one info bit per step per stream


def run(quick: bool = True) -> Dict:
    rows: List[Dict] = []
    spec = DECODE_SPEC
    code = spec.code
    ctx = DecodeContext(chunk=64)
    shapes = [s for s in ARCH.shapes if s.batch >= 128] if quick else ARCH.shapes
    for shape in shapes:
        if quick and shape.batch * shape.n_info_bits > 3e6:
            continue  # CPU-container friendly
        bits, bm = _mk_inputs(spec, shape.n_info_bits, shape.batch)
        row = {
            "shape": shape.name, "batch": shape.batch, "bits": shape.n_info_bits,
        }
        total_bits = shape.batch * shape.n_info_bits
        # time the registry backends head-to-head on identical tables
        for backend in ("sequential", "parallel"):
            fn = get_decoder(backend)
            t = _timeit(
                jax.jit(lambda b, fn=fn: fn(spec, b, ctx=ctx).path_metric), bm)
            row[f"{backend}_Mbit_per_s"] = total_bits / t / 1e6
        row["tpu_v5e_roofline_Gbit_per_s"] = (
            tpu_bound_bits_per_s(code, shape.batch) / 1e9)
        row["planned_backend"] = plan_decode(
            spec, bm.shape, ctx=ctx).backend
        rows.append(row)
    # BER sanity at the GSM code, through the fused registry backend
    gsm_spec = dataclasses.replace(spec, code=CODES["k5_gsm"])
    bits, bm = _mk_inputs(gsm_spec, 185, 256)
    res = get_decoder("fused")(gsm_spec, bm, ctx=ctx)
    ber = float((res.info_bits != bits).mean())
    return {"throughput": rows, "gsm_k5_ber_at_2pct_flips": ber,
            "paper_context_bits_per_day_target": 1e15}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
