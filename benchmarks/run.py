"""Benchmark harness entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything quick
  PYTHONPATH=src python -m benchmarks.run --full     # bigger sweeps
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    from benchmarks import fig3_scaling, fig4_trend, roofline_report, tables, viterbi_throughput

    jobs = {
        "tables_3_4_5": tables.run,
        "fig3_scaling": fig3_scaling.run,
        "fig4_trend": fig4_trend.run,
        "viterbi_throughput": lambda: viterbi_throughput.run(quick=not args.full),
        "roofline_report": roofline_report.run,
    }
    if args.only:
        jobs = {k: v for k, v in jobs.items() if args.only in k}

    report = {}
    failed = []
    for name, fn in jobs.items():
        print(f"== {name} ==", flush=True)
        try:
            out = fn()
            report[name] = out
            (RESULTS / f"{name}.json").write_text(
                json.dumps(out, indent=1, default=float))
            if name == "tables_3_4_5":
                print(json.dumps({k: out[k] for k in
                                  ("table3_dlx", "table4_picojava")}, indent=1,
                                 default=float))
            elif name == "roofline_report":
                print(json.dumps({k: v for k, v in out.items() if k != "rows"},
                                 indent=1, default=float))
            else:
                print("ok")
        except Exception as e:
            failed.append(name)
            print(f"FAILED {name}: {e}")
            traceback.print_exc()
    print(f"\n{len(report)}/{len(jobs)} benchmark groups succeeded; "
          f"results in {RESULTS}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
