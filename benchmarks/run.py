"""Benchmark harness entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything quick
  PYTHONPATH=src python -m benchmarks.run --full     # bigger sweeps
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback
from pathlib import Path

from repro.obs.log import get_logger

RESULTS = Path(__file__).resolve().parent / "results"

log = get_logger("bench.run")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--quiet", action="store_true",
                    help="warnings/failures only (JSON artifacts still written)")
    args = ap.parse_args()
    global log
    log = get_logger("bench.run", quiet=args.quiet)
    RESULTS.mkdir(parents=True, exist_ok=True)

    from benchmarks import fig3_scaling, fig4_trend, roofline_report, tables, viterbi_throughput

    jobs = {
        "tables_3_4_5": tables.run,
        "fig3_scaling": fig3_scaling.run,
        "fig4_trend": fig4_trend.run,
        "viterbi_throughput": lambda: viterbi_throughput.run(quick=not args.full),
        "roofline_report": roofline_report.run,
    }
    if args.only:
        jobs = {k: v for k, v in jobs.items() if args.only in k}

    report = {}
    failed = []
    for name, fn in jobs.items():
        log.info(f"== {name} ==")
        try:
            out = fn()
            report[name] = out
            (RESULTS / f"{name}.json").write_text(
                json.dumps(out, indent=1, default=float))
            if name == "tables_3_4_5":
                log.info(json.dumps({k: out[k] for k in
                                     ("table3_dlx", "table4_picojava")}, indent=1,
                                    default=float))
            elif name == "roofline_report":
                log.info(json.dumps({k: v for k, v in out.items() if k != "rows"},
                                    indent=1, default=float))
            else:
                log.info("ok", group=name)
        except Exception as e:
            failed.append(name)
            log.error(f"FAILED {name}: {e}")
            traceback.print_exc()
    log.info("benchmark groups done", succeeded=len(report), total=len(jobs),
             results=str(RESULTS))
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
