"""Streaming vs. block Viterbi throughput — and sharded-scheduler scaling.

Three modes:

* default: drives the continuous-batching StreamScheduler with >= 64
  concurrent decode sessions multiplexed through ONE jitted chunked Pallas
  call per tick — comparing the unpacked ``fused`` hot loop against the
  ``fused_packed`` pipeline (bit-packed survivor ring + on-device traceback,
  device-resident input arena) — and reports sustained decoded bits/s
  against the full-block fused decoder on the same workload, re-checking the
  two correctness gates the streaming path promises (depth >= T bit-exact;
  depth = 5K within 1e-3 BER of the block decoder).

* ``--shards N``: ONE scheduler spanning an N-way ``data`` mesh (the slot
  table, input arena, and survivor ring partitioned per device, shard_map
  tick).  The slot table weak-scales — ``--slots-per-shard`` slots per
  device — so aggregate bits/s measures how throughput grows with the mesh;
  results land in a per-shard-count table (``stream.by_shards``) inside
  ``results/BENCH_viterbi.json`` and the run prints the scaling factor vs
  the recorded ``--shards 1`` row.  On a CPU container the mesh is
  host-platform devices (``--xla_force_host_platform_device_count``, set
  below BEFORE jax initializes — it cannot be applied afterwards); on a real
  TPU slice the same flag-free invocation spans the physical devices.

* ``--online``: true online ingestion under steady-state load — every
  stream is fed by a RATE-LIMITED producer (rows released on a wall clock,
  polled within the stream's backpressure credit) instead of a full table,
  and the run measures what a serving deployment cares about: sustained
  bits/s at the offered rate, per-bit commit latency from symbol ARRIVAL to
  emission (mean/p50/p95), queue-depth statistics from ``load_report()``,
  and how often slots starved.  The decoded bits are asserted identical to
  the same scheduler fed offline (arrival timing must never change the
  decode).  Results land in ``stream.online`` of BENCH_viterbi.json
  (schema v3).

  PYTHONPATH=src python benchmarks/stream_throughput.py [--sessions 64]
      [--steps 512] [--chunk 64] [--flip 0.02] [--backend fused]
  PYTHONPATH=src python benchmarks/stream_throughput.py --smoke --shards 1
  PYTHONPATH=src python benchmarks/stream_throughput.py --smoke --shards 8
  PYTHONPATH=src python benchmarks/stream_throughput.py --smoke --online

Numbers from the CPU container are interpret-mode / host-platform proxies
(shape + scheduling parity only); on a real TPU the same code runs the
compiled kernels.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path


def _force_host_devices() -> None:
    """--shards N needs N devices, and XLA reads the host-platform device
    count once, at first backend init — so peek at argv before importing
    jax (running on a real multi-device platform skips the flag)."""
    n = None
    for i, arg in enumerate(sys.argv):
        if arg == "--shards" and i + 1 < len(sys.argv):
            n = sys.argv[i + 1]
        elif arg.startswith("--shards="):
            n = arg.split("=", 1)[1]
    try:
        n = int(n)
    except (TypeError, ValueError):
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()


_force_host_devices()

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.paper_viterbi import DECODE_SPEC, STREAM  # noqa: E402
from repro.core.viterbi import viterbi_decode  # noqa: E402
from repro.decode import DecodeContext, get_decoder  # noqa: E402
from repro.stream import StreamScheduler, viterbi_decode_windowed  # noqa: E402

RESULTS = Path(__file__).resolve().parent / "results"
BENCH_JSON = RESULTS / "BENCH_viterbi.json"


def make_workload(spec, key, n_streams, info_bits, flip):
    info = jax.random.bernoulli(key, 0.5, (n_streams, info_bits)).astype(np.int32)
    coded = spec.encode(info)
    rx = spec.channel(jax.random.fold_in(key, 1), coded, flip_prob=flip)
    return info, spec.branch_metrics(rx)


def run_scheduler(spec, bm, n_slots, chunk, depth, backend, mesh=None):
    """Drain all streams through one scheduler; returns (elapsed_s, stats,
    results, total_bits).  Submission (arena appends) happens before the
    clock starts: the timed region is the tick loop + flushes."""
    sched = StreamScheduler(
        spec, n_slots=n_slots, chunk=chunk, depth=depth, backend=backend,
        mesh=mesh, mesh_axis=STREAM.mesh_axis,
    )
    for i in range(bm.shape[0]):
        sched.submit(f"s{i}", bm[i])
    t0 = time.perf_counter()
    out = sched.run()
    elapsed = time.perf_counter() - t0
    total_bits = sum(len(b) for b, _ in out.values())
    return elapsed, sched.stats, out, total_bits


def _load_bench() -> dict:
    if BENCH_JSON.exists():
        try:
            return json.loads(BENCH_JSON.read_text())
        except ValueError:
            pass
    return {"schema": "bench_viterbi/v3",
            "generated_by": "benchmarks/stream_throughput.py"}


def run_shard_scaling(args) -> None:
    """One weak-scaled scheduler run on an n-way data mesh; merges a row
    into the per-shard-count table in BENCH_viterbi.json."""
    n = args.shards
    if len(jax.devices()) < n:
        raise SystemExit(
            f"--shards {n} needs {n} devices, found {len(jax.devices())} "
            "(the host-platform flag must be set before jax initializes)"
        )
    spec = DECODE_SPEC
    depth = STREAM.depth(spec.code)
    slots_per_shard = args.slots_per_shard or (8 if args.smoke else STREAM.n_slots)
    steps = args.steps if args.steps else (256 if args.smoke else 512)
    n_slots = STREAM.n_slots_for(n, slots_per_shard)
    backend = args.backend or "scan"  # pure-XLA hot loop: the host-platform
    # proxy then measures scheduling + partitioning, not interpret overhead
    mesh = jax.make_mesh((n,), (STREAM.mesh_axis,))
    key = jax.random.PRNGKey(0)
    info_bits = steps - spec.n_flush
    _, bm = make_workload(spec, key, n_slots, info_bits, args.flip)

    run_scheduler(spec, bm, n_slots, args.chunk, depth, backend, mesh=mesh)  # warm
    elapsed, stats, out, total_bits = run_scheduler(
        spec, bm, n_slots, args.chunk, depth, backend, mesh=mesh
    )
    assert stats.streams_finished == n_slots
    platform = jax.devices()[0].platform
    row = {
        "shards": n,
        "slots_per_shard": slots_per_shard,
        "n_slots": n_slots,
        "sessions": n_slots,
        "steps": steps,
        "chunk": args.chunk,
        "depth": depth,
        "backend": backend,
        "device": platform,
        "host_cores": os.cpu_count(),
        "ticks": stats.ticks,
        "bits_decoded": total_bits,
        "elapsed_s": elapsed,
        "wallclock_bits_per_s": total_bits / elapsed,
    }
    if n > 1 and platform == "cpu":
        # Forced host-platform "devices" time-multiplex the same few cores,
        # so single-controller wall-clock cannot exhibit the concurrency the
        # partitioned program has (the tick carries NO cross-shard
        # communication — each shard's slice runs independently).  The
        # aggregate metric is therefore the device-concurrent proxy: shard
        # count x the MEASURED one-device rate of the identical per-shard
        # slot load (one partition of the same program, same process).  On
        # real multi-chip hardware the wall-clock number itself is the
        # aggregate and this branch is skipped.
        mesh1 = jax.make_mesh((1,), (STREAM.mesh_axis,))
        bm1 = bm[:slots_per_shard]
        run_scheduler(spec, bm1, slots_per_shard, args.chunk, depth, backend,
                      mesh=mesh1)  # warm
        t1, _, _, bits1 = run_scheduler(
            spec, bm1, slots_per_shard, args.chunk, depth, backend, mesh=mesh1
        )
        row["per_device_elapsed_s"] = t1
        row["per_device_bits_per_s"] = bits1 / t1
        # the proxy is linear by construction, so never report above n x the
        # per-device rate (run-to-run jit jitter would otherwise fabricate
        # superlinear scaling)
        row["bits_per_s"] = n * (bits1 / t1)
        row["aggregate_metric"] = "device_concurrent_proxy"
    else:
        row["bits_per_s"] = total_bits / elapsed
        row["aggregate_metric"] = "wallclock"
    print(f"shards={n}: {n_slots} sessions x {steps} steps (backend {backend}) "
          f"in {elapsed:.3f}s wallclock "
          f"-> {row['bits_per_s']:,.0f} bits/s aggregate "
          f"({row['aggregate_metric']})")

    bench = _load_bench()
    stream = bench.setdefault("stream", {})
    table = stream.setdefault("by_shards", {})
    table[str(n)] = row
    base = table.get("1")
    if base:  # (re)derive every row's scaling so invocation order is free
        for k, r in table.items():
            if k == "1":
                continue
            # proxy rows are linear-by-construction: clamp at the shard
            # count so jit jitter between the two one-device measurements
            # can never fabricate superlinear scaling
            raw = r["bits_per_s"] / base["bits_per_s"]
            cap = r["shards"] if r["aggregate_metric"] != "wallclock" else raw
            r["scaling_vs_shards1"] = min(raw, cap)
            r["wallclock_scaling_vs_shards1"] = (
                r["wallclock_bits_per_s"] / base["wallclock_bits_per_s"]
            )
    if base and n > 1:
        print(f"scaling vs --shards 1: {row['scaling_vs_shards1']:.2f}x "
              f"aggregate ({row['aggregate_metric']}); single-controller "
              f"wallclock ratio {row['wallclock_scaling_vs_shards1']:.2f}x")
    RESULTS.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    print(f"merged by_shards[{n}] into {BENCH_JSON}")


def run_online(args) -> None:
    """Steady-state serving measurement: rate-limited producers feed the
    chunk ingestion path; report sustained throughput, arrival-to-commit
    latency, and queue depths; merge a ``stream.online`` section into
    BENCH_viterbi.json (schema v3)."""
    import bisect

    from repro.stream import RateLimitedProducer

    spec = DECODE_SPEC
    depth = STREAM.depth(spec.code)
    sessions = args.sessions or (8 if args.smoke else 32)
    steps = args.steps or (384 if args.smoke else 2048)
    backend = args.backend or ("scan" if args.smoke else "fused_packed")
    chunk = args.chunk
    key = jax.random.PRNGKey(0)
    info_bits = steps - spec.n_flush
    _, bm = make_workload(spec, key, sessions, info_bits, args.flip)
    bm = np.asarray(bm)

    # offered load: each producer releases rows at `rate`; default is sized
    # so the batched tick loop is the bottleneck-free steady state (the
    # interpret-mode CPU proxy is slow — scale to finish in reasonable time)
    sched_probe = StreamScheduler(
        spec, n_slots=sessions, chunk=chunk, depth=depth, backend=backend,
        max_buffered=STREAM.max_buffered,
    )
    for i in range(sessions):  # calibration: offline drain rate of this box
        sched_probe.submit(f"w{i}", bm[i])
    t0 = time.perf_counter()
    sched_probe.run()
    offline_elapsed = time.perf_counter() - t0
    offline_rate = sessions * steps / offline_elapsed / sessions  # rows/s/stream
    rate = args.rate or max(50.0, 0.5 * offline_rate)

    sched = StreamScheduler(
        spec, n_slots=sessions, chunk=chunk, depth=depth, backend=backend,
        max_buffered=STREAM.max_buffered,
    )
    producers = {}
    for i in range(sessions):
        producers[f"s{i}"] = RateLimitedProducer(bm[i], rows_per_s=rate)
        sched.open_stream(f"s{i}", producer=producers[f"s{i}"])

    latencies: list = []
    queue_depths: list = []
    stream_depths: list = []
    committed = {f"s{i}": 0 for i in range(sessions)}
    t0 = time.perf_counter()
    while sched.pending_work():
        emitted = sched.step()
        now = time.perf_counter()
        for sid, bits in emitted.items():
            # latency of the NEWEST committed bit: now - arrival time of the
            # producer chunk that contained its row
            committed[sid] += len(bits)
            arr = producers[sid].arrivals
            j = bisect.bisect_left(arr, (committed[sid],))
            if j < len(arr):
                latencies.append(now - arr[j][1])
        report = sched.load_report()
        queue_depths.append(report["queued_rows_total"])
        stream_depths.append(report["max_stream_queued_rows"])
    elapsed = time.perf_counter() - t0
    total_bits = sum(len(b) for b, _ in sched.results.values())

    # arrival timing must never change the decode: online == offline, bit
    # for bit (the acceptance gate; a clean exit IS the verification)
    for i in range(sessions):
        on_bits, _ = sched.results[f"s{i}"]
        off_bits, _ = sched_probe.results[f"w{i}"]
        assert (on_bits == off_bits).all(), f"online decode diverged on s{i}"

    lat = np.asarray(sorted(latencies)) if latencies else np.zeros((1,))
    row = {
        "sessions": sessions,
        "steps": steps,
        "chunk": chunk,
        "depth": depth,
        "backend": backend,
        "device": jax.devices()[0].platform,
        "max_buffered": STREAM.max_buffered,
        "offered_rows_per_s_per_stream": rate,
        "elapsed_s": elapsed,
        "bits_decoded": total_bits,
        "bits_per_s": total_bits / elapsed,
        "ticks": sched.stats.ticks,
        "starved_slot_ticks": sched.stats.starved_slot_ticks,
        "busy_rejections": sched.stats.busy_rejections,
        "chunks_ingested": sched.stats.chunks_submitted,
        "latency_s": {
            "mean": float(lat.mean()),
            "p50": float(lat[int(0.5 * (len(lat) - 1))]),
            "p95": float(lat[int(0.95 * (len(lat) - 1))]),
            "max": float(lat.max()),
        },
        "queue_depth_rows": {
            "mean": float(np.mean(queue_depths)) if queue_depths else 0.0,
            "max": int(max(queue_depths)) if queue_depths else 0,
            "max_stream": int(max(stream_depths)) if stream_depths else 0,
        },
        "bit_exact_vs_offline": True,  # asserted above
    }
    print(f"online: {sessions} rate-limited streams x {steps} steps "
          f"({rate:,.0f} rows/s/stream offered, backend {backend})")
    print(f"  {total_bits} bits in {elapsed:.3f}s -> {row['bits_per_s']:,.0f} "
          f"bits/s sustained; latency mean {row['latency_s']['mean'] * 1e3:.1f}ms "
          f"p95 {row['latency_s']['p95'] * 1e3:.1f}ms")
    print(f"  queue depth mean {row['queue_depth_rows']['mean']:.0f} / "
          f"max {row['queue_depth_rows']['max']} rows total, deepest stream "
          f"{row['queue_depth_rows']['max_stream']} (bound {STREAM.max_buffered}"
          f"/stream); {row['starved_slot_ticks']} starved slot-ticks over "
          f"{row['ticks']} ticks")
    print("  online decode bit-exact vs offline feed of the same symbols")

    bench = _load_bench()
    bench.setdefault("stream", {})["online"] = row
    RESULTS.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    print(f"merged stream.online into {BENCH_JSON}")


def run_backend_comparison(args) -> None:
    spec = DECODE_SPEC
    code = spec.code
    depth = STREAM.depth(code)
    key = jax.random.PRNGKey(0)
    steps = args.steps or 512
    sessions = args.sessions or STREAM.n_slots
    backend = args.backend or "fused"
    info_bits = steps - spec.n_flush
    info, bm = make_workload(spec, key, sessions, info_bits, args.flip)
    ref_bits, _ = viterbi_decode(code, bm)

    # ---------------- correctness gates ---------------- #
    wide, _ = viterbi_decode_windowed(
        code, bm[:4], depth=steps, chunk=args.chunk, backend="scan"
    )
    exact = bool((np.asarray(wide) == np.asarray(ref_bits[:4])).all())
    trunc, _ = viterbi_decode_windowed(
        code, bm, depth=depth, chunk=args.chunk, backend="scan"
    )
    ber_ref = float((np.asarray(ref_bits)[:, :info_bits] != np.asarray(info)).mean())
    ber_win = float((np.asarray(trunc)[:, :info_bits] != np.asarray(info)).mean())
    print(f"gate 1  depth>=T bit-identical to block decode : {exact}")
    print(f"gate 2  BER block {ber_ref:.2e} vs windowed(D=5K) {ber_win:.2e} "
          f"(|diff| {abs(ber_win - ber_ref):.2e} <= 1e-3: {abs(ber_win - ber_ref) <= 1e-3})")
    assert exact and abs(ber_win - ber_ref) <= 1e-3

    # ---------------- streaming scheduler: requested + packed ---------------- #
    backends = [backend]
    if "fused_packed" not in backends:
        backends.append("fused_packed")
    sched_rows = {}
    for bk in backends:
        run_scheduler(spec, bm, sessions, args.chunk, depth, bk)  # warm
        t_stream, stats, out, total_bits = run_scheduler(
            spec, bm, sessions, args.chunk, depth, bk
        )
        mismatches = sum(
            int((out[f"s{i}"][0] != np.asarray(ref_bits[i])).sum())
            for i in range(sessions)
        )
        sched_rows[bk] = {
            "ticks": stats.ticks,
            "bits_decoded": total_bits,
            "stream_s": t_stream,
            "stream_bits_per_s": total_bits / t_stream,
            "mismatches_vs_block": mismatches,
        }
        print(f"\nscheduler[{bk}]: {sessions} sessions x {steps} "
              f"steps, chunk {args.chunk}, depth {depth}")
        print(f"  {stats.ticks} ticks (one jitted call each), {stats.slot_claims} "
              f"slot claims, {total_bits} bits in {t_stream:.3f}s "
              f"-> {total_bits / t_stream:,.0f} bits/s; "
              f"mismatches vs block: {mismatches}/{total_bits}")

    # ---------------- block baseline ---------------- #
    fused = get_decoder("fused_packed")
    ctx = DecodeContext(chunk=args.chunk)
    dec = jax.jit(lambda t: fused(spec, t, ctx=ctx).bits)
    jax.block_until_ready(dec(bm))  # warm
    t0 = time.perf_counter()
    jax.block_until_ready(dec(bm))
    t_block = time.perf_counter() - t0
    total_bits = sched_rows[backend]["bits_decoded"]
    print(f"\nblock fused_packed decode of the same (B={sessions}, "
          f"T={steps}) workload: {t_block:.3f}s -> "
          f"{total_bits / t_block:,.0f} bits/s")
    t_stream = sched_rows[backend]["stream_s"]
    print(f"streaming/block time ratio: {t_stream / t_block:.2f}x "
          f"(streaming adds the sliding-window traceback per tick but needs "
          f"O(depth+chunk) memory instead of O(T))")

    RESULTS.mkdir(parents=True, exist_ok=True)
    payload = {
        "sessions": sessions, "steps": steps, "chunk": args.chunk,
        "depth": depth, "schedulers": sched_rows,
        "block_s": t_block, "block_bits_per_s": total_bits / t_block,
        "bit_exact_wide_window": exact,
        "ber_block": ber_ref, "ber_windowed": ber_win,
    }
    (RESULTS / "stream_throughput.json").write_text(json.dumps(payload, indent=1))
    print(f"\nwrote {RESULTS / 'stream_throughput.json'}")

    # merge into the shared perf baseline (by_shards / online preserved)
    bench = _load_bench()
    stream = bench.setdefault("stream", {})
    kept = {k: stream[k] for k in ("by_shards", "online") if k in stream}
    stream.clear()
    stream.update(payload)
    stream.update(kept)
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    print(f"merged stream section into {BENCH_JSON}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None,
                    help="trellis steps per stream")
    ap.add_argument("--chunk", type=int, default=STREAM.chunk)
    ap.add_argument("--flip", type=float, default=0.02)
    ap.add_argument("--backend", default=None,
                    choices=("fused", "fused_packed", "scan"))
    ap.add_argument("--shards", type=int, default=0,
                    help="run the sharded-scheduler scaling mode on an N-way "
                         "data mesh (weak-scaled: --slots-per-shard per device)")
    ap.add_argument("--slots-per-shard", type=int, default=None)
    ap.add_argument("--online", action="store_true",
                    help="steady-state ingestion mode: rate-limited chunk "
                         "producers, arrival-to-commit latency, queue depths")
    ap.add_argument("--rate", type=float, default=None,
                    help="--online offered load, rows/s per stream (default: "
                         "half the measured offline drain rate)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI shapes for the scaling/online modes")
    args = ap.parse_args()
    if args.online:
        run_online(args)
    elif args.shards:
        run_shard_scaling(args)
    else:
        run_backend_comparison(args)


if __name__ == "__main__":
    main()
