"""Streaming vs. block Viterbi throughput.

Drives the continuous-batching StreamScheduler with >= 64 concurrent decode
sessions multiplexed through ONE jitted chunked Pallas call per tick —
comparing the unpacked ``fused`` hot loop against the ``fused_packed``
pipeline (bit-packed survivor ring + on-device traceback, device-resident
input arena) — and reports sustained decoded bits/s against the full-block
fused decoder on the same workload.  Also re-checks the two correctness
gates the streaming path promises:

  * depth >= T      -> bit-identical to core.viterbi.viterbi_decode
  * depth  = 5K     -> BER within 1e-3 of the full-block decoder

  PYTHONPATH=src python benchmarks/stream_throughput.py [--sessions 64]
      [--steps 512] [--chunk 64] [--flip 0.02] [--backend fused]

Results land in ``results/stream_throughput.json`` and are merged into the
machine-readable ``results/BENCH_viterbi.json`` perf baseline (``stream``
section).  Numbers from the CPU container are interpret-mode (shape parity
only); on a real TPU the same code runs the compiled kernels.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.paper_viterbi import DECODE_SPEC, STREAM
from repro.core.viterbi import viterbi_decode
from repro.decode import DecodeContext, get_decoder
from repro.stream import StreamScheduler, viterbi_decode_windowed

RESULTS = Path(__file__).resolve().parent / "results"
BENCH_JSON = RESULTS / "BENCH_viterbi.json"


def make_workload(spec, key, n_streams, info_bits, flip):
    info = jax.random.bernoulli(key, 0.5, (n_streams, info_bits)).astype(np.int32)
    coded = spec.encode(info)
    rx = spec.channel(jax.random.fold_in(key, 1), coded, flip_prob=flip)
    return info, spec.branch_metrics(rx)


def run_scheduler(spec, bm, n_slots, chunk, depth, backend):
    """Drain all streams through one scheduler; returns (elapsed_s, stats,
    results, total_bits)."""
    sched = StreamScheduler(
        spec, n_slots=n_slots, chunk=chunk, depth=depth, backend=backend
    )
    for i in range(bm.shape[0]):
        sched.submit(f"s{i}", bm[i])
    t0 = time.perf_counter()
    out = sched.run()
    elapsed = time.perf_counter() - t0
    total_bits = sum(len(b) for b, _ in out.values())
    return elapsed, sched.stats, out, total_bits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=STREAM.n_slots)
    ap.add_argument("--steps", type=int, default=512, help="trellis steps per stream")
    ap.add_argument("--chunk", type=int, default=STREAM.chunk)
    ap.add_argument("--flip", type=float, default=0.02)
    ap.add_argument("--backend", default="fused",
                    choices=("fused", "fused_packed", "scan"))
    args = ap.parse_args()

    spec = DECODE_SPEC
    code = spec.code
    depth = STREAM.depth(code)
    key = jax.random.PRNGKey(0)
    info_bits = args.steps - spec.n_flush
    info, bm = make_workload(spec, key, args.sessions, info_bits, args.flip)
    ref_bits, _ = viterbi_decode(code, bm)

    # ---------------- correctness gates ---------------- #
    wide, _ = viterbi_decode_windowed(
        code, bm[:4], depth=args.steps, chunk=args.chunk, backend="scan"
    )
    exact = bool((np.asarray(wide) == np.asarray(ref_bits[:4])).all())
    trunc, _ = viterbi_decode_windowed(
        code, bm, depth=depth, chunk=args.chunk, backend="scan"
    )
    ber_ref = float((np.asarray(ref_bits)[:, :info_bits] != np.asarray(info)).mean())
    ber_win = float((np.asarray(trunc)[:, :info_bits] != np.asarray(info)).mean())
    print(f"gate 1  depth>=T bit-identical to block decode : {exact}")
    print(f"gate 2  BER block {ber_ref:.2e} vs windowed(D=5K) {ber_win:.2e} "
          f"(|diff| {abs(ber_win - ber_ref):.2e} <= 1e-3: {abs(ber_win - ber_ref) <= 1e-3})")
    assert exact and abs(ber_win - ber_ref) <= 1e-3

    # ---------------- streaming scheduler: requested + packed ---------------- #
    backends = [args.backend]
    if "fused_packed" not in backends:
        backends.append("fused_packed")
    sched_rows = {}
    for backend in backends:
        run_scheduler(spec, bm, args.sessions, args.chunk, depth, backend)  # warm
        t_stream, stats, out, total_bits = run_scheduler(
            spec, bm, args.sessions, args.chunk, depth, backend
        )
        mismatches = sum(
            int((out[f"s{i}"][0] != np.asarray(ref_bits[i])).sum())
            for i in range(args.sessions)
        )
        sched_rows[backend] = {
            "ticks": stats.ticks,
            "bits_decoded": total_bits,
            "stream_s": t_stream,
            "stream_bits_per_s": total_bits / t_stream,
            "mismatches_vs_block": mismatches,
        }
        print(f"\nscheduler[{backend}]: {args.sessions} sessions x {args.steps} "
              f"steps, chunk {args.chunk}, depth {depth}")
        print(f"  {stats.ticks} ticks (one jitted call each), {stats.slot_claims} "
              f"slot claims, {total_bits} bits in {t_stream:.3f}s "
              f"-> {total_bits / t_stream:,.0f} bits/s; "
              f"mismatches vs block: {mismatches}/{total_bits}")

    # ---------------- block baseline ---------------- #
    fused = get_decoder("fused_packed")
    ctx = DecodeContext(chunk=args.chunk)
    dec = jax.jit(lambda t: fused(spec, t, ctx=ctx).bits)
    jax.block_until_ready(dec(bm))  # warm
    t0 = time.perf_counter()
    jax.block_until_ready(dec(bm))
    t_block = time.perf_counter() - t0
    total_bits = sched_rows[args.backend]["bits_decoded"]
    print(f"\nblock fused_packed decode of the same (B={args.sessions}, "
          f"T={args.steps}) workload: {t_block:.3f}s -> "
          f"{total_bits / t_block:,.0f} bits/s")
    t_stream = sched_rows[args.backend]["stream_s"]
    print(f"streaming/block time ratio: {t_stream / t_block:.2f}x "
          f"(streaming adds the sliding-window traceback per tick but needs "
          f"O(depth+chunk) memory instead of O(T))")

    RESULTS.mkdir(parents=True, exist_ok=True)
    payload = {
        "sessions": args.sessions, "steps": args.steps, "chunk": args.chunk,
        "depth": depth, "schedulers": sched_rows,
        "block_s": t_block, "block_bits_per_s": total_bits / t_block,
        "bit_exact_wide_window": exact,
        "ber_block": ber_ref, "ber_windowed": ber_win,
    }
    (RESULTS / "stream_throughput.json").write_text(json.dumps(payload, indent=1))
    print(f"\nwrote {RESULTS / 'stream_throughput.json'}")

    # merge into the shared perf baseline
    bench = json.loads(BENCH_JSON.read_text()) if BENCH_JSON.exists() else {
        "schema": "bench_viterbi/v1", "generated_by": "benchmarks/stream_throughput.py",
    }
    bench["stream"] = payload
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    print(f"merged stream section into {BENCH_JSON}")


if __name__ == "__main__":
    main()
