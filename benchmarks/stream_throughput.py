"""Streaming vs. block Viterbi throughput — and sharded-scheduler scaling.

All results merge into the ONE benchmark artifact,
``results/BENCH_viterbi.json`` (see benchmarks/README.md): each mode owns a
section and preserves the others, so any invocation order converges to the
same file.  No mode writes a private side-car JSON.

Five modes:

* default: drives the continuous-batching StreamScheduler with >= 64
  concurrent decode sessions multiplexed through ONE jitted chunked Pallas
  call per tick — comparing the unpacked ``fused`` hot loop against the
  ``fused_packed`` pipeline (bit-packed survivor ring + on-device traceback,
  device-resident input arena) — and reports sustained decoded bits/s
  against the full-block fused decoder on the same workload, re-checking the
  two correctness gates the streaming path promises (depth >= T bit-exact;
  depth = 5K within 1e-3 BER of the block decoder).

* ``--shards N``: ONE scheduler spanning an N-way ``data`` mesh (the slot
  table, input arena, and survivor ring partitioned per device, shard_map
  tick).  The slot table weak-scales — ``--slots-per-shard`` slots per
  device — so aggregate bits/s measures how throughput grows with the mesh;
  results land in a per-shard-count table (``stream.by_shards``) inside
  ``results/BENCH_viterbi.json`` and the run prints the scaling factor vs
  the recorded ``--shards 1`` row.  On a CPU container the mesh is
  host-platform devices (``--xla_force_host_platform_device_count``, set
  below BEFORE jax initializes — it cannot be applied afterwards); on a real
  TPU slice the same flag-free invocation spans the physical devices.

* ``--online``: true online ingestion under steady-state load — every
  stream is fed by a RATE-LIMITED producer (rows released on a wall clock,
  polled within the stream's backpressure credit) instead of a full table,
  and the run measures what a serving deployment cares about: sustained
  bits/s at the offered rate, per-bit commit latency from symbol ARRIVAL to
  emission (mean/p50/p95), queue-depth statistics from ``load_report()``,
  and how often slots starved.  The decoded bits are asserted identical to
  the same scheduler fed offline (arrival timing must never change the
  decode).  Results land in ``stream.online`` of BENCH_viterbi.json.

* ``--telemetry``: the observability acceptance run — drain the same
  workload with telemetry OFF and ON (tick-phase tracing + metrics +
  latency histograms), assert the decode is bit-identical and the measured
  host-plane overhead stays under 5%, check the tick phase spans cover
  >= 95% of tick wall clock, export ``results/trace.json`` (Perfetto) and
  ``results/trace.jsonl``, run a separate device-counter drain (merge
  depth / starved ticks / renorm accumulated inside the jitted tick; its
  overhead is recorded but NOT gated — the S-walker merge-depth scan is
  comparable to the whole tick on toy interpret-mode shapes), and merge an
  ``obs`` section into BENCH_viterbi.json (schema v4).

* ``--chaos``: the resilience acceptance run — drain the workload under
  seeded fault injection (~``--fault-rate`` producer faults per poll via
  ``ChaosPolicy.producer_mix`` plus simulated device-step failures on the
  tick), assert every stream either finishes bit-exact vs a fault-free
  reference drain or is quarantined with a structured error and a metrics
  trail, then measure snapshot/restore recovery latency mid-drain and
  assert the restored drain is bit-exact.  Results land in
  ``stream.resilience`` of BENCH_viterbi.json (schema v6).

  PYTHONPATH=src python benchmarks/stream_throughput.py [--sessions 64]
      [--steps 512] [--chunk 64] [--flip 0.02] [--backend fused]
  PYTHONPATH=src python benchmarks/stream_throughput.py --smoke --shards 1
  PYTHONPATH=src python benchmarks/stream_throughput.py --smoke --shards 8
  PYTHONPATH=src python benchmarks/stream_throughput.py --smoke --online
  PYTHONPATH=src python benchmarks/stream_throughput.py --smoke --telemetry
  PYTHONPATH=src python benchmarks/stream_throughput.py --smoke --chaos

Numbers from the CPU container are interpret-mode / host-platform proxies
(shape + scheduling parity only); on a real TPU the same code runs the
compiled kernels.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import time
from pathlib import Path


def _force_host_devices() -> None:
    """--shards N needs N devices, and XLA reads the host-platform device
    count once, at first backend init — so peek at argv before importing
    jax (running on a real multi-device platform skips the flag)."""
    n = None
    for i, arg in enumerate(sys.argv):
        if arg == "--shards" and i + 1 < len(sys.argv):
            n = sys.argv[i + 1]
        elif arg.startswith("--shards="):
            n = arg.split("=", 1)[1]
    try:
        n = int(n)
    except (TypeError, ValueError):
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()


_force_host_devices()

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.paper_viterbi import DECODE_SPEC, STREAM  # noqa: E402
from repro.core.viterbi import viterbi_decode  # noqa: E402
from repro.decode import DecodeContext, get_decoder  # noqa: E402
from repro.obs import Telemetry, get_logger, percentile  # noqa: E402
from repro.stream import StreamScheduler, viterbi_decode_windowed  # noqa: E402
from repro.stream.scheduler import TICK_PHASES  # noqa: E402

RESULTS = Path(__file__).resolve().parent / "results"
BENCH_JSON = RESULTS / "BENCH_viterbi.json"

log = get_logger("bench.stream")


def make_workload(spec, key, n_streams, info_bits, flip):
    info = jax.random.bernoulli(key, 0.5, (n_streams, info_bits)).astype(np.int32)
    coded = spec.encode(info)
    rx = spec.channel(jax.random.fold_in(key, 1), coded, flip_prob=flip)
    return info, spec.branch_metrics(rx)


def run_scheduler(spec, bm, n_slots, chunk, depth, backend, mesh=None,
                  telemetry=None):
    """Drain all streams through one scheduler; returns (elapsed_s, sched,
    results, total_bits).  Submission (arena appends) happens before the
    clock starts: the timed region is the tick loop + flushes."""
    sched = StreamScheduler(
        spec, n_slots=n_slots, chunk=chunk, depth=depth, backend=backend,
        mesh=mesh, mesh_axis=STREAM.mesh_axis, telemetry=telemetry,
    )
    for i in range(bm.shape[0]):
        sched.submit(f"s{i}", bm[i])
    t0 = time.perf_counter()
    out = sched.run()
    elapsed = time.perf_counter() - t0
    total_bits = sum(len(b) for b, _ in out.values())
    return elapsed, sched, out, total_bits


def _load_bench() -> dict:
    from viterbi_throughput import BENCH_SCHEMA

    if BENCH_JSON.exists():
        with contextlib.suppress(ValueError):  # corrupt artifact: rebuild
            bench = json.loads(BENCH_JSON.read_text())
            bench["schema"] = BENCH_SCHEMA
            return bench
    return {"schema": BENCH_SCHEMA,
            "generated_by": "benchmarks/stream_throughput.py"}


def run_shard_scaling(args) -> None:
    """One weak-scaled scheduler run on an n-way data mesh; merges a row
    into the per-shard-count table in BENCH_viterbi.json."""
    n = args.shards
    if len(jax.devices()) < n:
        raise SystemExit(
            f"--shards {n} needs {n} devices, found {len(jax.devices())} "
            "(the host-platform flag must be set before jax initializes)"
        )
    spec = DECODE_SPEC
    depth = STREAM.depth(spec.code)
    slots_per_shard = args.slots_per_shard or (8 if args.smoke else STREAM.n_slots)
    steps = args.steps if args.steps else (256 if args.smoke else 512)
    n_slots = STREAM.n_slots_for(n, slots_per_shard)
    backend = args.backend or "scan"  # pure-XLA hot loop: the host-platform
    # proxy then measures scheduling + partitioning, not interpret overhead
    mesh = jax.make_mesh((n,), (STREAM.mesh_axis,))
    key = jax.random.PRNGKey(0)
    info_bits = steps - spec.n_flush
    _, bm = make_workload(spec, key, n_slots, info_bits, args.flip)

    run_scheduler(spec, bm, n_slots, args.chunk, depth, backend, mesh=mesh)  # warm
    elapsed, sched, out, total_bits = run_scheduler(
        spec, bm, n_slots, args.chunk, depth, backend, mesh=mesh
    )
    stats = sched.stats
    assert stats.streams_finished == n_slots
    platform = jax.devices()[0].platform
    row = {
        "shards": n,
        "slots_per_shard": slots_per_shard,
        "n_slots": n_slots,
        "sessions": n_slots,
        "steps": steps,
        "chunk": args.chunk,
        "depth": depth,
        "backend": backend,
        "device": platform,
        "host_cores": os.cpu_count(),
        "ticks": stats.ticks,
        "bits_decoded": total_bits,
        "elapsed_s": elapsed,
        "wallclock_bits_per_s": total_bits / elapsed,
    }
    if n > 1 and platform == "cpu":
        # Forced host-platform "devices" time-multiplex the same few cores,
        # so single-controller wall-clock cannot exhibit the concurrency the
        # partitioned program has (the tick carries NO cross-shard
        # communication — each shard's slice runs independently).  The
        # aggregate metric is therefore the device-concurrent proxy: shard
        # count x the MEASURED one-device rate of the identical per-shard
        # slot load (one partition of the same program, same process).  On
        # real multi-chip hardware the wall-clock number itself is the
        # aggregate and this branch is skipped.
        mesh1 = jax.make_mesh((1,), (STREAM.mesh_axis,))
        bm1 = bm[:slots_per_shard]
        run_scheduler(spec, bm1, slots_per_shard, args.chunk, depth, backend,
                      mesh=mesh1)  # warm
        t1, _, _, bits1 = run_scheduler(
            spec, bm1, slots_per_shard, args.chunk, depth, backend, mesh=mesh1
        )
        row["per_device_elapsed_s"] = t1
        row["per_device_bits_per_s"] = bits1 / t1
        # the proxy is linear by construction, so never report above n x the
        # per-device rate (run-to-run jit jitter would otherwise fabricate
        # superlinear scaling)
        row["bits_per_s"] = n * (bits1 / t1)
        row["aggregate_metric"] = "device_concurrent_proxy"
    else:
        row["bits_per_s"] = total_bits / elapsed
        row["aggregate_metric"] = "wallclock"
    log.info(
        f"shards={n}: {n_slots} sessions x {steps} steps (backend {backend}) "
        f"in {elapsed:.3f}s wallclock "
        f"-> {row['bits_per_s']:,.0f} bits/s aggregate "
        f"({row['aggregate_metric']})"
    )

    bench = _load_bench()
    stream = bench.setdefault("stream", {})
    table = stream.setdefault("by_shards", {})
    table[str(n)] = row
    base = table.get("1")
    if base:  # (re)derive every row's scaling so invocation order is free
        for k, r in table.items():
            if k == "1":
                continue
            # proxy rows are linear-by-construction: clamp at the shard
            # count so jit jitter between the two one-device measurements
            # can never fabricate superlinear scaling
            raw = r["bits_per_s"] / base["bits_per_s"]
            cap = r["shards"] if r["aggregate_metric"] != "wallclock" else raw
            r["scaling_vs_shards1"] = min(raw, cap)
            r["wallclock_scaling_vs_shards1"] = (
                r["wallclock_bits_per_s"] / base["wallclock_bits_per_s"]
            )
    if base and n > 1:
        log.info(
            f"scaling vs --shards 1: {row['scaling_vs_shards1']:.2f}x "
            f"aggregate ({row['aggregate_metric']}); single-controller "
            f"wallclock ratio {row['wallclock_scaling_vs_shards1']:.2f}x"
        )
    RESULTS.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    log.info(f"merged by_shards[{n}] into {BENCH_JSON}")


def run_online(args) -> None:
    """Steady-state serving measurement: rate-limited producers feed the
    chunk ingestion path; report sustained throughput, arrival-to-commit
    latency, and queue depths; merge a ``stream.online`` section into
    BENCH_viterbi.json (schema v3)."""
    import bisect

    from repro.stream import RateLimitedProducer

    spec = DECODE_SPEC
    depth = STREAM.depth(spec.code)
    sessions = args.sessions or (8 if args.smoke else 32)
    steps = args.steps or (384 if args.smoke else 2048)
    backend = args.backend or ("scan" if args.smoke else "fused_packed")
    chunk = args.chunk
    key = jax.random.PRNGKey(0)
    info_bits = steps - spec.n_flush
    _, bm = make_workload(spec, key, sessions, info_bits, args.flip)
    bm = np.asarray(bm)

    # offered load: each producer releases rows at `rate`; default is sized
    # so the batched tick loop is the bottleneck-free steady state (the
    # interpret-mode CPU proxy is slow — scale to finish in reasonable time)
    sched_probe = StreamScheduler(
        spec, n_slots=sessions, chunk=chunk, depth=depth, backend=backend,
        max_buffered=STREAM.max_buffered,
    )
    for i in range(sessions):  # calibration: offline drain rate of this box
        sched_probe.submit(f"w{i}", bm[i])
    t0 = time.perf_counter()
    sched_probe.run()
    offline_elapsed = time.perf_counter() - t0
    offline_rate = sessions * steps / offline_elapsed / sessions  # rows/s/stream
    rate = args.rate or max(50.0, 0.5 * offline_rate)

    sched = StreamScheduler(
        spec, n_slots=sessions, chunk=chunk, depth=depth, backend=backend,
        max_buffered=STREAM.max_buffered,
    )
    producers = {}
    for i in range(sessions):
        producers[f"s{i}"] = RateLimitedProducer(bm[i], rows_per_s=rate)
        sched.open_stream(f"s{i}", producer=producers[f"s{i}"])

    latencies: list = []
    queue_depths: list = []
    stream_depths: list = []
    committed = {f"s{i}": 0 for i in range(sessions)}
    t0 = time.perf_counter()
    while sched.pending_work():
        emitted = sched.step()
        now = time.perf_counter()
        for sid, bits in emitted.items():
            # latency of the NEWEST committed bit: now - arrival time of the
            # producer chunk that contained its row
            committed[sid] += len(bits)
            arr = producers[sid].arrivals
            j = bisect.bisect_left(arr, (committed[sid],))
            if j < len(arr):
                latencies.append(now - arr[j][1])
        report = sched.load_report()
        queue_depths.append(report["queued_rows_total"])
        stream_depths.append(report["max_stream_queued_rows"])
    elapsed = time.perf_counter() - t0
    total_bits = sum(len(b) for b, _ in sched.results.values())

    # arrival timing must never change the decode: online == offline, bit
    # for bit (the acceptance gate; a clean exit IS the verification)
    for i in range(sessions):
        on_bits, _ = sched.results[f"s{i}"]
        off_bits, _ = sched_probe.results[f"w{i}"]
        assert (on_bits == off_bits).all(), f"online decode diverged on s{i}"

    row = {
        "sessions": sessions,
        "steps": steps,
        "chunk": chunk,
        "depth": depth,
        "backend": backend,
        "device": jax.devices()[0].platform,
        "max_buffered": STREAM.max_buffered,
        "offered_rows_per_s_per_stream": rate,
        "elapsed_s": elapsed,
        "bits_decoded": total_bits,
        "bits_per_s": total_bits / elapsed,
        "ticks": sched.stats.ticks,
        "starved_slot_ticks": sched.stats.starved_slot_ticks,
        "busy_rejections": sched.stats.busy_rejections,
        "chunks_ingested": sched.stats.chunks_submitted,
        # per-bit commit latency, summarized through the ONE shared helper
        # (obs.percentile: sorts, nearest-rank, safe on empty)
        "latency_s": {
            "mean": float(np.mean(latencies)) if latencies else 0.0,
            "p50": percentile(latencies, 0.5),
            "p95": percentile(latencies, 0.95),
            "max": float(max(latencies)) if latencies else 0.0,
        },
        # the scheduler's own arrival-to-commit histogram (chunk granularity,
        # tracked on-line inside the commit phase — no benchmark bookkeeping)
        "latency_scheduler_s": sched.load_report()["latency_s"],
        "queue_depth_rows": {
            "mean": float(np.mean(queue_depths)) if queue_depths else 0.0,
            "max": int(max(queue_depths)) if queue_depths else 0,
            "max_stream": int(max(stream_depths)) if stream_depths else 0,
        },
        "bit_exact_vs_offline": True,  # asserted above
    }
    log.info(f"online: {sessions} rate-limited streams x {steps} steps "
             f"({rate:,.0f} rows/s/stream offered, backend {backend})")
    log.info(f"  {total_bits} bits in {elapsed:.3f}s -> {row['bits_per_s']:,.0f} "
             f"bits/s sustained; latency mean {row['latency_s']['mean'] * 1e3:.1f}ms "
             f"p95 {row['latency_s']['p95'] * 1e3:.1f}ms")
    log.info(f"  queue depth mean {row['queue_depth_rows']['mean']:.0f} / "
             f"max {row['queue_depth_rows']['max']} rows total, deepest stream "
             f"{row['queue_depth_rows']['max_stream']} (bound {STREAM.max_buffered}"
             f"/stream); {row['starved_slot_ticks']} starved slot-ticks over "
             f"{row['ticks']} ticks")
    log.info("  online decode bit-exact vs offline feed of the same symbols")

    bench = _load_bench()
    bench.setdefault("stream", {})["online"] = row
    RESULTS.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    log.info(f"merged stream.online into {BENCH_JSON}")


def run_telemetry(args) -> None:
    """Observability acceptance run: telemetry-off vs telemetry-on drains of
    the same workload.  Gates (all asserted here, re-checked by CI):

      * decode bits identical with telemetry on (observation never changes
        the result);
      * host-plane overhead (tracing + metrics + latency histograms)
        < 5% of the telemetry-off drain time, min-of-``--repeats``;
      * tick phase spans cover >= 95% of tick wall clock;
      * the Perfetto export loads (trace.json with a non-empty traceEvents
        list containing tick spans).

    A separate drain with device counters on records merge-depth statistics
    and ITS overhead ungated: the S-walker merge-depth scan is O(R·S) work
    per tick — comparable to the whole tick on the toy interpret-mode CPU
    shapes CI runs, and a deliberate opt-in everywhere.
    """
    spec = DECODE_SPEC
    depth = STREAM.depth(spec.code)
    sessions = args.sessions or (8 if args.smoke else 32)
    steps = args.steps or (384 if args.smoke else 1024)
    backend = args.backend or "scan"
    chunk = args.chunk
    repeats = args.repeats
    key = jax.random.PRNGKey(0)
    info_bits = steps - spec.n_flush
    _, bm = make_workload(spec, key, sessions, info_bits, args.flip)
    bm = np.asarray(bm)

    def drain(make_tel):
        return run_scheduler(
            spec, bm, sessions, chunk, depth, backend,
            telemetry=make_tel() if make_tel else None,
        )

    host_tel = lambda: Telemetry.enabled(device_counters=False)  # noqa: E731
    dev_tel = lambda: Telemetry.enabled(device_counters=True)  # noqa: E731

    # warm every jit variant before any timed drain (the device-counter step
    # is a different traced computation)
    drain(None)
    drain(host_tel)
    drain(dev_tel)

    t_off = min(drain(None)[0] for _ in range(repeats))
    on_runs = [drain(host_tel) for _ in range(repeats)]
    t_on = min(r[0] for r in on_runs)
    _, sched_on, out_on, _ = on_runs[-1]
    _, _, out_off, total_bits = drain(None)

    for i in range(sessions):
        assert (out_on[f"s{i}"][0] == out_off[f"s{i}"][0]).all(), (
            f"telemetry changed the decode of s{i}"
        )

    tracer = sched_on.telemetry.tracer
    coverage = tracer.coverage("tick", TICK_PHASES)
    overhead = (t_on - t_off) / t_off
    n_ticks = sched_on.stats.ticks

    RESULTS.mkdir(parents=True, exist_ok=True)
    trace_path = RESULTS / "trace.json"
    tracer.write_chrome(trace_path)
    tracer.write_jsonl(RESULTS / "trace.jsonl")
    trace = json.loads(trace_path.read_text())
    tick_events = [e for e in trace["traceEvents"] if e.get("name") == "tick"]
    assert tick_events, "trace.json has no tick spans"

    # device-counter drain: overhead recorded, not gated
    t_dev, sched_dev, out_dev, _ = min(
        (drain(dev_tel) for _ in range(repeats)), key=lambda r: r[0]
    )
    for i in range(sessions):
        assert (out_dev[f"s{i}"][0] == out_off[f"s{i}"][0]).all(), (
            f"device counters changed the decode of s{i}"
        )
    depth_hist = sched_dev.telemetry.metrics.histogram("stream_merge_depth")

    row = {
        "sessions": sessions,
        "steps": steps,
        "chunk": chunk,
        "depth": depth,
        "backend": backend,
        "device": jax.devices()[0].platform,
        "repeats": repeats,
        "ticks": n_ticks,
        "elapsed_off_s": t_off,
        "elapsed_on_s": t_on,
        "overhead_frac": overhead,
        "tick_span_coverage": coverage,
        "trace_events": len(trace["traceEvents"]),
        "latency_s": sched_on.load_report()["latency_s"],
        "device_counters": {
            "elapsed_s": t_dev,
            "overhead_frac_ungated": (t_dev - t_off) / t_off,
            "merge_depth": depth_hist.summary(),
        },
        "bit_exact_with_telemetry": True,  # asserted above
    }
    log.info(f"telemetry: {sessions} streams x {steps} steps "
             f"(backend {backend}, min of {repeats})")
    log.info(f"  off {t_off:.3f}s / on {t_on:.3f}s -> overhead "
             f"{overhead * 100:.2f}% (gate < 5%); phase coverage "
             f"{coverage * 100:.2f}% of {n_ticks} ticks (gate >= 95%)")
    log.info(f"  device counters: {t_dev:.3f}s "
             f"({row['device_counters']['overhead_frac_ungated'] * 100:.1f}% "
             f"ungated); retiree merge depth "
             f"p50 {depth_hist.summary()['p50']:.0f} / "
             f"max {depth_hist.summary()['max']:.0f} steps (window {depth})")
    log.info(f"  wrote {trace_path} ({len(trace['traceEvents'])} events) "
             f"+ trace.jsonl; {total_bits} bits bit-exact on all three drains")

    assert coverage >= 0.95, f"tick phase coverage {coverage:.3f} < 0.95"
    assert overhead < 0.05, (
        f"telemetry overhead {overhead * 100:.2f}% exceeds the 5% budget"
    )

    bench = _load_bench()
    bench["obs"] = row
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    log.info(f"merged obs section into {BENCH_JSON}")


def run_chaos(args) -> None:
    """Resilience acceptance run: drain the workload under seeded fault
    injection (``ChaosPolicy.producer_mix`` producer faults + simulated
    device-step failures), verify every stream is accounted for (finished
    bit-exact or quarantined with a structured error), then measure
    snapshot/restore recovery latency on a clean mid-drain scheduler.
    Merges a ``stream.resilience`` section into BENCH_viterbi.json
    (schema v6)."""
    import pickle

    from repro.stream import ChaosPolicy, ChaosProducer, install_tick_faults

    spec = DECODE_SPEC
    depth = STREAM.depth(spec.code)
    sessions = args.sessions or (8 if args.smoke else 32)
    steps = args.steps or (384 if args.smoke else 1024)
    backend = args.backend or "scan"
    chunk = args.chunk
    seed = args.seed
    rate = args.fault_rate
    key = jax.random.PRNGKey(0)
    info_bits = steps - spec.n_flush
    _, bm = make_workload(spec, key, sessions, info_bits, args.flip)
    bm = np.asarray(bm)

    # fault-free reference drain: the bit-exactness oracle
    _, _, ref, _ = run_scheduler(spec, bm, sessions, chunk, depth, backend)

    # ---- chaotic drain: producer faults + injected device-step failures ----
    sched = StreamScheduler(
        spec, n_slots=sessions, chunk=chunk, depth=depth, backend=backend,
        max_buffered=STREAM.max_buffered,
    )
    policy = ChaosPolicy.producer_mix(rate, seed=seed)
    tick_injector = install_tick_faults(
        sched, ChaosPolicy(seed=seed, device_step_failure=rate / 2)
    )
    def _chunked(table):
        # bind the table now: a bare genexp in the loop would close over the
        # loop variable and feed every stream the LAST table
        return (table[j:j + chunk] for j in range(0, len(table), chunk))

    producers = {}
    for i in range(sessions):
        sid = f"s{i}"
        producers[sid] = ChaosProducer(
            _chunked(bm[i]), policy, stream_id=sid,
            metrics=sched.telemetry.metrics,
        )
        sched.open_stream(sid, producer=producers[sid],
                          max_buffered=STREAM.max_buffered)

    t0 = time.perf_counter()
    guard = 0
    while sched.pending_work():
        sched.step()
        guard += 1
        assert guard < 200_000, "chaotic drain failed to converge"
    elapsed = time.perf_counter() - t0

    injected: dict = dict(tick_injector.injected)
    for p in producers.values():
        for cls, n in p.injected.items():
            injected[cls] = injected.get(cls, 0) + n
    quarantined = sorted(sched.errors)
    survivors = [f"s{i}" for i in range(sessions) if f"s{i}" not in sched.errors]
    # timing faults (stall, drip, dropped ticks) must never change the
    # decode: every non-quarantined stream is bit-identical to the
    # fault-free drain
    for sid in survivors:
        assert (sched.results[sid][0] == ref[sid][0]).all(), (
            f"chaos changed the decode of surviving stream {sid}"
        )
    metrics = sched.metrics_text()
    for cls, n in injected.items():
        assert f"chaos_{cls}_total {n}" in metrics, (
            f"injected {cls} not visible in metrics_text()"
        )
    bits_committed = sum(len(b) for b, _ in sched.results.values())

    # ---- snapshot/restore recovery latency on a clean mid-drain state ----
    snap_sched = StreamScheduler(
        spec, n_slots=sessions, chunk=chunk, depth=depth, backend=backend,
    )
    for i in range(sessions):
        snap_sched.submit(f"s{i}", bm[i])
    snap_tick = max(1, (steps // chunk) // 2)
    for _ in range(snap_tick):
        snap_sched.step()
    t0 = time.perf_counter()
    blob = pickle.dumps(snap_sched.snapshot())
    save_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    restored = StreamScheduler.restore(pickle.loads(blob))
    restore_s = time.perf_counter() - t0
    out = restored.run()
    snap_exact = all(
        (out[f"s{i}"][0] == ref[f"s{i}"][0]).all() for i in range(sessions)
    )
    assert snap_exact, "restore diverged from the uninterrupted drain"

    row = {
        "sessions": sessions,
        "steps": steps,
        "chunk": chunk,
        "depth": depth,
        "backend": backend,
        "device": jax.devices()[0].platform,
        "seed": seed,
        "producer_fault_rate": rate,
        "elapsed_s": elapsed,
        "injected": injected,
        "streams_finished": len(survivors),
        "streams_quarantined": len(quarantined),
        "quarantine_reasons": {
            sid: sched.errors[sid].reason for sid in quarantined
        },
        "ticks": sched.stats.ticks,
        "ticks_dropped": sched.stats.tick_device_failures,
        "bits_committed": bits_committed,
        "timing_faults_bit_exact": True,  # asserted above
        "snapshot": {
            "tick": snap_tick,
            "streams": len(out),
            "bytes": len(blob),
            "save_s": save_s,
            "restore_s": restore_s,
            "bit_exact": bool(snap_exact),
        },
    }
    n_inj = sum(injected.values())
    log.info(f"chaos: {sessions} streams x {steps} steps (backend {backend}, "
             f"fault rate {rate}, seed {seed})")
    log.info(f"  {n_inj} faults injected {injected}; "
             f"{len(survivors)} streams finished bit-exact, "
             f"{len(quarantined)} quarantined "
             f"({row['quarantine_reasons']}); "
             f"{row['ticks_dropped']} ticks dropped and retried")
    log.info(f"  {bits_committed} bits committed in {elapsed:.3f}s; snapshot "
             f"at tick {snap_tick}: save {save_s * 1e3:.1f}ms / restore "
             f"{restore_s * 1e3:.1f}ms ({len(blob)} bytes), restored drain "
             f"bit-exact")

    bench = _load_bench()
    bench.setdefault("stream", {})["resilience"] = row
    RESULTS.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    log.info(f"merged stream.resilience into {BENCH_JSON}")


def run_backend_comparison(args) -> None:
    spec = DECODE_SPEC
    code = spec.code
    depth = STREAM.depth(code)
    key = jax.random.PRNGKey(0)
    steps = args.steps or 512
    sessions = args.sessions or STREAM.n_slots
    backend = args.backend or "fused"
    info_bits = steps - spec.n_flush
    info, bm = make_workload(spec, key, sessions, info_bits, args.flip)
    ref_bits, _ = viterbi_decode(code, bm)

    # ---------------- correctness gates ---------------- #
    wide, _ = viterbi_decode_windowed(
        code, bm[:4], depth=steps, chunk=args.chunk, backend="scan"
    )
    exact = bool((np.asarray(wide) == np.asarray(ref_bits[:4])).all())
    trunc, _ = viterbi_decode_windowed(
        code, bm, depth=depth, chunk=args.chunk, backend="scan"
    )
    ber_ref = float((np.asarray(ref_bits)[:, :info_bits] != np.asarray(info)).mean())
    ber_win = float((np.asarray(trunc)[:, :info_bits] != np.asarray(info)).mean())
    log.info(f"gate 1  depth>=T bit-identical to block decode : {exact}")
    log.info(
        f"gate 2  BER block {ber_ref:.2e} vs windowed(D=5K) {ber_win:.2e} "
        f"(|diff| {abs(ber_win - ber_ref):.2e} <= 1e-3: {abs(ber_win - ber_ref) <= 1e-3})"
    )
    assert exact and abs(ber_win - ber_ref) <= 1e-3

    # ---------------- streaming scheduler: requested + packed ---------------- #
    backends = [backend]
    if "fused_packed" not in backends:
        backends.append("fused_packed")
    sched_rows = {}
    for bk in backends:
        run_scheduler(spec, bm, sessions, args.chunk, depth, bk)  # warm
        t_stream, sched_bk, out, total_bits = run_scheduler(
            spec, bm, sessions, args.chunk, depth, bk
        )
        stats = sched_bk.stats
        mismatches = sum(
            int((out[f"s{i}"][0] != np.asarray(ref_bits[i])).sum())
            for i in range(sessions)
        )
        sched_rows[bk] = {
            "ticks": stats.ticks,
            "bits_decoded": total_bits,
            "stream_s": t_stream,
            "stream_bits_per_s": total_bits / t_stream,
            "mismatches_vs_block": mismatches,
        }
        log.info(f"scheduler[{bk}]: {sessions} sessions x {steps} "
                 f"steps, chunk {args.chunk}, depth {depth}")
        log.info(f"  {stats.ticks} ticks (one jitted call each), {stats.slot_claims} "
                 f"slot claims, {total_bits} bits in {t_stream:.3f}s "
                 f"-> {total_bits / t_stream:,.0f} bits/s; "
                 f"mismatches vs block: {mismatches}/{total_bits}")

    # ---------------- block baseline ---------------- #
    fused = get_decoder("fused_packed")
    ctx = DecodeContext(chunk=args.chunk)
    dec = jax.jit(lambda t: fused(spec, t, ctx=ctx).bits)
    jax.block_until_ready(dec(bm))  # warm
    t0 = time.perf_counter()
    jax.block_until_ready(dec(bm))
    t_block = time.perf_counter() - t0
    total_bits = sched_rows[backend]["bits_decoded"]
    log.info(f"block fused_packed decode of the same (B={sessions}, "
             f"T={steps}) workload: {t_block:.3f}s -> "
             f"{total_bits / t_block:,.0f} bits/s")
    t_stream = sched_rows[backend]["stream_s"]
    log.info(f"streaming/block time ratio: {t_stream / t_block:.2f}x "
             f"(streaming adds the sliding-window traceback per tick but needs "
             f"O(depth+chunk) memory instead of O(T))")

    # ONE results file: merge into the shared perf baseline, preserving the
    # sections owned by the other modes (see benchmarks/README.md)
    RESULTS.mkdir(parents=True, exist_ok=True)
    payload = {
        "sessions": sessions, "steps": steps, "chunk": args.chunk,
        "depth": depth, "schedulers": sched_rows,
        "block_s": t_block, "block_bits_per_s": total_bits / t_block,
        "bit_exact_wide_window": exact,
        "ber_block": ber_ref, "ber_windowed": ber_win,
    }
    bench = _load_bench()
    stream = bench.setdefault("stream", {})
    kept = {k: stream[k] for k in ("by_shards", "online", "resilience")
            if k in stream}
    stream.clear()
    stream.update(payload)
    stream.update(kept)
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    log.info(f"merged stream section into {BENCH_JSON}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None,
                    help="trellis steps per stream")
    ap.add_argument("--chunk", type=int, default=STREAM.chunk)
    ap.add_argument("--flip", type=float, default=0.02)
    ap.add_argument("--backend", default=None,
                    choices=("fused", "fused_packed", "scan"))
    ap.add_argument("--shards", type=int, default=0,
                    help="run the sharded-scheduler scaling mode on an N-way "
                         "data mesh (weak-scaled: --slots-per-shard per device)")
    ap.add_argument("--slots-per-shard", type=int, default=None)
    ap.add_argument("--online", action="store_true",
                    help="steady-state ingestion mode: rate-limited chunk "
                         "producers, arrival-to-commit latency, queue depths")
    ap.add_argument("--rate", type=float, default=None,
                    help="--online offered load, rows/s per stream (default: "
                         "half the measured offline drain rate)")
    ap.add_argument("--telemetry", action="store_true",
                    help="observability acceptance mode: telemetry on/off "
                         "overhead, phase-span coverage, Perfetto export")
    ap.add_argument("--repeats", type=int, default=3,
                    help="--telemetry timing repeats (min is reported)")
    ap.add_argument("--chaos", action="store_true",
                    help="resilience acceptance mode: seeded fault-injection "
                         "drain + snapshot/restore recovery latency")
    ap.add_argument("--fault-rate", type=float, default=0.1,
                    help="--chaos producer fault probability per poll "
                         "(split across the producer_mix classes)")
    ap.add_argument("--seed", type=int, default=0,
                    help="--chaos injection seed (same seed, same faults)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI shapes for the scaling/online modes")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress stdout reporting (warnings still print); "
                         "the JSON artifact is the output")
    args = ap.parse_args()
    get_logger("bench.stream", quiet=args.quiet)  # reconfigure module logger
    if args.chaos:
        run_chaos(args)
    elif args.telemetry:
        run_telemetry(args)
    elif args.online:
        run_online(args)
    elif args.shards:
        run_shard_scaling(args)
    else:
        run_backend_comparison(args)


if __name__ == "__main__":
    main()
