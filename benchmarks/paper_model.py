"""The paper's instruction/cycle-count model, reproduced exactly.

Tables III/IV (DLX on CPUSim, PicoJava II on MIC-1):
  total_microinstructions = (M.I + A.I) * calls          ["F.I = I x 4" row:
  total_time_cycles       = total_microinstructions * 4   the fetch column
                                                           equals A.I]
Table V (NIOS II f/s/e):
  total_cycles = cycles_per_call * calls

calls(coded_bits) — §V: the trellis-expansion function is called once per
*active state* per step; the frontier grows 1, 2, 4, 4, ... for the 4-state
K=3 code, giving 19 calls for 12 coded bits and 2·bits − 5 in general.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.trellis import CODE_K3_STD, ConvCode, paper_expansion_calls


@dataclasses.dataclass(frozen=True)
class MicrocodedImpl:
    """A DLX/PicoJava-style implementation (microinstruction counting)."""

    name: str
    assembly_instructions: int  # A.I
    microinstructions: int  # M.I per call
    cycles_per_microinstruction: int = 4

    def total_mi(self, calls: int) -> int:
        return (self.microinstructions + self.assembly_instructions) * calls

    def total_cycles(self, calls: int) -> int:
        return self.total_mi(calls) * self.cycles_per_microinstruction


@dataclasses.dataclass(frozen=True)
class NiosImpl:
    """A NIOS II-style implementation (direct cycle counting)."""

    name: str
    cycles_per_call: int

    def total_cycles(self, calls: int) -> int:
        return self.cycles_per_call * calls


# ----------------------------- paper constants ----------------------------- #

DLX_ASSEMBLY = MicrocodedImpl("DLX trellis assembly fn", 63, 277)
DLX_TEXPAND = MicrocodedImpl("DLX Texpand", 1, 100)

PICOJAVA_ASSEMBLY = MicrocodedImpl("PicoJava II trellis assembly fn", 41, 255)
PICOJAVA_TEXPAND = MicrocodedImpl("PicoJava II Texpand", 1, 102)

NIOS = {
    "f": (NiosImpl("NIOS II/f A.L.T.F", 59), NiosImpl("NIOS II/f C.I", 28)),
    "s": (NiosImpl("NIOS II/s A.L.T.F", 59), NiosImpl("NIOS II/s C.I", 35)),
    "e": (NiosImpl("NIOS II/e A.L.T.F", 264), NiosImpl("NIOS II/e C.I", 151)),
}

PAPER_BITS = 12  # the tables' operating point
PAPER_CALLS = 19


def improvement_pct(base_cycles: float, fast_cycles: float) -> float:
    """The paper's '%age Improvement' = (base - fast) / fast * 100."""
    return (base_cycles - fast_cycles) / fast_cycles * 100.0


def calls_for_bits(coded_bits: int, code: ConvCode = CODE_K3_STD) -> int:
    return paper_expansion_calls(coded_bits, code)


def table3() -> Dict[str, float]:
    calls = PAPER_CALLS
    return {
        "assembly_total_mi": DLX_ASSEMBLY.total_mi(calls),
        "assembly_total_cycles": DLX_ASSEMBLY.total_cycles(calls),
        "texpand_total_mi": DLX_TEXPAND.total_mi(calls),
        "texpand_total_cycles": DLX_TEXPAND.total_cycles(calls),
        "improvement_pct": improvement_pct(
            DLX_ASSEMBLY.total_cycles(calls), DLX_TEXPAND.total_cycles(calls)),
        "speedup": DLX_ASSEMBLY.total_cycles(calls) / DLX_TEXPAND.total_cycles(calls),
    }


def table4() -> Dict[str, float]:
    calls = PAPER_CALLS
    return {
        "assembly_total_mi": PICOJAVA_ASSEMBLY.total_mi(calls),
        "assembly_total_cycles": PICOJAVA_ASSEMBLY.total_cycles(calls),
        "texpand_total_mi": PICOJAVA_TEXPAND.total_mi(calls),
        "texpand_total_cycles": PICOJAVA_TEXPAND.total_cycles(calls),
        "improvement_pct": improvement_pct(
            PICOJAVA_ASSEMBLY.total_cycles(calls),
            PICOJAVA_TEXPAND.total_cycles(calls)),
        "speedup": PICOJAVA_ASSEMBLY.total_cycles(calls)
        / PICOJAVA_TEXPAND.total_cycles(calls),
    }


def table5() -> Dict[str, Dict[str, float]]:
    out = {}
    for ver, (base, ci) in NIOS.items():
        out[ver] = {
            "assembly_total_cycles": base.total_cycles(PAPER_CALLS),
            "ci_total_cycles": ci.total_cycles(PAPER_CALLS),
            "improvement_pct": improvement_pct(
                base.total_cycles(PAPER_CALLS), ci.total_cycles(PAPER_CALLS)),
        }
    return out


# The paper's published numbers, for assertion in benchmarks and tests.
PAPER_TABLE3 = {"assembly_total_mi": 6460, "assembly_total_cycles": 25840,
                "texpand_total_mi": 1919, "texpand_total_cycles": 7676,
                "improvement_pct": 236}
PAPER_TABLE4 = {"assembly_total_mi": 5624, "assembly_total_cycles": 22496,
                "texpand_total_mi": 1957, "texpand_total_cycles": 7828,
                "improvement_pct": 187}
PAPER_TABLE5 = {"f": {"assembly_total_cycles": 1121, "ci_total_cycles": 532,
                      "improvement_pct": 110.7},
                "s": {"assembly_total_cycles": 1121, "ci_total_cycles": 665,
                      "improvement_pct": 68.5},
                "e": {"assembly_total_cycles": 5016, "ci_total_cycles": 2869,
                      "improvement_pct": 74.8}}
