"""SISO turbo decoder benchmark: one BER point against the equivalent-rate
Viterbi baseline + decoded bits/s per iteration count, merged as the
``turbo`` section of the ONE benchmark artifact, BENCH_viterbi.json
(schema bench_viterbi/v5).

Workload: the golden-gate pair from tests/test_golden_ber.py — a rate-1/3
LTE-constituent turbo code (K=4 RSC, N=512 QPP interleaver) against the
rate-1/3 K=7 (133,171,165) soft-decision Viterbi code, both at
Eb/N0 = 1.0 dB.  The BER comparison is the acceptance gate (iterative
SISO must beat the one-shot Viterbi baseline there); the per-iteration
throughput rows show what each extra BCJR sweep costs.

Numbers from the CPU container are interpret-mode proxies (shape parity
only); on a real TPU the same code runs the compiled kernels.

  PYTHONPATH=src python benchmarks/siso_throughput.py [--smoke]
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trellis import ConvCode
from repro.decode import CodecSpec, decode
from repro.obs.log import get_logger
from repro.siso import QPPInterleaver, RSC_K4_LTE, TurboSpec, turbo_decode

BENCH_JSON = Path(__file__).resolve().parent / "results" / "BENCH_viterbi.json"
log = get_logger("bench.siso")

EBN0_DB = 1.0
RATE = 1.0 / 3.0
TURBO_SPEC = TurboSpec(code=RSC_K4_LTE, interleaver=QPPInterleaver(512, 31, 64))
CONV_SPEC = CodecSpec(
    code=ConvCode(7, (0o133, 0o171, 0o165)), metric="soft", terminated=False
)


def _load_bench() -> dict:
    from viterbi_throughput import BENCH_SCHEMA

    if BENCH_JSON.exists():
        with contextlib.suppress(ValueError):  # corrupt artifact: rebuild
            bench = json.loads(BENCH_JSON.read_text())
            bench["schema"] = BENCH_SCHEMA
            return bench
    return {"schema": BENCH_SCHEMA,
            "generated_by": "benchmarks/siso_throughput.py"}


def _timed_turbo(spec, llrs, *, iterations, early_exit, repeats):
    """(mean seconds, result) with a warm-up decode excluded from timing."""
    result = turbo_decode(spec, llrs, iterations=iterations,
                          early_exit=early_exit)
    jax.block_until_ready(result.llr)
    t0 = time.perf_counter()
    for _ in range(repeats):
        result = turbo_decode(spec, llrs, iterations=iterations,
                              early_exit=early_exit)
    jax.block_until_ready(result.llr)
    return (time.perf_counter() - t0) / repeats, result


def run(quick: bool = True) -> dict:
    batch, n_keys, repeats = (8, 2, 1) if quick else (64, 8, 3)
    tspec, cspec = TURBO_SPEC, CONV_SPEC
    snr_db = EBN0_DB + 10 * np.log10(RATE)
    rng = np.random.default_rng(2026)
    bits = jnp.asarray(rng.integers(0, 2, size=(batch, tspec.block_len)),
                       jnp.int32)
    tcoded = tspec.encode(bits)
    ccoded = cspec.encode(bits)

    # --- BER point: turbo vs equivalent-rate Viterbi at Eb/N0 = 1 dB ------- #
    t_errs = c_errs = total = 0
    for k in range(n_keys):
        key = jax.random.PRNGKey(500 + k)
        k1, k2 = jax.random.split(key)
        rx_t = tspec.channel(k1, tcoded, snr_db=snr_db)
        res_t = turbo_decode(tspec, tspec.channel_llrs(rx_t, snr_db=snr_db))
        t_errs += int(jnp.sum(res_t.bits != bits))
        rx_c = cspec.channel(k2, ccoded, snr_db=snr_db)
        res_c = decode(cspec, rx_c)
        c_errs += int(jnp.sum(res_c.info_bits != bits))
        total += bits.size
    ber_turbo, ber_viterbi = t_errs / total, c_errs / total

    # --- throughput per iteration count ------------------------------------ #
    rx = tspec.channel(jax.random.PRNGKey(900), tcoded, snr_db=snr_db)
    llrs = tspec.channel_llrs(rx, snr_db=snr_db)
    decoded_bits = batch * tspec.block_len
    by_iterations = {}
    for n_iter in (1, 2, tspec.iterations):
        t, _ = _timed_turbo(tspec, llrs, iterations=n_iter, early_exit=False,
                            repeats=repeats)
        by_iterations[str(n_iter)] = {
            "time_s": t, "bits_per_s": decoded_bits / t,
        }
    t_ee, res_ee = _timed_turbo(tspec, llrs, iterations=None, early_exit=True,
                                repeats=repeats)
    section = {
        "workload": {
            "constituent_constraint": tspec.code.constraint,
            "constituent_fb_oct": oct(tspec.code.feedback),
            "constituent_fwd_oct": [oct(g) for g in tspec.code.forward],
            "interleaver": repr(tspec.interleaver),
            "block_len": tspec.block_len,
            "batch": batch,
            "rate": RATE,
            "iterations": tspec.iterations,
            "extrinsic_scale": tspec.extrinsic_scale,
            "noise_keys": n_keys,
            "viterbi_baseline": cspec.describe(),
        },
        "ebn0_db": EBN0_DB,
        "ber": {"turbo": ber_turbo, "viterbi": ber_viterbi},
        "by_iterations": by_iterations,
        "early_exit": {
            "time_s": t_ee,
            "bits_per_s": decoded_bits / t_ee,
            "iterations_run": int(res_ee.iterations_run),
            "converged_frac": float(jnp.mean(res_ee.converged.astype(
                jnp.float32))),
        },
    }
    return section


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CPU-container shapes (the CI gate; default)")
    ap.add_argument("--full", action="store_true", help="production shapes")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    global log
    log = get_logger("bench.siso", quiet=args.quiet)
    section = run(quick=not args.full)
    bench = _load_bench()
    bench["turbo"] = section
    BENCH_JSON.parent.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    ber = section["ber"]
    log.info("turbo vs viterbi BER @ Eb/N0=1.0dB",
             turbo=ber["turbo"], viterbi=ber["viterbi"])
    for n, row in section["by_iterations"].items():
        log.info(f"turbo x{n} iterations", bits_per_s=row["bits_per_s"])
    ee = section["early_exit"]
    log.info("turbo early-exit", bits_per_s=ee["bits_per_s"],
             iterations_run=ee["iterations_run"])
    log.info(f"merged turbo section into {BENCH_JSON}")
    assert ber["turbo"] <= ber["viterbi"], (
        f"turbo BER {ber['turbo']} did not beat viterbi {ber['viterbi']}"
    )


if __name__ == "__main__":
    main()
