"""Static-analysis acceptance report -> ``analysis`` section of
``results/BENCH_viterbi.json`` (schema v8).

Records, as CI-gated data rather than prose:

  * the repo-rule lint result over ``src/`` (files, violations — must be 0),
  * the jaxpr contract trace of EVERY registered hot path (equations
    walked, violations — must be 0, backend coverage must equal the
    registry),
  * the pragma census (total and the stream-scope count, which must be
    exactly the one sanctioned host sync),
  * with ``--sanitize``: a steady-state scheduler probe run under the full
    :func:`repro.analysis.sanitized` bundle — transfer guard + debug-NaNs +
    counters — asserting exactly one user host sync per tick, zero
    steady-state recompiles, and bit-exact output vs an unguarded run.

Exit status is non-zero on any violation, so the CI job fails loudly even
if nobody reads the JSON.
"""
import argparse
import json
import sys
import time
from pathlib import Path

HERE = Path(__file__).resolve().parent
REPO = HERE.parent
sys.path.insert(0, str(REPO / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.analysis import (  # noqa: E402
    check_hot_paths,
    count_pragmas,
    lint_paths,
    sanitized,
)
from repro.analysis.repo_lint import RULES  # noqa: E402
from repro.core import CODE_K3_STD, bsc, encode, hard_branch_metrics  # noqa: E402
from repro.decode import list_decoders  # noqa: E402
from repro.obs import get_logger  # noqa: E402
from repro.stream import StreamScheduler  # noqa: E402

RESULTS = HERE / "results"
BENCH_JSON = RESULTS / "BENCH_viterbi.json"
SRC = REPO / "src"

log = get_logger("bench.analysis")

SANITIZE_TICKS = 4


def _lint_block() -> dict:
    violations, n_files = lint_paths([SRC])
    return {
        "files": n_files,
        "rules": len(RULES),
        "violations": len(violations),
        "violation_lines": [str(v) for v in violations[:20]],
    }


def _contracts_block() -> dict:
    report = check_hot_paths()
    contracts = {
        name: {
            "backend": entry["backend"],
            "equations": entry["equations"],
            "violations": len(entry["violations"]),
        }
        for name, entry in sorted(report.items())
    }
    return {
        "contracts": contracts,
        "backends_registered": len(list_decoders()),
        "backends_traced": len({e["backend"] for e in report.values()}),
        "violations": sum(len(e["violations"]) for e in report.values()),
    }


def _scheduler_outputs(streams, guarded: bool) -> tuple:
    """Drain the probe workload; when guarded, steady ticks run under the
    full sanitizer and the per-tick counters are recorded."""
    sched = StreamScheduler(
        CODE_K3_STD, n_slots=2, chunk=16, depth=30, backend="scan"
    )
    if not guarded:
        for sid, bm in streams.items():
            sched.submit(sid, bm)
        return sched.run(), None
    per_tick = []
    with sanitized() as rep:
        with rep.allow_transfers():  # admission + warm-up: control plane
            for sid, bm in streams.items():
                sched.submit(sid, bm)
            sched.step()
        base = rep.snapshot()
        t0 = time.perf_counter()
        for _ in range(SANITIZE_TICKS):
            tick = rep.snapshot()
            sched.step()
            per_tick.append(rep.host_syncs - tick.host_syncs)
        elapsed = time.perf_counter() - t0
        steady_recompiles = rep.recompiles - base.recompiles
        with rep.allow_transfers():  # drain: slot finishing is control plane
            out = sched.run()
    return out, {
        "ticks": SANITIZE_TICKS,
        "host_syncs_per_tick": per_tick,
        "steady_recompiles": steady_recompiles,
        "guarded_tick_s": elapsed / SANITIZE_TICKS,
        "transfer_guard": rep.transfer_guard,
        "debug_nans": rep.debug_nans,
    }


def _sanitize_block() -> dict:
    key = jax.random.PRNGKey(0)
    bits = jax.random.bernoulli(key, 0.5, (2, 158)).astype(np.int32)
    coded = encode(CODE_K3_STD, bits, terminate=True)
    rx = bsc(jax.random.fold_in(key, 1), coded, 0.04)
    bm = hard_branch_metrics(CODE_K3_STD, rx)
    streams = {f"s{i}": bm[i] for i in range(2)}
    plain, _ = _scheduler_outputs(streams, guarded=False)
    guarded, stats = _scheduler_outputs(streams, guarded=True)
    bit_exact = all(
        np.array_equal(plain[sid][0], guarded[sid][0]) for sid in streams
    )
    stats["bit_exact_vs_unguarded"] = bool(bit_exact)
    return stats


def build_section(sanitize: bool) -> dict:
    section = {
        "lint": _lint_block(),
        "jaxpr": _contracts_block(),
        "pragmas": count_pragmas([SRC]),
        "stream_pragmas": count_pragmas([SRC / "repro" / "stream"]),
    }
    if sanitize:
        section["sanitize"] = _sanitize_block()
    return section


def _violation_count(section: dict) -> int:
    n = section["lint"]["violations"] + section["jaxpr"]["violations"]
    if section["jaxpr"]["backends_traced"] != section["jaxpr"]["backends_registered"]:
        n += 1
    san = section.get("sanitize")
    if san is not None:
        if any(s != 1 for s in san["host_syncs_per_tick"]):
            n += 1
        if san["steady_recompiles"] != 0 or not san["bit_exact_vs_unguarded"]:
            n += 1
    return n


def _merge(section: dict) -> None:
    from viterbi_throughput import BENCH_SCHEMA

    if BENCH_JSON.exists():
        try:
            bench = json.loads(BENCH_JSON.read_text())
        except ValueError:
            bench = {}
    else:
        bench = {}
    bench.setdefault("generated_by", "benchmarks/analysis_report.py")
    bench["schema"] = BENCH_SCHEMA
    bench["analysis"] = section
    RESULTS.mkdir(parents=True, exist_ok=True)
    BENCH_JSON.write_text(json.dumps(bench, indent=1))
    log.info(f"merged analysis into {BENCH_JSON}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--sanitize", action="store_true",
        help="also run the steady-state scheduler probe under the runtime "
             "sanitizer bundle (transfer guard + debug-NaNs + counters)",
    )
    ap.add_argument(
        "--no-merge", action="store_true",
        help="report only; do not touch results/BENCH_viterbi.json",
    )
    args = ap.parse_args()
    section = build_section(sanitize=args.sanitize)
    for line in section["lint"]["violation_lines"]:
        log.warning(line)
    jx = section["jaxpr"]
    log.info(
        "analysis",
        files=section["lint"]["files"],
        lint_violations=section["lint"]["violations"],
        hot_paths=len(jx["contracts"]),
        backends=f"{jx['backends_traced']}/{jx['backends_registered']}",
        contract_violations=jx["violations"],
        stream_pragmas=sum(section["stream_pragmas"].values()),
    )
    san = section.get("sanitize")
    if san is not None:
        log.info(
            "sanitize",
            host_syncs_per_tick=",".join(map(str, san["host_syncs_per_tick"])),
            steady_recompiles=san["steady_recompiles"],
            bit_exact=san["bit_exact_vs_unguarded"],
            guarded_tick_s=san["guarded_tick_s"],
        )
    if not args.no_merge:
        _merge(section)
    return 1 if _violation_count(section) else 0


if __name__ == "__main__":
    sys.exit(main())
