"""Assemble the EXPERIMENTS.md §Roofline table from the dry-run records.

Reads benchmarks/results/dryrun/*.json (written by launch/dryrun.py),
computes the three roofline terms per (arch × shape) on the single-pod mesh,
flags the dominant term, and emits both a JSON report and a markdown table.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

RESULTS = Path(__file__).resolve().parent / "results"


def load_cells(mesh: str = "single", tag: str = "") -> List[Dict]:
    cells = []
    for f in sorted((RESULTS / "dryrun").glob(f"*--{mesh}{tag}.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def one_row(cell: Dict) -> Dict:
    from repro.roofline.analysis import HW, roofline_report

    if cell["status"] != "ok":
        return {"arch": cell["arch"], "shape": cell["shape"],
                "status": cell["status"], "reason": cell.get("reason", "")}
    terms = roofline_report(cell)
    mem = cell["memory_analysis"]
    fits = (mem["temp_size_in_bytes"] + mem["argument_size_in_bytes"]) \
        < HW.hbm_bytes
    return {
        "arch": cell["arch"], "shape": cell["shape"], "status": "ok",
        "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"], "dominant": terms["dominant"],
        "bound_s": terms["bound_s"],
        "mfu_bound": terms["mfu_bound"],
        "useful_ratio": terms["useful_ratio"],
        "temp_gib": mem["temp_size_in_bytes"] / 2 ** 30,
        "args_gib": mem["argument_size_in_bytes"] / 2 ** 30,
        "fits_hbm": fits,
    }


def markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | MFU-bound | useful | fits |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']}: {r.get('reason','')[:40]} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant'].replace('_s','')}** | {r['mfu_bound']:.2f} | "
            f"{r['useful_ratio']:.2f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} ({r['temp_gib']+r['args_gib']:.1f}G) |")
    return "\n".join(lines)


def run(tag: str = "") -> Dict:
    cells = load_cells("single", tag)
    rows = [one_row(c) for c in cells]
    md = markdown(rows)
    (RESULTS / f"roofline{tag or ''}.md").write_text(md + "\n")
    multi = load_cells("multi", tag)
    multi_ok = sum(1 for c in multi if c["status"] == "ok")
    multi_skip = sum(1 for c in multi if c["status"] == "skipped")
    summary = {
        "n_single": len(cells),
        "n_single_ok": sum(1 for r in rows if r["status"] == "ok"),
        "n_single_skipped": sum(1 for r in rows if r["status"] == "skipped"),
        "n_multi_ok": multi_ok,
        "n_multi_skipped": multi_skip,
        "n_fit": sum(1 for r in rows if r.get("fits_hbm")),
        "dominant_histogram": _hist(rows),
        "rows": rows,
    }
    (RESULTS / f"roofline{tag or ''}.json").write_text(
        json.dumps(summary, indent=1, default=float))
    return summary


def _hist(rows):
    h = {}
    for r in rows:
        if r["status"] == "ok":
            h[r["dominant"]] = h.get(r["dominant"], 0) + 1
    return h


if __name__ == "__main__":
    from repro.obs.log import get_logger

    log = get_logger("bench.roofline")
    out = run()
    log.info(json.dumps({k: v for k, v in out.items() if k != "rows"}, indent=1))
    log.info((RESULTS / "roofline.md").read_text())
