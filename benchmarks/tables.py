"""Tables III/IV/V + the TPU analogue measurement.

Each paper table is reproduced from the cycle model (asserting the published
numbers) and paired with the TPU-native analogue of the same comparison:

  paper: trellis assembly function (many instructions/call)
     vs  Texpand custom instruction (1 instruction/call)
  here:  unfused ACS (explicit per-transition add/compare/select HLO ops)
     vs  fused Pallas ACS kernel (1 pallas_call op)

The analogue is measured two ways on this CPU container:
  - structural: jaxpr op counts of one ACS step (the 'instruction count')
  - wall time:  batched decode throughput, unfused vs fused (interpret mode
    understates the fused kernel on real TPU; the structural counts and the
    roofline report carry the hardware claim)
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax
import jax.numpy as jnp

from benchmarks import paper_model as pm
from repro.core import CODE_K3_STD, bsc, encode, hard_branch_metrics
from repro.core.acs import acs_step, acs_step_unfused
from repro.core.viterbi import viterbi_decode
from repro.kernels.ops import viterbi_decode_fused


def _assert_close(got: Dict, want: Dict, tol=1.0):
    for k, v in want.items():
        g = got[k]
        assert abs(g - v) <= tol, (k, g, v)


def jaxpr_op_count(fn, *args) -> int:
    jaxpr = jax.make_jaxpr(fn)(*args)
    return sum(1 for _ in jaxpr.jaxpr.eqns)


def acs_op_counts() -> Dict[str, int]:
    code = CODE_K3_STD
    pm_ = jnp.zeros((8, code.n_states))
    bm = jnp.zeros((8, code.n_symbols))
    unfused = jaxpr_op_count(lambda p, b: acs_step_unfused(code, p, b), pm_, bm)
    fused_ref = jaxpr_op_count(lambda p, b: acs_step(code, p, b), pm_, bm)
    # the Pallas kernel is ONE op at the jaxpr level — the custom instruction
    from repro.kernels.ops import texpand_op

    fused_kernel = jaxpr_op_count(
        lambda p, b: texpand_op(code, p, b), pm_, bm)
    return {"unfused_ops": unfused, "fused_ref_ops": fused_ref,
            "fused_kernel_ops": fused_kernel}


def _bench(fn, *args, iters=3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def tpu_analogue(batch=512, info_bits=64, seed=0) -> Dict[str, float]:
    code = CODE_K3_STD
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (batch, info_bits)).astype(jnp.int32)
    coded = encode(code, bits, terminate=True)
    rx = bsc(jax.random.fold_in(key, 1), coded, 0.02)
    bm = hard_branch_metrics(code, rx)

    @jax.jit
    def dec_unfused(bm):
        B, T, M = bm.shape
        pm0 = jnp.full((B, code.n_states), 1e30).at[:, 0].set(0.0)

        def step(pmv, bm_t):
            return acs_step_unfused(code, pmv, bm_t)

        pmv, bps = jax.lax.scan(step, pm0, bm.swapaxes(0, 1))
        return pmv

    @jax.jit
    def dec_fused_ref(bm):
        return viterbi_decode(code, bm)[1]

    def dec_fused_kernel(bm):
        return viterbi_decode_fused(code, bm)[1]

    t_unfused = _bench(dec_unfused, bm)
    t_ref = _bench(dec_fused_ref, bm)
    t_kernel = _bench(dec_fused_kernel, bm)
    return {
        "batch": batch, "info_bits": info_bits,
        "t_unfused_ms": t_unfused * 1e3,
        "t_fused_ref_ms": t_ref * 1e3,
        "t_fused_kernel_interpret_ms": t_kernel * 1e3,
        "speedup_ref_vs_unfused": t_unfused / t_ref,
    }


def run() -> Dict:
    t3, t4, t5 = pm.table3(), pm.table4(), pm.table5()
    _assert_close(t3, pm.PAPER_TABLE3)
    _assert_close(t4, pm.PAPER_TABLE4)
    for v in ("f", "s", "e"):
        _assert_close(t5[v], pm.PAPER_TABLE5[v])
    ops = acs_op_counts()
    ana = tpu_analogue()
    report = {
        "table3_dlx": {**t3, "matches_paper": True},
        "table4_picojava": {**t4, "matches_paper": True},
        "table5_nios": {**t5, "matches_paper": True},
        "tpu_analogue_op_counts": ops,
        "tpu_analogue_walltime": ana,
    }
    return report


if __name__ == "__main__":
    from repro.obs.log import get_logger

    get_logger("bench.tables").info(json.dumps(run(), indent=1))
