"""Fig. 4 — the performance-improvement trend across processors, plus our
TPU-analogue column: the improvement ratio of fused over unfused decode."""
from __future__ import annotations

import json
from typing import Dict

from benchmarks import paper_model as pm


def run() -> Dict:
    calls = pm.PAPER_CALLS
    rows = {
        "DLX": pm.improvement_pct(
            pm.DLX_ASSEMBLY.total_cycles(calls), pm.DLX_TEXPAND.total_cycles(calls)),
        "PicoJava II": pm.improvement_pct(
            pm.PICOJAVA_ASSEMBLY.total_cycles(calls),
            pm.PICOJAVA_TEXPAND.total_cycles(calls)),
        "NIOS II/f": pm.improvement_pct(
            pm.NIOS["f"][0].total_cycles(calls), pm.NIOS["f"][1].total_cycles(calls)),
        "NIOS II/s": pm.improvement_pct(
            pm.NIOS["s"][0].total_cycles(calls), pm.NIOS["s"][1].total_cycles(calls)),
        "NIOS II/e": pm.improvement_pct(
            pm.NIOS["e"][0].total_cycles(calls), pm.NIOS["e"][1].total_cycles(calls)),
    }
    # ours: HLO-op-count improvement of the fused kernel vs the unfused loop
    from benchmarks.tables import acs_op_counts

    ops = acs_op_counts()
    rows["TPU analogue (op count)"] = (
        (ops["unfused_ops"] - ops["fused_kernel_ops"]) / ops["fused_kernel_ops"] * 100)
    return {"improvement_pct": rows}


if __name__ == "__main__":
    from repro.obs.log import get_logger

    get_logger("bench.fig4").info(json.dumps(run(), indent=1))
