"""CodecSpec — the immutable description of *what* is being decoded.

One spec bundles everything the scattered ``code``/``soft``/puncture plumbing
used to carry separately:

  * the convolutional code (trellis),
  * the branch-metric kind (``hard`` Hamming vs ``soft`` correlation),
  * an optional puncturing pattern (punctured positions are erasures —
    they contribute 0 to every branch metric, so the same decoders handle
    every punctured rate),
  * whether the trellis is terminated (encoder flushed back to state 0).

A CodecSpec is hashable (puncture patterns are normalized to nested tuples),
so it can key jit caches and registry plans the same way ConvCode does.
Every decode backend consumes ``(spec, bm_tables)`` — the spec owns the
encode side, the channel simulation helpers, and the branch-metric
construction so hard/soft/punctured workloads share one code path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import (
    awgn,
    bpsk_modulate,
    bsc,
    hard_branch_metrics,
    soft_branch_metrics,
)
from repro.core.encoder import encode
from repro.core.puncture import pattern_mask, punctured_hard_metrics
from repro.core.trellis import CODE_K3_STD, ConvCode
from repro.siso.rsc import RSCCode

METRIC_KINDS = ("hard", "soft")


def spec_family(spec) -> str:
    """Code family of any decode spec: "conv" (feed-forward convolutional),
    "rsc" (recursive systematic, SISO-decoded), or "turbo" (TurboSpec).
    The planner and capability validation dispatch on this, so adding a
    family stays a registry/property change, not an if/elif edit."""
    return getattr(spec, "family", "conv")


@dataclasses.dataclass(frozen=True)
class CodecSpec:
    """Immutable codec description shared by every decode backend.

    Attributes:
      code: the convolutional code (trellis structure + polynomials) — a
        feed-forward ConvCode (Viterbi-decoded) or a recursive systematic
        RSCCode (SISO/BCJR-decoded; the planner routes by ``family``).
      metric: ``"hard"`` (Hamming distance over received bits) or ``"soft"``
        (correlation metric over real channel outputs / LLRs).
      puncture: optional (n_out, period) 0/1 pattern (see core/puncture.py);
        accepted as any array-like, stored as nested tuples so the spec stays
        hashable.
      terminated: the encoder appends K-1 flush bits so the trellis ends in
        state 0 (the paper's convention).  ``False`` decodes open-ended
        blocks: the traceback starts from the best frontier state instead.
    """

    code: Union[ConvCode, RSCCode] = CODE_K3_STD
    metric: str = "hard"
    puncture: Optional[Tuple[Tuple[int, ...], ...]] = None
    terminated: bool = True

    def __post_init__(self):
        if self.metric not in METRIC_KINDS:
            raise ValueError(f"metric must be one of {METRIC_KINDS}, got {self.metric!r}")
        if self.puncture is not None:
            pat = np.asarray(self.puncture)
            if pat.ndim != 2 or pat.shape[0] != self.code.n_out:
                raise ValueError(
                    f"puncture pattern must be (n_out={self.code.n_out}, period), "
                    f"got shape {pat.shape}"
                )
            object.__setattr__(
                self, "puncture", tuple(tuple(int(x) for x in row) for row in pat)
            )

    @classmethod
    def of(cls, obj: Union["CodecSpec", ConvCode]) -> "CodecSpec":
        """Normalize a bare ConvCode (legacy call sites) into a CodecSpec."""
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, ConvCode):
            return cls(code=obj)
        raise TypeError(f"expected CodecSpec or ConvCode, got {type(obj).__name__}")

    # ------------------------------ derived ------------------------------ #

    @property
    def family(self) -> str:
        return "rsc" if isinstance(self.code, RSCCode) else "conv"

    @property
    def table_width(self) -> int:
        """Last-axis width of the per-step decoder input: the (B, T, M)
        bm-table for Viterbi families, per-bit LLR columns for SISO."""
        return self.code.n_out if self.family == "rsc" else self.code.n_symbols

    @property
    def soft(self) -> bool:
        return self.metric == "soft"

    @property
    def puncture_array(self) -> Optional[np.ndarray]:
        return None if self.puncture is None else np.asarray(self.puncture)

    @property
    def n_flush(self) -> int:
        """Flush bits appended by the encoder (0 for open-ended streams)."""
        return self.code.constraint - 1 if self.terminated else 0

    def n_steps(self, n_info_bits: int) -> int:
        """Trellis steps for a block of ``n_info_bits`` information bits."""
        return n_info_bits + self.n_flush

    # ---------------------------- encode side ---------------------------- #

    def encode(self, bits: jnp.ndarray) -> jnp.ndarray:
        """(..., T) info bits -> (..., T + n_flush, n_out) coded bits, with
        punctured positions zeroed (not transmitted)."""
        if self.family == "rsc":
            coded = self.code.encode(bits, terminate=self.terminated)
        else:
            coded = encode(self.code, bits, terminate=self.terminated)
        if self.puncture is not None:
            mask = pattern_mask(self.code, coded.shape[-2], self.puncture_array)
            coded = (coded * mask).astype(coded.dtype)
        return coded

    def channel(self, key: jax.Array, coded_bits: jnp.ndarray, *,
                flip_prob: float = 0.0, snr_db: Optional[float] = None) -> jnp.ndarray:
        """Simulate the channel this spec's metric kind expects: BSC for hard
        decisions, BPSK + AWGN for soft.  A knob for the other metric kind is
        rejected rather than silently ignored."""
        if self.soft:
            if snr_db is None:
                raise ValueError("soft metric channel needs snr_db")
            if flip_prob:
                raise ValueError("flip_prob is a hard-decision knob; soft channels use snr_db")
            return awgn(key, bpsk_modulate(coded_bits), snr_db)
        if snr_db is not None:
            raise ValueError("snr_db is a soft-decision knob; hard channels use flip_prob")
        return bsc(key, coded_bits, flip_prob)

    # ---------------------------- decode side ---------------------------- #

    def branch_metrics(self, received: jnp.ndarray) -> jnp.ndarray:
        """(..., T, n_out) received bits / channel values -> the per-step
        decoder input.

        Viterbi (conv) family: (..., T, M) branch-metric tables (to be
        minimized).  SISO (rsc) family: (..., T, n_out) per-coded-bit LLRs
        with the convention ``lambda = log P(0)/P(1)`` — soft channel values
        pass through (max-log is scale-invariant), hard bits map to +-1.
        Punctured positions are erasures (contribute 0) in both.
        """
        if self.family == "rsc":
            r = received.astype(jnp.float32)
            lam = r if self.soft else 1.0 - 2.0 * r
            if self.puncture is not None:
                mask = pattern_mask(self.code, received.shape[-2], self.puncture_array)
                lam = lam * mask
            return lam
        if self.soft:
            if self.puncture is not None:
                mask = pattern_mask(self.code, received.shape[-2], self.puncture_array)
                received = received * mask  # erased positions correlate to 0
            return soft_branch_metrics(self.code, received)
        if self.puncture is not None:
            return punctured_hard_metrics(self.code, received, self.puncture_array)
        return hard_branch_metrics(self.code, received)

    def strip_flush(self, bits: jnp.ndarray) -> jnp.ndarray:
        """Drop the trailing flush bits from a (..., T) decode (no-op for
        unterminated specs)."""
        return bits[..., : bits.shape[-1] - self.n_flush] if self.n_flush else bits

    def describe(self) -> str:
        punct = "unpunctured" if self.puncture is None else f"punctured{self.puncture}"
        term = "terminated" if self.terminated else "open"
        if self.family == "rsc":
            head = (
                f"RSCCode(K={self.code.constraint}, fb={oct(self.code.feedback)}, "
                f"fwd={tuple(oct(g) for g in self.code.forward)}"
            )
        else:
            head = (
                f"ConvCode(K={self.code.constraint}, "
                f"polys={tuple(oct(g) for g in self.code.polys)}"
            )
        return f"{head}, S={self.code.n_states}) {self.metric}/{punct}/{term}"
