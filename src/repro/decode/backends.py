"""The decode backends, re-homed onto the DecoderRegistry.

Each backend is a thin adapter from the normalized
``decode(spec, bm_tables, *, ctx) -> DecodeResult`` signature onto the
existing implementation it wraps; the implementations themselves stay where
they live (core/, kernels/, parallel/, stream/).  Importing this module
(which ``repro.decode`` does) populates the registry.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.viterbi import viterbi_decode, viterbi_decode_parallel
from repro.decode.registry import BackendCapabilities, register_decoder
from repro.decode.request import DecodeContext, DecodeResult
from repro.decode.spec import CodecSpec

#: Largest trellis the VMEM-resident fused scan keeps on-chip comfortably:
#: path metrics + the (S, S) select matmuls stay within one VMEM working set
#: up to K=13 (4096 states); beyond that the planner falls back to the
#: lax.scan decoders, which spill to HBM gracefully.
FUSED_MAX_STATES = 4096


def _result(spec: CodecSpec, bits: jnp.ndarray, metric: jnp.ndarray, **diag) -> DecodeResult:
    return DecodeResult(bits=bits, path_metric=metric, spec=spec, diagnostics=diag)


@register_decoder(
    "fused",
    capabilities=BackendCapabilities(family="conv", max_states=FUSED_MAX_STATES),
)
def decode_fused(spec: CodecSpec, bm_tables, *, ctx: DecodeContext) -> DecodeResult:
    """Pallas Texpand scan with VMEM-resident path metrics (the paper's
    custom instruction) — the default block decoder."""
    from repro.kernels.ops import viterbi_decode_fused

    bits, metric = viterbi_decode_fused(
        spec.code, bm_tables, terminated=spec.terminated, interpret=ctx.interpret
    )
    return _result(spec, bits, metric, backend="fused")


def _fused_packed_from_received(
    spec: CodecSpec, received, *, ctx: DecodeContext
) -> DecodeResult:
    """Raw-symbol entry: branch metrics computed in-kernel — the (B, T, M)
    bm table never exists, in HBM or on the host."""
    from repro.kernels.metrics import fused_metric_plan
    from repro.kernels.ops import viterbi_decode_fused_packed

    plan = fused_metric_plan(spec.code, spec.metric, spec.puncture_array)
    bits, metric = viterbi_decode_fused_packed(
        plan, received, terminated=spec.terminated, interpret=ctx.interpret
    )
    return _result(spec, bits, metric, backend="fused_packed", metrics="in-kernel")


@register_decoder(
    "fused_packed",
    capabilities=BackendCapabilities(
        family="conv", max_states=FUSED_MAX_STATES, accepts_received=True
    ),
    from_received=_fused_packed_from_received,
)
def decode_fused_packed(spec: CodecSpec, bm_tables, *, ctx: DecodeContext) -> DecodeResult:
    """Memory-lean Pallas pipeline: VMEM-resident scan with bit-packed
    survivors (32× smaller than ``fused``'s) + on-device packed traceback;
    given raw symbols it also computes branch metrics in-kernel."""
    from repro.kernels.ops import viterbi_decode_packed

    bits, metric = viterbi_decode_packed(
        spec.code, bm_tables, terminated=spec.terminated, interpret=ctx.interpret
    )
    return _result(spec, bits, metric, backend="fused_packed", metrics="table")


def _tile_count(ctx: DecodeContext, B: int, T: int, S: int) -> int:
    """ctx.tiles when the caller (or the planner) pinned one, else the
    shape-derived default."""
    if ctx.tiles is not None:
        return max(1, int(ctx.tiles))
    from repro.kernels.tiling import default_tiles

    return default_tiles(B, T, S)


def _tiled_from_received(
    spec: CodecSpec, received, *, ctx: DecodeContext
) -> DecodeResult:
    """Raw-symbol entry: each tile computes its branch metrics in-kernel."""
    from repro.kernels.metrics import fused_metric_plan
    from repro.kernels.ops import viterbi_decode_tiled_fused

    B, T = received.shape[:2]
    n = _tile_count(ctx, B, T, spec.code.n_states)
    plan = fused_metric_plan(spec.code, spec.metric, spec.puncture_array)
    bits, metric = viterbi_decode_tiled_fused(
        plan, received, n_tiles=n, overlap=ctx.tile_overlap,
        terminated=spec.terminated, interpret=ctx.interpret,
    )
    return _result(
        spec, bits, metric, backend="tiled", tiles=n,
        overlap=ctx.tile_overlap, metrics="in-kernel",
    )


@register_decoder(
    "tiled",
    capabilities=BackendCapabilities(
        family="conv", max_states=FUSED_MAX_STATES, accepts_received=True
    ),
    from_received=_tiled_from_received,
)
def decode_tiled(spec: CodecSpec, bm_tables, *, ctx: DecodeContext) -> DecodeResult:
    """Time-parallel tiled decode: T splits into ctx.tiles tiles that run
    through the packed Pallas scan as ONE batched launch (tiles on the lane
    axis), seams resolved via the min-plus state-map composition — O(T/P)
    critical path, bit-exact in the default exact-overlap regime."""
    from repro.kernels.ops import viterbi_decode_tiled_op

    B, T = bm_tables.shape[:2]
    n = _tile_count(ctx, B, T, spec.code.n_states)
    bits, metric = viterbi_decode_tiled_op(
        spec.code, bm_tables, n_tiles=n, overlap=ctx.tile_overlap,
        terminated=spec.terminated, interpret=ctx.interpret,
    )
    return _result(
        spec, bits, metric, backend="tiled", tiles=n,
        overlap=ctx.tile_overlap, metrics="table",
    )


@register_decoder("sequential", capabilities=BackendCapabilities(family="conv"))
def decode_sequential(spec: CodecSpec, bm_tables, *, ctx: DecodeContext) -> DecodeResult:
    """lax.scan reference decoder — the oracle every other backend is tested
    against."""
    bits, metric = viterbi_decode(spec.code, bm_tables, terminated=spec.terminated)
    return _result(spec, bits, metric, backend="sequential")


@register_decoder("parallel", capabilities=BackendCapabilities(family="conv"))
def decode_parallel(spec: CodecSpec, bm_tables, *, ctx: DecodeContext) -> DecodeResult:
    """(min,+) associative scan over chunk transfer matrices — log-depth in
    the number of chunks, the single-device long-block decoder."""
    bits, metric = viterbi_decode_parallel(
        spec.code, bm_tables, chunk=ctx.chunk, terminated=spec.terminated
    )
    return _result(spec, bits, metric, backend="parallel", chunk=ctx.chunk)


@register_decoder(
    "seqparallel",
    capabilities=BackendCapabilities(
        family="conv", supports_mesh=True, requires_mesh=True
    ),
)
def decode_seqparallel(spec: CodecSpec, bm_tables, *, ctx: DecodeContext) -> DecodeResult:
    """shard_map sequence-parallel decoder: the time axis is split across the
    mesh, chunk transfer matrices are all-gathered (n·S² floats, independent
    of T)."""
    from repro.parallel.collectives import viterbi_decode_seqparallel

    if ctx.mesh is None:
        raise ValueError("seqparallel backend needs ctx.mesh")
    bits, metric = viterbi_decode_seqparallel(
        spec.code, bm_tables, ctx.mesh, axis=ctx.mesh_axis, terminated=spec.terminated
    )
    return _result(
        spec, bits, metric, backend="seqparallel",
        mesh_axis=ctx.mesh_axis, mesh_size=int(ctx.mesh.shape[ctx.mesh_axis]),
    )


@register_decoder(
    "sharded_stream",
    capabilities=BackendCapabilities(
        family="conv",
        supports_mesh=True,
        requires_mesh=True,
        supports_streaming=True,
        sharded_stream=True,
        online=True,
        max_states=FUSED_MAX_STATES,
    ),
)
def decode_sharded_stream(spec: CodecSpec, bm_tables, *, ctx: DecodeContext) -> DecodeResult:
    """Mesh-sharded continuous-batching scheduler: the (B, T, M) block runs
    as B streams through ONE StreamScheduler whose slot table, input arena,
    and survivor ring are partitioned along ``ctx.batch_axis`` — every
    device on that axis decodes its slice of the slots each tick.  Each
    block row enters through ``submit`` — the documented adapter over the
    scheduler's chunk-fed ingestion path (``online=True``: live callers use
    open_stream/submit_chunk against the same machinery)."""
    import numpy as np

    from repro.parallel.collectives import mesh_axis_size
    from repro.stream import StreamScheduler
    from repro.stream.window import default_depth

    if ctx.mesh is None:
        raise ValueError("sharded_stream backend needs ctx.mesh")
    n = mesh_axis_size(ctx.mesh, ctx.batch_axis)
    if not n:
        raise ValueError(f"mesh lacks batch axis {ctx.batch_axis!r}")
    B, T = bm_tables.shape[:2]
    depth = ctx.stream_depth if ctx.stream_depth is not None else default_depth(spec.code)
    n_slots = -(-B // n) * n  # slot table must divide over the shards
    backend = "fused_packed" if ctx.chunk % 32 == 0 else "fused"
    sched = StreamScheduler(
        spec, n_slots=n_slots, chunk=ctx.chunk, depth=depth, backend=backend,
        interpret=ctx.interpret, mesh=ctx.mesh, mesh_axis=ctx.batch_axis,
    )
    for i in range(B):
        sched.submit(str(i), bm_tables[i])
    out = sched.run()
    bits = jnp.asarray(np.stack([out[str(i)][0] for i in range(B)]))
    metric = jnp.asarray([out[str(i)][1] for i in range(B)], dtype=jnp.float32)
    return _result(
        spec, bits, metric, backend="sharded_stream", shards=n,
        batch_axis=ctx.batch_axis, n_slots=n_slots, depth=depth,
        hot_loop=backend,
    )


def _bcjr_from_received(spec: CodecSpec, received, *, ctx: DecodeContext) -> DecodeResult:
    """Raw-symbol entry: channel output -> per-coded-bit LLR columns through
    the spec (puncture-masked), then the SISO kernel."""
    return decode_bcjr(spec, spec.branch_metrics(received), ctx=ctx)


@register_decoder(
    "bcjr",
    capabilities=BackendCapabilities(
        family="rsc", max_states=FUSED_MAX_STATES, accepts_received=True
    ),
    from_received=_bcjr_from_received,
)
def decode_bcjr(spec: CodecSpec, llr_coded, *, ctx: DecodeContext) -> DecodeResult:
    """Max-log-MAP BCJR SISO decoder (Pallas alpha/beta scans) for recursive
    systematic codes — bits are LLR signs, posterior LLRs ride along in the
    diagnostics for iterative (turbo) consumers."""
    from repro.kernels.ops import bcjr_llr_op

    llr, metric = bcjr_llr_op(
        spec.code, llr_coded, terminated=spec.terminated, interpret=ctx.interpret
    )
    bits = (llr < 0).astype(jnp.int32)
    return _result(spec, bits, metric, backend="bcjr", llr=llr)


def _turbo_from_received(spec, received, *, ctx: DecodeContext) -> DecodeResult:
    """Raw-symbol entry: channel output -> depunctured stream LLRs through
    the TurboSpec, then the iterative loop."""
    return decode_turbo(spec, spec.channel_llrs(received), ctx=ctx)


@register_decoder(
    "turbo",
    capabilities=BackendCapabilities(family="turbo", accepts_received=True),
    from_received=_turbo_from_received,
)
def decode_turbo(spec, llrs, *, ctx: DecodeContext) -> DecodeResult:
    """Iterative turbo decoder: two BCJR SISO passes per iteration exchanging
    scaled extrinsic LLRs through the spec's interleaver, early-exiting on
    LLR-sign agreement.  ``path_metric`` is the negated mean posterior |LLR|
    (lower = more confident, matching the minimized-metric convention)."""
    from repro.siso.turbo import turbo_decode

    result = turbo_decode(spec, llrs, interpret=ctx.interpret)
    metric = -jnp.mean(jnp.abs(result.llr), axis=-1)
    return _result(
        spec, result.bits, metric, backend="turbo",
        iterations=result.iterations_run, converged=result.converged,
        agreement=result.agreement, llr=result.llr,
    )


@register_decoder(
    "streaming",
    capabilities=BackendCapabilities(
        family="conv", supports_streaming=True, online=True
    ),
)
def decode_streaming(spec: CodecSpec, bm_tables, *, ctx: DecodeContext) -> DecodeResult:
    """Truncated-traceback sliding window over the chunked Pallas scan —
    O(depth + chunk) memory, the online path behind sessions and the
    continuous-batching scheduler (stream/)."""
    from repro.stream.window import default_depth, viterbi_decode_windowed

    depth = ctx.stream_depth if ctx.stream_depth is not None else default_depth(spec.code)
    bits, metric = viterbi_decode_windowed(
        spec.code,
        bm_tables,
        depth=depth,
        chunk=ctx.chunk,
        terminated=spec.terminated,
        interpret=ctx.interpret,
    )
    return _result(spec, bits, metric, backend="streaming", depth=depth, chunk=ctx.chunk)
