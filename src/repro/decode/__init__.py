"""Unified decode API — the single public decode surface.

  CodecSpec        what is decoded: code + metric kind + puncturing +
                   termination (spec.py)
  DecoderRegistry  who decodes it: every backend behind one normalized
                   ``decode(spec, bm_tables, *, ctx)`` signature with a
                   capability record (registry.py, backends.py)
  plan_decode      which backend runs: shape-aware auto-selection with
                   explicit override and ``explain()`` (planner.py)
  decode           one-shot convenience: plan + execute

Quickstart::

    from repro.decode import CodecSpec, DecodeRequest, decode

    spec = CodecSpec(code=CODE_K3_STD, metric="hard")
    coded = spec.encode(bits)                      # (B, T, n_out)
    rx = spec.channel(key, coded, flip_prob=0.02)
    res = decode(DecodeRequest(spec, received=rx))
    res.info_bits, res.path_metric, res.plan.explain()

SISO code families route through the same surface: a ``repro.siso.TurboSpec``
(or a CodecSpec wrapping an RSCCode) given to ``decode``/``plan_decode`` is
family-routed to the "turbo"/"bcjr" registry backends.
"""
from repro.decode import backends as _backends  # noqa: F401  (registers the backends)
from repro.decode.planner import LONG_BLOCK_T, DecodePlan, decode, plan_decode
from repro.decode.registry import (
    REGISTRY,
    BackendCapabilities,
    DecoderBackend,
    DecoderRegistry,
    RegisteredDecoder,
    get_decoder,
    list_decoders,
    register_decoder,
)
from repro.decode.request import DecodeContext, DecodeRequest, DecodeResult
from repro.decode.spec import CodecSpec, spec_family

__all__ = [
    "BackendCapabilities",
    "CodecSpec",
    "DecodeContext",
    "DecodePlan",
    "DecodeRequest",
    "DecodeResult",
    "DecoderBackend",
    "DecoderRegistry",
    "LONG_BLOCK_T",
    "REGISTRY",
    "RegisteredDecoder",
    "decode",
    "get_decoder",
    "list_decoders",
    "plan_decode",
    "register_decoder",
    "spec_family",
]
