"""Shape-aware decode planner.

``plan_decode(spec, shape)`` picks a backend from the code family, the
problem shape (B, T, S), the device kind, and mesh presence — replacing the
mode string the old serving head forced onto every caller.  The choice is a
pure function of its inputs (deterministic), can always be overridden with
``backend=...``, and every plan carries an ``explain()`` string for
debuggability.

Selection policy (each branch has a planner unit test):

  * explicit ``backend=`` override wins (validated against capabilities);
  * non-Viterbi code families route first — a TurboSpec to ``turbo``, an
    RSC CodecSpec to ``bcjr`` — so family dispatch stays a registry rule
    and the Viterbi shape rules below are untouched by new families;
  * a streaming context (``ctx.streaming``) with a multi-device ``data``
    (``ctx.batch_axis``) mesh axis -> ``sharded_stream`` (one scheduler
    spanning the axis); otherwise -> ``streaming``;
  * long blocks (T >= LONG_BLOCK_T) -> ``seqparallel`` when a mesh is
    present and T divides across it; without a usable mesh the rule
    ``long-conv-tiled`` routes to the time-parallel ``tiled`` backend and
    picks the tile count P by scoring ``predicted_costs()`` over candidate
    counts (``_pick_tiles``; ``parallel`` remains the fallback for
    trellises past the tiled VMEM cap);
  * everything else (short batched blocks) -> ``fused_packed`` (bit-packed
    survivors + on-device traceback; in-kernel branch metrics when the
    request carries raw symbols), falling back to ``parallel`` for
    trellises too large for the VMEM-resident scan.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.trellis import ConvCode
from repro.decode import backends as _backends  # noqa: F401  (populates the registry)
from repro.decode.registry import RegisteredDecoder, get_decoder
from repro.decode.request import DecodeContext, DecodeRequest, DecodeResult
from repro.decode.spec import CodecSpec, spec_family
from repro.siso.turbo import TurboSpec

#: family -> SISO backend the planner routes non-Viterbi specs to.
FAMILY_BACKENDS = {"rsc": "bcjr", "turbo": "turbo"}

#: Above this many trellis steps the log-depth chunk decoders beat the
#: sequential-scan forward pass (the scan's T-deep dependency chain stops
#: fitting latency budgets long before memory runs out).
LONG_BLOCK_T = 1024


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """A resolved decode: spec + shape + backend choice + why."""

    spec: CodecSpec
    backend: str
    batch: int
    steps: int
    ctx: DecodeContext
    reason: str
    device_kind: str

    @property
    def decoder(self) -> RegisteredDecoder:
        return get_decoder(self.backend)

    def predicted_costs(self) -> Optional[dict]:
        """Roofline-predicted flops/bytes of the planned decode: trace the
        backend on zeros of the planned shape and walk the jaxpr
        (roofline.jaxpr_cost, trip-count aware).  Returns {"flops", "bytes",
        "input_bytes"} or None for backends the tracer cannot follow
        end-to-end (host-side orchestration like the stream schedulers)."""
        import jax.numpy as jnp

        from repro.roofline.jaxpr_cost import count_fn_costs

        M = self.spec.table_width
        bm = jnp.zeros((self.batch, self.steps, M), dtype=jnp.float32)
        try:
            return count_fn_costs(
                lambda t: self.decoder(self.spec, t, ctx=self.ctx).bits, bm
            )
        except Exception:
            return None

    def explain(self, costs: bool = False) -> str:
        """Human-readable plan summary; ``costs=True`` appends the roofline
        prediction (predicted flops/bytes and arithmetic intensity) when the
        backend is traceable."""
        caps = self.decoder.capabilities
        text = (
            f"plan: backend={self.backend!r} for shape (B={self.batch}, T={self.steps}, "
            f"S={self.spec.code.n_states}) on {self.device_kind}\n"
            f"  spec: {self.spec.describe()}\n"
            f"  why:  {self.reason}\n"
            f"  caps: mesh={caps.supports_mesh} streaming={caps.supports_streaming} "
            f"max_states={caps.max_states} needs_terminated={caps.needs_terminated}"
        )
        if costs:
            c = self.predicted_costs()
            if c is None:
                text += "\n  cost: untraceable (host-side orchestration backend)"
            else:
                intensity = c["flops"] / c["bytes"] if c["bytes"] else 0.0
                text += (
                    f"\n  cost: ~{c['flops']:.3g} flops, ~{c['bytes']:.3g} bytes "
                    f"moved ({intensity:.2f} flops/byte), "
                    f"{c['input_bytes']:.3g} input bytes"
                )
        return text

    def execute(self, bm_tables) -> DecodeResult:
        """Run the planned backend on (B, T, M) branch-metric tables."""
        result = self.decoder(self.spec, bm_tables, ctx=self.ctx)
        result.plan = self
        return result

    def execute_request(self, request: "DecodeRequest") -> DecodeResult:
        """Run the plan on a DecodeRequest, routing raw channel output to
        the backend's in-kernel-metric entry when it has one — the bm table
        is only materialized for backends that need it.  Precomputed
        ``bm_tables`` take precedence over ``received`` (the DecodeRequest
        contract), so callers with custom tables never get them recomputed."""
        if (
            request.bm_tables is None
            and request.received is not None
            and self.decoder.from_received is not None
        ):
            received = np.asarray(request.received)
            if not np.isfinite(received).all():
                # the in-kernel metric path skips every host-side table
                # build where bad values would otherwise surface — guard
                # here, or a single NaN symbol poisons the whole decode
                bad = int(np.count_nonzero(~np.isfinite(received)))
                raise ValueError(
                    f"non-finite input: {bad} NaN/Inf value(s) in received "
                    f"symbols {received.shape} — in-kernel branch metrics "
                    "would silently corrupt the path metrics"
                )
            result = self.decoder.decode_received(
                self.spec, request.received, ctx=self.ctx
            )
            result.plan = self
            return result
        return self.execute(request.metrics())


@functools.lru_cache(maxsize=128)
def _pick_tiles(
    spec: CodecSpec, B: int, T: int, device_kind: str, chunk: int,
    interpret: Optional[bool],
) -> Tuple[int, str]:
    """Tile count for a long-block tiled decode, chosen from the roofline
    cost model: trace the tiled backend once per candidate P (the same
    ``predicted_costs()`` surface ``explain(costs=True)`` reports) and take
    the argmin of predicted (flops + bytes) / P — the critical path when the
    P tiles run time-parallel on the lane axis.  Candidates that the tracer
    cannot follow are skipped; if none trace, fall back to the shape-derived
    default.  Cached per (spec, shape, device): planning stays cheap and
    deterministic."""
    from repro.kernels.tiling import MIN_TILE_CORE, default_tiles

    S = spec.code.n_states
    fallback = default_tiles(B, T, S)
    cap = max(1, T // MIN_TILE_CORE)
    candidates = sorted({p for p in (1, 2, 4, 8, 16, 32) if p <= cap} | {fallback})
    scored = {}
    for p in candidates:
        plan = DecodePlan(
            spec=spec, backend="tiled", batch=B, steps=T,
            ctx=DecodeContext(chunk=chunk, interpret=interpret, tiles=p),
            reason="tile-count candidate", device_kind=device_kind,
        )
        c = plan.predicted_costs()
        if c is not None:
            scored[p] = (c["flops"] + c["bytes"]) / p
    if not scored:
        return fallback, "predicted_costs untraceable -> shape default"
    best = min(scored, key=scored.get)
    return best, (
        f"argmin of predicted (flops+bytes)/P over P in {list(scored)} "
        "(roofline predicted_costs)"
    )


def _normalize_shape(shape: Sequence[int]) -> Tuple[int, int]:
    """Accept (B, T) or a full (B, T, M) bm-table shape."""
    if len(shape) == 2:
        return int(shape[0]), int(shape[1])
    if len(shape) == 3:
        return int(shape[0]), int(shape[1])
    raise ValueError(f"shape must be (B, T) or (B, T, M), got {tuple(shape)}")


def _normalize_spec(spec):
    """Promote a bare ConvCode to a CodecSpec; family specs with their own
    encode/metric surface (TurboSpec) pass through untouched."""
    if isinstance(spec, (CodecSpec, ConvCode)):
        return CodecSpec.of(spec)
    return spec


def _validate(decoder: RegisteredDecoder, spec, ctx: DecodeContext) -> None:
    caps = decoder.capabilities
    fam = spec_family(spec)
    if caps.family != fam:
        raise ValueError(
            f"backend {decoder.name!r} decodes the {caps.family!r} code family, "
            f"spec is {fam!r} — pick a backend registered for that family"
        )
    S = spec.code.n_states
    if caps.requires_mesh and ctx.mesh is None:
        raise ValueError(f"backend {decoder.name!r} requires a mesh (pass mesh=/ctx.mesh)")
    if caps.max_states is not None and S > caps.max_states:
        raise ValueError(
            f"backend {decoder.name!r} handles at most {caps.max_states} states, "
            f"spec has {S}"
        )
    if caps.needs_terminated and not spec.terminated:
        raise ValueError(f"backend {decoder.name!r} only decodes terminated trellises")
    if (caps.sharded_stream and ctx.mesh is not None
            and not int(ctx.mesh.shape.get(ctx.batch_axis, 0))):
        raise ValueError(
            f"backend {decoder.name!r} shards over mesh axis "
            f"{ctx.batch_axis!r}, which {ctx.mesh} lacks"
        )


def plan_decode(
    spec: Union[CodecSpec, ConvCode, TurboSpec],
    shape: Sequence[int],
    *,
    mesh: Optional[object] = None,
    backend: Optional[str] = None,
    ctx: Optional[DecodeContext] = None,
) -> DecodePlan:
    """Pick (or validate) a decode backend for a (B, T[, M]) problem.

    Args:
      spec: the CodecSpec (a bare ConvCode is promoted with defaults).
      shape: (B, T) or the full (B, T, M) branch-metric table shape.
      mesh: convenience override for ``ctx.mesh``.
      backend: explicit registry name — skips auto-selection (still
        capability-validated).
      ctx: execution context (chunking, stream depth, streaming flag, ...).

    Returns:
      DecodePlan; ``plan.execute(bm_tables)`` runs it, ``plan.explain()``
      says why.
    """
    spec = _normalize_spec(spec)
    B, T = _normalize_shape(shape)
    ctx = ctx or DecodeContext()
    if mesh is not None:
        ctx = dataclasses.replace(ctx, mesh=mesh)
    device_kind = jax.devices()[0].platform
    S = spec.code.n_states

    fam = spec_family(spec)
    if backend is not None:
        choice, reason = backend, f"explicit backend={backend!r} override"
    elif fam in FAMILY_BACKENDS:
        choice = FAMILY_BACKENDS[fam]
        reason = (
            f"code family {fam!r} -> registry family rule routes to "
            f"{choice!r} (shape rules below select only among 'conv'/Viterbi "
            "backends)"
        )
    elif ctx.streaming:
        n_data = (
            int(ctx.mesh.shape.get(ctx.batch_axis, 0)) if ctx.mesh is not None else 0
        )
        sharded_max = get_decoder("sharded_stream").capabilities.max_states
        if n_data > 1 and (sharded_max is None or S <= sharded_max):
            choice = "sharded_stream"
            reason = (
                f"session context with a multi-device mesh "
                f"({ctx.batch_axis}={n_data}) -> one scheduler spanning the "
                f"{ctx.batch_axis!r} axis (slot table sharded per device)"
            )
        elif n_data > 1:
            choice = "streaming"
            reason = (
                f"session context, {ctx.batch_axis}={n_data} mesh, but S={S} "
                f"exceeds the sharded hot-loop VMEM cap ({sharded_max}) -> "
                "single-device windowed decode"
            )
        else:
            choice = "streaming"
            reason = "session context given -> windowed online decode (O(depth+chunk) memory)"
    elif T >= LONG_BLOCK_T:
        n = int(ctx.mesh.shape.get(ctx.mesh_axis, 0)) if ctx.mesh is not None else 0
        if n and T % n == 0:
            choice = "seqparallel"
            reason = (
                f"long block (T={T} >= {LONG_BLOCK_T}) with a mesh "
                f"({ctx.mesh_axis}={n}, T divisible) -> shard the time axis"
            )
        else:
            if ctx.mesh is None:
                why_not = "no mesh"
            elif not n:
                why_not = f"mesh lacks axis {ctx.mesh_axis!r}"
            else:
                why_not = f"T % {ctx.mesh_axis}={n} != 0"
            tiled_max = get_decoder("tiled").capabilities.max_states
            if tiled_max is not None and S > tiled_max:
                choice = "parallel"
                reason = (
                    f"long block (T={T} >= {LONG_BLOCK_T}), {why_not}, and "
                    f"S={S} exceeds the tiled VMEM cap ({tiled_max}) -> "
                    "single-device (min,+) associative scan"
                )
            else:
                choice = "tiled"
                if ctx.tiles is not None:
                    tiles, how = int(ctx.tiles), "ctx.tiles pinned by caller"
                else:
                    tiles, how = _pick_tiles(
                        spec, B, T, device_kind, ctx.chunk, ctx.interpret
                    )
                    ctx = dataclasses.replace(ctx, tiles=tiles)
                reason = (
                    f"long block (T={T} >= {LONG_BLOCK_T}), {why_not} -> "
                    f"rule 'long-conv-tiled': time-parallel tiled decode, "
                    f"P={tiles} ({how})"
                )
    else:
        fused_max = get_decoder("fused_packed").capabilities.max_states
        if fused_max is not None and S > fused_max:
            choice = "parallel"
            reason = (
                f"short block but S={S} exceeds the fused VMEM budget "
                f"({fused_max}) -> chunked scan"
            )
        else:
            choice = "fused_packed"
            reason = (
                f"short batched block (T={T} < {LONG_BLOCK_T}) -> "
                "VMEM-resident Pallas scan with packed survivors + "
                "on-device traceback"
            )

    decoder = get_decoder(choice)
    _validate(decoder, spec, ctx)
    return DecodePlan(
        spec=spec, backend=choice, batch=B, steps=T, ctx=ctx,
        reason=reason, device_kind=device_kind,
    )


def decode(
    request: Union[DecodeRequest, CodecSpec],
    received=None,
    *,
    mesh: Optional[object] = None,
    backend: Optional[str] = None,
    ctx: Optional[DecodeContext] = None,
) -> DecodeResult:
    """One-shot decode: plan + execute.

    Either ``decode(DecodeRequest(spec, received=rx))`` or the shorthand
    ``decode(spec, rx)``.  Returns a DecodeResult whose ``info_bits`` has
    flush bits stripped per the spec.  When the request carries raw channel
    output and the planned backend computes metrics in-kernel
    (``accepts_received``), the symbols go straight to the kernel — no
    (B, T, M) bm table is built.
    """
    if not isinstance(request, DecodeRequest):
        request = DecodeRequest(spec=_normalize_spec(request), received=received)
    shape = request.shape()
    plan = plan_decode(request.spec, shape, mesh=mesh, backend=backend, ctx=ctx)
    return plan.execute_request(request)
