"""DecoderBackend protocol + DecoderRegistry.

Every decoder in the repo implements ONE normalized signature

    decode(spec: CodecSpec, bm_tables: (B, T, M), *, ctx: DecodeContext)
        -> DecodeResult

and registers itself with a capability record:

    @register_decoder("fused", capabilities=BackendCapabilities(...))
    def _fused(spec, bm_tables, *, ctx): ...

The registry replaces the string ``if/elif`` dispatch chain of the old
serving head: adding a backend (a ROADMAP item like sharded streaming or
adaptive depth) — or a whole code family, like the SISO "bcjr"/"turbo"
entries — is a registry entry, not a chain edit.  The planner (planner.py)
reads the capability records to auto-select.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, Optional, Protocol, Tuple

from repro.decode.request import DecodeContext, DecodeResult
from repro.decode.spec import CodecSpec


class DecoderBackend(Protocol):
    """The one normalized decode signature every backend implements."""

    def __call__(self, spec: CodecSpec, bm_tables, *, ctx: DecodeContext) -> DecodeResult:
        ...


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """What a backend can run — the planner's selection input.

    Attributes:
      family: code family the backend decodes — ``"conv"`` (feed-forward
        convolutional, Viterbi), ``"rsc"`` (recursive systematic, SISO
        max-log-MAP) or ``"turbo"`` (iterative parallel concatenation).
        Requests are routed within their family; a mismatch is a validation
        error, never a silent wrong-algebra decode.
      supports_mesh: can shard the decode across a device mesh (and, if
        ``requires_mesh``, must be given one).
      requires_mesh: refuses to run without ``ctx.mesh``.
      supports_streaming: windowed/online decode — bounded memory for
        unbounded streams, bits emitted a fixed lag behind the channel.
      max_states: largest trellis (n_states) the backend handles, or None
        for unlimited.
      needs_terminated: only decodes terminated trellises.
      accepts_received: the backend has a raw-symbol entry (``from_received``)
        that computes branch metrics in-kernel — the planner's ``decode``
        routes channel output straight to it, skipping the host-side
        (B, T, M) bm-table materialization entirely.
      sharded_stream: the backend partitions a streaming slot table along
        the batch/``data`` mesh axis (one scheduler spanning all devices) —
        the planner routes multi-device streaming requests to it.
      online: the backing machinery ingests incrementally — chunk-fed
        producers with per-stream backpressure (StreamScheduler.open_stream/
        submit_chunk, StreamSession.push) — so it can serve live connections
        rather than requiring the full table up front.  The normalized
        ``decode(spec, bm_tables, ctx)`` entry still takes a whole block;
        the flag tells serving layers which backends they can keep feeding.
    """

    family: str = "conv"
    supports_mesh: bool = False
    requires_mesh: bool = False
    supports_streaming: bool = False
    max_states: Optional[int] = None
    needs_terminated: bool = False
    accepts_received: bool = False
    sharded_stream: bool = False
    online: bool = False


@dataclasses.dataclass(frozen=True)
class RegisteredDecoder:
    name: str
    fn: DecoderBackend
    capabilities: BackendCapabilities
    summary: str = ""
    #: optional raw-symbol entry: (spec, received (B, T, n_out), *, ctx) ->
    #: DecodeResult with branch metrics computed in-kernel.
    from_received: Optional[Callable] = None

    def __call__(self, spec: CodecSpec, bm_tables, *, ctx: DecodeContext) -> DecodeResult:
        return self.fn(spec, bm_tables, ctx=ctx)

    def decode_received(self, spec: CodecSpec, received, *, ctx: DecodeContext) -> DecodeResult:
        if self.from_received is None:
            raise ValueError(f"backend {self.name!r} has no raw-symbol entry")
        return self.from_received(spec, received, ctx=ctx)


class DecoderRegistry:
    """Name -> RegisteredDecoder mapping with decorator-style registration."""

    def __init__(self):
        self._decoders: Dict[str, RegisteredDecoder] = {}

    def register(
        self,
        name: str,
        *,
        capabilities: Optional[BackendCapabilities] = None,
        summary: str = "",
        from_received: Optional[Callable] = None,
    ) -> Callable[[DecoderBackend], DecoderBackend]:
        def deco(fn: DecoderBackend) -> DecoderBackend:
            if name in self._decoders:
                raise KeyError(f"decoder {name!r} already registered")
            doc = summary
            if not doc and fn.__doc__:
                doc = fn.__doc__.strip().splitlines()[0]
            self._decoders[name] = RegisteredDecoder(
                name=name,
                fn=fn,
                capabilities=capabilities or BackendCapabilities(),
                summary=doc,
                from_received=from_received,
            )
            return fn

        return deco

    def get(self, name: str) -> RegisteredDecoder:
        try:
            return self._decoders[name]
        except KeyError:
            raise KeyError(
                f"unknown decoder {name!r}; registered: {', '.join(self.names())}"
            ) from None

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._decoders))

    def __contains__(self, name: str) -> bool:
        return name in self._decoders

    def __iter__(self) -> Iterator[RegisteredDecoder]:
        return iter(self._decoders.values())

    def items(self):
        return self._decoders.items()


#: The process-wide registry every built-in backend registers onto.
REGISTRY = DecoderRegistry()
register_decoder = REGISTRY.register
get_decoder = REGISTRY.get


def list_decoders() -> Tuple[str, ...]:
    return REGISTRY.names()
