"""Request/result/context dataclasses of the unified decode API.

DecodeContext  everything about *where/how* to run that is not part of the
               codec itself: mesh, chunking, streaming window depth,
               interpret-mode override.  The planner consumes it to pick a
               backend; the chosen backend consumes it to execute.
DecodeRequest  one decode job: a CodecSpec plus either raw channel output
               (``received``) or precomputed branch-metric tables.
DecodeResult   bits + path metric + per-stream diagnostics + the plan that
               produced them.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, Optional

import jax.numpy as jnp

from repro.decode.spec import CodecSpec

if TYPE_CHECKING:  # planner imports this module; annotation only
    from repro.decode.planner import DecodePlan


@dataclasses.dataclass(frozen=True)
class DecodeContext:
    """Execution context shared by the planner and every backend.

    Attributes:
      mesh: jax device mesh for distributed backends (None = single device).
      mesh_axis: mesh axis name the sequence is sharded over.
      batch_axis: mesh axis name batch/slot-parallel backends shard over
        (the sharded stream scheduler's slot table spans this axis).
      chunk: chunk length for chunked backends (parallel scan, streaming).
      stream_depth: truncated-traceback depth for the streaming backend
        (None = the textbook 5*K).
      streaming: a live session context — the caller consumes bits a fixed
        lag behind the channel, so the planner must pick a windowed backend.
      tiles: time-tile count for the ``tiled`` backend (None = the planner
        picks one from predicted costs, or kernels/tiling.default_tiles).
      tile_overlap: per-tile warm-up steps for the ``tiled`` backend.  None
        (the default) and any value >= the truncation depth 5·K select the
        exact min-plus seam resolution (bit-exact); smaller values select
        the cheaper truncated warm-up approximation.
      interpret: force Pallas interpret mode (None = auto: interpret off-TPU).
    """

    mesh: Optional[object] = None
    mesh_axis: str = "model"
    batch_axis: str = "data"
    chunk: int = 64
    stream_depth: Optional[int] = None
    streaming: bool = False
    tiles: Optional[int] = None
    tile_overlap: Optional[int] = None
    interpret: Optional[bool] = None


@dataclasses.dataclass(frozen=True)
class DecodeRequest:
    """One decode job.  Provide ``received`` (channel output, shaped
    (B, T, n_out)) or ``bm_tables`` ((B, T, n_symbols), already built)."""

    spec: CodecSpec
    received: Optional[jnp.ndarray] = None
    bm_tables: Optional[jnp.ndarray] = None

    def shape(self):
        """(B, T) problem shape for the planner — derivable from either
        input form without building branch metrics."""
        src = self.bm_tables if self.bm_tables is not None else self.received
        if src is None:
            raise ValueError("DecodeRequest needs received or bm_tables")
        return src.shape[:2]

    def metrics(self) -> jnp.ndarray:
        """Branch-metric tables for this request (built from ``received``
        through the spec unless precomputed tables were handed in)."""
        if self.bm_tables is not None:
            return self.bm_tables
        if self.received is None:
            raise ValueError("DecodeRequest needs received or bm_tables")
        return self.spec.branch_metrics(self.received)


@dataclasses.dataclass
class DecodeResult:
    """What every backend returns, in one normalized shape.

    Attributes:
      bits: (B, T) decoded input bits, *including* flush bits when the spec
        is terminated — ``info_bits`` strips them.
      path_metric: (B,) winning path metric (minimized).
      spec: the CodecSpec that was decoded.
      plan: the DecodePlan that chose the backend (filled by plan.execute).
      diagnostics: per-backend extras (backend name, chunking, depth, ...).
    """

    bits: jnp.ndarray
    path_metric: jnp.ndarray
    spec: CodecSpec
    plan: Optional["DecodePlan"] = None
    diagnostics: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def info_bits(self) -> jnp.ndarray:
        """Decoded information bits (flush bits stripped per the spec)."""
        return self.spec.strip_flush(self.bits)
