"""Optimizers as pure pytree transforms (no external deps).

AdamW keeps two fp32 moments per param (sharded like the param — and over
'data' too when FSDP is on, i.e. ZeRO-1/2/3 follow from the sharding rules,
not special code).  Adafactor keeps factored second moments: O(n+m) state per
(n, m) matrix — the practical choice for the 110B config.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------- #
# Schedules                                                                    #
# --------------------------------------------------------------------------- #


def cosine_warmup(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


# --------------------------------------------------------------------------- #
# AdamW                                                                        #
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step, lr) -> (new_params, new_state)
    state_specs: Callable  # param_specs -> state spec tree (for sharding)


def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, max_grad_norm=1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params, step, lr):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        t = jnp.asarray(step, jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            upd = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), mu, nu

        out = jax.tree_util.tree_map(upd, grads, state["mu"], state["nu"], params)
        new_params = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "nu": new_nu}, gnorm

    def state_specs(param_specs):
        import dataclasses as dc

        from repro.models.common import ParamSpec, _is_spec

        f32 = lambda s: dc.replace(s, dtype=jnp.float32, init="zeros")  # noqa: E731
        m = jax.tree_util.tree_map(f32, param_specs, is_leaf=_is_spec)
        return {"mu": m, "nu": m}

    return Optimizer(init, update, state_specs)


# --------------------------------------------------------------------------- #
# Adafactor (factored second moments)                                          #
# --------------------------------------------------------------------------- #


def adafactor(decay=0.8, eps=1e-30, clip_threshold=1.0, weight_decay=0.0,
              max_grad_norm=1.0, min_dim_size_to_factor=128) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor \
            and shape[-2] >= min_dim_size_to_factor

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"v": jax.tree_util.tree_map(one, params)}

    def update(grads, state, params, step, lr):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps))[..., None] * vc[..., None, :]
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                nv = {"vr": vr, "vc": vc}
            else:
                nv_ = beta * v["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(nv_, eps))
                nv = {"v": nv_}
            rms_u = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

        out = jax.tree_util.tree_map(
            upd, grads, state["v"], params, is_leaf=lambda x: isinstance(x, jnp.ndarray))
        istup = lambda x: isinstance(x, tuple)  # noqa: E731
        new_params = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup)
        new_v = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup)
        return new_params, {"v": new_v}, gnorm

    def state_specs(param_specs):
        import dataclasses as dc

        from repro.models.common import ParamSpec, _is_spec

        def one(s: "ParamSpec"):
            if _factored(s.shape):
                return {
                    "vr": dc.replace(s, shape=s.shape[:-1], axes=s.axes[:-1],
                                     dtype=jnp.float32, init="zeros"),
                    "vc": dc.replace(s, shape=s.shape[:-2] + s.shape[-1:],
                                     axes=s.axes[:-2] + s.axes[-1:],
                                     dtype=jnp.float32, init="zeros"),
                }
            return {"v": dc.replace(s, dtype=jnp.float32, init="zeros")}

        return {"v": jax.tree_util.tree_map(one, param_specs, is_leaf=_is_spec)}

    return Optimizer(init, update, state_specs)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise KeyError(name)
