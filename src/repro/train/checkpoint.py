"""Sharded, async checkpointing with elastic restore.

Format: one ``step_<N>/`` directory per checkpoint holding a single .npz of
flattened leaves (this process's shards — on a real multi-host pod each host
writes its own addressable shards; the manifest records the tree structure
and step).  ``reshard_restored`` device_puts the loaded arrays with the
*current* shardings, so a checkpoint taken on one mesh restores onto any
other mesh whose axes divide the dims — elastic scaling.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional, Tuple

import jax
import numpy as np


class SimulatedFailure(RuntimeError):
    """Raised by test fail_hooks to simulate a node crash."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree, step: int) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrs = {f"leaf_{i}": np.asarray(jax.device_get(l)) for i, l in enumerate(leaves)}
    np.savez(os.path.join(path, "shards.npz"), **arrs)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves),
                   "treedef": str(treedef)}, f)
    # commit marker makes partially-written checkpoints detectable
    with open(os.path.join(path, "COMMITTED"), "w") as f:
        f.write(str(step))


def load_pytree(path: str, like_tree) -> Tuple[Any, int]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shards.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    _, treedef = _flatten(like_tree)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writes on a background thread.

    ``save`` snapshots to host memory synchronously (cheap) and writes to
    disk asynchronously; ``wait`` joins outstanding writes.  Keeps the
    newest ``keep`` committed checkpoints.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    def save(self, step: int, params, opt_state) -> None:
        # snapshot on the caller thread: device_get here so the training step
        # can donate/overwrite device buffers immediately after
        host = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), (params, opt_state))
        self.wait()
        self._pending = self._pool.submit(self._write, step, host)

    def _write(self, step: int, host_tree) -> None:
        path = os.path.join(self.dir, f"step_{step:08d}")
        save_pytree(path, host_tree, step)
        self._gc()

    def _gc(self) -> None:
        with self._lock:
            cks = sorted(d for d in os.listdir(self.dir) if d.startswith("step_"))
            committed = [d for d in cks
                         if os.path.exists(os.path.join(self.dir, d, "COMMITTED"))]
            for d in committed[: -self.keep] if self.keep else []:
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def latest_path(self) -> Optional[str]:
        if not os.path.isdir(self.dir):
            return None
        cks = sorted(d for d in os.listdir(self.dir) if d.startswith("step_"))
        for d in reversed(cks):
            if os.path.exists(os.path.join(self.dir, d, "COMMITTED")):
                return os.path.join(self.dir, d)
        return None

    def restore_latest(self, block: bool = False):
        if block:
            self.wait()
        path = self.latest_path()
        if path is None:
            return None
        return path  # opaque handle consumed by reshard_restored


def reshard_restored(path_or_tree, params_like, opt_like):
    """Load a checkpoint and device_put it with the CURRENT shardings of
    ``params_like``/``opt_like`` (elastic restore onto a different mesh)."""
    (params, opt_state), step = load_pytree(path_or_tree, (params_like, opt_like))

    def put(arr, like):
        sharding = getattr(like, "sharding", None)
        if sharding is not None:
            return jax.device_put(jax.numpy.asarray(arr, like.dtype), sharding)
        return jax.numpy.asarray(arr, like.dtype)

    params = jax.tree_util.tree_map(put, params, params_like)
    opt_state = jax.tree_util.tree_map(put, opt_state, opt_like)
    return params, opt_state, step
