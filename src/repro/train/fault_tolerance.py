"""Fault-tolerance utilities: straggler detection and elastic mesh rebuild.

At 1000+ nodes, per-step time is the cheapest cluster-health signal: a
straggling host shows up as a step-time outlier long before it fails.  The
detector keeps an EMA of step time and variance and flags z-score outliers;
the launcher's mitigation hook can then trigger a checkpoint + drop the slow
pod (elastic restart onto the surviving mesh — see ``elastic_mesh``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerDetector:
    zscore: float = 4.0
    decay: float = 0.95
    warmup_steps: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)
    on_straggler: Optional[Callable[[int, float], None]] = None

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.n += 1
        if self.n <= self.warmup_steps:
            # prime the EMA; never flag during warmup (includes compile step)
            w = 1.0 / self.n
            self.mean = (1 - w) * self.mean + w * dt
            self.var = (1 - w) * self.var + w * (dt - self.mean) ** 2
            return False
        std = math.sqrt(max(self.var, 1e-12))
        z = (dt - self.mean) / max(std, 0.05 * max(self.mean, 1e-9))
        is_straggler = z > self.zscore
        if is_straggler:
            self.events.append({"step": step, "time_s": dt, "z": z})
            if self.on_straggler is not None:
                self.on_straggler(step, z)
        else:  # only fold healthy steps into the baseline
            self.mean = self.decay * self.mean + (1 - self.decay) * dt
            self.var = self.decay * self.var + (1 - self.decay) * (dt - self.mean) ** 2
        return is_straggler


def elastic_mesh(prefer_shape, axes, devices=None):
    """Build the largest mesh of the preferred shape that the surviving
    device set supports, shrinking the *leading* (data-parallel) axis first.
    A checkpoint resharded onto the result resumes training with reduced
    throughput instead of failing the job."""
    import jax
    import numpy as np

    devices = list(devices if devices is not None else jax.devices())
    shape = list(prefer_shape)
    while shape[0] > 1 and int(np.prod(shape)) > len(devices):
        shape[0] //= 2
    if int(np.prod(shape)) > len(devices):
        # drop axes entirely until it fits (last resort: single device)
        shape = [1] * (len(prefer_shape) - 1) + [1]
    n = int(np.prod(shape))
    arr = np.array(devices[:n]).reshape(shape)
    from jax.sharding import Mesh

    return Mesh(arr, axes)
