"""Training runtime: optimizers, jitted step builders, checkpointing,
fault tolerance."""
from repro.train.optimizer import adafactor, adamw, cosine_warmup
from repro.train.train_loop import make_train_step, train

__all__ = ["adamw", "adafactor", "cosine_warmup", "make_train_step", "train"]
