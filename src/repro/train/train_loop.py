"""Jitted train-step builder + fault-tolerant training loop.

``make_train_step`` builds one pjit'd step: value_and_grad over the model
loss, microbatch gradient accumulation (lax.scan over chunks), optimizer
update.  Gradient reduction across data-parallel replicas is inserted by
SPMD from the shardings; with FSDP rules the reduction lowers to
reduce-scatter + all-gather (ZeRO) instead of all-reduce.

``train`` wraps the step in the fault-tolerance harness: periodic async
checkpoints, crash -> restore -> resume, straggler detection.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer, cosine_warmup, get_optimizer


def build_step_fn(
    model,
    optimizer: Optimizer,
    lr_fn: Callable,
    mesh=None,
    rules=None,
):
    """Raw (unjitted) train step — shared by make_train_step (which jits it)
    and launch/dryrun.py (which lowers it).  With part.microbatches > 1, the
    batch's leading dim is split and gradients are accumulated over chunks
    (sequential remat of the batch dim — the standard memory/throughput
    trade)."""
    mb = model.part.microbatches

    def loss_fn(params, batch):
        return model.train_loss(params, batch, mesh=mesh, rules=rules)

    def step(params, opt_state, batch, step_idx):
        # mixed precision: forward/backward consume a bf16 copy of the fp32
        # master weights, cast while still sharded — FSDP weight all-gathers
        # then move bf16, not f32 (halves the dominant collective term)
        params_c = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.dtype(jnp.float32) else p, params)
        if mb > 1:
            split = jax.tree_util.tree_map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

            def acc_fn(acc, chunk):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params_c, chunk)
                acc_g, acc_l = acc
                return (jax.tree_util.tree_map(jnp.add, acc_g, g), acc_l + l), m

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params_c)
            (grads, loss_sum), ms = jax.lax.scan(acc_fn, (zeros, 0.0), split)
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            loss = loss_sum / mb
            metrics = jax.tree_util.tree_map(lambda x: x[-1], ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params_c, batch)
        lr = lr_fn(step_idx)
        new_params, new_opt, gnorm = optimizer.update(
            grads, opt_state, params, step_idx, lr)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
        return new_params, new_opt, metrics

    return step


def make_train_step(
    model,
    optimizer: Optimizer,
    lr_fn: Callable,
    mesh=None,
    rules=None,
    donate: bool = True,
):
    """Jitted train step with param/optimizer shardings attached."""
    step = build_step_fn(model, optimizer, lr_fn, mesh, rules)
    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    p_sh = model.param_shardings(mesh, rules)
    o_sh = _opt_shardings(model, optimizer, mesh, rules)
    from jax.sharding import NamedSharding, PartitionSpec as P

    repl = NamedSharding(mesh, P())
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, None, None),
        out_shardings=(p_sh, o_sh, repl),
        donate_argnums=(0, 1) if donate else (),
    )


def _opt_shardings(model, optimizer, mesh, rules=None):
    from repro.models import common as cm

    specs = optimizer.state_specs(model.param_specs)
    return cm.shardings(specs, mesh, model._rules(rules, for_opt=True))


def train(
    model,
    data_iter,
    *,
    steps: int,
    lr: float = 3e-4,
    warmup: int = 100,
    mesh=None,
    rules=None,
    seed: int = 0,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    keep_checkpoints: int = 3,
    fail_hook: Optional[Callable[[int], None]] = None,
    log_every: int = 10,
    straggler_zscore: float = 4.0,
) -> Dict[str, Any]:
    """Fault-tolerant training loop.

    fail_hook(step) may raise to simulate node failure (used by tests); on
    any exception the loop restores the latest checkpoint and resumes.
    Returns the final params/opt_state plus a run report.
    """
    from repro.train import checkpoint as ckpt
    from repro.train.fault_tolerance import StragglerDetector

    optimizer = get_optimizer(model.part.optimizer)
    lr_fn = cosine_warmup(lr, warmup, steps)
    step_fn = make_train_step(model, optimizer, lr_fn, mesh, rules, donate=False)

    params = model.init(jax.random.PRNGKey(seed))
    opt_state = optimizer.init(params)
    start_step = 0
    saver = ckpt.AsyncCheckpointer(checkpoint_dir, keep=keep_checkpoints) \
        if checkpoint_dir else None
    if saver is not None:
        restored = saver.restore_latest()
        if restored is not None:
            params, opt_state, start_step = ckpt.reshard_restored(
                restored, params, opt_state)

    detector = StragglerDetector(zscore=straggler_zscore)
    history = []
    restarts = 0
    step = start_step
    while step < steps:
        try:
            batch = data_iter(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch, step)
            metrics = jax.tree_util.tree_map(float, jax.device_get(metrics))
            dt = time.perf_counter() - t0
            straggle = detector.observe(step, dt)
            if straggle:
                metrics["straggler_event"] = 1.0
            if log_every and step % log_every == 0:
                history.append({"step": step, "time_s": dt, **metrics})
            if saver is not None and checkpoint_every and \
                    step % checkpoint_every == checkpoint_every - 1:
                saver.save(step + 1, params, opt_state)
            if fail_hook is not None:
                fail_hook(step)
            step += 1
        except (ckpt.SimulatedFailure,) as e:  # node failure -> restore
            restarts += 1
            if saver is None:
                raise
            restored = saver.restore_latest(block=True)
            if restored is None:  # no checkpoint yet: restart from scratch
                params = model.init(jax.random.PRNGKey(seed))
                opt_state = optimizer.init(params)
                step = 0
            else:
                params, opt_state, step = ckpt.reshard_restored(
                    restored, params, opt_state)
    if saver is not None:
        saver.wait()
    return {
        "params": params,
        "opt_state": opt_state,
        "history": history,
        "restarts": restarts,
        "straggler_events": detector.events,
        "final_step": step,
    }
