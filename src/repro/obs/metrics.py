"""Metrics core: counters, gauges, fixed-bucket histograms, one registry.

The streaming plane needs numbers a routing tier can throttle on (p95
arrival-to-commit latency, queue depths) and the adaptive-traceback work
needs per-stream survivor statistics — both are *metrics*, not log lines.
This module is the low-overhead primitive layer those consumers share:

  Counter / Gauge     plain monotone / last-value cells (python ints and
                      floats — observing one is an attribute add, no locks,
                      no allocation on the hot path).
  Histogram           fixed upper-bound buckets chosen at construction, so
                      ``observe`` is a bisect + two adds; quantiles are
                      estimated from the bucket boundaries (clamped to the
                      exactly-tracked min/max), never from stored samples —
                      memory is O(buckets) no matter how many observations.
  MetricsRegistry     name -> instrument, ``snapshot()`` as one plain dict,
                      Prometheus-style text exposition via ``render()``.

plus :func:`percentile`, the ONE nearest-rank helper every place that
summarizes a list of raw latencies must use (the ad-hoc copies it replaces
indexed into unsorted arrays and crashed on empty input).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


def percentile(values: Sequence[float], q: float, default: float = 0.0) -> float:
    """Nearest-rank percentile of raw samples (q in [0, 1]).

    Sorts a copy (callers need not pre-sort) and returns ``default`` for an
    empty sequence instead of crashing — the two bugs of the ad-hoc
    ``sorted_lat[int(q * (len - 1))]`` copies this replaces.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"q must be in [0, 1], got {q}")
    vals = sorted(values)
    if not vals:
        return default
    return float(vals[int(round(q * (len(vals) - 1)))])


#: Default latency buckets: 1 ms .. ~8.7 min, doubling — 20 buckets cover
#: everything from a warm TPU tick to a cold-compile stall.
LATENCY_BUCKETS_S: Tuple[float, ...] = tuple(0.001 * 2 ** i for i in range(20))

#: Default merge-depth buckets (trellis steps): survivor windows are tens to
#: a few hundred steps deep.
DEPTH_BUCKETS: Tuple[float, ...] = (1, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96,
                                    128, 192, 256, 384, 512)

#: Tick wall-time buckets (seconds): 100 µs .. ~1.6 s, doubling — the input
#: resolution the StragglerDetector's z-score flags against.
TICK_BUCKETS: Tuple[float, ...] = tuple(0.0001 * 2 ** i for i in range(15))


@dataclasses.dataclass
class Counter:
    """Monotone event count.  ``inc`` only — resets mean a new Counter."""

    name: str
    help: str = ""
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def set(self, v: float) -> None:
        """Absorb an externally-kept monotone count (e.g. SchedulerStats
        fields mirrored into the registry at snapshot time)."""
        self.value = float(v)


@dataclasses.dataclass
class Gauge:
    """Last-value instrument (queue depth, utilization, ...)."""

    name: str
    help: str = ""
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Fixed-bucket histogram: cumulative-style buckets + exact count/sum/
    min/max.  ``observe`` is O(log buckets); quantiles are bucket-boundary
    estimates clamped into the exact [min, max] envelope, so ``q(0.5) <=
    q(0.95)`` holds by construction and a single observation reports itself
    exactly."""

    def __init__(self, name: str, buckets: Iterable[float], help: str = ""):
        self.name = name
        self.help = help
        self.uppers: List[float] = sorted(float(b) for b in buckets)
        if not self.uppers:
            raise ValueError("histogram needs at least one bucket bound")
        # counts[i] <-> uppers[i]; counts[-1] is the +inf overflow bucket
        self.counts: List[int] = [0] * (len(self.uppers) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.uppers, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-upper-bound estimate of the q-quantile (q in [0, 1])."""
        if self.count == 0:
            return 0.0
        target = max(1, math.ceil(q * self.count))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target:
                upper = self.uppers[i] if i < len(self.uppers) else self.max
                return float(min(max(upper, self.min), self.max))
        return float(self.max)

    def summary(self) -> Dict[str, float]:
        """The load_report / bench shape: count, mean, p50, p95, max."""
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.quantile(0.5),
            "p95": self.quantile(0.95),
            "max": self.max if self.count else 0.0,
        }


class MetricsRegistry:
    """Get-or-create instrument registry with one snapshot/exposition view.

    Not thread-safe by design: every scheduler/session owns its own registry
    and mutates it from its own control thread (the same discipline as the
    rest of the host-side bookkeeping).
    """

    def __init__(self) -> None:
        self._instruments: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kwargs):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name=name, help=help, **kwargs) if cls is not Histogram \
                else cls(name, kwargs["buckets"], help=help)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {type(inst).__name__}"
            )
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(
        self, name: str, buckets: Optional[Iterable[float]] = None, help: str = ""
    ) -> Histogram:
        return self._get(
            Histogram, name, help, buckets=tuple(buckets or LATENCY_BUCKETS_S)
        )

    def snapshot(self) -> Dict[str, object]:
        """One plain dict: scalars for counters/gauges, summary dicts for
        histograms — JSON-ready, the shape ``load_report`` re-exports."""
        out: Dict[str, object] = {}
        for name, inst in sorted(self._instruments.items()):
            out[name] = (
                inst.summary() if isinstance(inst, Histogram) else inst.value
            )
        return out

    def render(self) -> str:
        """Prometheus text exposition (text/plain; version 0.0.4)."""
        lines: List[str] = []
        for name, inst in sorted(self._instruments.items()):
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            if isinstance(inst, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {_fmt(inst.value)}")
            elif isinstance(inst, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {_fmt(inst.value)}")
            else:
                lines.append(f"# TYPE {name} histogram")
                cum = 0
                for upper, c in zip(inst.uppers, inst.counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{_fmt(upper)}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {inst.count}')
                lines.append(f"{name}_sum {_fmt(inst.sum)}")
                lines.append(f"{name}_count {inst.count}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() and abs(v) < 1e15 else repr(float(v))
