"""Tick-phase tracing: nested wall-clock spans, Perfetto + JSONL export.

A Tracer records *complete* spans (begin timestamp + duration, Chrome trace
``"ph": "X"``) around the phases of a scheduler tick — admission, gather,
forward+traceback, compaction, flush — so "where does a tick spend its
time" is a picture, not a guess.  Design constraints, in order:

  * off by default: every instrumented call site goes through
    :func:`span`, which returns a shared no-op context manager when the
    tracer is ``None`` — the disabled cost is one ``is None`` check;
  * cheap when on: a span is two ``perf_counter_ns`` calls and one tuple
    append (no dict building, no formatting) — well under the <2% budget
    against a millisecond-scale jitted tick;
  * standard consumers: ``write_chrome`` emits a ``trace.json`` loadable by
    Perfetto / ``chrome://tracing``; ``write_jsonl`` emits one structured
    event per line for ad-hoc processing.

Spans nest by time containment on one track, which is exactly how Perfetto
renders "X" events — a ``tick`` parent with phase children needs no
explicit parent ids.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, List, Optional, Tuple


class _Span:
    """Context manager for one live span (allocated only when tracing)."""

    __slots__ = ("tracer", "name", "t0")

    def __init__(self, tracer: "Tracer", name: str):
        self.tracer = tracer
        self.name = name

    def __enter__(self) -> "_Span":
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        self.tracer._events.append(
            (self.name, self.t0, time.perf_counter_ns() - self.t0)
        )


class _NullSpan:
    """The disabled path: one shared instance, no state, no clock reads."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


def span(tracer: Optional["Tracer"], name: str):
    """``with span(tracer, "gather"): ...`` — a real span when ``tracer`` is
    live, the shared no-op otherwise.  The ONE call-site idiom for optional
    tracing (hot paths never branch on telemetry themselves)."""
    return _NULL_SPAN if tracer is None else _Span(tracer, name)


class Tracer:
    """Span recorder for one instrumented component.

    Events live in memory as (name, t0_ns, dur_ns) tuples until exported;
    a steady server should export + ``clear()`` periodically (a span is 3
    machine words — ~1M spans per 100 MB)."""

    def __init__(self, process_name: str = "repro") -> None:
        self.process_name = process_name
        self._events: List[Tuple[str, int, int]] = []
        self._t_origin = time.perf_counter_ns()

    # ------------------------------ recording ------------------------------ #

    def span(self, name: str) -> _Span:
        return _Span(self, name)

    def instant(self, name: str) -> None:
        """Zero-duration marker (admissions, evictions, compactions)."""
        self._events.append((name, time.perf_counter_ns(), 0))

    def clear(self) -> None:
        self._events.clear()

    # ------------------------------ queries ------------------------------ #

    def __len__(self) -> int:
        return len(self._events)

    def durations_s(self, name: str) -> List[float]:
        """Seconds spent in every completed span called ``name``."""
        return [d * 1e-9 for n, _, d in self._events if n == name]

    def total_s(self, name: str) -> float:
        return sum(self.durations_s(name))

    def coverage(self, parent: str, children: Tuple[str, ...]) -> float:
        """Fraction of ``parent`` span time covered by ``children`` spans —
        the "do the phase spans account for the tick" acceptance number."""
        total = self.total_s(parent)
        if total == 0.0:
            return 0.0
        return sum(self.total_s(c) for c in children) / total

    # ------------------------------ export ------------------------------ #

    def chrome_events(self) -> List[Dict]:
        """Chrome trace event list (``ph: "X"`` complete events, µs units)."""
        tid = threading.get_ident() % 2 ** 31
        events: List[Dict] = [
            {
                "ph": "M",
                "name": "process_name",
                "pid": 1,
                "tid": tid,
                "args": {"name": self.process_name},
            }
        ]
        for name, t0, dur in self._events:
            events.append(
                {
                    "ph": "X",
                    "name": name,
                    "pid": 1,
                    "tid": tid,
                    "ts": (t0 - self._t_origin) / 1e3,
                    "dur": dur / 1e3,
                }
            )
        return events

    def write_chrome(self, path) -> None:
        """Perfetto / chrome://tracing loadable ``trace.json``."""
        payload = {
            "traceEvents": self.chrome_events(),
            "displayTimeUnit": "ms",
        }
        with open(path, "w") as f:
            json.dump(payload, f)

    def write_jsonl(self, path) -> None:
        """One structured event per line: {"name", "t_s", "dur_s"}."""
        with open(path, "w") as f:
            for name, t0, dur in self._events:
                f.write(
                    json.dumps(
                        {
                            "name": name,
                            "t_s": (t0 - self._t_origin) * 1e-9,
                            "dur_s": dur * 1e-9,
                        }
                    )
                    + "\n"
                )
