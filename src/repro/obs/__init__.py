"""Telemetry plane for the streaming decode system.

metrics.py — counters / gauges / fixed-bucket histograms, one registry with
             ``snapshot()`` and Prometheus text exposition; the shared
             ``percentile`` helper every latency summary must use.
trace.py   — tick-phase spans (admission / gather / step / commit / flush),
             exported as Perfetto ``trace.json`` + JSONL; one ``is None``
             check when disabled.
log.py     — structured key=value stdlib-logging wrapper for scripts.

:class:`Telemetry` bundles the per-component knobs: a metrics registry
(always on — a counter bump is an attribute add), an optional tracer (off
by default), and the ``device_counters`` flag that makes the jitted tick
accumulate per-stream decode statistics (survivor merge depth, starved
ticks, renormalization magnitude) into a device-resident buffer that is
flushed only at drain / report time — never one host sync per tick.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.log import ObsLogger, get_logger, kv
from repro.obs.metrics import (
    DEPTH_BUCKETS,
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    percentile,
)
from repro.obs.trace import Tracer, span


@dataclasses.dataclass
class Telemetry:
    """Per-component telemetry configuration + state.

    metrics:          registry the component records into (always live).
    tracer:           span recorder; ``None`` (default) disables tracing.
    device_counters:  collect per-stream decode counters inside the jitted
                      tick (merge depth, starved ticks, renorm magnitude).
                      Changes compiled shapes, so it is a construction-time
                      flag, not a runtime toggle.
    """

    metrics: MetricsRegistry = dataclasses.field(default_factory=MetricsRegistry)
    tracer: Optional[Tracer] = None
    device_counters: bool = False

    @classmethod
    def enabled(cls, device_counters: bool = True,
                process_name: str = "repro") -> "Telemetry":
        """Everything on: tracing + metrics + device-side counters."""
        return cls(
            metrics=MetricsRegistry(),
            tracer=Tracer(process_name),
            device_counters=device_counters,
        )


__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ObsLogger",
    "Telemetry",
    "Tracer",
    "DEPTH_BUCKETS",
    "LATENCY_BUCKETS_S",
    "get_logger",
    "kv",
    "percentile",
    "span",
]
