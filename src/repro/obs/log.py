"""Structured stdlib-logging wrapper for scripts and launch entry points.

Benchmarks and launchers used to report through bare ``print()`` — not
level-gated, not grep-able, impossible to silence in CI pipelines that only
want the JSON artifact.  This wrapper keeps the human-readable line but
routes it through ``logging`` with a ``key=value`` structured suffix:

    log = get_logger("bench.stream", quiet=args.quiet)
    log.info("online sustained", bits_per_s=123456, p95_s=0.41)
    # -> "online sustained bits_per_s=123456 p95_s=0.41"

``quiet=True`` gates the logger to WARNING, so ``--quiet`` script runs emit
nothing on stdout but still surface failures.  Handlers are installed once
per logger name and never propagate, so importing a benchmark module twice
(CI does, via the schema check) cannot double every line.
"""
from __future__ import annotations

import logging
import sys
from typing import Optional, TextIO


def kv(**fields) -> str:
    """Render fields as a ``k=v`` line fragment.  Floats compact to 6
    significant digits; strings with spaces are repr-quoted."""
    parts = []
    for k, v in fields.items():
        if isinstance(v, float):
            parts.append(f"{k}={v:.6g}")
        elif isinstance(v, str) and (" " in v or not v):
            parts.append(f"{k}={v!r}")
        else:
            parts.append(f"{k}={v}")
    return " ".join(parts)


class ObsLogger:
    """Thin wrapper: ``info("msg", key=val)`` == message + kv suffix."""

    def __init__(self, logger: logging.Logger):
        self._logger = logger

    def _emit(self, level: int, msg: str, fields) -> None:
        if fields and self._logger.isEnabledFor(level):
            msg = f"{msg} {kv(**fields)}" if msg else kv(**fields)
        self._logger.log(level, msg)

    def debug(self, msg: str = "", **fields) -> None:
        self._emit(logging.DEBUG, msg, fields)

    def info(self, msg: str = "", **fields) -> None:
        self._emit(logging.INFO, msg, fields)

    def warning(self, msg: str = "", **fields) -> None:
        self._emit(logging.WARNING, msg, fields)

    def error(self, msg: str = "", **fields) -> None:
        self._emit(logging.ERROR, msg, fields)

    def setLevel(self, level) -> None:
        self._logger.setLevel(level)


def get_logger(
    name: str,
    quiet: bool = False,
    stream: Optional[TextIO] = None,
) -> ObsLogger:
    """Level-gated structured logger writing plain lines to ``stream``
    (default stdout — scripts are reporting tools, their output IS stdout).

    Args:
      name: dotted logger name (``bench.stream``, ``launch.dryrun``).
      quiet: gate to WARNING — the ``--quiet`` flag every script exposes.
      stream: override the output stream (tests capture with StringIO).
    """
    logger = logging.getLogger(f"repro.{name}")
    logger.propagate = False
    # one handler per logger, replaced (not appended) on reconfiguration so
    # repeated get_logger calls never multiply output lines
    handler = logging.StreamHandler(stream or sys.stdout)
    handler.setFormatter(logging.Formatter("%(message)s"))
    logger.handlers[:] = [handler]
    logger.setLevel(logging.WARNING if quiet else logging.INFO)
    return ObsLogger(logger)
