"""Encoder-decoder assembly (seamless-m4t family).

Encoder: bidirectional attention blocks over (stub) audio frame embeddings —
the modality frontend provides precomputed (B, S_enc, frontend_dim) frames
per the assignment; a linear projector maps them into d_model.

Decoder: causal self-attention + cross-attention + MLP blocks over text
tokens, with a self KV cache and precomputed cross K/V for serving.

Shape conventions (documented in DESIGN.md):
  train:   S_enc = shape.seq_len frames, S_dec = seq_len // dec_ratio tokens
  prefill: encoder forward over seq_len + cross-KV precompute + decoder
           prefill over seq_len // dec_ratio
  decode:  one decoder token against a self cache of seq_len and cross K/V
           of length seq_len (the cell's "KV cache of seq_len").
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.attention import (
    attention_specs,
    cross_attention,
    cross_kv,
    self_attention,
    self_attention_decode,
)
from repro.models.mlp import mlp_apply, mlp_specs
from repro.models.transformer import (
    _remat_policy,
    lm_head,
    remat_scan,
    softmax_xent,
)


# --------------------------------------------------------------------------- #
# Specs                                                                        #
# --------------------------------------------------------------------------- #


def encdec_specs(cfg, part) -> Dict[str, Any]:
    d = cfg.d_model
    enc_stack = cfg.enc_layers
    dec_stack = cfg.n_layers
    p: Dict[str, Any] = {
        "frontend_proj": cm.dense_spec((cfg.frontend_dim,), (d,), ("frontend",), ("embed",)),
        "embed": cm.embed_spec(cfg.vocab, d),
        "encoder": {
            "ln1": cm.norm_spec(d, stack=enc_stack),
            "attn": attention_specs(cfg, enc_stack),
            "ln2": cm.norm_spec(d, stack=enc_stack),
            "mlp": mlp_specs(cfg, enc_stack),
        },
        "enc_norm": cm.norm_spec(d, stack=0),
        "decoder": {
            "ln1": cm.norm_spec(d, stack=dec_stack),
            "self": attention_specs(cfg, dec_stack),
            "ln_cross": cm.norm_spec(d, stack=dec_stack),
            "cross": attention_specs(cfg, dec_stack),
            "ln2": cm.norm_spec(d, stack=dec_stack),
            "mlp": mlp_specs(cfg, dec_stack),
        },
        "final_norm": cm.norm_spec(d, stack=0),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = cm.dense_spec((d,), (cfg.vocab,), ("embed",), ("vocab",))
    return p


def encdec_cache_specs(cfg, part, B: int, S: int) -> Dict[str, Any]:
    """Self cache (dec_stack, B, S, KV, hd) + cross K/V of the same S_enc=S."""
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dec_stack = cfg.n_layers
    seq_ax = "kv_seq" if part.flash_decode else None
    kv = cm.ParamSpec(
        (dec_stack, B, S, KV, hd),
        ("layers", "batch", seq_ax, "kv_heads", "head_dim"),
        "zeros", dtype=jnp.bfloat16)
    return {"self": {"k": kv, "v": kv}, "cross": {"k": kv, "v": kv}}


# --------------------------------------------------------------------------- #
# Encoder                                                                      #
# --------------------------------------------------------------------------- #


def encode_frames(params, cfg, part, frames, mesh=None, rules=None):
    """frames: (B, S_enc, frontend_dim) -> (B, S_enc, d)."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = cm.dense(params["frontend_proj"], frames, "...f,fd->...d", cd)
    if mesh is not None:
        x = cm.constrain(x, mesh, rules, ("batch", None, None))

    def layer_fn(x, lp):
        h = cm.rmsnorm(lp["ln1"], x, cfg.norm_eps, compute_dtype=cd)
        y, _ = self_attention(lp["attn"], cfg, part, h, kind="attn_bidir", mesh=mesh)
        x = x + y
        h = cm.rmsnorm(lp["ln2"], x, cfg.norm_eps, compute_dtype=cd)
        x = x + mlp_apply(lp["mlp"], cfg, h)
        return x, None

    policy = _remat_policy(part)
    if policy is not None:
        layer_fn = jax.checkpoint(layer_fn, policy=policy)
    x, _ = remat_scan(layer_fn, x, params["encoder"], cfg.enc_layers, policy)
    return cm.rmsnorm(params["enc_norm"], x, cfg.norm_eps, compute_dtype=cd)


def encode_cross_kv(params, cfg, enc_out):
    """Per-decoder-layer cross K/V from encoder output: (L, B, S, KV, hd)."""

    def one_layer(_, lp):
        kv = cross_kv(lp["cross"], cfg, enc_out)
        return None, (kv["k"].astype(jnp.bfloat16), kv["v"].astype(jnp.bfloat16))

    _, (ks, vs) = jax.lax.scan(one_layer, None, params["decoder"])
    return {"k": ks, "v": vs}


# --------------------------------------------------------------------------- #
# Decoder                                                                      #
# --------------------------------------------------------------------------- #


def _dec_layer_full(lp, cfg, part, x, enc_out, self_cache, mesh):
    """One decoder layer.  Cross K/V are computed HERE from enc_out (and
    recomputed in backward under remat) — precomputing all layers' cross
    K/V up front costs L×(B,S_enc,KV,hd)×2 live tensors, which dominated
    the enc-dec train cells."""
    cd = jnp.dtype(cfg.compute_dtype)
    h = cm.rmsnorm(lp["ln1"], x, cfg.norm_eps, compute_dtype=cd)
    y, new_self = self_attention(
        lp["self"], cfg, part, h, kind="attn", cache=self_cache, mesh=mesh)
    x = x + y
    h = cm.rmsnorm(lp["ln_cross"], x, cfg.norm_eps, compute_dtype=cd)
    kv = cross_kv(lp["cross"], cfg, enc_out)
    x = x + cross_attention(lp["cross"], cfg, part, h, enc_kv=kv, mesh=mesh)
    h = cm.rmsnorm(lp["ln2"], x, cfg.norm_eps, compute_dtype=cd)
    x = x + mlp_apply(lp["mlp"], cfg, h)
    return x, new_self


def decoder_forward(params, cfg, part, tokens, enc_out, *,
                    self_caches=None, mesh=None, rules=None):
    """Teacher-forced decoder.  tokens: (B, S_dec); enc_out: (B, S_enc, d).
    Returns (hidden, new self caches or None)."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = cm.embed_lookup(params["embed"], tokens, cd)

    def layer_fn(x, xs):
        lp, sc = xs
        x, new_self = _dec_layer_full(lp, cfg, part, x, enc_out, sc, mesh)
        return x, new_self

    policy = _remat_policy(part)
    if policy is not None:
        layer_fn = jax.checkpoint(layer_fn, policy=policy)
    x, new_selfs = remat_scan(
        layer_fn, x, (params["decoder"], self_caches),
        cfg.n_layers, policy)
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps, compute_dtype=cd)
    return x, (new_selfs if self_caches is not None else None)


# --------------------------------------------------------------------------- #
# Top-level steps                                                              #
# --------------------------------------------------------------------------- #


def encdec_train_loss(params, cfg, part, batch, mesh=None, rules=None):
    """batch: {"frames": (B,S_enc,F), "tokens": (B,S_dec), "labels": (B,S_dec)}."""
    enc_out = encode_frames(params, cfg, part, batch["frames"], mesh, rules)
    x, _ = decoder_forward(params, cfg, part, batch["tokens"], enc_out,
                           mesh=mesh, rules=rules)
    logits = lm_head(params, cfg, x)
    loss = softmax_xent(logits, batch["labels"], batch.get("valid"), mesh=mesh)
    return loss, {"loss": loss}


def encdec_prefill(params, cfg, part, batch, caches, *, mesh=None, rules=None):
    """Encoder forward + cross-KV precompute + decoder prefill.

    batch: {"frames": (B, S_enc, F), "tokens": (B, S_dec)}.
    caches: {"self": ..., "cross": ...} with S = S_enc (cross) / >=S_dec (self).
    """
    enc_out = encode_frames(params, cfg, part, batch["frames"], mesh, rules)
    cross = encode_cross_kv(params, cfg, enc_out)
    # write cross K/V into the (possibly longer) cross cache
    ck = jax.lax.dynamic_update_slice_in_dim(
        caches["cross"]["k"], cross["k"].astype(caches["cross"]["k"].dtype), 0, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(
        caches["cross"]["v"], cross["v"].astype(caches["cross"]["v"].dtype), 0, axis=2)
    x, new_selfs = decoder_forward(
        params, cfg, part, batch["tokens"], enc_out,
        self_caches=caches["self"], mesh=mesh, rules=rules)
    logits = lm_head(params, cfg, x[:, -1:])[:, 0]
    return logits, {"self": new_selfs, "cross": {"k": ck, "v": cv}}


def encdec_decode_step(params, cfg, part, tokens, positions, caches, *,
                       mesh=None, rules=None):
    """One decoder token.  tokens: (B,1); caches: {"self","cross"} stacked."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = cm.embed_lookup(params["embed"], tokens, cd)

    def layer_fn(x, xs):
        lp, sc, ck, cv = xs
        h = cm.rmsnorm(lp["ln1"], x, cfg.norm_eps, compute_dtype=cd)
        y, new_self = self_attention_decode(
            lp["self"], cfg, part, h, kind="attn", positions=positions,
            cache=sc, mesh=mesh)
        x = x + y
        h = cm.rmsnorm(lp["ln_cross"], x, cfg.norm_eps, compute_dtype=cd)
        x = x + cross_attention(lp["cross"], cfg, part, h,
                                enc_kv={"k": ck, "v": cv}, decode=True, mesh=mesh)
        h = cm.rmsnorm(lp["ln2"], x, cfg.norm_eps, compute_dtype=cd)
        x = x + mlp_apply(lp["mlp"], cfg, h)
        return x, new_self

    x, new_selfs = jax.lax.scan(
        layer_fn, x,
        (params["decoder"], caches["self"], caches["cross"]["k"], caches["cross"]["v"]))
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps, compute_dtype=cd)
    logits = lm_head(params, cfg, x)[:, 0]
    return logits, {"self": new_selfs, "cross": caches["cross"]}
