"""Decoder-only LM assembly: heterogeneous block stacks, scan-over-groups,
train / prefill / decode paths, cache management.

The layer stack is organized as ``n_groups`` repetitions of the config's
``pattern`` (a tuple of (mixer, ffn) block kinds).  All parameters of block
position ``p`` are stacked over groups, and the forward pass is a
``lax.scan`` over groups — HLO size and compile time are O(group), not
O(n_layers).  Heterogeneous stacks (gemma3 5:1 local:global, jamba 1:7
attn:mamba, xlstm 7:1 mLSTM:sLSTM) scan over the repeating group.

Caches are pytrees stacked the same way ((n_groups, ...) leading dim) so the
decode step scans over (params, caches) jointly.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import (
    attention_specs,
    self_attention,
    self_attention_decode,
)
from repro.models.mlp import mlp_apply, mlp_specs

ATTN_KINDS = ("attn", "attn_bidir", "attn_local")


# --------------------------------------------------------------------------- #
# Block specs                                                                  #
# --------------------------------------------------------------------------- #


def _mixer_specs(cfg, mixer: str, stack: int):
    if mixer in ATTN_KINDS:
        return attention_specs(cfg, stack)
    if mixer == "mla":
        return mla_mod.mla_specs(cfg, stack)
    if mixer == "mamba":
        return ssm_mod.ssm_specs(cfg, stack)
    if mixer == "mlstm":
        return xlstm_mod.mlstm_specs(cfg, stack)
    if mixer == "slstm":
        return xlstm_mod.slstm_specs(cfg, stack)
    raise ValueError(f"unknown mixer {mixer}")


def _ffn_specs(cfg, ffn: str, stack: int):
    if ffn == "mlp":
        return mlp_specs(cfg, stack)
    if ffn == "moe":
        return moe_mod.moe_specs(cfg, stack)
    if ffn == "none":
        return None
    raise ValueError(f"unknown ffn {ffn}")


def block_specs(cfg, mixer: str, ffn: str, stack: int, cross: bool = False):
    style = "rms"
    p: Dict[str, Any] = {
        "ln1": cm.norm_spec(cfg.d_model, stack=stack, style=style),
        "mixer": _mixer_specs(cfg, mixer, stack),
    }
    if cfg.norm_style == "sandwich":
        p["ln1_post"] = cm.norm_spec(cfg.d_model, stack=stack, style=style)
    if cross:
        p["ln_cross"] = cm.norm_spec(cfg.d_model, stack=stack, style=style)
        p["cross"] = attention_specs(cfg, stack)
    f = _ffn_specs(cfg, ffn, stack)
    if f is not None:
        p["ln2"] = cm.norm_spec(cfg.d_model, stack=stack, style=style)
        p["ffn"] = f
        if cfg.norm_style == "sandwich":
            p["ln2_post"] = cm.norm_spec(cfg.d_model, stack=stack, style=style)
    return p


def lm_specs(cfg, part) -> Dict[str, Any]:
    """Full parameter spec tree for a decoder-only LM."""
    stack = cfg.n_groups
    p: Dict[str, Any] = {"embed": cm.embed_spec(cfg.vocab, cfg.d_model)}
    p["blocks"] = {
        f"p{i}": block_specs(cfg, mixer, ffn, stack)
        for i, (mixer, ffn) in enumerate(cfg.pattern)
    }
    p["final_norm"] = cm.norm_spec(cfg.d_model, stack=0)
    if not cfg.tie_embeddings:
        p["lm_head"] = cm.dense_spec(
            (cfg.d_model,), (cfg.vocab,), ("embed",), ("vocab",), scale=1.0
        )
    if cfg.modality == "vision":
        p["frontend_proj"] = cm.dense_spec(
            (cfg.frontend_dim,), (cfg.d_model,), ("frontend",), ("embed",)
        )
    return p


# --------------------------------------------------------------------------- #
# Cache specs                                                                  #
# --------------------------------------------------------------------------- #


def _mixer_cache_specs(cfg, part, mixer: str, B: int, S: int, stack: int):
    """ParamSpec tree for one mixer's decode cache (stacked over groups).

    Logical axes: 'kv_seq' shards the cache sequence dim over 'model' when
    flash-decode is on (resolve_axes drops it gracefully otherwise).
    """
    bf16 = jnp.bfloat16
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    seq_ax = "kv_seq" if part.flash_decode else None
    L = ("layers",)

    def PS(shape, axes, dtype=bf16):
        return cm.ParamSpec((stack,) + shape, L + axes, "zeros", dtype=dtype)

    if mixer in ("attn", "attn_bidir"):
        kv = PS((B, S, KV, hd), ("batch", seq_ax, "kv_heads", "head_dim"))
        return {"k": kv, "v": kv}
    if mixer == "attn_local":
        W = min(cfg.window, S)
        kv = PS((B, W, KV, hd), ("batch", None, "kv_heads", "head_dim"))
        pos = PS((B, W), ("batch", None), dtype=jnp.int32)
        return {"k": kv, "v": kv, "pos": pos}
    if mixer == "mla":
        m = cfg.mla
        return {
            "c_kv": PS((B, S, m.kv_lora_rank), ("batch", seq_ax, "kv_lora")),
            "k_rope": PS((B, S, m.rope_head_dim), ("batch", seq_ax, "head_dim")),
        }
    if mixer == "mamba":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        return {
            "ssm": PS((B, d_in, s.d_state), ("batch", "dinner", "dstate"), jnp.float32),
            "conv": PS((B, s.d_conv - 1, d_in), ("batch", None, "dinner")),
        }
    if mixer == "mlstm":
        x = cfg.xlstm
        d_in = int(x.mlstm_proj_factor * cfg.d_model)
        H = cfg.n_heads
        dh = d_in // H
        return {
            "C": PS((B, H, dh, dh), ("batch", "heads", None, None), jnp.float32),
            "n": PS((B, H, dh), ("batch", "heads", None), jnp.float32),
            "m": PS((B, H), ("batch", "heads"), jnp.float32),
            "conv": PS((B, x.conv_kernel - 1, d_in), ("batch", None, "dinner")),
        }
    if mixer == "slstm":
        d = cfg.d_model
        st = {
            k: PS((B, d), ("batch", "dinner"), jnp.float32) for k in ("c", "n", "h", "m")
        }
        return {"state": st}
    raise ValueError(mixer)


def cache_specs(cfg, part, B: int, S: int) -> Dict[str, Any]:
    stack = cfg.n_groups
    return {
        f"p{i}": _mixer_cache_specs(cfg, part, mixer, B, S, stack)
        for i, (mixer, _) in enumerate(cfg.pattern)
    }


def init_cache(cfg, part, B: int, S: int):
    """Zero caches (slstm m / mlstm m start at -inf; attn_local pos at -1)."""
    specs = cache_specs(cfg, part, B, S)
    caches = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), specs, is_leaf=cm._is_spec
    )
    for i, (mixer, _) in enumerate(cfg.pattern):
        c = caches[f"p{i}"]
        if mixer == "attn_local":
            c["pos"] = jnp.full_like(c["pos"], -1)
        elif mixer == "mlstm":
            c["m"] = jnp.full_like(c["m"], -1e30)
        elif mixer == "slstm":
            c["state"]["m"] = jnp.full_like(c["state"]["m"], -1e30)
    return caches


# --------------------------------------------------------------------------- #
# Block application                                                            #
# --------------------------------------------------------------------------- #


def _norm(params, cfg, x):
    return cm.rmsnorm(params, x, cfg.norm_eps, compute_dtype=jnp.dtype(cfg.compute_dtype))


def apply_block_full(
    bp, cfg, part, mixer: str, ffn: str, x, *,
    positions=None, cache=None, mesh=None, rules=None,
):
    """Full-sequence block (train / prefill).  Returns (x, new_cache, aux)."""
    h = _norm(bp["ln1"], cfg, x)
    new_cache = None
    if mixer in ATTN_KINDS:
        y, new_cache = self_attention(
            bp["mixer"], cfg, part, h, kind=mixer, positions=positions,
            cache=cache, mesh=mesh)
    elif mixer == "mla":
        y, new_cache = mla_mod.mla_attention(
            bp["mixer"], cfg, part, h, positions=positions, cache=cache)
    elif mixer == "mamba":
        y, new_cache = ssm_mod.ssm_apply(bp["mixer"], cfg, h, cache=cache)
    elif mixer == "mlstm":
        y, new_cache = xlstm_mod.mlstm_apply(bp["mixer"], cfg, h, cache=cache)
    elif mixer == "slstm":
        y, new_cache = xlstm_mod.slstm_apply(bp["mixer"], cfg, h, cache=cache)
    else:
        raise ValueError(mixer)
    if cfg.norm_style == "sandwich":
        y = _norm(bp["ln1_post"], cfg, y)
    x = x + y
    aux = {}
    if ffn != "none":
        h = _norm(bp["ln2"], cfg, x)
        if ffn == "mlp":
            y = mlp_apply(bp["ffn"], cfg, h)
        else:
            y, aux = moe_mod.moe_apply(bp["ffn"], cfg, h, mesh=mesh)
        if cfg.norm_style == "sandwich":
            y = _norm(bp["ln2_post"], cfg, y)
        x = x + y
    if part.seq_shard_activations and mesh is not None:
        x = cm.constrain(x, mesh, rules, ("batch", "seq_shard", None))
    return x, new_cache, aux


def _local_ring_decode(params, cfg, part, x, *, positions, cache):
    """Sliding-window decode against a ring cache of width W.

    cache: k/v (B, W, KV, hd) with RoPE pre-applied at write; pos (B, W)
    absolute positions (-1 = empty).  New entry lands in slot pos % W — the
    ring invariant keeps exactly the last W positions resident, so validity
    is just ``pos >= 0``.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    B = x.shape[0]
    W = cache["k"].shape[1]
    q = cm.dense(params["wq"], x, "...d,dhk->...hk", cd)
    k_new = cm.dense(params["wk"], x, "...d,dhk->...hk", cd)
    v_new = cm.dense(params["wv"], x, "...d,dhk->...hk", cd)
    if cfg.qk_norm:
        q = cm.headwise_rmsnorm(params["qknorm"]["q_scale"], q, cfg.norm_eps)
        k_new = cm.headwise_rmsnorm(params["qknorm"]["k_scale"], k_new, cfg.norm_eps)
    cos, sin = cm.rope_angles(positions[:, None], hd, cfg.rope_local_theta)
    q = cm.apply_rope(q, cos, sin)
    k_new = cm.apply_rope(k_new, cos, sin)
    slot = (positions % W).astype(jnp.int32)
    iota = jnp.arange(W).reshape(1, -1, 1, 1)
    sel = iota == slot.reshape(B, 1, 1, 1)
    k_cache = jnp.where(sel, k_new.astype(cache["k"].dtype), cache["k"])
    v_cache = jnp.where(sel, v_new.astype(cache["v"].dtype), cache["v"])
    pos_arr = jnp.where(
        jnp.arange(W)[None, :] == slot[:, None], positions[:, None], cache["pos"]
    ).astype(cache["pos"].dtype)
    # attend over valid ring slots
    KV = cfg.n_kv_heads
    H = cfg.n_heads
    G = H // KV
    q4 = (q[:, 0] * (hd ** -0.5)).reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", q4, k_cache.astype(cd))
    s = s.astype(jnp.float32)
    if cfg.logit_softcap:
        s = jnp.tanh(s / cfg.logit_softcap) * cfg.logit_softcap
    valid = pos_arr >= 0
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(cd), v_cache.astype(cd))
    out = out.reshape(B, 1, H, hd)
    y = cm.dense(params["wo"], out, "...hk,hkd->...d", cd)
    return y, {"k": k_cache, "v": v_cache, "pos": pos_arr}


def apply_block_decode(
    bp, cfg, part, mixer: str, ffn: str, x, *, positions, cache, mesh=None, rules=None
):
    """Single-token block.  x: (B, 1, d).  Returns (x, new_cache, aux)."""
    h = _norm(bp["ln1"], cfg, x)
    if mixer in ("attn", "attn_bidir"):
        y, new_cache = self_attention_decode(
            bp["mixer"], cfg, part, h, kind=mixer, positions=positions,
            cache=cache, mesh=mesh)
    elif mixer == "attn_local":
        y, new_cache = _local_ring_decode(
            bp["mixer"], cfg, part, h, positions=positions, cache=cache)
    elif mixer == "mla":
        y, new_cache = mla_mod.mla_attention_decode(
            bp["mixer"], cfg, part, h, positions=positions, cache=cache)
    elif mixer == "mamba":
        y, new_cache = ssm_mod.ssm_decode(bp["mixer"], cfg, h, cache=cache)
    elif mixer == "mlstm":
        y, new_cache = xlstm_mod.mlstm_decode(bp["mixer"], cfg, h, cache=cache)
    elif mixer == "slstm":
        y, new_cache = xlstm_mod.slstm_decode(bp["mixer"], cfg, h, cache=cache)
    else:
        raise ValueError(mixer)
    if cfg.norm_style == "sandwich":
        y = _norm(bp["ln1_post"], cfg, y)
    x = x + y
    if ffn != "none":
        h = _norm(bp["ln2"], cfg, x)
        if ffn == "mlp":
            y = mlp_apply(bp["ffn"], cfg, h)
        else:
            y, _ = moe_mod.moe_apply(bp["ffn"], cfg, h, mesh=mesh)
        if cfg.norm_style == "sandwich":
            y = _norm(bp["ln2_post"], cfg, y)
        x = x + y
    return x, new_cache


# --------------------------------------------------------------------------- #
# Group scan                                                                   #
# --------------------------------------------------------------------------- #


def _remat_policy(part):
    if part.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    if part.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def remat_scan(body, carry, xs, n: int, policy, scan: bool = True):
    """O(sqrt(L)) recursive activation checkpointing over a layer scan.

    A flat ``lax.scan`` backward stores every iteration's residuals —
    O(L·block) memory even with block-level remat (measured: ~2.8 GiB/layer
    on the 110B config).  Factoring the scan as outer(≈sqrt L, checkpointed)
    × inner(sqrt L) stores only outer boundaries plus one inner pass:
    O(sqrt(L)·carry + block).
    """
    if not scan:
        ys = []
        for g in range(n):
            xg = jax.tree_util.tree_map(lambda a: a[g], xs)
            carry, y = body(carry, xg)
            ys.append(y)
        if all(y is None for y in ys):
            return carry, None
        return carry, jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)

    if policy is None or n < 4:
        return jax.lax.scan(body, carry, xs)

    import math

    no = int(math.ceil(math.sqrt(n)))
    while n % no:
        no += 1
    ni = n // no
    xs2 = jax.tree_util.tree_map(
        lambda a: a.reshape((no, ni) + a.shape[1:]), xs)

    def outer(c, xo):
        return jax.lax.scan(body, c, xo)

    outer = jax.checkpoint(outer, policy=policy)
    carry, ys2 = jax.lax.scan(outer, carry, xs2)
    if ys2 is None:
        return carry, None
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((n,) + a.shape[2:]), ys2)
    return carry, ys


def run_stack_full(
    params_blocks, cfg, part, x, *,
    positions=None, caches=None, mesh=None, rules=None, collect_aux=True,
):
    """Scan the (stacked) block groups over a full-sequence input.

    caches: optional stacked cache tree (prefill) — consumed/produced as
    scan xs/ys.  Returns (x, new_caches, aux_sums).
    """
    policy = _remat_policy(part)

    def group_fn(carry, xs):
        x, aux_acc = carry
        gp, gc = xs
        new_caches = {}
        for i, (mixer, ffn) in enumerate(cfg.pattern):
            cache_i = None if gc is None else gc.get(f"p{i}")
            def block_fn(bp, x, cache, _mixer=mixer, _ffn=ffn):
                return apply_block_full(
                    bp, cfg, part, _mixer, _ffn, x,
                    positions=positions, cache=cache, mesh=mesh, rules=rules)

            if policy is not None:
                # remat at BLOCK granularity: backward recomputes one block's
                # internals at a time (peak = one block, not a whole group)
                block_fn = jax.checkpoint(block_fn, policy=policy)
            x, nc, aux = block_fn(gp[f"p{i}"], x, cache_i)
            if nc is not None:
                new_caches[f"p{i}"] = nc
            if aux and collect_aux:
                aux_acc = (
                    aux_acc[0] + aux.get("load_balance_loss", 0.0),
                    aux_acc[1] + aux.get("router_z_loss", 0.0),
                )
        return (x, aux_acc), (new_caches if gc is not None else None)

    aux0 = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    (x, aux), new_caches = remat_scan(
        group_fn, (x, aux0), (params_blocks, caches), cfg.n_groups, policy,
        scan=part.scan_layers)
    return x, new_caches, {"load_balance_loss": aux[0], "router_z_loss": aux[1]}


def run_stack_decode(
    params_blocks, cfg, part, x, *, positions, caches, mesh=None, rules=None
):
    """Scan block groups for one decode step; caches are scan xs -> ys."""

    def group_fn(x, xs):
        gp, gc = xs
        new_caches = {}
        for i, (mixer, ffn) in enumerate(cfg.pattern):
            x, nc = apply_block_decode(
                gp[f"p{i}"], cfg, part, mixer, ffn, x,
                positions=positions, cache=gc[f"p{i}"], mesh=mesh, rules=rules)
            new_caches[f"p{i}"] = nc
        return x, new_caches

    if part.scan_layers:
        x, new_caches = jax.lax.scan(group_fn, x, (params_blocks, caches))
    else:
        outs = []
        for g in range(cfg.n_groups):
            gp = jax.tree_util.tree_map(lambda a: a[g], params_blocks)
            gc = jax.tree_util.tree_map(lambda a: a[g], caches)
            x, yc = group_fn(x, (gp, gc))
            outs.append(yc)
        new_caches = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *outs)
    return x, new_caches


# --------------------------------------------------------------------------- #
# Embedding / head                                                             #
# --------------------------------------------------------------------------- #


def embed_tokens(params, cfg, tokens, patches=None):
    """tokens: (B, S_tok); patches: (B, n_prefix, frontend_dim) for VLMs.
    Returns (B, S, d) with patches projected and prefixed."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = cm.embed_lookup(params["embed"], tokens, cd)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cd)
    if patches is not None:
        px = cm.dense(params["frontend_proj"], patches, "...f,fd->...d", cd)
        x = jnp.concatenate([px, x], axis=1)
    return x


def lm_head(params, cfg, x):
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        w = params["embed"]["embedding"].astype(cd)  # (V, d)
        return jnp.einsum("...d,vd->...v", x, w)
    return cm.dense(params["lm_head"], x, "...d,dv->...v", cd)


def softmax_xent(logits, labels, valid=None, z_weight: float = 0.0, mesh=None):
    """Cross-entropy in f32.  logits: (B,S,V); labels: (B,S) int32.

    On a mesh with a 'model' axis the loss runs under shard_map with the
    vocab dim sharded: per-shard masked gold-gather + psum, and a
    pmax/psum-logsumexp — no (B,S,V)-sized intermediate beyond the local
    bf16 logits ever materializes.  (A plain take_along_axis over the
    vocab-sharded dim makes GSPMD gather full f32 logits per chip; a
    one-hot einsum materializes (B,S,V) iota/pred/f32 masks.)"""
    if mesh is not None and "model" in mesh.shape and \
            logits.shape[-1] % mesh.shape["model"] == 0:
        nll, lse = _xent_sharded(logits, labels, mesh)
    else:
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
        nll = lse - gold
    if valid is None:
        valid = jnp.ones_like(nll)
    else:
        valid = valid.astype(jnp.float32)
    denom = jnp.maximum(valid.sum(), 1.0)
    loss = (nll * valid).sum() / denom
    if z_weight:
        loss = loss + z_weight * ((lse ** 2) * valid).sum() / denom
    return loss


def _xent_sharded(logits, labels, mesh):
    """Vocab-sharded NLL: returns (nll (B,S), lse (B,S)) f32."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    V = logits.shape[-1]
    n = mesh.shape["model"]
    v_loc = V // n
    ba = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in ba:
        dp *= mesh.shape[a]
    bspec = (ba if len(ba) > 1 else ba[0]) if (ba and logits.shape[0] % dp == 0) \
        else None

    def f(lg, lb):  # lg: (Bl, S, v_loc) bf16; lb: (Bl, S)
        lg = lg.astype(jnp.float32)
        off = jax.lax.axis_index("model") * v_loc
        loc = lb - off
        ok = (loc >= 0) & (loc < v_loc)
        gold_l = jnp.take_along_axis(
            lg, jnp.clip(loc, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
        gold = jax.lax.psum(jnp.where(ok, gold_l, 0.0), "model")
        # stabilizer only -> constant under differentiation (pmax has no VJP;
        # stop_gradient BEFORE pmax so AD sees a symbolic-zero tangent)
        m = jax.lax.pmax(jax.lax.stop_gradient(lg.max(axis=-1)), "model")
        sumexp = jax.lax.psum(jnp.exp(lg - m[..., None]).sum(axis=-1), "model")
        lse = m + jnp.log(sumexp)
        return lse - gold, lse

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(bspec, None, "model"), P(bspec, None)),
        out_specs=(P(bspec, None), P(bspec, None)),
        check_rep=False,
    )(logits, labels)


# --------------------------------------------------------------------------- #
# Top-level LM functions                                                       #
# --------------------------------------------------------------------------- #


def lm_train_loss(params, cfg, part, batch, mesh=None, rules=None):
    """batch: {"tokens": (B,S), "labels": (B,S)} (+ "patches" for VLM).
    Returns (loss, metrics)."""
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens, batch.get("patches"))
    if mesh is not None:
        x = cm.constrain(x, mesh, rules, ("batch", None, None))
    x, _, aux = run_stack_full(
        params["blocks"], cfg, part, x, mesh=mesh, rules=rules)
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps,
                   compute_dtype=jnp.dtype(cfg.compute_dtype))
    logits = lm_head(params, cfg, x)
    labels = batch["labels"]
    if cfg.modality == "vision" and cfg.n_prefix_tokens:
        # patch positions carry no next-token target
        logits = logits[:, cfg.n_prefix_tokens:]
    loss = softmax_xent(logits, labels, batch.get("valid"), mesh=mesh)
    total = loss
    if cfg.moe is not None:
        total = total + cfg.moe.aux_loss_weight * aux["load_balance_loss"] \
            + 1e-3 * aux["router_z_loss"]
    metrics = {"loss": loss, **aux}
    return total, metrics


def lm_prefill(params, cfg, part, tokens, caches, *,
               patches=None, mesh=None, rules=None):
    """Prefill: run the full sequence, writing decode caches.

    Returns (logits_last (B, V), caches)."""
    x = embed_tokens(params, cfg, tokens, patches)
    if mesh is not None:
        # pin batch sharding: without this GSPMD derives a batch-replicated
        # layout from the weight shardings (measured: gemma3 prefill carried
        # full-batch f32 activations on every chip)
        x = cm.constrain(x, mesh, rules, ("batch", None, None))
    x, new_caches, _ = run_stack_full(
        params["blocks"], cfg, part, x, caches=caches, mesh=mesh, rules=rules,
        collect_aux=False)
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps,
                   compute_dtype=jnp.dtype(cfg.compute_dtype))
    logits = lm_head(params, cfg, x[:, -1:])[:, 0]
    return logits, new_caches


def lm_decode_step(params, cfg, part, tokens, positions, caches, *,
                   mesh=None, rules=None):
    """One decode step.  tokens: (B, 1); positions: (B,).
    Returns (logits (B, V), new caches)."""
    x = embed_tokens(params, cfg, tokens)
    x, new_caches = run_stack_decode(
        params["blocks"], cfg, part, x, positions=positions, caches=caches,
        mesh=mesh, rules=rules)
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps,
                   compute_dtype=jnp.dtype(cfg.compute_dtype))
    logits = lm_head(params, cfg, x)[:, 0]
    return logits, new_caches
