"""Attention: chunked (online-softmax) training/prefill attention, sliding
window, GQA, qk-norm, cross-attention, and two decode paths (local
full-cache, and seq-sharded flash-decode via shard_map).

No S×S score matrix is ever materialized: prefill_32k and train_4k run in
O(chunk_q × chunk_kv) score blocks (pure-JAX flash attention), with the
per-KV-block inner step checkpointed so the backward pass recomputes score
blocks instead of saving them.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm

NEG_INF = -1e30


def _softcap(x, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(x / cap) * cap
    return x


# ---------------------------------------------------------------------------- #
# Chunked attention core (train / prefill)                                      #
# ---------------------------------------------------------------------------- #


def chunked_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, KV, D)
    v: jnp.ndarray,  # (B, Sk, KV, Dv)
    *,
    causal: bool = True,
    window: int = 0,  # >0 with causal: keys restricted to (q-window, q]
    chunk_q: int = 2048,
    chunk_kv: int = 2048,
    q_offset: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5

    def _divisor_chunk(total, want):
        c = min(want, total)
        while total % c:  # shrink to the largest divisor <= want
            c -= 1
        return c

    cq = _divisor_chunk(Sq, chunk_q)
    ck = _divisor_chunk(Sk, chunk_kv)
    nq, nk = Sq // cq, Sk // ck
    # Head-major layout: expand KV heads to H up front so every tensor keeps
    # a plain H dim.  The (B,S,KV,G,D) reshape splits the sharded H axis into
    # two dims GSPMD cannot map onto the mesh -> it replicates the (cq,ck)
    # score blocks.  Post-repeat, scores are (B,H,cq,ck) sharded on H.
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    q4 = q * scale

    banded = window > 0 and causal
    if banded:
        kw = cq + window  # keys possibly visible to one q chunk
        nk_inner = min(-(-kw // ck), nk)
    else:
        nk_inner = nk

    def kv_block_step(carry, inputs):
        acc, m, l, q_blk, qpos = carry
        k_blk, v_blk, kpos = inputs
        s = jnp.einsum("bqhd,bshd->bhqs", q_blk, k_blk)  # (B,H,cq,ck)
        s = _softcap(s, softcap).astype(jnp.float32)
        mask = jnp.ones((q_blk.shape[1], k_blk.shape[1]), dtype=bool)
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", p.astype(v_blk.dtype), v_blk
        ).astype(jnp.float32)
        return (acc, m_new, l, q_blk, qpos), None

    kv_block_step = jax.checkpoint(kv_block_step)

    def q_block(args):
        qi, q_blk = args  # q_blk: (B, cq, H, D)
        qpos = q_offset + qi * cq + jnp.arange(cq)
        acc0 = jnp.zeros((B, H, cq, Dv), jnp.float32)
        m0 = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        if banded:
            width = nk_inner * ck
            start = jnp.clip(qi * cq + q_offset - window + 1, 0, Sk - width)
            k_loc = jax.lax.dynamic_slice_in_dim(k, start, width, axis=1)
            v_loc = jax.lax.dynamic_slice_in_dim(v, start, width, axis=1)
            kpos = start + jnp.arange(width)
        else:
            k_loc, v_loc, kpos = k, v, jnp.arange(Sk)
        nblk = k_loc.shape[1] // ck
        ks = k_loc.reshape(B, nblk, ck, H, D).swapaxes(0, 1)
        vs = v_loc.reshape(B, nblk, ck, H, Dv).swapaxes(0, 1)
        kps = kpos.reshape(nblk, ck)
        (acc, m, l, _, _), _ = jax.lax.scan(
            kv_block_step, (acc0, m0, l0, q_blk, qpos), (ks, vs, kps)
        )
        return acc / jnp.maximum(l[..., None], 1e-30)  # (B, H, cq, Dv)

    if nq == 1:
        outs = q_block((jnp.asarray(0), q4))[None]
    else:
        qs = q4.reshape(B, nq, cq, H, D).swapaxes(0, 1)
        outs = jax.lax.map(q_block, (jnp.arange(nq), qs))
    # outs: (nq, B, H, cq, Dv) -> (B, Sq, H, Dv)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------- #
# Decode attention                                                              #
# ---------------------------------------------------------------------------- #


def _masked_decode(q1, k_cache, v_cache, lo, hi, softcap):
    """q1: (B,H,D); cache (B,S,KV,*); valid key positions p: lo <= p < hi.

    Head-major (KV repeated to H) so the (B,H,S) score tensor stays sharded
    on H under tensor parallelism — see chunked_attention."""
    B, H, D = q1.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    if G > 1:
        k_cache = jnp.repeat(k_cache, G, axis=2)
        v_cache = jnp.repeat(v_cache, G, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q1 * (D ** -0.5), k_cache)
    s = _softcap(s, softcap).astype(jnp.float32)
    ar = jnp.arange(S)[None, :]
    valid = (ar < hi[:, None]) & (ar >= lo[:, None])
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p.astype(v_cache.dtype), v_cache)
    return out.astype(q1.dtype)


def flash_decode_sharded(q1, k_cache, v_cache, lo, hi, softcap, mesh, batch_axes):
    """Seq-sharded flash decode: KV cache sharded on its seq dim over the
    'model' mesh axis; each shard computes a partial softmax (o, m, l);
    partials are LSE-merged with an all-gather over 'model'.

    This is what lets a 500k-token cache decode even when kv_heads < 16:
    per-chip KV bytes shrink by the model-axis size regardless of head count.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    S, KV = k_cache.shape[1], k_cache.shape[2]
    n_shard = mesh.shape["model"]
    if S % n_shard != 0:
        return _masked_decode(q1, k_cache, v_cache, lo, hi, softcap)
    S_loc = S // n_shard
    H, D = q1.shape[1], q1.shape[2]

    def shard_fn(q_loc, k_loc, v_loc, lo_l, hi_l):
        idx = jax.lax.axis_index("model")
        Bl = q_loc.shape[0]
        G = H // KV
        kpos = idx * S_loc + jnp.arange(S_loc)
        valid = (kpos[None, :] < hi_l[:, None]) & (kpos[None, :] >= lo_l[:, None])
        q4 = (q_loc * (D ** -0.5)).reshape(Bl, KV, G, D)
        s = jnp.einsum("bkgd,bskd->bkgs", q4, k_loc)
        s = _softcap(s, softcap).astype(jnp.float32)
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        m = s.max(axis=-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(axis=-1)
        o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_loc.dtype), v_loc).astype(jnp.float32)
        # LSE merge across the model axis
        om = jax.lax.all_gather(m, "model")
        ol = jax.lax.all_gather(l, "model")
        oo = jax.lax.all_gather(o, "model")
        m_g = om.max(axis=0)
        w = jnp.exp(om - m_g[None])
        l_g = (ol * w).sum(axis=0)
        o_g = (oo * w[..., None]).sum(axis=0)
        out = o_g / jnp.maximum(l_g[..., None], 1e-30)
        return out.reshape(Bl, H, v_loc.shape[-1]).astype(q_loc.dtype)

    ba = tuple(a for a in batch_axes if a in mesh.shape) or None
    if ba is not None:
        dp = 1
        for a in ba:
            dp *= mesh.shape[a]
        if q1.shape[0] % dp != 0:  # e.g. global_batch=1 long-context decode
            ba = None
    q_spec = P(ba, None, None)
    kv_spec = P(ba, "model", None, None)
    s_spec = P(ba)
    return shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, s_spec, s_spec),
        out_specs=q_spec,
        check_rep=False,
    )(q1, k_cache, v_cache, lo, hi)


# ---------------------------------------------------------------------------- #
# Attention module: specs + apply                                               #
# ---------------------------------------------------------------------------- #


def attention_specs(cfg, stack: int) -> Dict[str, Any]:
    d, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    p = {
        "wq": cm.dense_spec((d,), (H, hd), ("embed",), ("heads", "head_dim"),
                            stack=stack, bias=cfg.qkv_bias),
        "wk": cm.dense_spec((d,), (KV, hd), ("embed",), ("kv_heads", "head_dim"),
                            stack=stack, bias=cfg.qkv_bias),
        "wv": cm.dense_spec((d,), (KV, hd), ("embed",), ("kv_heads", "head_dim"),
                            stack=stack, bias=cfg.qkv_bias),
        "wo": cm.dense_spec((H, hd), (d,), ("heads", "head_dim"), ("embed",),
                            stack=stack),
    }
    if cfg.qk_norm:
        p["qknorm"] = cm.qknorm_spec(hd, stack)
    return p


def _rope_theta_for(cfg, kind: str) -> float:
    return cfg.rope_local_theta if kind == "attn_local" else cfg.rope_theta


def self_attention(
    params, cfg, part, x, *, kind: str,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    mesh=None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full-sequence self-attention (train / prefill / encoder).

    x: (B, S, d).  If ``cache`` is given (prefill), K/V are written into it.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    S = x.shape[1]
    q = cm.dense(params["wq"], x, "...d,dhk->...hk", cd)
    k = cm.dense(params["wk"], x, "...d,dhk->...hk", cd)
    v = cm.dense(params["wv"], x, "...d,dhk->...hk", cd)
    if cfg.qk_norm:
        q = cm.headwise_rmsnorm(params["qknorm"]["q_scale"], q, cfg.norm_eps)
        k = cm.headwise_rmsnorm(params["qknorm"]["k_scale"], k, cfg.norm_eps)
    pos = positions if positions is not None else jnp.arange(S)[None, :]
    cos, sin = cm.rope_angles(pos, hd, _rope_theta_for(cfg, kind))
    q = cm.apply_rope(q, cos, sin)
    k = cm.apply_rope(k, cos, sin)
    out = chunked_attention(
        q, k, v,
        causal=(kind != "attn_bidir"),
        window=cfg.window if kind == "attn_local" else 0,
        chunk_q=part.attn_chunk_q, chunk_kv=part.attn_chunk_kv,
        softcap=cfg.logit_softcap,
    )
    y = cm.dense(params["wo"], out, "...hk,hkd->...d", cd)
    new_cache = None
    if cache is not None:
        if "pos" in cache:  # sliding-window ring cache
            new_cache = _ring_from_prefill(cache, k, v)
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            new_cache = {"k": kc, "v": vc}
    return y, new_cache


def _ring_from_prefill(cache, k, v):
    """Build the sliding-window ring cache after a prefill of S tokens
    starting at position 0.  Ring slot i holds absolute position p ≡ i
    (mod W), p ∈ [S-W, S-1] — the gather indices are static (S, W are
    trace-time Python ints)."""
    import numpy as np

    W = cache["k"].shape[1]
    S = k.shape[1]
    if S >= W:
        base = S - W
        idx = np.array([base + ((i - base) % W) for i in range(W)])
        kc = k[:, idx].astype(cache["k"].dtype)
        vc = v[:, idx].astype(cache["v"].dtype)
        pos = jnp.broadcast_to(jnp.asarray(idx, cache["pos"].dtype), cache["pos"].shape)
    else:
        B = k.shape[0]
        pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
        kc = jnp.pad(k, pad).astype(cache["k"].dtype)
        vc = jnp.pad(v, pad).astype(cache["v"].dtype)
        pos1 = jnp.concatenate(
            [jnp.arange(S), jnp.full((W - S,), -1)]).astype(cache["pos"].dtype)
        pos = jnp.broadcast_to(pos1, (B, W))
    return {"k": kc, "v": vc, "pos": pos}


def self_attention_decode(
    params, cfg, part, x, *, kind: str,
    positions: jnp.ndarray,  # (B,) absolute position of the new token
    cache: Dict[str, jnp.ndarray],
    mesh=None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode: update cache at ``positions``, attend over it."""
    cd = jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    q = cm.dense(params["wq"], x, "...d,dhk->...hk", cd)  # (B,1,H,hd)
    k_new = cm.dense(params["wk"], x, "...d,dhk->...hk", cd)
    v_new = cm.dense(params["wv"], x, "...d,dhk->...hk", cd)
    if cfg.qk_norm:
        q = cm.headwise_rmsnorm(params["qknorm"]["q_scale"], q, cfg.norm_eps)
        k_new = cm.headwise_rmsnorm(params["qknorm"]["k_scale"], k_new, cfg.norm_eps)
    cos, sin = cm.rope_angles(positions[:, None], hd, _rope_theta_for(cfg, kind))
    q = cm.apply_rope(q, cos, sin)
    k_new = cm.apply_rope(k_new, cos, sin)
    k_cache = _scatter_cache(cache["k"], k_new, positions)
    v_cache = _scatter_cache(cache["v"], v_new, positions)
    hi = positions + 1
    if kind == "attn_local" and cfg.window > 0:
        lo = jnp.maximum(hi - cfg.window, 0)
    else:
        lo = jnp.zeros_like(hi)
    q1 = q[:, 0]
    if part.flash_decode and mesh is not None and "model" in mesh.shape:
        out = flash_decode_sharded(
            q1, k_cache, v_cache, lo, hi, cfg.logit_softcap, mesh, ("pod", "data"))
    else:
        out = _masked_decode(q1, k_cache, v_cache, lo, hi, cfg.logit_softcap)
    y = cm.dense(params["wo"], out[:, None], "...hk,hkd->...d", cd)
    return y, {"k": k_cache, "v": v_cache}


def cross_attention(
    params, cfg, part, x, *,
    enc_kv: Dict[str, jnp.ndarray],  # precomputed {"k","v"}: (B, S_enc, KV, hd)
    decode: bool = False,
    mesh=None,
) -> jnp.ndarray:
    """Cross-attention against (precomputed) encoder K/V.  No RoPE."""
    cd = jnp.dtype(cfg.compute_dtype)
    q = cm.dense(params["wq"], x, "...d,dhk->...hk", cd)
    if cfg.qk_norm:
        q = cm.headwise_rmsnorm(params["qknorm"]["q_scale"], q, cfg.norm_eps)
    k, v = enc_kv["k"].astype(cd), enc_kv["v"].astype(cd)
    if decode:
        B = x.shape[0]
        S_enc = k.shape[1]
        lo = jnp.zeros((B,), jnp.int32)
        hi = jnp.full((B,), S_enc, jnp.int32)
        out = _masked_decode(q[:, 0], k, v, lo, hi, cfg.logit_softcap)[:, None]
    else:
        out = chunked_attention(
            q, k, v, causal=False,
            chunk_q=part.attn_chunk_q, chunk_kv=part.attn_chunk_kv,
            softcap=cfg.logit_softcap,
        )
    return cm.dense(params["wo"], out, "...hk,hkd->...d", cd)


def cross_kv(params, cfg, enc_out: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Precompute cross-attention K/V from encoder outputs."""
    cd = jnp.dtype(cfg.compute_dtype)
    k = cm.dense(params["wk"], enc_out, "...d,dhk->...hk", cd)
    v = cm.dense(params["wv"], enc_out, "...d,dhk->...hk", cd)
    if cfg.qk_norm:
        k = cm.headwise_rmsnorm(params["qknorm"]["k_scale"], k, cfg.norm_eps)
    return {"k": k, "v": v}


def _scatter_cache(cache, new, pos):
    """Place (B,1,KV,hd) entries at per-batch positions (B,) along axis 1."""
    B = cache.shape[0]
    idx = pos.reshape(B, 1, 1, 1).astype(jnp.int32)
    iota = jnp.arange(cache.shape[1]).reshape(1, -1, 1, 1)
    return jnp.where(iota == idx, new.astype(cache.dtype), cache)
