"""Model substrate: param-spec system, norms, dense/embedding, RoPE.

Params are plain pytrees (nested dicts of jnp arrays).  Every param is
declared first as a :class:`ParamSpec` carrying shape, dtype, *logical axis
names* and an initializer.  The spec tree gives us, without any allocation:
  - ``abstract(specs)``      -> ShapeDtypeStruct tree (dry-run inputs)
  - ``shardings(specs, ...)`` -> NamedSharding tree (pjit in_shardings)
  - ``init_params(specs, key)`` -> materialized params (smoke tests / training)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------- #
# Param specs                                                                   #
# ---------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float = 1.0  # std multiplier for normal init (before fan-in scaling)
    fan_in: int = 0  # 0 -> no fan-in scaling
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_leaves_with_specs(specs):
    return jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)


def abstract(specs):
    """ShapeDtypeStruct tree from a spec tree (no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=_is_spec
    )


def init_params(specs, key: jax.Array):
    leaves, treedef = tree_leaves_with_specs(specs)
    keys = jax.random.split(key, max(2, len(leaves)))

    def one(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        std = spec.scale
        if spec.fan_in:
            std = spec.scale / np.sqrt(spec.fan_in)
        if spec.init == "embed":
            std = spec.scale
        return (jax.random.normal(k, spec.shape) * std).astype(spec.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(s, k) for s, k in zip(leaves, keys)]
    )


# ---------------------------------------------------------------------------- #
# Logical-axis -> mesh resolution                                               #
# ---------------------------------------------------------------------------- #

# Default logical rules.  Values are mesh axis names (or tuples).  An axis is
# only actually sharded if the dim size divides the mesh axis size (maybe-shard
# semantics) — this is what makes e.g. kv_heads=2 compile under model=16.
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": None,
    "ff": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "expert": "model",
    "expert_ff": None,
    "kv_lora": None,
    "seq": None,
    "seq_shard": "model",  # activations under Megatron-SP
    "dstate": None,
    "dinner": "model",  # mamba/xlstm inner dim
    "layers": None,
    "conv": None,
    "capacity": None,
    "frontend": None,
}

FSDP_RULES_OVERRIDE: Dict[str, Any] = {
    # ZeRO-3: additionally shard the embed dim of weights over the data axis
    "embed": "data",
}


def _mesh_axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= _mesh_axis_size(mesh, a)
        return out
    return mesh.shape[axis] if axis in mesh.shape else 1


def resolve_axes(mesh, rules: Dict[str, Any], shape, axes) -> "jax.sharding.PartitionSpec":
    """Logical axes -> PartitionSpec with divisibility (maybe-shard) checks
    and no mesh axis used twice."""
    from jax.sharding import PartitionSpec as P

    used = set()
    out = []
    for size, name in zip(shape, axes):
        mesh_axis = rules.get(name) if name else None
        if mesh_axis is None:
            out.append(None)
            continue
        axes_tuple = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        # drop axes missing from mesh, already used, or non-dividing
        kept = []
        for a in axes_tuple:
            if a in mesh.shape and a not in used:
                kept.append(a)
        if not kept:
            out.append(None)
            continue
        total = 1
        for a in kept:
            total *= mesh.shape[a]
        if size % total != 0:
            # try progressively shorter prefixes
            while kept:
                kept = kept[:-1]
                total = 1
                for a in kept:
                    total *= mesh.shape[a]
                if kept and size % total == 0:
                    break
            if not kept:
                out.append(None)
                continue
        used.update(kept)
        out.append(tuple(kept) if len(kept) > 1 else kept[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shardings(specs, mesh, rules: Optional[Dict[str, Any]] = None):
    """NamedSharding tree for a spec tree."""
    from jax.sharding import NamedSharding

    rules = {**DEFAULT_RULES, **(rules or {})}
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, resolve_axes(mesh, rules, s.shape, s.axes)),
        specs,
        is_leaf=_is_spec,
    )


def logical_sharding(mesh, rules, shape, axes):
    from jax.sharding import NamedSharding

    rules = {**DEFAULT_RULES, **(rules or {})}
    return NamedSharding(mesh, resolve_axes(mesh, rules, shape, axes))


def constrain(x, mesh, rules, axes):
    """with_sharding_constraint by logical axes (no-op off-mesh)."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, logical_sharding(mesh, rules, x.shape, axes)
    )


# ---------------------------------------------------------------------------- #
# Layers                                                                        #
# ---------------------------------------------------------------------------- #


def dense_spec(
    in_dims: Sequence[int],
    out_dims: Sequence[int],
    in_axes: Sequence[Optional[str]],
    out_axes: Sequence[Optional[str]],
    *,
    stack: int = 0,
    bias: bool = False,
    dtype=jnp.float32,
    scale: float = 1.0,
):
    """Spec for a (possibly layer-stacked) dense kernel of shape
    (stack?, *in_dims, *out_dims)."""
    shape = tuple(in_dims) + tuple(out_dims)
    axes = tuple(in_axes) + tuple(out_axes)
    if stack:
        shape = (stack,) + shape
        axes = ("layers",) + axes
    fan_in = int(np.prod(in_dims))
    p = {"kernel": ParamSpec(shape, axes, "normal", scale, fan_in, dtype)}
    if bias:
        bshape = tuple(out_dims)
        baxes = tuple(out_axes)
        if stack:
            bshape = (stack,) + bshape
            baxes = ("layers",) + baxes
        p["bias"] = ParamSpec(bshape, baxes, "zeros", dtype=dtype)
    return p


def dense(params, x, spec: str, compute_dtype=jnp.bfloat16):
    """Apply a dense layer given an einsum spec, e.g. '...d,dhq->...hq'."""
    kernel = params["kernel"].astype(compute_dtype)
    y = jnp.einsum(spec, x.astype(compute_dtype), kernel)
    if "bias" in params:
        y = y + params["bias"].astype(compute_dtype)
    return y


def norm_spec(d: int, *, stack: int = 0, style: str = "rms"):
    shape, axes = (d,), ("embed",)
    if stack:
        shape, axes = (stack, d), ("layers", "embed")
    init = "zeros" if style == "gemma" else "ones"
    p = {"scale": ParamSpec(shape, axes, init)}
    if style == "layer":
        p["bias"] = ParamSpec(shape, axes, "zeros")
    return p


def rmsnorm(params, x, eps: float = 1e-6, gemma: bool = False, compute_dtype=jnp.bfloat16):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    if gemma:
        scale = scale + 1.0
    return (y * scale).astype(compute_dtype)


def layernorm(params, x, eps: float = 1e-6, compute_dtype=jnp.bfloat16):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(compute_dtype)


def embed_spec(vocab: int, d: int, dtype=jnp.float32):
    # std = 1/sqrt(d): keeps tied-head logits O(1) at init (gemma-style
    # embed_scale multiplies the *input* side back up by sqrt(d)).
    return {"embedding": ParamSpec((vocab, d), ("vocab", "embed"), "embed", d ** -0.5, 0, dtype)}


def embed_lookup(params, tokens, compute_dtype=jnp.bfloat16):
    return params["embedding"].astype(compute_dtype)[tokens]


def qknorm_spec(head_dim: int, stack: int = 0):
    shape, axes = (head_dim,), ("head_dim",)
    if stack:
        shape, axes = (stack, head_dim), ("layers", "head_dim")
    return {
        "q_scale": ParamSpec(shape, axes, "ones"),
        "k_scale": ParamSpec(shape, axes, "ones"),
    }


def headwise_rmsnorm(scale, x, eps=1e-6):
    """RMS norm over the last (head) dim; x: (..., head_dim)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------- #
# RoPE                                                                          #
# ---------------------------------------------------------------------------- #


def rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (..., S) int -> cos/sin (..., S, dim//2) float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D) with D even; cos/sin: (..., S, D//2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def activation(name: str) -> Callable:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]
