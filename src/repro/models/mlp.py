"""Gated MLP (SwiGLU / GeGLU) with tensor-parallel logical axes."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import common as cm


def mlp_specs(cfg, stack: int, d_ff: int = 0):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    return {
        "gate": cm.dense_spec((d,), (ff,), ("embed",), ("ff",), stack=stack),
        "up": cm.dense_spec((d,), (ff,), ("embed",), ("ff",), stack=stack),
        "down": cm.dense_spec((ff,), (d,), ("ff",), ("embed",), stack=stack),
    }


def mlp_apply(params, cfg, x):
    cd = jnp.dtype(cfg.compute_dtype)
    act = cm.activation(cfg.act)
    g = cm.dense(params["gate"], x, "...d,df->...f", cd)
    u = cm.dense(params["up"], x, "...d,df->...f", cd)
    return cm.dense(params["down"], act(g) * u, "...f,fd->...d", cd)
