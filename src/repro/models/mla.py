"""Multi-head Latent Attention (DeepSeek-V2).

Prefill/train use the expanded form (materialize per-head K/V from the
compressed c_kv) with chunked attention.  Decode uses the **absorbed** form:
the cache holds only (c_kv: r=512, k_rope: 64) per token — the whole point of
MLA — and queries are mapped into the compressed space via W_uk, so decode
attention runs directly against the 576-wide cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.attention import NEG_INF, chunked_attention


def mla_specs(cfg, stack: int):
    d, H = cfg.d_model, cfg.n_heads
    m = cfg.mla
    dn, dr, dv, r = m.nope_head_dim, m.rope_head_dim, m.v_head_dim, m.kv_lora_rank
    p = {
        "wq": cm.dense_spec((d,), (H, dn + dr), ("embed",), ("heads", "head_dim"), stack=stack),
        "kv_down": cm.dense_spec((d,), (r,), ("embed",), ("kv_lora",), stack=stack),
        "k_rope": cm.dense_spec((d,), (dr,), ("embed",), ("head_dim",), stack=stack),
        "kv_norm": cm.norm_spec(r, stack=stack) | {},
        "k_up": cm.dense_spec((r,), (H, dn), ("kv_lora",), ("heads", "head_dim"), stack=stack),
        "v_up": cm.dense_spec((r,), (H, dv), ("kv_lora",), ("heads", "head_dim"), stack=stack),
        "wo": cm.dense_spec((H, dv), (d,), ("heads", "head_dim"), ("embed",), stack=stack),
    }
    # kv_norm spec needs the right axes name for the lora dim
    p["kv_norm"] = {"scale": cm.ParamSpec(((stack, r) if stack else (r,)),
                                          (("layers", "kv_lora") if stack else ("kv_lora",)),
                                          "ones")}
    return p


def _q_proj(params, cfg, x, cd):
    m = cfg.mla
    dn, dr = m.nope_head_dim, m.rope_head_dim
    q = cm.dense(params["wq"], x, "...d,dhk->...hk", cd)
    return q[..., :dn], q[..., dn:]  # nope, rope parts


def mla_attention(
    params, cfg, part, x, *,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full-sequence MLA (train / prefill).  x: (B, S, d)."""
    cd = jnp.dtype(cfg.compute_dtype)
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.nope_head_dim, m.rope_head_dim, m.v_head_dim
    q_nope, q_rope = _q_proj(params, cfg, x, cd)
    c_kv = cm.dense(params["kv_down"], x, "...d,dr->...r", cd)
    c_kv = cm.rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps, compute_dtype=cd)
    k_rope = cm.dense(params["k_rope"], x, "...d,dr->...r", cd)[:, :, None, :]  # (B,S,1,dr)
    pos = positions if positions is not None else jnp.arange(S)[None, :]
    cos, sin = cm.rope_angles(pos, dr, cfg.rope_theta)
    q_rope = cm.apply_rope(q_rope, cos, sin)
    k_rope = cm.apply_rope(k_rope, cos, sin)
    k_nope = cm.dense(params["k_up"], c_kv, "...r,rhk->...hk", cd)  # (B,S,H,dn)
    v = cm.dense(params["v_up"], c_kv, "...r,rhk->...hk", cd)  # (B,S,H,dv)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(
        q, k, v, causal=True,
        chunk_q=part.attn_chunk_q, chunk_kv=part.attn_chunk_kv,
        scale=(dn + dr) ** -0.5,
    )
    y = cm.dense(params["wo"], out, "...hk,hkd->...d", cd)
    new_cache = None
    if cache is not None:
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, axis=1)
        krc = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype), 0, axis=1)
        new_cache = {"c_kv": ckv, "k_rope": krc}
    return y, new_cache


def mla_attention_decode(
    params, cfg, part, x, *,
    positions: jnp.ndarray,  # (B,)
    cache: Dict[str, jnp.ndarray],  # c_kv: (B,S,r), k_rope: (B,S,dr)
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Absorbed-form decode: attention runs in the compressed (r+dr) space."""
    cd = jnp.dtype(cfg.compute_dtype)
    m = cfg.mla
    B = x.shape[0]
    dn, dr, dv, r = m.nope_head_dim, m.rope_head_dim, m.v_head_dim, m.kv_lora_rank
    q_nope, q_rope = _q_proj(params, cfg, x, cd)  # (B,1,H,dn/(dr))
    c_new = cm.dense(params["kv_down"], x, "...d,dr->...r", cd)
    c_new = cm.rmsnorm(params["kv_norm"], c_new, cfg.norm_eps, compute_dtype=cd)
    kr_new = cm.dense(params["k_rope"], x, "...d,dr->...r", cd)  # (B,1,dr)
    cos, sin = cm.rope_angles(positions[:, None], dr, cfg.rope_theta)
    q_rope = cm.apply_rope(q_rope, cos, sin)
    kr_new = cm.apply_rope(kr_new[:, :, None, :], cos, sin)[:, :, 0]  # (B,1,dr)

    idx = positions.reshape(B, 1, 1).astype(jnp.int32)
    iota2 = jnp.arange(cache["c_kv"].shape[1]).reshape(1, -1, 1)
    c_kv = jnp.where(iota2 == idx, c_new.astype(cache["c_kv"].dtype), cache["c_kv"])
    k_rope = jnp.where(iota2 == idx, kr_new.astype(cache["k_rope"].dtype), cache["k_rope"])

    # absorb W_uk into the query: q_eff (B,H,r)
    k_up = params["k_up"]["kernel"].astype(cd)  # (r,H,dn)
    q_eff = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], k_up)
    scale = (dn + dr) ** -0.5
    s = jnp.einsum("bhr,bsr->bhs", q_eff, c_kv.astype(cd))
    s = s + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], k_rope.astype(cd))
    s = (s * scale).astype(jnp.float32)
    valid = jnp.arange(c_kv.shape[1])[None, :] < (positions + 1)[:, None]
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhs,bsr->bhr", p.astype(cd), c_kv.astype(cd))  # (B,H,r)
    v_up = params["v_up"]["kernel"].astype(cd)  # (r,H,dv)
    out = jnp.einsum("bhr,rhk->bhk", o_c, v_up)  # (B,H,dv)
    y = cm.dense(params["wo"], out[:, None], "...hk,hkd->...d", cd)
    return y, {"c_kv": c_kv, "k_rope": k_rope}
