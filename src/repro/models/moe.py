"""Mixture-of-Experts with top-k routing and batch-local sort dispatch.

SPMD-friendly by construction: dispatch (sort, capacity, scatter) happens
independently per leading-batch row (vmap), so every intermediate keeps the
``batch`` sharding and GSPMD never has to reshard a global scatter — the
failure mode that made a global-sort dispatch materialize the full (E·C, d)
buffer per device.  Expert weights carry the 'expert' logical axis
(-> 'model' mesh axis); the expert einsum contracts locally because the
dispatch buffer is replicated across 'model' (activations are batch-sharded)
— zero dispatch collectives on the dry-run meshes.

Capacity is per batch row: C = ceil(S·k/E · capacity_factor) (Switch-style
per-shard capacity; overflow tokens drop).  No (T, E, C) one-hot tensor is
ever built: positions-in-expert come from a sorted cummax trick, dispatch is
a batched scatter, combine a batched gather.

Aux losses: switch load-balance loss + router z-loss.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


def _batch_axes_for(mesh, B: int):
    """Mesh axes the batch dim can shard over (empty tuple -> no shard_map)."""
    if mesh is None:
        return ()
    ba = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not ba:
        return ()
    dp = 1
    for a in ba:
        dp *= mesh.shape[a]
    return ba if (dp > 0 and B % dp == 0) else ()


def moe_specs(cfg, stack: int) -> Dict[str, Any]:
    d = cfg.d_model
    moe = cfg.moe
    ff = moe.d_expert or cfg.d_ff
    E = moe.n_experts

    def expert_dense(in_d, out_d, in_ax, out_ax):
        shape = (E, in_d, out_d)
        axes = ("expert", in_ax, out_ax)
        if stack:
            shape = (stack,) + shape
            axes = ("layers",) + axes
        return {"kernel": cm.ParamSpec(shape, axes, "normal", 1.0, in_d)}

    p = {
        "router": cm.dense_spec((d,), (E,), ("embed",), ("expert",), stack=stack),
        "gate": expert_dense(d, ff, "embed", "expert_ff"),
        "up": expert_dense(d, ff, "embed", "expert_ff"),
        "down": expert_dense(ff, d, "expert_ff", "embed"),
    }
    if moe.n_shared:
        from repro.models.mlp import mlp_specs

        p["shared"] = mlp_specs(cfg, stack, d_ff=ff * moe.n_shared)
    return p


def _dispatch_row(xt, expert_idx, gate_vals, E: int, C: int, k: int, cd):
    """Per-batch-row dispatch.  xt: (S, d); expert_idx/gate_vals: (S, k).
    Returns (buf (E, C, d), slot (S*k,), tok_sorted (S*k,), keep, gates_sorted).
    """
    S = xt.shape[0]
    flat_e = expert_idx.reshape(-1)  # (S*k,)
    order = jnp.argsort(flat_e, stable=True)  # ties keep token order
    e_sorted = flat_e[order]
    idx = jnp.arange(S * k)
    # position within each expert run: idx - index of the run's first element
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), e_sorted[1:] != e_sorted[:-1]])
    run_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    pos_in_e = idx - run_start
    keep = pos_in_e < C
    slot = jnp.where(keep, e_sorted * C + pos_in_e, E * C)  # E*C = drop bin
    tok_sorted = order // k
    buf = jnp.zeros((E * C + 1, xt.shape[1]), cd)
    buf = buf.at[slot].set(xt[tok_sorted].astype(cd), mode="drop")
    gates_sorted = gate_vals.reshape(-1)[order]
    return buf[: E * C].reshape(E, C, xt.shape[1]), slot, tok_sorted, keep, gates_sorted


def _combine_row(yb, slot, tok_sorted, gates_sorted, S: int, cd):
    """Inverse of _dispatch_row.  yb: (E, C, d) -> y (S, d)."""
    d = yb.shape[-1]
    yb_flat = jnp.concatenate([yb.reshape(-1, d), jnp.zeros((1, d), cd)], axis=0)
    gathered = yb_flat[slot]  # dropped tokens hit the zero row
    contrib = gathered * gates_sorted[:, None].astype(cd)
    return jnp.zeros((S, d), cd).at[tok_sorted].add(contrib)


def _dispatch_batch(x, expert_idx, gate_vals, E, C, k, cd):
    return jax.vmap(
        lambda xr, er, gr: _dispatch_row(xr, er, gr, E, C, k, cd)
    )(x, expert_idx, gate_vals)


def _combine_batch(yb, slot, tok_sorted, gates_sorted, S, cd):
    return jax.vmap(
        lambda ybr, sl, ts, gs: _combine_row(ybr, sl, ts, gs, S, cd)
    )(yb, slot, tok_sorted, gates_sorted)


def moe_apply(params, cfg, x: jnp.ndarray, mesh=None) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, d) -> (y, aux) with aux = {load_balance_loss, router_z_loss}."""
    cd = jnp.dtype(cfg.compute_dtype)
    moe = cfg.moe
    B, S, d = x.shape
    E, k = moe.n_experts, moe.top_k
    act = cm.activation(cfg.act)

    logits = cm.dense(params["router"], x, "bsd,de->bse", cd).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (B, S, k)
    if moe.renormalize:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # per-row capacity; k distinct experts per token guarantee C>=k covers S=1
    C = max(int(S * k / E * moe.capacity_factor) + 1, 1)

    # Dispatch under shard_map over the batch axes when possible: GSPMD has
    # no good sharding for batched sort/scatter and replicates the (E·C, d)
    # buffers otherwise (measured ~68 GB/layer on jamba).  shard_map pins
    # every dispatch intermediate to its batch shard; there are no
    # collectives inside (dispatch is per-row math).
    ba = _batch_axes_for(mesh, B)
    if ba:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        bspec = ba if len(ba) > 1 else ba[0]
        disp = shard_map(
            lambda xr, er, gr: _dispatch_batch(xr, er, gr, E, C, k, cd),
            mesh=mesh,
            in_specs=(P(bspec), P(bspec), P(bspec)),
            out_specs=(P(bspec), P(bspec), P(bspec), P(bspec), P(bspec)),
            check_rep=False,
        )
        buf, slot, tok_sorted, keep, gates_sorted = disp(x, expert_idx, gate_vals)
    else:
        buf, slot, tok_sorted, keep, gates_sorted = _dispatch_batch(
            x, expert_idx, gate_vals, E, C, k, cd)

    # expert computation: b batch-sharded, e expert(model)-sharded
    g = jnp.einsum("becd,edf->becf", buf, params["gate"]["kernel"].astype(cd))
    u = jnp.einsum("becd,edf->becf", buf, params["up"]["kernel"].astype(cd))
    yb = jnp.einsum("becf,efd->becd", act(g) * u, params["down"]["kernel"].astype(cd))

    if ba:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        bspec = ba if len(ba) > 1 else ba[0]
        comb = shard_map(
            lambda ybr, sl, ts, gs: _combine_batch(ybr, sl, ts, gs, S, cd),
            mesh=mesh,
            in_specs=(P(bspec), P(bspec), P(bspec), P(bspec)),
            out_specs=P(bspec),
            check_rep=False,
        )
        y = comb(yb, slot, tok_sorted, gates_sorted)
    else:
        y = _combine_batch(yb, slot, tok_sorted, gates_sorted, S, cd)

    if moe.n_shared:
        from repro.models.mlp import mlp_apply

        y = y + mlp_apply(params["shared"], cfg, x)

    # switch load-balance: E * sum_e f_e * p_e  (f from kept+dropped picks)
    me = probs.mean(axis=(0, 1))  # (E,)
    ce = jax.vmap(lambda fe: jnp.zeros((E,), jnp.float32).at[fe.reshape(-1)].add(1.0))(
        expert_idx).sum(axis=0) / (B * S * k)
    lb = E * jnp.sum(ce * me)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"load_balance_loss": lb, "router_z_loss": z}
    return y, aux
