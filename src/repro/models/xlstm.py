"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, true recurrence), per arXiv:2405.04517.

mLSTM's chunkwise form mirrors the repo's semiring-scan theme: the gate
stabilizer m_t follows a (max,+) recurrence — the same algebra as the Viterbi
path metrics — carried across chunks by ``lax.scan`` while everything within
a chunk is computed in parallel.

sLSTM is genuinely sequential (recurrent weights through a nonlinearity), so
it runs as a ``lax.scan`` over time with per-head block-diagonal recurrence —
the honest TPU mapping (documented in DESIGN.md).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import common as cm

# --------------------------------------------------------------------------- #
# mLSTM                                                                        #
# --------------------------------------------------------------------------- #


def mlstm_specs(cfg, stack: int):
    d = cfg.d_model
    x = cfg.xlstm
    d_in = int(x.mlstm_proj_factor * d)
    H = cfg.n_heads
    K = x.conv_kernel

    def P(shape, axes, init="normal", scale=1.0, fan_in=0):
        if stack:
            shape, axes = (stack,) + shape, ("layers",) + axes
        return cm.ParamSpec(shape, axes, init, scale, fan_in)

    return {
        "up_proj": cm.dense_spec((d,), (2 * d_in,), ("embed",), ("dinner",), stack=stack),
        "conv_w": P((K, d_in), ("conv", "dinner"), "normal", 1.0, K),
        "conv_b": P((d_in,), ("dinner",), "zeros"),
        "wq": cm.dense_spec((d_in,), (d_in,), ("dinner",), (None,), stack=stack),
        "wk": cm.dense_spec((d_in,), (d_in,), ("dinner",), (None,), stack=stack),
        "wv": cm.dense_spec((d_in,), (d_in,), ("dinner",), (None,), stack=stack),
        "w_if": cm.dense_spec((d_in,), (2 * H,), ("dinner",), (None,), stack=stack, bias=True),
        "gn": P((d_in,), ("dinner",), "ones"),
        "down_proj": cm.dense_spec((d_in,), (d,), ("dinner",), ("embed",), stack=stack),
    }


def _conv1d(params, x, cd):
    w = params["conv_w"].astype(cd)
    K, S = w.shape[0], x.shape[1]
    xpad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xpad[:, i : i + S] * w[i] for i in range(K)) + params["conv_b"].astype(cd)


def _mlstm_chunk(q, k, v, log_i, log_f, state, chunk: int):
    """Chunkwise stabilized mLSTM.

    q,k,v: (B,S,H,dh); log_i/log_f: (B,S,H); state: (C: (B,H,dh,dh),
    n: (B,H,dh), m: (B,H)).  Returns h (B,S,H,dh) and final state.
    """
    B, S, H, dh = q.shape
    chunk = min(chunk, S)
    while S % chunk:  # largest divisor <= requested chunk
        chunk -= 1
    nc = S // chunk
    scale = dh ** -0.5

    def resh(x):
        return x.reshape((B, nc, chunk) + x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs, lis, lfs = map(resh, (q * scale, k, v, log_i, log_f))

    def chunk_step(carry, xs):
        C, n, m = carry
        qc, kc, vc, li, lf = xs  # (B, chunk, H, ...)
        F = jnp.cumsum(lf, axis=1)  # inclusive decay-to-i  (B,chunk,H)
        G = li - F  # (B,chunk,H)
        gmax = jax.lax.cummax(G, axis=1)
        m_new = jnp.maximum(m[:, None] + F, F + gmax)  # (B,chunk,H)
        # intra-chunk weights: D_ij = exp(F_i - F_j + li_j - m_i), j<=i
        logD = F[:, :, None] - F[:, None, :] + li[:, None, :] - m_new[:, :, None]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        Dm = jnp.where(tri[None, :, :, None], jnp.exp(logD), 0.0)  # (B,i,j,H)
        s = jnp.einsum("bihd,bjhd->bijh", qc.astype(jnp.float32), kc.astype(jnp.float32))
        w = s * Dm
        h_num = jnp.einsum("bijh,bjhd->bihd", w, vc.astype(jnp.float32))
        n_num = jnp.einsum("bijh,bjhd->bihd", Dm, kc.astype(jnp.float32))
        # inter-chunk (carried state) contribution
        inter_w = jnp.exp(m[:, None] + F - m_new)  # (B,chunk,H)
        h_num += inter_w[..., None] * jnp.einsum(
            "bihd,bhde->bihe", qc.astype(jnp.float32), C)
        n_num += inter_w[..., None] * n[:, None]
        qn = jnp.einsum("bihd,bihd->bih", qc.astype(jnp.float32), n_num)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
        h = h_num / denom[..., None]
        # state update to chunk end
        FL = F[:, -1]  # (B,H)
        m_next = jnp.maximum(m + FL, FL + gmax[:, -1])
        wj = jnp.exp(FL[:, None] - F + li - m_next[:, None])  # (B,chunk,H)
        C_next = jnp.exp(m + FL - m_next)[:, :, None, None] * C + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", wj, kc.astype(jnp.float32), vc.astype(jnp.float32))
        n_next = jnp.exp(m + FL - m_next)[:, :, None] * n + jnp.einsum(
            "bjh,bjhd->bhd", wj, kc.astype(jnp.float32))
        return (C_next, n_next, m_next), h.astype(jnp.bfloat16)

    # checkpoint per chunk: the (B, chunk, chunk, H) decay/score tensors are
    # recomputed in backward instead of stored for every chunk
    chunk_step = jax.checkpoint(chunk_step)
    (C, n, m), hs = jax.lax.scan(chunk_step, state, (qs, ks, vs, lis, lfs))
    h = hs.swapaxes(0, 1).reshape(B, S, H, dh)
    return h, (C, n, m)


def mlstm_init_state(B, H, dh):
    return (jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.full((B, H), -1e30, jnp.float32))


def mlstm_apply(params, cfg, x, *, cache=None):
    """x: (B,S,d).  cache (decode/prefill): {"C","n","m","conv"}."""
    cd = jnp.dtype(cfg.compute_dtype)
    xc_cfg = cfg.xlstm
    B, S, d = x.shape
    d_in = int(xc_cfg.mlstm_proj_factor * d)
    H = cfg.n_heads
    dh = d_in // H
    up = cm.dense(params["up_proj"], x, "...d,df->...f", cd)
    xm, z = up[..., :d_in], up[..., d_in:]
    conv = jax.nn.silu(_conv1d(params, xm, cd))
    q = cm.dense(params["wq"], conv, "...f,fg->...g", cd).reshape(B, S, H, dh)
    k = cm.dense(params["wk"], conv, "...f,fg->...g", cd).reshape(B, S, H, dh)
    v = cm.dense(params["wv"], xm, "...f,fg->...g", cd).reshape(B, S, H, dh)
    if_raw = cm.dense(params["w_if"], xm, "...f,fg->...g", cd).astype(jnp.float32)
    log_i = if_raw[..., :H]  # exp input gate -> log_i = raw
    log_f = jax.nn.log_sigmoid(if_raw[..., H:])
    state = mlstm_init_state(B, H, dh) if cache is None else (
        cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32),
        cache["m"].astype(jnp.float32))
    h, (C, n, m) = _mlstm_chunk(q, k, v, log_i, log_f, state, xc_cfg.chunk)
    h = h.reshape(B, S, d_in).astype(cd)
    # per-head group norm
    hg = h.reshape(B, S, H, dh).astype(jnp.float32)
    hg = hg * jax.lax.rsqrt(jnp.mean(hg * hg, axis=-1, keepdims=True) + cfg.norm_eps)
    h = (hg.reshape(B, S, d_in) * params["gn"].astype(jnp.float32)).astype(cd)
    out = cm.dense(params["down_proj"], h * jax.nn.silu(z), "...f,fd->...d", cd)
    new_cache = None
    if cache is not None:
        K = params["conv_w"].shape[0]
        new_cache = {"C": C, "n": n, "m": m, "conv": xm[:, -(K - 1):].astype(cache["conv"].dtype)}
    return out, new_cache


def mlstm_decode(params, cfg, x, *, cache):
    """Single-step mLSTM recurrence.  x: (B,1,d)."""
    cd = jnp.dtype(cfg.compute_dtype)
    xc_cfg = cfg.xlstm
    B, _, d = x.shape
    d_in = int(xc_cfg.mlstm_proj_factor * d)
    H = cfg.n_heads
    dh = d_in // H
    up = cm.dense(params["up_proj"], x, "...d,df->...f", cd)[:, 0]
    xm, z = up[..., :d_in], up[..., d_in:]
    w = params["conv_w"].astype(cd)
    window = jnp.concatenate([cache["conv"].astype(cd), xm[:, None]], axis=1)
    conv = jax.nn.silu(jnp.einsum("bkf,kf->bf", window, w) + params["conv_b"].astype(cd))
    q = cm.dense(params["wq"], conv, "...f,fg->...g", cd).reshape(B, H, dh) * (dh ** -0.5)
    k = cm.dense(params["wk"], conv, "...f,fg->...g", cd).reshape(B, H, dh)
    v = cm.dense(params["wv"], xm, "...f,fg->...g", cd).reshape(B, H, dh)
    if_raw = cm.dense(params["w_if"], xm, "...f,fg->...g", cd).astype(jnp.float32)
    log_i, log_f = if_raw[..., :H], jax.nn.log_sigmoid(if_raw[..., H:])
    C, n, m = (cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32),
               cache["m"].astype(jnp.float32))
    m_new = jnp.maximum(log_f + m, log_i)
    fw = jnp.exp(log_f + m - m_new)[:, :, None]
    iw = jnp.exp(log_i - m_new)[:, :, None]
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    C = fw[..., None] * C + iw[..., None] * kf[:, :, :, None] * vf[:, :, None, :]
    n = fw * n + iw * kf
    h_num = jnp.einsum("bhd,bhde->bhe", qf, C)
    qn = jnp.einsum("bhd,bhd->bh", qf, n)
    h = h_num / jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))[..., None]
    h = h.reshape(B, d_in)
    hg = h.reshape(B, H, dh)
    hg = hg * jax.lax.rsqrt(jnp.mean(hg * hg, axis=-1, keepdims=True) + cfg.norm_eps)
    h = (hg.reshape(B, d_in) * params["gn"].astype(jnp.float32)).astype(cd)
    out = cm.dense(params["down_proj"], (h * jax.nn.silu(z))[:, None], "...f,fd->...d", cd)
    return out, {"C": C, "n": n, "m": m_new, "conv": window[:, 1:].astype(cache["conv"].dtype)}


# --------------------------------------------------------------------------- #
# sLSTM                                                                        #
# --------------------------------------------------------------------------- #


def slstm_specs(cfg, stack: int):
    d = cfg.d_model
    x = cfg.xlstm
    H = cfg.n_heads
    dh = d // H
    d_ff = int(x.slstm_proj_factor * d)

    def P(shape, axes, init="normal", scale=1.0, fan_in=0):
        if stack:
            shape, axes = (stack,) + shape, ("layers",) + axes
        return cm.ParamSpec(shape, axes, init, scale, fan_in)

    return {
        "w_gates": cm.dense_spec((d,), (4, d), ("embed",), (None, "dinner"), stack=stack, bias=True),
        "r_gates": P((4, H, dh, dh), (None, "heads", "head_dim", None), "normal", 1.0, dh),
        "gn": P((d,), ("dinner",), "ones"),
        "up_gate": cm.dense_spec((d,), (d_ff,), ("embed",), ("ff",), stack=stack),
        "up": cm.dense_spec((d,), (d_ff,), ("embed",), ("ff",), stack=stack),
        "down": cm.dense_spec((d_ff,), (d,), ("ff",), ("embed",), stack=stack),
    }


def slstm_init_state(B, d):
    z = jnp.zeros((B, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((B, d), -1e30, jnp.float32)}


def _slstm_cell(params, cfg, x_t, state):
    """One sLSTM step.  x_t: (B, 4, d) pre-computed Wx part."""
    H = cfg.n_heads
    d = state["h"].shape[-1]
    dh = d // H
    B = x_t.shape[0]
    h_prev = state["h"].reshape(B, H, dh)
    r = params["r_gates"].astype(jnp.float32)  # (4,H,dh,dh)
    rec = jnp.einsum("bhd,ghde->bghe", h_prev, r).reshape(B, 4, d)
    g = x_t.astype(jnp.float32) + rec
    log_i = g[:, 0]
    log_f = jax.nn.log_sigmoid(g[:, 1])
    z_in = jnp.tanh(g[:, 2])
    o = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    c = f_s * state["c"] + i_s * z_in
    n = jnp.maximum(f_s * state["n"] + i_s, jnp.exp(-m_new))
    h = o * (c / n)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(params, cfg, x, *, cache=None):
    """x: (B,S,d); sequential scan over time."""
    cd = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    wx = cm.dense(params["w_gates"], x, "...d,dgf->...gf", cd)  # (B,S,4,d)
    state = cache["state"] if cache is not None else slstm_init_state(B, d)

    def step(st, x_t):
        st2 = _slstm_cell(params, cfg, x_t, st)
        return st2, st2["h"].astype(jnp.bfloat16)

    # checkpoint per step: keeps backward residuals at O(state), not O(T·state)
    step = jax.checkpoint(step)
    state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(cd)  # (B,S,d)
    H = cfg.n_heads
    dh = d // H
    hg = h.reshape(B, S, H, dh).astype(jnp.float32)
    hg = hg * jax.lax.rsqrt(jnp.mean(hg * hg, axis=-1, keepdims=True) + cfg.norm_eps)
    h = (hg.reshape(B, S, d) * params["gn"].astype(jnp.float32)).astype(cd)
    up = jax.nn.gelu(cm.dense(params["up_gate"], h, "...d,df->...f", cd))
    y = cm.dense(params["down"], up * cm.dense(params["up"], h, "...d,df->...f", cd),
                 "...f,fd->...d", cd)
    new_cache = {"state": state} if cache is not None else None
    return y, new_cache


def slstm_decode(params, cfg, x, *, cache):
    cd = jnp.dtype(cfg.compute_dtype)
    B, _, d = x.shape
    wx = cm.dense(params["w_gates"], x, "...d,dgf->...gf", cd)[:, 0]  # (B,4,d)
    state = _slstm_cell(params, cfg, wx, cache["state"])
    h = state["h"].astype(cd)
    H = cfg.n_heads
    dh = d // H
    hg = h.reshape(B, H, dh).astype(jnp.float32)
    hg = hg * jax.lax.rsqrt(jnp.mean(hg * hg, axis=-1, keepdims=True) + cfg.norm_eps)
    h = (hg.reshape(B, d) * params["gn"].astype(jnp.float32)).astype(cd)
    up = jax.nn.gelu(cm.dense(params["up_gate"], h, "...d,df->...f", cd))
    y = cm.dense(params["down"], up * cm.dense(params["up"], h, "...d,df->...f", cd),
                 "...f,fd->...d", cd)
    return y[:, None], {"state": state}
