"""Model zoo: one uniform interface over every assigned architecture.

``build(bundle)`` returns a :class:`Model` whose methods close over the
config; all take/return plain pytrees so they compose with pjit/shard_map,
checkpointing and the launchers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchBundle, ModelConfig, PartitionConfig, ShapeConfig
from repro.models import common as cm
from repro.models import encdec as ed
from repro.models import transformer as tf


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    part: PartitionConfig
    param_specs: Dict[str, Any]

    # ---------------- params ---------------- #

    def init(self, key: jax.Array):
        return cm.init_params(self.param_specs, key)

    def abstract_params(self):
        return cm.abstract(self.param_specs)

    def param_shardings(self, mesh, rules=None):
        return cm.shardings(self.param_specs, mesh, self._rules(rules))

    def _rules(self, rules=None, for_opt=False):
        r = dict(cm.DEFAULT_RULES)
        if self.part.fsdp and (for_opt or self.part.zero_stage >= 3):
            # ZeRO-1: optimizer state shards over data, params stay
            # replicated on data (sharded on model only)
            r.update(cm.FSDP_RULES_OVERRIDE)
        if self.part.flash_decode:
            r["kv_seq"] = "model"
        if rules:
            r.update(rules)
        return r

    # ---------------- caches ---------------- #

    def cache_specs(self, B: int, S: int):
        if self.cfg.family == "encdec":
            return ed.encdec_cache_specs(self.cfg, self.part, B, S)
        return tf.cache_specs(self.cfg, self.part, B, S)

    def abstract_cache(self, B: int, S: int):
        return cm.abstract(self.cache_specs(B, S))

    def cache_shardings(self, mesh, B: int, S: int, rules=None):
        return cm.shardings(self.cache_specs(B, S), mesh, self._rules(rules))

    def init_cache(self, B: int, S: int):
        if self.cfg.family == "encdec":
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                self.cache_specs(B, S), is_leaf=cm._is_spec)
        return tf.init_cache(self.cfg, self.part, B, S)

    # ---------------- steps ---------------- #

    def train_loss(self, params, batch, mesh=None, rules=None):
        rules = self._rules(rules)
        if self.cfg.family == "encdec":
            return ed.encdec_train_loss(params, self.cfg, self.part, batch, mesh, rules)
        return tf.lm_train_loss(params, self.cfg, self.part, batch, mesh, rules)

    def prefill(self, params, batch, caches, mesh=None, rules=None):
        rules = self._rules(rules)
        if self.cfg.family == "encdec":
            return ed.encdec_prefill(params, self.cfg, self.part, batch, caches,
                                     mesh=mesh, rules=rules)
        return tf.lm_prefill(params, self.cfg, self.part, batch["tokens"], caches,
                             patches=batch.get("patches"), mesh=mesh, rules=rules)

    def decode_step(self, params, tokens, positions, caches, mesh=None, rules=None):
        rules = self._rules(rules)
        if self.cfg.family == "encdec":
            return ed.encdec_decode_step(params, self.cfg, self.part, tokens,
                                         positions, caches, mesh=mesh, rules=rules)
        return tf.lm_decode_step(params, self.cfg, self.part, tokens, positions,
                                 caches, mesh=mesh, rules=rules)

    # ---------------- dry-run inputs ---------------- #

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of the step kind
        (the modality frontend is a stub: precomputed frame/patch embeddings
        appear as inputs, per the assignment)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        bf16 = jnp.bfloat16

        def tok(*s):
            return jax.ShapeDtypeStruct(s, i32)

        if shape.kind == "train":
            if cfg.family == "encdec":
                S_dec = S // cfg.dec_ratio
                return {
                    "batch": {
                        "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), bf16),
                        "tokens": tok(B, S_dec),
                        "labels": tok(B, S_dec),
                    }
                }
            if cfg.modality == "vision":
                n_tok = S - cfg.n_prefix_tokens
                return {
                    "batch": {
                        "tokens": tok(B, n_tok),
                        "patches": jax.ShapeDtypeStruct(
                            (B, cfg.n_prefix_tokens, cfg.frontend_dim), bf16),
                        "labels": tok(B, n_tok),
                    }
                }
            return {"batch": {"tokens": tok(B, S), "labels": tok(B, S)}}

        if shape.kind == "prefill":
            caches = self.abstract_cache(B, S)
            if cfg.family == "encdec":
                S_dec = S // cfg.dec_ratio
                batch = {
                    "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), bf16),
                    "tokens": tok(B, S_dec),
                }
            elif cfg.modality == "vision":
                batch = {
                    "tokens": tok(B, S - cfg.n_prefix_tokens),
                    "patches": jax.ShapeDtypeStruct(
                        (B, cfg.n_prefix_tokens, cfg.frontend_dim), bf16),
                }
            else:
                batch = {"tokens": tok(B, S)}
            return {"batch": batch, "caches": caches}

        # decode: one new token against a cache of S
        return {
            "tokens": tok(B, 1),
            "positions": jax.ShapeDtypeStruct((B,), i32),
            "caches": self.abstract_cache(B, S),
        }

    def batch_shardings(self, mesh, tree, rules=None):
        """NamedShardings for an input_specs()-shaped tree: leading dim of
        every leaf is batch (except nothing else needs sharding)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

        def shard_leaf(leaf):
            if leaf.ndim == 0:
                return NamedSharding(mesh, P())
            spec = [None] * leaf.ndim
            if leaf.shape[0] % max(1, _prod(mesh.shape[a] for a in batch_axes)) == 0:
                spec[0] = batch_axes if len(batch_axes) > 1 else (
                    batch_axes[0] if batch_axes else None)
            return NamedSharding(mesh, P(*spec))

        return jax.tree_util.tree_map(shard_leaf, tree)


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out


def build(bundle: ArchBundle) -> Model:
    cfg, part = bundle.model, bundle.partition
    if cfg.family == "encdec":
        specs = ed.encdec_specs(cfg, part)
    else:
        specs = tf.lm_specs(cfg, part)
    return Model(cfg=cfg, part=part, param_specs=specs)
