"""Mamba-1 selective SSM block (for jamba), TPU-adapted.

The selective scan is computed in *chunks*: within a chunk the linear
recurrence h_t = a_t h_{t-1} + b_t is solved with ``jax.lax.associative_scan``
(log-depth — the same semiring-scan machinery as the block-parallel Viterbi
decoder in core/viterbi.py, with (×,+) instead of (min,+)); across chunks a
``lax.scan`` carries the (B, d_inner, d_state) state.  This bounds the
materialized (B, chunk, d_inner, d_state) tensor while keeping VPU-friendly
parallel depth, analogous to how the Texpand kernel keeps its recurrent state
(path metrics) in VMEM.

Decode is the exact single-step recurrence (O(1) state, no KV growth).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


def _dt_rank(cfg) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def ssm_specs(cfg, stack: int):
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    dtr = _dt_rank(cfg)
    N = s.d_state

    def P(shape, axes, init="normal", scale=1.0, fan_in=0):
        if stack:
            shape = (stack,) + shape
            axes = ("layers",) + axes
        return cm.ParamSpec(shape, axes, init, scale, fan_in)

    return {
        "in_proj": cm.dense_spec((d,), (2 * d_in,), ("embed",), ("dinner",), stack=stack),
        "conv_w": P((s.d_conv, d_in), ("conv", "dinner"), "normal", 1.0, s.d_conv),
        "conv_b": P((d_in,), ("dinner",), "zeros"),
        "x_proj": cm.dense_spec((d_in,), (dtr + 2 * N,), ("dinner",), (None,), stack=stack),
        "dt_proj": cm.dense_spec((dtr,), (d_in,), (None,), ("dinner",), stack=stack, bias=True),
        "A_log": P((d_in, N), ("dinner", "dstate"), "ones"),
        "D": P((d_in,), ("dinner",), "ones"),
        "out_proj": cm.dense_spec((d_in,), (d,), ("dinner",), ("embed",), stack=stack),
    }


def _ssm_scan_chunked(xc, dt, Bm, Cm, A, h0, chunk: int):
    """Selective-scan with fully chunk-local intermediates.

    Solves h_t = a_t h_{t-1} + b_t and emits y_t = <h_t, C_t>, where
    a = exp(dt·A), b = dt·B·x.  a/b/h live only at (B, chunk, D, N) — the
    full-length (B, S, D, N) tensor is never materialized (it dominated the
    jamba train cells at ~8.6 GB/layer).

    xc/dt: (B, S, D); Bm/Cm: (B, S, N); A: (D, N); h0: (B, D, N).
    Returns y (B, S, D) float32 and the final state.
    """
    B, S, D = xc.shape
    chunk = min(chunk, S)
    while S % chunk:  # largest divisor <= requested chunk
        chunk -= 1
    nc = S // chunk

    def resh(t):
        return t.reshape((B, nc, chunk) + t.shape[2:]).swapaxes(0, 1)

    xs = (resh(xc), resh(dt), resh(Bm), resh(Cm))

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    def chunk_step(h, xs_c):
        xc_c, dt_c, B_c, C_c = xs_c  # (B, chunk, ...)
        a_k = jnp.exp(dt_c[..., None] * A)  # (B, chunk, D, N)
        b_k = (dt_c[..., None] * B_c[:, :, None, :]) * xc_c[..., None]
        b_k = b_k.at[:, 0].add(a_k[:, 0] * h)  # fold carry into element 0
        _, hh = jax.lax.associative_scan(combine, (a_k, b_k), axis=1)
        y_c = jnp.einsum("bsdn,bsn->bsd", hh, C_c)  # contract N immediately
        return hh[:, -1], y_c

    # checkpoint per chunk: (B, chunk, D, N) intermediates recompute in bwd
    chunk_step = jax.checkpoint(chunk_step)
    hT, ys = jax.lax.scan(chunk_step, h0, xs)
    return ys.swapaxes(0, 1).reshape(B, S, D), hT


def ssm_apply(
    params, cfg, x, *,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full-sequence selective SSM.  x: (B, S, d).

    If ``cache`` is given (prefill), the final conv window and ssm state are
    stored for decode.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    s = cfg.ssm
    B, S, d = x.shape
    d_in = s.expand * d
    N = s.d_state
    dtr = _dt_rank(cfg)

    xz = cm.dense(params["in_proj"], x, "...d,df->...f", cd)
    xi, z = xz[..., :d_in], xz[..., d_in:]
    # depthwise causal conv1d
    w = params["conv_w"].astype(cd)  # (K, d_in)
    K = w.shape[0]
    xpad = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(xpad[:, i : i + S] * w[i] for i in range(K)) + params["conv_b"].astype(cd)
    xc = jax.nn.silu(conv)

    proj = cm.dense(params["x_proj"], xc, "...f,fp->...p", cd)
    dt_in, Bm, Cm = proj[..., :dtr], proj[..., dtr : dtr + N], proj[..., dtr + N :]
    dt = jax.nn.softplus(cm.dense(params["dt_proj"], dt_in, "...r,rf->...f", cd)).astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (d_in, N)

    h0 = cache["ssm"].astype(jnp.float32) if cache is not None else jnp.zeros((B, d_in, N), jnp.float32)
    y, hT = _ssm_scan_chunked(
        xc.astype(jnp.float32), dt, Bm.astype(jnp.float32),
        Cm.astype(jnp.float32), A, h0, s.chunk)
    y = (y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)).astype(cd)
    y = y * jax.nn.silu(z)
    out = cm.dense(params["out_proj"], y, "...f,fd->...d", cd)
    new_cache = None
    if cache is not None:
        new_cache = {"ssm": hT.astype(cache["ssm"].dtype),
                     "conv": xi[:, -(K - 1):].astype(cache["conv"].dtype)}
    return out, new_cache


def ssm_decode(
    params, cfg, x, *,
    cache: Dict[str, jnp.ndarray],  # ssm: (B, d_in, N); conv: (B, K-1, d_in)
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token recurrence.  x: (B, 1, d)."""
    cd = jnp.dtype(cfg.compute_dtype)
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    N = s.d_state
    dtr = _dt_rank(cfg)

    xz = cm.dense(params["in_proj"], x, "...d,df->...f", cd)[:, 0]
    xi, z = xz[..., :d_in], xz[..., d_in:]
    w = params["conv_w"].astype(cd)  # (K, d_in)
    window = jnp.concatenate([cache["conv"].astype(cd), xi[:, None]], axis=1)  # (B,K,d_in)
    conv = jnp.einsum("bkf,kf->bf", window, w) + params["conv_b"].astype(cd)
    xc = jax.nn.silu(conv)

    proj = cm.dense(params["x_proj"], xc, "...f,fp->...p", cd)
    dt_in, Bm, Cm = proj[..., :dtr], proj[..., dtr : dtr + N], proj[..., dtr + N :]
    dt = jax.nn.softplus(cm.dense(params["dt_proj"], dt_in, "...r,rf->...f", cd)).astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[..., None] * A)  # (B,d_in,N)
    bx = (dt[..., None] * Bm[:, None, :].astype(jnp.float32)) * xc[..., None].astype(jnp.float32)
    h = a * cache["ssm"].astype(jnp.float32) + bx
    y = jnp.einsum("bdn,bn->bd", h, Cm.astype(jnp.float32))
    y = (y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)).astype(cd)
    y = y * jax.nn.silu(z)
    out = cm.dense(params["out_proj"], y[:, None], "...f,fd->...d", cd)
    new_cache = {"ssm": h.astype(cache["ssm"].dtype),
                 "conv": window[:, 1:].astype(cache["conv"].dtype)}
    return out, new_cache
