"""Model substrate: blocks (attention/MLA/MoE/SSM/xLSTM), assemblies
(decoder-only LM, encoder-decoder), and the model zoo."""
from repro.models.model_zoo import Model, build

__all__ = ["Model", "build"]
