"""Interleavers as hashable specs.

A turbo code is defined by its constituent RSC code *and* its interleaver,
so the interleaver must be part of the hashable TurboSpec the jit caches
key on.  Both kinds here are frozen dataclasses of ints whose permutation
tables are derived lazily (cached) — the spec itself stays tiny and
hashable, like ConvCode/RSCCode.

Convention: ``interleaved[k] = natural[permutation[k]]`` — i.e.
``interleave(x) = x[perm]`` and ``deinterleave(y) = y[inverse]``.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np


@dataclasses.dataclass(frozen=True)
class BlockInterleaver:
    """Classic row-column interleaver: write row-major into a (rows, cols)
    matrix, read column-major."""

    rows: int
    cols: int

    def __post_init__(self):
        if self.rows < 1 or self.cols < 1:
            raise ValueError("rows and cols must be positive")

    @property
    def n(self) -> int:
        return self.rows * self.cols

    @cached_property
    def permutation(self) -> np.ndarray:
        k = np.arange(self.n)
        # k-th read (column-major) hits element (k % rows, k // rows)
        return ((k % self.rows) * self.cols + k // self.rows).astype(np.int32)

    @cached_property
    def inverse(self) -> np.ndarray:
        return np.argsort(self.permutation).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class QPPInterleaver:
    """Quadratic permutation polynomial interleaver (the LTE turbo family):
    ``pi(k) = (f1*k + f2*k^2) mod n``.

    Contention-free and maximally spread for the standardized (n, f1, f2)
    triples; the constructor verifies the polynomial actually permutes
    [0, n) so a bad triple fails loudly at spec-construction time.
    """

    n: int
    f1: int
    f2: int

    def __post_init__(self):
        if self.n < 2:
            raise ValueError("interleaver length must be >= 2")
        perm = self._compute()
        if len(np.unique(perm)) != self.n:
            raise ValueError(
                f"(f1={self.f1}, f2={self.f2}) is not a permutation polynomial "
                f"mod {self.n}"
            )

    def _compute(self) -> np.ndarray:
        k = np.arange(self.n, dtype=np.int64)
        return ((self.f1 * k + self.f2 * k * k) % self.n).astype(np.int32)

    @cached_property
    def permutation(self) -> np.ndarray:
        return self._compute()

    @cached_property
    def inverse(self) -> np.ndarray:
        return np.argsort(self.permutation).astype(np.int32)
