"""Recursive systematic convolutional (RSC) codes — the SISO trellis.

Turbo constituents are *recursive* systematic codes: the shift register
feeds back through ``feedback`` (g0) and the transmitted outputs are the
systematic bit plus one parity per ``forward`` polynomial (g1, ...).

Register/state convention mirrors core/trellis.py: the register at time t
holds ``[a_t, a_{t-1}, ..., a_{t-K+1}]`` (newest first) where ``a_t`` is the
*feedback-combined* bit ``a = u XOR parity(g0 & state)``; the state is the
top K-1 bits after the shift, ``s_t = (a_t << (K-2)) | (s_{t-1} >> 1)``.

The crucial consequence: with ``a`` in the role ConvCode gives the input
bit, the RSC trellis has the IDENTICAL de Bruijn butterfly connectivity —
successor ``s' = a*S/2 + v`` with predecessors ``p0 = 2v`` and ``p1 = 2v+1``
— so the (S, S) one-hot select matmuls of the Pallas ACS kernels carry over
unchanged.  Only the labelling differs: the transition ``p -> s'`` consumes
input ``u = a XOR f(p)`` (``f(p) = parity(g0 & p)``) and emits
``[u, parity(g_j & reg), ...]``.

Branch costs are affine in per-bit log-likelihood ratios (the same trick as
kernels/metrics.py fused metric plans): with the convention
``lambda = log P(bit=0) / P(bit=1)`` the cost of a transition is
``sum_j x_j * lambda_c[j] + u * lambda_a`` — a ``(S, F)`` weight matrix
times the F = n_out + 1 per-step feature column ``[channel LLRs, a-priori
LLR]``.  The cached properties below bake those weights, plus the gather
matrices the backward/LLR kernel needs, as numpy constants.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import cached_property
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trellis import _parity


@dataclasses.dataclass(frozen=True)
class RSCCode:
    """Rate 1/(1+len(forward)) recursive systematic convolutional code.

    Attributes:
      constraint: constraint length K (register holds K bits).
      feedback: recursion polynomial g0 (K bits, monic: bit K-1 — the tap on
        the current bit — must be set; bits K-2..0 tap the state).
      forward: parity generator polynomials, each of K bits over the
        *feedback-combined* register (bit K-1 taps ``a_t``).
    """

    constraint: int = 3
    feedback: int = 0b111
    forward: Tuple[int, ...] = (0b101,)

    def __post_init__(self):
        K = self.constraint
        if K < 2:
            raise ValueError("constraint length must be >= 2")
        if not (1 << (K - 1)) <= self.feedback < (1 << K):
            raise ValueError(
                f"feedback poly {self.feedback:#o} must be monic in K={K} bits"
            )
        if not self.forward:
            raise ValueError("need at least one forward (parity) polynomial")
        for g in self.forward:
            if not 0 <= g < (1 << K):
                raise ValueError(f"poly {g:#o} does not fit in K={K} bits")

    # ------------------------------ shape ------------------------------ #

    @property
    def n_parity(self) -> int:
        return len(self.forward)

    @property
    def n_out(self) -> int:
        """Coded bits per input bit: systematic + parities."""
        return 1 + self.n_parity

    @property
    def n_states(self) -> int:
        return 1 << (self.constraint - 1)

    @property
    def n_symbols(self) -> int:
        return 1 << self.n_out

    @property
    def n_features(self) -> int:
        """Per-step feature width: n_out channel LLRs + one a-priori LLR."""
        return self.n_out + 1

    # ------------------------------ tables ----------------------------- #

    @cached_property
    def feedback_bits(self) -> np.ndarray:
        """(S,) int32: f(s) = parity(g0 & s) — the recursion term."""
        return np.array(
            [_parity(self.feedback & s) for s in range(self.n_states)],
            dtype=np.int32,
        )

    @cached_property
    def next_state(self) -> np.ndarray:
        """(S, 2) int32: successor of (state=p, input=u)."""
        K, S = self.constraint, self.n_states
        nxt = np.zeros((S, 2), dtype=np.int32)
        for p in range(S):
            for u in (0, 1):
                a = u ^ int(self.feedback_bits[p])
                nxt[p, u] = (a << (K - 2)) | (p >> 1)
        return nxt

    @cached_property
    def out_bits(self) -> np.ndarray:
        """(S, 2, n_out) int32: coded bits of transition (state=p, input=u),
        systematic bit first."""
        K, S = self.constraint, self.n_states
        out = np.zeros((S, 2, self.n_out), dtype=np.int32)
        for p in range(S):
            for u in (0, 1):
                a = u ^ int(self.feedback_bits[p])
                reg = (a << (K - 1)) | p
                out[p, u, 0] = u
                for j, g in enumerate(self.forward):
                    out[p, u, 1 + j] = _parity(g & reg)
        return out

    def _weight_row(self, p: int, u: int) -> np.ndarray:
        """(F,) cost weights of transition (p, u): coded bits then u (the
        a-priori tap)."""
        row = np.zeros(self.n_features, dtype=np.float32)
        row[: self.n_out] = self.out_bits[p, u]
        row[self.n_out] = u
        return row

    @cached_property
    def select_matrices(self) -> Tuple[np.ndarray, np.ndarray]:
        """(P0, P1) as in ConvCode: ``P_j[s', 2v+j] = 1`` — identical
        butterfly connectivity, reused verbatim by the alpha scan."""
        S = self.n_states
        half = S // 2
        P0 = np.zeros((S, S), dtype=np.float32)
        P1 = np.zeros((S, S), dtype=np.float32)
        for sp in range(S):
            v = sp % half
            P0[sp, 2 * v] = 1.0
            P1[sp, 2 * v + 1] = 1.0
        return P0, P1

    @cached_property
    def alpha_weights(self) -> Tuple[np.ndarray, np.ndarray]:
        """(b0, b1), each (S, F): row s' holds the branch-cost weights of the
        transition arriving from predecessor ``p_j = 2v + j``."""
        S, F = self.n_states, self.n_features
        half = S // 2
        b0 = np.zeros((S, F), dtype=np.float32)
        b1 = np.zeros((S, F), dtype=np.float32)
        for sp in range(S):
            a, v = sp // half, sp % half
            for j, b in ((0, b0), (1, b1)):
                p = 2 * v + j
                u = a ^ int(self.feedback_bits[p])
                b[sp] = self._weight_row(p, u)
        return b0, b1

    @cached_property
    def beta_matrices(self) -> Tuple[np.ndarray, np.ndarray]:
        """(N0, N1), each (S, S): ``N_a[p, s'] = 1`` iff s' is the successor
        of p under new register bit a — the backward-recursion gathers."""
        S = self.n_states
        half = S // 2
        N0 = np.zeros((S, S), dtype=np.float32)
        N1 = np.zeros((S, S), dtype=np.float32)
        for p in range(S):
            for a, N in ((0, N0), (1, N1)):
                N[p, a * half + (p >> 1)] = 1.0
        return N0, N1

    @cached_property
    def beta_weights(self) -> Tuple[np.ndarray, np.ndarray]:
        """(c0, c1), each (S, F): branch-cost weights of the transition
        leaving p under new register bit a (input ``u = a XOR f(p)``)."""
        S, F = self.n_states, self.n_features
        c0 = np.zeros((S, F), dtype=np.float32)
        c1 = np.zeros((S, F), dtype=np.float32)
        for p in range(S):
            for a, c in ((0, c0), (1, c1)):
                c[p] = self._weight_row(p, a ^ int(self.feedback_bits[p]))
        return c0, c1

    @cached_property
    def llr_matrices(self) -> Tuple[np.ndarray, np.ndarray]:
        """(U0, U1), each (S, S): ``U_u[p, s'] = 1`` iff s' is the successor
        of p under *input bit* u — the per-hypothesis gathers of the LLR
        extraction (min over transitions with u fixed)."""
        S = self.n_states
        U0 = np.zeros((S, S), dtype=np.float32)
        U1 = np.zeros((S, S), dtype=np.float32)
        for p in range(S):
            for u, U in ((0, U0), (1, U1)):
                U[p, self.next_state[p, u]] = 1.0
        return U0, U1

    @cached_property
    def llr_weights(self) -> Tuple[np.ndarray, np.ndarray]:
        """(w0, w1), each (S, F): branch-cost weights of the transition
        leaving p under input bit u."""
        S, F = self.n_states, self.n_features
        w0 = np.zeros((S, F), dtype=np.float32)
        w1 = np.zeros((S, F), dtype=np.float32)
        for p in range(S):
            for u, w in ((0, w0), (1, w1)):
                w[p] = self._weight_row(p, u)
        return w0, w1

    # ------------------------------ encode ----------------------------- #

    @property
    def n_flush(self) -> int:
        return self.constraint - 1

    def encode(self, bits: jnp.ndarray, terminate: bool = True) -> jnp.ndarray:
        """(..., T) info bits -> (..., T [+ n_flush], n_out) coded bits.

        The recursion makes this a genuine sequential scan (unlike the
        windowed feed-forward encoder).  Termination drives the register to
        zero with the state-dependent tail ``u = f(s)`` (so ``a = 0`` each
        flush step); tail bits are transmitted like any others.
        """
        return _rsc_encode(self, bool(terminate), jnp.asarray(bits, jnp.int32))


@functools.partial(jax.jit, static_argnums=(0, 1))
def _rsc_encode(code: RSCCode, terminate: bool, bits: jnp.ndarray) -> jnp.ndarray:
    lead = bits.shape[:-1]
    T = bits.shape[-1]
    flat = bits.reshape((-1, T))
    nxt = jnp.asarray(code.next_state)
    out = jnp.asarray(code.out_bits)
    fb = jnp.asarray(code.feedback_bits)

    def step(s, u):
        return nxt[s, u], out[s, u]

    s0 = jnp.zeros(flat.shape[0], dtype=jnp.int32)
    s_end, coded = jax.lax.scan(step, s0, flat.T)
    coded = coded.transpose(1, 0, 2)  # (B, T, n_out)
    if terminate:
        def tail_step(s, _):
            u = fb[s]
            return nxt[s, u], out[s, u]

        _, tail = jax.lax.scan(tail_step, s_end, None, length=code.n_flush)
        coded = jnp.concatenate([coded, tail.transpose(1, 0, 2)], axis=1)
        T = T + code.n_flush
    return coded.reshape(lead + (T, code.n_out))


# Named codes used by tests / benchmarks.
RSC_K3_75 = RSCCode(3, 0b111, (0b101,))      # recursive (1, 5/7): the textbook SISO toy
RSC_K4_LTE = RSCCode(4, 0o13, (0o15,))       # the LTE turbo constituent (13, 15)_oct
