"""Iterative turbo decoding: two RSC SISO passes exchanging extrinsic LLRs.

A TurboSpec is the turbo-family analogue of CodecSpec: constituent RSC code
+ interleaver + optional puncture pattern + iteration policy, hashable so it
keys jit caches and the decode registry the same way CodecSpec does.  The
encoder emits [systematic, parity1, parity2(interleaved input)] — the
classic rate-1/3 parallel concatenation; both constituent trellises are
left open (no tails), which keeps the rate exactly 1/(1 + 2*n_parity) and
both SISO passes shape-identical (one kernel compilation serves both).

Decode loop (all LLRs min-domain, ``lambda = log P(0)/P(1)``):

  La1 = deinterleave(Le2)
  L1  = SISO1(lam_sys, lam_p1, La1)          Le1 = L1 - lam_sys - La1
  La2 = interleave(Le1)
  L2  = SISO2(lam_sys[pi], lam_p2, La2)      Le2 = L2 - lam_sys[pi] - La2

Early exit: a stream whose hard decisions agree with its previous iteration
is *frozen* — its extrinsic input is held at the value that produced the
converged decisions, so every later iteration reproduces them exactly.
That makes the early-exit path bit-exact with the fixed-iteration path by
construction (gated in tests), and the loop stops once every stream froze.

Observability: pass ``metrics=MetricsRegistry()`` (repro.obs) and the loop
records per-iteration LLR-sign agreement, iteration counts, converged
streams, and early exits.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import awgn, bpsk_modulate
from repro.core.puncture import pattern_mask
from repro.kernels.ops import bcjr_llr_op
from repro.siso.interleave import BlockInterleaver, QPPInterleaver
from repro.siso.rsc import RSC_K3_75, RSCCode

InterleaverSpec = Union[BlockInterleaver, QPPInterleaver]


@dataclasses.dataclass(frozen=True)
class TurboSpec:
    """Immutable turbo-codec description (the "turbo" code family).

    Attributes:
      code: the constituent RSC code (both constituents are identical).
      interleaver: hashable interleaver spec; fixes the block length N.
      puncture: optional (n_streams, period) 0/1 pattern over the
        [systematic, parities1..., parities2...] streams (WIMAX-style
        rate-compatible puncturing); stored as nested tuples.
      iterations: full decode iterations (two SISO passes each).
      early_exit: stop once every stream's hard decisions stabilized
        (bit-exact with running all ``iterations`` — see module docstring).
      extrinsic_scale: damping on the exchanged extrinsic LLRs.  Max-log
        SISO overestimates reliability; the classic 0.7 scaling recovers
        most of the gap to true log-MAP (Vogt & Finger 2000).
    """

    code: RSCCode = RSC_K3_75
    interleaver: InterleaverSpec = QPPInterleaver(64, 7, 16)
    puncture: Optional[Tuple[Tuple[int, ...], ...]] = None
    iterations: int = 6
    early_exit: bool = True
    extrinsic_scale: float = 0.7

    def __post_init__(self):
        if self.iterations < 1:
            raise ValueError("iterations must be >= 1")
        if self.puncture is not None:
            pat = np.asarray(self.puncture)
            if pat.ndim != 2 or pat.shape[0] != self.n_streams:
                raise ValueError(
                    f"puncture pattern must be (n_streams={self.n_streams}, "
                    f"period), got shape {pat.shape}"
                )
            object.__setattr__(
                self, "puncture", tuple(tuple(int(x) for x in row) for row in pat)
            )

    # ----------------------------- derived ----------------------------- #

    @property
    def family(self) -> str:
        return "turbo"

    @property
    def n_streams(self) -> int:
        """Coded streams per info bit: systematic + both constituents' parities."""
        return 1 + 2 * self.code.n_parity

    @property
    def block_len(self) -> int:
        return self.interleaver.n

    @property
    def terminated(self) -> bool:
        """Constituent trellises are left open (no tail bits)."""
        return False

    @property
    def metric(self) -> str:
        return "soft"

    @property
    def puncture_array(self) -> Optional[np.ndarray]:
        return None if self.puncture is None else np.asarray(self.puncture)

    @property
    def n_flush(self) -> int:
        return 0

    @property
    def table_width(self) -> int:
        """Width of the per-step decoder input (the bm-table analogue)."""
        return self.n_streams

    def n_steps(self, n_info_bits: int) -> int:
        return n_info_bits

    # --------------------------- encode side --------------------------- #

    def encode(self, bits: jnp.ndarray) -> jnp.ndarray:
        """(..., N) info bits -> (..., N, n_streams) coded bits, N =
        interleaver.n; punctured positions zeroed (not transmitted)."""
        if bits.shape[-1] != self.block_len:
            raise ValueError(
                f"turbo block length is fixed by the interleaver: expected "
                f"{self.block_len} info bits, got {bits.shape[-1]}"
            )
        perm = jnp.asarray(self.interleaver.permutation)
        c1 = self.code.encode(bits, terminate=False)  # (..., N, 1 + n_parity)
        c2 = self.code.encode(bits[..., perm], terminate=False)
        coded = jnp.concatenate([c1, c2[..., 1:]], axis=-1)
        if self.puncture is not None:
            mask = pattern_mask(self.n_streams, self.block_len, self.puncture_array)
            coded = (coded * mask).astype(coded.dtype)
        return coded

    def channel(self, key: jax.Array, coded_bits: jnp.ndarray, *,
                snr_db: float) -> jnp.ndarray:
        """BPSK + AWGN — turbo decoding is soft-input by nature."""
        return awgn(key, bpsk_modulate(coded_bits), snr_db)

    # --------------------------- decode side --------------------------- #

    def channel_llrs(self, received: jnp.ndarray,
                     snr_db: Optional[float] = None) -> jnp.ndarray:
        """(..., N, n_streams) channel values -> per-bit LLRs.

        With BPSK (bit 0 -> +1) over AWGN at Es/N0 = snr, the exact LLR is
        ``4 * snr * y``; max-log decoding is invariant to a positive scale,
        so ``snr_db=None`` just uses y.  Punctured positions are erased to 0
        whatever the channel delivered there.
        """
        lam = received.astype(jnp.float32)
        if snr_db is not None:
            lam = lam * (4.0 * 10.0 ** (snr_db / 10.0))
        if self.puncture is not None:
            mask = pattern_mask(self.n_streams, received.shape[-2], self.puncture_array)
            lam = lam * mask
        return lam

    def branch_metrics(self, received: jnp.ndarray) -> jnp.ndarray:
        """The bm-table analogue for the registry's normalized signature:
        per-stream channel LLRs (scale-free; see channel_llrs)."""
        return self.channel_llrs(received)

    def strip_flush(self, bits: jnp.ndarray) -> jnp.ndarray:
        return bits

    def describe(self) -> str:
        punct = "unpunctured" if self.puncture is None else f"punctured{self.puncture}"
        return (
            f"Turbo(RSC K={self.code.constraint}, fb={oct(self.code.feedback)}, "
            f"fwd={tuple(oct(g) for g in self.code.forward)}, "
            f"{type(self.interleaver).__name__} N={self.block_len}) "
            f"rate-1/{self.n_streams} {punct}/"
            f"{self.iterations}it{'/early-exit' if self.early_exit else ''}"
        )


@dataclasses.dataclass
class TurboResult:
    """Outcome of one turbo decode."""

    bits: jnp.ndarray            #: (B, N) int32 hard decisions
    llr: jnp.ndarray             #: (B, N) float32 a-posteriori LLRs
    iterations_run: int          #: iterations actually executed
    agreement: Tuple[float, ...]  #: per-iteration LLR-sign agreement fraction
    converged: jnp.ndarray       #: (B,) bool — streams whose decisions froze


@functools.lru_cache(maxsize=None)
def _iteration_fn(spec: TurboSpec, interpret: Optional[bool]):
    """Jitted single turbo iteration, cached per (spec, interpret)."""
    code = spec.code
    perm = jnp.asarray(spec.interleaver.permutation)
    inv = jnp.asarray(spec.interleaver.inverse)
    npar = code.n_parity
    scale = float(spec.extrinsic_scale)

    @jax.jit
    def step(llrs, le2, prev_bits, done):
        lam_sys = llrs[..., 0]
        lam_p1 = llrs[..., 1:1 + npar]
        lam_p2 = llrs[..., 1 + npar:]
        # SISO 1 (natural order)
        la1 = le2[:, inv]
        l1, _ = bcjr_llr_op(
            code, jnp.concatenate([lam_sys[..., None], lam_p1], axis=-1),
            la1, terminated=False, interpret=interpret,
        )
        le1 = scale * (l1 - lam_sys - la1)
        # SISO 2 (interleaved order)
        sys2 = lam_sys[:, perm]
        la2 = le1[:, perm]
        l2, _ = bcjr_llr_op(
            code, jnp.concatenate([sys2[..., None], lam_p2], axis=-1),
            la2, terminated=False, interpret=interpret,
        )
        le2_new = scale * (l2 - sys2 - la2)
        llr_full = l2[:, inv]
        bits = (llr_full < 0).astype(jnp.int32)
        agree_stream = jnp.mean((bits == prev_bits).astype(jnp.float32), axis=1)
        done_new = done | (agree_stream >= 1.0)
        # freeze converged streams at the extrinsic INPUT that produced their
        # decisions: every later iteration replays them bit-exactly
        le2_out = jnp.where(done_new[:, None], le2, le2_new)
        agree_frac = jnp.mean((bits == prev_bits).astype(jnp.float32))
        return le2_out, bits, llr_full, done_new, agree_frac

    return step


def turbo_decode(
    spec: TurboSpec,
    llrs: jnp.ndarray,
    *,
    iterations: Optional[int] = None,
    early_exit: Optional[bool] = None,
    interpret: Optional[bool] = None,
    metrics=None,
) -> TurboResult:
    """Iteratively decode (B, N, n_streams) channel LLRs.

    Args:
      llrs: per-bit channel LLRs (spec.channel_llrs of the received block).
      iterations / early_exit: override the spec's policy.
      metrics: optional repro.obs MetricsRegistry — records
        ``turbo_iterations_total``, ``turbo_llr_agreement`` (per-iteration
        sign-agreement histogram), ``turbo_converged_streams`` and
        ``turbo_early_exits_total``.
    """
    iterations = spec.iterations if iterations is None else int(iterations)
    early_exit = spec.early_exit if early_exit is None else bool(early_exit)
    B, N, ns = llrs.shape
    if N != spec.block_len or ns != spec.n_streams:
        raise ValueError(
            f"expected (B, {spec.block_len}, {spec.n_streams}) LLRs, "
            f"got {llrs.shape}"
        )
    step = _iteration_fn(spec, interpret)
    llrs = jnp.asarray(llrs, jnp.float32)
    le2 = jnp.zeros((B, N), jnp.float32)
    prev_bits = jnp.full((B, N), -1, jnp.int32)  # never matches: no false freeze
    done = jnp.zeros((B,), bool)
    agreements = []
    bits = llr_full = None
    n_run = 0
    for _ in range(iterations):
        le2, bits, llr_full, done, agree = step(llrs, le2, prev_bits, done)
        prev_bits = bits
        n_run += 1
        agree = float(agree)
        agreements.append(agree)
        if metrics is not None:
            metrics.counter(
                "turbo_iterations_total", "turbo decode iterations executed"
            ).inc()
            metrics.histogram(
                "turbo_llr_agreement",
                buckets=(0.5, 0.9, 0.99, 0.999, 1.0),
                help="per-iteration LLR-sign agreement with the previous iteration",
            ).observe(agree)
        if early_exit and bool(done.all()):
            if metrics is not None:
                metrics.counter(
                    "turbo_early_exits_total",
                    "decodes stopped before the iteration budget",
                ).inc()
            break
    if metrics is not None:
        metrics.gauge(
            "turbo_converged_streams", "streams whose decisions froze"
        ).set(float(done.sum()))
    return TurboResult(
        bits=bits, llr=llr_full, iterations_run=n_run,
        agreement=tuple(agreements), converged=done,
    )
