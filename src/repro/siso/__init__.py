"""Soft-in/soft-out (SISO) codec subsystem.

rsc.py        recursive systematic convolutional codes: same de Bruijn
              butterfly as ConvCode (the Pallas select matmuls carry over),
              plus the gather/weight tables of the BCJR backward pass.
interleave.py block and QPP interleavers as hashable specs.
turbo.py      TurboSpec (the "turbo" code family) + the iterative
              extrinsic-exchange loop over two RSC SISO passes.

The kernels live in kernels/bcjr.py (alpha scan + fused beta/LLR scan),
exposed as kernels/ops.bcjr_llr_op; registry backends ("bcjr", "turbo") in
decode/backends.py route here via the planner's code-family rule.
"""
from repro.siso.interleave import BlockInterleaver, QPPInterleaver
from repro.siso.rsc import RSC_K3_75, RSC_K4_LTE, RSCCode
from repro.siso.turbo import TurboResult, TurboSpec, turbo_decode

__all__ = [
    "BlockInterleaver",
    "QPPInterleaver",
    "RSCCode",
    "RSC_K3_75",
    "RSC_K4_LTE",
    "TurboResult",
    "TurboSpec",
    "turbo_decode",
]
