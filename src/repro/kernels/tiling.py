"""Time-tiling plans for the tiled (time-parallel) decode path.

A long block's T trellis steps are split into P tiles that all run through
the packed Pallas forward scan *in one launch* — the tiles are folded into
the lane (batch) axis, so the launch's grid time dimension shrinks from T to
``span`` ≈ T/P and the wall-clock critical path with it.  Every tile gets a
uniform ``span`` of rows so the launch stays rectangular; where a tile's
real coverage is shorter (the warm-up of tile 0 reaches before step 0, the
last tile's core runs past T, T % P != 0, T % 32 != 0) the per-lane validity
windows of kernels/viterbi_scan.py and kernels/survivors.py pass the extra
steps through untouched.

Two seam-resolution regimes, selected by ``overlap``:

  exact (overlap == 0)      tiles abut; seams are resolved exactly by the
                            min-plus state-map composition of
                            kernels/minplus.py (two passes, see
                            ops.viterbi_decode_tiled_op).  Bit-exact vs the
                            full-length scan.
  truncated (0 < overlap)   each tile is re-warmed from a uniform-zero
                            metric vector over ``overlap`` extra leading
                            steps (the classic truncated/sliding-window
                            approximation); one pass, approximate when
                            overlap < the truncation depth 5·K.

``ops.viterbi_decode_tiled_op`` promotes any requested overlap >= the
truncation depth to the exact regime — exactness subsumes warm-up, so
"overlap at least the truncation depth" always means "bit-exact".
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: The textbook truncated-traceback depth multiplier (D = 5·K) — same rule
#: as stream/window.default_depth, restated here so kernels/ stays below
#: stream/ in the layering.
DEPTH_MULTIPLIER = 5

#: A tile shorter than this wastes more launch overhead than it saves;
#: default_tiles will not split below it.
MIN_TILE_CORE = 128


def truncation_depth(code) -> int:
    """Survivor-merge depth after which truncation is conventionally safe."""
    return DEPTH_MULTIPLIER * code.constraint


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """How one length-T sequence folds into a rectangular tile launch.

    Attributes:
      steps: T, the real trellis length.
      n_tiles: effective tile count (<= the requested count when T is short).
      core: steps each tile owns; tile p's core is [p*core, (p+1)*core) ∩ [0, T).
      overlap: warm-up steps prepended to each core (0 = exact seams).
      span: overlap + core — the uniform per-launch step count.
    """

    steps: int
    n_tiles: int
    core: int
    overlap: int
    span: int

    @property
    def exact(self) -> bool:
        return self.overlap == 0

    def tile_length(self, p: int) -> int:
        """Real (core) steps owned by tile p — the last tile may be ragged."""
        return min(self.steps - p * self.core, self.core)

    def windows(self):
        """Per-tile validity windows within the span: (lo, hi) int32 (P,)
        arrays.  Row r of tile p's span is global step
        ``p*core - overlap + r``; rows outside [0, T) are invalid."""
        p = np.arange(self.n_tiles)
        g0 = p * self.core - self.overlap  # global step of span row 0
        lo = np.maximum(0, -g0)
        hi = np.minimum(self.span, self.steps - g0)
        return lo.astype(np.int32), hi.astype(np.int32)

    def gather_index(self) -> np.ndarray:
        """(P, span) global step index feeding each span row, clipped to
        [0, T) — clipped rows are invalid per ``windows`` and pass through."""
        p = np.arange(self.n_tiles)[:, None]
        idx = p * self.core - self.overlap + np.arange(self.span)[None, :]
        return np.clip(idx, 0, self.steps - 1).astype(np.int32)


def plan_tiles(T: int, n_tiles: int, overlap: int = 0) -> TilePlan:
    """Normalize a requested tiling to a valid TilePlan.

    The core length is ceil(T / n_tiles); the effective tile count then
    shrinks to ceil(T / core), which absorbs every awkward request (more
    tiles than steps, T % P != 0, overlap longer than the sequence).
    """
    if T < 1:
        raise ValueError(f"need at least one trellis step, got T={T}")
    n_tiles = max(1, min(int(n_tiles), T))
    core = -(-T // n_tiles)
    n_eff = -(-T // core)
    overlap = max(0, min(int(overlap), T))
    return TilePlan(
        steps=T, n_tiles=n_eff, core=core, overlap=overlap, span=core + overlap
    )


def default_tiles(B: int, T: int, S: int, lane_budget: int = 512) -> int:
    """Default tile count for a (B, T, S) problem: the largest power of two
    that keeps every tile at least MIN_TILE_CORE steps and the widest folded
    launch (the B·P·S lanes of the transfer-map / traceback passes) within
    ``lane_budget`` lanes — past that the lane blocks serialize and the
    added tiles stop buying wall-clock."""
    P = 1
    while P * 2 <= T // MIN_TILE_CORE and B * (P * 2) * S <= lane_budget:
        P *= 2
    return P
