"""Shared helpers for the Pallas kernel entry points.

Lives below ops.py so the raw kernel modules (viterbi_scan, texpand, minplus,
survivors) can share interpret-mode auto-detection without importing ops
(which imports them).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

#: Survivor bits packed per word along the time axis (uint32 words).
PACK_BITS = 32


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Pallas interpret-mode policy: explicit override wins, otherwise run
    compiled on a real TPU and interpreted everywhere else (CPU containers,
    CI).  Public kernel entry points default to ``interpret=None`` so calling
    them directly on a TPU never silently runs interpret mode.

    Pinning rule: anything that composes MORE THAN ONE kernel — the decode
    entry points in ops.py (forward + traceback), stream sessions and the
    scheduler (per-tick forward, tail feeds, flush traceback) — resolves
    ``None`` exactly once, up front, and passes the concrete bool down.
    Per-kernel auto-detection inside a multi-kernel decode would read
    ``jax.default_backend()`` at each kernel's (independently cached) trace
    time, and a platform-context change between those traces silently splits
    one decode across the compiled and interpreted code paths."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def pad_axis_to(x: jnp.ndarray, axis: int, mult: int, value) -> Tuple[jnp.ndarray, int]:
    """Pad ``axis`` of ``x`` up to a multiple of ``mult`` with ``value``."""
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


def lane_block(batch: int, block_b: int = 128) -> int:
    """Lane-axis block size: full 128-lane tiles when the batch fills them,
    a small padded tile otherwise (ops.py pads the batch up to this)."""
    return block_b if batch >= block_b else max(8, batch)
