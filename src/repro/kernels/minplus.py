"""(min,+) semiring matrix multiply as a Pallas kernel, plus the state-map
algebra built on it.

Used by the block-parallel Viterbi decoder (chunk transfer-matrix products)
and the general HMM Viterbi: ``C[i,j] = min_k A[i,k] + B[k,j]``.

Tiled like a matmul: grid (batch, i-tile, j-tile, k-tile), k innermost, with a
float32 accumulator tile in VMEM scratch that is min-reduced across k-tiles.
The inner body broadcasts an (bi, bk, 1) tile against a (1, bk, bj) tile on
the VPU — the (min,+) semiring has no MXU path, so this is deliberately a
VPU kernel with MXU-friendly tile shapes (multiples of 8×128).

State-map algebra (the seam calculus shared by the sequence-parallel
collectives and the tiled time-parallel decoder): a span of trellis steps is
summarized by its (S, S) *state map* M[i, j] = best metric of any path that
enters the span in state i and leaves it in state j.  Maps compose in the
(min,+) semiring (``compose_maps``), ``identity_map`` is the semiring unit,
and ``prefix_maps`` left-folds a stack of per-tile maps into exclusive
prefixes — prefix p applied to the initial metric vector is *exactly* the
full-length forward path metrics at tile p's entry seam (for integer-valued
hard metrics, bit-exactly: the sums are small integers in float32).
``seam_argmin`` pins the tie-break: the lowest state index among minimizers
(jnp.argmin's first-occurrence rule — the same rule ops._frontier applies to
an open trellis frontier).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.trellis import NEG_UNREACHABLE
from repro.kernels.common import resolve_interpret


def _minplus_kernel(a_ref, b_ref, out_ref, acc_ref):
    k = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, NEG_UNREACHABLE)

    a = a_ref[0].astype(jnp.float32)  # (bi, bk)
    b = b_ref[0].astype(jnp.float32)  # (bk, bj)
    part = jnp.min(a[:, :, None] + b[None, :, :], axis=1)  # (bi, bj)
    acc_ref[...] = jnp.minimum(acc_ref[...], part)

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnums=(2, 3, 4, 5))
def minplus_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Batched (min,+) matmul.  a: (N, I, K), b: (N, K, J) -> (N, I, J).

    Dims must be multiples of the block sizes (ops.py pads with the
    semiring's +inf identity, which is correct for min-reduction).
    ``interpret=None`` auto-detects: compiled on TPU, interpreted elsewhere.
    """
    N, I, K = a.shape
    _, _, J = b.shape
    grid = (N, I // block_i, J // block_j, K // block_k)
    out = pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_i, block_k), lambda n, i, j, k: (n, i, k)),
            pl.BlockSpec((1, block_k, block_j), lambda n, i, j, k: (n, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_i, block_j), lambda n, i, j, k: (n, i, j)),
        out_shape=jax.ShapeDtypeStruct((N, I, J), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_i, block_j), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(a, b)
    return out


# --------------------------------------------------------------------------- #
# State-map algebra: compose / prefix-fold per-tile (S, S) transfer maps.     #
# --------------------------------------------------------------------------- #


def identity_map(n_states: int, batch_shape: tuple = ()) -> jnp.ndarray:
    """The (min,+) unit: 0 on the diagonal, +inf (NEG_UNREACHABLE) off it."""
    eye = jnp.where(jnp.eye(n_states, dtype=bool), 0.0, NEG_UNREACHABLE)
    return jnp.broadcast_to(eye, tuple(batch_shape) + (n_states, n_states))


def compose_maps(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Sequence a's span followed by b's: ``c[i,j] = min_k a[i,k] + b[k,j]``,
    clamped so stacked unreachable (BIG + BIG) entries stay at the semiring
    +inf.  a, b: (..., S, S) with matching batch dims."""
    c = jnp.min(a[..., :, :, None] + b[..., None, :, :], axis=-2)
    return jnp.minimum(c, NEG_UNREACHABLE)


def prefix_maps(mats: jnp.ndarray):
    """Exclusive (min,+) prefixes of a stack of per-tile state maps.

    mats: (P, ..., S, S), tile 0 first.  Returns ``(excl, total)`` where
    ``excl[p] = mats[0] ∘ ... ∘ mats[p-1]`` (the identity at p = 0) and
    ``total`` composes all P maps.  A left fold (lax.scan), matching the
    association order of the seqparallel decoder; since each compose does a
    single add then an exact min-reduction, the results are independent of
    reduction order — integer-metric maps compose bit-exactly.
    """
    S = mats.shape[-1]
    eye = identity_map(S, mats.shape[1:-2])

    def step(acc, m):
        return compose_maps(acc, m), acc  # emit the *exclusive* prefix

    total, excl = jax.lax.scan(step, eye, mats)
    return excl, total


def tile_entry_metrics(excl: jnp.ndarray, init_state: int = 0) -> jnp.ndarray:
    """Forward path metrics entering each tile, for paths that start the
    full sequence in ``init_state``: excl (P, ..., S, S) -> (P, ..., S).
    Row p equals the full-length forward pass's metric vector at tile p's
    entry seam."""
    return excl[..., init_state, :]


def seam_argmin(metrics: jnp.ndarray) -> jnp.ndarray:
    """Winning state on a seam metric vector (..., S) -> (...) int32.

    Tie-break rule (pinned, tested): among equal-metric minimizers the
    LOWEST state index wins — jnp.argmin's first-occurrence rule, identical
    to the open-trellis frontier rule in kernels/ops._frontier.
    """
    return jnp.argmin(metrics, axis=-1).astype(jnp.int32)
