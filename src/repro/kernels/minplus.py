"""(min,+) semiring matrix multiply as a Pallas kernel.

Used by the block-parallel Viterbi decoder (chunk transfer-matrix products)
and the general HMM Viterbi: ``C[i,j] = min_k A[i,k] + B[k,j]``.

Tiled like a matmul: grid (batch, i-tile, j-tile, k-tile), k innermost, with a
float32 accumulator tile in VMEM scratch that is min-reduced across k-tiles.
The inner body broadcasts an (bi, bk, 1) tile against a (1, bk, bj) tile on
the VPU — the (min,+) semiring has no MXU path, so this is deliberately a
VPU kernel with MXU-friendly tile shapes (multiples of 8×128).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.trellis import NEG_UNREACHABLE
from repro.kernels.common import resolve_interpret


def _minplus_kernel(a_ref, b_ref, out_ref, acc_ref):
    k = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, NEG_UNREACHABLE)

    a = a_ref[0].astype(jnp.float32)  # (bi, bk)
    b = b_ref[0].astype(jnp.float32)  # (bk, bj)
    part = jnp.min(a[:, :, None] + b[None, :, :], axis=1)  # (bi, bj)
    acc_ref[...] = jnp.minimum(acc_ref[...], part)

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnums=(2, 3, 4, 5))
def minplus_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Batched (min,+) matmul.  a: (N, I, K), b: (N, K, J) -> (N, I, J).

    Dims must be multiples of the block sizes (ops.py pads with the
    semiring's +inf identity, which is correct for min-reduction).
    ``interpret=None`` auto-detects: compiled on TPU, interpreted elsewhere.
    """
    N, I, K = a.shape
    _, _, J = b.shape
    grid = (N, I // block_i, J // block_j, K // block_k)
    out = pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_i, block_k), lambda n, i, j, k: (n, i, k)),
            pl.BlockSpec((1, block_k, block_j), lambda n, i, j, k: (n, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_i, block_j), lambda n, i, j, k: (n, i, j)),
        out_shape=jax.ShapeDtypeStruct((N, I, J), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_i, block_j), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(a, b)
    return out
