"""Bit-packed survivor memory: pack/unpack helpers + Pallas traceback kernel.

Hardware Viterbi decoders never store survivors one-per-word — the survivor
memory unit keeps one *bit* per (step, state) and the traceback unit walks it
in place (the register-exchange / traceback units of the WIMAX decoder
survey).  This module is that unit for the TPU pipeline:

  pack_survivors / unpack_survivors
      (T, ...) {0,1} backpointer parities <-> (ceil(T/32), ...) uint32 words,
      32 steps per word along time (bit p of word w = step 32*w + p; tail
      bits of a partial last word are zero).  Pure-jnp, layout-agnostic over
      the trailing axes — the oracle the kernel formats are tested against.

  traceback_packed
      Pallas kernel that walks the packed words directly: grid is
      (batch-tile, word) with the word axis time-reversed, the per-stream
      state rides a VMEM scratch row across grid steps, and each word's 32
      select bits are consumed by an in-register unrolled walk — the decoded
      (T, B) bits are the only tensor that ever reaches HBM.  Replaces the
      sequential XLA scan-of-gathers traceback for the fused decode path.

  traceback_packed_window
      The same walk restricted to a per-lane step window [lo, hi): outside
      it the state passes through unchanged and the emitted bit is 0.  Also
      returns the state each lane holds after the walk — the state at step
      ``lo``, i.e. the lane's *entry* state.  The tiled decoder uses this to
      run every tile's traceback (from every candidate exit state) in one
      launch and then resolve tile seams by chaining exit -> entry states —
      the exact survivor walk the sequential traceback would have done,
      including its tie-breaks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.trellis import ConvCode
from repro.kernels.common import PACK_BITS, resolve_interpret


def n_words(T: int) -> int:
    """Packed words needed for T trellis steps."""
    return -(-T // PACK_BITS)


def pack_survivors(bps: jnp.ndarray) -> jnp.ndarray:
    """Pack {0,1} survivor parities 32-per-uint32 along leading (time) axis.

    Args:
      bps: (T, ...) integer 0/1 backpointer parities (any trailing layout).
    Returns:
      (ceil(T/32), ...) uint32; bit p of word w is step ``32*w + p``.
    """
    T = bps.shape[0]
    W = n_words(T)
    pad = W * PACK_BITS - T
    b = bps.astype(jnp.uint32)
    if pad:
        b = jnp.pad(b, [(0, pad)] + [(0, 0)] * (b.ndim - 1))
    b = b.reshape((W, PACK_BITS) + bps.shape[1:])
    shifts = jnp.arange(PACK_BITS, dtype=jnp.uint32).reshape(
        (1, PACK_BITS) + (1,) * (bps.ndim - 1)
    )
    # disjoint bit positions -> sum == bitwise or
    return jnp.sum(b << shifts, axis=1, dtype=jnp.uint32)


def unpack_survivors(packed: jnp.ndarray, T: int) -> jnp.ndarray:
    """Inverse of :func:`pack_survivors`: (W, ...) uint32 -> (T, ...) int32."""
    shifts = jnp.arange(PACK_BITS, dtype=jnp.uint32).reshape(
        (1, PACK_BITS) + (1,) * (packed.ndim - 1)
    )
    bits = (packed[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape((packed.shape[0] * PACK_BITS,) + packed.shape[1:])[:T].astype(
        jnp.int32
    )


def _make_traceback_kernel(code: ConvCode, T: int):
    """Traceback over packed survivor words for one (code, T)."""
    K = code.constraint
    half = code.n_states // 2

    def kernel(packed_ref, fs_ref, out_ref, state_scratch):
        i = pl.program_id(1)
        W = pl.num_programs(1)

        @pl.when(i == 0)
        def _init():
            state_scratch[...] = fs_ref[...]

        w = W - 1 - i  # time-reversed word walk
        word = packed_ref[0]  # (S, bB) uint32
        state = state_scratch[...]  # (1, bB) int32
        rows = jax.lax.broadcasted_iota(jnp.int32, word.shape, 0)
        out_rows = []
        for p in range(PACK_BITS - 1, -1, -1):
            valid = w * PACK_BITS + p < T  # tail bits of a partial last word
            # per-lane select of bit p at each lane's current state: a
            # one-hot row mask + sum-reduce over states (no gathers)
            onehot = rows == state
            bit_p = ((word >> jnp.uint32(p)) & jnp.uint32(1)).astype(jnp.int32)
            j = jnp.sum(jnp.where(onehot, bit_p, 0), axis=0, keepdims=True)
            u = state >> (K - 2)  # input bit that produced this state
            v = state & (half - 1) if half > 1 else jnp.zeros_like(state)
            prev = 2 * v + j
            out_rows.append(jnp.where(valid, u, 0))
            state = jnp.where(valid, prev, state)
        state_scratch[...] = state
        out_ref[...] = jnp.concatenate(out_rows[::-1], axis=0)

    return kernel


def _make_traceback_window_kernel(code: ConvCode):
    """Traceback over packed words with a per-lane [lo, hi) walk window."""
    K = code.constraint
    half = code.n_states // 2

    def kernel(packed_ref, fs_ref, lo_ref, hi_ref, out_ref, entry_ref, state_scratch):
        i = pl.program_id(1)
        W = pl.num_programs(1)

        @pl.when(i == 0)
        def _init():
            state_scratch[...] = fs_ref[...]

        w = W - 1 - i  # time-reversed word walk
        word = packed_ref[0]  # (S, bB) uint32
        state = state_scratch[...]  # (1, bB) int32
        lo = lo_ref[...]  # (1, bB) int32
        hi = hi_ref[...]  # (1, bB) int32
        rows = jax.lax.broadcasted_iota(jnp.int32, word.shape, 0)
        out_rows = []
        for p in range(PACK_BITS - 1, -1, -1):
            t = w * PACK_BITS + p
            # per-lane window (vs the static tail guard of the full-T
            # kernel): a lane's walk only consumes steps lo <= t < hi
            valid = (t >= lo) & (t < hi)
            onehot = rows == state
            bit_p = ((word >> jnp.uint32(p)) & jnp.uint32(1)).astype(jnp.int32)
            j = jnp.sum(jnp.where(onehot, bit_p, 0), axis=0, keepdims=True)
            u = state >> (K - 2)  # input bit that produced this state
            v = state & (half - 1) if half > 1 else jnp.zeros_like(state)
            prev = 2 * v + j
            out_rows.append(jnp.where(valid, u, 0))
            state = jnp.where(valid, prev, state)
        state_scratch[...] = state
        # VMEM-resident out tile: the value of the *last* grid visit — the
        # state after the whole walk, i.e. the state at step lo — lands in HBM
        entry_ref[...] = state
        out_ref[...] = jnp.concatenate(out_rows[::-1], axis=0)

    return kernel


@functools.partial(jax.jit, static_argnums=(0, 5, 6))
def traceback_packed_window(
    code: ConvCode,
    packed: jnp.ndarray,
    final_state: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Windowed traceback: walk packed survivors through per-lane [lo, hi).

    Args:
      packed: (W, S, B) uint32 survivor words (kernel layout).
      final_state: (1, B) int32 state each lane starts walking from (its
        state at step ``hi``).
      lo, hi: (1, B) int32 per-lane walk windows; steps outside emit bit 0
        and leave the state untouched.
    Returns:
      bits: (32*W, B) int32 decoded bits (0 outside the window).
      entry_state: (1, B) int32 the state each lane reached at step ``lo`` —
      for a time tile, the state on the seam with the previous tile.
    """
    W, S, B = packed.shape
    grid = (B // block_b, W)
    bits, entry = pl.pallas_call(
        _make_traceback_window_kernel(code),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, block_b), lambda b, i: (W - 1 - i, 0, b)),
            pl.BlockSpec((1, block_b), lambda b, i: (0, b)),
            pl.BlockSpec((1, block_b), lambda b, i: (0, b)),
            pl.BlockSpec((1, block_b), lambda b, i: (0, b)),
        ],
        out_specs=[
            pl.BlockSpec((PACK_BITS, block_b), lambda b, i: (W - 1 - i, b)),
            pl.BlockSpec((1, block_b), lambda b, i: (0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((W * PACK_BITS, B), jnp.int32),
            jax.ShapeDtypeStruct((1, B), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_b), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(packed, final_state.astype(jnp.int32), lo.astype(jnp.int32), hi.astype(jnp.int32))
    return bits, entry


@functools.partial(jax.jit, static_argnums=(0, 3, 4, 5))
def traceback_packed(
    code: ConvCode,
    packed: jnp.ndarray,
    final_state: jnp.ndarray,
    T: int,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Trace back through packed survivors entirely on-device.

    Args:
      packed: (W, S, B) uint32 survivor words (kernel layout), W = ceil(T/32).
      final_state: (1, B) int32 state to start the walk from.
      T: trellis steps actually encoded (T <= 32*W; tail bits ignored).
    Returns:
      bits: (32*W, B) int32 decoded input bits; rows >= T are zero padding —
      callers slice ``[:T]``.
    """
    W, S, B = packed.shape
    grid = (B // block_b, W)
    bits = pl.pallas_call(
        _make_traceback_kernel(code, T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, block_b), lambda b, i: (W - 1 - i, 0, b)),
            pl.BlockSpec((1, block_b), lambda b, i: (0, b)),
        ],
        out_specs=pl.BlockSpec((PACK_BITS, block_b), lambda b, i: (W - 1 - i, b)),
        out_shape=jax.ShapeDtypeStruct((W * PACK_BITS, B), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, block_b), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(packed, final_state)
    return bits
