"""Jit'd public wrappers around the Pallas kernels.

Handle layout conversion ((B, S) user layout <-> (S, B) kernel layout),
lane/sublane padding, interpret-mode selection (CPU container -> interpret;
real TPU -> compiled), and compose the full fused decoders:

  classic      viterbi_decode_fused: bm tables in, unpacked (T, S, B) int32
               survivors out, XLA scan-of-gathers traceback.
  packed       viterbi_decode_packed: bm tables in, 32×-smaller packed
               survivors out, Pallas traceback kernel — the survivors never
               exist unpacked in HBM.
  fused+packed viterbi_decode_fused_packed: raw received symbols in, branch
               metrics computed in-kernel (kernels/metrics.py), packed
               survivors, Pallas traceback — the full memory-lean hot path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.trellis import NEG_UNREACHABLE, ConvCode
from repro.core.viterbi import _traceback
from repro.kernels import bcjr as _bcjr
from repro.kernels import minplus as _minplus
from repro.kernels import survivors as _surv
from repro.kernels import texpand as _texpand
from repro.kernels import viterbi_scan as _vscan
from repro.kernels.common import lane_block, pad_axis_to, resolve_interpret
from repro.kernels.metrics import FusedMetricPlan


def texpand_op(
    code: ConvCode,
    pm: jnp.ndarray,
    bm_table: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ACS step in user layout.  pm: (B, S); bm_table: (B, M)."""
    B = pm.shape[0]
    pm_k = pm.T  # (S, B)
    bm_k = bm_table.T  # (M, B)
    block_b = lane_block(B)
    pm_k, _ = pad_axis_to(pm_k, 1, block_b, NEG_UNREACHABLE)
    bm_k, _ = pad_axis_to(bm_k, 1, block_b, 0.0)
    new_pm, bp = _texpand.texpand(
        code, pm_k.astype(jnp.float32), bm_k.astype(jnp.float32), block_b, interpret
    )
    return new_pm[:, :B].T, bp[:, :B].T


def viterbi_forward_op(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full fused forward pass.  bm_tables: (B, T, M).

    Returns final_pm (B, S) and backpointers (T, B, S) (traceback layout).
    """
    B, T, M = bm_tables.shape
    bm_k = bm_tables.transpose(1, 2, 0)  # (T, M, B)
    block_b = lane_block(B)
    bm_k, _ = pad_axis_to(bm_k, 2, block_b, 0.0)
    final_pm, bps = _vscan.viterbi_scan(
        code, bm_k.astype(jnp.float32), block_b, interpret
    )
    return final_pm[:, :B].T, bps[:, :, :B].transpose(0, 2, 1)


def viterbi_forward_chunk_op(
    code: ConvCode,
    pm: jnp.ndarray,
    bm_chunk: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked fused forward pass with carried path metrics — the streaming
    entry point.  The caller owns the cross-chunk state (path metrics and a
    traceback ring buffer, see stream/session.py); this op advances the path
    metrics C steps through the VMEM-resident Pallas scan.

    Args:
      pm: (B, S) float32 path metrics entering the chunk.
      bm_chunk: (B, C, M) branch-metric tables for the chunk.
    Returns:
      new_pm: (B, S) path metrics after the chunk.
      bps: (C, B, S) int32 backpointer parities (traceback layout).
    """
    B, C, M = bm_chunk.shape
    pm_k = pm.T  # (S, B)
    bm_k = bm_chunk.transpose(1, 2, 0)  # (C, M, B)
    block_b = lane_block(B)
    pm_k, _ = pad_axis_to(pm_k, 1, block_b, NEG_UNREACHABLE)
    bm_k, _ = pad_axis_to(bm_k, 2, block_b, 0.0)
    new_pm, bps = _vscan.viterbi_scan_carry(
        code, pm_k.astype(jnp.float32), bm_k.astype(jnp.float32), block_b, interpret
    )
    return new_pm[:, :B].T, bps[:, :, :B].transpose(0, 2, 1)


# --------------------------------------------------------------------------- #
# Packed-survivor pipeline: forward (+ optional in-kernel metrics), traceback. #
# --------------------------------------------------------------------------- #


def viterbi_forward_weighted_op(
    code: ConvCode,
    pm0: Optional[jnp.ndarray],
    data_btf: jnp.ndarray,
    weights: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Generic packed forward: any (b0, b1, rb) metric weights, optional
    carried pm0 (None -> state-0 init).  data_btf: (B, T, F) user layout ->
    final_pm (B, S), packed (W, B, S) traceback layout.  The streaming
    subsystem calls this directly with its per-session weights."""
    B, T, F = data_btf.shape
    b0, b1, rb = weights
    data = data_btf.transpose(1, 2, 0).astype(jnp.float32)  # (T, F, B)
    block_b = lane_block(B)
    data, _ = pad_axis_to(data, 2, block_b, 0.0)
    if pm0 is None:
        final_pm, packed = _vscan.viterbi_scan_packed(
            code, data, b0, b1, rb, block_b, interpret
        )
    else:
        pm_k, _ = pad_axis_to(pm0.T, 1, block_b, NEG_UNREACHABLE)
        final_pm, packed = _vscan.viterbi_scan_packed_carry(
            code, pm_k.astype(jnp.float32), data, b0, b1, rb, block_b, interpret
        )
    return final_pm[:, :B].T, packed[:, :, :B].transpose(0, 2, 1)


def viterbi_forward_packed_op(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Forward pass with bit-packed survivors from precomputed bm tables.

    bm_tables: (B, T, M) -> final_pm (B, S), packed (ceil(T/32), B, S) uint32
    — the survivor tensor is 32× smaller than viterbi_forward_op's.
    """
    return viterbi_forward_weighted_op(
        code, None, bm_tables, _vscan.table_weights(code), interpret
    )


def viterbi_forward_fused_op(
    plan: FusedMetricPlan,
    received: jnp.ndarray,
    t0: int = 0,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Forward pass with **in-kernel branch metrics** + packed survivors.

    received: (B, T, n_out) raw channel symbols (hard bits or soft values);
    the kernel streams these F-wide features instead of an M-wide bm table.
    Returns final_pm (B, S), packed (ceil(T/32), B, S) uint32.
    """
    feats = plan.features(received, t0)
    return viterbi_forward_weighted_op(plan.code, None, feats, plan.folded(), interpret)


def viterbi_traceback_op(
    code: ConvCode,
    packed: jnp.ndarray,
    final_state: jnp.ndarray,
    T: int,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """On-device traceback over packed survivors.

    packed: (W, B, S) uint32 (traceback layout); final_state: (B,) int32.
    Returns bits (B, T) — the survivors are never unpacked in HBM.
    """
    W, B, S = packed.shape
    pk = packed.transpose(0, 2, 1)  # (W, S, B)
    block_b = lane_block(B)
    pk, _ = pad_axis_to(pk, 2, block_b, 0)
    fs, _ = pad_axis_to(final_state.reshape(1, B).astype(jnp.int32), 1, block_b, 0)
    bits = _surv.traceback_packed(code, pk, fs, T, block_b, interpret)
    return bits[:T, :B].T


def _frontier(
    final_pm: jnp.ndarray, terminated: bool
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Traceback start state + winning metric from (B, S) frontier metrics."""
    if terminated:
        final_state = jnp.zeros(final_pm.shape[:1], dtype=jnp.int32)
        metric = final_pm[:, 0]
    else:
        final_state = jnp.argmin(final_pm, axis=-1).astype(jnp.int32)
        metric = final_pm.min(axis=-1)
    return final_state, metric


def viterbi_decode_fused(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    terminated: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in fused replacement for core.viterbi.viterbi_decode.

    bm_tables: (B, T, M) -> (bits (B, T), metric (B,)).
    """
    interpret = resolve_interpret(interpret)  # pinned per decode
    final_pm, bps = viterbi_forward_op(code, bm_tables, interpret)
    final_state, metric = _frontier(final_pm, terminated)
    bits, _ = _traceback(code, bps, final_state)
    return bits, metric


def viterbi_decode_packed(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    terminated: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused decode with packed survivors + on-device traceback (bm tables
    in).  Bit-exact vs viterbi_decode_fused; survivor HBM footprint is 32×
    smaller and the traceback never leaves the device."""
    T = bm_tables.shape[1]
    # resolve interpret ONCE so the forward scan and the traceback kernel of
    # this decode can never auto-detect onto different code paths
    interpret = resolve_interpret(interpret)
    final_pm, packed = viterbi_forward_packed_op(code, bm_tables, interpret)
    final_state, metric = _frontier(final_pm, terminated)
    bits = viterbi_traceback_op(code, packed, final_state, T, interpret)
    return bits, metric


def viterbi_decode_fused_packed(
    plan: FusedMetricPlan,
    received: jnp.ndarray,
    terminated: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The full memory-lean hot path: raw received symbols in, branch
    metrics computed in-kernel, bit-packed survivors, Pallas traceback.

    received: (B, T, n_out) -> (bits (B, T), metric (B,)).
    """
    T = received.shape[1]
    interpret = resolve_interpret(interpret)  # pinned per decode
    final_pm, packed = viterbi_forward_fused_op(plan, received, 0, interpret)
    final_state, metric = _frontier(final_pm, terminated)
    bits = viterbi_traceback_op(plan.code, packed, final_state, T, interpret)
    return bits, metric


def bcjr_llr_op(
    code,
    llr_coded: jnp.ndarray,
    llr_apriori: Optional[jnp.ndarray] = None,
    terminated: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Max-log-MAP SISO decode of one RSC code block (kernels/bcjr.py).

    The SISO analogue of viterbi_decode_fused: forward (alpha) scan with
    VMEM-resident metrics, then a time-reversed backward scan that fuses the
    beta recursion with per-step LLR extraction.

    Args:
      code: an RSCCode (duck-typed — kernels/ never imports siso/).
      llr_coded: (B, T, n_out) per-coded-bit channel LLRs, convention
        ``lambda = log P(0)/P(1)`` (punctured positions = 0).
      llr_apriori: (B, T) a-priori LLRs on the info bits (None -> zeros).
      terminated: trellis flushed to state 0 (beta seeded there) vs open.
    Returns:
      llr: (B, T) float32 a-posteriori LLRs (negative -> decide bit 1).
      metric: (B,) float32 best-path terminal cost (renormalized per step,
        so meaningful relative to other streams of the same T, not absolute).
    """
    B, T, n = llr_coded.shape
    if llr_apriori is None:
        llr_apriori = jnp.zeros((B, T), jnp.float32)
    feat = jnp.concatenate(
        [llr_coded.astype(jnp.float32), llr_apriori[..., None].astype(jnp.float32)],
        axis=-1,
    )
    feat = feat.transpose(1, 2, 0)  # (T, F, B)
    block_b = lane_block(B)
    feat, _ = pad_axis_to(feat, 2, block_b, 0.0)
    interpret = resolve_interpret(interpret)  # pinned once for both kernels
    P0, P1 = code.select_matrices
    b0, b1 = code.alpha_weights
    alphas, final_pm = _bcjr.bcjr_alpha_scan(
        tuple(jnp.asarray(m) for m in (P0, P1, b0, b1)), feat, block_b, interpret
    )
    N0, N1 = code.beta_matrices
    U0, U1 = code.llr_matrices
    c0, c1 = code.beta_weights
    w0, w1 = code.llr_weights
    llr = _bcjr.bcjr_beta_llr_scan(
        tuple(jnp.asarray(m) for m in (N0, N1, U0, U1, c0, c1, w0, w1)),
        alphas, feat, terminated, block_b, interpret,
    )
    metric = final_pm[0, :B] if terminated else final_pm[:, :B].min(axis=0)
    return llr[:, :B].T, metric


def minplus_matmul_op(
    a: jnp.ndarray, b: jnp.ndarray, interpret: Optional[bool] = None
) -> jnp.ndarray:
    """Batched (min,+) matmul with padding.  a: (..., I, K), b: (..., K, J)."""
    batch_shape = a.shape[:-2]
    I, K = a.shape[-2:]
    J = b.shape[-1]
    a2 = a.reshape((-1, I, K))
    b2 = b.reshape((-1, K, J))
    bi = min(128, max(8, I))
    bj = lane_block(J)
    bk = min(128, max(8, K))
    a2, _ = pad_axis_to(a2, 1, bi, NEG_UNREACHABLE)
    a2, _ = pad_axis_to(a2, 2, bk, NEG_UNREACHABLE)
    b2, _ = pad_axis_to(b2, 1, bk, NEG_UNREACHABLE)
    b2, _ = pad_axis_to(b2, 2, bj, NEG_UNREACHABLE)
    out = _minplus.minplus_matmul(
        a2.astype(jnp.float32), b2.astype(jnp.float32), bi, bj, bk, interpret
    )
    out = jnp.minimum(out, NEG_UNREACHABLE)  # padded lanes produced 2*BIG
    return out[:, :I, :J].reshape(batch_shape + (I, J))
