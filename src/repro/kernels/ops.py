"""Jit'd public wrappers around the Pallas kernels.

Handle layout conversion ((B, S) user layout <-> (S, B) kernel layout),
lane/sublane padding, interpret-mode selection (CPU container -> interpret;
real TPU -> compiled), and compose the full fused decoders:

  classic      viterbi_decode_fused: bm tables in, unpacked (T, S, B) int32
               survivors out, XLA scan-of-gathers traceback.
  packed       viterbi_decode_packed: bm tables in, 32×-smaller packed
               survivors out, Pallas traceback kernel — the survivors never
               exist unpacked in HBM.
  fused+packed viterbi_decode_fused_packed: raw received symbols in, branch
               metrics computed in-kernel (kernels/metrics.py), packed
               survivors, Pallas traceback — the full memory-lean hot path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.trellis import NEG_UNREACHABLE, ConvCode
from repro.core.viterbi import _traceback
from repro.kernels import bcjr as _bcjr
from repro.kernels import minplus as _minplus
from repro.kernels import survivors as _surv
from repro.kernels import texpand as _texpand
from repro.kernels import tiling as _tiling
from repro.kernels import viterbi_scan as _vscan
from repro.kernels.common import lane_block, pad_axis_to, resolve_interpret
from repro.kernels.metrics import FusedMetricPlan


def texpand_op(
    code: ConvCode,
    pm: jnp.ndarray,
    bm_table: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ACS step in user layout.  pm: (B, S); bm_table: (B, M)."""
    B = pm.shape[0]
    pm_k = pm.T  # (S, B)
    bm_k = bm_table.T  # (M, B)
    block_b = lane_block(B)
    pm_k, _ = pad_axis_to(pm_k, 1, block_b, NEG_UNREACHABLE)
    bm_k, _ = pad_axis_to(bm_k, 1, block_b, 0.0)
    new_pm, bp = _texpand.texpand(
        code, pm_k.astype(jnp.float32), bm_k.astype(jnp.float32), block_b, interpret
    )
    return new_pm[:, :B].T, bp[:, :B].T


def viterbi_forward_op(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full fused forward pass.  bm_tables: (B, T, M).

    Returns final_pm (B, S) and backpointers (T, B, S) (traceback layout).
    """
    B, T, M = bm_tables.shape
    bm_k = bm_tables.transpose(1, 2, 0)  # (T, M, B)
    block_b = lane_block(B)
    bm_k, _ = pad_axis_to(bm_k, 2, block_b, 0.0)
    final_pm, bps = _vscan.viterbi_scan(
        code, bm_k.astype(jnp.float32), block_b, interpret
    )
    return final_pm[:, :B].T, bps[:, :, :B].transpose(0, 2, 1)


def viterbi_forward_chunk_op(
    code: ConvCode,
    pm: jnp.ndarray,
    bm_chunk: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked fused forward pass with carried path metrics — the streaming
    entry point.  The caller owns the cross-chunk state (path metrics and a
    traceback ring buffer, see stream/session.py); this op advances the path
    metrics C steps through the VMEM-resident Pallas scan.

    Args:
      pm: (B, S) float32 path metrics entering the chunk.
      bm_chunk: (B, C, M) branch-metric tables for the chunk.
    Returns:
      new_pm: (B, S) path metrics after the chunk.
      bps: (C, B, S) int32 backpointer parities (traceback layout).
    """
    B, C, M = bm_chunk.shape
    pm_k = pm.T  # (S, B)
    bm_k = bm_chunk.transpose(1, 2, 0)  # (C, M, B)
    block_b = lane_block(B)
    pm_k, _ = pad_axis_to(pm_k, 1, block_b, NEG_UNREACHABLE)
    bm_k, _ = pad_axis_to(bm_k, 2, block_b, 0.0)
    new_pm, bps = _vscan.viterbi_scan_carry(
        code, pm_k.astype(jnp.float32), bm_k.astype(jnp.float32), block_b, interpret
    )
    return new_pm[:, :B].T, bps[:, :, :B].transpose(0, 2, 1)


# --------------------------------------------------------------------------- #
# Packed-survivor pipeline: forward (+ optional in-kernel metrics), traceback. #
# --------------------------------------------------------------------------- #


def viterbi_forward_weighted_op(
    code: ConvCode,
    pm0: Optional[jnp.ndarray],
    data_btf: jnp.ndarray,
    weights: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Generic packed forward: any (b0, b1, rb) metric weights, optional
    carried pm0 (None -> state-0 init).  data_btf: (B, T, F) user layout ->
    final_pm (B, S), packed (W, B, S) traceback layout.  The streaming
    subsystem calls this directly with its per-session weights."""
    B, T, F = data_btf.shape
    b0, b1, rb = weights
    data = data_btf.transpose(1, 2, 0).astype(jnp.float32)  # (T, F, B)
    block_b = lane_block(B)
    data, _ = pad_axis_to(data, 2, block_b, 0.0)
    if pm0 is None:
        final_pm, packed = _vscan.viterbi_scan_packed(
            code, data, b0, b1, rb, block_b, interpret
        )
    else:
        pm_k, _ = pad_axis_to(pm0.T, 1, block_b, NEG_UNREACHABLE)
        final_pm, packed = _vscan.viterbi_scan_packed_carry(
            code, pm_k.astype(jnp.float32), data, b0, b1, rb, block_b, interpret
        )
    return final_pm[:, :B].T, packed[:, :, :B].transpose(0, 2, 1)


def viterbi_forward_packed_op(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Forward pass with bit-packed survivors from precomputed bm tables.

    bm_tables: (B, T, M) -> final_pm (B, S), packed (ceil(T/32), B, S) uint32
    — the survivor tensor is 32× smaller than viterbi_forward_op's.
    """
    return viterbi_forward_weighted_op(
        code, None, bm_tables, _vscan.table_weights(code), interpret
    )


def viterbi_forward_fused_op(
    plan: FusedMetricPlan,
    received: jnp.ndarray,
    t0: int = 0,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Forward pass with **in-kernel branch metrics** + packed survivors.

    received: (B, T, n_out) raw channel symbols (hard bits or soft values);
    the kernel streams these F-wide features instead of an M-wide bm table.
    Returns final_pm (B, S), packed (ceil(T/32), B, S) uint32.
    """
    feats = plan.features(received, t0)
    return viterbi_forward_weighted_op(plan.code, None, feats, plan.folded(), interpret)


def viterbi_traceback_op(
    code: ConvCode,
    packed: jnp.ndarray,
    final_state: jnp.ndarray,
    T: int,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """On-device traceback over packed survivors.

    packed: (W, B, S) uint32 (traceback layout); final_state: (B,) int32.
    Returns bits (B, T) — the survivors are never unpacked in HBM.
    """
    W, B, S = packed.shape
    pk = packed.transpose(0, 2, 1)  # (W, S, B)
    block_b = lane_block(B)
    pk, _ = pad_axis_to(pk, 2, block_b, 0)
    fs, _ = pad_axis_to(final_state.reshape(1, B).astype(jnp.int32), 1, block_b, 0)
    bits = _surv.traceback_packed(code, pk, fs, T, block_b, interpret)
    return bits[:T, :B].T


def _frontier(
    final_pm: jnp.ndarray, terminated: bool
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Traceback start state + winning metric from (B, S) frontier metrics."""
    if terminated:
        final_state = jnp.zeros(final_pm.shape[:1], dtype=jnp.int32)
        metric = final_pm[:, 0]
    else:
        final_state = jnp.argmin(final_pm, axis=-1).astype(jnp.int32)
        metric = final_pm.min(axis=-1)
    return final_state, metric


def viterbi_decode_fused(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    terminated: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in fused replacement for core.viterbi.viterbi_decode.

    bm_tables: (B, T, M) -> (bits (B, T), metric (B,)).
    """
    interpret = resolve_interpret(interpret)  # pinned per decode
    final_pm, bps = viterbi_forward_op(code, bm_tables, interpret)
    final_state, metric = _frontier(final_pm, terminated)
    bits, _ = _traceback(code, bps, final_state)
    return bits, metric


def viterbi_decode_packed(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    terminated: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused decode with packed survivors + on-device traceback (bm tables
    in).  Bit-exact vs viterbi_decode_fused; survivor HBM footprint is 32×
    smaller and the traceback never leaves the device."""
    T = bm_tables.shape[1]
    # resolve interpret ONCE so the forward scan and the traceback kernel of
    # this decode can never auto-detect onto different code paths
    interpret = resolve_interpret(interpret)
    final_pm, packed = viterbi_forward_packed_op(code, bm_tables, interpret)
    final_state, metric = _frontier(final_pm, terminated)
    bits = viterbi_traceback_op(code, packed, final_state, T, interpret)
    return bits, metric


def viterbi_decode_fused_packed(
    plan: FusedMetricPlan,
    received: jnp.ndarray,
    terminated: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The full memory-lean hot path: raw received symbols in, branch
    metrics computed in-kernel, bit-packed survivors, Pallas traceback.

    received: (B, T, n_out) -> (bits (B, T), metric (B,)).
    """
    T = received.shape[1]
    interpret = resolve_interpret(interpret)  # pinned per decode
    final_pm, packed = viterbi_forward_fused_op(plan, received, 0, interpret)
    final_state, metric = _frontier(final_pm, terminated)
    bits = viterbi_traceback_op(plan.code, packed, final_state, T, interpret)
    return bits, metric


# --------------------------------------------------------------------------- #
# Time-parallel tiled decode: P tiles of one long block ride the lane axis.   #
# --------------------------------------------------------------------------- #


def _tile_lane_row(per_tile: np.ndarray, B: int, S: int = 1) -> jnp.ndarray:
    """Per-tile (P,) int vector -> per-lane (1, B*P*S) row in the canonical
    lane order (b outer, p middle, s inner)."""
    # host-side plan construction on a plain numpy vector, not a device sync
    v = np.tile(np.asarray(per_tile, np.int32), B)  # repr-lint: allow[RPR003]
    if S > 1:
        v = np.repeat(v, S)
    return jnp.asarray(v.reshape(1, -1))


def _tiled_weighted_decode(
    code: ConvCode,
    data_btf: jnp.ndarray,
    weights: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    n_tiles: int,
    overlap: Optional[int],
    terminated: bool,
    interpret: Optional[bool],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Shared tiled-decode core (see viterbi_decode_tiled_op for the
    contract).  data_btf: (B, T, F) user layout + (b0, b1, rb) weights."""
    B, T, F = data_btf.shape
    S = code.n_states
    interpret = resolve_interpret(interpret)  # pinned across all launches
    # any overlap covering the truncation depth is promoted to the exact
    # two-pass seam resolution: strictly better and guaranteed bit-exact
    exact = overlap is None or int(overlap) >= _tiling.truncation_depth(code)
    tp = _tiling.plan_tiles(T, n_tiles, 0 if exact else int(overlap))
    P, V = tp.n_tiles, tp.span
    if P == 1:
        # degenerate tiling: the plain packed pipeline IS the exact decode
        final_pm, packed = viterbi_forward_weighted_op(
            code, None, data_btf, weights, interpret
        )
        final_state, metric = _frontier(final_pm, terminated)
        bits = viterbi_traceback_op(code, packed, final_state, T, interpret)
        return bits, metric

    b0, b1, rb = weights
    lo_np, hi_np = tp.windows()
    data = data_btf.transpose(1, 2, 0).astype(jnp.float32)  # (T, F, B)
    # (V, F, B*P): every tile's span gathered onto the lane axis
    tiles = data[jnp.asarray(tp.gather_index())].transpose(1, 2, 3, 0)
    tiles = tiles.reshape(V, F, B * P)
    eye = jnp.where(jnp.arange(S)[:, None] == jnp.arange(S)[None, :],
                    0.0, NEG_UNREACHABLE)

    if exact:
        # pass 1 — per-tile (S, S) transfer maps: the S unit-entry-state
        # problems of every tile also ride the lane axis (lanes (b, p, j)),
        # so the map build costs one span-deep launch, not S of them
        lanes1 = B * P * S
        blk1 = lane_block(lanes1)
        t1, _ = pad_axis_to(jnp.repeat(tiles, S, axis=2), 2, blk1, 0.0)
        p1, _ = pad_axis_to(jnp.tile(eye, (1, B * P)), 1, blk1, NEG_UNREACHABLE)
        l1, _ = pad_axis_to(_tile_lane_row(lo_np, B, S), 1, blk1, 0)
        h1, _ = pad_axis_to(_tile_lane_row(hi_np, B, S), 1, blk1, 0)
        fpm1, _ = _vscan.viterbi_scan_packed_window(
            code, p1, t1, b0, b1, rb, l1, h1, blk1, interpret
        )
        # map[b, p, i, j] = best metric entering tile p in state i, leaving j
        maps = fpm1[:, :lanes1].reshape(S, B, P, S).transpose(2, 1, 3, 0)
        excl, total = _minplus.prefix_maps(maps)
        entry = _minplus.tile_entry_metrics(excl)  # (P, B, S): exact seam pms
        final_pm = total[:, 0, :]  # (B, S) full-sequence metrics from state 0
        final_state, metric = _frontier(final_pm, terminated)
        pm0 = entry.transpose(2, 1, 0).reshape(S, B * P)  # lanes (b, p)
    else:
        # truncated warm-up: tile 0 enters in state 0, later tiles enter
        # "cold" (uniform 0) and converge over the overlap steps
        is_first = jnp.asarray((np.arange(B * P) % P) == 0)[None, :]
        pm0 = jnp.where(is_first, eye[:, :1], 0.0)  # (S, B*P)

    # forward over all tiles at once — survivors for V steps per tile
    lanes2 = B * P
    blk2 = lane_block(lanes2)
    t2, _ = pad_axis_to(tiles, 2, blk2, 0.0)
    p2, _ = pad_axis_to(pm0, 1, blk2, NEG_UNREACHABLE)
    l2, _ = pad_axis_to(_tile_lane_row(lo_np, B), 1, blk2, 0)
    h2, _ = pad_axis_to(_tile_lane_row(hi_np, B), 1, blk2, 0)
    fpm2, packed2 = _vscan.viterbi_scan_packed_window(
        code, p2, t2, b0, b1, rb, l2, h2, blk2, interpret
    )
    packed2 = packed2[:, :, :lanes2]  # (ceil(V/32), S, B*P)
    if not exact:
        # approximate frontier: the last tile's span covers the block end;
        # its metric is relative (warm-up re-zeroed the earlier history)
        last_pm = fpm2[:, :lanes2].reshape(S, B, P)[:, :, -1].T  # (B, S)
        final_state, metric = _frontier(last_pm, terminated)

    # traceback — every tile from EVERY candidate exit state in one launch
    # (lanes (b, p, s)); each lane also reports the state it entered on, so
    # seam states resolve by chaining exit -> entry from the final frontier:
    # exactly the walk the sequential traceback would have done, tie-breaks
    # included
    lanesT = B * P * S
    blkT = lane_block(lanesT)
    pkT, _ = pad_axis_to(jnp.repeat(packed2, S, axis=2), 2, blkT, 0)
    stT, _ = pad_axis_to(_tile_lane_row(np.arange(S), B * P), 1, blkT, 0)
    ov = tp.overlap
    ltT, _ = pad_axis_to(jnp.full((1, lanesT), ov, jnp.int32), 1, blkT, 0)
    htT, _ = pad_axis_to(_tile_lane_row(hi_np, B, S), 1, blkT, 0)
    bits_all, ent = _surv.traceback_packed_window(
        code, pkT, stT, ltT, htT, blkT, interpret
    )
    bits_r = bits_all[:V, :lanesT].reshape(V, B, P, S)
    ent = ent[0, :lanesT].reshape(B, P, S)

    # stitch: walk the seam chain backwards, keep each tile's core bits
    state = final_state  # (B,) exit state of the last tile
    pieces = []
    for p in range(P - 1, -1, -1):
        sel = bits_r[:, :, p, :]  # (V, B, S) bits per candidate exit state
        piece = jnp.take_along_axis(sel, state[None, :, None], axis=2)[..., 0]
        pieces.append(piece[ov:int(hi_np[p])].T)  # (B, tile_length(p))
        state = jnp.take_along_axis(ent[:, p, :], state[:, None], axis=1)[:, 0]
    bits = jnp.concatenate(pieces[::-1], axis=1)  # (B, T)
    return bits, metric


def viterbi_decode_tiled_op(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    n_tiles: int,
    overlap: Optional[int] = None,
    terminated: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Time-parallel tiled decode: split T into ``n_tiles`` tiles that all
    run through the packed Pallas scan in one launch, resolve the tile seams,
    and trace every tile back in parallel — O(T/P + seam work) wall-clock.

    ``overlap`` picks the seam regime (kernels/tiling.py): ``None`` or any
    value >= the truncation depth 5·K -> **exact** two-pass mode — per-tile
    (S, S) transfer maps composed with the min-plus algebra of
    kernels/minplus.py seed each tile's re-scan with the *exact* full-length
    forward metrics, so survivors, bits, and metric are bit-exact vs
    viterbi_decode_packed for integer-valued (hard) branch metrics (soft
    metrics agree to float32 rounding, exactly the kernels/metrics.py
    contract).  ``0 <= overlap < 5·K`` -> single-pass truncated warm-up:
    each tile re-converges from a cold metric vector over ``overlap`` extra
    steps — approximate, with BER drift bounded by the usual truncated
    -traceback argument (tests/test_tiled.py pins a seeded bound).

    bm_tables: (B, T, M) -> (bits (B, T), metric (B,)).
    """
    return _tiled_weighted_decode(
        code, bm_tables, _vscan.table_weights(code), n_tiles, overlap,
        terminated, interpret,
    )


def viterbi_decode_tiled_fused(
    plan: FusedMetricPlan,
    received: jnp.ndarray,
    n_tiles: int,
    overlap: Optional[int] = None,
    terminated: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`viterbi_decode_tiled_op` fed raw received symbols — branch
    metrics are computed in-kernel per tile (kernels/metrics.py), so the
    (B, T, M) table never exists.  received: (B, T, n_out)."""
    feats = plan.features(received, 0)
    return _tiled_weighted_decode(
        plan.code, feats, plan.folded(), n_tiles, overlap, terminated, interpret
    )


def bcjr_llr_op(
    code,
    llr_coded: jnp.ndarray,
    llr_apriori: Optional[jnp.ndarray] = None,
    terminated: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Max-log-MAP SISO decode of one RSC code block (kernels/bcjr.py).

    The SISO analogue of viterbi_decode_fused: forward (alpha) scan with
    VMEM-resident metrics, then a time-reversed backward scan that fuses the
    beta recursion with per-step LLR extraction.

    Args:
      code: an RSCCode (duck-typed — kernels/ never imports siso/).
      llr_coded: (B, T, n_out) per-coded-bit channel LLRs, convention
        ``lambda = log P(0)/P(1)`` (punctured positions = 0).
      llr_apriori: (B, T) a-priori LLRs on the info bits (None -> zeros).
      terminated: trellis flushed to state 0 (beta seeded there) vs open.
    Returns:
      llr: (B, T) float32 a-posteriori LLRs (negative -> decide bit 1).
      metric: (B,) float32 best-path terminal cost (renormalized per step,
        so meaningful relative to other streams of the same T, not absolute).
    """
    B, T, n = llr_coded.shape
    if llr_apriori is None:
        llr_apriori = jnp.zeros((B, T), jnp.float32)
    feat = jnp.concatenate(
        [llr_coded.astype(jnp.float32), llr_apriori[..., None].astype(jnp.float32)],
        axis=-1,
    )
    feat = feat.transpose(1, 2, 0)  # (T, F, B)
    block_b = lane_block(B)
    feat, _ = pad_axis_to(feat, 2, block_b, 0.0)
    interpret = resolve_interpret(interpret)  # pinned once for both kernels
    P0, P1 = code.select_matrices
    b0, b1 = code.alpha_weights
    alphas, final_pm = _bcjr.bcjr_alpha_scan(
        tuple(jnp.asarray(m) for m in (P0, P1, b0, b1)), feat, block_b, interpret
    )
    N0, N1 = code.beta_matrices
    U0, U1 = code.llr_matrices
    c0, c1 = code.beta_weights
    w0, w1 = code.llr_weights
    llr = _bcjr.bcjr_beta_llr_scan(
        tuple(jnp.asarray(m) for m in (N0, N1, U0, U1, c0, c1, w0, w1)),
        alphas, feat, terminated, block_b, interpret,
    )
    metric = final_pm[0, :B] if terminated else final_pm[:, :B].min(axis=0)
    return llr[:, :B].T, metric


def minplus_matmul_op(
    a: jnp.ndarray, b: jnp.ndarray, interpret: Optional[bool] = None
) -> jnp.ndarray:
    """Batched (min,+) matmul with padding.  a: (..., I, K), b: (..., K, J)."""
    batch_shape = a.shape[:-2]
    I, K = a.shape[-2:]
    J = b.shape[-1]
    a2 = a.reshape((-1, I, K))
    b2 = b.reshape((-1, K, J))
    bi = min(128, max(8, I))
    bj = lane_block(J)
    bk = min(128, max(8, K))
    a2, _ = pad_axis_to(a2, 1, bi, NEG_UNREACHABLE)
    a2, _ = pad_axis_to(a2, 2, bk, NEG_UNREACHABLE)
    b2, _ = pad_axis_to(b2, 1, bk, NEG_UNREACHABLE)
    b2, _ = pad_axis_to(b2, 2, bj, NEG_UNREACHABLE)
    out = _minplus.minplus_matmul(
        a2.astype(jnp.float32), b2.astype(jnp.float32), bi, bj, bk, interpret
    )
    out = jnp.minimum(out, NEG_UNREACHABLE)  # padded lanes produced 2*BIG
    return out[:, :I, :J].reshape(batch_shape + (I, J))
