"""Jit'd public wrappers around the Pallas kernels.

Handle layout conversion ((B, S) user layout <-> (S, B) kernel layout),
lane/sublane padding, interpret-mode selection (CPU container -> interpret;
real TPU -> compiled), and compose the full fused decoder (kernel forward
pass + traceback).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.trellis import NEG_UNREACHABLE, ConvCode
from repro.core.viterbi import _traceback
from repro.kernels import minplus as _minplus
from repro.kernels import texpand as _texpand
from repro.kernels import viterbi_scan as _vscan


def _use_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value) -> Tuple[jnp.ndarray, int]:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


def texpand_op(
    code: ConvCode,
    pm: jnp.ndarray,
    bm_table: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ACS step in user layout.  pm: (B, S); bm_table: (B, M)."""
    B = pm.shape[0]
    pm_k = pm.T  # (S, B)
    bm_k = bm_table.T  # (M, B)
    block_b = 128 if B >= 128 else max(8, B)
    pm_k, _ = _pad_to(pm_k, 1, block_b, NEG_UNREACHABLE)
    bm_k, _ = _pad_to(bm_k, 1, block_b, 0.0)
    new_pm, bp = _texpand.texpand(
        code, pm_k.astype(jnp.float32), bm_k.astype(jnp.float32), block_b, _use_interpret(interpret)
    )
    return new_pm[:, :B].T, bp[:, :B].T


def viterbi_forward_op(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full fused forward pass.  bm_tables: (B, T, M).

    Returns final_pm (B, S) and backpointers (T, B, S) (traceback layout).
    """
    B, T, M = bm_tables.shape
    bm_k = bm_tables.transpose(1, 2, 0)  # (T, M, B)
    block_b = 128 if B >= 128 else max(8, B)
    bm_k, _ = _pad_to(bm_k, 2, block_b, 0.0)
    final_pm, bps = _vscan.viterbi_scan(
        code, bm_k.astype(jnp.float32), block_b, _use_interpret(interpret)
    )
    return final_pm[:, :B].T, bps[:, :, :B].transpose(0, 2, 1)


def viterbi_forward_chunk_op(
    code: ConvCode,
    pm: jnp.ndarray,
    bm_chunk: jnp.ndarray,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked fused forward pass with carried path metrics — the streaming
    entry point.  The caller owns the cross-chunk state (path metrics and a
    traceback ring buffer, see stream/session.py); this op advances the path
    metrics C steps through the VMEM-resident Pallas scan.

    Args:
      pm: (B, S) float32 path metrics entering the chunk.
      bm_chunk: (B, C, M) branch-metric tables for the chunk.
    Returns:
      new_pm: (B, S) path metrics after the chunk.
      bps: (C, B, S) int32 backpointer parities (traceback layout).
    """
    B, C, M = bm_chunk.shape
    pm_k = pm.T  # (S, B)
    bm_k = bm_chunk.transpose(1, 2, 0)  # (C, M, B)
    block_b = 128 if B >= 128 else max(8, B)
    pm_k, _ = _pad_to(pm_k, 1, block_b, NEG_UNREACHABLE)
    bm_k, _ = _pad_to(bm_k, 2, block_b, 0.0)
    new_pm, bps = _vscan.viterbi_scan_carry(
        code, pm_k.astype(jnp.float32), bm_k.astype(jnp.float32), block_b, _use_interpret(interpret)
    )
    return new_pm[:, :B].T, bps[:, :, :B].transpose(0, 2, 1)


def viterbi_decode_fused(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    terminated: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in fused replacement for core.viterbi.viterbi_decode.

    bm_tables: (B, T, M) -> (bits (B, T), metric (B,)).
    """
    B = bm_tables.shape[0]
    final_pm, bps = viterbi_forward_op(code, bm_tables, interpret)
    if terminated:
        final_state = jnp.zeros((B,), dtype=jnp.int32)
        metric = final_pm[:, 0]
    else:
        final_state = jnp.argmin(final_pm, axis=-1).astype(jnp.int32)
        metric = final_pm.min(axis=-1)
    bits, _ = _traceback(code, bps, final_state)
    return bits, metric


def minplus_matmul_op(
    a: jnp.ndarray, b: jnp.ndarray, interpret: Optional[bool] = None
) -> jnp.ndarray:
    """Batched (min,+) matmul with padding.  a: (..., I, K), b: (..., K, J)."""
    batch_shape = a.shape[:-2]
    I, K = a.shape[-2:]
    J = b.shape[-1]
    a2 = a.reshape((-1, I, K))
    b2 = b.reshape((-1, K, J))
    bi = min(128, max(8, I))
    bj = 128 if J >= 128 else max(8, J)
    bk = min(128, max(8, K))
    a2, _ = _pad_to(a2, 1, bi, NEG_UNREACHABLE)
    a2, _ = _pad_to(a2, 2, bk, NEG_UNREACHABLE)
    b2, _ = _pad_to(b2, 1, bk, NEG_UNREACHABLE)
    b2, _ = _pad_to(b2, 2, bj, NEG_UNREACHABLE)
    out = _minplus.minplus_matmul(
        a2.astype(jnp.float32), b2.astype(jnp.float32), bi, bj, bk, _use_interpret(interpret)
    )
    out = jnp.minimum(out, NEG_UNREACHABLE)  # padded lanes produced 2*BIG
    return out[:, :I, :J].reshape(batch_shape + (I, J))
