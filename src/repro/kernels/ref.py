"""Pure-jnp oracles for every Pallas kernel (the reference implementations
the kernels are validated against, in kernel-native (state, batch) layout)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.trellis import ConvCode


def texpand_ref(
    code: ConvCode, pm: jnp.ndarray, bm_table: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the one-step fused ACS kernel.

    Kernel-native layout: states/symbols lead, batch is the minor (lane) axis.

    Args:
      pm: (S, B) float32 path metrics.
      bm_table: (M, B) float32 per-step branch-metric table.
    Returns:
      new_pm: (S, B); bp: (S, B) int32 backpointer parity (ties -> 0).
    """
    P0, P1 = code.select_matrices
    OH0, OH1 = code.branch_onehot_pair
    cand0 = jnp.asarray(P0) @ pm + jnp.asarray(OH0) @ bm_table
    cand1 = jnp.asarray(P1) @ pm + jnp.asarray(OH1) @ bm_table
    take1 = cand1 < cand0
    return jnp.where(take1, cand1, cand0), take1.astype(jnp.int32)


def viterbi_scan_ref(
    code: ConvCode, bm_tables: jnp.ndarray, pm0: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the full-sequence kernel.

    Args:
      bm_tables: (T, M, B); pm0: (S, B) initial metrics.
    Returns:
      final_pm: (S, B); bps: (T, S, B) int32.
    """

    def step(pm, bm_t):
        new_pm, bp = texpand_ref(code, pm, bm_t)
        return new_pm, bp

    final_pm, bps = jax.lax.scan(step, pm0, bm_tables)
    return final_pm, bps


def minplus_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the (min,+) matmul kernel.  a: (B, I, K), b: (B, K, J)."""
    return jnp.min(a[..., :, :, None] + b[..., None, :, :], axis=-2)
