"""Pure-jnp oracles for every Pallas kernel (the reference implementations
the kernels are validated against, in kernel-native (state, batch) layout)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.trellis import ConvCode


def texpand_ref(
    code: ConvCode, pm: jnp.ndarray, bm_table: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the one-step fused ACS kernel.

    Kernel-native layout: states/symbols lead, batch is the minor (lane) axis.

    Args:
      pm: (S, B) float32 path metrics.
      bm_table: (M, B) float32 per-step branch-metric table.
    Returns:
      new_pm: (S, B); bp: (S, B) int32 backpointer parity (ties -> 0).
    """
    P0, P1 = code.select_matrices
    OH0, OH1 = code.branch_onehot_pair
    cand0 = jnp.asarray(P0) @ pm + jnp.asarray(OH0) @ bm_table
    cand1 = jnp.asarray(P1) @ pm + jnp.asarray(OH1) @ bm_table
    take1 = cand1 < cand0
    return jnp.where(take1, cand1, cand0), take1.astype(jnp.int32)


def viterbi_scan_ref(
    code: ConvCode, bm_tables: jnp.ndarray, pm0: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the full-sequence kernel.

    Args:
      bm_tables: (T, M, B); pm0: (S, B) initial metrics.
    Returns:
      final_pm: (S, B); bps: (T, S, B) int32.
    """

    def step(pm, bm_t):
        new_pm, bp = texpand_ref(code, pm, bm_t)
        return new_pm, bp

    final_pm, bps = jax.lax.scan(step, pm0, bm_tables)
    return final_pm, bps


def minplus_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the (min,+) matmul kernel.  a: (B, I, K), b: (B, K, J)."""
    return jnp.min(a[..., :, :, None] + b[..., None, :, :], axis=-2)


def bcjr_llr_ref(code, feat: jnp.ndarray, terminated: bool = False) -> jnp.ndarray:
    """Oracle for the alpha + beta/LLR BCJR kernel pair (kernels/bcjr.py).

    Same operand matrices, same min-domain algebra, kernel-native layout.

    Args:
      code: an RSCCode (duck-typed: only the cached table properties are
        used, so kernels/ never imports siso/).
      feat: (T, F, B) per-step feature columns (channel LLRs + a-priori).
    Returns:
      llr: (T, B) float32 max-log LLRs (negative -> decide 1).
    """
    from repro.core.trellis import NEG_UNREACHABLE

    T, F, B = feat.shape
    S = code.n_states
    P0, P1 = (jnp.asarray(m) for m in code.select_matrices)
    b0, b1 = (jnp.asarray(m) for m in code.alpha_weights)
    N0, N1 = (jnp.asarray(m) for m in code.beta_matrices)
    c0, c1 = (jnp.asarray(m) for m in code.beta_weights)
    U0, U1 = (jnp.asarray(m) for m in code.llr_matrices)
    w0, w1 = (jnp.asarray(m) for m in code.llr_weights)

    col0 = jnp.where(jnp.arange(S)[:, None] == 0, 0.0, NEG_UNREACHABLE)
    col0 = jnp.broadcast_to(col0, (S, B))

    def fwd(alpha, f_t):
        new = jnp.minimum(P0 @ alpha + b0 @ f_t, P1 @ alpha + b1 @ f_t)
        new = jnp.minimum(new - new.min(axis=0, keepdims=True), NEG_UNREACHABLE)
        return new, alpha  # emit the PRE-update A_t, like the kernel

    _, alphas = jax.lax.scan(fwd, col0, feat)

    def bwd(beta, inputs):
        alpha, f_t = inputs
        cost0 = alpha + w0 @ f_t + U0 @ beta
        cost1 = alpha + w1 @ f_t + U1 @ beta
        llr_t = cost1.min(axis=0) - cost0.min(axis=0)
        new = jnp.minimum(N0 @ beta + c0 @ f_t, N1 @ beta + c1 @ f_t)
        new = jnp.minimum(new - new.min(axis=0, keepdims=True), NEG_UNREACHABLE)
        return new, llr_t

    beta_T = col0 if terminated else jnp.zeros((S, B))
    _, llr = jax.lax.scan(bwd, beta_T, (alphas, feat), reverse=True)
    return llr
