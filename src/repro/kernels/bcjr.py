"""Max-log-MAP BCJR forward/backward scans as Pallas kernels.

Same structure as the Viterbi ACS scan (kernels/viterbi_scan.py) — the state
metrics live in VMEM scratch across all T grid steps, branch costs are an
``(S, F)`` weight matrix times the per-step feature column, and the state
gathers are (S, S) one-hot matmuls — run twice:

  alpha (forward)   exactly the Viterbi recursion over the RSC butterfly
                    (``A_{t+1}(s') = min_j [P_j @ A + b_j @ feat]``), but
                    every pre-update metric column ``A_t`` is streamed to
                    HBM because the backward pass needs it.
  beta + LLR        a time-REVERSED grid (the traceback-kernel idiom from
  (backward)        kernels/survivors.py): scratch carries ``B_{t+1}``, each
                    step emits the max-log LLR
                    ``L_t = min_s[A_t + gamma_t(s,1) + B_{t+1}(s'_1)]
                          - min_s[A_t + gamma_t(s,0) + B_{t+1}(s'_0)]``
                    and then retires ``B_t = min_a [N_a @ B + c_a @ feat]``.

All metrics are min-domain costs with the convention
``lambda = log P(0)/P(1)`` (cost of bit b = b * lambda), so a *negative* LLR
means "decide 1".  Max-log == Viterbi algebra, which is why the subtract-min
renormalization per step (the kernels' numerical guard for unbounded T)
cancels exactly in the emitted LLRs.

Both kernels are generic over the operand arrays (built by
``siso/rsc.RSCCode``'s cached properties) — like viterbi_scan they never
import the code object.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.trellis import NEG_UNREACHABLE
from repro.kernels.common import resolve_interpret

_HI = jax.lax.Precision.HIGHEST


def _state0_column(shape) -> jnp.ndarray:
    """(S, bB) init metrics: state 0 costs 0, everything else unreachable."""
    row = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    return jnp.where(row == 0, 0.0, NEG_UNREACHABLE)


def _alpha_kernel(p0_ref, p1_ref, b0_ref, b1_ref, data_ref,
                  out_a_ref, out_pm_ref, scratch, shift_acc):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        # the encoder starts in state 0 (same convention as Viterbi)
        scratch[...] = _state0_column(scratch.shape)
        shift_acc[...] = jnp.zeros_like(shift_acc)

    alpha = scratch[...]
    out_a_ref[0] = alpha  # pre-update A_t, consumed by the backward pass
    data = data_ref[0].astype(jnp.float32)
    cand0 = (jax.lax.dot(p0_ref[...], alpha, precision=_HI)
             + jax.lax.dot(b0_ref[...], data, precision=_HI))
    cand1 = (jax.lax.dot(p1_ref[...], alpha, precision=_HI)
             + jax.lax.dot(b1_ref[...], data, precision=_HI))
    new = jnp.minimum(cand0, cand1)
    # subtract-min renorm: keeps metrics bounded for any T; a per-(t, stream)
    # constant, so it cancels in the LLR extraction.  The shifts accumulate
    # so the terminal metrics can be reported in absolute cost units.
    shift = jnp.min(new, axis=0, keepdims=True)
    new = jnp.minimum(new - shift, NEG_UNREACHABLE)
    scratch[...] = new
    shift_acc[...] = shift_acc[...] + shift
    out_pm_ref[...] = new + shift_acc[...]


def _make_beta_kernel(terminated: bool):
    def kernel(n0_ref, n1_ref, u0_ref, u1_ref, c0_ref, c1_ref, w0_ref, w1_ref,
               a_ref, data_ref, out_llr_ref, scratch):
        i = pl.program_id(1)

        @pl.when(i == 0)
        def _init():
            if terminated:
                scratch[...] = _state0_column(scratch.shape)
            else:
                scratch[...] = jnp.zeros_like(scratch)

        beta = scratch[...]  # B_{t+1} (grid step i handles t = T-1-i)
        alpha = a_ref[0]
        data = data_ref[0].astype(jnp.float32)
        # per-input-hypothesis total costs: A_t(s) + gamma_t(s, u) + B_{t+1}(s')
        cost0 = (alpha
                 + jax.lax.dot(w0_ref[...], data, precision=_HI)
                 + jax.lax.dot(u0_ref[...], beta, precision=_HI))
        cost1 = (alpha
                 + jax.lax.dot(w1_ref[...], data, precision=_HI)
                 + jax.lax.dot(u1_ref[...], beta, precision=_HI))
        out_llr_ref[...] = (jnp.min(cost1, axis=0, keepdims=True)
                            - jnp.min(cost0, axis=0, keepdims=True))
        # retire to B_t over the new-register-bit branches
        cand0 = (jax.lax.dot(n0_ref[...], beta, precision=_HI)
                 + jax.lax.dot(c0_ref[...], data, precision=_HI))
        cand1 = (jax.lax.dot(n1_ref[...], beta, precision=_HI)
                 + jax.lax.dot(c1_ref[...], data, precision=_HI))
        new = jnp.minimum(cand0, cand1)
        new = new - jnp.min(new, axis=0, keepdims=True)
        new = jnp.minimum(new, NEG_UNREACHABLE)
        scratch[...] = new

    return kernel


@functools.partial(jax.jit, static_argnums=(2, 3))
def bcjr_alpha_scan(
    mats: Tuple[jnp.ndarray, ...],
    feat: jnp.ndarray,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Forward (alpha) scan.

    Args:
      mats: (P0, P1, b0, b1) — select matrices (S, S) + branch weights (S, F).
      feat: (T, F, B) per-step feature columns (channel LLRs + a-priori LLR).
        B must be a multiple of ``block_b``.
    Returns:
      alphas: (T, S, B) float32 — the PRE-update metrics A_t (A_0 is the
        state-0 init), renormalized per step.
      final_pm: (S, B) float32 — A_T in ABSOLUTE cost units (the per-step
        renorm shifts are accumulated and added back), so its min over
        states is the Viterbi best-path metric of the same trellis.
    """
    p0, p1, b0, b1 = mats
    T, F, B = feat.shape
    S = p0.shape[0]
    grid = (B // block_b, T)
    tbl = lambda r, c: pl.BlockSpec((r, c), lambda b, t: (0, 0))  # noqa: E731
    return pl.pallas_call(
        _alpha_kernel,
        grid=grid,
        in_specs=[
            tbl(S, S), tbl(S, S), tbl(S, F), tbl(S, F),
            pl.BlockSpec((1, F, block_b), lambda b, t: (t, 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, block_b), lambda b, t: (t, 0, b)),
            pl.BlockSpec((S, block_b), lambda b, t: (0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, S, B), jnp.float32),
            jax.ShapeDtypeStruct((S, B), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((S, block_b), jnp.float32),
            pltpu.VMEM((1, block_b), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(p0, p1, b0, b1, feat)


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def bcjr_beta_llr_scan(
    mats: Tuple[jnp.ndarray, ...],
    alphas: jnp.ndarray,
    feat: jnp.ndarray,
    terminated: bool = False,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Backward (beta) scan fused with max-log LLR extraction.

    Args:
      mats: (N0, N1, U0, U1, c0, c1, w0, w1) from RSCCode's cached tables.
      alphas: (T, S, B) pre-update forward metrics from bcjr_alpha_scan.
      feat: (T, F, B) the same feature columns the forward pass consumed.
      terminated: trellis ends in state 0 (beta init [0, inf, ...]) vs open
        (uniform beta init).
    Returns:
      llr: (T, B) float32 — ``log P(u_t=0) - log P(u_t=1)`` in max-log
        approximation; decide bit 1 where negative.
    """
    n0, n1, u0, u1, c0, c1, w0, w1 = mats
    T, S, B = alphas.shape
    F = feat.shape[1]
    grid = (B // block_b, T)
    tbl = lambda r, c: pl.BlockSpec((r, c), lambda b, t: (0, 0))  # noqa: E731
    rev3 = lambda b, t: (T - 1 - t, 0, b)  # noqa: E731
    (llr,) = pl.pallas_call(
        _make_beta_kernel(bool(terminated)),
        grid=grid,
        in_specs=[
            tbl(S, S), tbl(S, S), tbl(S, S), tbl(S, S),
            tbl(S, F), tbl(S, F), tbl(S, F), tbl(S, F),
            pl.BlockSpec((1, S, block_b), rev3),
            pl.BlockSpec((1, F, block_b), rev3),
        ],
        out_specs=[pl.BlockSpec((1, block_b), lambda b, t: (T - 1 - t, b))],
        out_shape=[jax.ShapeDtypeStruct((T, B), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((S, block_b), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(n0, n1, u0, u1, c0, c1, w0, w1, alphas, feat)
    return llr
