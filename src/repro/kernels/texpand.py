"""`Texpand` — the paper's custom instruction as a fused Pallas TPU kernel.

One trellis-expansion (Add-Compare-Select) step for all states of a batch of
decoders, fused into a single kernel:

  ADD      cand_j = P_j @ pm + OH_j @ bm_table     (two small MXU matmuls)
  COMPARE  take1  = cand_1 < cand_0               (strict -> paper tie-break)
  SELECT   pm'    = where(take1, cand_1, cand_0)

The predecessor "gather" is expressed as one-hot matmuls against static
selection matrices (see trellis.py) so the kernel contains **no gathers** —
adds/compares ride the VPU, table lookups ride the MXU.  Path metrics,
selection matrices and branch tables all live in VMEM.

Layout: (state, batch) — batch on the 128-wide lane axis, states on sublanes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.trellis import ConvCode
from repro.kernels.common import resolve_interpret


def _texpand_kernel(p0_ref, p1_ref, oh0_ref, oh1_ref, pm_ref, bm_ref, out_pm_ref, out_bp_ref):
    pm = pm_ref[...]
    bm = bm_ref[...]
    f32 = jnp.float32
    cand0 = jax.lax.dot(p0_ref[...], pm.astype(f32), precision=jax.lax.Precision.HIGHEST) + jax.lax.dot(
        oh0_ref[...], bm.astype(f32), precision=jax.lax.Precision.HIGHEST
    )
    cand1 = jax.lax.dot(p1_ref[...], pm.astype(f32), precision=jax.lax.Precision.HIGHEST) + jax.lax.dot(
        oh1_ref[...], bm.astype(f32), precision=jax.lax.Precision.HIGHEST
    )
    take1 = cand1 < cand0
    out_pm_ref[...] = jnp.where(take1, cand1, cand0).astype(out_pm_ref.dtype)
    out_bp_ref[...] = take1.astype(out_bp_ref.dtype)


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def texpand(
    code: ConvCode,
    pm: jnp.ndarray,
    bm_table: jnp.ndarray,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One fused ACS step.  pm: (S, B); bm_table: (M, B).  B must be a
    multiple of ``block_b`` (ops.py handles padding).  ``interpret=None``
    auto-detects: compiled on TPU, interpreted elsewhere."""
    S, B = pm.shape
    M = bm_table.shape[0]
    P0, P1 = code.select_matrices
    OH0, OH1 = code.branch_onehot_pair
    grid = (B // block_b,)
    tbl = lambda r, c: pl.BlockSpec((r, c), lambda i: (0, 0))  # noqa: E731
    out_pm, out_bp = pl.pallas_call(
        _texpand_kernel,
        grid=grid,
        in_specs=[
            tbl(S, S),
            tbl(S, S),
            tbl(S, M),
            tbl(S, M),
            pl.BlockSpec((S, block_b), lambda i: (0, i)),
            pl.BlockSpec((M, block_b), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((S, block_b), lambda i: (0, i)),
            pl.BlockSpec((S, block_b), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, B), pm.dtype),
            jax.ShapeDtypeStruct((S, B), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(jnp.asarray(P0), jnp.asarray(P1), jnp.asarray(OH0), jnp.asarray(OH1), pm, bm_table)
    return out_pm, out_bp
