"""Full-sequence Viterbi forward pass as a single Pallas kernel.

This is the strongest TPU analogue of the paper's custom instruction: the
path metrics stay **resident in VMEM scratch across all T trellis steps** —
they never round-trip to HBM, exactly like the microcoded Texpand keeps its
operands out of the fetch/decode path.  The grid iterates (batch-tile, time);
TPU grid execution is sequential, so scratch carries state across time steps.

One parameterized kernel body (`_make_scan_kernel`) serves every variant —
the old block/carry pair were byte-identical except for their init path:

  init path      ``carry=False`` seeds pm = [0, +inf, ...] in-kernel (paper
                 §IV-B, paths start in state 0); ``carry=True`` seeds from a
                 pm0 input (the streaming chunk scan).
  branch metrics the per-step input is a generic ``(F, bB)`` tile multiplied
                 by an ``(S, F)`` weight pair plus an ``(S, 2)`` bias.  With
                 weights = the branch one-hots and F = n_symbols this is the
                 classic precomputed bm-table path; with weights = the folded
                 metric matrices of kernels/metrics.py and F = n features the
                 kernel computes hard/soft/punctured branch metrics from raw
                 received symbols **in-kernel**, cutting the per-step HBM
                 read from M·B to F·B floats (M = 2^n symbols vs F = n raw
                 values per step).
  survivors      ``pack=False`` emits one int32 per (t, state, stream) —
                 one useful bit per 4 bytes.  ``pack=True`` accumulates the
                 ACS select bits in a uint32 scratch word and emits
                 ``(ceil(T/32), S, B)`` — a 32× smaller survivor tensor that
                 kernels/survivors.py traces back without ever unpacking in
                 HBM.
  validity       ``windowed=True`` adds per-lane int32 ``(lo, hi)`` rows: a
                 lane only runs ACS on steps ``lo <= t < hi`` and passes its
                 path metrics through unchanged (survivor bit forced 0)
                 elsewhere.  This is what lets the tiled decoder fold P
                 time-tiles of *different* effective lengths (front warm-up,
                 ragged T%P / T%32 tails) into one uniform batched launch —
                 see kernels/tiling.py.

Per grid step:  data tile (F, bB) streams in;  bp tile (S, bB) — or, packed,
                1/32nd of one — streams out;  pm (S, bB) lives in scratch.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.trellis import NEG_UNREACHABLE, ConvCode
from repro.kernels.common import PACK_BITS, resolve_interpret


def _make_scan_kernel(carry: bool, pack: bool, windowed: bool = False):
    """Build the ACS scan kernel for one (init path, survivor format,
    validity) combo.

    Ref order: p0, p1, b0, b1, rb, [pm0], [lo, hi], data, out_bp, out_pm,
    pm_scratch, [pack_scratch].
    """

    def kernel(*refs):
        refs = list(refs)
        p0_ref, p1_ref, b0_ref, b1_ref, rb_ref = refs[:5]
        del refs[:5]
        pm0_ref = refs.pop(0) if carry else None
        lo_ref, hi_ref = (refs.pop(0), refs.pop(0)) if windowed else (None, None)
        data_ref, out_bp_ref, out_pm_ref, pm_scratch = refs[:4]
        t = pl.program_id(1)

        @pl.when(t == 0)
        def _init():
            if carry:
                pm_scratch[...] = pm0_ref[...]
            else:
                # paths start in state 0 (paper §IV-B): pm = [0, +inf, ...]
                row = jax.lax.broadcasted_iota(jnp.int32, pm_scratch.shape, 0)
                pm_scratch[...] = jnp.where(row == 0, 0.0, NEG_UNREACHABLE)

        pm = pm_scratch[...]
        data = data_ref[0].astype(jnp.float32)
        hi = jax.lax.Precision.HIGHEST
        cand0 = (
            jax.lax.dot(p0_ref[...], pm, precision=hi)
            + jax.lax.dot(b0_ref[...], data, precision=hi)
            + rb_ref[:, 0:1]
        )
        cand1 = (
            jax.lax.dot(p1_ref[...], pm, precision=hi)
            + jax.lax.dot(b1_ref[...], data, precision=hi)
            + rb_ref[:, 1:2]
        )
        take1 = cand1 < cand0
        new_pm = jnp.where(take1, cand1, cand0)
        # clamp: unreachable-state metrics grow by BIG per matmul otherwise
        new_pm = jnp.minimum(new_pm, NEG_UNREACHABLE)
        if windowed:
            # outside a lane's [lo, hi) validity window the metrics pass
            # through untouched and the survivor bit is forced to 0 — the
            # step simply does not exist for that lane
            valid = (t >= lo_ref[...]) & (t < hi_ref[...])  # (1, bB)
            take1 = take1 & valid
            new_pm = jnp.where(valid, new_pm, pm)
        pm_scratch[...] = new_pm
        out_pm_ref[...] = new_pm.astype(out_pm_ref.dtype)

        if pack:
            pack_scratch = refs[4]
            pos = (t % PACK_BITS).astype(jnp.uint32)
            bit = take1.astype(jnp.uint32) << pos
            # pos == 0 starts a fresh word (the masked read of uninitialized
            # scratch on the first step is discarded by the where)
            word = jnp.where(pos == 0, jnp.uint32(0), pack_scratch[...]) | bit
            pack_scratch[...] = word
            # the out tile stays VMEM-resident for 32 steps (its block index
            # is t // 32); the value at the window's last visit — the fully
            # packed word — is what lands in HBM.
            out_bp_ref[0] = word
        else:
            out_bp_ref[0] = take1.astype(out_bp_ref.dtype)

    return kernel


def _scan_call(
    code: ConvCode,
    pm0: Optional[jnp.ndarray],
    data: jnp.ndarray,
    b0: jnp.ndarray,
    b1: jnp.ndarray,
    rb: jnp.ndarray,
    block_b: int,
    interpret: Optional[bool],
    pack: bool,
    window: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Shared pallas_call plumbing for all the scan variants."""
    T, F, B = data.shape
    S = code.n_states
    P0, P1 = code.select_matrices
    carry = pm0 is not None
    grid = (B // block_b, T)  # time innermost: scratch carries pm across t
    tbl = lambda r, c: pl.BlockSpec((r, c), lambda b, t: (0, 0))  # noqa: E731
    in_specs = [tbl(S, S), tbl(S, S), tbl(S, F), tbl(S, F), tbl(S, 2)]
    args = [jnp.asarray(P0), jnp.asarray(P1), b0, b1, rb]
    if carry:
        in_specs.append(pl.BlockSpec((S, block_b), lambda b, t: (0, b)))
        args.append(pm0)
    if window is not None:
        lo, hi = window
        for w in (lo, hi):
            in_specs.append(pl.BlockSpec((1, block_b), lambda b, t: (0, b)))
            args.append(w.astype(jnp.int32))
    in_specs.append(pl.BlockSpec((1, F, block_b), lambda b, t: (t, 0, b)))
    args.append(data)
    if pack:
        n_words = pl.cdiv(T, PACK_BITS)
        bp_spec = pl.BlockSpec(
            (1, S, block_b), lambda b, t: (t // PACK_BITS, 0, b)
        )
        bp_shape = jax.ShapeDtypeStruct((n_words, S, B), jnp.uint32)
    else:
        bp_spec = pl.BlockSpec((1, S, block_b), lambda b, t: (t, 0, b))
        bp_shape = jax.ShapeDtypeStruct((T, S, B), jnp.int32)
    scratch = [pltpu.VMEM((S, block_b), jnp.float32)]
    if pack:
        scratch.append(pltpu.VMEM((S, block_b), jnp.uint32))
    bps, final_pm = pl.pallas_call(
        _make_scan_kernel(carry, pack, windowed=window is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[bp_spec, pl.BlockSpec((S, block_b), lambda b, t: (0, b))],
        out_shape=[bp_shape, jax.ShapeDtypeStruct((S, B), jnp.float32)],
        scratch_shapes=scratch,
        interpret=resolve_interpret(interpret),
    )(*args)
    return final_pm, bps


def table_weights(code: ConvCode):
    """Weights that make the generic kernel consume precomputed bm tables:
    the branch one-hots select bm[c] per transition, bias contributes 0."""
    OH0, OH1 = code.branch_onehot_pair
    rb = jnp.zeros((code.n_states, 2), jnp.float32)
    return jnp.asarray(OH0), jnp.asarray(OH1), rb


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def viterbi_scan(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run all T ACS steps with VMEM-resident path metrics.

    Args:
      bm_tables: (T, M, B) float32.  B must be a multiple of ``block_b``.
    Returns:
      final_pm: (S, B) float32; bps: (T, S, B) int32 backpointer parities.
    """
    b0, b1, rb = table_weights(code)
    return _scan_call(code, None, bm_tables, b0, b1, rb, block_b, interpret, pack=False)


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def viterbi_scan_carry(
    code: ConvCode,
    pm0: jnp.ndarray,
    bm_tables: jnp.ndarray,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked ACS scan with carried state: run C steps starting from ``pm0``.

    The streaming subsystem calls this once per chunk: pm0 is the previous
    chunk's final path metrics, so a stream of arbitrary length runs through
    the same VMEM-resident scan without re-materializing history.

    Args:
      pm0: (S, B) float32 path metrics entering the chunk.
      bm_tables: (C, M, B) float32.  B must be a multiple of ``block_b``.
    Returns:
      final_pm: (S, B) float32; bps: (C, S, B) int32 backpointer parities.
    """
    b0, b1, rb = table_weights(code)
    return _scan_call(code, pm0, bm_tables, b0, b1, rb, block_b, interpret, pack=False)


@functools.partial(jax.jit, static_argnums=(0, 5, 6))
def viterbi_scan_packed(
    code: ConvCode,
    data: jnp.ndarray,
    b0: jnp.ndarray,
    b1: jnp.ndarray,
    rb: jnp.ndarray,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Forward scan with bit-packed survivors and generic in-kernel metrics.

    Args:
      data: (T, F, B) per-step inputs — precomputed bm tables (F = M,
        weights from ``table_weights``) or raw received symbols (F = n
        features, weights from kernels/metrics.py folded through the branch
        one-hots).  B must be a multiple of ``block_b``.
      b0, b1: (S, F) float32 per-parity metric weights.
      rb: (S, 2) float32 per-parity metric bias.
    Returns:
      final_pm: (S, B) float32.
      packed: (ceil(T/32), S, B) uint32 — bit p of word w is the ACS select
        of trellis step ``t = 32*w + p`` (tail bits of a partial last word
        are zero).
    """
    return _scan_call(code, None, data, b0, b1, rb, block_b, interpret, pack=True)


@functools.partial(jax.jit, static_argnums=(0, 6, 7))
def viterbi_scan_packed_carry(
    code: ConvCode,
    pm0: jnp.ndarray,
    data: jnp.ndarray,
    b0: jnp.ndarray,
    b1: jnp.ndarray,
    rb: jnp.ndarray,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`viterbi_scan_packed` seeded from carried path metrics — the
    streaming hot path (pm0: (S, B) float32 entering the chunk)."""
    return _scan_call(code, pm0, data, b0, b1, rb, block_b, interpret, pack=True)


@functools.partial(jax.jit, static_argnums=(0, 8, 9))
def viterbi_scan_packed_window(
    code: ConvCode,
    pm0: jnp.ndarray,
    data: jnp.ndarray,
    b0: jnp.ndarray,
    b1: jnp.ndarray,
    rb: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`viterbi_scan_packed_carry` with a per-lane step-validity window
    — the tiled-decode launch (kernels/tiling.py folds P time-tiles into the
    lane axis; each lane's tile covers a different slice of the sequence).

    Args:
      pm0: (S, B) float32 metrics entering each lane's window (held
        untouched through any leading invalid steps).
      lo, hi: (1, B) int32 — lane b runs ACS only on steps lo[b] <= t <
        hi[b]; elsewhere the metrics pass through and the survivor bit is 0.
    Returns: final_pm (S, B) float32; packed (ceil(T/32), S, B) uint32.
    """
    return _scan_call(
        code, pm0, data, b0, b1, rb, block_b, interpret, pack=True,
        window=(lo, hi),
    )
