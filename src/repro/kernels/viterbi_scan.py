"""Full-sequence Viterbi forward pass as a single Pallas kernel.

This is the strongest TPU analogue of the paper's custom instruction: the
path metrics stay **resident in VMEM scratch across all T trellis steps** —
they never round-trip to HBM, exactly like the microcoded Texpand keeps its
operands out of the fetch/decode path.  The grid iterates (batch-tile, time);
TPU grid execution is sequential, so scratch carries state across time steps.

Per grid step:   bm_t tile (M, bB) streams in;  bp tile (S, bB) streams out;
                 pm (S, bB) lives in scratch.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.trellis import NEG_UNREACHABLE, ConvCode


def _viterbi_scan_kernel(
    p0_ref, p1_ref, oh0_ref, oh1_ref, bm_ref, out_bp_ref, out_pm_ref, pm_scratch
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        # paths start in state 0 (paper §IV-B): pm = [0, +inf, ...]
        row = jax.lax.broadcasted_iota(jnp.int32, pm_scratch.shape, 0)
        pm_scratch[...] = jnp.where(row == 0, 0.0, NEG_UNREACHABLE)

    pm = pm_scratch[...]
    bm = bm_ref[0].astype(jnp.float32)
    hi = jax.lax.Precision.HIGHEST
    cand0 = jax.lax.dot(p0_ref[...], pm, precision=hi) + jax.lax.dot(oh0_ref[...], bm, precision=hi)
    cand1 = jax.lax.dot(p1_ref[...], pm, precision=hi) + jax.lax.dot(oh1_ref[...], bm, precision=hi)
    take1 = cand1 < cand0
    new_pm = jnp.where(take1, cand1, cand0)
    # clamp: unreachable-state metrics grow by BIG per matmul otherwise
    new_pm = jnp.minimum(new_pm, NEG_UNREACHABLE)
    pm_scratch[...] = new_pm
    out_bp_ref[0] = take1.astype(out_bp_ref.dtype)
    out_pm_ref[...] = new_pm.astype(out_pm_ref.dtype)


def _viterbi_scan_carry_kernel(
    p0_ref, p1_ref, oh0_ref, oh1_ref, pm0_ref, bm_ref, out_bp_ref, out_pm_ref, pm_scratch
):
    """Like _viterbi_scan_kernel but seeded from carried path metrics.

    The streaming subsystem calls this once per chunk: pm0 is the previous
    chunk's final path metrics, so a stream of arbitrary length runs through
    the same VMEM-resident scan without re-materializing history.
    """
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        pm_scratch[...] = pm0_ref[...]

    pm = pm_scratch[...]
    bm = bm_ref[0].astype(jnp.float32)
    hi = jax.lax.Precision.HIGHEST
    cand0 = jax.lax.dot(p0_ref[...], pm, precision=hi) + jax.lax.dot(oh0_ref[...], bm, precision=hi)
    cand1 = jax.lax.dot(p1_ref[...], pm, precision=hi) + jax.lax.dot(oh1_ref[...], bm, precision=hi)
    take1 = cand1 < cand0
    new_pm = jnp.where(take1, cand1, cand0)
    new_pm = jnp.minimum(new_pm, NEG_UNREACHABLE)
    pm_scratch[...] = new_pm
    out_bp_ref[0] = take1.astype(out_bp_ref.dtype)
    out_pm_ref[...] = new_pm.astype(out_pm_ref.dtype)


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def viterbi_scan_carry(
    code: ConvCode,
    pm0: jnp.ndarray,
    bm_tables: jnp.ndarray,
    block_b: int = 128,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked ACS scan with carried state: run C steps starting from ``pm0``.

    Args:
      pm0: (S, B) float32 path metrics entering the chunk.
      bm_tables: (C, M, B) float32.  B must be a multiple of ``block_b``.
    Returns:
      final_pm: (S, B) float32; bps: (C, S, B) int32 backpointer parities.
    """
    C, M, B = bm_tables.shape
    S = code.n_states
    P0, P1 = code.select_matrices
    OH0, OH1 = code.branch_onehot_pair
    grid = (B // block_b, C)  # time innermost: scratch carries pm across t
    tbl = lambda r, c: pl.BlockSpec((r, c), lambda b, t: (0, 0))  # noqa: E731
    bps, final_pm = pl.pallas_call(
        _viterbi_scan_carry_kernel,
        grid=grid,
        in_specs=[
            tbl(S, S),
            tbl(S, S),
            tbl(S, M),
            tbl(S, M),
            pl.BlockSpec((S, block_b), lambda b, t: (0, b)),
            pl.BlockSpec((1, M, block_b), lambda b, t: (t, 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, block_b), lambda b, t: (t, 0, b)),
            pl.BlockSpec((S, block_b), lambda b, t: (0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, S, B), jnp.int32),
            jax.ShapeDtypeStruct((S, B), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((S, block_b), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(P0), jnp.asarray(P1), jnp.asarray(OH0), jnp.asarray(OH1), pm0, bm_tables)
    return final_pm, bps


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def viterbi_scan(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    block_b: int = 128,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run all T ACS steps with VMEM-resident path metrics.

    Args:
      bm_tables: (T, M, B) float32.  B must be a multiple of ``block_b``.
    Returns:
      final_pm: (S, B) float32; bps: (T, S, B) int32 backpointer parities.
    """
    T, M, B = bm_tables.shape
    S = code.n_states
    P0, P1 = code.select_matrices
    OH0, OH1 = code.branch_onehot_pair
    grid = (B // block_b, T)  # time innermost: scratch carries pm across t
    tbl = lambda r, c: pl.BlockSpec((r, c), lambda b, t: (0, 0))  # noqa: E731
    bps, final_pm = pl.pallas_call(
        _viterbi_scan_kernel,
        grid=grid,
        in_specs=[
            tbl(S, S),
            tbl(S, S),
            tbl(S, M),
            tbl(S, M),
            pl.BlockSpec((1, M, block_b), lambda b, t: (t, 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, block_b), lambda b, t: (t, 0, b)),
            pl.BlockSpec((S, block_b), lambda b, t: (0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, S, B), jnp.int32),
            jax.ShapeDtypeStruct((S, B), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((S, block_b), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(P0), jnp.asarray(P1), jnp.asarray(OH0), jnp.asarray(OH1), bm_tables)
    return final_pm, bps
