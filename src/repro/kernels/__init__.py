"""Pallas TPU kernels for the paper's compute hot-spot (trellis ACS).

texpand.py      — the paper's custom instruction: one fused ACS step
viterbi_scan.py — full-T / chunked scan with VMEM-resident path metrics, one
                  parameterized body: table or in-kernel branch metrics,
                  unpacked or bit-packed survivors
survivors.py    — survivor memory unit: 32-per-uint32 pack/unpack helpers +
                  the Pallas traceback kernel over packed words
metrics.py      — affine in-kernel branch-metric plans (hard/soft/punctured)
minplus.py      — (min,+) matmul for block-parallel / HMM Viterbi + the
                  state-map seam algebra (compose/prefix/entry/argmin)
tiling.py       — time-tiling plans for the tiled (time-parallel) decoder
ops.py          — jit'd public wrappers (layout, padding, interpret switch)
ref.py          — pure-jnp oracles
common.py       — shared interpret auto-detection + padding helpers
"""
from repro.kernels.metrics import FusedMetricPlan, fused_metric_plan
from repro.kernels.minplus import (
    compose_maps,
    identity_map,
    prefix_maps,
    seam_argmin,
    tile_entry_metrics,
)
from repro.kernels.ops import (
    minplus_matmul_op,
    texpand_op,
    viterbi_decode_fused,
    viterbi_decode_fused_packed,
    viterbi_decode_packed,
    viterbi_decode_tiled_fused,
    viterbi_decode_tiled_op,
    viterbi_forward_chunk_op,
    viterbi_forward_fused_op,
    viterbi_forward_op,
    viterbi_forward_packed_op,
    viterbi_forward_weighted_op,
    viterbi_traceback_op,
)
from repro.kernels.survivors import (
    pack_survivors,
    traceback_packed,
    traceback_packed_window,
    unpack_survivors,
)
from repro.kernels.tiling import TilePlan, default_tiles, plan_tiles, truncation_depth

__all__ = [
    "FusedMetricPlan",
    "TilePlan",
    "compose_maps",
    "default_tiles",
    "fused_metric_plan",
    "identity_map",
    "minplus_matmul_op",
    "pack_survivors",
    "plan_tiles",
    "prefix_maps",
    "seam_argmin",
    "texpand_op",
    "tile_entry_metrics",
    "traceback_packed",
    "traceback_packed_window",
    "truncation_depth",
    "unpack_survivors",
    "viterbi_decode_fused",
    "viterbi_decode_fused_packed",
    "viterbi_decode_packed",
    "viterbi_decode_tiled_fused",
    "viterbi_decode_tiled_op",
    "viterbi_forward_chunk_op",
    "viterbi_forward_fused_op",
    "viterbi_forward_op",
    "viterbi_forward_packed_op",
    "viterbi_forward_weighted_op",
    "viterbi_traceback_op",
]
