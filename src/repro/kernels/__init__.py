"""Pallas TPU kernels for the paper's compute hot-spot (trellis ACS).

texpand.py      — the paper's custom instruction: one fused ACS step
viterbi_scan.py — full-T decode with VMEM-resident path metrics
minplus.py      — (min,+) matmul for block-parallel / HMM Viterbi
ops.py          — jit'd public wrappers (layout, padding, interpret switch)
ref.py          — pure-jnp oracles
"""
from repro.kernels.ops import (
    minplus_matmul_op,
    texpand_op,
    viterbi_decode_fused,
    viterbi_forward_chunk_op,
    viterbi_forward_op,
)

__all__ = [
    "texpand_op",
    "viterbi_forward_op",
    "viterbi_forward_chunk_op",
    "viterbi_decode_fused",
    "minplus_matmul_op",
]
