"""In-kernel branch metrics: fold the metric computation into the scan kernel.

Every branch metric this repo uses is affine in the received symbols:

  hard (Hamming)        bm(c) = Σ_j |r_j - x_cj|          (r ∈ {0,1})
                              = Σ_j (1 - 2 x_cj) r_j + Σ_j x_cj
  hard + puncture mask  bm(c) = Σ_j m_j |r_j - x_cj|
                              = Σ_j (1 - 2 x_cj)(m_j r_j) + Σ_j x_cj m_j
  soft (correlation)    bm(c) = Σ_j (2 x_cj - 1) y_j      (y real, mask
                                                           pre-applied)

i.e. ``bm = W @ feat + bias`` with a static (M, F) weight, a static (M,)
bias, and F = n (or 2n punctured-hard) per-step *features* — versus the
M = 2^n entries of a precomputed table.  Folding W through the branch
one-hots (one-hot matmuls are exact row selections) turns the scan kernel's
per-parity metric lookup into ``b_j @ feat + rb_j`` directly, so the kernel
streams raw received symbols and never touches a bm table: per-step HBM
reads drop from M·B to F·B floats and the metric add rides the same MXU
matmul that did the table lookup.

A FusedMetricPlan bundles (W, bias, feature builder) for one
(code, metric kind, puncture) combination; ``folded()`` yields the kernel
operands.  Integer-valued plans (hard metrics) are bit-exact against the
table path; soft plans agree to float32 rounding.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.puncture import pattern_mask
from repro.core.trellis import ConvCode


@functools.lru_cache(maxsize=None)
def _phase_mask(
    code: ConvCode, T: int, pattern: Tuple[Tuple[int, ...], ...], phase: int
) -> jnp.ndarray:
    """(T, n) 0/1 puncture mask for trellis steps starting at ``phase``
    within the pattern period (callers reduce an absolute t0 mod period, so
    the key space — and the cache — is bounded by the period).  Build is
    O(T) however deep into a stream the chunk starts; a steady-state
    received session (fixed chunk, cycling phases) pays the host tile +
    device transfer once per phase, not once per push."""
    # puncture pattern is a python tuple-of-tuples — host data, not a sync
    pat = np.asarray(pattern)  # repr-lint: allow[RPR003]
    return pattern_mask(code, phase + T, pat)[phase:]


@dataclasses.dataclass(frozen=True)
class FusedMetricPlan:
    """Static affine form of one branch metric + its feature builder."""

    code: ConvCode
    metric: str  # "hard" | "soft"
    puncture: Optional[Tuple[Tuple[int, ...], ...]]
    weight: np.ndarray  # (M, F)
    bias: np.ndarray  # (M,)

    @property
    def n_features(self) -> int:
        return self.weight.shape[1]

    def features(self, received: jnp.ndarray, t0: int = 0) -> jnp.ndarray:
        """(..., T, n_out) raw channel output -> (..., T, F) kernel features.

        ``t0`` is the absolute trellis step of the first row — it phases the
        puncture mask for mid-stream chunks.
        """
        r = received.astype(jnp.float32)
        if self.puncture is None:
            return r
        period = len(self.puncture[0])
        mask = _phase_mask(self.code, r.shape[-2], self.puncture, t0 % period)
        if self.metric == "soft":
            return r * mask  # erased positions correlate to 0
        return jnp.concatenate([r * mask, jnp.broadcast_to(mask, r.shape)], axis=-1)

    def bm_from_features(self, feats: jnp.ndarray) -> jnp.ndarray:
        """(..., T, F) features -> (..., T, M) bm tables: the affine form
        evaluated outside the kernel (streaming tail chunks that take the
        lax.scan reference path).  Bit-exact vs the table builders for
        integer-valued (hard) metrics."""
        W = jnp.asarray(self.weight)
        return jnp.einsum("...tf,mf->...tm", feats, W) + jnp.asarray(self.bias)

    def bm_tables(self, received: jnp.ndarray, t0: int = 0) -> jnp.ndarray:
        """(..., T, n_out) raw symbols -> (..., T, M) bm tables."""
        return self.bm_from_features(self.features(received, t0))

    def folded(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Kernel operands: (b0 (S, F), b1 (S, F), rb (S, 2)).

        The branch one-hots are 0/1 row selectors, so ``OH_j @ W`` just
        re-indexes W per successor state — exact, no precision cost.
        """
        OH0, OH1 = self.code.branch_onehot_pair
        b0 = OH0 @ self.weight
        b1 = OH1 @ self.weight
        rb = np.stack([OH0 @ self.bias, OH1 @ self.bias], axis=1)
        return (
            jnp.asarray(b0, jnp.float32),
            jnp.asarray(b1, jnp.float32),
            jnp.asarray(rb, jnp.float32),
        )


def fused_metric_plan(
    code: ConvCode,
    metric: str = "hard",
    puncture: Optional[np.ndarray] = None,
) -> FusedMetricPlan:
    """Build the affine in-kernel form of a branch metric (see module doc)."""
    # plan construction: symbol table / puncture rows are host numpy inputs
    X = np.asarray(code.symbol_bits, np.float64)  # repr-lint: allow[RPR003]
    punct = (
        None
        if puncture is None
        else tuple(
            tuple(int(v) for v in row)
            for row in np.asarray(puncture)  # repr-lint: allow[RPR003]
        )
    )
    if metric == "soft":
        W = 2.0 * X - 1.0
        bias = np.zeros((X.shape[0],))
    elif punct is None:
        W = 1.0 - 2.0 * X
        bias = X.sum(axis=1)
    else:
        # features are [masked bits | mask]: Σ m|r-x| = (1-2X)@(mr) + X@m
        W = np.concatenate([1.0 - 2.0 * X, X], axis=1)
        bias = np.zeros((X.shape[0],))
    if metric not in ("hard", "soft"):
        raise ValueError(f"metric must be 'hard' or 'soft', got {metric!r}")
    return FusedMetricPlan(
        code=code,
        metric=metric,
        puncture=punct,
        weight=W.astype(np.float32),
        bias=bias.astype(np.float32),
    )
