"""Distribution: logical-axis sharding, shard_map collectives (sequence-
parallel Viterbi, flash-decode), and a GPipe-style pipeline stage."""
from repro.parallel.sharding import (
    batch_spec,
    make_rules,
    named_sharding,
    step_shardings,
)

__all__ = ["batch_spec", "make_rules", "named_sharding", "step_shardings"]
