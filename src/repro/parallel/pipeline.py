"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

Not used by the production dry-run meshes (the pod axis there is data-
parallel: DP×TP covers 512 chips for every assigned arch), but provided as a
first-class scheme for deeper scaling.  The schedule is the classic
fill/steady/drain: with n stages and M microbatches, step t has stage s
processing microbatch (t - s); activations hop stages via ppermute.

Bubble fraction = (n-1)/(M+n-1) — reported by :func:`bubble_fraction` so
launch configs can budget microbatches.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def bubble_fraction(n_stages: int, microbatches: int) -> float:
    return (n_stages - 1) / (microbatches + n_stages - 1)


def pipeline_apply(
    layer_fn: Callable,
    stage_params,
    x_mb: jnp.ndarray,
    *,
    mesh,
    axis: str = "stage",
):
    """Run ``layer_fn(params_s, h)`` across pipeline stages.

    Args:
      stage_params: pytree whose leaves have leading dim n_stages.
      x_mb: (M, mb, ...) microbatched input (replicated).
    Returns:
      (M, mb, ...) outputs (replicated).
    """
    n = mesh.shape[axis]
    M = x_mb.shape[0]
    steps = M + n - 1

    def shard_fn(params_s, xs):
        # params_s: this stage's params (leading stage dim stripped by
        # shard_map); xs: full microbatch stream (replicated).
        params_s = jax.tree_util.tree_map(lambda a: a[0], params_s)
        s = jax.lax.axis_index(axis)
        h0 = jnp.zeros_like(xs[0])

        def body(h_in, t):
            mb_idx = t - s  # microbatch this stage works on at step t
            valid = (mb_idx >= 0) & (mb_idx < M)
            # stage 0 reads fresh input; others use the hopped-in activation
            x_t = xs[jnp.clip(t, 0, M - 1)]
            h = jnp.where(s == 0, x_t, h_in)
            y = layer_fn(params_s, h)
            y = jnp.where(valid, y, h_in)
            # hop to the next stage (ring; the wraparound value is ignored)
            y_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n) for i in range(n)])
            return y_next, y  # emit this stage's freshly computed activation

        _, ys = jax.lax.scan(body, h0, jnp.arange(steps))
        return ys[None]  # (1, steps, mb, ...): stage-major for stitching

    ys = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(axis),
        check_rep=False,
    )(stage_params, x_mb)
    # ys: (n, steps, mb, ...); microbatch m exits the last stage at step m+n-1
    return ys[n - 1, n - 1 : n - 1 + M]
