"""shard_map collectives: the sequence-parallel Viterbi decoder.

Beyond-paper distribution of the paper's technique: the Viterbi forward pass
is a product in the (min,+) semiring, which is associative, so a length-T
decode can be split across the ``model`` mesh axis:

  1. each shard runs the fused local forward over its T/n chunk, producing a
     chunk transfer matrix (S, S) — all shards in parallel;
  2. one all-gather of the (small: S×S) chunk matrices;
  3. every shard computes the exclusive (min,+) prefix locally (n is the mesh
     axis size, so this is O(n·S^3) scalar work — negligible);
  4. each shard re-scans its chunk from the now-known boundary metrics to
     recover backpointers, and traceback stitches bits.

Communication = n · S² floats per batch element — independent of T.  This is
the TPU-mesh analogue of the paper's "execute the custom instruction in
parallel to other independent instructions" future-work note.

The seam calculus here (per-chunk state maps composed with (min,+) prefixes)
is the shared algebra of kernels/minplus.py; the single-device analogue of
this decoder is the ``tiled`` backend (kernels/ops.viterbi_decode_tiled_op),
which folds the tiles into one Pallas launch's lane axis instead of across
a mesh — prefer it when no model-axis mesh is available.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.acs import acs_step
from repro.core.trellis import NEG_UNREACHABLE, ConvCode
from repro.core.viterbi import _traceback
from repro.decode.spec import CodecSpec
from repro.kernels.minplus import compose_maps, identity_map


def _local_transfer_and_bps(code: ConvCode, bm_local: jnp.ndarray):
    """Per-shard chunk pass.  bm_local: (B, C, M).
    Returns transfer matrix (B, S, S): [i, s] = best metric entering in state
    i and leaving in state s."""
    S = code.n_states
    B = bm_local.shape[0]
    pm0 = jnp.where(jnp.eye(S, dtype=bool), 0.0, NEG_UNREACHABLE)
    pm0 = jnp.broadcast_to(pm0, (B, S, S))

    def step(pm, bm_t):  # pm: (B, S_init, S); bm_t: (B, M)
        new_pm, _ = acs_step(code, pm, bm_t[:, None, :])
        return jnp.minimum(new_pm, NEG_UNREACHABLE), None

    mat, _ = jax.lax.scan(step, pm0, bm_local.swapaxes(0, 1))
    return mat


def viterbi_decode_seqparallel(
    code: Union[ConvCode, CodecSpec],
    bm_tables: jnp.ndarray,
    mesh,
    axis: str = "model",
    terminated: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequence-parallel Viterbi.  bm_tables: (B, T, M) with T divisible by
    the mesh axis size.  Matches the sequential decoder's metric exactly.
    ``code`` may be a bare ConvCode or a CodecSpec (whose ``terminated`` flag
    is the default when the ``terminated`` argument is omitted)."""
    spec = CodecSpec.of(code)
    code = spec.code
    if terminated is None:
        terminated = spec.terminated
    n = mesh.shape[axis]
    B, T, M = bm_tables.shape
    S = code.n_states
    assert T % n == 0, (T, n)

    def shard_fn(bm_loc):  # (B, T/n, M) on each shard
        idx = jax.lax.axis_index(axis)
        mat = _local_transfer_and_bps(code, bm_loc)  # (B, S, S)
        mats = jax.lax.all_gather(mat, axis)  # (n, B, S, S)

        # exclusive (min,+) prefix over shards, computed redundantly per
        # shard — the shared state-map algebra of kernels/minplus.py
        eye = identity_map(S, (B,))

        def pref_step(acc, m):
            return compose_maps(acc, m), acc  # emit the *exclusive* prefix

        total, excl = jax.lax.scan(pref_step, eye, mats)
        my_excl = excl[idx]  # (B, S, S)
        boundary_pm = my_excl[:, 0, :]  # start state 0 -> (B, S)

        # local re-scan for backpointers
        def bp_step(pm, bm_t):
            new_pm, bp = acs_step(code, pm, bm_t)
            return jnp.minimum(new_pm, NEG_UNREACHABLE), bp

        _, bps_loc = jax.lax.scan(bp_step, boundary_pm, bm_loc.swapaxes(0, 1))
        final_pm = total[:, 0, :]  # (B, S) full-sequence metrics from state 0
        return bps_loc, final_pm

    bps_loc, final_pm = shard_map(
        shard_fn, mesh=mesh,
        in_specs=P(None, axis, None),
        out_specs=(P(axis, None, None), P()),
        check_rep=False,
    )(bm_tables)
    # bps_loc concatenates shard-local (T/n, B, S) blocks along time
    bps = bps_loc  # (T, B, S) — shard_map stitches the sharded axis

    if terminated:
        final_state = jnp.zeros((B,), jnp.int32)
        metric = final_pm[:, 0]
    else:
        final_state = jnp.argmin(final_pm, axis=-1).astype(jnp.int32)
        metric = final_pm.min(axis=-1)
    bits, _ = _traceback(code, bps, final_state)
    return bits, metric


def psum_scalar(x, axis: str):
    return jax.lax.psum(x, axis)


def mesh_axis_size(mesh, axis: str) -> int:
    """Size of a named mesh axis, 0 when the mesh lacks it (the planner and
    the stream scheduler both branch on this)."""
    if mesh is None:
        return 0
    return int(mesh.shape.get(axis, 0))


def reduce_across_shards(
    mesh, axis: str, per_shard: jnp.ndarray, op: str = "sum"
) -> jnp.ndarray:
    """Reduce a per-shard leading-axis array to a mesh-global scalar view.

    The sharded stream scheduler keeps admission/eviction bookkeeping
    host-side per shard; the few scalars that need a global view —
    utilization, pending-work counts, committed-bit totals, telemetry
    aggregates like the worst per-shard merge depth — reduce across the
    ``data`` axis here instead of gathering any decode state.  This is the
    same collective a multi-controller deployment (one host per shard) would
    issue over its own shard-local metrics.

    ``per_shard``: (n_shards, ...) with row i owned by shard i;
    ``op``: 'sum' | 'max' | 'min'; returns the reduced (...) value,
    replicated on every shard.
    """
    try:
        local_reduce, collective = {
            "sum": (jnp.sum, jax.lax.psum),
            "max": (jnp.max, jax.lax.pmax),
            "min": (jnp.min, jax.lax.pmin),
        }[op]
    except KeyError:
        raise ValueError(f"op must be 'sum', 'max' or 'min', got {op!r}") from None

    def local_fn(x):  # x: (1, ...) — this shard's row
        return collective(local_reduce(x, axis=0), axis)

    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=P(axis),
        out_specs=P(),
        check_rep=False,
    )(jnp.asarray(per_shard))


def sum_across_shards(mesh, axis: str, per_shard: jnp.ndarray) -> jnp.ndarray:
    """reduce_across_shards with op='sum' — the common scheduler case."""
    return reduce_across_shards(mesh, axis, per_shard, op="sum")
