"""Sharding helpers: logical-axis rules -> NamedShardings for whole step
signatures (params, optimizer state, batches, caches).

The actual resolution logic (maybe-shard divisibility, no axis reuse) lives
in models/common.py; this module packages it for the launchers.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import common as cm


def make_rules(part, extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    rules = dict(cm.DEFAULT_RULES)
    if part.fsdp:
        rules.update(cm.FSDP_RULES_OVERRIDE)
    if part.flash_decode:
        rules["kv_seq"] = "model"
    if extra:
        rules.update(extra)
    return rules


def batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh, ndim: int, batch_dim: int = 0) -> P:
    """PartitionSpec sharding dim `batch_dim` over ("pod","data")."""
    ba = batch_axes(mesh)
    spec = [None] * ndim
    if ba:
        spec[batch_dim] = ba if len(ba) > 1 else ba[0]
    return P(*spec)


def named_sharding(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def shard_batch_tree(mesh, tree):
    """NamedShardings for a batch pytree: dim 0 of every leaf is batch if it
    divides the dp size, else replicated."""
    ba = batch_axes(mesh)
    dp = 1
    for a in ba:
        dp *= mesh.shape[a]

    def one(leaf):
        shape = leaf.shape if hasattr(leaf, "shape") else ()
        if len(shape) and shape[0] % dp == 0 and dp > 1:
            return NamedSharding(mesh, batch_spec(mesh, len(shape)))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, tree)


def step_shardings(model, mesh, shape_kind: str, B: int, S: int, rules=None):
    """(in_shardings, out_shardings) trees for a given step kind.

    train:  in = (params, batch) -> out (loss/metrics replicated)
    prefill: in = (params, batch, caches)
    decode: in = (params, tokens, positions, caches)
    """
    p_sh = model.param_shardings(mesh, rules)
    repl = NamedSharding(mesh, P())
    if shape_kind == "train":
        return p_sh, repl
    c_sh = model.cache_shardings(mesh, B, S, rules)
    return p_sh, c_sh
