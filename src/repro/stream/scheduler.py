"""Continuous-batching stream scheduler.

Thousands of independent broadcast streams, one jitted Pallas call: every
live stream is pinned to a slot of a fixed (n_slots, chunk) decode block —
the same compile-once bucket discipline as serve/kv_cache.py — and each
``step()`` tick advances ALL slots through one batched stream_step.  Streams
join when a slot frees (FIFO admission), leave when their input drains (the
tail + final traceback run per-slot, off the hot path), and their slot is
recycled for the next pending stream: classic continuous batching, applied
to trellis decode instead of token decode.

Per-stream input queues are **device-resident**: at admission a stream's
remaining table is appended to one device arena, and each tick gathers the
(n_slots, chunk, ·) decode block by slot offset in a single jitted take —
no host-side numpy packing or per-tick H2D copy on the hot path (the arena
is compacted off the hot path when retired segments dominate it).

The per-slot python bookkeeping (positions, commit counts) mirrors
StreamSession; the batched StreamState lives in one pytree so the hot loop
is a single dispatch regardless of how many streams are in flight.  With
``backend="fused_packed"`` the ring holds bit-packed survivor words and the
per-tick traceback runs in the Pallas traceback kernel; with
``inputs="received"`` the arena holds raw channel symbols (features) and
branch metrics are computed in-kernel.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trellis import ConvCode
from repro.core.viterbi import _initial_pm
from repro.decode.spec import CodecSpec
from repro.serve.kv_cache import SlotAllocator
from repro.stream import window as _w


@dataclasses.dataclass
class _Stream:
    """Per-stream bookkeeping (host side; the table itself lives in the
    device arena once the stream is admitted)."""

    stream_id: str
    bm: Optional[np.ndarray]  # (T, ·) input rows; dropped at admission
    terminated: bool
    n_steps: int = 0  # total trellis steps in the stream
    arena_start: int = 0  # arena row of stream step 0 (valid once admitted)
    pos: int = 0  # steps fed to the kernel
    committed: int = 0  # bits already emitted
    out: List[np.ndarray] = dataclasses.field(default_factory=list)

    @property
    def remaining(self) -> int:
        return self.n_steps - self.pos


@dataclasses.dataclass
class SchedulerStats:
    ticks: int = 0
    streams_submitted: int = 0
    streams_finished: int = 0
    slot_claims: int = 0
    steps_decoded: int = 0  # trellis steps through the batched kernel (incl. idle slots)
    arena_compactions: int = 0

    def asdict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class StreamScheduler:
    """Continuous batching of independent Viterbi streams.

    Args:
      spec: CodecSpec shared by all streams (a bare ConvCode is promoted);
        its ``terminated`` flag is the per-stream default for ``submit``.
      n_slots: decode-block batch size (compile-once; streams beyond this
        queue FIFO until a slot frees).
      chunk: trellis steps per tick per slot.
      depth: truncated-traceback depth (default 5*K; rounded up to a
        multiple of 32 for the packed backend).
      backend: 'fused' | 'fused_packed' | 'scan' forward pass for the hot
        loop ('fused_packed': bit-packed survivor ring + Pallas traceback).
      inputs: 'bm' — submit takes (T, M) branch-metric tables; 'received'
        (fused_packed only) — submit takes raw (T, n_out) channel symbols
        and branch metrics are computed in-kernel.

    Usage:
      sched.submit("tv-0", bm_tables)      # (T, M) per stream
      while sched.pending_work():
          emitted = sched.step()           # {stream_id: np bits} this tick
      bits, metric = sched.result("tv-0")
    """

    def __init__(
        self,
        spec: Union[CodecSpec, ConvCode],
        n_slots: int = 64,
        chunk: int = 64,
        depth: Optional[int] = None,
        backend: str = "fused",
        normalize: bool = True,
        interpret: Optional[bool] = None,
        inputs: str = "bm",
    ):
        self.spec = CodecSpec.of(spec)
        code = self.spec.code
        self.code = code
        self.n_slots = n_slots
        self.chunk = chunk
        self.depth = _w.default_depth(code) if depth is None else depth
        self.backend = backend
        self.inputs = inputs
        self.packed, self.depth, self._plan, self._weights = _w.resolve_stream_backend(
            self.spec, chunk, self.depth, backend, inputs
        )
        self._width = (
            self._plan.n_features if inputs == "received" else code.n_symbols
        )
        self.state = _w.init_stream_state(
            code, n_slots, self.depth, chunk, packed=self.packed
        )
        self.offset = jnp.zeros((n_slots,), dtype=jnp.float32)
        self.alloc = SlotAllocator(n_slots)
        self.active: Dict[int, _Stream] = {}
        self.pending: Deque[_Stream] = deque()
        self.results: Dict[str, Tuple[np.ndarray, float]] = {}
        self.stats = SchedulerStats()
        self._pm0_row = _initial_pm(code, ())  # (S,) fresh-slot path metrics
        self._interpret = interpret
        self._step_fn = _w.jitted_stream_step(
            code, backend=backend, normalize=normalize, interpret=interpret
        )
        # device-resident input arena: rows [0, chunk) are zeros — the read
        # target for idle slots — and each admitted stream appends its rows.
        # Capacity grows geometrically (so the jitted gather sees a handful
        # of shapes over a server's life, not one per admission) and the
        # used prefix is compacted when retired rows exceed _compact_ratio x
        # the live rows (past _compact_floor, so toy workloads never bother).
        self._arena = jnp.zeros((chunk, self._width), dtype=jnp.float32)
        self._arena_len = chunk  # used rows; rows beyond stay zero
        self._compact_ratio = 4
        self._compact_floor = 4096
        self._gather = jax.jit(
            lambda arena, offs: jnp.take(
                arena, offs[:, None] + jnp.arange(chunk)[None, :], axis=0
            )
        )

    # ------------------------------ intake ------------------------------ #

    def submit(self, stream_id: str, bm_tables, terminated: Optional[bool] = None) -> None:
        """Queue a stream.  bm_tables: (T, M) branch metrics — or raw
        (T, n_out) received symbols for ``inputs='received'``.
        ``terminated`` defaults to the scheduler spec's flag."""
        if terminated is None:
            terminated = self.spec.terminated
        bm = np.asarray(bm_tables, dtype=np.float32)
        expected = self.code.n_out if self.inputs == "received" else self.code.n_symbols
        kind = "received symbols" if self.inputs == "received" else "bm tables"
        if bm.ndim != 2 or bm.shape[1] != expected:
            raise ValueError(
                f"{self.inputs!r} streams take {kind} shaped (T, {expected}), "
                f"got {bm.shape}"
            )
        if stream_id in self.results or any(
            s.stream_id == stream_id for s in list(self.active.values()) + list(self.pending)
        ):
            raise KeyError(f"duplicate stream_id {stream_id!r}")
        self.pending.append(_Stream(stream_id, bm, terminated, n_steps=bm.shape[0]))
        self.stats.streams_submitted += 1
        self._admit()

    def evict(self, stream_id: str) -> Optional[np.ndarray]:
        """Cancel a stream.  Returns the bits committed so far (or None if it
        was still pending); the slot is recycled immediately."""
        for i, s in enumerate(self.pending):
            if s.stream_id == stream_id:
                del self.pending[i]
                return None
        for slot, s in self.active.items():
            if s.stream_id == stream_id:
                partial = self._collect(s)
                del self.active[slot]
                self.alloc.release(slot)  # state is re-initialized at next claim
                self._admit()
                return partial
        raise KeyError(stream_id)

    # ------------------------------ ticking ------------------------------ #

    def pending_work(self) -> bool:
        return bool(self.active or self.pending)

    def step(self) -> Dict[str, np.ndarray]:
        """One scheduler tick: retire drained streams, admit pending ones,
        then advance every live slot ``chunk`` steps through ONE jitted call.
        Returns the bits each stream newly committed this tick."""
        # 1. retire streams that cannot fill a full chunk (tail + flush run
        #    batched over all slots retiring this tick — off the hot path),
        #    re-admit, and repeat: an admitted pending stream may itself be
        #    shorter than a chunk and must retire before the gather sees it.
        self._admit()
        while True:
            drained = [s for s, st in self.active.items() if st.remaining < self.chunk]
            if not drained:
                break
            self._finish_slots(drained)
            self._admit()
        if not self.active:
            return {}

        # 2. gather the decode block from the device arena by slot offset;
        #    idle slots read the zero rows (harmless: a slot's state is
        #    re-initialized when a stream claims it).
        offs = np.zeros((self.n_slots,), dtype=np.int32)
        for slot, st in self.active.items():
            offs[slot] = st.arena_start + st.pos
        block = self._gather(self._arena, jnp.asarray(offs))  # (n_slots, chunk, ·)

        # 3. the one jitted call for all live streams.
        if self.packed:
            self.state, bits, delta = self._step_fn(self.state, block, self._weights)
        else:
            self.state, bits, delta = self._step_fn(self.state, block)
        self.offset = self.offset + delta
        bits_np = np.asarray(bits)
        self.stats.ticks += 1
        self.stats.steps_decoded += self.n_slots * self.chunk

        # 4. distribute newly-final bits.
        emitted: Dict[str, np.ndarray] = {}
        for slot, st in self.active.items():
            st.pos += self.chunk
            committable = max(0, st.pos - self.depth)
            n_new = committable - st.committed
            st.committed = committable
            if n_new:
                fresh = bits_np[slot, self.chunk - n_new :]
                st.out.append(fresh)
                emitted[st.stream_id] = fresh
        return emitted

    def run(self) -> Dict[str, Tuple[np.ndarray, float]]:
        """Drain everything; returns {stream_id: (bits (T,), metric)}."""
        while self.pending_work():
            self.step()
        return self.results

    def result(self, stream_id: str) -> Tuple[np.ndarray, float]:
        return self.results[stream_id]

    def pop_result(self, stream_id: str) -> Tuple[np.ndarray, float]:
        """result() + drop — long-lived servers must use this (or otherwise
        prune ``results``) so finished-stream outputs don't accumulate
        forever."""
        return self.results.pop(stream_id)

    def utilization(self) -> float:
        return self.alloc.utilization()

    # ------------------------------ internals ------------------------------ #

    def _admit(self) -> None:
        while self.pending and self.alloc.free:
            st = self.pending.popleft()
            slot = self.alloc.claim(st.stream_id)
            # reset at CLAIM time, not release time: free slots keep being
            # advanced with zero branch metrics every tick, which would
            # otherwise erase the start-in-state-0 constraint (paper §IV-B)
            # for the next stream.
            self._reset_slot(slot)
            # move the stream's input rows into the device arena (features
            # are built once here — phase 0 is the stream start, so any
            # later window of them is correctly puncture-phased).
            rows = jnp.asarray(st.bm)
            if self.inputs == "received":
                rows = self._plan.features(rows, t0=0)
            st.arena_start = self._append_rows(rows)
            st.bm = None
            self.active[slot] = st
            self.stats.slot_claims += 1
        self._maybe_compact()

    def _append_rows(self, rows: jnp.ndarray) -> int:
        """Write rows into the arena's used prefix, doubling capacity as
        needed; returns the start row."""
        start = self._arena_len
        need = start + rows.shape[0]
        cap = self._arena.shape[0]
        if need > cap:
            new_cap = max(2 * cap, need)
            self._arena = jnp.concatenate(
                [self._arena, jnp.zeros((new_cap - cap, self._width), jnp.float32)]
            )
        self._arena = jax.lax.dynamic_update_slice(
            self._arena, rows.astype(jnp.float32), (start, 0)
        )
        self._arena_len = need
        return start

    def _maybe_compact(self) -> None:
        """Rebuild the arena's used prefix from the live segments when
        retired rows dominate it (off the hot path; keeps long-lived servers
        bounded).  Capacity is kept when the live rows fit, so the gather's
        compiled shape survives the compaction."""
        live = sum(st.remaining for st in self.active.values()) + sum(
            st.n_steps for st in self.pending
        )
        if self._arena_len <= max(
            self._compact_ratio * (live + self.chunk), self._compact_floor
        ):
            return
        parts = [jnp.zeros((self.chunk, self._width), dtype=jnp.float32)]
        cursor = self.chunk
        for st in self.active.values():
            seg = self._arena[st.arena_start + st.pos : st.arena_start + st.n_steps]
            # keep arena_start meaning "row of stream step 0"
            st.arena_start = cursor - st.pos
            parts.append(seg)
            cursor += seg.shape[0]
        cap = self._arena.shape[0]
        parts.append(jnp.zeros((max(cap - cursor, 0), self._width), jnp.float32))
        self._arena = jnp.concatenate(parts, axis=0)
        self._arena_len = cursor
        self.stats.arena_compactions += 1

    def _collect(self, st: _Stream) -> np.ndarray:
        return (
            np.concatenate(st.out) if st.out else np.zeros((0,), dtype=np.int32)
        ).astype(np.int32)

    def _reset_slot(self, slot: int) -> None:
        self.state = _w.StreamState(
            pm=self.state.pm.at[slot].set(self._pm0_row),
            ring=self.state.ring.at[:, slot].set(0),
        )
        self.offset = self.offset.at[slot].set(0.0)

    def _tail_rows(self, st: _Stream) -> jnp.ndarray:
        """(r, M) bm tables for a stream's remaining odd tail, sliced from
        the device arena (raw features go through the metric plan)."""
        seg = self._arena[st.arena_start + st.pos : st.arena_start + st.n_steps]
        if self.inputs == "received":
            return self._plan.bm_from_features(seg)
        return seg

    def _finish_slots(self, slots: Sequence[int]) -> None:
        """Tail-feed + final traceback for every drained stream retiring this
        tick, then recycle the slots.  Tails are fed grouped by length (one
        jitted_chunk_forward per distinct tail length) and the final
        traceback over all retirees runs as ONE batched jitted_stream_flush
        per termination kind — not one dispatch per slot.  Every batched call
        is padded to ``n_slots`` rows so cohort size never creates a new
        compiled shape (padded rows decode garbage that is sliced away).
        Packed survivor rings are unpacked here, once, off the hot path."""
        streams = [(slot, self.active.pop(slot)) for slot in slots]

        def pad_rows(x: jnp.ndarray, axis: int) -> jnp.ndarray:
            extra = self.n_slots - x.shape[axis]
            if extra <= 0:
                return x
            widths = [(0, 0)] * x.ndim
            widths[axis] = (0, extra)
            return jnp.pad(x, widths)

        ring = self.state.ring
        if self.packed:
            ring = _w.unpack_ring(self.code, ring)  # (R, n_slots, S)

        # tail-feed, grouped by tail length r (each group one batched call)
        by_r: Dict[int, List[Tuple[int, _Stream]]] = {}
        for slot, st in streams:
            by_r.setdefault(st.remaining, []).append((slot, st))
        ordered: List[Tuple[int, _Stream]] = []
        pm_parts: List[jnp.ndarray] = []
        ring_parts: List[jnp.ndarray] = []
        for r, group in sorted(by_r.items()):
            n = len(group)
            idx = jnp.asarray([slot for slot, _ in group])
            pm_g = self.state.pm[idx]  # (n, S)
            ring_g = ring[:, idx]  # (R, n, S)
            if r > 0:
                tails = pad_rows(
                    jnp.stack([self._tail_rows(st) for _, st in group]), 0
                )  # (n_slots, r, M)
                pm_p, bps = _w.jitted_chunk_forward(self.code)(
                    pad_rows(pm_g, 0), tails
                )
                pm_g = pm_p[:n]
                ring_g = jnp.concatenate([ring_g[r:], bps[:, :n]], axis=0)
                for _, st in group:
                    st.pos += r
            ordered.extend(group)
            pm_parts.append(pm_g)
            ring_parts.append(ring_g)
        pm_all = jnp.concatenate(pm_parts, axis=0)  # (n_total, S)
        ring_all = jnp.concatenate(ring_parts, axis=1)  # (R, n_total, S)

        # one flush per termination kind (a single call in the common case
        # of uniformly-terminated streams)
        flushed: Dict[int, Tuple[np.ndarray, float]] = {}
        for term in (True, False):
            rows = [i for i, (_, st) in enumerate(ordered) if st.terminated == term]
            if not rows:
                continue
            sel = jnp.asarray(rows)
            bits, metric = _w.jitted_stream_flush(
                self.code, terminated=term, interpret=self._interpret
            )(
                _w.StreamState(
                    pm=pad_rows(pm_all[sel], 0), ring=pad_rows(ring_all[:, sel], 1)
                )
            )
            bits_np, metric_np = np.asarray(bits), np.asarray(metric)
            for k, i in enumerate(rows):
                flushed[i] = (bits_np[k], float(metric_np[k]))

        R = ring.shape[0]
        for i, (slot, st) in enumerate(ordered):
            bits_i, metric_i = flushed[i]
            n_rest = st.pos - st.committed
            if n_rest:
                st.out.append(bits_i[R - n_rest :])
            st.committed = st.pos
            self.results[st.stream_id] = (
                self._collect(st), metric_i + float(self.offset[slot])
            )
            self.stats.streams_finished += 1
            self.alloc.release(slot)  # state is re-initialized at next claim
