"""Continuous-batching stream scheduler.

Thousands of independent broadcast streams, one jitted Pallas call: every
live stream is pinned to a slot of a fixed (n_slots, chunk) decode block —
the same compile-once bucket discipline as serve/kv_cache.py — and each
``step()`` tick advances ALL slots through one batched stream_step.  Streams
join when a slot frees (FIFO admission), leave when their input drains (the
tail + final traceback run per-slot, off the hot path), and their slot is
recycled for the next pending stream: classic continuous batching, applied
to trellis decode instead of token decode.

Per-stream input queues are **device-resident**: at admission a stream's
remaining table is appended to one device arena, and each tick gathers the
(n_slots, chunk, ·) decode block by slot offset in a single jitted take —
no host-side numpy packing or per-tick H2D copy on the hot path (the arena
is compacted off the hot path when retired segments dominate it).

The per-slot python bookkeeping (positions, commit counts) mirrors
StreamSession; the batched StreamState lives in one pytree so the hot loop
is a single dispatch regardless of how many streams are in flight.  With
``backend="fused_packed"`` the ring holds bit-packed survivor words and the
per-tick traceback runs in the Pallas traceback kernel; with
``inputs="received"`` the arena holds raw channel symbols (features) and
branch metrics are computed in-kernel.

**Sharding.**  Given ``mesh=``, ONE scheduler spans every device on the
``data`` mesh axis: the slot table is partitioned into contiguous
slots-per-shard blocks (slot → shard ``slot // slots_per_shard``), and the
input arena, path metrics, and survivor ring are laid out per shard
(arena ``(n_shards, cap, ·)``, pm ``P(data, None)``, ring
``P(None, data, None)``).  The per-tick gather + forward + traceback runs
under one shard_map with NO cross-shard communication — slots are
independent streams — while admission, eviction, and flush bookkeeping stay
host-side over global slot ids; the few mesh-global scalars (utilization,
pending work) reduce through parallel.collectives.sum_across_shards.
Decode results are bit-exact with the single-device scheduler: each slot
sees the same inputs in the same order regardless of which shard hosts it.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trellis import ConvCode
from repro.core.viterbi import _initial_pm
from repro.decode.spec import CodecSpec
from repro.serve.kv_cache import SlotAllocator
from repro.stream import window as _w


@dataclasses.dataclass
class _Stream:
    """Per-stream bookkeeping (host side; the table itself lives in the
    device arena once the stream is admitted)."""

    stream_id: str
    bm: Optional[np.ndarray]  # (T, ·) input rows; dropped at admission
    terminated: bool
    n_steps: int = 0  # total trellis steps in the stream
    arena_start: int = 0  # shard-local arena row of stream step 0 (once admitted)
    shard: int = 0  # mesh shard hosting the stream's slot (0 unsharded)
    pos: int = 0  # steps fed to the kernel
    committed: int = 0  # bits already emitted
    out: List[np.ndarray] = dataclasses.field(default_factory=list)

    @property
    def remaining(self) -> int:
        return self.n_steps - self.pos


@dataclasses.dataclass
class SchedulerStats:
    ticks: int = 0
    streams_submitted: int = 0
    streams_finished: int = 0
    slot_claims: int = 0
    steps_decoded: int = 0  # trellis steps through the batched kernel (incl. idle slots)
    arena_compactions: int = 0

    def asdict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class StreamScheduler:
    """Continuous batching of independent Viterbi streams.

    Args:
      spec: CodecSpec shared by all streams (a bare ConvCode is promoted);
        its ``terminated`` flag is the per-stream default for ``submit``.
      n_slots: decode-block batch size (compile-once; streams beyond this
        queue FIFO until a slot frees).
      chunk: trellis steps per tick per slot.
      depth: truncated-traceback depth (default 5*K; rounded up to a
        multiple of 32 for the packed backend).
      backend: 'fused' | 'fused_packed' | 'scan' forward pass for the hot
        loop ('fused_packed': bit-packed survivor ring + Pallas traceback).
      inputs: 'bm' — submit takes (T, M) branch-metric tables; 'received'
        (fused_packed only) — submit takes raw (T, n_out) channel symbols
        and branch metrics are computed in-kernel.
      mesh: optional device mesh — shard the slot table, input arena, and
        survivor ring along ``mesh_axis`` so one scheduler spans all devices
        on that axis (n_slots must divide evenly; decode results stay
        bit-exact with the unsharded scheduler).
      mesh_axis: mesh axis the slots are partitioned over (default 'data').

    Usage:
      sched.submit("tv-0", bm_tables)      # (T, M) per stream
      while sched.pending_work():
          emitted = sched.step()           # {stream_id: np bits} this tick
      bits, metric = sched.result("tv-0")
    """

    def __init__(
        self,
        spec: Union[CodecSpec, ConvCode],
        n_slots: int = 64,
        chunk: int = 64,
        depth: Optional[int] = None,
        backend: str = "fused",
        normalize: bool = True,
        interpret: Optional[bool] = None,
        inputs: str = "bm",
        mesh: Optional[object] = None,
        mesh_axis: str = "data",
    ):
        self.spec = CodecSpec.of(spec)
        code = self.spec.code
        self.code = code
        self.n_slots = n_slots
        self.chunk = chunk
        self.depth = _w.default_depth(code) if depth is None else depth
        self.backend = backend
        self.inputs = inputs
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        if mesh is not None:
            from repro.parallel.collectives import mesh_axis_size

            self.n_shards = mesh_axis_size(mesh, mesh_axis)
            if not self.n_shards:
                raise ValueError(f"mesh has no {mesh_axis!r} axis: {mesh}")
            if n_slots % self.n_shards:
                raise ValueError(
                    f"n_slots={n_slots} must divide evenly over the "
                    f"{self.n_shards} shards of mesh axis {mesh_axis!r}"
                )
        else:
            self.n_shards = 1
        self.slots_per_shard = n_slots // self.n_shards
        self.packed, self.depth, self._plan, self._weights = _w.resolve_stream_backend(
            self.spec, chunk, self.depth, backend, inputs
        )
        self._width = (
            self._plan.n_features if inputs == "received" else code.n_symbols
        )
        self.state = _w.init_stream_state(
            code, n_slots, self.depth, chunk, packed=self.packed
        )
        self.offset = jnp.zeros((n_slots,), dtype=jnp.float32)
        self.alloc = SlotAllocator(n_slots)
        self.active: Dict[int, _Stream] = {}
        self.pending: Deque[_Stream] = deque()
        self.results: Dict[str, Tuple[np.ndarray, float]] = {}
        self.stats = SchedulerStats()
        self._pm0_row = _initial_pm(code, ())  # (S,) fresh-slot path metrics
        self._interpret = interpret
        # device-resident input arena, laid out per shard: (n_shards, cap, ·)
        # with rows [0, chunk) of every shard kept zero — the read target for
        # idle slots — and each admitted stream appended to the slab of the
        # shard hosting its slot.  Capacity grows geometrically (so the
        # jitted gather sees a handful of shapes over a server's life, not
        # one per admission) and the used prefixes are compacted when retired
        # rows exceed _compact_ratio x the live rows (past _compact_floor,
        # so toy workloads never bother).
        self._arena = jnp.zeros((self.n_shards, chunk, self._width), jnp.float32)
        self._arena_len = [chunk] * self.n_shards  # used rows per shard
        self._compact_ratio = 4
        self._compact_floor = 4096
        if mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            self._arena_sharding = NamedSharding(mesh, P(mesh_axis, None, None))
            self.state = _w.shard_stream_state(mesh, mesh_axis, self.state)
            self._arena = jax.device_put(self._arena, self._arena_sharding)
            self._step_fn = None  # sharded tick replaces the plain jitted step
            self._sharded_step = _w.make_sharded_stream_step(
                code, mesh, mesh_axis, chunk=chunk, backend=backend,
                normalize=normalize, interpret=interpret,
                weights=self._weights,
            )
        else:
            self._arena_sharding = None
            self._sharded_step = None
            self._step_fn = _w.jitted_stream_step(
                code, backend=backend, normalize=normalize, interpret=interpret
            )
        self._gather = jax.jit(
            lambda arena, offs: jnp.take(
                arena[0], offs[:, None] + jnp.arange(chunk)[None, :], axis=0
            )
        )

    # ------------------------------ intake ------------------------------ #

    def submit(self, stream_id: str, bm_tables, terminated: Optional[bool] = None) -> None:
        """Queue a stream.  bm_tables: (T, M) branch metrics — or raw
        (T, n_out) received symbols for ``inputs='received'``.
        ``terminated`` defaults to the scheduler spec's flag."""
        if terminated is None:
            terminated = self.spec.terminated
        bm = np.asarray(bm_tables, dtype=np.float32)
        expected = self.code.n_out if self.inputs == "received" else self.code.n_symbols
        kind = "received symbols" if self.inputs == "received" else "bm tables"
        if bm.ndim != 2 or bm.shape[1] != expected:
            raise ValueError(
                f"{self.inputs!r} streams take {kind} shaped (T, {expected}), "
                f"got {bm.shape}"
            )
        if stream_id in self.results or any(
            s.stream_id == stream_id for s in list(self.active.values()) + list(self.pending)
        ):
            raise KeyError(f"duplicate stream_id {stream_id!r}")
        self.pending.append(_Stream(stream_id, bm, terminated, n_steps=bm.shape[0]))
        self.stats.streams_submitted += 1
        self._admit()

    def evict(self, stream_id: str) -> Optional[np.ndarray]:
        """Cancel a stream.  Returns the bits committed so far (or None if it
        was still pending); the slot is recycled immediately."""
        for i, s in enumerate(self.pending):
            if s.stream_id == stream_id:
                del self.pending[i]
                return None
        for slot, s in self.active.items():
            if s.stream_id == stream_id:
                partial = self._collect(s)
                del self.active[slot]
                self.alloc.release(slot)  # state is re-initialized at next claim
                self._admit()
                return partial
        raise KeyError(stream_id)

    # ------------------------------ ticking ------------------------------ #

    def pending_work(self) -> bool:
        return bool(self.active or self.pending)

    def step(self) -> Dict[str, np.ndarray]:
        """One scheduler tick: retire drained streams, admit pending ones,
        then advance every live slot ``chunk`` steps through ONE jitted call.
        Returns the bits each stream newly committed this tick."""
        # 1. retire streams that cannot fill a full chunk (tail + flush run
        #    batched over all slots retiring this tick — off the hot path),
        #    re-admit, and repeat: an admitted pending stream may itself be
        #    shorter than a chunk and must retire before the gather sees it.
        self._admit()
        while True:
            drained = [s for s, st in self.active.items() if st.remaining < self.chunk]
            if not drained:
                break
            self._finish_slots(drained)
            self._admit()
        if not self.active:
            return {}

        # 2. gather the decode block from the device arena by (shard-local)
        #    slot offset; idle slots read the zero rows (harmless: a slot's
        #    state is re-initialized when a stream claims it).
        offs = np.zeros((self.n_slots,), dtype=np.int32)
        for slot, st in self.active.items():
            offs[slot] = st.arena_start + st.pos

        # 3. the one jitted call for all live streams — under shard_map when
        #    the scheduler spans a mesh (gather + step fused, shard-local).
        if self._sharded_step is not None:
            self.state, bits, delta = self._sharded_step(
                self._arena, jnp.asarray(offs), self.state
            )
        else:
            block = self._gather(self._arena, jnp.asarray(offs))  # (n_slots, chunk, ·)
            if self.packed:
                self.state, bits, delta = self._step_fn(self.state, block, self._weights)
            else:
                self.state, bits, delta = self._step_fn(self.state, block)
        self.offset = self.offset + delta
        bits_np = np.asarray(bits)
        self.stats.ticks += 1
        self.stats.steps_decoded += self.n_slots * self.chunk

        # 4. distribute newly-final bits.
        emitted: Dict[str, np.ndarray] = {}
        for slot, st in self.active.items():
            st.pos += self.chunk
            committable = max(0, st.pos - self.depth)
            n_new = committable - st.committed
            st.committed = committable
            if n_new:
                fresh = bits_np[slot, self.chunk - n_new :]
                st.out.append(fresh)
                emitted[st.stream_id] = fresh
        return emitted

    def run(self) -> Dict[str, Tuple[np.ndarray, float]]:
        """Drain everything; returns {stream_id: (bits (T,), metric)}."""
        while self.pending_work():
            self.step()
        return self.results

    def result(self, stream_id: str) -> Tuple[np.ndarray, float]:
        return self.results[stream_id]

    def pop_result(self, stream_id: str) -> Tuple[np.ndarray, float]:
        """result() + drop — long-lived servers must use this (or otherwise
        prune ``results``) so finished-stream outputs don't accumulate
        forever."""
        return self.results.pop(stream_id)

    def utilization(self) -> float:
        return self.alloc.utilization()

    def load_report(self) -> Dict[str, object]:
        """Occupancy per shard plus the mesh-global scalars.  The per-shard
        counts come from this controller's bookkeeping; the totals reduce
        through parallel.collectives.sum_across_shards — the same psum a
        multi-controller deployment (one host per shard) would issue, so the
        global view never gathers any decode state."""
        per_shard = np.zeros((self.n_shards,), dtype=np.int32)
        for slot in self.active:
            per_shard[slot // self.slots_per_shard] += 1
        per_shard_pending = np.zeros((self.n_shards,), dtype=np.int32)
        per_shard_pending[0] = len(self.pending)  # FIFO queue lives host-side
        if self.mesh is not None:
            from repro.parallel.collectives import sum_across_shards

            totals = sum_across_shards(
                self.mesh, self.mesh_axis,
                jnp.stack([jnp.asarray(per_shard), jnp.asarray(per_shard_pending)], 1),
            )
            active_total, pending_total = (int(x) for x in np.asarray(totals))
        else:
            active_total, pending_total = int(per_shard.sum()), len(self.pending)
        return {
            "n_shards": self.n_shards,
            "per_shard_active": per_shard.tolist(),
            "active_total": active_total,
            "pending_total": pending_total,
            "utilization": active_total / self.n_slots,
        }

    # ------------------------------ internals ------------------------------ #

    def _shard_of(self, slot: int) -> int:
        return slot // self.slots_per_shard

    def _pin_arena(self) -> None:
        """Re-assert the per-shard arena placement after an eager mutation
        (admission append, growth, compaction — all off the hot path)."""
        if self._arena_sharding is not None:
            self._arena = jax.device_put(self._arena, self._arena_sharding)

    def _pin_state(self) -> None:
        if self.mesh is not None:
            self.state = _w.shard_stream_state(self.mesh, self.mesh_axis, self.state)

    def _admit(self) -> None:
        while self.pending and self.alloc.free:
            st = self.pending.popleft()
            slot = self.alloc.claim(st.stream_id)
            # reset at CLAIM time, not release time: free slots keep being
            # advanced with zero branch metrics every tick, which would
            # otherwise erase the start-in-state-0 constraint (paper §IV-B)
            # for the next stream.
            self._reset_slot(slot)
            # move the stream's input rows into the arena slab of the shard
            # hosting its slot (features are built once here — phase 0 is
            # the stream start, so any later window of them is correctly
            # puncture-phased).
            rows = jnp.asarray(st.bm)
            if self.inputs == "received":
                rows = self._plan.features(rows, t0=0)
            st.shard = self._shard_of(slot)
            st.arena_start = self._append_rows(st.shard, rows)
            st.bm = None
            self.active[slot] = st
            self.stats.slot_claims += 1
        self._maybe_compact()

    def _append_rows(self, shard: int, rows: jnp.ndarray) -> int:
        """Write rows into a shard's used prefix, doubling the (uniform)
        capacity as needed; returns the shard-local start row."""
        start = self._arena_len[shard]
        need = start + rows.shape[0]
        cap = self._arena.shape[1]
        if need > cap:
            new_cap = max(2 * cap, need)
            self._arena = jnp.concatenate(
                [
                    self._arena,
                    jnp.zeros((self.n_shards, new_cap - cap, self._width), jnp.float32),
                ],
                axis=1,
            )
        self._arena = jax.lax.dynamic_update_slice(
            self._arena, rows.astype(jnp.float32)[None], (shard, start, 0)
        )
        self._arena_len[shard] = need
        self._pin_arena()
        return start

    def _maybe_compact(self) -> None:
        """Rebuild every shard's used prefix from its live segments when
        retired rows dominate the arena (off the hot path; keeps long-lived
        servers bounded).  Capacity is kept when the live rows fit, so the
        tick's compiled shape survives the compaction."""
        live = sum(st.remaining for st in self.active.values()) + sum(
            st.n_steps for st in self.pending
        )
        if sum(self._arena_len) <= max(
            self._compact_ratio * (live + self.n_shards * self.chunk),
            self._compact_floor,
        ):
            return
        by_shard: Dict[int, List[_Stream]] = {}
        for st in self.active.values():
            by_shard.setdefault(st.shard, []).append(st)
        cap = self._arena.shape[1]
        slabs = []
        for shard in range(self.n_shards):
            parts = [jnp.zeros((self.chunk, self._width), dtype=jnp.float32)]
            cursor = self.chunk
            for st in by_shard.get(shard, ()):
                seg = self._arena[
                    shard, st.arena_start + st.pos : st.arena_start + st.n_steps
                ]
                # keep arena_start meaning "row of stream step 0"
                st.arena_start = cursor - st.pos
                parts.append(seg)
                cursor += seg.shape[0]
            parts.append(jnp.zeros((max(cap - cursor, 0), self._width), jnp.float32))
            slabs.append(jnp.concatenate(parts, axis=0))
            self._arena_len[shard] = cursor
        self._arena = jnp.stack(slabs, axis=0)
        self._pin_arena()
        self.stats.arena_compactions += 1

    def _collect(self, st: _Stream) -> np.ndarray:
        return (
            np.concatenate(st.out) if st.out else np.zeros((0,), dtype=np.int32)
        ).astype(np.int32)

    def _reset_slot(self, slot: int) -> None:
        self.state = _w.StreamState(
            pm=self.state.pm.at[slot].set(self._pm0_row),
            ring=self.state.ring.at[:, slot].set(0),
        )
        self._pin_state()
        self.offset = self.offset.at[slot].set(0.0)

    def _tail_rows(self, st: _Stream) -> jnp.ndarray:
        """(r, M) bm tables for a stream's remaining odd tail, sliced from
        its shard's arena slab (raw features go through the metric plan)."""
        seg = self._arena[st.shard, st.arena_start + st.pos : st.arena_start + st.n_steps]
        if self.inputs == "received":
            return self._plan.bm_from_features(seg)
        return seg

    def _finish_slots(self, slots: Sequence[int]) -> None:
        """Tail-feed + final traceback for every drained stream retiring this
        tick, then recycle the slots.  Tails are fed grouped by length (one
        jitted_chunk_forward per distinct tail length) and the final
        traceback over all retirees runs as ONE batched jitted_stream_flush
        per termination kind — not one dispatch per slot.  Every batched call
        is padded to ``n_slots`` rows so cohort size never creates a new
        compiled shape (padded rows decode garbage that is sliced away).
        Packed survivor rings are unpacked here, once, off the hot path."""
        streams = [(slot, self.active.pop(slot)) for slot in slots]

        def pad_rows(x: jnp.ndarray, axis: int) -> jnp.ndarray:
            extra = self.n_slots - x.shape[axis]
            if extra <= 0:
                return x
            widths = [(0, 0)] * x.ndim
            widths[axis] = (0, extra)
            return jnp.pad(x, widths)

        # the flush math below slices slot subsets with fancy indexing; on a
        # sharded state every such op would become its own cross-shard
        # gather, so materialize the retiring cohort's state onto one device
        # first (off the hot path, and the tick state itself is untouched).
        pm_frontier = self.state.pm
        ring = self.state.ring
        if self.mesh is not None:
            pm_frontier = jnp.asarray(np.asarray(pm_frontier))
            ring = jnp.asarray(np.asarray(ring))
        if self.packed:
            ring = _w.unpack_ring(self.code, ring)  # (R, n_slots, S)

        # tail-feed, grouped by tail length r (each group one batched call)
        by_r: Dict[int, List[Tuple[int, _Stream]]] = {}
        for slot, st in streams:
            by_r.setdefault(st.remaining, []).append((slot, st))
        ordered: List[Tuple[int, _Stream]] = []
        pm_parts: List[jnp.ndarray] = []
        ring_parts: List[jnp.ndarray] = []
        for r, group in sorted(by_r.items()):
            n = len(group)
            idx = jnp.asarray([slot for slot, _ in group])
            pm_g = pm_frontier[idx]  # (n, S)
            ring_g = ring[:, idx]  # (R, n, S)
            if r > 0:
                tails = pad_rows(
                    jnp.stack([self._tail_rows(st) for _, st in group]), 0
                )  # (n_slots, r, M)
                pm_p, bps = _w.jitted_chunk_forward(self.code)(
                    pad_rows(pm_g, 0), tails
                )
                pm_g = pm_p[:n]
                ring_g = jnp.concatenate([ring_g[r:], bps[:, :n]], axis=0)
                for _, st in group:
                    st.pos += r
            ordered.extend(group)
            pm_parts.append(pm_g)
            ring_parts.append(ring_g)
        pm_all = jnp.concatenate(pm_parts, axis=0)  # (n_total, S)
        ring_all = jnp.concatenate(ring_parts, axis=1)  # (R, n_total, S)

        # one flush per termination kind (a single call in the common case
        # of uniformly-terminated streams)
        flushed: Dict[int, Tuple[np.ndarray, float]] = {}
        for term in (True, False):
            rows = [i for i, (_, st) in enumerate(ordered) if st.terminated == term]
            if not rows:
                continue
            sel = jnp.asarray(rows)
            bits, metric = _w.jitted_stream_flush(
                self.code, terminated=term, interpret=self._interpret
            )(
                _w.StreamState(
                    pm=pad_rows(pm_all[sel], 0), ring=pad_rows(ring_all[:, sel], 1)
                )
            )
            bits_np, metric_np = np.asarray(bits), np.asarray(metric)
            for k, i in enumerate(rows):
                flushed[i] = (bits_np[k], float(metric_np[k]))

        R = ring.shape[0]
        offset_np = np.asarray(self.offset)  # one transfer, not one per slot
        for i, (slot, st) in enumerate(ordered):
            bits_i, metric_i = flushed[i]
            n_rest = st.pos - st.committed
            if n_rest:
                st.out.append(bits_i[R - n_rest :])
            st.committed = st.pos
            self.results[st.stream_id] = (
                self._collect(st), metric_i + float(offset_np[slot])
            )
            self.stats.streams_finished += 1
            self.alloc.release(slot)  # state is re-initialized at next claim
