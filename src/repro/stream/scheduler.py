"""Continuous-batching stream scheduler with true online ingestion.

Thousands of independent broadcast streams, one jitted Pallas call: every
live stream is pinned to a slot of a fixed (n_slots, chunk) decode block —
the same compile-once bucket discipline as serve/kv_cache.py — and each
``step()`` tick advances ALL slots through one batched stream_step.  Streams
join when a slot frees (FIFO admission), leave when their input drains (the
tail + final traceback run per-slot, off the hot path), and their slot is
recycled for the next pending stream: classic continuous batching, applied
to trellis decode instead of token decode.

**Ingestion is chunk-fed.**  A caller serving live connections opens a
stream, feeds rows as they arrive, and closes it at EOF:

    sched.open_stream("uplink-7")
    while rx := conn.recv_symbols():
        while True:                     # StreamBusy accepts NOTHING — keep
            try:                        # the same rx and retry once a tick
                sched.submit_chunk("uplink-7", rx)   # rows, any size
                break                   # has drained the bounded queue
            except StreamBusy:
                emit(sched.step())
        emit(sched.step())
    sched.close("uplink-7")             # finalizes the mid-chunk tail

or attaches a ChunkProducer (generator / callable / socket-fed push buffer,
see stream/ingest.py) that the tick loop polls within the stream's credit.
Every stream has a **bounded input queue** (``max_buffered`` unconsumed
rows): ``submit_chunk`` returns the remaining credit and raises StreamBusy
on overrun, so backpressure propagates to the source instead of buffering
without bound.  ``submit(stream_id, full_table)`` survives as a thin
adapter over this one path — open, feed the whole table as a single chunk,
close — so offline and online decode share every line of ingestion code.

A slot whose stream has no full chunk ready **idles without being evicted**:
the batched kernel still runs over it (fixed shapes — that is the whole
point of the bucket discipline) but its carried pm/ring are re-selected
unchanged (``stream_step(active=...)``), because advancing a real stream
with zero branch metrics is not a no-op.  Streams that close mid-chunk
retire through the same grouped tail-feed + batched flush as before.

Per-stream input rows are **device-resident**: each accepted chunk is
appended to one device arena and every tick gathers the (n_slots, chunk, ·)
decode block by per-slot row indices in a single jitted take — no host-side
numpy packing or per-tick H2D copy of symbol data on the hot path.  Chunks
of different streams interleave in arrival order, so a stream's rows are
tracked as explicit arena row indices (not a contiguous base offset); the
arena is compacted off the hot path when retired/consumed rows dominate.

**Sharding.**  Given ``mesh=``, ONE scheduler spans every device on the
``data`` mesh axis: the slot table is partitioned into contiguous
slots-per-shard blocks (slot → shard ``slot // slots_per_shard``), and the
input arena, path metrics, and survivor ring are laid out per shard
(arena ``(n_shards, cap, ·)``, pm ``P(data, None)``, ring
``P(None, data, None)``).  The per-tick gather + forward + traceback runs
under one shard_map with NO cross-shard communication — slots are
independent streams — while admission, ingestion, and flush bookkeeping stay
host-side over global slot ids (a stream's chunks land in the slab of the
shard hosting its slot); the few mesh-global scalars (utilization, pending
work, queue depths) reduce through parallel.collectives.sum_across_shards.
Decode results are bit-exact with the single-device scheduler AND with the
offline block decode of the same symbols: arrival schedule and placement
never change what a slot's kernel sees.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trellis import ConvCode
from repro.core.viterbi import _initial_pm
from repro.decode.spec import CodecSpec
from repro.kernels.common import resolve_interpret
from repro.obs import Telemetry
from repro.obs.metrics import DEPTH_BUCKETS, LATENCY_BUCKETS_S, TICK_BUCKETS
from repro.obs.trace import span
from repro.serve.kv_cache import SlotAllocator
from repro.stream import window as _w
from repro.stream.ingest import ChunkProducer, StreamBusy, as_producer
from repro.stream.resilience import StreamError, TickFault
from repro.train.fault_tolerance import StragglerDetector

#: Tick-phase span names, in order, as they nest under the "tick" parent —
#: the children list Tracer.coverage() checks the tick against.
TICK_PHASES = ("ingest", "admit", "gather", "step", "commit")


@dataclasses.dataclass(eq=False)
class _Stream:
    """Per-stream bookkeeping (host side; the rows themselves live in the
    device arena once accepted).  ``eq=False``: streams are identities, and
    the generated __eq__ would compare ndarray fields."""

    stream_id: str
    terminated: bool
    max_buffered: int  # backpressure bound on unconsumed rows
    producer: Optional[ChunkProducer] = None
    closed: bool = False  # no more input will arrive (close() / EOF)
    slot: Optional[int] = None  # decode slot while admitted
    shard: int = 0  # mesh shard hosting the stream's slot (0 unsharded)
    priority: int = 0  # overload shedding victimizes the lowest first
    deadline_tick: Optional[int] = None  # evict_expired() retires past this
    seq: int = 0  # admission sequence (shed tie-break: newest loses)
    fed: int = 0  # rows accepted into the device arena
    pos: int = 0  # steps consumed by the kernel
    committed: int = 0  # bits already emitted
    #: shard-local arena rows holding steps [pos, fed) — explicit indices,
    #: because chunks of concurrent streams interleave in the arena.
    rows: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), dtype=np.int32)
    )
    queued: List[np.ndarray] = dataclasses.field(default_factory=list)
    queued_rows: int = 0  # raw rows awaiting admission (no shard known yet)
    out: List[np.ndarray] = dataclasses.field(default_factory=list)
    #: (cumulative_rows_after_chunk, arrival_monotonic_ts) per accepted
    #: chunk, popped as commits pass the chunk's last row — the bounded
    #: bookkeeping behind the arrival-to-commit latency histogram.
    arrivals: Deque[Tuple[int, float]] = dataclasses.field(default_factory=deque)

    @property
    def available(self) -> int:
        """Rows in the arena the kernel has not consumed yet."""
        return self.fed - self.pos

    @property
    def buffered(self) -> int:
        """Unconsumed rows anywhere (arena + pre-admission queue) — what the
        per-stream credit is charged against."""
        return self.fed - self.pos + self.queued_rows


@dataclasses.dataclass
class SchedulerStats:
    ticks: int = 0
    streams_submitted: int = 0
    streams_finished: int = 0
    slot_claims: int = 0
    steps_decoded: int = 0  # trellis steps actually consumed by streams
    arena_compactions: int = 0
    chunks_submitted: int = 0  # submit_chunk / producer deliveries accepted
    busy_rejections: int = 0  # StreamBusy raised by submit_chunk
    starved_slot_ticks: int = 0  # slot-ticks spent admitted-but-starved
    poisoned_rejections: int = 0  # chunks rejected for non-finite values
    streams_quarantined: int = 0  # streams failed by poison / producer crash
    streams_expired: int = 0  # streams retired by evict_expired (TTL)
    streams_shed: int = 0  # streams dropped by the overload policy
    tick_device_failures: int = 0  # step-phase TickFaults absorbed (retried)
    straggler_ticks: int = 0  # tick wall times flagged by StragglerDetector

    def asdict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class StreamScheduler:
    """Continuous batching of independent Viterbi streams.

    Args:
      spec: CodecSpec shared by all streams (a bare ConvCode is promoted);
        its ``terminated`` flag is the per-stream default.
      n_slots: decode-block batch size (compile-once; streams beyond this
        queue FIFO until a slot frees).
      chunk: trellis steps per tick per slot.
      depth: truncated-traceback depth (default 5*K; rounded up to a
        multiple of 32 for the packed backend).
      backend: 'fused' | 'fused_packed' | 'scan' forward pass for the hot
        loop ('fused_packed': bit-packed survivor ring + Pallas traceback).
      inputs: 'bm' — chunks are (t, M) branch-metric rows; 'received'
        (fused_packed only) — chunks are raw (t, n_out) channel symbols and
        branch metrics are computed in-kernel.
      max_buffered: default per-stream input-queue bound, in unconsumed rows
        (None -> 8 * chunk).  ``open_stream`` can override per stream.
      mesh: optional device mesh — shard the slot table, input arena, and
        survivor ring along ``mesh_axis`` so one scheduler spans all devices
        on that axis (n_slots must divide evenly; decode results stay
        bit-exact with the unsharded scheduler).
      mesh_axis: mesh axis the slots are partitioned over (default 'data').
      telemetry: obs.Telemetry bundle.  The metrics registry (always live)
        absorbs SchedulerStats plus the arrival-to-commit latency histogram;
        an attached tracer records tick-phase spans (see TICK_PHASES);
        ``device_counters=True`` makes the jitted tick accumulate per-stream
        survivor merge depth / starved ticks / renormalization magnitude
        into a device-resident buffer flushed only at retire / report time —
        the tick keeps exactly one host sync (the committed bits).

    Online usage (live connections):
      sched.open_stream("tv-0", producer=gen_of_chunks)  # or submit_chunk
      while serving:
          emitted = sched.step()           # {stream_id: np bits} this tick
      bits, metric = sched.pop_result("tv-0")

    Offline usage (whole table known) — the adapter over the same path:
      sched.submit("tv-0", bm_tables)      # == open + submit_chunk + close
      sched.run()
    """

    def __init__(
        self,
        spec: Union[CodecSpec, ConvCode],
        n_slots: int = 64,
        chunk: int = 64,
        depth: Optional[int] = None,
        backend: str = "fused",
        normalize: bool = True,
        interpret: Optional[bool] = None,
        inputs: str = "bm",
        max_buffered: Optional[int] = None,
        max_pending: Optional[int] = None,
        mesh: Optional[object] = None,
        mesh_axis: str = "data",
        telemetry: Optional[Telemetry] = None,
    ):
        self.spec = CodecSpec.of(spec)
        code = self.spec.code
        self.code = code
        self.n_slots = n_slots
        self.chunk = chunk
        self.depth = _w.default_depth(code) if depth is None else depth
        self.backend = backend
        self.normalize = normalize
        self.inputs = inputs
        if max_pending is not None and max_pending < 0:
            raise ValueError(f"max_pending must be >= 0, got {max_pending}")
        self.max_pending = max_pending
        self.max_buffered = 8 * chunk if max_buffered is None else int(max_buffered)
        if self.max_buffered < chunk:
            # rows only leave the queue in full-chunk ticks: a bound below
            # one chunk could never fill a tick and the stream would starve
            # forever with its credit pinned at zero
            raise ValueError(
                f"max_buffered ({self.max_buffered}) must be >= chunk ({chunk})"
            )
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        if mesh is not None:
            from repro.parallel.collectives import mesh_axis_size

            self.n_shards = mesh_axis_size(mesh, mesh_axis)
            if not self.n_shards:
                raise ValueError(f"mesh has no {mesh_axis!r} axis: {mesh}")
            if n_slots % self.n_shards:
                raise ValueError(
                    f"n_slots={n_slots} must divide evenly over the "
                    f"{self.n_shards} shards of mesh axis {mesh_axis!r}"
                )
        else:
            self.n_shards = 1
        self.slots_per_shard = n_slots // self.n_shards
        self.packed, self.depth, self._plan, self._weights = _w.resolve_stream_backend(
            self.spec, chunk, self.depth, backend, inputs
        )
        self._width = (
            self._plan.n_features if inputs == "received" else code.n_symbols
        )
        self.state = _w.init_stream_state(
            code, n_slots, self.depth, chunk, packed=self.packed
        )
        self.offset = jnp.zeros((n_slots,), dtype=jnp.float32)
        self.alloc = SlotAllocator(n_slots)
        self.active: Dict[int, _Stream] = {}
        self.pending: Deque[_Stream] = deque()
        self._by_id: Dict[str, _Stream] = {}  # every OPEN stream, by id
        self.results: Dict[str, Tuple[np.ndarray, float]] = {}
        self.errors: Dict[str, StreamError] = {}  # early-terminated streams
        self.stats = SchedulerStats()
        self._seq = 0  # admission sequence counter (shed tie-break)
        #: straggler detection over per-tick wall time (only ticks that
        #: dispatched real work — idle ticks would poison the EMA).
        self.straggler = StragglerDetector()
        #: test/chaos seam: called with the tick number at the top of the
        #: step phase; a raised TickFault drops the tick (state untouched).
        self.tick_fault_hook = None
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._tracer = self.telemetry.tracer
        self._latency_hist = self.telemetry.metrics.histogram(
            "stream_arrival_to_commit_seconds",
            buckets=LATENCY_BUCKETS_S,
            help="seconds from chunk arrival to its last bit committing",
        )
        self._depth_hist = self.telemetry.metrics.histogram(
            "stream_merge_depth",
            buckets=DEPTH_BUCKETS,
            help="survivor merge depth of retiring streams (trellis steps)",
        )
        self._tick_hist = self.telemetry.metrics.histogram(
            "stream_tick_seconds",
            buckets=TICK_BUCKETS,
            help="wall time of scheduler ticks that dispatched work",
        )
        self._retry_hist = self.telemetry.metrics.histogram(
            "stream_busy_retry_ticks",
            buckets=DEPTH_BUCKETS,
            help="retry_after_ticks hints handed out with StreamBusy",
        )
        m = self.telemetry.metrics
        self._straggler_ctr = m.counter(
            "stream_tick_straggler_total",
            help="ticks whose wall time the StragglerDetector flagged",
        )
        self._quarantine_ctr = m.counter(
            "stream_quarantined_total",
            help="streams quarantined (poisoned chunk / producer error)",
        )
        self._expired_ctr = m.counter(
            "stream_expired_total", help="streams retired by TTL deadline"
        )
        self._shed_ctr = m.counter(
            "stream_shed_total", help="streams dropped by the overload policy"
        )
        self._device_failure_ctr = m.counter(
            "stream_tick_device_failures_total",
            help="tick device-step failures absorbed (tick dropped + retried)",
        )
        self._poison_ctr = m.counter(
            "stream_poisoned_chunks_total",
            help="chunks rejected for non-finite values or bad shape",
        )
        self._counters = (
            _w.init_device_counters(n_slots)
            if self.telemetry.device_counters
            else None
        )
        self._pm0_row = _initial_pm(code, ())  # (S,) fresh-slot path metrics
        # interpret-mode resolution is pinned ONCE per scheduler (see
        # kernels/common.py): the forward and traceback kernels of every tick
        # and flush must run on the same code path.
        self._interpret = resolve_interpret(interpret)
        # device-resident input arena, laid out per shard: (n_shards, cap, ·)
        # with rows [0, chunk) of every shard kept zero — the read target for
        # idle/starved slots — and each accepted chunk appended to the slab
        # of the shard hosting its stream's slot.  Capacity grows
        # geometrically (so the jitted gather sees a handful of shapes over a
        # server's life, not one per chunk) and the used prefixes are
        # compacted when consumed/retired rows exceed _compact_ratio x the
        # live rows (past _compact_floor, so toy workloads never bother).
        self._arena = jnp.zeros((self.n_shards, chunk, self._width), jnp.float32)
        self._arena_len = [chunk] * self.n_shards  # used rows per shard
        self._compact_ratio = 4
        self._compact_floor = 4096
        if mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            self._arena_sharding = NamedSharding(mesh, P(mesh_axis, None, None))
            self._counter_sharding = NamedSharding(mesh, P(mesh_axis))
            self.state = _w.shard_stream_state(mesh, mesh_axis, self.state)
            self._arena = jax.device_put(self._arena, self._arena_sharding)
            self._pin_counters()
            self._step_fn = None  # sharded tick replaces the plain jitted step
            self._sharded_step = _w.make_sharded_stream_step(
                code, mesh, mesh_axis, chunk=chunk, backend=backend,
                normalize=normalize, interpret=self._interpret,
                weights=self._weights,
                device_metrics=self._counters is not None,
            )
        else:
            self._arena_sharding = None
            self._counter_sharding = None
            self._sharded_step = None
            self._step_fn = _w.jitted_stream_step(
                code, backend=backend, normalize=normalize,
                interpret=self._interpret,
            )
        self._gather = jax.jit(
            lambda arena, idx: jnp.take(arena[0], idx, axis=0)
        )

    # ------------------------------ intake ------------------------------ #

    def open_stream(
        self,
        stream_id: str,
        *,
        terminated: Optional[bool] = None,
        producer=None,
        max_buffered: Optional[int] = None,
        priority: int = 0,
        ttl_ticks: Optional[int] = None,
    ) -> None:
        """Register a stream for chunk-fed decode.  It queues for a slot
        immediately (FIFO) and may sit admitted-but-starved until rows
        arrive via ``submit_chunk`` or the attached ``producer``.

        Args:
          terminated: stream ends in state 0 (defaults to the spec's flag).
          producer: optional chunk source polled every tick within the
            stream's credit — a ChunkProducer, a generator/iterable of row
            arrays, or a poll callable (see stream/ingest.py).  When it
            reports ``exhausted`` the stream is closed automatically.
          max_buffered: per-stream override of the input-queue bound.
          priority: overload-shedding rank — when ``max_pending`` is
            exceeded the LOWEST priority open stream is shed first (newest
            among equals; see ``errors`` for the structured record).
          ttl_ticks: optional deadline, in scheduler ticks from now; once it
            passes, ``evict_expired()`` (run at the top of every tick)
            retires the stream with a partial-result flush and an "expired"
            StreamError.
        """
        if terminated is None:
            terminated = self.spec.terminated
        if stream_id in self._by_id or stream_id in self.results:
            raise KeyError(f"duplicate stream_id {stream_id!r}")
        bound = self.max_buffered if max_buffered is None else int(max_buffered)
        if bound < self.chunk:
            raise ValueError(
                f"max_buffered ({bound}) must be >= chunk ({self.chunk}): a "
                "smaller bound can never buffer a full decode chunk, so the "
                "stream would starve forever"
            )
        if ttl_ticks is not None and ttl_ticks <= 0:
            raise ValueError(f"ttl_ticks must be > 0, got {ttl_ticks}")
        st = _Stream(
            stream_id=stream_id,
            terminated=bool(terminated),
            max_buffered=bound,
            producer=as_producer(producer) if producer is not None else None,
            priority=int(priority),
            deadline_tick=(
                None if ttl_ticks is None else self.stats.ticks + int(ttl_ticks)
            ),
            seq=self._seq,
        )
        self._seq += 1
        self._by_id[stream_id] = st
        self.pending.append(st)
        self.stats.streams_submitted += 1
        self._admit()
        self._shed_overload()

    def submit_chunk(self, stream_id: str, rows, *, close: bool = False) -> int:
        """Feed ``rows`` ((t, M) bm rows or (t, n_out) received symbols per
        the scheduler's ``inputs`` kind; any t >= 0) to an open stream.

        Returns the stream's remaining credit (rows its bounded queue can
        still take).  Raises StreamBusy — accepting nothing — when the chunk
        exceeds the current credit; callers throttle and retry after ticks
        drain the queue.  ``close=True`` marks EOF after accepting the rows
        (same as a separate ``close()``)."""
        st = self._open(stream_id)
        if st.closed:
            raise RuntimeError(f"stream {stream_id!r} is closed")
        rows = np.asarray(rows, dtype=np.float32)
        self._check_rows(rows)
        n = rows.shape[0]
        if n:
            credit = st.max_buffered - st.buffered
            if n > credit:
                self.stats.busy_rejections += 1
                # hint horizon: ticks until the queue can take this chunk —
                # capped at the queue bound, since a chunk larger than
                # max_buffered must be split and can never fit whole
                retry = self._retry_after_ticks(
                    st, min(n, st.max_buffered) - max(0, credit)
                )
                self._retry_hist.observe(retry)
                raise StreamBusy(
                    stream_id, max(0, credit), n, retry_after_ticks=retry
                )
            self._accept_rows(st, rows)
            self.stats.chunks_submitted += 1
        if close:
            st.closed = True
        self._admit()
        return max(0, st.max_buffered - st.buffered)

    def attach_producer(self, stream_id: str, producer) -> None:
        """Attach (or replace) a chunk source on an open stream — the
        re-attach half of snapshot/restore, since producers are deliberately
        not serialized (see stream/resilience.py)."""
        st = self._open(stream_id)
        if st.closed:
            raise RuntimeError(f"stream {stream_id!r} is closed")
        st.producer = as_producer(producer)

    def close(self, stream_id: str) -> None:
        """Mark EOF: no more chunks will arrive.  The stream retires once its
        remaining buffered rows (including a mid-chunk tail shorter than one
        decode chunk) are drained — idempotent."""
        self._open(stream_id).closed = True

    def credit(self, stream_id: str) -> int:
        """Rows the stream's bounded input queue can accept right now."""
        st = self._open(stream_id)
        return max(0, st.max_buffered - st.buffered)

    def submit(self, stream_id: str, bm_tables, terminated: Optional[bool] = None) -> None:
        """Whole-table submission — a thin ADAPTER over the chunk path (the
        scheduler's one ingestion code path): opens the stream with enough
        credit for the full table, feeds it as a single chunk, and closes
        it.  bm_tables: (T, M) branch metrics — or raw (T, n_out) received
        symbols for ``inputs='received'``."""
        bm = np.asarray(bm_tables, dtype=np.float32)
        self._check_rows(bm)
        self.open_stream(
            stream_id,
            terminated=terminated,
            max_buffered=max(self.max_buffered, bm.shape[0]),
        )
        self.submit_chunk(stream_id, bm, close=True)

    def evict(self, stream_id: str) -> Optional[np.ndarray]:
        """Cancel a stream.  Returns the bits committed so far (or None if it
        was still awaiting a slot); the slot is recycled immediately.  Any
        attached producer is detached (its undelivered rows — pending credit
        included — are simply never polled again)."""
        st = self._by_id.pop(stream_id, None)
        if st is None:
            raise KeyError(stream_id)
        st.producer = None
        if st.slot is None:
            self.pending.remove(st)
            return None
        partial = self._collect(st)
        del self.active[st.slot]
        self.alloc.release(st.slot)  # state is re-initialized at next claim
        st.slot = None
        self._admit()
        return partial

    def evict_expired(self) -> List[str]:
        """Retire every open stream whose TTL deadline has passed: partial
        result flushed into ``results``, an "expired" StreamError recorded in
        ``errors``, slot recycled.  Runs at the top of every tick; callable
        directly too.  Returns the expired stream ids."""
        now_tick = self.stats.ticks
        expired = [
            st for st in list(self._by_id.values())
            if st.deadline_tick is not None and now_tick >= st.deadline_tick
        ]
        for st in expired:
            self._retire_early(
                st, "expired",
                f"deadline tick {st.deadline_tick} passed at tick {now_tick}",
            )
            self.stats.streams_expired += 1
            self._expired_ctr.inc()
        return [st.stream_id for st in expired]

    def pop_error(self, stream_id: str) -> StreamError:
        """Structured record of an early-terminated stream (+ drop), the
        error-side sibling of ``pop_result``."""
        return self.errors.pop(stream_id)

    # ------------------------------ ticking ------------------------------ #

    def pending_work(self) -> bool:
        return bool(self.active or self.pending)

    def step(self) -> Dict[str, np.ndarray]:
        """One scheduler tick: poll producers, retire drained streams, admit
        pending ones, then advance every slot with a full chunk ready
        through ONE jitted call (slots without one idle, state untouched).
        Returns the bits each stream newly committed this tick.

        When a tracer is attached the tick records a parent ``tick`` span
        with the TICK_PHASES children; disabled tracing costs one ``is
        None`` check per phase (see obs.trace.span)."""
        t0 = time.monotonic()
        ticks_before = self.stats.ticks
        with span(self._tracer, "tick"):
            out = self._step_traced()
        # straggler detection: only ticks that dispatched real device work
        # feed the EMA — idle/starved ticks are microseconds and would make
        # every working tick look like an outlier.
        if self.stats.ticks > ticks_before:
            self._observe_tick_time(time.monotonic() - t0)
        return out

    def _observe_tick_time(self, dt: float) -> None:
        self._tick_hist.observe(dt)
        if self.straggler.observe(self.stats.ticks, dt):
            self.stats.straggler_ticks += 1
            self._straggler_ctr.inc()

    def _step_traced(self) -> Dict[str, np.ndarray]:
        tr = self._tracer
        with span(tr, "ingest"):
            self.evict_expired()
            self._poll_producers()
        # 1. retire closed streams that cannot fill a full chunk (tail +
        #    flush run batched over all slots retiring this tick — off the
        #    hot path), re-admit, and repeat: an admitted pending stream may
        #    itself be closed with less than a chunk buffered and must
        #    retire before the gather sees it.
        with span(tr, "admit"):
            self._admit()
            while True:
                drained = [
                    slot for slot, st in self.active.items()
                    if st.closed and st.available < self.chunk
                ]
                if not drained:
                    break
                self._finish_slots(drained)
                self._admit()
        # 2. slots with a full chunk of rows ready advance; admitted slots
        #    that are starved (open stream, no chunk yet) idle masked —
        #    their gather reads the zero prefix and their carried state is
        #    re-selected unchanged inside stream_step.
        with span(tr, "gather"):
            ready = [
                slot for slot, st in self.active.items()
                if st.available >= self.chunk
            ]
            self.stats.starved_slot_ticks += len(self.active) - len(ready)
            if not ready:
                return {}
            idx = np.zeros((self.n_slots, self.chunk), dtype=np.int32)
            mask = np.zeros((self.n_slots,), dtype=bool)
            for slot in ready:
                idx[slot] = self.active[slot].rows[: self.chunk]
                mask[slot] = True
            idx_j, mask_j = jnp.asarray(idx), jnp.asarray(mask)

        # 3. the one jitted call for all live streams — under shard_map when
        #    the scheduler spans a mesh (gather + step fused, shard-local).
        #    The span measures dispatch, not device time: the only forced
        #    sync stays the bits transfer in the commit phase.
        with span(tr, "step"):
            try:
                if self.tick_fault_hook is not None:
                    # chaos/test seam: a raised TickFault simulates a
                    # transient device-step failure BEFORE any carried state
                    # is reassigned — the tick drops, the next one retries
                    # the identical gather, the decode is unchanged.
                    self.tick_fault_hook(self.stats.ticks)
                if self._sharded_step is not None:
                    if self._counters is not None:
                        self.state, bits, delta, self._counters = self._sharded_step(
                            self._arena, idx_j, mask_j, self.state, self._counters
                        )
                    else:
                        self.state, bits, delta = self._sharded_step(
                            self._arena, idx_j, mask_j, self.state
                        )
                else:
                    block = self._gather(self._arena, idx_j)  # (n_slots, chunk, ·)
                    weights = self._weights if self.packed else None
                    if self._counters is not None:
                        self.state, bits, delta, self._counters = self._step_fn(
                            self.state, block, weights, mask_j,
                            counters=self._counters,
                        )
                    else:
                        self.state, bits, delta = self._step_fn(
                            self.state, block, weights, mask_j
                        )
            except TickFault:
                self.stats.tick_device_failures += 1
                self._device_failure_ctr.inc()
                return {}
            self.offset = self.offset + delta

        # 4. the tick's ONE host sync, then distribute newly-final bits.
        with span(tr, "commit"):
            # The sanctioned device->host transfer: every other per-tick
            # value stays device-resident (DeviceCounters, arena, ring).
            bits_np = np.asarray(bits)  # repr-lint: allow[RPR003]
            self.stats.ticks += 1
            self.stats.steps_decoded += len(ready) * self.chunk
            now = time.monotonic()
            emitted: Dict[str, np.ndarray] = {}
            for slot in ready:
                st = self.active[slot]
                st.rows = st.rows[self.chunk :]
                st.pos += self.chunk
                committable = max(0, st.pos - self.depth)
                n_new = committable - st.committed
                st.committed = committable
                self._observe_commit_latency(st, now)
                if n_new:
                    fresh = bits_np[slot, self.chunk - n_new :]
                    st.out.append(fresh)
                    emitted[st.stream_id] = fresh
            return emitted

    def run(self) -> Dict[str, Tuple[np.ndarray, float]]:
        """Drain everything; returns {stream_id: (bits (T,), metric)}.

        Every open stream must either be closed or have a producer attached:
        a stream waiting on future ``submit_chunk`` calls can never make
        progress inside this loop, so that state raises instead of spinning
        (producer-fed streams busy-poll — their source delivers on its own
        clock)."""
        while self.pending_work():
            marker = self._progress_marker()
            self.step()
            if marker == self._progress_marker() and not any(
                st.producer is not None and not st.closed
                for st in self._by_id.values()
            ):
                starved = sorted(
                    st.stream_id for st in self._by_id.values() if not st.closed
                )
                raise RuntimeError(
                    f"StreamScheduler.run() stalled: open streams {starved} are "
                    "starved with no producer attached — drive step() from your "
                    "serving loop, attach a ChunkProducer, or close() them"
                )
        return self.results

    def _progress_marker(self) -> Tuple[int, int, int]:
        return (
            self.stats.ticks,
            self.stats.streams_finished,
            sum(st.fed + st.queued_rows for st in self._by_id.values()),
        )

    def result(self, stream_id: str) -> Tuple[np.ndarray, float]:
        return self.results[stream_id]

    def pop_result(self, stream_id: str) -> Tuple[np.ndarray, float]:
        """result() + drop — long-lived servers must use this (or otherwise
        prune ``results``) so finished-stream outputs don't accumulate
        forever."""
        return self.results.pop(stream_id)

    def utilization(self) -> float:
        return self.alloc.utilization()

    def load_report(self) -> Dict[str, object]:
        """Occupancy and queue depth per shard plus the mesh-global scalars.
        The per-shard counts come from this controller's bookkeeping; the
        totals reduce through parallel.collectives.sum_across_shards — the
        same psum a multi-controller deployment (one host per shard) would
        issue, so the global view never gathers any decode state.  Callers
        throttle on the queue-depth numbers: ``queued_rows_total`` is how
        much input sits unconsumed on-device, ``starved_active`` how many
        slots are idling for lack of it.

        ``latency_s`` summarizes the arrival-to-commit histogram (always
        tracked); with device counters enabled the report also carries
        ``merge_depth`` — per active stream, the survivor merge-depth
        last/mean/max plus starved ticks and renormalization magnitude,
        materialized here (an explicit drain point, never per tick)."""
        per_shard = np.zeros((self.n_shards,), dtype=np.int32)
        per_shard_queued = np.zeros((self.n_shards,), dtype=np.int32)
        starved = 0
        for slot, st in self.active.items():
            shard = slot // self.slots_per_shard
            per_shard[shard] += 1
            per_shard_queued[shard] += st.available
            if not st.closed and st.available < self.chunk:
                starved += 1
        per_shard_pending = np.zeros((self.n_shards,), dtype=np.int32)
        per_shard_pending[0] = len(self.pending)  # FIFO queue lives host-side
        pending_rows = sum(st.queued_rows for st in self.pending)
        if self.mesh is not None:
            from repro.parallel.collectives import sum_across_shards

            totals = sum_across_shards(
                self.mesh, self.mesh_axis,
                jnp.stack(
                    [
                        jnp.asarray(per_shard),
                        jnp.asarray(per_shard_pending),
                        jnp.asarray(per_shard_queued),
                    ],
                    1,
                ),
            )
            active_total, pending_total, queued_total = (
                int(x) for x in np.asarray(totals)
            )
        else:
            active_total = int(per_shard.sum())
            pending_total = len(self.pending)
            queued_total = int(per_shard_queued.sum())
        report: Dict[str, object] = {
            "n_shards": self.n_shards,
            "per_shard_active": per_shard.tolist(),
            "per_shard_queued_rows": per_shard_queued.tolist(),
            "active_total": active_total,
            "pending_total": pending_total,
            "queued_rows_total": queued_total,
            "pending_rows": pending_rows,
            # deepest single stream queue (vs its max_buffered bound) — the
            # number a throttling caller compares against the credit limit
            "max_stream_queued_rows": max(
                (st.buffered for st in self._by_id.values()), default=0
            ),
            "starved_active": starved,
            "utilization": active_total / self.n_slots,
            "latency_s": self._latency_hist.summary(),
        }
        if self._counters is not None:
            report["merge_depth"] = self.device_counter_report()
        return report

    def device_counter_report(self) -> Dict[str, Dict[str, float]]:
        """Materialize the device-resident counters for every ACTIVE stream:
        {stream_id: {ticks, starved_ticks, merge_depth_last, merge_depth_mean,
        merge_depth_max, renorm_sum}}.  One host transfer per counter leaf,
        only when called — never on the tick path."""
        if self._counters is None:
            raise RuntimeError(
                "device counters are off — construct the scheduler with "
                "telemetry=Telemetry(device_counters=True)"
            )
        leaves = {
            name: np.asarray(x)
            for name, x in zip(_w.DeviceCounters._fields, self._counters)
        }
        out: Dict[str, Dict[str, float]] = {}
        for slot, st in self.active.items():
            ticks = int(leaves["ticks"][slot])
            out[st.stream_id] = {
                "ticks": ticks,
                "starved_ticks": int(leaves["starved_ticks"][slot]),
                "merge_depth_last": int(leaves["merge_depth_last"][slot]),
                "merge_depth_mean": (
                    float(leaves["merge_depth_sum"][slot]) / ticks if ticks else 0.0
                ),
                "merge_depth_max": int(leaves["merge_depth_max"][slot]),
                "renorm_sum": float(leaves["renorm_sum"][slot]),
            }
        return out

    def metrics_snapshot(self) -> Dict[str, object]:
        """Mirror SchedulerStats into the metrics registry and return one
        JSON-ready snapshot (scalars + histogram summaries)."""
        m = self.telemetry.metrics
        for name, v in self.stats.asdict().items():
            m.counter(
                f"scheduler_{name}", help=f"SchedulerStats.{name}"
            ).set(v)
        m.gauge("scheduler_active_slots").set(len(self.active))
        m.gauge("scheduler_pending_streams").set(len(self.pending))
        m.gauge("scheduler_utilization").set(self.utilization())
        return m.snapshot()

    def metrics_text(self) -> str:
        """Prometheus text exposition of the scheduler's registry."""
        self.metrics_snapshot()
        return self.telemetry.metrics.render()

    # --------------------------- snapshot/restore --------------------------- #

    def snapshot(self):
        """Freeze the full serving state — slot table, device arena rows,
        path metrics, survivor ring, renorm offsets, DeviceCounters,
        per-stream queues/credits, stats/results/errors — into a versioned
        on-host :class:`~repro.stream.resilience.StreamSnapshot`.  The
        scheduler is untouched and keeps serving.  Call between ticks (every
        call site is one: the API is host-driven)."""
        from repro.stream.resilience import snapshot_scheduler

        return snapshot_scheduler(self)

    @classmethod
    def restore(
        cls,
        snap,
        *,
        mesh: Optional[object] = None,
        mesh_axis: str = "data",
        telemetry: Optional[Telemetry] = None,
        interpret: Optional[bool] = None,
    ) -> "StreamScheduler":
        """Resume a snapshot on a fresh scheduler — same or different mesh
        shape — with committed output bit-exact vs the uninterrupted run.
        Producers are not restored; re-attach with ``attach_producer``."""
        from repro.stream.resilience import restore_scheduler

        return restore_scheduler(
            snap, mesh=mesh, mesh_axis=mesh_axis,
            telemetry=telemetry, interpret=interpret,
        )

    # ------------------------------ internals ------------------------------ #

    def _shard_of(self, slot: int) -> int:
        return slot // self.slots_per_shard

    def _open(self, stream_id: str) -> _Stream:
        try:
            return self._by_id[stream_id]
        except KeyError:
            raise KeyError(
                f"unknown or finished stream {stream_id!r} (open_stream first)"
            ) from None

    def _check_rows(self, rows: np.ndarray) -> None:
        expected = (
            self.code.n_out if self.inputs == "received" else self.code.n_symbols
        )
        kind = "received symbols" if self.inputs == "received" else "bm tables"
        if rows.ndim != 2 or rows.shape[1] != expected:
            raise ValueError(
                f"{self.inputs!r} streams take {kind} shaped (t, {expected}), "
                f"got {rows.shape}"
            )
        if rows.size and not np.isfinite(rows).all():
            # a single NaN/Inf symbol would corrupt path metrics for EVERY
            # stream in the batch tick (renormalization subtracts a max over
            # the slot axis) — reject at the boundary, poison nothing.
            bad = int(np.count_nonzero(~np.isfinite(rows)))
            self.stats.poisoned_rejections += 1
            self._poison_ctr.inc()
            raise ValueError(
                f"non-finite input: {bad} NaN/Inf value(s) in a {rows.shape} "
                "chunk — non-finite symbols corrupt path metrics for the "
                "whole batch tick"
            )

    def _accept_rows(self, st: _Stream, rows: np.ndarray) -> None:
        """Route accepted rows: straight into the arena for admitted streams,
        host-side queue otherwise (no shard known until a slot is claimed)."""
        # latency bookkeeping: a chunk counts as committed once the commit
        # watermark passes its LAST row (fed + queued_rows is the cumulative
        # arrival count regardless of which side of admission the rows land)
        st.arrivals.append(
            (st.fed + st.queued_rows + rows.shape[0], time.monotonic())
        )
        if st.slot is not None:
            self._append_stream_rows(st, rows)
        else:
            st.queued.append(rows)
            st.queued_rows += rows.shape[0]

    def _observe_commit_latency(self, st: _Stream, now: float) -> None:
        while st.arrivals and st.arrivals[0][0] <= st.committed:
            _, ts = st.arrivals.popleft()
            self._latency_hist.observe(now - ts)

    def _append_stream_rows(self, st: _Stream, rows: np.ndarray) -> None:
        """Append a chunk to the stream's shard slab and extend its row map.
        Features are built here chunk-by-chunk (``t0=st.fed`` keeps the
        puncture phase right no matter how arrival sizes slice the stream)."""
        data = jnp.asarray(rows)
        if self.inputs == "received":
            data = self._plan.features(data, t0=st.fed)
        start = self._append_rows(st.shard, data)
        st.rows = np.concatenate(
            [st.rows, np.arange(start, start + rows.shape[0], dtype=np.int32)]
        )
        st.fed += rows.shape[0]

    def _poll_producers(self) -> None:
        """Pull from attached producers into each stream's queue, never past
        its credit — the scheduler-side half of the backpressure contract.

        One stream's fault never fails the tick: a poisoned chunk (bad
        values/shape) or a raised producer exception quarantines THAT stream
        — partial result flushed, structured StreamError recorded — and the
        loop moves on to the next producer."""
        for st in list(self.active.values()) + list(self.pending):
            if st.producer is None or st.closed:
                continue
            try:
                credit = st.max_buffered - st.buffered
                if credit > 0:
                    got = st.producer.poll(credit)
                    if got is not None:
                        got = np.asarray(got, dtype=np.float32)
                        if got.shape[0]:
                            self._check_rows(got)
                            if got.shape[0] > credit:
                                raise ValueError(
                                    f"producer for {st.stream_id!r} returned "
                                    f"{got.shape[0]} rows against credit {credit}"
                                )
                            self._accept_rows(st, got)
                            self.stats.chunks_submitted += 1
                if st.producer.exhausted:
                    st.closed = True
            except ValueError as e:
                self._quarantine(st, "poisoned_chunk", repr(e))
            except Exception as e:  # noqa: BLE001 — producer code is untrusted
                self._quarantine(st, "producer_error", repr(e))

    # --------------------- graceful degradation --------------------- #

    def _quarantine(self, st: _Stream, reason: str, detail: str) -> None:
        self._retire_early(st, reason, detail)
        self.stats.streams_quarantined += 1
        self._quarantine_ctr.inc()

    def _retire_early(self, st: _Stream, reason: str, detail: str) -> None:
        """Fail ONE stream without failing the tick: flush the partial
        result it already DECODED (committed prefix + the traceback window),
        recycle the slot, and record a structured StreamError in ``errors``.
        Buffered-but-undecoded input is dropped — a failing stream's salvage
        is its decoded prefix, and a multi-hundred-row backlog cannot pass
        through the flush tail-feed (the survivor ring only spans
        depth + chunk steps)."""
        st.producer = None
        st.closed = True
        st.queued, st.queued_rows = [], 0
        st.rows = st.rows[:0]
        st.fed = st.pos
        # an early cut is a truncation: the encoder never flushed to state 0
        # at the cut point, so the final traceback must start from the best
        # state, not the terminated=True state-0 path
        st.terminated = False
        if st.slot is not None:
            self._finish_slots([st.slot])
        else:
            self.pending.remove(st)
            del self._by_id[st.stream_id]
        result = self.results.get(st.stream_id)
        self.errors[st.stream_id] = StreamError(
            stream_id=st.stream_id,
            reason=reason,
            detail=detail,
            tick=self.stats.ticks,
            committed_bits=0 if result is None else int(result[0].shape[0]),
        )
        self._admit()

    def _shed_overload(self) -> None:
        """Overload policy: when the pending queue outgrows ``max_pending``,
        shed the globally lowest-priority open stream (pending preferred over
        active among equals, newest last-in first) with a partial-result
        flush — admission never stalls, and the victim is recorded in
        ``errors`` rather than silently dropped."""
        if self.max_pending is None:
            return
        while len(self.pending) > self.max_pending:
            victim = min(
                self._by_id.values(),
                key=lambda s: (s.priority, 0 if s.slot is None else 1, -s.seq),
            )
            self._retire_early(
                victim, "shed",
                f"overload: {len(self.pending)} pending > max_pending "
                f"{self.max_pending}; priority {victim.priority} shed",
            )
            self.stats.streams_shed += 1
            self._shed_ctr.inc()

    def _retry_after_ticks(self, st: _Stream, deficit: int) -> int:
        """Backoff hint handed out with StreamBusy: admitted streams drain
        one chunk per tick, so the deficit converts directly; a pending
        stream first waits out its FIFO position (approximated as one tick
        per admission ahead of it)."""
        ticks = max(1, -(-int(deficit) // self.chunk))
        if st.slot is None:
            try:
                ticks += self.pending.index(st) + 1
            except ValueError:
                ticks += 1
        return ticks

    def _pin_arena(self) -> None:
        """Re-assert the per-shard arena placement after an eager mutation
        (chunk append, growth, compaction — all off the hot path)."""
        if self._arena_sharding is not None:
            self._arena = jax.device_put(self._arena, self._arena_sharding)

    def _pin_state(self) -> None:
        if self.mesh is not None:
            self.state = _w.shard_stream_state(self.mesh, self.mesh_axis, self.state)

    def _pin_counters(self) -> None:
        if self._counters is not None and self._counter_sharding is not None:
            self._counters = _w.DeviceCounters(
                *(jax.device_put(x, self._counter_sharding) for x in self._counters)
            )

    def _admit(self) -> None:
        while self.pending and self.alloc.free:
            st = self.pending.popleft()
            slot = self.alloc.claim(st.stream_id)
            # reset at CLAIM time, not release time: a recycled slot's pm/ring
            # must not leak the previous resident's state into the
            # start-in-state-0 constraint (paper §IV-B) for the next stream.
            self._reset_slot(slot)
            st.slot = slot
            st.shard = self._shard_of(slot)
            self.active[slot] = st
            self.stats.slot_claims += 1
            if st.queued:
                queued, st.queued, st.queued_rows = st.queued, [], 0
                self._append_stream_rows(st, np.concatenate(queued, axis=0))
        self._maybe_compact()

    def _append_rows(self, shard: int, rows: jnp.ndarray) -> int:
        """Write rows into a shard's used prefix, doubling the (uniform)
        capacity as needed; returns the shard-local start row."""
        start = self._arena_len[shard]
        need = start + rows.shape[0]
        cap = self._arena.shape[1]
        if need > cap:
            new_cap = max(2 * cap, need)
            self._arena = jnp.concatenate(
                [
                    self._arena,
                    jnp.zeros((self.n_shards, new_cap - cap, self._width), jnp.float32),
                ],
                axis=1,
            )
        self._arena = jax.lax.dynamic_update_slice(
            self._arena, rows.astype(jnp.float32)[None], (shard, start, 0)
        )
        self._arena_len[shard] = need
        self._pin_arena()
        return start

    def _maybe_compact(self) -> None:
        """Rebuild every shard's used prefix from its live (unconsumed)
        segments when dead rows dominate the arena (off the hot path; keeps
        long-lived servers bounded).  Capacity is kept when the live rows
        fit, so the tick's compiled shape survives the compaction."""
        live = sum(st.available for st in self.active.values()) + sum(
            st.queued_rows for st in self._by_id.values()
        )
        if sum(self._arena_len) <= max(
            self._compact_ratio * (live + self.n_shards * self.chunk),
            self._compact_floor,
        ):
            return
        with span(self._tracer, "compact"):
            self._compact()

    def _compact(self) -> None:
        by_shard: Dict[int, List[_Stream]] = {}
        for st in self.active.values():
            by_shard.setdefault(st.shard, []).append(st)
        cap = self._arena.shape[1]
        slabs = []
        for shard in range(self.n_shards):
            parts = [jnp.zeros((self.chunk, self._width), dtype=jnp.float32)]
            cursor = self.chunk
            for st in by_shard.get(shard, ()):
                n = st.available
                if n:
                    parts.append(
                        jnp.take(self._arena[shard], jnp.asarray(st.rows), axis=0)
                    )
                st.rows = np.arange(cursor, cursor + n, dtype=np.int32)
                cursor += n
            parts.append(jnp.zeros((max(cap - cursor, 0), self._width), jnp.float32))
            slabs.append(jnp.concatenate(parts, axis=0))
            self._arena_len[shard] = cursor
        self._arena = jnp.stack(slabs, axis=0)
        self._pin_arena()
        self.stats.arena_compactions += 1

    def _collect(self, st: _Stream) -> np.ndarray:
        return (
            np.concatenate(st.out) if st.out else np.zeros((0,), dtype=np.int32)
        ).astype(np.int32)

    def _reset_slot(self, slot: int) -> None:
        self.state = _w.StreamState(
            pm=self.state.pm.at[slot].set(self._pm0_row),
            ring=self.state.ring.at[:, slot].set(0),
        )
        self._pin_state()
        self.offset = self.offset.at[slot].set(0.0)
        if self._counters is not None:
            # counters reset at claim for the same reason as pm/ring: the
            # recycled slot must not leak the previous resident's statistics
            self._counters = _w.DeviceCounters(
                *(x.at[slot].set(0) for x in self._counters)
            )
            self._pin_counters()

    def _tail_rows(self, st: _Stream) -> jnp.ndarray:
        """(r, M) bm tables for a stream's remaining sub-chunk tail, gathered
        from its shard's arena slab by row index (raw features go through
        the metric plan)."""
        seg = jnp.take(self._arena[st.shard], jnp.asarray(st.rows), axis=0)
        if self.inputs == "received":
            return self._plan.bm_from_features(seg)
        return seg

    def _finish_slots(self, slots: Sequence[int]) -> None:
        """Tail-feed + final traceback for every drained stream retiring this
        tick, then recycle the slots.  Tails are fed grouped by length (one
        jitted_chunk_forward per distinct tail length) and the final
        traceback over all retirees runs as ONE batched jitted_stream_flush
        per termination kind — not one dispatch per slot.  Every batched call
        is padded to ``n_slots`` rows so cohort size never creates a new
        compiled shape (padded rows decode garbage that is sliced away).
        Packed survivor rings are unpacked here, once, off the hot path."""
        with span(self._tracer, "flush"):
            self._finish_slots_traced(slots)

    def _finish_slots_traced(self, slots: Sequence[int]) -> None:
        streams = [(slot, self.active.pop(slot)) for slot in slots]
        if self._counters is not None:
            # retirement IS the device-counter drain point: one host read of
            # the (B,) merge-depth leaf for the whole cohort, off the hot path
            md_last = np.asarray(self._counters.merge_depth_last)
            for slot, _ in streams:
                self._depth_hist.observe(int(md_last[slot]))

        def pad_rows(x: jnp.ndarray, axis: int) -> jnp.ndarray:
            extra = self.n_slots - x.shape[axis]
            if extra <= 0:
                return x
            widths = [(0, 0)] * x.ndim
            widths[axis] = (0, extra)
            return jnp.pad(x, widths)

        # the flush math below slices slot subsets with fancy indexing; on a
        # sharded state every such op would become its own cross-shard
        # gather, so materialize the retiring cohort's state onto one device
        # first (off the hot path, and the tick state itself is untouched).
        pm_frontier = self.state.pm
        ring = self.state.ring
        if self.mesh is not None:
            pm_frontier = jnp.asarray(np.asarray(pm_frontier))
            ring = jnp.asarray(np.asarray(ring))
        if self.packed:
            ring = _w.unpack_ring(self.code, ring)  # (R, n_slots, S)

        # tail-feed, grouped by tail length r (each group one batched call)
        by_r: Dict[int, List[Tuple[int, _Stream]]] = {}
        for slot, st in streams:
            by_r.setdefault(st.available, []).append((slot, st))
        ordered: List[Tuple[int, _Stream]] = []
        pm_parts: List[jnp.ndarray] = []
        ring_parts: List[jnp.ndarray] = []
        for r, group in sorted(by_r.items()):
            n = len(group)
            idx = jnp.asarray([slot for slot, _ in group])
            pm_g = pm_frontier[idx]  # (n, S)
            ring_g = ring[:, idx]  # (R, n, S)
            if r > 0:
                tails = pad_rows(
                    jnp.stack([self._tail_rows(st) for _, st in group]), 0
                )  # (n_slots, r, M)
                pm_p, bps = _w.jitted_chunk_forward(self.code)(
                    pad_rows(pm_g, 0), tails
                )
                pm_g = pm_p[:n]
                ring_g = jnp.concatenate([ring_g[r:], bps[:, :n]], axis=0)
                for _, st in group:
                    st.pos += r
                    st.rows = st.rows[r:]
            ordered.extend(group)
            pm_parts.append(pm_g)
            ring_parts.append(ring_g)
        pm_all = jnp.concatenate(pm_parts, axis=0)  # (n_total, S)
        ring_all = jnp.concatenate(ring_parts, axis=1)  # (R, n_total, S)

        # one flush per termination kind (a single call in the common case
        # of uniformly-terminated streams)
        flushed: Dict[int, Tuple[np.ndarray, float]] = {}
        for term in (True, False):
            rows = [i for i, (_, st) in enumerate(ordered) if st.terminated == term]
            if not rows:
                continue
            sel = jnp.asarray(rows)
            bits, metric = _w.jitted_stream_flush(
                self.code, terminated=term, interpret=self._interpret
            )(
                _w.StreamState(
                    pm=pad_rows(pm_all[sel], 0), ring=pad_rows(ring_all[:, sel], 1)
                )
            )
            bits_np, metric_np = np.asarray(bits), np.asarray(metric)
            for k, i in enumerate(rows):
                flushed[i] = (bits_np[k], float(metric_np[k]))

        R = ring.shape[0]
        offset_np = np.asarray(self.offset)  # one transfer, not one per slot
        now = time.monotonic()
        for i, (slot, st) in enumerate(ordered):
            bits_i, metric_i = flushed[i]
            n_rest = st.pos - st.committed
            if n_rest:
                st.out.append(bits_i[R - n_rest :])
            st.committed = st.pos
            self._observe_commit_latency(st, now)
            self.results[st.stream_id] = (
                self._collect(st), metric_i + float(offset_np[slot])
            )
            self.stats.streams_finished += 1
            st.slot = None
            del self._by_id[st.stream_id]
            self.alloc.release(slot)  # state is re-initialized at next claim
