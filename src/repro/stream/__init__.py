"""Streaming Viterbi subsystem: online decode for unbounded bitstreams.

window.py     — truncated-traceback sliding-window core (jittable)
session.py    — stateful per-stream sessions, O(depth + chunk) memory
scheduler.py  — continuous batching of many streams into one jitted call,
                chunk-fed with per-stream backpressure
ingest.py     — ChunkProducer adapters (generator / callable / push-fed) and
                the StreamBusy backpressure signal
resilience.py — crash-consistent snapshot/restore (drain/migrate primitive)
                + the StreamError / TickFault degradation types
chaos.py      — deterministic seeded fault injection harness
"""
from repro.stream.chaos import (
    FAULT_CLASSES,
    ChaosClock,
    ChaosPolicy,
    ChaosProducer,
    ChaosProducerError,
    FaultInjector,
    InjectedDeviceFault,
    install_tick_faults,
)
from repro.stream.ingest import (
    CallableProducer,
    ChunkProducer,
    GeneratorProducer,
    PushProducer,
    RateLimitedProducer,
    StreamBusy,
    as_producer,
)
from repro.stream.resilience import (
    SNAPSHOT_VERSION,
    StreamError,
    StreamSnapshot,
    TickFault,
)
from repro.stream.scheduler import SchedulerStats, StreamScheduler
from repro.stream.session import StreamSession
from repro.stream.window import (
    StreamState,
    chunk_forward_scan,
    default_depth,
    init_stream_state,
    make_sharded_stream_step,
    packed_depth,
    shard_stream_state,
    state_shardings,
    stream_flush,
    stream_step,
    viterbi_decode_windowed,
)

__all__ = [
    "StreamState",
    "StreamSession",
    "StreamScheduler",
    "SchedulerStats",
    "StreamBusy",
    "StreamError",
    "StreamSnapshot",
    "SNAPSHOT_VERSION",
    "TickFault",
    "FAULT_CLASSES",
    "ChaosClock",
    "ChaosPolicy",
    "ChaosProducer",
    "ChaosProducerError",
    "FaultInjector",
    "InjectedDeviceFault",
    "install_tick_faults",
    "ChunkProducer",
    "GeneratorProducer",
    "CallableProducer",
    "PushProducer",
    "RateLimitedProducer",
    "as_producer",
    "chunk_forward_scan",
    "default_depth",
    "init_stream_state",
    "make_sharded_stream_step",
    "packed_depth",
    "shard_stream_state",
    "state_shardings",
    "stream_flush",
    "stream_step",
    "viterbi_decode_windowed",
]
