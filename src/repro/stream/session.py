"""Stateful streaming decode sessions.

A StreamSession owns the carried StreamState for one (optionally batched)
bitstream and the python-side bookkeeping that the jittable core cannot do:
how many steps have been pushed, how many bits are already committed, and
therefore which slice of each chunk's committed window is actually valid.
Memory is O(depth + chunk) regardless of stream length; path metrics are
renormalized every chunk so float32 never saturates, with the accumulated
offset tracked so ``finish`` still reports the absolute path metric.

Backends: ``fused``/``scan`` consume (B, chunk, M) branch-metric tables.
``fused_packed`` runs the memory-lean pipeline — bit-packed survivor ring,
on-device traceback — and with ``inputs="received"`` consumes raw
(B, chunk, n_out) channel symbols, computing branch metrics in-kernel
(kernels/metrics.py).  The packed ring shifts whole uint32 words, so the
chunk must be a multiple of 32 and the depth is rounded up to one (a deeper
window only helps accuracy; the lag grows accordingly).

Typical use:

    sess = StreamSession(code, chunk=64)
    for bm_chunk in channel:                  # (B, 64, M) each
        emit(sess.push(bm_chunk))             # (B, <=64) newly-final bits
    emit(*sess.finish(terminated=True))       # the last `depth` bits + metric
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trellis import ConvCode
from repro.decode.spec import CodecSpec
from repro.kernels.common import resolve_interpret
from repro.obs import Telemetry
from repro.obs.trace import span
from repro.stream import window as _w


class StreamSession:
    """Online Viterbi decoder for one stream (or a batch sharing timing).

    Args:
      spec: a CodecSpec (or a bare ConvCode, promoted with defaults) — its
        ``terminated`` flag is the default for ``finish``/``decode_all``.
      batch: number of independent streams advanced in lock-step (one jitted
        call decodes all of them; the scheduler uses this with batch=n_slots).
      chunk: trellis steps consumed per push (fixed — one compiled shape).
      depth: truncated-traceback depth D; bits commit D steps behind the
        frontier.  Default 5*K (the textbook rule); rounded up to a multiple
        of 32 for the packed backend.
      backend: 'fused' (Pallas), 'fused_packed' (packed survivors +
        on-device traceback), or 'scan' (jnp reference).
      inputs: 'bm' — push takes (B, chunk, M) branch-metric tables;
        'received' (fused_packed only) — push takes raw (B, chunk, n_out)
        channel symbols and the kernel computes the metrics.
      normalize: renormalize path metrics every chunk (required for streams
        longer than ~1e30/bm_max steps; cheap, on by default).
      mesh: optional device mesh — carry the session state as per-shard
        pytrees partitioned along ``mesh_axis`` (batch must divide evenly);
        pushed chunks are placed with the same layout so the jitted step
        runs batch-parallel across the mesh with no resharding.
      mesh_axis: mesh axis the batch is sharded over (default 'data').
      telemetry: obs.Telemetry bundle — an attached tracer records ``push``
        / ``finish`` spans; ``device_counters=True`` carries a DeviceCounters
        pytree through every push (merge depth, renorm magnitude), exposed
        host-side via :meth:`device_counter_report`, materialized only there.
    """

    def __init__(
        self,
        spec: Union[CodecSpec, ConvCode],
        batch: int = 1,
        chunk: int = 64,
        depth: Optional[int] = None,
        backend: str = "fused",
        normalize: bool = True,
        interpret: Optional[bool] = None,
        inputs: str = "bm",
        mesh: Optional[object] = None,
        mesh_axis: str = "data",
        telemetry: Optional[Telemetry] = None,
        validate: bool = True,
    ):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        #: reject non-finite chunks at push time (a single NaN/Inf poisons
        #: the carried path metrics for every stream in the batch, silently).
        #: ``validate=False`` skips the host-side isfinite scan for callers
        #: feeding device arrays on a measured hot path.
        self.validate = bool(validate)
        self.spec = CodecSpec.of(spec)
        code = self.spec.code
        self.code = code
        self.batch = batch
        self.chunk = chunk
        self.depth = _w.default_depth(code) if depth is None else depth
        if self.depth < 1:
            raise ValueError("depth must be >= 1")
        self.backend = backend
        self.inputs = inputs
        self.packed, self.depth, self._plan, self._weights = _w.resolve_stream_backend(
            self.spec, chunk, self.depth, backend, inputs
        )
        self.state = _w.init_stream_state(
            code, batch, self.depth, chunk, packed=self.packed
        )
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self._chunk_sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            from repro.parallel.collectives import mesh_axis_size

            n = mesh_axis_size(mesh, mesh_axis)
            if not n:
                raise ValueError(f"mesh has no {mesh_axis!r} axis: {mesh}")
            if batch % n:
                raise ValueError(
                    f"batch={batch} must divide evenly over the {n} shards "
                    f"of mesh axis {mesh_axis!r}"
                )
            self.state = _w.shard_stream_state(mesh, mesh_axis, self.state)
            self._chunk_sharding = NamedSharding(mesh, P(mesh_axis, None, None))
        self.offset = jnp.zeros((batch,), dtype=jnp.float32)
        self.t = 0  # trellis steps pushed so far
        self.committed = 0  # bits already handed to the caller
        self.closed = False
        # pin interpret-mode resolution once per session (kernels/common.py):
        # every kernel this session dispatches — forward chunks, tail feeds,
        # the flush traceback — must resolve to the same code path.
        self._interpret = resolve_interpret(interpret)
        self._step = _w.jitted_stream_step(
            code, backend=backend, normalize=normalize, interpret=self._interpret
        )
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._tracer = self.telemetry.tracer
        self._counters = (
            _w.init_device_counters(batch)
            if self.telemetry.device_counters
            else None
        )

    @property
    def ring_size(self) -> int:
        return self.depth + self.chunk

    @property
    def lag(self) -> int:
        """Bits pushed but not yet committed (== depth at steady state)."""
        return self.t - self.committed

    def push(self, chunk_data: jnp.ndarray) -> jnp.ndarray:
        """Advance the stream by exactly ``chunk`` steps.

        Args:
          chunk_data: (B, chunk, M) branch-metric tables, or raw
            (B, chunk, n_out) symbols for ``inputs='received'``.
        Returns:
          (B, n_new) newly-committed bits, n_new in [0, chunk] — 0 while the
          window warms up, exactly ``chunk`` at steady state.
        """
        if self.closed:
            raise RuntimeError("session is finished")
        if chunk_data.shape[:2] != (self.batch, self.chunk):
            raise ValueError(
                f"expected ({self.batch}, {self.chunk}, ·) chunk, got {chunk_data.shape}"
            )
        if self.validate:
            flat = np.asarray(chunk_data)
            if not np.isfinite(flat).all():
                bad = int(np.count_nonzero(~np.isfinite(flat)))
                raise ValueError(
                    f"non-finite input: {bad} NaN/Inf value(s) in a "
                    f"{flat.shape} chunk — they would silently corrupt the "
                    "carried path metrics for the whole batch "
                    "(validate=False to skip this check)"
                )
        if self.inputs == "received":
            chunk_data = self._plan.features(chunk_data, t0=self.t)
        if self._chunk_sharding is not None:
            chunk_data = jax.device_put(jnp.asarray(chunk_data), self._chunk_sharding)
        weights = self._weights if self.packed else None
        with span(self._tracer, "push"):
            if self._counters is not None:
                self.state, bits, delta, self._counters = self._step(
                    self.state, chunk_data, weights, counters=self._counters
                )
            else:
                self.state, bits, delta = self._step(self.state, chunk_data, weights)
        self.offset = self.offset + delta
        self.t += self.chunk
        committable = max(0, self.t - self.depth)
        n_new = committable - self.committed
        self.committed = committable
        # the committed window covers positions [t-R, t-D); its valid tail
        # (positions >= previous commit point) is the last n_new entries.
        return bits[:, self.chunk - n_new :] if n_new else bits[:, :0]

    def _tail_bm(self, tail: jnp.ndarray) -> jnp.ndarray:
        """Branch-metric tables for an odd-length tail (raw symbols are
        converted through the metric plan, phased at the current step)."""
        if self.inputs == "received":
            return self._plan.bm_tables(tail, t0=self.t)
        return tail

    def finish(
        self,
        bm_tail: Optional[jnp.ndarray] = None,
        terminated: Optional[bool] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Consume an optional odd-length tail and flush the window.

        Args:
          bm_tail: (B, r, ·) with 0 < r < chunk, or None (same input kind as
            ``push``).
          terminated: the stream ends in state 0 (encoder flushed); defaults
            to the spec's ``terminated`` flag.
        Returns:
          bits: (B, lag) the remaining uncommitted bits.
          metric: (B,) absolute winning path metric (normalization undone).
        """
        if self.closed:
            raise RuntimeError("session is finished")
        if terminated is None:
            terminated = self.spec.terminated
        if bm_tail is not None and bm_tail.shape[1]:
            r = bm_tail.shape[1]
            if r >= self.chunk or bm_tail.shape[0] != self.batch:
                raise ValueError(f"tail must be (B, <chunk, ·), got {bm_tail.shape}")
            if self.validate and not np.isfinite(np.asarray(bm_tail)).all():
                raise ValueError(
                    "non-finite input: NaN/Inf value(s) in the finish() tail "
                    "(validate=False to skip this check)"
                )
            tail_bm = self._tail_bm(bm_tail)
            ring = self.state.ring
            if self.packed:
                # word shifts can't absorb an odd tail: unpack once, off the
                # hot path — the flush runs on the unpacked ring.
                ring = _w.unpack_ring(self.code, ring)
            new_pm, bps = _w.jitted_chunk_forward(self.code)(self.state.pm, tail_bm)
            ring = jnp.concatenate([ring[r:], bps], axis=0)
            self.state = _w.StreamState(pm=new_pm, ring=ring)
            self.t += r
        with span(self._tracer, "finish"):
            bits, metric = _w.jitted_stream_flush(
                self.code, terminated=terminated, interpret=self._interpret
            )(self.state)
        n_rest = self.t - self.committed
        self.committed = self.t
        self.closed = True
        R = bits.shape[1]
        return bits[:, R - n_rest :] if n_rest else bits[:, :0], metric + self.offset

    def device_counter_report(self) -> dict:
        """Materialize the per-row device counters (one host transfer per
        leaf, never on the push path): {field: (B,) list} plus the derived
        ``merge_depth_mean``."""
        if self._counters is None:
            raise RuntimeError(
                "device counters are off — construct the session with "
                "telemetry=Telemetry(device_counters=True)"
            )
        leaves = {
            name: np.asarray(x)
            for name, x in zip(_w.DeviceCounters._fields, self._counters)
        }
        ticks = np.maximum(leaves["ticks"], 1)
        out = {name: x.tolist() for name, x in leaves.items()}
        out["merge_depth_mean"] = (leaves["merge_depth_sum"] / ticks).tolist()
        return out

    def decode_all(
        self, bm_tables: jnp.ndarray, terminated: Optional[bool] = None
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Push a full (B, T, ·) block through this session and return the
        complete (B, T) decode + metric.  Convenience for tests/benchmarks
        (tables or raw symbols per the session's ``inputs`` kind)."""
        B, T = bm_tables.shape[:2]
        out = []
        n_full = T // self.chunk
        for i in range(n_full):
            out.append(self.push(bm_tables[:, i * self.chunk : (i + 1) * self.chunk]))
        tail = bm_tables[:, n_full * self.chunk :]
        rest, metric = self.finish(tail if tail.shape[1] else None, terminated=terminated)
        out.append(rest)
        return jnp.concatenate(out, axis=1), metric
