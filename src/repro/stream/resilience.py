"""Serving resilience: crash-consistent snapshot/restore for the scheduler.

Commodity serving hardware fails.  The multi-controller plane (ROADMAP) needs
a drain/migrate primitive: freeze a live :class:`~repro.stream.StreamScheduler`
— mid-decode, with streams at arbitrary window positions — move the frozen
state to another host (possibly a different mesh shape), and resume such that
every bit committed after the restore is IDENTICAL to the uninterrupted run.

The snapshot is taken at a tick boundary (the scheduler API is host-driven,
so every call site is one) and covers every piece of carried serving state:

  * per-stream host bookkeeping — id, termination flag, closed/credit state,
    fed/pos/committed watermarks, priority, deadline, pre-admission queue;
  * the device plane, re-keyed per STREAM rather than per slot/shard so a
    restore onto a different mesh shape is a pure re-layout: path-metric row,
    survivor-ring column (packed uint32 words or unpacked int32), accumulated
    renormalization offset, DeviceCounters leaves;
  * the stream's unconsumed input arena rows, extracted post-feature-
    transform (puncture phase is baked in at accept time, so replaying them
    through ``features`` again would corrupt the decode — restore appends
    them verbatim);
  * scheduler-scope state: SchedulerStats (tick count continues, so absolute
    deadline ticks stay valid), finished-stream results, structured stream
    errors, and the straggler detector's EMA.

What is deliberately NOT captured: attached producers (a generator or socket
cannot be serialized — re-attach with ``StreamScheduler.attach_producer``
after restoring) and the arrival-latency bookkeeping (monotonic timestamps
do not survive a host move; the latency histogram restarts).

``save``/``load`` serialize through pickle — the payload is plain dataclass
+ numpy + CodecSpec state.  Only load snapshots you wrote (the usual pickle
trust boundary).
"""
from __future__ import annotations

import dataclasses
import pickle
from typing import Dict, List, Optional, Tuple

import numpy as np

#: Bump when the snapshot layout changes; ``restore_scheduler`` refuses a
#: mismatched snapshot instead of mis-reading it.
SNAPSHOT_VERSION = 1


class TickFault(RuntimeError):
    """A transient failure of one scheduler tick's device step.

    The tick that observes it is dropped WITHOUT mutating any carried state
    (the jitted step is functional — nothing is assigned until it returns),
    so the next tick retries the identical gather and the decode is
    unchanged.  ``chaos.InjectedDeviceFault`` subclasses this to simulate
    device-step failures; the scheduler counts every occurrence in
    ``stream_tick_device_failures_total``.
    """


@dataclasses.dataclass
class StreamError:
    """Structured record of why a stream was terminated early.

    One stream's fault must never fail the tick: poisoned chunks, crashed
    producers, expired deadlines, and overload shedding all resolve to one
    of these in ``StreamScheduler.errors`` (keyed by stream id), alongside
    whatever partial result the flush could still commit to ``results``.

    reason: "poisoned_chunk" | "producer_error" | "expired" | "shed".
    detail: human-readable cause (repr of the offending exception, the
      deadline that passed, the priority that lost).
    tick:   scheduler tick count when the stream was terminated.
    committed_bits: bits the stream had delivered by then (including the
      partial-result flush, when one ran).
    """

    stream_id: str
    reason: str
    detail: str
    tick: int
    committed_bits: int = 0

    def __str__(self) -> str:  # readable in logs / pytest output
        return (
            f"StreamError({self.stream_id!r}: {self.reason} at tick "
            f"{self.tick}, {self.committed_bits} bits committed — {self.detail})"
        )


@dataclasses.dataclass
class StreamImage:
    """One open stream, frozen — everything needed to resume it anywhere."""

    stream_id: str
    terminated: bool
    closed: bool
    max_buffered: int
    priority: int
    deadline_tick: Optional[int]
    fed: int
    pos: int
    committed: int
    #: raw pre-admission chunks (feature transform happens at admission)
    queued: List[np.ndarray]
    #: bits already committed but not yet retired into ``results``
    out: List[np.ndarray]
    #: original slot (ordering only — restore may re-place the stream)
    slot: Optional[int] = None
    #: unconsumed arena rows [pos, fed), post-feature-transform
    arena_rows: Optional[np.ndarray] = None
    #: device plane, per stream (active streams only)
    pm: Optional[np.ndarray] = None
    ring: Optional[np.ndarray] = None
    offset: float = 0.0
    counters: Optional[Dict[str, np.ndarray]] = None


@dataclasses.dataclass
class StreamSnapshot:
    """Versioned on-host checkpoint of a whole StreamScheduler."""

    version: int
    spec: object  # CodecSpec — shared by every stream (scheduler contract)
    config: Dict[str, object]
    active: List[StreamImage]  # in slot order (restore re-places in order)
    pending: List[StreamImage]  # FIFO admission order
    stats: Dict[str, int]
    results: Dict[str, Tuple[np.ndarray, float]]
    errors: Dict[str, StreamError]
    straggler: Dict[str, float]

    def save(self, path) -> None:
        with open(path, "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path) -> "StreamSnapshot":
        with open(path, "rb") as f:
            snap = pickle.load(f)
        if not isinstance(snap, StreamSnapshot):
            raise TypeError(f"{path} is not a StreamSnapshot")
        return snap

    @property
    def stream_ids(self) -> List[str]:
        return [im.stream_id for im in self.active + self.pending]


def snapshot_scheduler(sched) -> StreamSnapshot:
    """Freeze ``sched`` into a StreamSnapshot (the scheduler is untouched
    and keeps serving).  Called between ticks — every device array is
    materialized host-side here, once, off the hot path."""
    pm_np = np.asarray(sched.state.pm)
    ring_np = np.asarray(sched.state.ring)
    offset_np = np.asarray(sched.offset)
    arena_np = np.asarray(sched._arena)
    ctr_np = (
        {
            name: np.asarray(leaf)
            for name, leaf in zip(type(sched._counters)._fields, sched._counters)
        }
        if sched._counters is not None
        else None
    )

    def image(st) -> StreamImage:
        im = StreamImage(
            stream_id=st.stream_id,
            terminated=st.terminated,
            closed=st.closed,
            max_buffered=st.max_buffered,
            priority=st.priority,
            deadline_tick=st.deadline_tick,
            fed=st.fed,
            pos=st.pos,
            committed=st.committed,
            queued=[np.array(c) for c in st.queued],
            out=list(st.out),
            slot=st.slot,
        )
        if st.slot is not None:
            im.arena_rows = arena_np[st.shard][st.rows].copy()
            im.pm = pm_np[st.slot].copy()
            im.ring = ring_np[:, st.slot].copy()
            im.offset = float(offset_np[st.slot])
            if ctr_np is not None:
                im.counters = {k: v[st.slot].copy() for k, v in ctr_np.items()}
        return im

    active = [image(st) for _, st in sorted(sched.active.items())]
    pending = [image(st) for st in sched.pending]
    return StreamSnapshot(
        version=SNAPSHOT_VERSION,
        spec=sched.spec,
        config={
            "n_slots": sched.n_slots,
            "chunk": sched.chunk,
            "depth": sched.depth,
            "backend": sched.backend,
            "inputs": sched.inputs,
            "normalize": sched.normalize,
            "max_buffered": sched.max_buffered,
            "max_pending": sched.max_pending,
        },
        active=active,
        pending=pending,
        stats=sched.stats.asdict(),
        results=dict(sched.results),
        errors=dict(sched.errors),
        straggler={
            "mean": sched.straggler.mean,
            "var": sched.straggler.var,
            "n": sched.straggler.n,
        },
    )


def restore_scheduler(
    snap: StreamSnapshot,
    *,
    mesh=None,
    mesh_axis: str = "data",
    telemetry=None,
    interpret: Optional[bool] = None,
):
    """Build a fresh StreamScheduler resuming exactly where ``snap`` froze.

    ``mesh`` need not match the snapshotted scheduler's — the snapshot is
    keyed per stream, so restoring onto a different shard count (or no mesh
    at all) is a re-layout, not a reshard of opaque buffers: each stream's
    pm row / ring column / arena rows land wherever its NEW slot lives.
    Committed output after the restore is bit-exact with the uninterrupted
    run (the acceptance gate fuzzed in tests/test_stream_resilience.py).

    Producers are not restored — re-attach with ``attach_producer``.
    """
    if snap.version != SNAPSHOT_VERSION:
        raise ValueError(
            f"snapshot version {snap.version} != supported {SNAPSHOT_VERSION}"
        )
    import jax.numpy as jnp

    from repro.stream import window as _w
    from repro.stream.scheduler import SchedulerStats, StreamScheduler, _Stream

    cfg = snap.config
    sched = StreamScheduler(
        snap.spec,
        n_slots=cfg["n_slots"],
        chunk=cfg["chunk"],
        depth=cfg["depth"],
        backend=cfg["backend"],
        normalize=cfg["normalize"],
        inputs=cfg["inputs"],
        max_buffered=cfg["max_buffered"],
        max_pending=cfg["max_pending"],
        mesh=mesh,
        mesh_axis=mesh_axis,
        telemetry=telemetry,
        interpret=interpret,
    )

    def stream_of(im: StreamImage) -> _Stream:
        return _Stream(
            stream_id=im.stream_id,
            terminated=im.terminated,
            max_buffered=im.max_buffered,
            closed=im.closed,
            priority=im.priority,
            deadline_tick=im.deadline_tick,
            fed=im.fed,
            pos=im.pos,
            committed=im.committed,
            queued=list(im.queued),
            queued_rows=sum(c.shape[0] for c in im.queued),
            out=list(im.out),
        )

    # device plane rebuilt host-side in one pass (numpy), then pinned once —
    # a per-slot .at[].set() on a sharded state would be one scatter each.
    pm = np.asarray(sched.state.pm).copy()
    ring = np.asarray(sched.state.ring).copy()
    offset = np.zeros((sched.n_slots,), dtype=np.float32)
    ctrs = (
        {k: np.asarray(v).copy() for k, v in
         zip(_w.DeviceCounters._fields, sched._counters)}
        if sched._counters is not None
        else None
    )
    for im in snap.active:
        st = stream_of(im)
        slot = sched.alloc.claim(st.stream_id)
        assert slot is not None  # same n_slots as the snapshotted scheduler
        st.slot = slot
        st.shard = sched._shard_of(slot)
        sched.active[slot] = st
        sched._by_id[st.stream_id] = st
        pm[slot] = im.pm
        ring[:, slot] = im.ring
        offset[slot] = im.offset
        if ctrs is not None and im.counters is not None:
            for k in ctrs:
                ctrs[k][slot] = im.counters[k]
        n = im.arena_rows.shape[0] if im.arena_rows is not None else 0
        if n:
            start = sched._append_rows(st.shard, jnp.asarray(im.arena_rows))
            st.rows = np.arange(start, start + n, dtype=np.int32)
    sched.state = _w.StreamState(pm=jnp.asarray(pm), ring=jnp.asarray(ring))
    sched._pin_state()
    sched.offset = jnp.asarray(offset)
    if ctrs is not None:
        sched._counters = _w.DeviceCounters(
            **{k: jnp.asarray(v) for k, v in ctrs.items()}
        )
        sched._pin_counters()

    for im in snap.pending:
        st = stream_of(im)
        sched.pending.append(st)
        sched._by_id[st.stream_id] = st

    sched.stats = SchedulerStats(**snap.stats)
    sched.results = dict(snap.results)
    sched.errors = dict(snap.errors)
    sched.straggler.mean = snap.straggler["mean"]
    sched.straggler.var = snap.straggler["var"]
    sched.straggler.n = int(snap.straggler["n"])
    return sched
