"""Deterministic fault injection for the streaming decode plane.

A production decode plane must survive producer crashes, poisoned inputs,
stalls, and flaky device steps — and every survival claim needs a harness
that can actually produce those faults, reproducibly, with a record of what
was injected so tests can assert both SURVIVAL (the scheduler kept serving)
and DETECTION (every fault shows up in ``repro.obs`` metrics).  This module
is that harness; the degradation machinery it exercises (quarantine, TTL
eviction, overload shedding, tick retry) lives in the scheduler itself.

Fault classes (all seeded, all per-stream deterministic):

  producer_exception   the producer raises mid-poll — a crashed connection.
                       The scheduler quarantines the ONE stream
                       ("producer_error"), flushes its partial result, and
                       the tick never sees the exception.
  producer_stall       poll returns None — a silent source.  The slot idles
                       (starved ticks), bit-exactness unaffected.
  slow_drip            poll hands out a single row — degenerate arrival
                       sizes; the arrival-invariance contract absorbs it.
  corrupt_nan / corrupt_inf
                       a random element of an otherwise-valid chunk becomes
                       non-finite — the poisoned-input case that silently
                       corrupted a whole batch tick before value validation;
                       now quarantined as "poisoned_chunk".
  corrupt_shape        the chunk loses a column — a framing bug upstream;
                       quarantined as "poisoned_chunk".
  device_step_failure  ``install_tick_faults`` hooks the tick's step phase
                       to raise :class:`InjectedDeviceFault` — the scheduler
                       drops the tick without touching carried state and
                       retries the identical gather next tick.
  clock_skew           :class:`ChaosClock` jumps a rate-limited producer's
                       clock forward — bursty arrival, never a decode change.

Every injection is recorded twice: in the injector's own ``injected``
counter dict (the harness-side ledger benches/tests read back) and, when a
metrics registry is supplied, as ``chaos_injected_total`` plus a per-class
``chaos_<class>_total`` counter in the same registry the scheduler exposes
through ``metrics_text()``.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional

import numpy as np

from repro.stream.resilience import TickFault

#: Every fault class a ChaosPolicy can inject, in catalog order.
FAULT_CLASSES = (
    "producer_exception",
    "producer_stall",
    "slow_drip",
    "corrupt_nan",
    "corrupt_inf",
    "corrupt_shape",
    "device_step_failure",
    "clock_skew",
)


class ChaosProducerError(RuntimeError):
    """The simulated producer crash ChaosProducer raises mid-poll."""


class InjectedDeviceFault(TickFault):
    """Simulated device-step failure (see install_tick_faults)."""


@dataclasses.dataclass(frozen=True)
class ChaosPolicy:
    """Per-poll / per-tick injection probabilities, seeded.

    Each field is the probability that the corresponding fault fires on one
    producer poll (or one tick, for ``device_step_failure``).  Streams
    derive independent deterministic RNGs from (seed, stream_id), so a run
    with the same policy, streams, and arrival schedule injects the same
    faults — the chaos suite is reproducible, not flaky.
    """

    seed: int = 0
    producer_exception: float = 0.0
    producer_stall: float = 0.0
    slow_drip: float = 0.0
    corrupt_nan: float = 0.0
    corrupt_inf: float = 0.0
    corrupt_shape: float = 0.0
    device_step_failure: float = 0.0
    clock_skew: float = 0.0

    def rate(self, cls: str) -> float:
        return float(getattr(self, cls))

    @classmethod
    def producer_mix(cls, p: float, seed: int = 0) -> "ChaosPolicy":
        """The bench's ``--chaos`` default: probability ``p`` split across
        the recoverable producer faults plus a light corruption tail."""
        return cls(
            seed=seed,
            producer_stall=p / 2,
            slow_drip=p / 4,
            producer_exception=p / 8,
            corrupt_nan=p / 8,
        )


class FaultInjector:
    """Shared seeded ledger: decides fault firings and records them."""

    def __init__(self, policy: ChaosPolicy, scope: str, metrics=None):
        self.policy = policy
        # stable per-scope stream: independent of python hash randomization
        self._rng = np.random.RandomState(
            (policy.seed ^ zlib.crc32(scope.encode())) % (2 ** 31)
        )
        self._metrics = metrics
        self.injected: Dict[str, int] = {}

    def trip(self, cls: str) -> bool:
        p = self.policy.rate(cls)
        if p <= 0.0 or self._rng.random_sample() >= p:
            return False
        self.record(cls)
        return True

    def record(self, cls: str) -> None:
        self.injected[cls] = self.injected.get(cls, 0) + 1
        if self._metrics is not None:
            self._metrics.counter(
                "chaos_injected_total", help="faults injected by the chaos harness"
            ).inc()
            self._metrics.counter(
                f"chaos_{cls}_total", help=f"injected {cls} faults"
            ).inc()


class ChaosProducer:
    """Wrap any ChunkProducer with seeded producer-side fault injection.

    Fault precedence per poll: exception > stall > slow_drip; corruption
    applies to whatever rows the inner producer returned.  After an injected
    exception the producer is dead (a crashed connection does not come
    back): further polls raise again until the scheduler quarantines the
    stream — which it does on the first one.
    """

    def __init__(
        self,
        inner,
        policy: ChaosPolicy,
        stream_id: str = "",
        metrics=None,
    ):
        from repro.stream.ingest import as_producer

        self.inner = as_producer(inner)
        self.injector = FaultInjector(policy, f"producer:{stream_id}", metrics)
        self._crashed = False

    @property
    def injected(self) -> Dict[str, int]:
        return self.injector.injected

    def poll(self, max_rows: int) -> Optional[np.ndarray]:
        if self._crashed:
            raise ChaosProducerError("producer already crashed")
        if self.injector.trip("producer_exception"):
            self._crashed = True
            raise ChaosProducerError("injected producer crash")
        if self.injector.trip("producer_stall"):
            return None
        if self.injector.trip("slow_drip"):
            max_rows = min(max_rows, 1)
        rows = self.inner.poll(max_rows)
        if rows is None or not rows.shape[0]:
            return rows
        if self.injector.trip("corrupt_nan"):
            rows = self._poison(rows, np.nan)
        if self.injector.trip("corrupt_inf"):
            rows = self._poison(rows, np.inf)
        if self.injector.trip("corrupt_shape"):
            rows = rows[:, :-1] if rows.shape[1] > 1 else np.repeat(rows, 2, axis=1)
        return rows

    def _poison(self, rows: np.ndarray, value: float) -> np.ndarray:
        rows = np.array(rows, dtype=np.float32)
        r = self.injector._rng
        rows[r.randint(rows.shape[0]), r.randint(rows.shape[1])] = value
        return rows

    @property
    def exhausted(self) -> bool:
        return not self._crashed and self.inner.exhausted


class ChaosClock:
    """Monotonic clock with seeded forward jumps (clock skew on a
    rate-limited producer: rows burst out early, arrival-invariance keeps
    the decode identical).  Pass as ``RateLimitedProducer(..., clock=...)``.
    """

    def __init__(self, policy: ChaosPolicy, max_skew_s: float = 0.25,
                 clock=None, metrics=None):
        import time

        self._clock = clock or time.monotonic
        self._skew = 0.0
        self._max_skew_s = max_skew_s
        self.injector = FaultInjector(policy, "clock", metrics)

    def __call__(self) -> float:
        if self.injector.trip("clock_skew"):
            # forward-only: a monotonic clock never runs backwards, but NTP
            # steps and VM freezes make it jump ahead
            self._skew += self.injector._rng.random_sample() * self._max_skew_s
        return self._clock() + self._skew


def install_tick_faults(sched, policy: ChaosPolicy) -> FaultInjector:
    """Arm ``sched`` with simulated device-step failures: each tick's step
    phase raises :class:`InjectedDeviceFault` with the policy's probability.
    The scheduler survives by construction — the fault fires before any
    carried state is reassigned, the tick is dropped and counted
    (``stream_tick_device_failures_total``), and the next tick retries the
    same gather.  Returns the injector (its ``injected`` dict is the
    harness-side ledger).  Uninstall with ``sched.tick_fault_hook = None``.
    """
    injector = FaultInjector(policy, "tick", sched.telemetry.metrics)

    def hook(tick: int) -> None:
        if injector.trip("device_step_failure"):
            raise InjectedDeviceFault(f"injected device failure at tick {tick}")

    sched.tick_fault_hook = hook
    return injector
