"""Chunk producers + backpressure types — the online-ingestion layer.

The scheduler no longer needs a stream's whole (T, ·) table up front:
callers open a stream, feed rows as they arrive (``submit_chunk``), and
close it when the connection ends.  This module is the adapter layer between
whatever is producing symbols — a generator, a polling callback, a socket
reader thread — and that chunk-fed scheduler API:

  ChunkProducer       the pull protocol the scheduler polls every tick:
                      ``poll(max_rows)`` returns up to max_rows new rows (or
                      None when nothing is ready yet), ``exhausted`` flips
                      when the source has ended.
  GeneratorProducer   wraps any iterator/generator of row arrays; chunks
                      larger than the scheduler's credit are split and the
                      remainder buffered, so arbitrary arrival sizes respect
                      backpressure.
  CallableProducer    wraps a poll function ``fn(max_rows) -> rows | None``
                      (raise StopIteration to end the stream) — the shape a
                      rate-limited or device-driven source naturally takes.
  PushProducer        thread-safe bounded buffer for push-style sources: a
                      socket reader or asyncio callback ``feed()``s rows from
                      its own thread/task and ``close()``s at EOF; the
                      scheduler drains it from the tick loop.

  StreamBusy          raised by ``StreamScheduler.submit_chunk`` when a
                      stream's bounded input queue cannot take the chunk;
                      carries the remaining ``credit`` so callers throttle
                      instead of guessing.

Backpressure contract: every producer is polled with the stream's current
credit (max_buffered - rows not yet consumed by the decoder) and must return
at most that many rows; direct ``submit_chunk`` callers get the same signal
as a returned credit count, or ``StreamBusy`` when they overrun it.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Deque, Iterable, Iterator, List, Optional, Protocol, Union

import numpy as np


class StreamBusy(RuntimeError):
    """A stream's bounded input queue cannot accept the offered chunk.

    Attributes:
      stream_id: the stream whose queue is full.
      credit: rows the queue can still take right now (retry with a chunk of
        at most this many rows, or wait for ticks to drain the queue).
      retry_after_ticks: scheduler's drain-rate estimate of how many ticks
        until the queue can take this chunk — back off this many ticks
        instead of hot-spinning (see RateLimitedProducer.pump).
    """

    def __init__(
        self,
        stream_id: str,
        credit: int,
        offered: int,
        retry_after_ticks: int = 1,
    ):
        self.stream_id = stream_id
        self.credit = credit
        self.offered = offered
        self.retry_after_ticks = retry_after_ticks
        super().__init__(
            f"stream {stream_id!r} queue full: offered {offered} rows, "
            f"credit {credit} — retry in ~{retry_after_ticks} tick(s) or "
            "send <= credit rows"
        )


class ChunkProducer(Protocol):
    """What the scheduler polls each tick for a producer-fed stream."""

    def poll(self, max_rows: int) -> Optional[np.ndarray]:
        """Return up to ``max_rows`` new (t, ·) rows, or None when no data is
        ready yet.  Must never return more than ``max_rows`` rows."""
        ...

    @property
    def exhausted(self) -> bool:
        """True once the source has ended and every buffered row has been
        handed out — the scheduler then closes the stream."""
        ...


def _as_rows(rows) -> np.ndarray:
    out = np.asarray(rows, dtype=np.float32)
    if out.ndim != 2:
        raise ValueError(f"producer rows must be 2-D (t, width), got {out.shape}")
    return out


class _CreditPolledProducer:
    """Shared pull-producer core: leftover splitting + the fill-credit loop.

    Subclasses implement ``_pull(max_rows) -> rows | None`` — None (or an
    empty array) means nothing ready right now, StopIteration means the
    source has ended.  ``poll`` keeps pulling until the credit is filled,
    the source pauses, or it ends: one source chunk per poll would cap
    ingest at a chunk per TICK and leave the rest of the credit idle, and a
    chunk bigger than the credit is split with the remainder served on
    later polls, so arbitrary arrival sizes honor backpressure."""

    def __init__(self):
        self._leftover: Optional[np.ndarray] = None
        self._done = False

    def _pull(self, max_rows: int) -> Optional[np.ndarray]:
        raise NotImplementedError

    def poll(self, max_rows: int) -> Optional[np.ndarray]:
        if max_rows <= 0:
            return None
        parts: List[np.ndarray] = []
        took = 0
        if self._leftover is not None:
            out, rest = self._leftover[:max_rows], self._leftover[max_rows:]
            parts.append(out)
            took = out.shape[0]
            self._leftover = rest if rest.shape[0] else None
        while took < max_rows and not self._done and self._leftover is None:
            try:
                got = self._pull(max_rows - took)
            except StopIteration:
                self._done = True
                break
            if got is None:
                break
            got = _as_rows(got)
            if not got.shape[0]:
                break
            out, rest = got[: max_rows - took], got[max_rows - took :]
            parts.append(out)
            took += out.shape[0]
            self._leftover = rest if rest.shape[0] else None
        return np.concatenate(parts, axis=0) if took else None

    @property
    def exhausted(self) -> bool:
        return self._done and self._leftover is None


class GeneratorProducer(_CreditPolledProducer):
    """ChunkProducer over any iterator/generator of (t, ·) row arrays."""

    def __init__(self, source: Union[Iterable, Iterator]):
        super().__init__()
        self._it = iter(source)

    def _pull(self, max_rows: int) -> Optional[np.ndarray]:
        return next(self._it)  # StopIteration propagates = end of stream


class CallableProducer(_CreditPolledProducer):
    """ChunkProducer over a poll function ``fn(max_rows) -> rows | None``.

    ``fn`` returns None (or an empty array) when nothing is ready and raises
    StopIteration when the source has ended."""

    def __init__(self, fn: Callable[[int], Optional[np.ndarray]]):
        super().__init__()
        self._fn = fn

    def _pull(self, max_rows: int) -> Optional[np.ndarray]:
        return self._fn(max_rows)


class PushProducer:
    """Thread-safe bounded buffer for push-style (socket / async) sources.

    The I/O side calls ``feed(rows)`` from its own thread or event-loop task
    — a socket reader pushing demodulated symbols, an asyncio protocol's
    ``data_received`` — and ``close()`` at EOF; the scheduler's tick loop
    polls rows back out.  ``feed`` raises StreamBusy when the buffer is full
    (``block=False``) or blocks until the tick loop drains it (default), so
    backpressure propagates all the way to the source:

        prod = PushProducer(max_rows=4 * chunk)
        sched.open_stream("uplink-7", producer=prod)
        # in the reader thread / protocol callback:
        prod.feed(symbol_rows)          # blocks when the decoder lags
        prod.close()                    # on EOF
    """

    def __init__(self, max_rows: int = 4096):
        if max_rows < 1:
            raise ValueError("max_rows must be >= 1")
        self.max_rows = max_rows
        self._chunks: Deque[np.ndarray] = deque()
        self._buffered = 0
        self._closed = False
        self._cv = threading.Condition()

    def feed(self, rows, block: bool = True, timeout: Optional[float] = None) -> None:
        import time

        rows = _as_rows(rows)
        if not rows.shape[0]:
            return
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if self._closed:
                raise RuntimeError("PushProducer is closed")
            if not block and self._buffered + rows.shape[0] > self.max_rows:
                raise StreamBusy(
                    "<push-producer>", self.max_rows - self._buffered, rows.shape[0]
                )
            while self._buffered + rows.shape[0] > self.max_rows:
                # a single deadline across wake-ups: partial drains notify the
                # condition, and a per-wait timeout would reset on every one
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise StreamBusy(
                        "<push-producer>", self.max_rows - self._buffered,
                        rows.shape[0],
                    )
                if not self._cv.wait(remaining):
                    raise StreamBusy(
                        "<push-producer>", self.max_rows - self._buffered,
                        rows.shape[0],
                    )
            self._chunks.append(rows)
            self._buffered += rows.shape[0]

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def poll(self, max_rows: int) -> Optional[np.ndarray]:
        if max_rows <= 0:
            return None
        with self._cv:
            if not self._chunks:
                return None
            parts: List[np.ndarray] = []
            took = 0
            while self._chunks and took < max_rows:
                head = self._chunks[0]
                take = min(head.shape[0], max_rows - took)
                parts.append(head[:take])
                if take == head.shape[0]:
                    self._chunks.popleft()
                else:
                    self._chunks[0] = head[take:]
                took += take
            self._buffered -= took
            self._cv.notify_all()
        return np.concatenate(parts, axis=0) if parts else None

    @property
    def exhausted(self) -> bool:
        with self._cv:
            return self._closed and not self._chunks


class RateLimitedProducer:
    """Release an in-memory (T, ·) table at ``rows_per_s`` — the steady-state
    load model the ``--online`` benchmark drives the scheduler with (and a
    handy stand-in for a live feed in examples/tests).

    Rows become available as the clock advances (fractional accumulation, so
    low rates work); ``poll`` hands out whatever is both available and within
    the scheduler's credit, stamping arrival times for latency accounting.
    """

    def __init__(self, table: np.ndarray, rows_per_s: float, clock=None):
        import time

        self._table = _as_rows(table)
        self._rate = float(rows_per_s)
        self._clock = clock or time.monotonic
        self._t0 = self._clock()
        self._served = 0
        #: (end_row_exclusive, arrival_time) per released chunk — the
        #: latency bookkeeping the benchmark reads.
        self.arrivals: List[tuple] = []
        # push-side (pump) state: rows a StreamBusy refused, ticks left to
        # back off, and the convergence counters tests/benches assert on
        self._hold: Optional[np.ndarray] = None
        self._backoff = 0
        self._closed_sent = False
        self.busy_events = 0  # StreamBusy raised against this producer
        self.skipped_pumps = 0  # pump calls skipped while backing off

    def poll(self, max_rows: int) -> Optional[np.ndarray]:
        if max_rows <= 0 or self._served >= self._table.shape[0]:
            return None
        now = self._clock()
        released = int((now - self._t0) * self._rate)
        ready = min(released, self._table.shape[0]) - self._served
        n = min(ready, max_rows)
        if n <= 0:
            return None
        out = self._table[self._served : self._served + n]
        self._served += n
        self.arrivals.append((self._served, now))
        return out

    @property
    def exhausted(self) -> bool:
        return self._served >= self._table.shape[0]

    def pump(self, sched, stream_id: str, *, close: bool = True) -> int:
        """Push-side driver honoring ``StreamBusy.retry_after_ticks``.

        Call once per scheduler tick from the serving loop (instead of
        attaching the producer for pull-side polling): releases whatever the
        rate limit has made available and submits it with ``submit_chunk``.
        On StreamBusy the refused rows are held and the next
        ``retry_after_ticks`` pump calls are skipped entirely — the backoff
        loop converges to the drain rate instead of hot-spinning one
        rejected submit per tick (``busy_events`` / ``skipped_pumps`` count
        both sides, so tests can assert convergence).  Returns the rows
        accepted this call; closes the stream at EOF when ``close``.
        """
        if self._backoff > 0:
            self._backoff -= 1
            self.skipped_pumps += 1
            return 0
        rows = self._hold
        self._hold = None
        if rows is None:
            rows = self.poll(self._table.shape[0])
        accepted = 0
        if rows is not None and rows.shape[0]:
            try:
                sched.submit_chunk(stream_id, rows)
                accepted = rows.shape[0]
            except StreamBusy as e:
                self.busy_events += 1
                if e.credit > 0:
                    # partial acceptance: fill the remaining credit now
                    # (guaranteed to fit) and hold only the overflow
                    sched.submit_chunk(stream_id, rows[: e.credit])
                    accepted = e.credit
                self._hold = rows[accepted:]
                self._backoff = max(1, int(e.retry_after_ticks))
        if (
            close
            and not self._closed_sent
            and self.exhausted
            and self._hold is None
        ):
            sched.close(stream_id)
            self._closed_sent = True
        return accepted


def as_producer(source) -> ChunkProducer:
    """Coerce a source to a ChunkProducer: producers pass through, callables
    become CallableProducer, iterables/generators become GeneratorProducer."""
    if hasattr(source, "poll") and hasattr(source, "exhausted"):
        return source
    if callable(source):
        return CallableProducer(source)
    return GeneratorProducer(source)
