"""Truncated-traceback sliding-window Viterbi — the streaming core.

Classic Viterbi hardware never materializes the full trellis: after D ≈ 5·K
steps all survivor paths merge with overwhelming probability, so a decoder
that traces back D steps from the current best state and commits everything
older is (a) within noise of the full-block optimum and (b) O(D) memory for
a stream of any length (Martina & Masera 2010, §Viterbi traceback units).

This module is the jittable core shared by sessions and the scheduler:

  StreamState     pytree carried across chunks: path metrics (B, S) and a
                  backpointer ring buffer — (R, B, S) int32 for the unpacked
                  backends, (R/32, B, S) uint32 packed survivor words for
                  ``fused_packed`` (R = depth + chunk).
  stream_step     advance C trellis steps (fused Pallas chunk scan, the
                  packed-survivor scan, or a lax.scan reference), shift the
                  ring, traceback from the frontier, and commit the C oldest
                  window positions.
  stream_flush    final traceback over the whole ring at end of stream.
  viterbi_decode_windowed
                  offline (B, T, M) -> (B, T) decode through the streaming
                  machinery — the equivalence oracle used by the tests.

Backends: ``fused`` (Pallas chunk scan, unpacked int32 ring, XLA traceback),
``scan`` (jnp reference), and ``fused_packed`` — the memory-lean hot path:
bit-packed survivor ring (32× smaller), word-aligned ring shifts (requires
chunk % 32 == 0 and depth % 32 == 0, sessions round the depth up), Pallas
traceback over the packed words, and optional in-kernel branch metrics when
the caller feeds raw received symbols + folded metric weights.

Exactness: when depth >= T nothing commits before the flush, the ring holds
the whole history, and the flush traceback from the terminated state IS the
full-block Viterbi traceback — bit-identical to core.viterbi.viterbi_decode.
Away from that regime the committed prefix differs from the full-block
decode only where survivor paths fail to merge within D steps.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.acs import acs_step
from repro.core.trellis import NEG_UNREACHABLE, ConvCode
from repro.core.viterbi import _initial_pm, _traceback
from repro.kernels.common import PACK_BITS

BIG = jnp.float32(NEG_UNREACHABLE)

DEPTH_MULTIPLIER = 5  # the textbook truncation rule: D = 5 * constraint

PACKED_BACKEND = "fused_packed"


def default_depth(code: ConvCode) -> int:
    return DEPTH_MULTIPLIER * code.constraint


def packed_depth(depth: int) -> int:
    """Round a traceback depth up to the packed ring's word granularity.
    A deeper window only improves accuracy; the session lag grows with it."""
    return -(-depth // PACK_BITS) * PACK_BITS


def resolve_stream_backend(spec, chunk: int, depth: int, backend: str, inputs: str):
    """Shared session/scheduler backend setup: validate the input kind,
    round the depth for the packed ring, and build the in-kernel metric plan.

    Returns (packed, depth, plan, weights): ``plan`` is the FusedMetricPlan
    for the packed backend (None otherwise); ``weights`` its folded kernel
    operands when raw symbols are fed (None -> bm-table weights).
    """
    packed = backend == PACKED_BACKEND
    if inputs not in ("bm", "received"):
        raise ValueError(f"inputs must be 'bm' or 'received', got {inputs!r}")
    if inputs == "received" and not packed:
        raise ValueError("inputs='received' needs the fused_packed backend")
    plan = weights = None
    if packed:
        if chunk % PACK_BITS:
            raise ValueError(
                f"{PACKED_BACKEND} streaming needs chunk % {PACK_BITS} == 0"
            )
        depth = packed_depth(depth)
        from repro.kernels.metrics import fused_metric_plan

        plan = fused_metric_plan(spec.code, spec.metric, spec.puncture_array)
        if inputs == "received":
            weights = plan.folded()
    return packed, depth, plan, weights


class DeviceCounters(NamedTuple):
    """Per-slot decode statistics accumulated INSIDE the jitted tick.

    Every field is a (B,) array living on device; the scheduler/session
    carries the pytree across ticks like any other state and materializes it
    host-side only at drain / report time — device telemetry never adds a
    per-tick host sync.  This is the raw signal the adaptive-traceback-depth
    work consumes: ``merge_depth_*`` track the all-states-agree depth of the
    survivor ring (how far back the traceback must really reach), and
    ``renorm_sum`` the accumulated path-metric renormalization magnitude
    (a proxy for channel quality drift).

    ticks:            active ticks this slot advanced through.
    starved_ticks:    ticks the slot sat admitted-but-masked (no full chunk).
    merge_depth_last: survivor merge depth after the latest active tick.
    merge_depth_sum:  sum of per-tick merge depths (mean = sum / ticks).
    merge_depth_max:  worst merge depth observed.
    renorm_sum:       accumulated |path-metric renormalization offset|.
    """

    ticks: jnp.ndarray
    starved_ticks: jnp.ndarray
    merge_depth_last: jnp.ndarray
    merge_depth_sum: jnp.ndarray
    merge_depth_max: jnp.ndarray
    renorm_sum: jnp.ndarray


def init_device_counters(batch: int) -> DeviceCounters:
    z_i = jnp.zeros((batch,), dtype=jnp.int32)
    z_f = jnp.zeros((batch,), dtype=jnp.float32)
    return DeviceCounters(
        ticks=z_i, starved_ticks=z_i, merge_depth_last=z_i,
        merge_depth_sum=z_f, merge_depth_max=z_i, renorm_sum=z_f,
    )


def survivor_merge_depth(code: ConvCode, ring: jnp.ndarray) -> jnp.ndarray:
    """All-states-agree depth of a survivor ring: the smallest d such that
    tracing back d steps from the frontier collapses every state's survivor
    path onto one trellis node (R + 1 when the window never merges).

    Classic truncated-traceback theory commits bits older than the merge
    point losslessly — so this, tracked per stream, is exactly the signal an
    adaptive-depth controller needs (cf. the tile-merge convergence of GPU
    tile-parallel decoders).  ``ring``: (R, B, S) int32 backpointer parities
    or packed (R/32, B, S) uint32 words; returns (B,) int32.

    Cost: an S-walker vectorized traceback over the ring — same O(R) gather
    structure as the per-tick committed-bit traceback, S lanes wide; only
    run when device counters are enabled.
    """
    if ring.dtype == jnp.uint32:
        ring = unpack_ring(code, ring)
    R, B, S = ring.shape
    half = S // 2
    walkers0 = jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None, :], (B, S)
    )

    def step(walkers, bp_t):  # walkers: (B, S) current state of each walker
        j = jnp.take_along_axis(bp_t, walkers, axis=1)
        v = walkers & (half - 1) if half > 1 else jnp.zeros_like(walkers)
        prev = 2 * v + j
        merged = (prev == prev[:, :1]).all(axis=1)
        return prev, merged

    # reverse scan: merged[i] == "walkers coalesced after absorbing steps
    # R-1 .. i", i.e. within depth R - i of the frontier.  Coalesced walkers
    # stay coalesced, so merged is monotone in depth; the merge depth is the
    # shallowest True.
    _, merged = jax.lax.scan(step, walkers0, ring.astype(jnp.int32), reverse=True)
    idx = jnp.where(
        merged, jnp.arange(R, dtype=jnp.int32)[:, None], jnp.int32(-1)
    ).max(axis=0)
    return jnp.where(idx >= 0, R - idx, R + 1).astype(jnp.int32)


class StreamState(NamedTuple):
    """Carried decode state — everything a stream needs across chunks.

    pm:   (B, S) float32 path metrics at the stream frontier (renormalized,
          see stream_step).
    ring: backpointer ring over the last R = depth + chunk steps; slot i
          holds the backpointers of absolute step ``t - R + i`` (pre-stream
          slots hold zeros and are never committed by the session
          bookkeeping).  (R, B, S) int32 unpacked, or (R/32, B, S) uint32
          survivor words for the packed backend.
    """

    pm: jnp.ndarray
    ring: jnp.ndarray


def init_stream_state(
    code: ConvCode, batch: int, depth: int, chunk: int, packed: bool = False
) -> StreamState:
    """Fresh state: paths start in state 0 (paper §IV-B), empty ring."""
    R = depth + chunk
    if packed:
        if R % PACK_BITS:
            raise ValueError(
                f"packed ring needs (depth + chunk) % {PACK_BITS} == 0, "
                f"got depth={depth}, chunk={chunk} (see packed_depth())"
            )
        ring = jnp.zeros((R // PACK_BITS, batch, code.n_states), dtype=jnp.uint32)
    else:
        ring = jnp.zeros((R, batch, code.n_states), dtype=jnp.int32)
    return StreamState(pm=_initial_pm(code, (batch,)), ring=ring)


def chunk_forward_scan(
    code: ConvCode, pm: jnp.ndarray, bm_chunk: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """lax.scan reference for the chunked forward pass (oracle for the fused
    kernels.ops chunk ops, and the path used for odd-length stream tails).
    pm: (B, S); bm_chunk: (B, C, M) -> (new_pm, bps (C, B, S)).
    """

    def step(pm, bm_t):
        new_pm, bp = acs_step(code, pm, bm_t)
        return jnp.minimum(new_pm, BIG), bp

    return jax.lax.scan(step, pm, bm_chunk.swapaxes(0, 1))


def stream_step(
    code: ConvCode,
    state: StreamState,
    chunk_inputs: jnp.ndarray,
    weights=None,
    active: Optional[jnp.ndarray] = None,
    backend: str = "fused",
    normalize: bool = True,
    interpret: Optional[bool] = None,
    counters: Optional[DeviceCounters] = None,
) -> Tuple[StreamState, jnp.ndarray, jnp.ndarray]:
    """One streaming update: advance C steps, commit the C oldest positions.

    Args:
      chunk_inputs: (B, C, M) branch metrics — or, for the packed backend
        with in-kernel metrics, (B, C, F) raw features matching ``weights``.
      weights: (b0, b1, rb) folded metric weights for ``fused_packed``
        (None -> the bm-table weights; ignored by the other backends).
      active: optional (B,) bool mask — rows where it is False keep their
        pm/ring/offset EXACTLY as they were (the batched kernel still runs
        over them, but its result is discarded row-wise).  This is how the
        chunk-fed scheduler lets a starved slot idle without corrupting its
        carried state: advancing a real stream with zero branch metrics is
        NOT a no-op (the ACS min mixes predecessor metrics and pushes
        garbage backpointers into the ring), so masked slots must be
        re-selected, not just fed zeros.  None == all rows active.
      backend: 'fused' (Pallas chunk scan), 'fused_packed' (packed
        survivors + in-kernel metrics + Pallas traceback; C % 32 == 0), or
        'scan' (jnp reference).
      normalize: subtract the per-stream min from the path metrics so an
        unbounded stream never overflows float32; the subtracted offset is
        returned so callers can reconstruct absolute metrics.
      counters: optional DeviceCounters pytree to advance inside the jitted
        step (merge depth, starved ticks, renorm magnitude).  When given the
        return value grows a fourth element — the updated counters — and the
        traced computation gains the S-walker merge-depth scan; rows masked
        inactive keep their last merge depth and count a starved tick.

    Returns:
      new_state: state after the chunk (ring shifted by C).
      committed: (B, C) decoded bits for the C oldest window positions —
        positions [t - R, t - D) where t is the new frontier.  The caller
        masks off any that predate the stream start (session bookkeeping);
        rows masked inactive hold garbage the caller must ignore.
      offset_delta: (B,) the amount subtracted from the path metrics (0 for
        masked rows).
      counters: updated DeviceCounters — only when ``counters`` was passed.
    """
    pm, ring = state
    C = chunk_inputs.shape[1]
    if backend == PACKED_BACKEND:
        from repro.kernels.ops import viterbi_forward_weighted_op, viterbi_traceback_op
        from repro.kernels.viterbi_scan import table_weights

        if C % PACK_BITS:
            raise ValueError(f"{PACKED_BACKEND} needs chunk % {PACK_BITS} == 0, got {C}")
        w = table_weights(code) if weights is None else weights
        new_pm, packed = viterbi_forward_weighted_op(
            code, pm, chunk_inputs, w, interpret
        )
        ring = jnp.concatenate([ring[C // PACK_BITS :], packed], axis=0)
        best = jnp.argmin(new_pm, axis=-1).astype(jnp.int32)
        R = ring.shape[0] * PACK_BITS
        bits = viterbi_traceback_op(code, ring, best, R, interpret)  # (B, R)
    else:
        if backend == "fused":
            from repro.kernels.ops import viterbi_forward_chunk_op

            new_pm, bps = viterbi_forward_chunk_op(code, pm, chunk_inputs, interpret)
        elif backend == "scan":
            new_pm, bps = chunk_forward_scan(code, pm, chunk_inputs)
        else:
            raise KeyError(backend)
        ring = jnp.concatenate([ring[C:], bps], axis=0)
        # truncated traceback: from the best frontier state back through the
        # whole window; only the positions >= depth behind the frontier commit.
        best = jnp.argmin(new_pm, axis=-1).astype(jnp.int32)
        bits, _ = _traceback(code, ring, best)  # (B, R)
    committed = bits[:, :C]

    if normalize:
        delta = new_pm.min(axis=-1)
        new_pm = jnp.minimum(new_pm - delta[:, None], BIG)
    else:
        delta = jnp.zeros(new_pm.shape[:1], dtype=new_pm.dtype)
    if active is not None:
        keep = active.astype(jnp.bool_)
        new_pm = jnp.where(keep[:, None], new_pm, pm)
        ring = jnp.where(keep[None, :, None], ring, state.ring)
        delta = jnp.where(keep, delta, jnp.zeros_like(delta))
    new_state = StreamState(pm=new_pm, ring=ring)
    if counters is None:
        return new_state, committed, delta
    act = (
        active.astype(jnp.bool_)
        if active is not None
        else jnp.ones(new_pm.shape[:1], dtype=jnp.bool_)
    )
    # merge depth on the post-mask ring: inactive rows kept their ring, so
    # the recomputed value equals their previous one — jnp.where keeps the
    # bookkeeping explicit anyway.
    md = survivor_merge_depth(code, ring)
    advanced = act.astype(jnp.int32)
    counters = DeviceCounters(
        ticks=counters.ticks + advanced,
        starved_ticks=counters.starved_ticks + (1 - advanced),
        merge_depth_last=jnp.where(act, md, counters.merge_depth_last),
        merge_depth_sum=counters.merge_depth_sum
        + jnp.where(act, md, 0).astype(jnp.float32),
        merge_depth_max=jnp.maximum(counters.merge_depth_max, md * advanced),
        renorm_sum=counters.renorm_sum + jnp.abs(delta).astype(jnp.float32),
    )
    return new_state, committed, delta, counters


def state_shardings(mesh, axis: str):
    """NamedShardings that partition a StreamState along its batch/slot
    dimension: pm (B, S) on axis 0, ring (R, B, S) on axis 1.  The layout
    every mesh-aware stream component (sessions, the sharded scheduler)
    shares, so carried pytrees move between them without resharding."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    return StreamState(
        pm=NamedSharding(mesh, P(axis, None)),
        ring=NamedSharding(mesh, P(None, axis, None)),
    )


def shard_stream_state(mesh, axis: str, state: StreamState) -> StreamState:
    """Pin a StreamState to the per-shard layout (no-op when already there)."""
    sh = state_shardings(mesh, axis)
    return StreamState(
        pm=jax.device_put(state.pm, sh.pm), ring=jax.device_put(state.ring, sh.ring)
    )


#: (code, mesh, axis, chunk, backend, normalize, interpret, device_metrics)
#: -> tick; see make_sharded_stream_step (only weight-free configs are
#: memoizable).
_SHARDED_STEP_CACHE: dict = {}


def make_sharded_stream_step(
    code: ConvCode,
    mesh,
    axis: str,
    *,
    chunk: int,
    backend: str = "fused",
    normalize: bool = True,
    interpret: Optional[bool] = None,
    weights=None,
    device_metrics: bool = False,
):
    """Build the mesh-sharded per-tick update for the stream scheduler.

    One shard_map spans the ``axis`` (``data``) mesh axis: each shard holds a
    contiguous block of decode slots, its slice of the input arena, and its
    slice of the survivor ring, and runs the tick — arena gather + forward +
    in-window traceback — entirely shard-locally.  There is NO cross-shard
    communication on the hot path (slots are independent streams); the only
    global coordination is the host-side admit/retire bookkeeping and the
    scalar reductions in parallel.collectives.

    Returns ``tick(arena, idx, active, state) -> (state, committed_bits,
    delta)`` where ``arena`` is the (n_shards, cap, W) stacked per-shard
    arena, ``idx`` the (n_slots, chunk) shard-LOCAL arena rows each slot
    decodes this tick (idle/starved slots point at the zero prefix — row
    indices rather than a base offset, because a chunk-fed stream's rows
    need not be contiguous in the arena), ``active`` the (n_slots,) bool
    mask of slots whose carried state actually advances (see stream_step),
    and the outputs keep the per-shard layout of ``state_shardings``.

    Ticks without custom ``weights`` are memoized on the static config (like
    jitted_stream_step), so every scheduler on the same (code, mesh, ...)
    shares one executable per shape instead of re-tracing per instance.

    With ``device_metrics=True`` the tick carries a DeviceCounters pytree —
    ``tick(arena, idx, active, state, counters)`` returning ``(state, bits,
    delta, counters)`` — with every (B,)-shaped counter leaf sharded P(axis)
    alongside the slots it describes, still shard-local (no collectives).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    cache_key = None
    if weights is None:
        cache_key = (code, mesh, axis, chunk, backend, normalize, interpret,
                     device_metrics)
        cached = _SHARDED_STEP_CACHE.get(cache_key)
        if cached is not None:
            return cached

    packed = backend == PACKED_BACKEND
    if packed and weights is None:
        from repro.kernels.viterbi_scan import table_weights

        weights = table_weights(code)

    n_counters = len(DeviceCounters._fields) if device_metrics else 0

    def local_tick(arena, idx, active, pm, ring, *rest):
        # arena: (1, cap, W) — this shard's slab; idx: (slots_per_shard, C)
        ctr = DeviceCounters(*rest[:n_counters]) if device_metrics else None
        w = rest[n_counters:]
        block = jnp.take(arena[0], idx, axis=0)  # (slots_per_shard, chunk, W)
        out = stream_step(
            code,
            StreamState(pm=pm, ring=ring),
            block,
            weights=w[0] if w else None,
            active=active,
            backend=backend,
            normalize=normalize,
            interpret=interpret,
            counters=ctr,
        )
        if device_metrics:
            state, bits, delta, ctr = out
            return (state.pm, state.ring, bits, delta) + tuple(ctr)
        state, bits, delta = out
        return state.pm, state.ring, bits, delta

    ctr_specs = tuple(P(axis) for _ in range(n_counters))
    w_specs: tuple = ()
    w_args: tuple = ()
    if packed:
        w_specs = tuple(P(*([None] * jnp.asarray(a).ndim)) for a in weights)
        w_args = (weights,)
    fn = jax.jit(
        shard_map(
            local_tick,
            mesh=mesh,
            in_specs=(P(axis, None, None), P(axis, None), P(axis),
                      P(axis, None), P(None, axis, None))
            + ctr_specs
            + ((w_specs,) if packed else ()),
            out_specs=(P(axis, None), P(None, axis, None), P(axis, None),
                       P(axis)) + ctr_specs,
            check_rep=False,
        )
    )

    if device_metrics:

        def tick(arena, idx, active, state: StreamState, counters: DeviceCounters):
            out = fn(arena, idx, active, state.pm, state.ring,
                     *tuple(counters), *w_args)
            pm, ring, bits, delta = out[:4]
            return (StreamState(pm=pm, ring=ring), bits, delta,
                    DeviceCounters(*out[4:]))

    else:

        def tick(arena, idx, active, state: StreamState):
            pm, ring, bits, delta = fn(
                arena, idx, active, state.pm, state.ring, *w_args
            )
            return StreamState(pm=pm, ring=ring), bits, delta

    if cache_key is not None:
        _SHARDED_STEP_CACHE[cache_key] = tick
    return tick


@functools.lru_cache(maxsize=None)
def jitted_stream_step(
    code: ConvCode,
    backend: str = "fused",
    normalize: bool = True,
    interpret: Optional[bool] = None,
):
    """Compiled stream_step, cached on the static config so every session and
    scheduler with the same (code, backend, flags) shares one executable per
    (batch, chunk) shape instead of re-tracing per instance.  The returned
    callable takes (state, chunk_inputs[, weights[, active[, counters]]]);
    passing ``counters=DeviceCounters(...)`` (a different pytree structure
    from the default None) traces the device-metrics variant, which returns
    the 4-tuple — the jit cache keeps both specializations apart."""
    return jax.jit(
        functools.partial(
            stream_step, code, backend=backend, normalize=normalize, interpret=interpret
        )
    )


def unpack_ring(code: ConvCode, ring: jnp.ndarray) -> jnp.ndarray:
    """Packed (R/32, B, S) uint32 ring -> unpacked (R, B, S) int32 — the
    off-hot-path escape hatch for odd-length tails and batched flushes."""
    from repro.kernels.survivors import unpack_survivors

    return unpack_survivors(ring, ring.shape[0] * PACK_BITS)


def stream_flush(
    code: ConvCode,
    state: StreamState,
    terminated: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """End-of-stream traceback over the full ring (packed or unpacked).

    Returns:
      bits: (B, R) bits for every ring position (caller slices the still-
        uncommitted tail).
      metric: (B,) winning path metric at the frontier (relative — add the
        session's accumulated normalization offset for the absolute value).
    """
    pm, ring = state
    B = pm.shape[0]
    if terminated:
        final_state = jnp.zeros((B,), dtype=jnp.int32)
        metric = pm[:, 0]
    else:
        final_state = jnp.argmin(pm, axis=-1).astype(jnp.int32)
        metric = pm.min(axis=-1)
    if ring.dtype == jnp.uint32:
        from repro.kernels.ops import viterbi_traceback_op

        bits = viterbi_traceback_op(
            code, ring, final_state, ring.shape[0] * PACK_BITS, interpret
        )
    else:
        bits, _ = _traceback(code, ring, final_state)
    return bits, metric


@functools.lru_cache(maxsize=None)
def jitted_stream_flush(
    code: ConvCode, terminated: bool = True, interpret: Optional[bool] = None
):
    """Compiled stream_flush, cached per (code, terminated).  Callers with a
    varying number of retiring streams (the scheduler's batched slot flush)
    pad the batch dimension to a fixed size so this compiles once per shape
    instead of once per cohort size."""
    return jax.jit(
        functools.partial(stream_flush, code, terminated=terminated, interpret=interpret)
    )


@functools.lru_cache(maxsize=None)
def jitted_chunk_forward(code: ConvCode):
    """Compiled chunk_forward_scan (odd-length stream tails; compiles once
    per tail length, shared across slots and sessions)."""
    return jax.jit(functools.partial(chunk_forward_scan, code))


def viterbi_decode_windowed(
    code: ConvCode,
    bm_tables: jnp.ndarray,
    depth: Optional[int] = None,
    chunk: int = 64,
    terminated: Optional[bool] = None,
    backend: str = "fused",
    normalize: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Offline sliding-window decode of a full (B, T, M) block.

    Drop-in shape-compatible with core.viterbi.viterbi_decode, but runs the
    O(depth + chunk) streaming path: bit-identical when depth >= T, and
    within truncation noise (vanishing for depth >~ 5K) otherwise.
    ``code`` may be a bare ConvCode or a full decode.CodecSpec;
    ``terminated`` defaults to the spec's flag (True for a bare code).
    """
    from repro.stream.session import StreamSession

    B = bm_tables.shape[0]
    sess = StreamSession(
        code,
        batch=B,
        chunk=chunk,
        depth=depth,
        backend=backend,
        normalize=normalize,
        interpret=interpret,
    )
    return sess.decode_all(bm_tables, terminated=terminated)
