"""Trip-count-aware cost accounting at the jaxpr level.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — with
scan-over-layers and microbatch accumulation that undercounts FLOPs by the
product of trip counts (we verified: adding microbatches=4 divided reported
FLOPs by 4).  This module walks the closed jaxpr of the step function and
counts:

  flops — dot_general counted exactly (2·M·N·K·batch); elementwise ops at
          1 flop/element; scan bodies multiplied by their length; remat
          (checkpoint) recompute included (its jaxpr is inlined by recursion)
  bytes — per-equation output bytes + input bytes, EXCLUDING pure layout ops
          (reshape/transpose/broadcast/convert/slice), a fusion-blind upper
          bound on HBM traffic, with the same trip-count multiplication.

Numbers are GLOBAL (pre-SPMD); divide by chip count for per-device terms
(valid when every large tensor is sharded, which the dry-run shardings
ensure).  Recorded next to the raw XLA numbers in every dry-run cell.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

# layout-only ops: no flops, no HBM traffic of their own after fusion
_LAYOUT_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "convert_element_type",
    "squeeze", "slice", "rev", "bitcast_convert_type", "copy",
    "stop_gradient", "dynamic_slice", "dynamic_update_slice",
    "gather", "concatenate", "pad", "iota",
}
# control/bookkeeping ops: skip entirely
_SKIP_PRIMS = {
    "add_any", "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
}


def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape)) * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0.0


def _aval_elems(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = np.prod([a.shape[i] for i in lb]) if lb else 1.0
    contract = np.prod([a.shape[i] for i in lc]) if lc else 1.0
    m = np.prod([a.shape[i] for i in range(a.ndim) if i not in lc and i not in lb]) or 1.0
    n = np.prod([b.shape[i] for i in range(b.ndim) if i not in rc and i not in rb]) or 1.0
    return 2.0 * float(batch) * float(m) * float(n) * float(contract)


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # 2 * output elems * (reduction window = rhs elems / out-features)
    feat = rhs.shape[eqn.params["dimension_numbers"].rhs_spec[0]] \
        if hasattr(eqn.params.get("dimension_numbers"), "rhs_spec") else 1
    red = float(np.prod(rhs.shape)) / max(1, feat)
    return 2.0 * _aval_elems(out) * red


def count_jaxpr(jaxpr, mult: float = 1.0) -> Dict[str, float]:
    flops = 0.0
    byts = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in _SKIP_PRIMS:
            continue
        if name == "dot_general":
            flops += mult * _dot_flops(eqn)
            byts += mult * (sum(_aval_bytes(v.aval) for v in eqn.invars)
                            + sum(_aval_bytes(v.aval) for v in eqn.outvars))
            continue
        if name in ("conv_general_dilated",):
            flops += mult * _conv_flops(eqn)
            byts += mult * (sum(_aval_bytes(v.aval) for v in eqn.invars)
                            + sum(_aval_bytes(v.aval) for v in eqn.outvars))
            continue
        if name == "scan":
            inner = count_jaxpr(eqn.params["jaxpr"].jaxpr, mult * eqn.params["length"])
            flops += inner["flops"]
            byts += inner["bytes"]
            continue
        if name == "while":
            # raw while: unknown trips -> count once (we never emit raw whiles)
            inner = count_jaxpr(eqn.params["body_jaxpr"].jaxpr, mult)
            flops += inner["flops"]
            byts += inner["bytes"]
            continue
        if name == "cond":
            branches = [count_jaxpr(b.jaxpr, mult) for b in eqn.params["branches"]]
            flops += max(b["flops"] for b in branches)
            byts += max(b["bytes"] for b in branches)
            continue
        if name in ("pjit", "remat2", "checkpoint", "custom_vjp_call_jaxpr",
                    "closed_call", "core_call", "xla_call"):
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                inner = count_jaxpr(getattr(sub, "jaxpr", sub), mult)
                flops += inner["flops"]
                byts += inner["bytes"]
            continue
        if name == "pallas_call":
            # interpret-mode kernels: count output traffic only
            byts += mult * sum(_aval_bytes(v.aval) for v in eqn.outvars)
            continue
        # default: elementwise-ish op
        out_elems = sum(_aval_elems(v.aval) for v in eqn.outvars)
        if name not in _LAYOUT_PRIMS:
            flops += mult * out_elems
            byts += mult * (sum(_aval_bytes(v.aval) for v in eqn.invars)
                            + sum(_aval_bytes(v.aval) for v in eqn.outvars))
    return {"flops": flops, "bytes": byts}


def count_fn_costs(fn, *args, **kwargs) -> Dict[str, float]:
    """Trace ``fn`` abstractly and count global trip-aware costs."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    out = count_jaxpr(closed.jaxpr)
    # count reading every input once (params, caches, batch)
    out["input_bytes"] = sum(_aval_bytes(v.aval) for v in closed.jaxpr.invars)
    return out
